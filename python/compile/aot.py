"""AOT exporter: lower every L2 graph to HLO text + a manifest.

HLO *text* is the interchange format (NOT serialized HloModuleProto):
jax ≥ 0.5 emits protos with 64-bit instruction ids which the image's
xla_extension 0.5.1 rejects; the text parser reassigns ids and
round-trips cleanly (see /opt/xla-example/README.md).

Usage: python -m compile.aot [--out DIR] [--skip-neural]
"""

import argparse
import json
import os
import sys

import jax
from jax._src.lib import xla_client as xc

from compile import model, neural


def to_hlo_text(fn, example_args):
    lowered = jax.jit(fn).lower(*example_args)
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def shapes_of(example_args):
    return [
        {"shape": list(a.shape), "dtype": str(a.dtype)}
        for a in example_args
    ]


def export_all(out_dir, skip_neural=False):
    os.makedirs(out_dir, exist_ok=True)
    manifest = {
        "format": "hlo-text",
        "batch": model.BATCH,
        "f": model.F,
        "k": model.K,
        "hash_n": model.HASH_N,
        "hash_m": model.HASH_M,
        "hash_g": model.HASH_G,
        "neural": {
            "n_users": neural.N_USERS,
            "n_items": neural.N_ITEMS,
            "embed": neural.EMBED,
            "batch": neural.BATCH,
            "eval_batch": neural.EVAL_BATCH,
        },
        "graphs": {},
    }

    for name, fn in model.GRAPHS.items():
        args = model.example_args(name)
        text = to_hlo_text(fn, args)
        path = os.path.join(out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        manifest["graphs"][name] = {
            "file": f"{name}.hlo.txt",
            "inputs": shapes_of(args),
        }
        print(f"exported {name}: {len(text)} chars")

    if not skip_neural:
        for kind in ("gmf", "mlp", "neumf"):
            step_args = neural.example_step_args(kind)
            text = to_hlo_text(neural.make_step_fn(kind), step_args)
            path = os.path.join(out_dir, f"{kind}_step.hlo.txt")
            with open(path, "w") as f:
                f.write(text)
            manifest["graphs"][f"{kind}_step"] = {
                "file": f"{kind}_step.hlo.txt",
                "inputs": shapes_of(step_args),
                "params": [
                    {"name": n, "shape": list(s)} for n, s in neural.flat_spec(kind)
                ],
            }
            score_args = neural.example_score_args(kind)
            text = to_hlo_text(neural.make_score_fn(kind), score_args)
            path = os.path.join(out_dir, f"{kind}_score.hlo.txt")
            with open(path, "w") as f:
                f.write(text)
            manifest["graphs"][f"{kind}_score"] = {
                "file": f"{kind}_score.hlo.txt",
                "inputs": shapes_of(score_args),
            }
            print(f"exported {kind} step+score")

    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"manifest written to {out_dir}/manifest.json")


def main():
    parser = argparse.ArgumentParser()
    parser.add_argument("--out", default="../artifacts")
    parser.add_argument("--skip-neural", action="store_true")
    args = parser.parse_args()
    export_all(args.out, skip_neural=args.skip_neural)


if __name__ == "__main__":
    sys.exit(main())
