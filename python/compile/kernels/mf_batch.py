"""Pallas L1 kernels: fused MF batch prediction / SGD step / RMSE chunk.

The paper's Algorithm 2 fuses, per rating, the dot product (via warp
shuffles), the error, and the register-resident factor updates. The batch
analogue fuses the same chain over a [B, F] tile: one pass over VMEM
computes the predictions, errors, and all parameter updates without ever
materializing intermediates in HBM.

The rust coordinator gathers conflict-free batches (no row or column is
repeated within a batch — the same invariant the paper's thread-block
assignment provides), so the updated rows can be scattered back without
read-modify-write hazards.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


DEFAULT_TILE_B = 256


def _sgd_kernel(
    scal_ref,
    r_ref,
    bi_ref,
    bj_ref,
    u_ref,
    v_ref,
    bi_out,
    bj_out,
    u_out,
    v_out,
    e_out,
):
    """Fused biased-MF SGD over one [TB, F] tile.

    scal_ref holds the broadcast scalars:
    [mu, gamma, lambda_b, lambda_u, lambda_v].
    """
    mu = scal_ref[0]
    gamma = scal_ref[1]
    lambda_b = scal_ref[2]
    lambda_u = scal_ref[3]
    lambda_v = scal_ref[4]
    u = u_ref[...]
    v = v_ref[...]
    bi = bi_ref[...]
    bj = bj_ref[...]
    pred = mu + bi + bj + jnp.sum(u * v, axis=-1)
    e = r_ref[...] - pred
    bi_out[...] = bi + gamma * (e - lambda_b * bi)
    bj_out[...] = bj + gamma * (e - lambda_b * bj)
    u_out[...] = u + gamma * (e[:, None] * v - lambda_u * u)
    # Eq. (5) uses the PRE-update u for v's gradient.
    v_out[...] = v + gamma * (e[:, None] * u - lambda_v * v)
    e_out[...] = e


@functools.partial(jax.jit, static_argnames=("tile_b", "interpret"))
def mf_sgd_batch(
    scalars, r, bi, bj, u, v, *, tile_b=DEFAULT_TILE_B, interpret=True
):
    """Fused batch SGD step.

    Args:
      scalars: [5] f32 = (mu, gamma, lambda_b, lambda_u, lambda_v).
      r, bi, bj: [B]. u, v: [B, F]. B must be a multiple of tile_b.

    Returns (bi', bj', u', v', e).
    """
    b, f = u.shape
    assert b % tile_b == 0, f"B={b} not a multiple of tile_b={tile_b}"
    grid = (b // tile_b,)
    vec = lambda: pl.BlockSpec((tile_b,), lambda i: (i,))
    mat = lambda: pl.BlockSpec((tile_b, f), lambda i: (i, 0))
    scal = pl.BlockSpec((5,), lambda i: (0,))
    return pl.pallas_call(
        _sgd_kernel,
        grid=grid,
        in_specs=[scal, vec(), vec(), vec(), mat(), mat()],
        out_specs=[vec(), vec(), mat(), mat(), vec()],
        out_shape=[
            jax.ShapeDtypeStruct((b,), jnp.float32),
            jax.ShapeDtypeStruct((b,), jnp.float32),
            jax.ShapeDtypeStruct((b, f), jnp.float32),
            jax.ShapeDtypeStruct((b, f), jnp.float32),
            jax.ShapeDtypeStruct((b,), jnp.float32),
        ],
        interpret=interpret,
    )(scalars, r, bi, bj, u, v)


def _rmse_kernel(scal_ref, r_ref, bi_ref, bj_ref, u_ref, v_ref, valid_ref, acc_ref, *, n_steps):
    """Accumulate (sse, count) across batch tiles into a [2] output."""

    @pl.when(pl.program_id(0) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    mu = scal_ref[0]
    pred = mu + bi_ref[...] + bj_ref[...] + jnp.sum(u_ref[...] * v_ref[...], axis=-1)
    e = (r_ref[...] - pred) * valid_ref[...]
    acc_ref[0] += jnp.sum(e * e)
    acc_ref[1] += jnp.sum(valid_ref[...])
    del n_steps


@functools.partial(jax.jit, static_argnames=("tile_b", "interpret"))
def rmse_chunk(scalars, r, bi, bj, u, v, valid, *, tile_b=DEFAULT_TILE_B, interpret=True):
    """Masked SSE/count reduction over a padded eval chunk.

    Args:
      scalars: [5] f32, only scalars[0] (= mu) is used (same layout as the
        SGD kernel so the rust side reuses one buffer).
      valid: [B] 1.0 live / 0.0 padding.

    Returns [2] f32 = (sse, count).
    """
    b, f = u.shape
    assert b % tile_b == 0
    n_steps = b // tile_b
    vec = lambda: pl.BlockSpec((tile_b,), lambda i: (i,))
    mat = lambda: pl.BlockSpec((tile_b, f), lambda i: (i, 0))
    scal = pl.BlockSpec((5,), lambda i: (0,))
    return pl.pallas_call(
        functools.partial(_rmse_kernel, n_steps=n_steps),
        grid=(n_steps,),
        in_specs=[scal, vec(), vec(), vec(), mat(), mat(), vec()],
        out_specs=pl.BlockSpec((2,), lambda i: (0,)),
        out_shape=jax.ShapeDtypeStruct((2,), jnp.float32),
        interpret=interpret,
    )(scalars, r, bi, bj, u, v, valid)
