"""Pallas L1 kernel: tiled simLSH sign-projection hashing.

Eq. (3) over all columns at once is `Υ(Ψ(Rᵀ) @ Φ)` — an [N, M] × [M, G]
matmul with a sign epilogue. The paper assigns one CUDA thread block per
column; the TPU mapping instead tiles the matmul for the MXU:

* grid = (N/TN, M/TM); each step multiplies a [TN, TM] tile of Ψ(Rᵀ)
  against a [TM, G] tile of Φ and accumulates into the [TN, G] output
  block, which stays VMEM-resident across the whole M loop (its index
  map is constant in the M grid axis);
* the sign threshold runs once on the last M-step (the epilogue), so the
  accumulator never round-trips to HBM as floats.

On this image the kernel must run with ``interpret=True`` (CPU PJRT has
no Mosaic); the structure is nevertheless the real-TPU structure, and the
DESIGN.md §Perf table estimates its VMEM/MXU characteristics.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


DEFAULT_TILE_N = 128
DEFAULT_TILE_M = 128


def _hash_kernel(x_ref, phi_ref, out_ref, *, n_steps_m):
    """One (n_tile, m_tile) grid step: accumulate, threshold at the end."""

    @pl.when(pl.program_id(1) == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    # MXU tile-matmul: [TN, TM] @ [TM, G] accumulated in f32.
    out_ref[...] += jnp.dot(
        x_ref[...], phi_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(pl.program_id(1) == n_steps_m - 1)
    def _epilogue():
        out_ref[...] = (out_ref[...] >= 0).astype(jnp.float32)


@functools.partial(jax.jit, static_argnames=("tile_n", "tile_m", "interpret"))
def simlsh_hash(psi_rt, phi, *, tile_n=DEFAULT_TILE_N, tile_m=DEFAULT_TILE_M, interpret=True):
    """Hash all columns: returns [N, G] float32 bits in {0, 1}.

    Args:
      psi_rt: [N, M] Ψ-weighted dense column-major ratings (zeros where
        there is no interaction — zero contributes nothing to Eq. 3).
      phi: [M, G] ±1 codes.
    """
    n, m = psi_rt.shape
    m2, g = phi.shape
    assert m == m2, f"inner dims {m} != {m2}"
    assert n % tile_n == 0, f"N={n} not a multiple of tile_n={tile_n}"
    assert m % tile_m == 0, f"M={m} not a multiple of tile_m={tile_m}"
    n_steps_m = m // tile_m

    return pl.pallas_call(
        functools.partial(_hash_kernel, n_steps_m=n_steps_m),
        grid=(n // tile_n, n_steps_m),
        in_specs=[
            pl.BlockSpec((tile_n, tile_m), lambda i, k: (i, k)),
            pl.BlockSpec((tile_m, g), lambda i, k: (k, 0)),
        ],
        out_specs=pl.BlockSpec((tile_n, g), lambda i, k: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, g), jnp.float32),
        interpret=interpret,
    )(psi_rt, phi)


def vmem_bytes(tile_n=DEFAULT_TILE_N, tile_m=DEFAULT_TILE_M, g=8):
    """Estimated VMEM working set per grid step (f32), for DESIGN.md §Perf:
    x tile + phi tile + resident out/accumulator block."""
    return 4 * (tile_n * tile_m + tile_m * g + tile_n * g)
