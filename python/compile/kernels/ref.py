"""Pure-jnp reference oracles for the Pallas kernels.

Every L1 kernel has an oracle here; pytest asserts allclose between the
kernel (interpret=True) and the oracle across hypothesis-swept shapes,
dtypes and seeds. The oracles are also the L2 fallback path used when a
graph is exported without Pallas (``aot.py --no-pallas``).
"""

import jax.numpy as jnp


def simlsh_hash_ref(psi_rt, phi):
    """Eq. (3) as a dense matmul.

    Args:
      psi_rt: [N, M] Ψ-weighted dense-ified transpose of the rating
        matrix (zeros where no interaction).
      phi: [M, G] ±1 row codes (Φ(H_i)).

    Returns:
      [N, G] float32 bits in {0, 1}: Υ(psi_rt @ phi).
    """
    acc = psi_rt @ phi
    return (acc >= 0).astype(jnp.float32)


def mf_predict_ref(mu, bi, bj, u, v):
    """Biased-MF batch prediction: mu + bi + bj + Σ_f u*v."""
    return mu + bi + bj + jnp.sum(u * v, axis=-1)


def mf_sgd_batch_ref(mu, r, bi, bj, u, v, gamma, lambda_b, lambda_u, lambda_v):
    """One fused Eq. (5) step over a gathered batch.

    All rows in the batch are assumed conflict-free (the rust coordinator
    schedules batches so no two samples share a row or column — the same
    invariant the paper's thread blocks rely on).

    Returns (bi', bj', u', v', e).
    """
    pred = mf_predict_ref(mu, bi, bj, u, v)
    e = r - pred
    bi_new = bi + gamma * (e - lambda_b * bi)
    bj_new = bj + gamma * (e - lambda_b * bj)
    u_new = u + gamma * (e[:, None] * v - lambda_u * u)
    v_new = v + gamma * (e[:, None] * u - lambda_v * v)  # pre-update u
    return bi_new, bj_new, u_new, v_new, e


def culsh_predict_ref(mu, bi, bj, u, v, w, c, resid, mask):
    """Eq. (1) batch prediction.

    Args:
      mu: scalar. bi, bj: [B]. u, v: [B, F].
      w, c: [B, K] gathered influence rows of the target column.
      resid: [B, K] explicit residuals (r_ij1 − b̄_ij1), zero where implicit.
      mask: [B, K] 1.0 where the neighbour slot is explicit (∈ R^K).

    Returns [B] predictions.
    """
    n_r = jnp.sum(mask, axis=-1)
    n_n = jnp.sum(1.0 - mask, axis=-1)
    scale_r = jnp.where(n_r > 0, 1.0 / jnp.sqrt(jnp.maximum(n_r, 1.0)), 0.0)
    scale_n = jnp.where(n_n > 0, 1.0 / jnp.sqrt(jnp.maximum(n_n, 1.0)), 0.0)
    explicit = scale_r * jnp.sum(mask * resid * w, axis=-1)
    implicit = scale_n * jnp.sum((1.0 - mask) * c, axis=-1)
    return mf_predict_ref(mu, bi, bj, u, v) + explicit + implicit


def culsh_sgd_batch_ref(
    mu,
    r,
    bi,
    bj,
    u,
    v,
    w,
    c,
    resid,
    mask,
    gamma,
    gamma_wc,
    lambda_b,
    lambda_u,
    lambda_v,
    lambda_w,
    lambda_c,
):
    """One fused Eq. (5) step for the full CULSH-MF parameter set.

    Returns (bi', bj', u', v', w', c', e).
    """
    pred = culsh_predict_ref(mu, bi, bj, u, v, w, c, resid, mask)
    e = r - pred
    n_r = jnp.sum(mask, axis=-1)
    n_n = jnp.sum(1.0 - mask, axis=-1)
    scale_r = jnp.where(n_r > 0, 1.0 / jnp.sqrt(jnp.maximum(n_r, 1.0)), 0.0)
    scale_n = jnp.where(n_n > 0, 1.0 / jnp.sqrt(jnp.maximum(n_n, 1.0)), 0.0)
    bi_new = bi + gamma * (e - lambda_b * bi)
    bj_new = bj + gamma * (e - lambda_b * bj)
    u_new = u + gamma * (e[:, None] * v - lambda_u * u)
    v_new = v + gamma * (e[:, None] * u - lambda_v * v)
    w_new = w + gamma_wc * (
        mask * ((e * scale_r)[:, None] * resid) - lambda_w * mask * w
    )
    c_new = c + gamma_wc * ((1.0 - mask) * (e * scale_n)[:, None] - lambda_c * (1.0 - mask) * c)
    return bi_new, bj_new, u_new, v_new, w_new, c_new, e


def rmse_chunk_ref(mu, r, bi, bj, u, v, valid):
    """Sum of squared errors over a padded evaluation chunk.

    valid: [B] 1.0 for live samples, 0.0 for padding. Returns (sse, count).
    """
    pred = mf_predict_ref(mu, bi, bj, u, v)
    e = (r - pred) * valid
    return jnp.sum(e * e), jnp.sum(valid)
