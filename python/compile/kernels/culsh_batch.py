"""Pallas L1 kernel: fused CULSH-MF batch step (Algorithm 3 as a tile).

One grid step consumes a [TB, F] factor tile plus the [TB, K] gathered
neighbourhood state (W/C rows, explicit residuals, explicit mask) and
produces every Eq. (5) update in a single VMEM pass. This is the TPU
restatement of the paper's warp-shuffle trick: the F-dot-product and both
K-reductions happen on the VPU while the tile is resident, and — like the
paper's R^K/N^K complement adjustment — explicit and implicit slots are
handled by one masked lane-wise expression, so the per-lane load is
uniform regardless of how many neighbours are rated.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


DEFAULT_TILE_B = 256

# scalars layout:
# [mu, gamma, gamma_wc, lambda_b, lambda_u, lambda_v, lambda_w, lambda_c]
N_SCALARS = 8


def _culsh_kernel(
    scal_ref,
    r_ref,
    bi_ref,
    bj_ref,
    u_ref,
    v_ref,
    w_ref,
    c_ref,
    resid_ref,
    mask_ref,
    bi_out,
    bj_out,
    u_out,
    v_out,
    w_out,
    c_out,
    e_out,
):
    mu = scal_ref[0]
    gamma = scal_ref[1]
    gamma_wc = scal_ref[2]
    lambda_b = scal_ref[3]
    lambda_u = scal_ref[4]
    lambda_v = scal_ref[5]
    lambda_w = scal_ref[6]
    lambda_c = scal_ref[7]

    u = u_ref[...]
    v = v_ref[...]
    w = w_ref[...]
    c = c_ref[...]
    bi = bi_ref[...]
    bj = bj_ref[...]
    resid = resid_ref[...]
    mask = mask_ref[...]

    n_r = jnp.sum(mask, axis=-1)
    n_n = jnp.sum(1.0 - mask, axis=-1)
    scale_r = jnp.where(n_r > 0, jax.lax.rsqrt(jnp.maximum(n_r, 1.0)), 0.0)
    scale_n = jnp.where(n_n > 0, jax.lax.rsqrt(jnp.maximum(n_n, 1.0)), 0.0)

    pred = (
        mu
        + bi
        + bj
        + jnp.sum(u * v, axis=-1)
        + scale_r * jnp.sum(mask * resid * w, axis=-1)
        + scale_n * jnp.sum((1.0 - mask) * c, axis=-1)
    )
    e = r_ref[...] - pred

    bi_out[...] = bi + gamma * (e - lambda_b * bi)
    bj_out[...] = bj + gamma * (e - lambda_b * bj)
    u_out[...] = u + gamma * (e[:, None] * v - lambda_u * u)
    v_out[...] = v + gamma * (e[:, None] * u - lambda_v * v)  # pre-update u
    w_out[...] = w + gamma_wc * (mask * ((e * scale_r)[:, None] * resid) - lambda_w * mask * w)
    c_out[...] = c + gamma_wc * ((1.0 - mask) * (e * scale_n)[:, None] - lambda_c * (1.0 - mask) * c)
    e_out[...] = e


@functools.partial(jax.jit, static_argnames=("tile_b", "interpret"))
def culsh_sgd_batch(
    scalars, r, bi, bj, u, v, w, c, resid, mask, *, tile_b=DEFAULT_TILE_B, interpret=True
):
    """Fused CULSH-MF batch step.

    Args:
      scalars: [8] f32 (see N_SCALARS layout above).
      r, bi, bj: [B]. u, v: [B, F]. w, c, resid, mask: [B, K].

    Returns (bi', bj', u', v', w', c', e).
    """
    b, f = u.shape
    _, k = w.shape
    assert b % tile_b == 0, f"B={b} not a multiple of tile_b={tile_b}"
    grid = (b // tile_b,)
    vec = lambda: pl.BlockSpec((tile_b,), lambda i: (i,))
    fmat = lambda: pl.BlockSpec((tile_b, f), lambda i: (i, 0))
    kmat = lambda: pl.BlockSpec((tile_b, k), lambda i: (i, 0))
    scal = pl.BlockSpec((N_SCALARS,), lambda i: (0,))
    return pl.pallas_call(
        _culsh_kernel,
        grid=grid,
        in_specs=[scal, vec(), vec(), vec(), fmat(), fmat(), kmat(), kmat(), kmat(), kmat()],
        out_specs=[vec(), vec(), fmat(), fmat(), kmat(), kmat(), vec()],
        out_shape=[
            jax.ShapeDtypeStruct((b,), jnp.float32),
            jax.ShapeDtypeStruct((b,), jnp.float32),
            jax.ShapeDtypeStruct((b, f), jnp.float32),
            jax.ShapeDtypeStruct((b, f), jnp.float32),
            jax.ShapeDtypeStruct((b, k), jnp.float32),
            jax.ShapeDtypeStruct((b, k), jnp.float32),
            jax.ShapeDtypeStruct((b,), jnp.float32),
        ],
        interpret=interpret,
    )(scalars, r, bi, bj, u, v, w, c, resid, mask)


def vmem_bytes(tile_b=DEFAULT_TILE_B, f=32, k=32):
    """VMEM working set per grid step (f32): in+out tiles."""
    per_sample = 2 * (2 * f + 4 * k) + 2 * 3 + 1 + 2  # u,v,w,c,resid,mask + biases/r/e
    return 4 * tile_b * per_sample
