"""Layer-2 JAX compute graphs.

Each exported graph composes the L1 Pallas kernels (interpret=True so the
lowered HLO runs on any PJRT backend, see /opt/xla-example/README.md) into
the unit of work the rust coordinator dispatches:

* ``mf_sgd_step``       — fused biased-MF minibatch SGD (CUSGD++ batch);
* ``culsh_sgd_step``    — fused Eq. (1)/(5) CULSH-MF minibatch;
* ``rmse_chunk_step``   — masked SSE/count reduction for evaluation;
* ``simlsh_hash_block`` — Eq. (3) sign-projection hashing of a dense
  column block.

The rust side owns all gathers/scatters (it has the CSR/CSC indexes); the
graphs see only dense, conflict-free batches — mirroring how the paper's
kernels see coalesced global-memory tiles.
"""

import jax.numpy as jnp

from compile.kernels import culsh_batch, mf_batch, simlsh

# Shapes the AOT artifacts are specialized to. The rust runtime pads the
# last partial batch (valid-mask for eval; identity no-op rows for SGD).
BATCH = 1024
F = 32
K = 32
HASH_N = 256
HASH_M = 512
HASH_G = 8


def mf_sgd_step(scalars, r, bi, bj, u, v):
    """[5], [B], [B], [B], [B,F], [B,F] -> (bi', bj', u', v', e)."""
    return mf_batch.mf_sgd_batch(scalars, r, bi, bj, u, v, interpret=True)


def culsh_sgd_step(scalars, r, bi, bj, u, v, w, c, resid, mask):
    """Fused CULSH-MF batch step (see culsh_batch for the layout)."""
    return culsh_batch.culsh_sgd_batch(
        scalars, r, bi, bj, u, v, w, c, resid, mask, interpret=True
    )


def rmse_chunk_step(scalars, r, bi, bj, u, v, valid):
    """Masked (sse, count) reduction over a padded chunk."""
    return mf_batch.rmse_chunk(scalars, r, bi, bj, u, v, valid, interpret=True)


def simlsh_hash_block(psi_rt, phi):
    """Hash a dense [N, M] Ψ-weighted block against [M, G] ±1 codes."""
    return simlsh.simlsh_hash(
        psi_rt,
        phi,
        tile_n=min(simlsh.DEFAULT_TILE_N, psi_rt.shape[0]),
        tile_m=min(simlsh.DEFAULT_TILE_M, psi_rt.shape[1]),
        interpret=True,
    )


def example_args(name):
    """ShapeDtypeStructs for AOT lowering of graph `name`."""
    import jax

    f32 = jnp.float32
    vec = jax.ShapeDtypeStruct((BATCH,), f32)
    fmat = jax.ShapeDtypeStruct((BATCH, F), f32)
    kmat = jax.ShapeDtypeStruct((BATCH, K), f32)
    if name == "mf_sgd_step":
        return (jax.ShapeDtypeStruct((5,), f32), vec, vec, vec, fmat, fmat)
    if name == "culsh_sgd_step":
        return (
            jax.ShapeDtypeStruct((8,), f32),
            vec,
            vec,
            vec,
            fmat,
            fmat,
            kmat,
            kmat,
            kmat,
            kmat,
        )
    if name == "rmse_chunk_step":
        return (jax.ShapeDtypeStruct((5,), f32), vec, vec, vec, fmat, fmat, vec)
    if name == "simlsh_hash_block":
        return (
            jax.ShapeDtypeStruct((HASH_N, HASH_M), f32),
            jax.ShapeDtypeStruct((HASH_M, HASH_G), f32),
        )
    raise KeyError(name)


GRAPHS = {
    "mf_sgd_step": mf_sgd_step,
    "culsh_sgd_step": culsh_sgd_step,
    "rmse_chunk_step": rmse_chunk_step,
    "simlsh_hash_block": simlsh_hash_block,
}
