"""Layer-2 JAX implementations of the NCF family (He et al. 2017) — the
deep-learning comparators of the paper's Table 10: GMF, MLP and NeuMF.

Architectures follow the original paper:

* **GMF**: user/item embeddings → Hadamard product → linear → sigmoid.
* **MLP**: concatenated embeddings → pyramid MLP (ReLU) → linear → sigmoid.
* **NeuMF**: both towers in parallel, last hidden layers concatenated.

Training is BCE on implicit 0/1 labels with **Adam** (He et al.'s
optimizer — plain SGD cannot train the bilinear GMF form from small
inits). Each exported step takes the flattened (params, m, v) state
tuple plus a batch of (user, item, label) and the step counter, and
returns the updated state plus the mean loss — the rust coordinator owns
the state buffers, the training loop and the evaluation protocol (HR@10
on 99 negatives).
"""

import functools

import jax
import jax.numpy as jnp

# Fixed export shapes (scaled datasets from rust fit under these).
N_USERS = 2048
N_ITEMS = 1024
EMBED = 16
MLP_LAYERS = (32, 16, 8)
BATCH = 512
EVAL_BATCH = 512


def _embed(table, idx):
    return jnp.take(table, idx, axis=0)


# ----------------------------------------------------------------- GMF


def gmf_init(rng_key, n_users=N_USERS, n_items=N_ITEMS, embed=EMBED):
    k1, k2, k3 = jax.random.split(rng_key, 3)
    # Embeddings start larger than the MLP towers: the bilinear GMF form
    # needs either Adam (the exported step) or a non-vanishing init for
    # its gradient (∝ scale²) to move under the plain-SGD test path.
    scale = 0.3
    return {
        "user": jax.random.normal(k1, (n_users, embed)) * scale,
        "item": jax.random.normal(k2, (n_items, embed)) * scale,
        "out_w": jax.random.normal(k3, (embed,)) * scale,
        "out_b": jnp.zeros(()),
    }


def gmf_logits(params, users, items):
    pu = _embed(params["user"], users)
    qi = _embed(params["item"], items)
    h = pu * qi
    return h @ params["out_w"] + params["out_b"]


# ----------------------------------------------------------------- MLP


def mlp_init(rng_key, n_users=N_USERS, n_items=N_ITEMS, embed=EMBED, layers=MLP_LAYERS):
    keys = jax.random.split(rng_key, 3 + 2 * len(layers))
    scale = 0.05
    params = {
        "user": jax.random.normal(keys[0], (n_users, embed)) * scale,
        "item": jax.random.normal(keys[1], (n_items, embed)) * scale,
    }
    dim = 2 * embed
    for li, width in enumerate(layers):
        params[f"w{li}"] = jax.random.normal(keys[2 + 2 * li], (dim, width)) * (
            1.0 / jnp.sqrt(dim)
        )
        params[f"b{li}"] = jnp.zeros((width,))
        dim = width
    params["out_w"] = jax.random.normal(keys[-1], (dim,)) * scale
    params["out_b"] = jnp.zeros(())
    return params


def mlp_hidden(params, users, items, layers=MLP_LAYERS):
    pu = _embed(params["user"], users)
    qi = _embed(params["item"], items)
    h = jnp.concatenate([pu, qi], axis=-1)
    for li in range(len(layers)):
        h = jax.nn.relu(h @ params[f"w{li}"] + params[f"b{li}"])
    return h


def mlp_logits(params, users, items):
    h = mlp_hidden(params, users, items)
    return h @ params["out_w"] + params["out_b"]


# ----------------------------------------------------------------- NeuMF


def neumf_init(rng_key, n_users=N_USERS, n_items=N_ITEMS, embed=EMBED, layers=MLP_LAYERS):
    k1, k2, k3 = jax.random.split(rng_key, 3)
    gmf = gmf_init(k1, n_users, n_items, embed)
    mlp = mlp_init(k2, n_users, n_items, embed, layers)
    fuse_dim = embed + layers[-1]
    return {
        "gmf_user": gmf["user"],
        "gmf_item": gmf["item"],
        **{f"mlp_{k}": v for k, v in mlp.items()},
        "fuse_w": jax.random.normal(k3, (fuse_dim,)) * 0.05,
        "fuse_b": jnp.zeros(()),
    }


def neumf_logits(params, users, items, layers=MLP_LAYERS):
    gmf_h = _embed(params["gmf_user"], users) * _embed(params["gmf_item"], items)
    mlp_params = {k[len("mlp_") :]: v for k, v in params.items() if k.startswith("mlp_")}
    mlp_h = mlp_hidden(mlp_params, users, items, layers)
    h = jnp.concatenate([gmf_h, mlp_h], axis=-1)
    return h @ params["fuse_w"] + params["fuse_b"]


# ----------------------------------------------------------------- training


LOGITS = {"gmf": gmf_logits, "mlp": mlp_logits, "neumf": neumf_logits}
INITS = {"gmf": gmf_init, "mlp": mlp_init, "neumf": neumf_init}


def bce_loss(logits_fn, params, users, items, labels):
    logits = logits_fn(params, users, items)
    # numerically stable BCE with logits
    return jnp.mean(jnp.maximum(logits, 0) - logits * labels + jnp.log1p(jnp.exp(-jnp.abs(logits))))


@functools.partial(jax.jit, static_argnames=("kind", "lr"))
def train_step(kind, params, users, items, labels, lr=0.05):
    """One plain-SGD step; returns (new_params, loss). Kept for unit tests
    and memorization checks — the AOT export uses Adam (He et al.'s
    optimizer), which plain SGD cannot replace on the bilinear GMF form
    (gradients through tiny embeddings vanish; see test history)."""
    logits_fn = LOGITS[kind]
    loss, grads = jax.value_and_grad(lambda p: bce_loss(logits_fn, p, users, items, labels))(
        params
    )
    new_params = jax.tree.map(lambda p, g: p - lr * g, params, grads)
    return new_params, loss


@functools.partial(jax.jit, static_argnames=("kind", "lr"))
def adam_step(kind, params, m, v, t, users, items, labels, lr=0.003):
    """One Adam step (β₁=0.9, β₂=0.999); returns (params', m', v', loss)."""
    logits_fn = LOGITS[kind]
    loss, grads = jax.value_and_grad(lambda p: bce_loss(logits_fn, p, users, items, labels))(
        params
    )
    b1, b2, eps = 0.9, 0.999, 1e-8
    m = jax.tree.map(lambda a, g: b1 * a + (1 - b1) * g, m, grads)
    v = jax.tree.map(lambda a, g: b2 * a + (1 - b2) * g * g, v, grads)
    mh = jax.tree.map(lambda a: a / (1 - b1**t), m)
    vh = jax.tree.map(lambda a: a / (1 - b2**t), v)
    params = jax.tree.map(lambda p, a, b: p - lr * a / (jnp.sqrt(b) + eps), params, mh, vh)
    return params, m, v, loss


@functools.partial(jax.jit, static_argnames=("kind",))
def score(kind, params, users, items):
    """Sigmoid scores for ranking (HR@10 protocol)."""
    return jax.nn.sigmoid(LOGITS[kind](params, users, items))


# ----------------------------------------------------------------- AOT


def flat_spec(kind):
    """Deterministic (name, shape) list for the parameter tuple the AOT
    artifact takes/returns (sorted by name for stability)."""
    params = INITS[kind](jax.random.PRNGKey(0))
    return [(k, tuple(params[k].shape)) for k in sorted(params)]


def make_step_fn(kind, lr=0.003):
    """A lowering-friendly **Adam** step over the flattened state tuple
    `(users, items, labels, t, *params, *m, *v)` →
    `(*params', *m', *v', loss)`. The rust runtime owns the state buffers
    and the step counter `t` (a [1] f32, 1-based)."""
    names = [k for k, _ in flat_spec(kind)]
    n = len(names)

    def step(users, items, labels, t, *state):
        params = dict(zip(names, state[:n]))
        m = dict(zip(names, state[n : 2 * n]))
        v = dict(zip(names, state[2 * n : 3 * n]))
        new_p, new_m, new_v, loss = adam_step(
            kind, params, m, v, t[0], users, items, labels, lr=lr
        )
        return (
            tuple(new_p[k] for k in names)
            + tuple(new_m[k] for k in names)
            + tuple(new_v[k] for k in names)
            + (loss,)
        )

    return step


def make_score_fn(kind):
    names = [k for k, _ in flat_spec(kind)]

    def score_flat(users, items, *flat):
        params = dict(zip(names, flat))
        s = score(kind, params, users, items)
        # NeuMF's scoring path never touches the MLP tower's own output
        # head; XLA would then prune those parameters from the lowered
        # program and the rust runtime's uniform param-tuple convention
        # would break ("supplied N buffers but expected M"). A zero-scaled
        # reduction keeps every parameter alive without changing scores.
        keep = sum(jnp.sum(p) for p in flat) * 0.0
        return s + keep

    return score_flat


def example_step_args(kind):
    i32 = jnp.int32
    f32 = jnp.float32
    args = [
        jax.ShapeDtypeStruct((BATCH,), i32),
        jax.ShapeDtypeStruct((BATCH,), i32),
        jax.ShapeDtypeStruct((BATCH,), f32),
        jax.ShapeDtypeStruct((1,), f32),  # adam step counter t
    ]
    spec = [jax.ShapeDtypeStruct(shape, f32) for _, shape in flat_spec(kind)]
    args += spec * 3  # params, m, v
    return tuple(args)


def example_score_args(kind):
    i32 = jnp.int32
    f32 = jnp.float32
    args = [
        jax.ShapeDtypeStruct((EVAL_BATCH,), i32),
        jax.ShapeDtypeStruct((EVAL_BATCH,), i32),
    ]
    args += [jax.ShapeDtypeStruct(shape, f32) for _, shape in flat_spec(kind)]
    return tuple(args)
