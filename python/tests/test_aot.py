"""AOT exporter: HLO text artifacts parse and the manifest is coherent."""

import json
import os

import pytest

from compile import aot, model


@pytest.fixture(scope="module")
def exported(tmp_path_factory):
    out = tmp_path_factory.mktemp("artifacts")
    # neural graphs are exported too, but at reduced cost we verify the
    # core graphs here and one neural graph separately
    aot.export_all(str(out), skip_neural=True)
    return out


def test_artifacts_exist_and_nonempty(exported):
    for name in model.GRAPHS:
        path = exported / f"{name}.hlo.txt"
        assert path.exists(), name
        text = path.read_text()
        assert "HloModule" in text, f"{name} missing HloModule header"
        assert len(text) > 200


def test_manifest_describes_all_graphs(exported):
    manifest = json.loads((exported / "manifest.json").read_text())
    assert manifest["format"] == "hlo-text"
    assert manifest["batch"] == model.BATCH
    for name in model.GRAPHS:
        entry = manifest["graphs"][name]
        assert (exported / entry["file"]).exists()
        assert len(entry["inputs"]) >= 2
        for spec in entry["inputs"]:
            assert "shape" in spec and "dtype" in spec


def test_hlo_text_has_entry_parameters(exported):
    manifest = json.loads((exported / "manifest.json").read_text())
    entry = manifest["graphs"]["mf_sgd_step"]
    text = (exported / entry["file"]).read_text()
    # every input should appear as a parameter in the entry computation
    assert text.count("parameter(") >= len(entry["inputs"])


def test_neural_export_one_kind(tmp_path):
    """Full neural export is exercised by `make artifacts`; here we lower
    the cheapest kind to keep the suite fast."""
    from compile import neural

    text = aot.to_hlo_text(neural.make_score_fn("gmf"), neural.example_score_args("gmf"))
    assert "HloModule" in text


def test_export_is_deterministic(exported, tmp_path):
    aot.export_all(str(tmp_path), skip_neural=True)
    a = (exported / "mf_sgd_step.hlo.txt").read_text()
    b = (tmp_path / "mf_sgd_step.hlo.txt").read_text()
    assert a == b
