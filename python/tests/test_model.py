"""L2 graphs: export shapes, numerical behaviour, and the neural family."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model, neural
from compile.kernels import ref


def test_graph_registry_covers_example_args():
    for name in model.GRAPHS:
        args = model.example_args(name)
        assert all(hasattr(a, "shape") for a in args)
    with pytest.raises(KeyError):
        model.example_args("nope")


def test_mf_sgd_step_runs_at_export_shape():
    rng = np.random.default_rng(0)
    b, f = model.BATCH, model.F
    scal = jnp.array([3.0, 0.01, 0.02, 0.02, 0.02], jnp.float32)
    r = jnp.array(rng.normal(3, 1, b), jnp.float32)
    bi = jnp.zeros(b)
    bj = jnp.zeros(b)
    u = jnp.array(rng.normal(0, 0.1, (b, f)), jnp.float32)
    v = jnp.array(rng.normal(0, 0.1, (b, f)), jnp.float32)
    out = model.mf_sgd_step(scal, r, bi, bj, u, v)
    assert out[2].shape == (b, f)
    want = ref.mf_sgd_batch_ref(3.0, r, bi, bj, u, v, 0.01, 0.02, 0.02, 0.02)
    np.testing.assert_allclose(np.array(out[4]), np.array(want[4]), rtol=1e-4, atol=1e-5)


def test_repeated_sgd_steps_reduce_error():
    """Driving the exported step in a loop must fit a batch (integration
    sanity of the update sign conventions)."""
    rng = np.random.default_rng(1)
    b, f = model.BATCH, model.F
    scal = jnp.array([3.0, 0.05, 0.001, 0.001, 0.001], jnp.float32)
    r = jnp.array(rng.normal(3, 1, b), jnp.float32)
    bi = jnp.zeros(b)
    bj = jnp.zeros(b)
    u = jnp.array(rng.normal(0, 0.1, (b, f)), jnp.float32)
    v = jnp.array(rng.normal(0, 0.1, (b, f)), jnp.float32)
    first_err = None
    for _ in range(50):
        bi, bj, u, v, e = model.mf_sgd_step(scal, r, bi, bj, u, v)
        if first_err is None:
            first_err = float(jnp.mean(e * e))
    last_err = float(jnp.mean(e * e))
    assert last_err < 0.25 * first_err, (first_err, last_err)


def test_simlsh_hash_block_matches_ref_at_export_shape():
    rng = np.random.default_rng(2)
    x = rng.normal(0, 1, (model.HASH_N, model.HASH_M)).astype(np.float32)
    phi = rng.choice([-1.0, 1.0], (model.HASH_M, model.HASH_G)).astype(np.float32)
    got = model.simlsh_hash_block(jnp.array(x), jnp.array(phi))
    want = ref.simlsh_hash_ref(jnp.array(x), jnp.array(phi))
    np.testing.assert_array_equal(np.array(got), np.array(want))


# ------------------------------------------------------------- neural NCF


@pytest.mark.parametrize("kind", ["gmf", "mlp", "neumf"])
def test_neural_init_and_logits_shapes(kind):
    params = neural.INITS[kind](jax.random.PRNGKey(0))
    users = jnp.arange(16, dtype=jnp.int32)
    items = jnp.arange(16, dtype=jnp.int32) % neural.N_ITEMS
    logits = neural.LOGITS[kind](params, users, items)
    assert logits.shape == (16,)
    s = neural.score(kind, params, users, items)
    assert float(jnp.min(s)) >= 0.0 and float(jnp.max(s)) <= 1.0


@pytest.mark.parametrize("kind", ["gmf", "mlp", "neumf"])
def test_neural_training_memorizes_pairs(kind):
    """All three NCF models must be able to fit 64 random (u, i) labels —
    the capacity/gradient-flow sanity check before the Table 10 bench."""
    params = neural.INITS[kind](jax.random.PRNGKey(1))
    rng = np.random.default_rng(3)
    pairs_u = rng.integers(0, 64, 64)
    pairs_i = rng.integers(0, 64, 64)
    lab = rng.integers(0, 2, 64).astype(np.float32)
    reps = neural.BATCH // 64
    users = jnp.array(np.tile(pairs_u, reps), jnp.int32)
    items = jnp.array(np.tile(pairs_i, reps), jnp.int32)
    labels = jnp.array(np.tile(lab, reps))
    losses = []
    for _ in range(300):
        params, loss = neural.train_step(kind, params, users, items, labels, lr=1.0)
        losses.append(float(loss))
    assert losses[-1] < 0.1, losses[::60]


def test_flat_spec_is_deterministic_and_sorted():
    for kind in ("gmf", "mlp", "neumf"):
        spec1 = neural.flat_spec(kind)
        spec2 = neural.flat_spec(kind)
        assert spec1 == spec2
        names = [n for n, _ in spec1]
        assert names == sorted(names)


def test_make_step_fn_roundtrips_flat_params():
    kind = "gmf"
    params = neural.INITS[kind](jax.random.PRNGKey(0))
    names = [n for n, _ in neural.flat_spec(kind)]
    flat = tuple(params[n] for n in names)
    users = jnp.zeros(neural.BATCH, jnp.int32)
    items = jnp.zeros(neural.BATCH, jnp.int32)
    labels = jnp.ones(neural.BATCH, jnp.float32)
    t = jnp.ones(1, jnp.float32)
    zeros = tuple(jnp.zeros_like(x) for x in flat)
    out = neural.make_step_fn(kind)(users, items, labels, t, *flat, *zeros, *zeros)
    assert len(out) == 3 * len(flat) + 1  # params, m, v + loss
    for o, p in zip(out[: len(flat)], flat):
        assert o.shape == p.shape
