"""L1 Pallas kernels vs pure-jnp oracles, hypothesis-swept."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import culsh_batch, mf_batch, ref, simlsh

settings.register_profile("kernels", max_examples=25, deadline=None)
settings.load_profile("kernels")


def _np(rng, *shape):
    return rng.normal(0, 0.5, shape).astype(np.float32)


# ------------------------------------------------------------- simLSH hash


@given(
    seed=st.integers(0, 2**31 - 1),
    n_tiles=st.integers(1, 3),
    m_tiles=st.integers(1, 3),
    g=st.sampled_from([4, 8, 16]),
)
def test_simlsh_hash_matches_ref(seed, n_tiles, m_tiles, g):
    tile_n, tile_m = 8, 16
    n, m = n_tiles * tile_n, m_tiles * tile_m
    rng = np.random.default_rng(seed)
    x = _np(rng, n, m)
    phi = rng.choice([-1.0, 1.0], size=(m, g)).astype(np.float32)
    got = simlsh.simlsh_hash(jnp.array(x), jnp.array(phi), tile_n=tile_n, tile_m=tile_m)
    want = ref.simlsh_hash_ref(jnp.array(x), jnp.array(phi))
    np.testing.assert_array_equal(np.array(got), np.array(want))


def test_simlsh_hash_sparse_input_zeros_are_neutral():
    # zero rows contribute nothing: hashing [x; 0] == hashing x padded
    rng = np.random.default_rng(7)
    x = _np(rng, 8, 32)
    x[:, 16:] = 0.0
    phi = rng.choice([-1.0, 1.0], size=(32, 8)).astype(np.float32)
    got = simlsh.simlsh_hash(jnp.array(x), jnp.array(phi), tile_n=8, tile_m=16)
    want = (x[:, :16] @ phi[:16] >= 0).astype(np.float32)
    np.testing.assert_array_equal(np.array(got), want)


def test_simlsh_hash_rejects_misaligned():
    rng = np.random.default_rng(3)
    x = _np(rng, 10, 16)  # 10 % 8 != 0
    phi = rng.choice([-1.0, 1.0], size=(16, 8)).astype(np.float32)
    with pytest.raises(AssertionError):
        simlsh.simlsh_hash(jnp.array(x), jnp.array(phi), tile_n=8, tile_m=16)


# --------------------------------------------------------------- MF batch


@given(
    seed=st.integers(0, 2**31 - 1),
    tiles=st.integers(1, 4),
    f=st.sampled_from([4, 8, 32]),
)
def test_mf_sgd_batch_matches_ref(seed, tiles, f):
    tile_b = 8
    b = tiles * tile_b
    rng = np.random.default_rng(seed)
    scal = np.array([3.2, 0.01, 0.02, 0.03, 0.04], np.float32)
    r = _np(rng, b) + 3.0
    bi, bj = _np(rng, b), _np(rng, b)
    u, v = _np(rng, b, f), _np(rng, b, f)
    got = mf_batch.mf_sgd_batch(
        jnp.array(scal), jnp.array(r), jnp.array(bi), jnp.array(bj), jnp.array(u), jnp.array(v),
        tile_b=tile_b,
    )
    want = ref.mf_sgd_batch_ref(3.2, r, bi, bj, u, v, 0.01, 0.02, 0.03, 0.04)
    for gk, wk in zip(got, want):
        np.testing.assert_allclose(np.array(gk), np.array(wk), rtol=1e-5, atol=1e-6)


def test_mf_sgd_uses_pre_update_u_for_v():
    # single sample, hand-computed (the Eq. 5 subtlety)
    scal = jnp.array([0.0, 0.1, 0.0, 0.0, 0.0], jnp.float32)
    r = jnp.array([1.5], jnp.float32)
    bi = bj = jnp.zeros(1, jnp.float32)
    u = jnp.array([[1.0]], jnp.float32)
    v = jnp.array([[2.0]], jnp.float32)
    # pred = 2.0, e = -0.5; u' = 1 + .1*(-0.5*2) = 0.9 ; v' = 2 + .1*(-0.5*1) = 1.95
    bi2, bj2, u2, v2, e = mf_batch.mf_sgd_batch(scal, r, bi, bj, u, v, tile_b=1)
    assert np.isclose(float(u2[0, 0]), 0.9)
    assert np.isclose(float(v2[0, 0]), 1.95)
    assert np.isclose(float(e[0]), -0.5)


@given(seed=st.integers(0, 2**31 - 1), pad=st.integers(0, 7))
def test_rmse_chunk_masks_padding(seed, pad):
    tile_b, b, f = 8, 16, 4
    rng = np.random.default_rng(seed)
    scal = np.array([3.0, 0, 0, 0, 0], np.float32)
    r = _np(rng, b) + 3.0
    bi, bj = _np(rng, b), _np(rng, b)
    u, v = _np(rng, b, f), _np(rng, b, f)
    valid = np.ones(b, np.float32)
    if pad:
        valid[-pad:] = 0.0
    got = mf_batch.rmse_chunk(
        jnp.array(scal), jnp.array(r), jnp.array(bi), jnp.array(bj),
        jnp.array(u), jnp.array(v), jnp.array(valid), tile_b=tile_b,
    )
    sse, count = ref.rmse_chunk_ref(3.0, r, bi, bj, u, v, valid)
    np.testing.assert_allclose(float(got[0]), float(sse), rtol=1e-5)
    assert float(got[1]) == b - pad


# ------------------------------------------------------------ CULSH batch


@given(
    seed=st.integers(0, 2**31 - 1),
    tiles=st.integers(1, 3),
    f=st.sampled_from([4, 8]),
    k=st.sampled_from([4, 8, 16]),
)
def test_culsh_sgd_batch_matches_ref(seed, tiles, f, k):
    tile_b = 8
    b = tiles * tile_b
    rng = np.random.default_rng(seed)
    scal = np.array([3.0, 0.02, 0.005, 0.01, 0.01, 0.01, 0.002, 0.002], np.float32)
    r = _np(rng, b) + 3.0
    bi, bj = _np(rng, b), _np(rng, b)
    u, v = _np(rng, b, f), _np(rng, b, f)
    w, c = _np(rng, b, k), _np(rng, b, k)
    resid = _np(rng, b, k)
    mask = rng.integers(0, 2, (b, k)).astype(np.float32)
    got = culsh_batch.culsh_sgd_batch(
        jnp.array(scal), jnp.array(r), jnp.array(bi), jnp.array(bj),
        jnp.array(u), jnp.array(v), jnp.array(w), jnp.array(c),
        jnp.array(resid), jnp.array(mask), tile_b=tile_b,
    )
    want = ref.culsh_sgd_batch_ref(
        3.0, r, bi, bj, u, v, w, c, resid, mask,
        0.02, 0.005, 0.01, 0.01, 0.01, 0.002, 0.002,
    )
    for gk, wk in zip(got, want):
        np.testing.assert_allclose(np.array(gk), np.array(wk), rtol=1e-5, atol=1e-6)


def test_culsh_all_explicit_and_all_implicit_edges():
    b, f, k = 8, 4, 4
    rng = np.random.default_rng(11)
    scal = np.array([3.0, 0.02, 0.005, 0.01, 0.01, 0.01, 0.002, 0.002], np.float32)
    args = dict(
        r=_np(rng, b) + 3.0, bi=_np(rng, b), bj=_np(rng, b),
        u=_np(rng, b, f), v=_np(rng, b, f), w=_np(rng, b, k), c=_np(rng, b, k),
        resid=_np(rng, b, k),
    )
    for mask in (np.ones((b, k), np.float32), np.zeros((b, k), np.float32)):
        got = culsh_batch.culsh_sgd_batch(
            jnp.array(scal), *(jnp.array(args[n]) for n in ("r", "bi", "bj", "u", "v", "w", "c", "resid")),
            jnp.array(mask), tile_b=8,
        )
        want = ref.culsh_sgd_batch_ref(
            3.0, args["r"], args["bi"], args["bj"], args["u"], args["v"],
            args["w"], args["c"], args["resid"], mask,
            0.02, 0.005, 0.01, 0.01, 0.01, 0.002, 0.002,
        )
        for gk, wk in zip(got, want):
            np.testing.assert_allclose(np.array(gk), np.array(wk), rtol=1e-5, atol=1e-6)
        # zero-count side must not produce NaNs
        assert not any(np.isnan(np.array(x)).any() for x in got)
