//! Integration tests for the concurrent serving stack: readers must make
//! progress while the single writer streams `RATE` events through
//! flushes, snapshots must be monotonically consistent (never torn,
//! never going backwards), and the streaming backpressure contract must
//! hold exactly at `queue_capacity`.

use lshmf::coordinator::banded::BandedEngine;
use lshmf::coordinator::client::{ClientCodec, LshmfClient};
use lshmf::coordinator::protocol::{
    read_frame, CodecChoice, ErrorKind, FrameRead, OkBody, Request, Response,
    BINARY_FRAME_BYTE, MAX_MPREDICT_COLS, MAX_MRATE_EVENTS, MAX_TOPN_ITEMS,
};
use lshmf::coordinator::server::{self, dispatch, handle_line, Serving};
use lshmf::coordinator::shared::SharedEngine;
use lshmf::coordinator::stream::{IngestResult, StreamConfig, StreamOrchestrator};
use lshmf::coordinator::Engine;
use lshmf::config::ServeConfig;
use lshmf::lsh::{OnlineHashState, SimLsh};
use lshmf::metrics::Registry;
use lshmf::mf::neighbourhood::{train_culsh_logged, CulshConfig};
use lshmf::rng::Rng;
use lshmf::sparse::{Csc, Csr, Triples};
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

/// Small trained engine over a dense-ish random fixture.
fn engine(seed: u64, stream_cfg: StreamConfig) -> Engine {
    let mut rng = Rng::seeded(seed);
    let (m, n) = (30, 15);
    let mut t = Triples::new(m, n);
    let mut seen = std::collections::HashSet::new();
    while t.nnz() < 180 {
        let (i, j) = (rng.below(m), rng.below(n));
        if seen.insert((i, j)) {
            t.push(i, j, 1.0 + rng.f32() * 4.0);
        }
    }
    let csr = Csr::from_triples(&t);
    let csc = Csc::from_triples(&t);
    let lsh = SimLsh::new(1, 5, 8, 2);
    let hash_state = OnlineHashState::build(lsh, &csc);
    let (topk, _) = hash_state.topk(4, &mut rng);
    let cfg = CulshConfig { f: 4, k: 4, epochs: 4, ..Default::default() };
    let (model, _) = train_culsh_logged(&csr, topk, &cfg, &mut rng);
    let metrics = Registry::new();
    let orch = StreamOrchestrator::new(
        model,
        hash_state,
        t,
        stream_cfg,
        cfg,
        rng.split(1),
        metrics.clone(),
    );
    Engine::new(orch, (1.0, 5.0), metrics)
}

/// The acceptance-criterion scenario, in-process: 6 reader threads issue
/// `PREDICT`/`TOPN`/`STATS` protocol lines nonstop while the writer
/// streams `RATE` events that trigger many flushes. No deadlock (the
/// test finishes), no torn reads (every reply well-formed), and every
/// reader observes monotonically non-decreasing snapshot versions and
/// dimensions.
#[test]
fn readers_progress_during_flushes() {
    let e = engine(41, StreamConfig { batch_size: 8, ..Default::default() });
    let (shared, writer_handle) = SharedEngine::spawn(e);
    let readers = 6;
    let requests_per_reader = 120;

    std::thread::scope(|scope| {
        for reader in 0..readers {
            let shared = shared.clone();
            scope.spawn(move || {
                let mut last_version = 0u64;
                let mut last_dims = (0usize, 0usize);
                for k in 0..requests_per_reader {
                    let line = match k % 3 {
                        0 => format!("PREDICT {} {}", (k + reader) % 30, k % 15),
                        1 => format!("TOPN {} 5", (k * 7 + reader) % 30),
                        _ => "STATS".to_string(),
                    };
                    let reply = handle_line(&shared, &line).expect("no QUIT here");
                    assert!(
                        reply.starts_with("PRED ")
                            || reply.starts_with("TOPN")
                            || reply.ends_with("END"),
                        "reader {reader}: {line} -> {reply}"
                    );
                    // snapshot monotonicity: version and dims never go back
                    let snap = shared.snapshot();
                    assert!(
                        snap.version >= last_version,
                        "version went backwards: {} -> {}",
                        last_version,
                        snap.version
                    );
                    let dims = snap.dims();
                    assert!(
                        dims.0 >= last_dims.0 && dims.1 >= last_dims.1,
                        "dims shrank: {last_dims:?} -> {dims:?}"
                    );
                    // the sharded snapshot is internally consistent:
                    // row factors cover every row, the bands tile the
                    // column axis exactly
                    assert_eq!(snap.rows().nrows(), dims.0);
                    let mut covered = 0usize;
                    for shard in snap.shards() {
                        assert_eq!(shard.lo, covered, "bands must tile contiguously");
                        covered = shard.hi;
                        assert_eq!(shard.v.rows(), shard.ncols());
                    }
                    assert_eq!(covered, dims.1, "bands must cover all columns");
                    last_version = snap.version;
                    last_dims = dims;
                }
            });
        }
        // the writer: 160 ratings at batch_size 8 -> ~20 flushes, with
        // universe growth sprinkled in
        let shared_writer = shared.clone();
        scope.spawn(move || {
            for k in 0u32..160 {
                let (i, j) = if k % 16 == 15 {
                    (30 + (k / 16), 15 + (k / 16)) // new row + new column
                } else {
                    (k % 30, k % 15)
                };
                let reply = handle_line(&shared_writer, &format!("RATE {i} {j} 3.5")).unwrap();
                assert!(reply.starts_with("OK"), "{reply}");
            }
        });
    });

    // all flushes published: final dims include every grown variable
    let engine = writer_handle.join();
    let (m, n) = engine.dims();
    assert!(m >= 40 && n >= 25, "dims after growth: {m}x{n}");
    assert!(shared.version() >= 19, "publishes: {}", shared.version());
}

/// Same scenario over real sockets: ≥4 simultaneous reader connections
/// complete PREDICT/TOPN streams while a writer connection drives
/// RATE-triggered flushes, against the pooled TCP server.
#[test]
fn tcp_concurrent_readers_and_writer() {
    let e = engine(42, StreamConfig { batch_size: 8, ..Default::default() });
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let stop = Arc::new(AtomicBool::new(false));
    let server_thread = {
        let stop = stop.clone();
        std::thread::spawn(move || server::serve(e, listener, stop, 6).unwrap())
    };

    let mut clients = Vec::new();
    for reader in 0..4usize {
        clients.push(std::thread::spawn(move || {
            let stream = TcpStream::connect(addr).unwrap();
            let mut writer = stream.try_clone().unwrap();
            let mut reader_buf = BufReader::new(stream);
            for k in 0..60 {
                let line = if k % 2 == 0 {
                    format!("PREDICT {} {}\n", (k + reader) % 30, k % 15)
                } else {
                    format!("TOPN {} 4\n", (k + reader) % 30)
                };
                writer.write_all(line.as_bytes()).unwrap();
                let mut reply = String::new();
                reader_buf.read_line(&mut reply).unwrap();
                assert!(
                    reply.starts_with("PRED ") || reply.starts_with("TOPN"),
                    "reader {reader}: {reply}"
                );
            }
            writer.write_all(b"QUIT\n").unwrap();
        }));
    }
    let rate_client = std::thread::spawn(move || {
        let stream = TcpStream::connect(addr).unwrap();
        let mut writer = stream.try_clone().unwrap();
        let mut reader_buf = BufReader::new(stream);
        let mut flushes = 0usize;
        for k in 0u32..96 {
            writer
                .write_all(format!("RATE {} {} 4.0\n", k % 30, k % 15).as_bytes())
                .unwrap();
            let mut reply = String::new();
            reader_buf.read_line(&mut reply).unwrap();
            assert!(reply.starts_with("OK"), "{reply}");
            if reply.starts_with("OK flushed") {
                flushes += 1;
            }
        }
        writer.write_all(b"QUIT\n").unwrap();
        flushes
    });

    for c in clients {
        c.join().unwrap();
    }
    let flushes = rate_client.join().unwrap();
    assert!(flushes >= 10, "expected many RATE-driven flushes, got {flushes}");

    // shut the server down and reclaim the engine
    stop.store(true, Ordering::Relaxed);
    let _ = TcpStream::connect(addr);
    let engine = server_thread.join().unwrap();
    assert_eq!(engine.buffered(), 0, "writer drained on shutdown");
}

/// Multi-writer flavour of the acceptance scenario: reader threads
/// stream protocol lines while one client thread per column band RATEs
/// concurrently into its own band (with universe growth sprinkled in).
/// No deadlock, no torn reads, versions and dims monotone, bands always
/// tile the column axis, and the joined engine drained every accepted
/// rating.
#[test]
fn banded_readers_progress_during_concurrent_band_writes() {
    let writers = 3usize;
    let e = engine(47, StreamConfig { batch_size: 8, ..Default::default() });
    let (banded, handle) = BandedEngine::spawn(e, writers);

    std::thread::scope(|scope| {
        for reader in 0..4usize {
            let banded = banded.clone();
            scope.spawn(move || {
                let mut last_version = 0u64;
                let mut last_dims = (0usize, 0usize);
                for k in 0..100usize {
                    let line = match k % 3 {
                        0 => format!("PREDICT {} {}", (k + reader) % 30, k % 15),
                        1 => format!("TOPN {} 5", (k * 7 + reader) % 30),
                        _ => "STATS".to_string(),
                    };
                    let reply = handle_line(&banded, &line).expect("no QUIT here");
                    assert!(
                        reply.starts_with("PRED ")
                            || reply.starts_with("TOPN")
                            || reply.ends_with("END"),
                        "reader {reader}: {line} -> {reply}"
                    );
                    let snap = banded.snapshot();
                    assert!(snap.version >= last_version, "version went backwards");
                    let dims = snap.dims();
                    assert!(
                        dims.0 >= last_dims.0 && dims.1 >= last_dims.1,
                        "dims shrank: {last_dims:?} -> {dims:?}"
                    );
                    let mut covered = 0usize;
                    for shard in snap.shards() {
                        assert_eq!(shard.lo, covered, "bands must tile contiguously");
                        covered = shard.hi;
                    }
                    assert_eq!(covered, dims.1, "bands must cover all columns");
                    last_version = snap.version;
                    last_dims = dims;
                }
            });
        }
        // one rater per band: 60 ratings each into its own column band,
        // with a growth rating every 15th — concurrent ingest across
        // every band writer plus cross-band growth barriers
        for band in 0..writers as u32 {
            let banded = banded.clone();
            scope.spawn(move || {
                for k in 0u32..60 {
                    let (i, j) = if k % 15 == 14 {
                        (30 + k / 15, 15 + band * 4 + k / 15)
                    } else {
                        ((k + band) % 30, (band * 5 + k % 5) % 15)
                    };
                    let reply =
                        handle_line(&banded, &format!("RATE {i} {j} 3.5")).unwrap();
                    assert!(reply.starts_with("OK"), "band {band}: {reply}");
                }
            });
        }
    });

    let engine = handle.join();
    assert_eq!(engine.buffered(), 0, "join drains every band");
    let (m, n) = engine.dims();
    assert!(m >= 31 && n >= 16, "growth applied: {m}x{n}");
    assert_eq!(banded.dims(), (m, n), "drained state republished");
    assert!(banded.version() >= 1);
}

/// Every [`ErrorKind`] wire form, on both codecs, against all three
/// serving flavours. The text form must be the exact legacy `ERR`
/// string; the binary form must round-trip encode → decode to the same
/// typed kind; and the three flavours must agree on every reply.
#[test]
fn error_kinds_cover_both_codecs_and_all_flavours() {
    // capacity 1 + reject_when_full so backpressure is reachable; the
    // default max_rows/max_cols (1<<24) make 4e9 out-of-bounds
    let cfg = StreamConfig {
        queue_capacity: 1,
        batch_size: 100,
        reject_when_full: true,
        ..Default::default()
    };
    let mutex_engine = std::sync::Mutex::new(engine(31, cfg.clone()));
    let (shared, shared_writer) = SharedEngine::spawn(engine(31, cfg.clone()));
    let (banded, banded_handle) = BandedEngine::spawn(engine(31, cfg), 3);
    let flavours: Vec<(&str, &dyn Serving)> =
        vec![("mutex", &mutex_engine), ("shared", &shared), ("banded", &banded)];

    // (request line, typed request if expressible, expected kind)
    let flood_cols = format!("MPREDICT 0{}", " 1".repeat(MAX_MPREDICT_COLS + 1));
    let flood_events = format!("MRATE{}", " 1 1 1.0".repeat(MAX_MRATE_EVENTS + 1));
    let cases: Vec<(String, Option<Request>, ErrorKind)> = vec![
        (
            "PREDICT 999 0".into(),
            Some(Request::Predict { row: 999, col: 0 }),
            ErrorKind::OutOfRange,
        ),
        (
            "MPREDICT 999 0 1".into(),
            Some(Request::MPredict { row: 999, cols: vec![0, 1] }),
            ErrorKind::OutOfRange,
        ),
        (
            flood_cols,
            Some(Request::MPredict { row: 0, cols: vec![1; MAX_MPREDICT_COLS + 1] }),
            ErrorKind::TooManyCols,
        ),
        (
            "TOPN 0 0".into(),
            Some(Request::TopN { row: 0, n: 0 }),
            ErrorKind::Usage("TOPN <row> <n>".into()),
        ),
        (
            format!("TOPN 0 {}", MAX_TOPN_ITEMS + 1),
            Some(Request::TopN { row: 0, n: MAX_TOPN_ITEMS + 1 }),
            ErrorKind::TooManyItems,
        ),
        (
            "RATE 0 0 NaN".into(),
            Some(Request::Rate { row: 0, col: 0, value: f32::NAN }),
            ErrorKind::InvalidValue,
        ),
        (
            "RATE 4000000000 0 3.0".into(),
            Some(Request::Rate { row: 4_000_000_000, col: 0, value: 3.0 }),
            ErrorKind::OutOfBounds,
        ),
        (
            "MRATE 0 1 NaN 0 2 3.0".into(),
            Some(Request::MRate { ratings: vec![(0, 1, f32::NAN), (0, 2, 3.0)] }),
            ErrorKind::InvalidValue,
        ),
        (
            flood_events,
            Some(Request::MRate { ratings: vec![(1, 1, 1.0); MAX_MRATE_EVENTS + 1] }),
            ErrorKind::TooManyEvents,
        ),
        ("BOGUS".into(), None, ErrorKind::UnknownVerb("BOGUS".into())),
        ("".into(), None, ErrorKind::Empty),
    ];

    for (name, flavour) in &flavours {
        for (line, request, kind) in &cases {
            // text codec: the exact legacy string
            assert_eq!(
                handle_line(*flavour, line),
                Some(kind.to_line()),
                "{name}: `{line}`"
            );
            // binary codec: the typed response survives its frame
            if let Some(req) = request {
                let resp = dispatch(*flavour, req);
                assert_eq!(resp, Response::Error(kind.clone()), "{name}: {req:?}");
                let bytes = resp.encode_frame(9);
                let mut cursor = &bytes[..];
                let FrameRead::Frame(frame) = read_frame(&mut cursor).unwrap() else {
                    panic!("{name}: bad frame for {kind:?}");
                };
                assert_eq!(
                    Response::decode_frame(&frame),
                    Ok(Response::Error(kind.clone())),
                    "{name}: {kind:?}"
                );
            }
        }
        // backpressure needs a full buffer: fill, hit it on RATE and
        // MRATE, then flush to recover
        assert_eq!(
            handle_line(*flavour, "RATE 0 0 3.0"),
            Some("OK buffered".into()),
            "{name}"
        );
        assert_eq!(
            handle_line(*flavour, "RATE 0 1 3.0"),
            Some(ErrorKind::Backpressure.to_line()),
            "{name}"
        );
        assert_eq!(
            dispatch(*flavour, &Request::MRate { ratings: vec![(0, 1, 3.0)] }),
            Response::Error(ErrorKind::Backpressure),
            "{name}"
        );
        assert_eq!(handle_line(*flavour, "FLUSH"), Some("OK flushed 1".into()), "{name}");
    }

    // malformed frames are binary-only: the typed kind decodes from a
    // truncated payload and an unknown opcode counts as unknown verb
    let full = Request::Predict { row: 1, col: 2 }.encode_frame(0);
    let mut cursor = &full[..full.len() - 3];
    assert!(matches!(read_frame(&mut cursor).unwrap(), FrameRead::Malformed(_)));

    shared_writer.join();
    banded_handle.join();
}

/// Regression pin for `--flush-mode exact` (the default): the reply
/// strings of every verb and error, byte for byte, against all three
/// serving flavours. These are the exact wire strings PR 4's typed
/// protocol layer froze; the relaxed flush mode must never leak into
/// them (it only changes *how* a flush trains, plus metrics lines that
/// appear in `STATS` solely when relaxed mode runs).
#[test]
fn exact_mode_wire_strings_stay_pinned() {
    // (request line, exact expected reply) in execution order — the
    // stateful verbs are sequenced so applied counts are deterministic.
    let script: Vec<(&str, &str)> = vec![
        ("RATE 0 5 4.5", "OK buffered"),
        ("FLUSH", "OK flushed 1"),
        ("FLUSH", "OK flushed 0"),
        ("MRATE 0 1 4.5 1 2 3.0", "OK buffered"),
        ("FLUSH", "OK flushed 2"),
        ("RATE 0 0 NaN", "ERR invalid-value"),
        ("RATE 0 0 inf", "ERR invalid-value"),
        ("RATE 4000000000 0 3.0", "ERR out-of-bounds"),
        ("PREDICT 999 0", "ERR out-of-range"),
        ("MPREDICT 0 999", "PREDS -"),
        ("MPREDICT 0", "ERR usage: MPREDICT <row> <col> [<col> ...]"),
        ("TOPN 0 0", "ERR usage: TOPN <row> <n>"),
        ("TOPN 0 257", "ERR too-many-items"),
        ("MRATE 0 1", "ERR usage: MRATE <row> <col> <value> [<row> <col> <value> ...]"),
        ("BOGUS", "ERR unknown verb `BOGUS`"),
        ("", "ERR empty"),
    ];
    fn run_script<S: Serving + ?Sized>(e: &S, flavour: &str, script: &[(&str, &str)]) {
        for (line, want) in script {
            let got = handle_line(e, line).unwrap();
            assert_eq!(got, *want, "{flavour}: `{line}`");
        }
        // PREDICT replies are model-dependent; pin the wire *shape*:
        // `PRED ` + a {:.4}-formatted float.
        let pred = handle_line(e, "PREDICT 0 0").unwrap();
        let value = pred.strip_prefix("PRED ").unwrap_or_else(|| {
            panic!("{flavour}: PREDICT reply `{pred}` lost its prefix")
        });
        let decimals = value.split('.').nth(1).unwrap_or("");
        assert_eq!(decimals.len(), 4, "{flavour}: `{pred}` is not {{:.4}}-formatted");
        assert!(handle_line(e, "QUIT").is_none(), "{flavour}: QUIT must close");
    }
    let mutexed = std::sync::Mutex::new(engine(70, StreamConfig::default()));
    run_script(&mutexed, "mutex", &script);
    let (shared, writer) = SharedEngine::spawn(engine(70, StreamConfig::default()));
    run_script(&shared, "shared", &script);
    writer.join();
    let (banded, handle) = BandedEngine::spawn(engine(70, StreamConfig::default()), 3);
    run_script(&banded, "banded", &script);
    handle.join();
}

/// Empty-payload ingest answers `Ignored` → `OK ignored` consistently
/// on both concurrent write paths (and the mutex flavour) — previously
/// only the caller-driven orchestrator had the `Ignored` contract.
#[test]
fn empty_batch_is_ignored_on_every_write_path() {
    let cfg = StreamConfig::default();
    let mutex_engine = std::sync::Mutex::new(engine(32, cfg.clone()));
    let (shared, shared_writer) = SharedEngine::spawn(engine(32, cfg.clone()));
    let (banded, banded_handle) = BandedEngine::spawn(engine(32, cfg), 2);
    let flavours: Vec<(&str, &dyn Serving)> =
        vec![("mutex", &mutex_engine), ("shared", &shared), ("banded", &banded)];
    for (name, flavour) in &flavours {
        assert_eq!(flavour.rate_many(&[]), IngestResult::Ignored, "{name}");
        assert_eq!(
            Response::from(flavour.rate_many(&[])).encode_text(),
            "OK ignored",
            "{name}"
        );
    }
    shared_writer.join();
    banded_handle.join();
}

/// The binary codec over real sockets: pipelined frames against the
/// auto-detecting server, responses tagged by sequence id, and the
/// `server.malformed_frames` / `server.unknown_verb` metrics asserted
/// through `STATS`.
#[test]
fn binary_tcp_pipelining_and_abuse_metrics() {
    let e = engine(33, StreamConfig { batch_size: 1000, ..Default::default() });
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let stop = Arc::new(AtomicBool::new(false));
    let server_thread = {
        let stop = stop.clone();
        std::thread::spawn(move || server::serve(e, listener, stop, 3).unwrap())
    };

    // 1) a pipelined binary client: MRATE batches + MPREDICT + FLUSH in
    // flight together, replies in order
    {
        let mut client = LshmfClient::connect(addr, ClientCodec::Binary).unwrap();
        let mut pipe = client.pipeline();
        for base in 0..4u32 {
            let batch: Vec<(u32, u32, f32)> =
                (0..8).map(|k| (base * 7 + k, (base + k) % 15, 3.0)).collect();
            pipe.push(&Request::MRate { ratings: batch }).unwrap();
        }
        pipe.push(&Request::MPredict { row: 0, cols: (0..15).collect() }).unwrap();
        pipe.push(&Request::Flush).unwrap();
        let replies = pipe.finish().unwrap();
        assert_eq!(replies.len(), 6);
        for reply in &replies[..4] {
            assert_eq!(reply, &Response::Ok(OkBody::Buffered), "{reply:?}");
        }
        assert!(matches!(&replies[4], Response::Preds(ps) if ps.len() == 15));
        assert!(matches!(replies[5], Response::Ok(OkBody::Flushed { .. })));
        client.shutdown().unwrap();
    }

    // 2) protocol abuse on raw sockets: a well-framed unknown opcode,
    // then (separate connection) an unframed byte stream
    {
        let mut stream = TcpStream::connect(addr).unwrap();
        let mut frame = vec![BINARY_FRAME_BYTE, 0x7E]; // unknown opcode
        frame.extend_from_slice(&5u32.to_le_bytes()); // seq
        frame.extend_from_slice(&0u32.to_le_bytes()); // empty payload
        stream.write_all(&frame).unwrap();
        let FrameRead::Frame(reply) = read_frame(&mut stream).unwrap() else {
            panic!("expected an error frame");
        };
        assert_eq!(reply.seq, 5, "tagged with the offending request's seq");
        assert!(matches!(
            Response::decode_frame(&reply),
            Ok(Response::Error(ErrorKind::UnknownVerb(_)))
        ));
        drop(stream);

        let mut stream = TcpStream::connect(addr).unwrap();
        // first byte claims binary, second frame byte is garbage: the
        // server replies a typed malformed-frame error and closes
        stream.write_all(&Request::Flush.encode_frame(0)).unwrap();
        let FrameRead::Frame(first) = read_frame(&mut stream).unwrap() else {
            panic!("expected the FLUSH reply");
        };
        assert!(matches!(Response::decode_frame(&first), Ok(Response::Ok(_))));
        stream.write_all(&[0xFF, 0x00, 0x01]).unwrap();
        let FrameRead::Frame(err) = read_frame(&mut stream).unwrap() else {
            panic!("expected a malformed-frame error");
        };
        assert!(matches!(
            Response::decode_frame(&err),
            Ok(Response::Error(ErrorKind::MalformedFrame(_)))
        ));
        // connection is closed after the error
        assert!(matches!(read_frame(&mut stream).unwrap(), FrameRead::Eof));
    }

    // 3) a text connection (same auto server) sees the abuse counters
    {
        let mut client = LshmfClient::connect(addr, ClientCodec::Text).unwrap();
        // also drive the text-side unknown-verb counter
        let mut raw = TcpStream::connect(addr).unwrap();
        raw.write_all(b"FROBNICATE\n").unwrap();
        let mut reply = String::new();
        BufReader::new(raw.try_clone().unwrap()).read_line(&mut reply).unwrap();
        assert!(reply.starts_with("ERR unknown verb"), "{reply}");
        drop(raw);
        let Response::Stats(body) = client.stats().unwrap() else {
            panic!("expected stats");
        };
        assert!(body.contains("counter server.malformed_frames 1"), "{body}");
        assert!(body.contains("counter server.unknown_verb 2"), "{body}");
        assert!(body.contains("counter server.mrate 4"), "{body}");
        client.shutdown().unwrap();
    }

    stop.store(true, Ordering::Relaxed);
    let _ = TcpStream::connect(addr);
    let engine = server_thread.join().unwrap();
    assert_eq!(engine.buffered(), 0, "drained on shutdown");
}

/// Codec policy: a `--codec binary` server refuses a text greeting with
/// a typed malformed-frame error, while `--codec text` and `auto`
/// behave as before for text clients.
#[test]
fn binary_only_server_rejects_text_greeting() {
    let e = engine(34, StreamConfig::default());
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let stop = Arc::new(AtomicBool::new(false));
    let server_thread = {
        let stop = stop.clone();
        std::thread::spawn(move || {
            let mut cfg = ServeConfig::default();
            cfg.server.threads = 2;
            cfg.server.codec = CodecChoice::Binary;
            cfg.engine.shards = 4;
            server::serve_sharded_with(e, listener, stop, &cfg).unwrap()
        })
    };
    // binary works
    let mut client = LshmfClient::connect(addr, ClientCodec::Binary).unwrap();
    assert!(matches!(client.predict(0, 0).unwrap(), Response::Pred(_)));
    client.shutdown().unwrap();
    // a text line is a malformed frame (first byte 'P' != frame byte)
    let mut stream = TcpStream::connect(addr).unwrap();
    stream.write_all(b"PREDICT 0 0\n").unwrap();
    let FrameRead::Frame(err) = read_frame(&mut stream).unwrap() else {
        panic!("expected a malformed-frame error frame");
    };
    assert!(matches!(
        Response::decode_frame(&err),
        Ok(Response::Error(ErrorKind::MalformedFrame(_)))
    ));
    assert!(matches!(read_frame(&mut stream).unwrap(), FrameRead::Eof));
    drop(stream);
    stop.store(true, Ordering::Relaxed);
    let _ = TcpStream::connect(addr);
    server_thread.join().unwrap();
}

/// Both codecs agree verb by verb against one auto server — the typed
/// reply a binary client decodes equals what a text client decodes for
/// the same request sequence (read-only verbs, so the two passes see
/// identical state).
#[test]
fn text_and_binary_clients_decode_identical_replies() {
    let e = engine(35, StreamConfig::default());
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let stop = Arc::new(AtomicBool::new(false));
    let server_thread = {
        let stop = stop.clone();
        std::thread::spawn(move || server::serve(e, listener, stop, 2).unwrap())
    };
    let requests: Vec<Request> = vec![
        Request::Predict { row: 0, col: 0 },
        Request::Predict { row: 999, col: 0 },
        Request::MPredict { row: 2, cols: vec![0, 3, 999] },
        Request::TopN { row: 1, n: 4 },
        Request::TopN { row: 999, n: 4 },
        Request::TopN { row: 0, n: 0 },
    ];
    let run = |codec: ClientCodec| -> Vec<Response> {
        let mut client = LshmfClient::connect(addr, codec).unwrap();
        let replies: Vec<Response> =
            requests.iter().map(|r| client.request(r).unwrap()).collect();
        client.shutdown().unwrap();
        replies
    };
    let text = run(ClientCodec::Text);
    let binary = run(ClientCodec::Binary);
    for ((t, b), req) in text.iter().zip(&binary).zip(&requests) {
        // text replies carry {:.4}-quantized floats; compare through
        // the text encoding, which is the wire-compat contract
        assert_eq!(t.encode_text(), b.encode_text(), "{req:?}");
    }
    stop.store(true, Ordering::Relaxed);
    let _ = TcpStream::connect(addr);
    server_thread.join().unwrap();
}

/// `StreamConfig::reject_when_full` contract, at the exact boundary:
/// ingest yields `Rejected` exactly when the buffer already holds
/// `queue_capacity` un-flushed events — not one sooner — and recovers
/// after a flush.
#[test]
fn backpressure_boundary_is_exact() {
    for capacity in [1usize, 4, 9] {
        let mut e = engine(
            43,
            StreamConfig {
                queue_capacity: capacity,
                batch_size: usize::MAX, // never auto-flush
                reject_when_full: true,
                ..Default::default()
            },
        );
        for k in 0..capacity {
            assert_eq!(
                e.rate(0, k as u32, 3.0),
                IngestResult::Buffered,
                "capacity {capacity}, event {k} must buffer"
            );
            assert_eq!(e.buffered(), k + 1);
        }
        assert_eq!(
            e.rate(0, 99, 3.0),
            IngestResult::Rejected,
            "capacity {capacity}: event {capacity} must reject"
        );
        assert_eq!(e.buffered(), capacity, "rejected event must not be buffered");
        assert_eq!(e.flush(), capacity);
        assert_eq!(e.rate(0, 99, 3.0), IngestResult::Buffered, "recovers after flush");
    }
}

/// Without `reject_when_full`, hitting capacity auto-flushes instead of
/// rejecting (the server default), and the new event is retained.
#[test]
fn full_queue_auto_flushes_by_default() {
    let mut e = engine(
        44,
        StreamConfig {
            queue_capacity: 3,
            batch_size: usize::MAX,
            reject_when_full: false,
            ..Default::default()
        },
    );
    for k in 0..3 {
        assert_eq!(e.rate(0, k, 3.0), IngestResult::Buffered);
    }
    match e.rate(0, 9, 3.0) {
        IngestResult::Flushed { applied } => assert_eq!(applied, 3),
        other => panic!("expected auto-flush, got {other:?}"),
    }
    assert_eq!(e.buffered(), 1, "the triggering event stays buffered");
}

/// `STATS` must never pair a pre-flush version with a post-flush
/// buffered count: both ride inside one published snapshot, so a single
/// pointer load yields a coherent (version, buffered) pair.
#[test]
fn stats_reads_one_coherent_snapshot() {
    let e = engine(46, StreamConfig { batch_size: 4, ..Default::default() });
    let (shared, writer_handle) = SharedEngine::spawn(e);
    // Sequential: the pair tracks the engine exactly.
    for k in 0..3u32 {
        assert_eq!(shared.rate(0, k, 3.0), IngestResult::Buffered);
        let stats = shared.stats();
        assert!(stats.contains(&format!("buffered {}", k + 1)), "{stats}");
        assert!(stats.contains("version 0"), "{stats}");
    }
    // 4th rating triggers the batch flush: buffered and version move
    // together in the very next snapshot.
    assert!(matches!(shared.rate(0, 3, 3.0), IngestResult::Flushed { .. }));
    let stats = shared.stats();
    assert!(stats.contains("buffered 0"), "{stats}");
    assert!(stats.contains("version 1"), "{stats}");

    // Concurrent: a racing reader sees monotone versions and never a
    // buffered count that one batch could not hold.
    std::thread::scope(|scope| {
        let reader = {
            let shared = shared.clone();
            scope.spawn(move || {
                let mut last_version = 0u64;
                for _ in 0..200 {
                    let snap = shared.snapshot();
                    assert!(snap.version >= last_version, "version went backwards");
                    assert!(snap.buffered() < 4, "buffered {} exceeds batch", snap.buffered());
                    last_version = snap.version;
                }
            })
        };
        let rater = {
            let shared = shared.clone();
            scope.spawn(move || {
                for k in 0..64u32 {
                    let r = shared.rate(k % 30, k % 15, 4.0);
                    assert!(
                        matches!(r, IngestResult::Buffered | IngestResult::Flushed { .. }),
                        "{r:?}"
                    );
                }
            })
        };
        reader.join().unwrap();
        rater.join().unwrap();
    });
    writer_handle.join();
}

/// The writer-thread path applies exactly what the equivalent direct
/// engine sequence applies (same seed, same events → same flush counts
/// and final dimensions).
#[test]
fn shared_path_matches_direct_engine() {
    let e = engine(45, StreamConfig { batch_size: 100, ..Default::default() });
    let (shared, writer) = SharedEngine::spawn(e);
    for k in 0..5u32 {
        assert_eq!(
            shared.rate(2, 20 + k, 2.5),
            IngestResult::Buffered,
            "event {k}"
        );
    }
    assert_eq!(shared.flush(), 5);
    assert_eq!(shared.flush(), 0, "nothing left to apply");
    let from_shared = writer.join();

    let mut direct = engine(45, StreamConfig { batch_size: 100, ..Default::default() });
    for k in 0..5u32 {
        assert_eq!(direct.rate(2, 20 + k, 2.5), IngestResult::Buffered);
    }
    assert_eq!(direct.flush(), 5);
    assert_eq!(from_shared.dims(), direct.dims());
}

/// Admission control over a real socket, via the config-driven entry
/// point: a client flooding `TOPN` past its token bucket sees typed
/// `ERR overloaded` refusals, while a concurrent `RATE` client — with
/// its own per-connection bucket — is admitted throughout.
#[test]
fn flooding_client_is_rate_limited_while_ingest_is_admitted() {
    let e = engine(46, StreamConfig::default());
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let stop = Arc::new(AtomicBool::new(false));
    let server_thread = {
        let stop = stop.clone();
        std::thread::spawn(move || {
            let mut cfg = ServeConfig::default();
            cfg.server.threads = 2;
            // 1 token/s with burst 3: the flood exhausts the bucket in
            // milliseconds and no refill lands within the test
            cfg.limits.rate_per_conn = 1;
            cfg.limits.burst = 3;
            server::serve_with(e, listener, stop, &cfg).unwrap()
        })
    };

    let rater = std::thread::spawn(move || {
        let mut conn = TcpStream::connect(addr).unwrap();
        let mut reader = BufReader::new(conn.try_clone().unwrap());
        let mut replies = Vec::new();
        for k in 0..3 {
            conn.write_all(format!("RATE 0 {k} 4.0\n").as_bytes()).unwrap();
            let mut line = String::new();
            reader.read_line(&mut line).unwrap();
            replies.push(line.trim().to_string());
        }
        conn.write_all(b"QUIT\n").unwrap();
        replies
    });

    let mut conn = TcpStream::connect(addr).unwrap();
    let mut reader = BufReader::new(conn.try_clone().unwrap());
    let (mut served, mut refused) = (0, 0);
    for _ in 0..10 {
        conn.write_all(b"TOPN 0 3\n").unwrap();
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        match line.trim() {
            l if l.starts_with("TOPN ") => served += 1,
            "ERR overloaded" => refused += 1,
            other => panic!("unexpected reply: {other}"),
        }
    }
    conn.write_all(b"QUIT\n").unwrap();
    drop(conn);
    // the burst is admitted, the flood beyond it is refused
    assert!(served >= 3, "served={served}");
    assert!(refused >= 1, "refused={refused}");

    // the concurrent ingest client never saw a refusal: buckets are
    // per connection, so one noisy reader cannot starve ingest
    for reply in rater.join().unwrap() {
        assert_eq!(reply, "OK buffered");
    }

    stop.store(true, Ordering::Relaxed);
    let _ = TcpStream::connect(addr);
    server_thread.join().unwrap();
}
