//! Property tests on coordinator and substrate invariants, via the
//! in-repo `lshmf::prop` mini-framework (proptest is unavailable offline).

use lshmf::coordinator::banded::BandedEngine;
use lshmf::coordinator::protocol::{
    read_frame, ErrorKind, FrameRead, OkBody, Request, Response, MAX_MPREDICT_COLS,
    MAX_MRATE_EVENTS, MAX_TOPN_ITEMS, MPREDICT_USAGE, MRATE_USAGE, PREDICT_USAGE,
    RATE_USAGE, TOPN_USAGE,
};
use lshmf::coordinator::rotation::RotationPlan;
use lshmf::coordinator::server::handle_line;
use lshmf::coordinator::shared::SharedEngine;
use lshmf::coordinator::stream::{FlushMode, StreamConfig, StreamOrchestrator};
use lshmf::coordinator::Engine;
use lshmf::lsh::{NeighbourSearch, OnlineHashState, SimLsh};
use lshmf::metrics::Registry;
use lshmf::mf::neighbourhood::{train_culsh_logged, CulshConfig, CulshModel};
use lshmf::prop::{check, Gen};
use lshmf::rng::Rng;
use lshmf::sparse::{BlockGrid, Csc, Csr, Triples};
use std::sync::Mutex;

fn gen_triples(g: &mut Gen, max_m: usize, max_n: usize, max_nnz: usize) -> Triples {
    let m = g.usize(2..=max_m);
    let n = g.usize(2..=max_n);
    let nnz = g.usize(1..=max_nnz);
    let mut t = Triples::new(m, n);
    let mut seen = std::collections::HashSet::new();
    for _ in 0..nnz {
        let (i, j) = (g.rng().below(m), g.rng().below(n));
        if seen.insert((i, j)) {
            t.push(i, j, 0.5 + g.rng().f32() * 4.5);
        }
    }
    t
}

/// The rotation schedule is a Latin square for every D and matrix shape.
#[test]
fn prop_rotation_schedule_is_latin_square() {
    check("rotation is latin square", 60, |g| {
        let t = gen_triples(g, 60, 60, 300);
        let d = g.usize(1..=6);
        RotationPlan::new(&t, d).validate().is_ok()
    });
}

/// CSR and CSC views agree entry-for-entry with the source triples.
#[test]
fn prop_csr_csc_roundtrip() {
    check("csr/csc roundtrip", 60, |g| {
        let t = gen_triples(g, 40, 40, 250);
        let csr = Csr::from_triples(&t);
        let csc = Csc::from_triples(&t);
        if csr.nnz() != t.nnz() || csc.nnz() != t.nnz() {
            return false;
        }
        t.entries().iter().all(|&(i, j, r)| {
            csr.row(i as usize).any(|(jj, rr)| jj == j as usize && rr == r)
                && csc.col(j as usize).any(|(ii, rr)| ii == i as usize && rr == r)
        })
    });
}

/// Block partitions cover every entry exactly once, for any D.
#[test]
fn prop_block_grid_partitions() {
    check("block grid partitions", 60, |g| {
        let t = gen_triples(g, 50, 50, 300);
        let d = g.usize(1..=5);
        let grid = BlockGrid::partition(&t, d);
        let total: usize = grid.blocks().iter().map(|b| b.entries.len()).sum();
        total == t.nnz()
    });
}

/// Top-K rows are always exactly K, self-free and duplicate-free.
#[test]
fn prop_topk_invariants() {
    check("topk invariants", 25, |g| {
        let t = gen_triples(g, 40, 30, 200);
        let n = t.ncols();
        if n < 3 {
            return true;
        }
        let csc = Csc::from_triples(&t);
        let k = g.usize(1..=(n - 1).min(8));
        let q = g.usize(1..=6);
        let mut lsh = SimLsh::new(g.usize(1..=2), q, 8, 2);
        let (topk, _) = lsh.build(&csc, k, g.rng());
        (0..n).all(|j| {
            let nb = topk.neighbours(j);
            let set: std::collections::HashSet<_> = nb.iter().collect();
            nb.len() == k
                && set.len() == k
                && nb.iter().all(|&c| (c as usize) < n && c as usize != j)
        })
    });
}

/// Online hash absorption ≡ from-scratch build (up to fp rounding at
/// near-zero accumulators) for arbitrary splits.
#[test]
fn prop_online_hash_matches_rebuild() {
    check("online hash == rebuild", 15, |g| {
        let full = gen_triples(g, 30, 15, 150);
        if full.nnz() < 4 {
            return true;
        }
        // random split point over columns/rows
        let base_rows = g.usize(1..=full.nrows());
        let base_cols = g.usize(1..=full.ncols());
        let mut base = Triples::new(base_rows, base_cols);
        let mut inc = Vec::new();
        for &(i, j, r) in full.entries() {
            if (i as usize) < base_rows && (j as usize) < base_cols {
                base.push(i as usize, j as usize, r);
            } else {
                inc.push((i, j, r));
            }
        }
        let lsh = SimLsh { p: 1, q: 4, g: 8, psi_power: 2, center: 0.0, seed: 7 };
        let mut online = OnlineHashState::build(lsh.clone(), &Csc::from_triples(&base));
        online.apply_increment(&inc, full.ncols());
        let scratch = OnlineHashState::build(lsh, &Csc::from_triples(&full));
        let mut flips = 0;
        let mut total = 0;
        for round in 0..4 {
            for j in 0..full.ncols() {
                total += 1;
                if online.hash(round, 0, j) != scratch.hash(round, 0, j) {
                    flips += 1;
                }
            }
        }
        flips * 50 <= total // ≤ 2% near-zero sign flips tolerated
    });
}

/// Small trained serving engine (mirrors `tests/serving.rs`'s fixture).
fn serving_engine(seed: u64, stream_cfg: StreamConfig) -> Engine {
    let mut rng = Rng::seeded(seed);
    let (m, n) = (30, 15);
    let mut t = Triples::new(m, n);
    let mut seen = std::collections::HashSet::new();
    while t.nnz() < 180 {
        let (i, j) = (rng.below(m), rng.below(n));
        if seen.insert((i, j)) {
            t.push(i, j, 1.0 + rng.f32() * 4.0);
        }
    }
    let csr = Csr::from_triples(&t);
    let csc = Csc::from_triples(&t);
    let lsh = SimLsh::new(1, 5, 8, 2);
    let hash_state = OnlineHashState::build(lsh, &csc);
    let (topk, _) = hash_state.topk(4, &mut rng);
    let cfg = CulshConfig { f: 4, k: 4, epochs: 4, ..Default::default() };
    let (model, _) = train_culsh_logged(&csr, topk, &cfg, &mut rng);
    let metrics = Registry::new();
    let orch = StreamOrchestrator::new(
        model,
        hash_state,
        t,
        stream_cfg,
        cfg,
        rng.split(1),
        metrics.clone(),
    );
    Engine::new(orch, (1.0, 5.0), metrics)
}

/// Serving parity: across randomized rate/flush interleavings — with
/// growth, re-ratings, NaN values and out-of-bounds ids mixed in — the
/// sharded concurrent engine's `PREDICT`/`MPREDICT`/`TOPN`/`RATE`/`FLUSH`
/// replies are byte-identical to the `Mutex<Engine>` flavour, for any
/// shard count. Extends the `shared_engine_protocol_parity` unit test to
/// arbitrary interleavings.
#[test]
fn prop_sharded_serving_matches_mutex_engine() {
    check("sharded serving parity", 8, |g| {
        let seed = 4600 + g.usize(0..=40) as u64;
        let stream_cfg = StreamConfig {
            batch_size: g.usize(2..=10),
            max_rows: 200,
            max_cols: 200,
            ..Default::default()
        };
        let single = Mutex::new(serving_engine(seed, stream_cfg.clone()));
        let shards = g.usize(1..=6);
        let (shared, writer) =
            SharedEngine::spawn_sharded(serving_engine(seed, stream_cfg), shards);
        let mut ok = true;
        for _ in 0..g.usize(20..=50) {
            let line = match g.usize(0..=5) {
                0 => format!("PREDICT {} {}", g.usize(0..=35), g.usize(0..=20)),
                1 => format!("TOPN {} {}", g.usize(0..=35), g.usize(1..=8)),
                2 => format!(
                    "MPREDICT {} {} {} {}",
                    g.usize(0..=35),
                    g.usize(0..=20),
                    g.usize(0..=20),
                    g.usize(0..=20)
                ),
                3 => {
                    let r = match g.usize(0..=8) {
                        0 => "NaN".to_string(),
                        1 => "inf".to_string(),
                        _ => format!("{:.1}", 1.0 + g.usize(0..=8) as f32 * 0.5),
                    };
                    let i = if g.usize(0..=9) == 0 {
                        4_000_000_000u32
                    } else {
                        g.usize(0..=33) as u32
                    };
                    format!("RATE {i} {} {r}", g.usize(0..=18))
                }
                4 => {
                    // MRATE batches (occasionally poisoned) must answer
                    // identically too: one admission unit per line
                    let mut line = "MRATE".to_string();
                    for _ in 0..g.usize(1..=4) {
                        let r = if g.usize(0..=11) == 0 {
                            "NaN".to_string()
                        } else {
                            format!("{:.1}", 1.0 + g.usize(0..=8) as f32 * 0.5)
                        };
                        line.push_str(&format!(
                            " {} {} {r}",
                            g.usize(0..=33),
                            g.usize(0..=18)
                        ));
                    }
                    line
                }
                _ => "FLUSH".to_string(),
            };
            let a = handle_line(&single, &line);
            let b = handle_line(&shared, &line);
            if a != b {
                eprintln!("serving parity mismatch on `{line}`: {a:?} vs {b:?}");
                ok = false;
                break;
            }
        }
        writer.join();
        ok
    });
}

/// Multi-writer serving parity: across randomized rate/flush/growth
/// interleavings — universe-growth ratings spread across bands, NaN
/// values, out-of-bounds ids and re-ratings mixed in — the per-band
/// multi-writer engine's replies are byte-identical to the
/// `Mutex<Engine>` reference at 1, 2 and 4 writers. The flush epoch
/// merges per-band buffers back into arrival order and runs the exact
/// single-writer computation, so equality must be bit-exact, not
/// approximate.
#[test]
fn prop_banded_multi_writer_matches_mutex_engine() {
    check("banded multi-writer parity", 6, |g| {
        let seed = 5200 + g.usize(0..=40) as u64;
        let stream_cfg = StreamConfig {
            batch_size: g.usize(2..=10),
            max_rows: 200,
            max_cols: 200,
            ..Default::default()
        };
        let single = Mutex::new(serving_engine(seed, stream_cfg.clone()));
        let writers = [1usize, 2, 4][g.usize(0..=2)];
        let (banded, handle) =
            BandedEngine::spawn(serving_engine(seed, stream_cfg), writers);
        let mut ok = true;
        let mut grow_step = 0u32;
        for _ in 0..g.usize(25..=55) {
            let line = match g.usize(0..=6) {
                0 => format!("PREDICT {} {}", g.usize(0..=35), g.usize(0..=40)),
                1 => format!("TOPN {} {}", g.usize(0..=35), g.usize(1..=8)),
                2 => format!(
                    "MPREDICT {} {} {} {}",
                    g.usize(0..=35),
                    g.usize(0..=40),
                    g.usize(0..=40),
                    g.usize(0..=40)
                ),
                3 => {
                    let r = match g.usize(0..=8) {
                        0 => "NaN".to_string(),
                        1 => "inf".to_string(),
                        _ => format!("{:.1}", 1.0 + g.usize(0..=8) as f32 * 0.5),
                    };
                    let i = if g.usize(0..=9) == 0 {
                        4_000_000_000u32
                    } else {
                        g.usize(0..=33) as u32
                    };
                    format!("RATE {i} {} {r}", g.usize(0..=18))
                }
                4 => {
                    // universe growth: column ids walk beyond the
                    // current extent, landing in different bands
                    grow_step += 1;
                    format!(
                        "RATE {} {} 4.5",
                        30 + grow_step % 7,
                        15 + (grow_step * 5) % 23
                    )
                }
                5 => {
                    // MRATE batches spanning bands (and occasionally
                    // growing the universe) must stay bit-identical:
                    // the batch is one cross-band admission unit
                    grow_step += 1;
                    let mut line = "MRATE".to_string();
                    for k in 0..g.usize(1..=4) {
                        let j = if k == 0 && g.usize(0..=3) == 0 {
                            15 + (grow_step * 3) % 23 // growth column
                        } else {
                            g.usize(0..=18) as u32
                        };
                        line.push_str(&format!(
                            " {} {j} {:.1}",
                            g.usize(0..=33),
                            1.0 + g.usize(0..=8) as f32 * 0.5
                        ));
                    }
                    line
                }
                _ => "FLUSH".to_string(),
            };
            let a = handle_line(&single, &line);
            let b = handle_line(&banded, &line);
            if a != b {
                eprintln!(
                    "banded parity mismatch (writers={writers}) on `{line}`: {a:?} vs {b:?}"
                );
                ok = false;
                break;
            }
        }
        handle.join();
        ok
    });
}

/// The relaxed-flush acceptance property, across randomized multi-round
/// scripts with row and column growth at 1, 2 and 4 writers:
///
/// * **Bounded divergence** — after the same script, the relaxed-mode
///   banded engine's factors sit within ε (Frobenius, relative to the
///   parameter norm) of the exact sequential reference; at one writer
///   the relaxed epoch is the sequential straggler path and the match
///   is bit-exact.
/// * **Relaxed cross-flavour bit-identity** — the relaxed banded engine
///   and a relaxed single-writer orchestrator with `flush_bands ==
///   writers` run the *same* deterministic rotation, so their factors
///   agree bit for bit (relaxation trades exactness against the exact
///   reference, not determinism or flavour agreement).
/// * `--flush-mode exact` stays the default: the exact-mode parity
///   property tests above keep pinning its replies byte-identical to
///   the `Mutex<Engine>` oracle.
#[test]
fn prop_relaxed_flush_bounded_divergence() {
    check("relaxed flush bounded divergence", 4, |g| {
        let seed = 6100 + g.usize(0..=30) as u64;
        let writers = [1usize, 2, 4][g.usize(0..=2)];
        // Explicit flushes only: a huge batch_size keeps flush
        // boundaries identical across the three engines.
        let exact_cfg = StreamConfig {
            batch_size: 1 << 20,
            max_rows: 400,
            max_cols: 400,
            flush_mode: FlushMode::Exact,
            ..Default::default()
        };
        let relaxed_cfg = StreamConfig {
            flush_mode: FlushMode::Relaxed,
            flush_bands: writers,
            ..exact_cfg.clone()
        };
        let mut exact = serving_engine(seed, exact_cfg);
        let mut relaxed_single = serving_engine(seed, relaxed_cfg.clone());
        let (banded, handle) = BandedEngine::spawn(serving_engine(seed, relaxed_cfg), writers);
        for _round in 0..g.usize(2..=3) {
            // a flush-worth of ratings: growth rows/cols mixed with
            // in-universe traffic and re-rates, spread over every band
            for _ in 0..g.usize(30..=60) {
                let i = g.usize(0..=45) as u32; // fixture is 30x15: ≥ 30 grows rows
                let j = g.usize(0..=25) as u32; // ≥ 15 grows columns
                let r = 1.0 + g.usize(0..=8) as f32 * 0.5;
                let a = exact.rate(i, j, r);
                let b = relaxed_single.rate(i, j, r);
                let c = banded.rate(i, j, r);
                if a != b || a != c {
                    eprintln!("ingest replies diverged on ({i},{j},{r}): {a:?} {b:?} {c:?}");
                    return false;
                }
            }
            let (fa, fb, fc) = (exact.flush(), relaxed_single.flush(), banded.flush());
            if fa != fb || fa != fc {
                eprintln!("flush counts diverged: {fa} {fb} {fc}");
                return false;
            }
        }
        let banded_engine = handle.join();
        if exact.dims() != banded_engine.dims() || exact.dims() != relaxed_single.dims() {
            eprintln!(
                "dims diverged: exact {:?} banded {:?} single {:?}",
                exact.dims(),
                banded_engine.dims(),
                relaxed_single.dims()
            );
            return false;
        }
        let dist = exact.model().frobenius_distance(banded_engine.model());
        let scale = exact.model().frobenius_norm().max(1.0);
        if writers == 1 && dist != 0.0 {
            eprintln!("one-writer relaxed must be bit-identical to exact, drifted {dist}");
            return false;
        }
        if dist > 0.02 * scale {
            eprintln!(
                "writers={writers}: relaxed drifted {dist} vs parameter norm {scale}"
            );
            return false;
        }
        let flavour_gap = relaxed_single.model().frobenius_distance(banded_engine.model());
        if flavour_gap != 0.0 {
            eprintln!(
                "writers={writers}: relaxed flavours disagree by {flavour_gap} (must be 0)"
            );
            return false;
        }
        true
    });
}

// ---------------------------------------------------------- protocol codecs

/// A finite f32 whose `Display` form round-trips exactly (any finite
/// float does — Rust prints the shortest decimal that re-parses to the
/// same bits).
fn gen_finite_f32(g: &mut Gen) -> f32 {
    g.f32(-1e6, 1e6)
}

/// A float exactly representable in 4 decimal digits (k/16), so the
/// text codec's lossy `{:.4}` reply forms round-trip bit-exactly.
fn gen_quantized_f32(g: &mut Gen) -> f32 {
    (g.u32(0..160_001) as f32) / 16.0 - 5000.0
}

fn gen_request(g: &mut Gen) -> Request {
    match g.usize(0..=8) {
        0 => Request::Predict { row: g.usize(0..=1 << 20), col: g.usize(0..=1 << 20) },
        1 => Request::MPredict {
            row: g.usize(0..=1 << 20),
            cols: g.vec(1..=MAX_MPREDICT_COLS.min(48), |g| g.u32(0..1 << 24)),
        },
        2 => Request::TopN { row: g.usize(0..=1 << 20), n: g.usize(1..=MAX_TOPN_ITEMS) },
        3 => Request::Rate {
            row: g.u32(0..1 << 24),
            col: g.u32(0..1 << 24),
            value: gen_finite_f32(g),
        },
        4 => Request::MRate {
            ratings: g.vec(1..=MAX_MRATE_EVENTS.min(48), |g| {
                (g.u32(0..1 << 24), g.u32(0..1 << 24), gen_finite_f32(g))
            }),
        },
        5 => Request::Flush,
        6 => Request::Stats,
        7 => Request::Subscribe,
        _ => Request::Shutdown,
    }
}

fn gen_error_kind(g: &mut Gen) -> ErrorKind {
    let words = ["flood", "verb", "frame", "cap", "probe"];
    match g.usize(0..=12) {
        0 => ErrorKind::OutOfRange,
        1 => ErrorKind::TooManyCols,
        2 => ErrorKind::TooManyItems,
        3 => ErrorKind::TooManyEvents,
        4 => ErrorKind::Backpressure,
        5 => ErrorKind::InvalidValue,
        6 => ErrorKind::OutOfBounds,
        7 => ErrorKind::Empty,
        8 => ErrorKind::Overloaded,
        9 => ErrorKind::Unavailable,
        10 => ErrorKind::UnknownVerb(g.choose(&words).to_string()),
        11 => {
            let usages = [PREDICT_USAGE, MPREDICT_USAGE, TOPN_USAGE, RATE_USAGE, MRATE_USAGE];
            ErrorKind::Usage(g.choose(&usages).to_string())
        }
        _ => ErrorKind::MalformedFrame(format!("truncated {} payload", g.choose(&words))),
    }
}

fn gen_response(g: &mut Gen) -> Response {
    match g.usize(0..=8) {
        0 => Response::Pred(gen_quantized_f32(g)),
        1 => Response::Preds(g.vec(1..=48, |g| {
            if g.bool() {
                Some(gen_quantized_f32(g))
            } else {
                None
            }
        })),
        2 => Response::TopN(g.vec(0..=24, |g| (g.u32(0..1 << 24), gen_quantized_f32(g)))),
        3 => Response::Ok(match g.usize(0..=2) {
            0 => OkBody::Buffered,
            1 => OkBody::Flushed { applied: g.usize(0..=1 << 20) as u64 },
            _ => OkBody::Ignored,
        }),
        // a realistic stats body: starts with `dims` (never colliding
        // with a structured reply prefix), newline-terminated lines
        4 => Response::Stats(format!(
            "dims {}x{}\nbuffered {}\nversion {}\ncounter server.rate {}\n",
            g.usize(1..=4096),
            g.usize(1..=4096),
            g.usize(0..=65536),
            g.usize(0..=1 << 20),
            g.usize(0..=1 << 20),
        )),
        5 => Response::Error(gen_error_kind(g)),
        6 => Response::Subscribed { version: g.usize(0..=1 << 20) as u64 },
        // An empty dirty list is the growth "everything changed" push.
        7 => Response::Push {
            version: g.usize(0..=1 << 20) as u64,
            dirty: g.vec(0..=8, |g| g.u32(0..64)),
        },
        _ => Response::Bye,
    }
}

fn binary_roundtrip_request(req: &Request) -> Option<Request> {
    let bytes = req.encode_frame(123);
    let mut cursor = &bytes[..];
    match read_frame(&mut cursor).ok()? {
        FrameRead::Frame(f) if f.seq == 123 => Request::decode_frame(&f).ok(),
        _ => None,
    }
}

fn binary_roundtrip_response(resp: &Response) -> Option<Response> {
    let bytes = resp.encode_frame(321);
    let mut cursor = &bytes[..];
    match read_frame(&mut cursor).ok()? {
        FrameRead::Frame(f) if f.seq == 321 => Response::decode_frame(&f).ok(),
        _ => None,
    }
}

/// Codec round-trip: an arbitrary `Request` survives encode → decode on
/// both codecs. Text `Display` floats re-parse to identical bits; the
/// binary codec is bit-exact by construction.
#[test]
fn prop_request_roundtrips_on_both_codecs() {
    check("request codec roundtrip", 200, |g| {
        let req = gen_request(g);
        let text_ok = Request::parse_text(&req.encode_text()) == Ok(req.clone());
        let binary_ok = binary_roundtrip_request(&req) == Some(req.clone());
        if !(text_ok && binary_ok) {
            eprintln!("codec roundtrip failed (text {text_ok}, binary {binary_ok}): {req:?}");
        }
        text_ok && binary_ok
    });
}

/// Codec round-trip for responses, including every `ErrorKind` wire
/// form, multi-line stats bodies, and the `{:.4}`-quantized reply
/// floats the text codec can carry exactly.
#[test]
fn prop_response_roundtrips_on_both_codecs() {
    check("response codec roundtrip", 200, |g| {
        let resp = gen_response(g);
        let text_ok = Response::decode_text(&resp.encode_text()) == Ok(resp.clone());
        let binary_ok = binary_roundtrip_response(&resp) == Some(resp.clone());
        if !(text_ok && binary_ok) {
            eprintln!("codec roundtrip failed (text {text_ok}, binary {binary_ok}): {resp:?}");
        }
        text_ok && binary_ok
    });
}

/// The TOML-subset parser round-trips what the config writer would emit.
#[test]
fn prop_config_parser_roundtrip() {
    check("config roundtrip", 100, |g| {
        let f = g.usize(1..=256);
        let k = g.usize(1..=256);
        let scale = (g.usize(1..=100) as f64) / 100.0;
        let epochs = g.usize(1..=500);
        let text = format!(
            "[model]\nf = {f}\nk = {k}\n[dataset]\nscale = {scale}\n[trainer]\nepochs = {epochs}\n"
        );
        let cfg = lshmf::config::ExperimentConfig::from_str(&text).unwrap();
        cfg.model.f == f
            && cfg.model.k == k
            && (cfg.dataset.scale - scale).abs() < 1e-12
            && cfg.trainer.epochs == epochs
    });
}

/// Baselines: weighted row deviations always sum to ~zero.
#[test]
fn prop_baseline_deviations_balance() {
    check("baseline deviations balance", 50, |g| {
        let t = gen_triples(g, 30, 30, 200);
        if t.nnz() == 0 {
            return true;
        }
        let csr = Csr::from_triples(&t);
        let b = lshmf::mf::Baselines::compute(&csr);
        let weighted: f64 = (0..csr.nrows())
            .map(|i| csr.row_nnz(i) as f64 * b.bi[i] as f64)
            .sum();
        weighted.abs() < 1e-2 * t.nnz() as f64
    });
}

/// Virtual clock: speedup is within [1/D overhead floor, D] and the
/// serial total is schedule-independent.
#[test]
fn prop_virtual_clock_bounds() {
    check("virtual clock bounds", 40, |g| {
        let t = gen_triples(g, 60, 60, 400);
        let d = g.usize(1..=5);
        let plan = RotationPlan::new(&t, d);
        let r = plan.virtual_clock(1e-7, 1e-7, true);
        r.speedup > 0.0 && r.speedup <= d as f64 + 1e-9 && r.serial_seconds >= 0.0
    });
}
