//! Property tests on coordinator and substrate invariants, via the
//! in-repo `lshmf::prop` mini-framework (proptest is unavailable offline).

use lshmf::coordinator::banded::BandedEngine;
use lshmf::coordinator::rotation::RotationPlan;
use lshmf::coordinator::server::handle_line;
use lshmf::coordinator::shared::SharedEngine;
use lshmf::coordinator::stream::{StreamConfig, StreamOrchestrator};
use lshmf::coordinator::Engine;
use lshmf::lsh::{NeighbourSearch, OnlineHashState, SimLsh};
use lshmf::metrics::Registry;
use lshmf::mf::neighbourhood::{train_culsh_logged, CulshConfig};
use lshmf::prop::{check, Gen};
use lshmf::rng::Rng;
use lshmf::sparse::{BlockGrid, Csc, Csr, Triples};
use std::sync::Mutex;

fn gen_triples(g: &mut Gen, max_m: usize, max_n: usize, max_nnz: usize) -> Triples {
    let m = g.usize(2..=max_m);
    let n = g.usize(2..=max_n);
    let nnz = g.usize(1..=max_nnz);
    let mut t = Triples::new(m, n);
    let mut seen = std::collections::HashSet::new();
    for _ in 0..nnz {
        let (i, j) = (g.rng().below(m), g.rng().below(n));
        if seen.insert((i, j)) {
            t.push(i, j, 0.5 + g.rng().f32() * 4.5);
        }
    }
    t
}

/// The rotation schedule is a Latin square for every D and matrix shape.
#[test]
fn prop_rotation_schedule_is_latin_square() {
    check("rotation is latin square", 60, |g| {
        let t = gen_triples(g, 60, 60, 300);
        let d = g.usize(1..=6);
        RotationPlan::new(&t, d).validate().is_ok()
    });
}

/// CSR and CSC views agree entry-for-entry with the source triples.
#[test]
fn prop_csr_csc_roundtrip() {
    check("csr/csc roundtrip", 60, |g| {
        let t = gen_triples(g, 40, 40, 250);
        let csr = Csr::from_triples(&t);
        let csc = Csc::from_triples(&t);
        if csr.nnz() != t.nnz() || csc.nnz() != t.nnz() {
            return false;
        }
        t.entries().iter().all(|&(i, j, r)| {
            csr.row(i as usize).any(|(jj, rr)| jj == j as usize && rr == r)
                && csc.col(j as usize).any(|(ii, rr)| ii == i as usize && rr == r)
        })
    });
}

/// Block partitions cover every entry exactly once, for any D.
#[test]
fn prop_block_grid_partitions() {
    check("block grid partitions", 60, |g| {
        let t = gen_triples(g, 50, 50, 300);
        let d = g.usize(1..=5);
        let grid = BlockGrid::partition(&t, d);
        let total: usize = grid.blocks().iter().map(|b| b.entries.len()).sum();
        total == t.nnz()
    });
}

/// Top-K rows are always exactly K, self-free and duplicate-free.
#[test]
fn prop_topk_invariants() {
    check("topk invariants", 25, |g| {
        let t = gen_triples(g, 40, 30, 200);
        let n = t.ncols();
        if n < 3 {
            return true;
        }
        let csc = Csc::from_triples(&t);
        let k = g.usize(1..=(n - 1).min(8));
        let q = g.usize(1..=6);
        let mut lsh = SimLsh::new(g.usize(1..=2), q, 8, 2);
        let (topk, _) = lsh.build(&csc, k, g.rng());
        (0..n).all(|j| {
            let nb = topk.neighbours(j);
            let set: std::collections::HashSet<_> = nb.iter().collect();
            nb.len() == k
                && set.len() == k
                && nb.iter().all(|&c| (c as usize) < n && c as usize != j)
        })
    });
}

/// Online hash absorption ≡ from-scratch build (up to fp rounding at
/// near-zero accumulators) for arbitrary splits.
#[test]
fn prop_online_hash_matches_rebuild() {
    check("online hash == rebuild", 15, |g| {
        let full = gen_triples(g, 30, 15, 150);
        if full.nnz() < 4 {
            return true;
        }
        // random split point over columns/rows
        let base_rows = g.usize(1..=full.nrows());
        let base_cols = g.usize(1..=full.ncols());
        let mut base = Triples::new(base_rows, base_cols);
        let mut inc = Vec::new();
        for &(i, j, r) in full.entries() {
            if (i as usize) < base_rows && (j as usize) < base_cols {
                base.push(i as usize, j as usize, r);
            } else {
                inc.push((i, j, r));
            }
        }
        let lsh = SimLsh { p: 1, q: 4, g: 8, psi_power: 2, center: 0.0, seed: 7 };
        let mut online = OnlineHashState::build(lsh.clone(), &Csc::from_triples(&base));
        online.apply_increment(&inc, full.ncols());
        let scratch = OnlineHashState::build(lsh, &Csc::from_triples(&full));
        let mut flips = 0;
        let mut total = 0;
        for round in 0..4 {
            for j in 0..full.ncols() {
                total += 1;
                if online.hash(round, 0, j) != scratch.hash(round, 0, j) {
                    flips += 1;
                }
            }
        }
        flips * 50 <= total // ≤ 2% near-zero sign flips tolerated
    });
}

/// Small trained serving engine (mirrors `tests/serving.rs`'s fixture).
fn serving_engine(seed: u64, stream_cfg: StreamConfig) -> Engine {
    let mut rng = Rng::seeded(seed);
    let (m, n) = (30, 15);
    let mut t = Triples::new(m, n);
    let mut seen = std::collections::HashSet::new();
    while t.nnz() < 180 {
        let (i, j) = (rng.below(m), rng.below(n));
        if seen.insert((i, j)) {
            t.push(i, j, 1.0 + rng.f32() * 4.0);
        }
    }
    let csr = Csr::from_triples(&t);
    let csc = Csc::from_triples(&t);
    let lsh = SimLsh::new(1, 5, 8, 2);
    let hash_state = OnlineHashState::build(lsh, &csc);
    let (topk, _) = hash_state.topk(4, &mut rng);
    let cfg = CulshConfig { f: 4, k: 4, epochs: 4, ..Default::default() };
    let (model, _) = train_culsh_logged(&csr, topk, &cfg, &mut rng);
    let metrics = Registry::new();
    let orch = StreamOrchestrator::new(
        model,
        hash_state,
        t,
        stream_cfg,
        cfg,
        rng.split(1),
        metrics.clone(),
    );
    Engine::new(orch, (1.0, 5.0), metrics)
}

/// Serving parity: across randomized rate/flush interleavings — with
/// growth, re-ratings, NaN values and out-of-bounds ids mixed in — the
/// sharded concurrent engine's `PREDICT`/`MPREDICT`/`TOPN`/`RATE`/`FLUSH`
/// replies are byte-identical to the `Mutex<Engine>` flavour, for any
/// shard count. Extends the `shared_engine_protocol_parity` unit test to
/// arbitrary interleavings.
#[test]
fn prop_sharded_serving_matches_mutex_engine() {
    check("sharded serving parity", 8, |g| {
        let seed = 4600 + g.usize(0..=40) as u64;
        let stream_cfg = StreamConfig {
            batch_size: g.usize(2..=10),
            max_rows: 200,
            max_cols: 200,
            ..Default::default()
        };
        let single = Mutex::new(serving_engine(seed, stream_cfg.clone()));
        let shards = g.usize(1..=6);
        let (shared, writer) =
            SharedEngine::spawn_sharded(serving_engine(seed, stream_cfg), shards);
        let mut ok = true;
        for _ in 0..g.usize(20..=50) {
            let line = match g.usize(0..=4) {
                0 => format!("PREDICT {} {}", g.usize(0..=35), g.usize(0..=20)),
                1 => format!("TOPN {} {}", g.usize(0..=35), g.usize(1..=8)),
                2 => format!(
                    "MPREDICT {} {} {} {}",
                    g.usize(0..=35),
                    g.usize(0..=20),
                    g.usize(0..=20),
                    g.usize(0..=20)
                ),
                3 => {
                    let r = match g.usize(0..=8) {
                        0 => "NaN".to_string(),
                        1 => "inf".to_string(),
                        _ => format!("{:.1}", 1.0 + g.usize(0..=8) as f32 * 0.5),
                    };
                    let i = if g.usize(0..=9) == 0 {
                        4_000_000_000u32
                    } else {
                        g.usize(0..=33) as u32
                    };
                    format!("RATE {i} {} {r}", g.usize(0..=18))
                }
                _ => "FLUSH".to_string(),
            };
            let a = handle_line(&single, &line);
            let b = handle_line(&shared, &line);
            if a != b {
                eprintln!("serving parity mismatch on `{line}`: {a:?} vs {b:?}");
                ok = false;
                break;
            }
        }
        writer.join();
        ok
    });
}

/// Multi-writer serving parity: across randomized rate/flush/growth
/// interleavings — universe-growth ratings spread across bands, NaN
/// values, out-of-bounds ids and re-ratings mixed in — the per-band
/// multi-writer engine's replies are byte-identical to the
/// `Mutex<Engine>` reference at 1, 2 and 4 writers. The flush epoch
/// merges per-band buffers back into arrival order and runs the exact
/// single-writer computation, so equality must be bit-exact, not
/// approximate.
#[test]
fn prop_banded_multi_writer_matches_mutex_engine() {
    check("banded multi-writer parity", 6, |g| {
        let seed = 5200 + g.usize(0..=40) as u64;
        let stream_cfg = StreamConfig {
            batch_size: g.usize(2..=10),
            max_rows: 200,
            max_cols: 200,
            ..Default::default()
        };
        let single = Mutex::new(serving_engine(seed, stream_cfg.clone()));
        let writers = [1usize, 2, 4][g.usize(0..=2)];
        let (banded, handle) =
            BandedEngine::spawn(serving_engine(seed, stream_cfg), writers);
        let mut ok = true;
        let mut grow_step = 0u32;
        for _ in 0..g.usize(25..=55) {
            let line = match g.usize(0..=5) {
                0 => format!("PREDICT {} {}", g.usize(0..=35), g.usize(0..=40)),
                1 => format!("TOPN {} {}", g.usize(0..=35), g.usize(1..=8)),
                2 => format!(
                    "MPREDICT {} {} {} {}",
                    g.usize(0..=35),
                    g.usize(0..=40),
                    g.usize(0..=40),
                    g.usize(0..=40)
                ),
                3 => {
                    let r = match g.usize(0..=8) {
                        0 => "NaN".to_string(),
                        1 => "inf".to_string(),
                        _ => format!("{:.1}", 1.0 + g.usize(0..=8) as f32 * 0.5),
                    };
                    let i = if g.usize(0..=9) == 0 {
                        4_000_000_000u32
                    } else {
                        g.usize(0..=33) as u32
                    };
                    format!("RATE {i} {} {r}", g.usize(0..=18))
                }
                4 => {
                    // universe growth: column ids walk beyond the
                    // current extent, landing in different bands
                    grow_step += 1;
                    format!(
                        "RATE {} {} 4.5",
                        30 + grow_step % 7,
                        15 + (grow_step * 5) % 23
                    )
                }
                _ => "FLUSH".to_string(),
            };
            let a = handle_line(&single, &line);
            let b = handle_line(&banded, &line);
            if a != b {
                eprintln!(
                    "banded parity mismatch (writers={writers}) on `{line}`: {a:?} vs {b:?}"
                );
                ok = false;
                break;
            }
        }
        handle.join();
        ok
    });
}

/// The TOML-subset parser round-trips what the config writer would emit.
#[test]
fn prop_config_parser_roundtrip() {
    check("config roundtrip", 100, |g| {
        let f = g.usize(1..=256);
        let k = g.usize(1..=256);
        let scale = (g.usize(1..=100) as f64) / 100.0;
        let epochs = g.usize(1..=500);
        let text = format!(
            "[model]\nf = {f}\nk = {k}\n[dataset]\nscale = {scale}\n[trainer]\nepochs = {epochs}\n"
        );
        let cfg = lshmf::config::ExperimentConfig::from_str(&text).unwrap();
        cfg.model.f == f
            && cfg.model.k == k
            && (cfg.dataset.scale - scale).abs() < 1e-12
            && cfg.trainer.epochs == epochs
    });
}

/// Baselines: weighted row deviations always sum to ~zero.
#[test]
fn prop_baseline_deviations_balance() {
    check("baseline deviations balance", 50, |g| {
        let t = gen_triples(g, 30, 30, 200);
        if t.nnz() == 0 {
            return true;
        }
        let csr = Csr::from_triples(&t);
        let b = lshmf::mf::Baselines::compute(&csr);
        let weighted: f64 = (0..csr.nrows())
            .map(|i| csr.row_nnz(i) as f64 * b.bi[i] as f64)
            .sum();
        weighted.abs() < 1e-2 * t.nnz() as f64
    });
}

/// Virtual clock: speedup is within [1/D overhead floor, D] and the
/// serial total is schedule-independent.
#[test]
fn prop_virtual_clock_bounds() {
    check("virtual clock bounds", 40, |g| {
        let t = gen_triples(g, 60, 60, 400);
        let d = g.usize(1..=5);
        let plan = RotationPlan::new(&t, d);
        let r = plan.virtual_clock(1e-7, 1e-7, true);
        r.speedup > 0.0 && r.speedup <= d as f64 + 1e-9 && r.serial_seconds >= 0.0
    });
}
