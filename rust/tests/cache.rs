//! Cache-correctness tests for the per-row Top-N cache: on every
//! serving flavour, cached and partial-hit `TOPN` replies must be
//! bit-identical to a full re-score of the same snapshot, and no cached
//! entry may survive a publish that dirtied its row or bands.

use lshmf::coordinator::banded::BandedEngine;
use lshmf::coordinator::protocol::MAX_TOPN_ITEMS;
use lshmf::coordinator::shared::SharedEngine;
use lshmf::coordinator::stream::{StreamConfig, StreamOrchestrator};
use lshmf::coordinator::Engine;
use lshmf::lsh::{OnlineHashState, SimLsh};
use lshmf::metrics::Registry;
use lshmf::mf::neighbourhood::{train_culsh_logged, CulshConfig};
use lshmf::prop::{check, Gen};
use lshmf::rng::Rng;
use lshmf::sparse::{Csc, Csr, Triples};

/// Small trained engine (the serving.rs recipe); `batch_size` is large
/// so publishes happen only where the script says `Flush`.
fn engine(seed: u64) -> Engine {
    let mut rng = Rng::seeded(seed);
    let (m, n) = (30, 15);
    let mut t = Triples::new(m, n);
    let mut seen = std::collections::HashSet::new();
    while t.nnz() < 180 {
        let (i, j) = (rng.below(m), rng.below(n));
        if seen.insert((i, j)) {
            t.push(i, j, 1.0 + rng.f32() * 4.0);
        }
    }
    let csr = Csr::from_triples(&t);
    let csc = Csc::from_triples(&t);
    let lsh = SimLsh::new(1, 5, 8, 2);
    let hash_state = OnlineHashState::build(lsh, &csc);
    let (topk, _) = hash_state.topk(4, &mut rng);
    let cfg = CulshConfig { f: 4, k: 4, epochs: 4, ..Default::default() };
    let (model, _) = train_culsh_logged(&csr, topk, &cfg, &mut rng);
    let metrics = Registry::new();
    let orch = StreamOrchestrator::new(
        model,
        hash_state,
        t,
        StreamConfig { batch_size: 1024, ..Default::default() },
        cfg,
        rng.split(1),
        metrics.clone(),
    );
    Engine::new(orch, (1.0, 5.0), metrics)
}

/// The one surface the scripts need, implemented by all three flavours.
trait Serve {
    fn rate(&mut self, i: u32, j: u32, v: f32);
    fn flush(&mut self) -> usize;
    fn top_n(&self, row: usize, n: usize) -> Vec<(u32, f32)>;
    /// `(hits, misses, partial)` from the flavour's `cache.*` counters.
    fn counts(&self) -> (u64, u64, u64);
}

struct Single(Engine);
struct Shared(SharedEngine);
struct Banded(BandedEngine);

impl Serve for Single {
    fn rate(&mut self, i: u32, j: u32, v: f32) {
        self.0.rate(i, j, v);
    }
    fn flush(&mut self) -> usize {
        self.0.flush()
    }
    fn top_n(&self, row: usize, n: usize) -> Vec<(u32, f32)> {
        self.0.top_n(row, n)
    }
    fn counts(&self) -> (u64, u64, u64) {
        self.0.cache().counts()
    }
}

impl Serve for Shared {
    fn rate(&mut self, i: u32, j: u32, v: f32) {
        self.0.rate(i, j, v);
    }
    fn flush(&mut self) -> usize {
        self.0.flush()
    }
    fn top_n(&self, row: usize, n: usize) -> Vec<(u32, f32)> {
        self.0.top_n(row, n)
    }
    fn counts(&self) -> (u64, u64, u64) {
        self.0.cache().counts()
    }
}

impl Serve for Banded {
    fn rate(&mut self, i: u32, j: u32, v: f32) {
        self.0.rate(i, j, v);
    }
    fn flush(&mut self) -> usize {
        self.0.flush()
    }
    fn top_n(&self, row: usize, n: usize) -> Vec<(u32, f32)> {
        self.0.top_n(row, n)
    }
    fn counts(&self) -> (u64, u64, u64) {
        self.0.cache().counts()
    }
}

/// Run `f` against a fresh engine of every flavour, tearing each down
/// (drop, then join the writer threads) before the next.
fn with_flavours(seed: u64, mut f: impl FnMut(&mut dyn Serve, &'static str)) {
    let mut single = Single(engine(seed));
    f(&mut single, "Mutex<Engine>");

    let (shared, writer) = SharedEngine::spawn(engine(seed));
    let mut shared = Shared(shared);
    f(&mut shared, "SharedEngine");
    drop(shared);
    writer.join();

    let (banded, handle) = BandedEngine::spawn(engine(seed), 2);
    let mut banded = Banded(banded);
    f(&mut banded, "BandedEngine");
    drop(banded);
    handle.join();
}

#[derive(Clone, Copy, Debug)]
enum Op {
    Rate(u32, u32, f32),
    Flush,
    Read(usize, usize),
}

/// Bit-exact rendering for comparisons ("close" is not good enough).
fn bits(items: &[(u32, f32)]) -> Vec<(u32, u32)> {
    items.iter().map(|&(j, s)| (j, s.to_bits())).collect()
}

/// The full re-score of `row` on the current snapshot: a request above
/// [`MAX_TOPN_ITEMS`] bypasses the cache on every flavour, and
/// `rank_cmp` is a total order, so its length-`n` prefix is exactly
/// what an uncached `TOPN row n` would return.
fn rescored(f: &dyn Serve, row: usize, n: usize) -> Vec<(u32, f32)> {
    let mut full = f.top_n(row, MAX_TOPN_ITEMS + 1);
    full.truncate(n);
    full
}

/// Replay the script; every read is checked cold and warm against the
/// uncached re-score of the same snapshot. Returns false (with a
/// diagnostic) on the first divergence.
fn replay_checked(f: &mut dyn Serve, flavour: &str, script: &[Op]) -> bool {
    let mut reads = 0u64;
    for (step, op) in script.iter().enumerate() {
        match *op {
            Op::Rate(i, j, v) => f.rate(i, j, v),
            Op::Flush => {
                f.flush();
            }
            Op::Read(row, n) => {
                reads += 1;
                let cold = f.top_n(row, n);
                let warm = f.top_n(row, n);
                let want = rescored(f, row, n);
                if bits(&cold) != bits(&want) || bits(&warm) != bits(&want) {
                    eprintln!(
                        "step {step} ({flavour}): TOPN {row} {n} diverges from re-score\n\
                         cold {cold:?}\nwarm {warm:?}\nwant {want:?}"
                    );
                    return false;
                }
            }
        }
    }
    // Every warm re-read (no publish in between) must have been served
    // from memory: the scripts only read in-range rows, so a zero hit
    // count means the cache is not actually caching.
    let (hits, _, _) = f.counts();
    if reads > 0 && hits < reads {
        eprintln!("{flavour}: {reads} warm re-reads but only {hits} cache hits");
        return false;
    }
    true
}

fn gen_script(g: &mut Gen) -> Vec<Op> {
    g.vec(12..=36, |g| match g.usize(0..=5) {
        // Rows/cols past the seed dims (30×15) grow the universe at the
        // next flush; values stay inside the (1.0, 5.0) clamp.
        0 | 1 => Op::Rate(g.u32(0..34), g.u32(0..18), 1.0 + g.rng().f32() * 4.0),
        2 => Op::Flush,
        // Reads stay in the seed row range (rows never shrink), so the
        // warm re-read is always a cacheable in-range request.
        _ => Op::Read(g.usize(0..=29), g.usize(1..=12)),
    })
}

/// Property: under randomized ingest / re-rate / growth / flush / read
/// scripts, cached and partial-hit TOPN replies are bit-identical to a
/// full re-scoring of the same snapshot on all three flavours.
#[test]
fn prop_cached_topn_bit_identical_to_rescore_on_all_flavours() {
    check("cached topn == re-score", 18, |g| {
        let script = gen_script(g);
        let seed = g.u32(1..u32::MAX) as u64;
        let mut ok = true;
        with_flavours(seed, |f, flavour| {
            ok = ok && replay_checked(f, flavour, &script);
        });
        ok
    });
}

/// Regression: a cached entry must not survive a publish that dirtied
/// it. Rating a row's current top column removes that column from the
/// row's unrated set; if the pre-publish cache entry survived the
/// dirty-band publish, the rated column would still be served.
#[test]
fn stale_entry_never_survives_dirty_publish() {
    with_flavours(4242, |f, flavour| {
        let row = 3usize;
        let before = f.top_n(row, 5);
        assert!(!before.is_empty(), "{flavour}: empty top-n on the seed snapshot");
        let warm = f.top_n(row, 5);
        assert_eq!(bits(&warm), bits(&before), "{flavour}: warm re-read diverged");
        let (top_col, _) = before[0];

        f.rate(row as u32, top_col, 5.0);
        assert_eq!(f.flush(), 1, "{flavour}: the re-rating must apply");

        let (hits_before, _, _) = f.counts();
        let after = f.top_n(row, 5);
        let (hits_after, _, _) = f.counts();
        assert_eq!(
            hits_before, hits_after,
            "{flavour}: post-publish read was served fully from cache"
        );
        assert!(
            after.iter().all(|&(j, _)| j != top_col),
            "{flavour}: rated column {top_col} survived the publish in {after:?}"
        );
        assert_eq!(
            bits(&after),
            bits(&rescored(f, row, 5)),
            "{flavour}: post-publish reply diverges from the re-score"
        );
    });
}
