//! Artifact-gated integration tests: the AOT graphs (Pallas/JAX lowered to
//! HLO, executed through PJRT) must agree numerically with the rust-native
//! math that the L3 trainers use.
//!
//! **Gated on `LSHMF_AOT_DIR`**: the artifacts do not exist offline (they
//! need the python AOT toolchain) and executing them needs a PJRT-enabled
//! build (see `lshmf::runtime` — the offline stub cannot run graphs). With
//! the variable unset every test here passes trivially with a skip note,
//! keeping tier-1 (`cargo test -q`) green offline. Point `LSHMF_AOT_DIR`
//! at a built `artifacts/` bundle on a PJRT-enabled build to opt in.

use lshmf::rng::Rng;
use lshmf::runtime::{culsh_scalars, mf_scalars, Runtime};

fn runtime() -> Option<Runtime> {
    let Ok(dir) = std::env::var("LSHMF_AOT_DIR") else {
        eprintln!("LSHMF_AOT_DIR not set; skipping PJRT parity test (offline tier-1)");
        return None;
    };
    let dir = std::path::PathBuf::from(dir);
    if !Runtime::available(&dir) {
        eprintln!(
            "no artifact bundle at {} (missing manifest.json); skipping PJRT parity test",
            dir.display()
        );
        return None;
    }
    Some(Runtime::open(&dir).expect("open runtime"))
}

fn randn(rng: &mut Rng, n: usize, std: f32) -> Vec<f32> {
    (0..n).map(|_| rng.normal_f32(0.0, std)).collect()
}

#[test]
fn mf_sgd_step_matches_native_math() {
    let Some(mut rt) = runtime() else { return };
    let (b, f) = (rt.manifest.batch, rt.manifest.f);
    let mut rng = Rng::seeded(101);
    let scal = mf_scalars(3.0, 0.01, 0.02, 0.03, 0.04);
    let r: Vec<f32> = (0..b).map(|_| 3.0 + rng.normal_f32(0.0, 1.0)).collect();
    let bi = randn(&mut rng, b, 0.1);
    let bj = randn(&mut rng, b, 0.1);
    let u = randn(&mut rng, b * f, 0.1);
    let v = randn(&mut rng, b * f, 0.1);

    let out = rt
        .run_f32(
            "mf_sgd_step",
            &[
                (&scal, &[5]),
                (&r, &[b]),
                (&bi, &[b]),
                (&bj, &[b]),
                (&u, &[b, f]),
                (&v, &[b, f]),
            ],
        )
        .expect("execute");
    assert_eq!(out.len(), 5, "bi', bj', u', v', e");

    // native Eq. (5) math
    for s in 0..b {
        let dot: f32 = (0..f).map(|k| u[s * f + k] * v[s * f + k]).sum();
        let pred = 3.0 + bi[s] + bj[s] + dot;
        let e = r[s] - pred;
        assert!((out[4][s] - e).abs() < 1e-4, "e mismatch at {s}");
        let bi_new = bi[s] + 0.01 * (e - 0.02 * bi[s]);
        assert!((out[0][s] - bi_new).abs() < 1e-4);
        for k in 0..f {
            let u_new = u[s * f + k] + 0.01 * (e * v[s * f + k] - 0.03 * u[s * f + k]);
            let v_new = v[s * f + k] + 0.01 * (e * u[s * f + k] - 0.04 * v[s * f + k]);
            assert!((out[2][s * f + k] - u_new).abs() < 1e-4);
            assert!((out[3][s * f + k] - v_new).abs() < 1e-4);
        }
    }
}

#[test]
fn culsh_sgd_step_matches_native_math() {
    let Some(mut rt) = runtime() else { return };
    let (b, f, k) = (rt.manifest.batch, rt.manifest.f, rt.manifest.k);
    let mut rng = Rng::seeded(102);
    let scal = culsh_scalars(3.0, 0.02, 0.005, 0.01, 0.01, 0.01, 0.002, 0.002);
    let r: Vec<f32> = (0..b).map(|_| 3.0 + rng.normal_f32(0.0, 1.0)).collect();
    let bi = randn(&mut rng, b, 0.1);
    let bj = randn(&mut rng, b, 0.1);
    let u = randn(&mut rng, b * f, 0.1);
    let v = randn(&mut rng, b * f, 0.1);
    let w = randn(&mut rng, b * k, 0.1);
    let c = randn(&mut rng, b * k, 0.1);
    let resid = randn(&mut rng, b * k, 0.5);
    let mask: Vec<f32> = (0..b * k).map(|_| if rng.chance(0.5) { 1.0 } else { 0.0 }).collect();

    let out = rt
        .run_f32(
            "culsh_sgd_step",
            &[
                (&scal, &[8]),
                (&r, &[b]),
                (&bi, &[b]),
                (&bj, &[b]),
                (&u, &[b, f]),
                (&v, &[b, f]),
                (&w, &[b, k]),
                (&c, &[b, k]),
                (&resid, &[b, k]),
                (&mask, &[b, k]),
            ],
        )
        .expect("execute");
    assert_eq!(out.len(), 7);

    for s in (0..b).step_by(37) {
        let dot: f32 = (0..f).map(|x| u[s * f + x] * v[s * f + x]).sum();
        let n_r: f32 = (0..k).map(|x| mask[s * k + x]).sum();
        let n_n = k as f32 - n_r;
        let scale_r = if n_r > 0.0 { 1.0 / n_r.sqrt() } else { 0.0 };
        let scale_n = if n_n > 0.0 { 1.0 / n_n.sqrt() } else { 0.0 };
        let explicit: f32 = (0..k)
            .map(|x| mask[s * k + x] * resid[s * k + x] * w[s * k + x])
            .sum();
        let implicit: f32 = (0..k).map(|x| (1.0 - mask[s * k + x]) * c[s * k + x]).sum();
        let pred = 3.0 + bi[s] + bj[s] + dot + scale_r * explicit + scale_n * implicit;
        let e = r[s] - pred;
        assert!(
            (out[6][s] - e).abs() < 2e-4,
            "e mismatch at {s}: {} vs {e}",
            out[6][s]
        );
        // spot-check w update
        for x in 0..k {
            let m = mask[s * k + x];
            let w_new = w[s * k + x]
                + 0.005 * (m * e * scale_r * resid[s * k + x] - 0.002 * m * w[s * k + x]);
            assert!(
                (out[4][s * k + x] - w_new).abs() < 2e-4,
                "w mismatch at ({s},{x})"
            );
        }
    }
}

#[test]
fn rmse_chunk_matches_native() {
    let Some(mut rt) = runtime() else { return };
    let (b, f) = (rt.manifest.batch, rt.manifest.f);
    let mut rng = Rng::seeded(103);
    let scal = mf_scalars(3.0, 0.0, 0.0, 0.0, 0.0);
    let r: Vec<f32> = (0..b).map(|_| 3.0 + rng.normal_f32(0.0, 1.0)).collect();
    let bi = randn(&mut rng, b, 0.1);
    let bj = randn(&mut rng, b, 0.1);
    let u = randn(&mut rng, b * f, 0.1);
    let v = randn(&mut rng, b * f, 0.1);
    let mut valid = vec![1.0f32; b];
    for x in valid.iter_mut().skip(b - 100) {
        *x = 0.0;
    }
    let out = rt
        .run_f32(
            "rmse_chunk_step",
            &[
                (&scal, &[5]),
                (&r, &[b]),
                (&bi, &[b]),
                (&bj, &[b]),
                (&u, &[b, f]),
                (&v, &[b, f]),
                (&valid, &[b]),
            ],
        )
        .expect("execute");
    let (sse, count) = (out[0][0], out[0][1]);
    assert_eq!(count as usize, b - 100);
    let mut want = 0f64;
    for s in 0..b - 100 {
        let dot: f32 = (0..f).map(|x| u[s * f + x] * v[s * f + x]).sum();
        let e = (r[s] - (3.0 + bi[s] + bj[s] + dot)) as f64;
        want += e * e;
    }
    assert!(
        (sse as f64 - want).abs() / want < 1e-4,
        "sse {sse} vs {want}"
    );
}

#[test]
fn simlsh_hash_block_matches_rust_hasher_semantics() {
    let Some(mut rt) = runtime() else { return };
    let (n, m, g) = (rt.manifest.hash_n, rt.manifest.hash_m, rt.manifest.hash_g);
    let mut rng = Rng::seeded(104);
    // dense Ψ-weighted block with ~90% zeros (sparse-like)
    let mut x = vec![0f32; n * m];
    for v in x.iter_mut() {
        if rng.chance(0.1) {
            *v = (1.0 + rng.f32() * 4.0).powi(2);
        }
    }
    let phi: Vec<f32> = (0..m * g).map(|_| if rng.chance(0.5) { 1.0 } else { -1.0 }).collect();
    let out = rt
        .run_f32("simlsh_hash_block", &[(&x, &[n, m]), (&phi, &[m, g])])
        .expect("execute");
    let bits = &out[0];
    assert_eq!(bits.len(), n * g);
    for row in (0..n).step_by(17) {
        for bit in 0..g {
            let acc: f32 = (0..m).map(|i| x[row * m + i] * phi[i * g + bit]).sum();
            let want = if acc >= 0.0 { 1.0 } else { 0.0 };
            assert_eq!(bits[row * g + bit], want, "bit ({row},{bit}), acc={acc}");
        }
    }
}

#[test]
fn neural_gmf_step_trains_through_pjrt() {
    let Some(mut rt) = runtime() else { return };
    if !rt.manifest.graphs.contains_key("gmf_step") {
        eprintln!("neural graphs not exported; skipping");
        return;
    }
    let meta = rt.manifest.neural.clone();
    let params_spec = rt.manifest.graphs["gmf_step"].params.clone();
    let n = params_spec.len();
    let mut rng = Rng::seeded(105);
    // init params in the manifest's declared order; Adam moments at zero
    let mut params: Vec<Vec<f32>> = params_spec
        .iter()
        .map(|(_, shape)| {
            let len: usize = shape.iter().product();
            randn(&mut rng, len, 0.3)
        })
        .collect();
    let mut m_state: Vec<Vec<f32>> = params.iter().map(|p| vec![0.0; p.len()]).collect();
    let mut v_state: Vec<Vec<f32>> = params.iter().map(|p| vec![0.0; p.len()]).collect();
    // memorizable batch: 32 pairs tiled
    let bsz = meta.batch;
    let mut users = vec![0i32; bsz];
    let mut items = vec![0i32; bsz];
    let mut labels = vec![0f32; bsz];
    for s in 0..bsz {
        let p = s % 32;
        users[s] = (p * 7 % meta.n_users) as i32;
        items[s] = (p * 13 % meta.n_items) as i32;
        labels[s] = (p % 2) as f32;
    }
    let mut first_loss = None;
    let mut last_loss = 0f32;
    for step in 1..=100i32 {
        let t = [step as f32];
        let mut lits = vec![
            Runtime::lit_i32(&users, &[bsz]).unwrap(),
            Runtime::lit_i32(&items, &[bsz]).unwrap(),
            Runtime::lit_f32(&labels, &[bsz]).unwrap(),
            Runtime::lit_f32(&t, &[1]).unwrap(),
        ];
        for bank in [&params, &m_state, &v_state] {
            for (p, (_, shape)) in bank.iter().zip(&params_spec) {
                lits.push(Runtime::lit_f32(p, shape).unwrap());
            }
        }
        let out = rt.run_literals("gmf_step", lits).expect("execute");
        // outputs: params..., m..., v..., loss
        for (dst, src) in params.iter_mut().zip(&out[..n]) {
            dst.copy_from_slice(src);
        }
        for (dst, src) in m_state.iter_mut().zip(&out[n..2 * n]) {
            dst.copy_from_slice(src);
        }
        for (dst, src) in v_state.iter_mut().zip(&out[2 * n..3 * n]) {
            dst.copy_from_slice(src);
        }
        last_loss = out[3 * n][0];
        first_loss.get_or_insert(last_loss);
    }
    let first = first_loss.unwrap();
    assert!(
        last_loss < first * 0.7,
        "loss did not drop: {first} -> {last_loss}"
    );
}
