//! Crash-recovery integration tests for the durability subsystem
//! (`lshmf::persist`). The headline property kills a persisted run at
//! *every* op boundary — including the boundaries right after
//! auto-flush-triggering and universe-growing events — recovers from
//! disk, finishes the script, and asserts the final predict grid and
//! Top-N rankings are **bit-identical** to a never-crashed reference,
//! on both the shared single-writer and the banded multi-writer
//! engines, at checkpoint cadences 1 and 3. Satellites: a torn or
//! bit-flipped WAL tail degrades without panicking, a corrupt newest
//! checkpoint falls back one generation and replays to the identical
//! state, and `MPREDICT` answers from the per-row Top-N cache
//! bit-identically to the uncached score path.

use lshmf::coordinator::banded::{BandedEngine, BandedHandle};
use lshmf::coordinator::server;
use lshmf::coordinator::shared::{SharedEngine, WriterHandle};
use lshmf::coordinator::stream::{StreamConfig, StreamOrchestrator};
use lshmf::coordinator::Engine;
use lshmf::lsh::{OnlineHashState, SimLsh};
use lshmf::metrics::Registry;
use lshmf::mf::neighbourhood::{train_culsh_logged, CulshConfig};
use lshmf::persist::{recover, FsyncPolicy, Persister, RecoverInfo};
use lshmf::rng::Rng;
use lshmf::sparse::{Csc, Csr, Triples};
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::Arc;

const SEED: u64 = 91;
const BANDED_WRITERS: usize = 2;

fn stream_cfg() -> StreamConfig {
    StreamConfig { batch_size: 4, ..Default::default() }
}

fn train_cfg() -> CulshConfig {
    CulshConfig { f: 3, k: 3, epochs: 2, ..Default::default() }
}

/// Small trained engine over a dense-ish random fixture (the serving
/// test fixture, shrunk — every call with the same seed is bit-exact).
fn engine(seed: u64) -> Engine {
    let mut rng = Rng::seeded(seed);
    let (m, n) = (20, 12);
    let mut t = Triples::new(m, n);
    let mut seen = std::collections::HashSet::new();
    while t.nnz() < 100 {
        let (i, j) = (rng.below(m), rng.below(n));
        if seen.insert((i, j)) {
            t.push(i, j, 1.0 + rng.f32() * 4.0);
        }
    }
    let csr = Csr::from_triples(&t);
    let csc = Csc::from_triples(&t);
    let lsh = SimLsh::new(1, 4, 8, 2);
    let hash_state = OnlineHashState::build(lsh, &csc);
    let (topk, _) = hash_state.topk(3, &mut rng);
    let cfg = train_cfg();
    let (model, _) = train_culsh_logged(&csr, topk, &cfg, &mut rng);
    let metrics = Registry::new();
    let orch = StreamOrchestrator::new(
        model,
        hash_state,
        t,
        stream_cfg(),
        cfg,
        rng.split(1),
        metrics.clone(),
    );
    Engine::new(orch, (1.0, 5.0), metrics)
}

static DIR_ID: AtomicUsize = AtomicUsize::new(0);

/// A fresh scratch directory under the system temp dir (no tempfile
/// crate offline); the caller removes it on success.
fn scratch_dir(tag: &str) -> PathBuf {
    let id = DIR_ID.fetch_add(1, Ordering::Relaxed);
    let dir = std::env::temp_dir().join(format!(
        "lshmf-persist-{tag}-{}-{id}",
        std::process::id()
    ));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

/// One scripted client action, applied identically to every flavour.
#[derive(Clone, Debug)]
enum Op {
    Rate(u32, u32, f32),
    Batch(Vec<(u32, u32, f32)>),
    Flush,
}

/// The acceptance script: threshold-triggered flushes (batch_size 4),
/// explicit flushes, `MRATE` batches, and universe growth on both axes
/// (base dims are 20x12) — so kill points land before, inside, and
/// after flush- and growth-carrying events.
fn script() -> Vec<Op> {
    vec![
        Op::Rate(0, 1, 4.0),
        Op::Rate(1, 2, 3.5),
        Op::Batch(vec![(2, 3, 2.5), (3, 4, 5.0), (4, 5, 1.5)]), // crosses the threshold
        Op::Rate(5, 0, 3.0),
        Op::Flush, // explicit: logged as a WAL marker
        Op::Rate(22, 2, 4.5), // row growth
        Op::Rate(3, 14, 2.0), // column growth
        Op::Batch(vec![(6, 1, 3.0), (7, 2, 4.0), (8, 3, 2.0), (9, 4, 5.0)]), // flushes the growth
        Op::Rate(10, 5, 3.5),
        Op::Rate(11, 6, 1.0),
        Op::Flush,
        Op::Batch(vec![(24, 11, 4.0), (0, 0, 2.0)]), // row growth inside a batch
        Op::Rate(12, 7, 4.5), // left buffered until the closing flush
    ]
}

#[derive(Clone, Copy, Debug, PartialEq)]
enum Flavour {
    Shared,
    Banded,
}

impl Flavour {
    fn nbands(self) -> usize {
        match self {
            Flavour::Shared => 1,
            Flavour::Banded => BANDED_WRITERS,
        }
    }
}

/// Uniform driver over both concurrent serving flavours.
enum Driver {
    Shared(SharedEngine, WriterHandle),
    Banded(BandedEngine, BandedHandle),
}

impl Driver {
    fn spawn(flavour: Flavour, engine: Engine) -> Driver {
        match flavour {
            Flavour::Shared => {
                let (shared, writer) = SharedEngine::spawn(engine);
                Driver::Shared(shared, writer)
            }
            Flavour::Banded => {
                let (banded, handle) = BandedEngine::spawn(engine, BANDED_WRITERS);
                Driver::Banded(banded, handle)
            }
        }
    }

    fn apply(&self, op: &Op) {
        match (self, op) {
            (Driver::Shared(s, _), Op::Rate(i, j, r)) => drop(s.rate(*i, *j, *r)),
            (Driver::Shared(s, _), Op::Batch(b)) => drop(s.rate_many(b)),
            (Driver::Shared(s, _), Op::Flush) => drop(s.flush()),
            (Driver::Banded(b, _), Op::Rate(i, j, r)) => drop(b.rate(*i, *j, *r)),
            (Driver::Banded(b, _), Op::Batch(batch)) => drop(b.rate_many(batch)),
            (Driver::Banded(b, _), Op::Flush) => drop(b.flush()),
        }
    }

    fn join(self) -> Engine {
        match self {
            Driver::Shared(shared, writer) => {
                drop(shared);
                writer.join()
            }
            Driver::Banded(banded, handle) => {
                drop(banded);
                handle.join()
            }
        }
    }
}

/// Bit-exact observable state: flush version, dims, buffered count, the
/// full clamped predict grid, and every row's Top-5 (column ids and
/// score bits).
fn fingerprint(e: &Engine) -> (u64, (usize, usize), usize, Vec<u64>) {
    let (m, n) = e.dims();
    let mut bits = Vec::with_capacity(m * n + m * 5);
    for i in 0..m {
        for j in 0..n {
            bits.push(e.predict(i, j).map_or(0, |v| u64::from(v.to_bits()) + 1));
        }
    }
    for i in 0..m {
        for (c, s) in e.top_n(i, 5) {
            bits.push((u64::from(c) << 32) | u64::from(s.to_bits()));
        }
    }
    (e.version(), (m, n), e.buffered(), bits)
}

/// Run the full script on `flavour` with no persistence attached and
/// return the never-crashed reference fingerprint.
fn reference_run(flavour: Flavour, ops: &[Op]) -> (u64, (usize, usize), usize, Vec<u64>) {
    let driver = Driver::spawn(flavour, engine(SEED));
    for op in ops {
        driver.apply(op);
    }
    driver.apply(&Op::Flush);
    fingerprint(&driver.join())
}

/// Recover from `dir`, reattach a persister continuing the on-disk
/// history, and return the engine ready to resume.
fn recover_and_reattach(
    dir: &Path,
    cadence: usize,
    nbands: usize,
) -> (Engine, RecoverInfo) {
    let metrics = Registry::new();
    let (mut e, info) = recover(dir, stream_cfg(), train_cfg(), &metrics)
        .expect("recovery IO")
        .expect("the attach checkpoint always exists");
    let p = Persister::create(
        dir,
        FsyncPolicy::PerFlush,
        cadence,
        nbands,
        &e,
        Some(&info),
        &metrics,
    )
    .expect("reattach persister");
    e.attach_persister(p);
    (e, info)
}

/// The headline property: kill a persisted run at every op boundary,
/// recover from disk, finish the script, and the final state is
/// bit-identical to the never-crashed reference.
fn crash_recovery_is_bit_exact(flavour: Flavour) {
    let ops = script();
    let want = reference_run(flavour, &ops);
    for cadence in [1usize, 3] {
        for kill in 0..=ops.len() {
            let dir = scratch_dir("crash");
            // Run 1: persisted, killed after `kill` ops. The crash()
            // switch freezes the disk, so the clean-shutdown drain the
            // join performs cannot persist state past the kill point.
            {
                let mut e = engine(SEED);
                let metrics = e.metrics().clone();
                let p = Persister::create(
                    &dir,
                    FsyncPolicy::PerFlush,
                    cadence,
                    flavour.nbands(),
                    &e,
                    None,
                    &metrics,
                )
                .expect("create persister");
                e.attach_persister(Arc::clone(&p));
                let driver = Driver::spawn(flavour, e);
                for op in &ops[..kill] {
                    driver.apply(op);
                }
                p.crash();
                drop(driver.join());
            }
            // Run 2: recover, reattach, finish the script.
            let (e, info) = recover_and_reattach(&dir, cadence, flavour.nbands());
            assert_eq!(
                info.torn_tails, 0,
                "{flavour:?} cadence {cadence} kill {kill}: clean files"
            );
            let driver = Driver::spawn(flavour, e);
            for op in &ops[kill..] {
                driver.apply(op);
            }
            driver.apply(&Op::Flush);
            let got = fingerprint(&driver.join());
            assert_eq!(
                got, want,
                "{flavour:?} cadence {cadence} kill {kill}: recovered state drifted"
            );
            std::fs::remove_dir_all(&dir).expect("cleanup");
        }
    }
}

#[test]
fn crash_recovery_is_bit_exact_shared() {
    crash_recovery_is_bit_exact(Flavour::Shared);
}

#[test]
fn crash_recovery_is_bit_exact_banded() {
    crash_recovery_is_bit_exact(Flavour::Banded);
}

fn wal_segments(dir: &Path) -> Vec<PathBuf> {
    let mut segs: Vec<PathBuf> = std::fs::read_dir(dir)
        .expect("read persist dir")
        .flatten()
        .map(|e| e.path())
        .filter(|p| {
            p.file_name()
                .and_then(|n| n.to_str())
                .is_some_and(|n| n.starts_with("wal-") && n.ends_with(".log"))
        })
        .collect();
    segs.sort();
    segs
}

fn checkpoints(dir: &Path) -> Vec<(u64, PathBuf)> {
    let mut ckpts: Vec<(u64, PathBuf)> = std::fs::read_dir(dir)
        .expect("read persist dir")
        .flatten()
        .map(|e| e.path())
        .filter_map(|p| {
            let name = p.file_name()?.to_str()?;
            let gen = name.strip_prefix("ckpt-")?.strip_suffix(".bin")?.parse().ok()?;
            Some((gen, p))
        })
        .collect();
    ckpts.sort();
    ckpts
}

/// Leave three un-flushed ratings in a single-band WAL, then damage the
/// final record: the tail truncates at the tear, `wal.torn_tail` counts
/// it, and recovery still succeeds with the surviving prefix.
fn damaged_tail_recovers(damage: impl FnOnce(&Path)) {
    let dir = scratch_dir("torn");
    {
        let mut e = engine(SEED);
        let metrics = e.metrics().clone();
        let p = Persister::create(&dir, FsyncPolicy::Off, 100, 1, &e, None, &metrics)
            .expect("create persister");
        e.attach_persister(p);
        for k in 0..3u32 {
            e.rate(k, k % 12, 3.0 + k as f32 * 0.5);
        }
        // batch_size 4: nothing flushed, all three live in the tail
    }
    let segs = wal_segments(&dir);
    assert_eq!(segs.len(), 1, "one band, one segment: {segs:?}");
    damage(&segs[0]);
    let metrics = Registry::new();
    let (e, info) = recover(&dir, stream_cfg(), train_cfg(), &metrics)
        .expect("recovery IO")
        .expect("checkpoint survives WAL damage");
    assert_eq!(info.torn_tails, 1);
    assert_eq!(info.replayed_events, 2, "the damaged final record is dropped");
    assert_eq!(e.buffered(), 2);
    assert!(e.predict(0, 0).is_some(), "recovered engine serves reads");
    assert!(
        metrics.snapshot().contains("counter wal.torn_tail 1"),
        "{}",
        metrics.snapshot()
    );
    std::fs::remove_dir_all(&dir).expect("cleanup");
}

#[test]
fn truncated_wal_tail_is_skipped_not_fatal() {
    damaged_tail_recovers(|seg| {
        let bytes = std::fs::read(seg).expect("read segment");
        std::fs::write(seg, &bytes[..bytes.len() - 3]).expect("truncate tail");
    });
}

#[test]
fn bit_flipped_wal_tail_is_skipped_not_fatal() {
    damaged_tail_recovers(|seg| {
        let mut bytes = std::fs::read(seg).expect("read segment");
        let n = bytes.len();
        bytes[n - 5] ^= 0x40; // inside the final record's payload: CRC must catch it
        std::fs::write(seg, &bytes).expect("write flipped segment");
    });
}

/// A corrupt newest checkpoint falls back to the previous generation,
/// whose surviving WAL tail replays forward to the *identical* state —
/// recovery before and after the corruption fingerprints bit-equal.
#[test]
fn corrupt_newest_checkpoint_falls_back_a_generation() {
    let dir = scratch_dir("ckpt");
    {
        let mut e = engine(SEED);
        let metrics = e.metrics().clone();
        let p = Persister::create(&dir, FsyncPolicy::Off, 1, 1, &e, None, &metrics)
            .expect("create persister");
        e.attach_persister(p);
        e.rate(0, 1, 4.0);
        e.rate(1, 2, 3.0);
        e.flush(); // checkpoint generation 2
        e.rate(2, 3, 2.0);
        e.rate(3, 4, 5.0);
        e.flush(); // checkpoint generation 3
        e.rate(4, 5, 3.5); // tail past generation 3
        e.rate(5, 6, 1.5);
    }
    let metrics = Registry::new();
    let (intact, info) = recover(&dir, stream_cfg(), train_cfg(), &metrics)
        .expect("recovery IO")
        .expect("valid history");
    assert_eq!(info.gen, 3);
    assert_eq!(info.replayed_events, 2, "only the post-checkpoint tail replays");
    let want = fingerprint(&intact);

    let ckpts = checkpoints(&dir);
    let (newest_gen, newest) = ckpts.last().expect("checkpoints on disk");
    assert_eq!(*newest_gen, 3);
    let mut bytes = std::fs::read(newest).expect("read checkpoint");
    let mid = bytes.len() / 2;
    bytes[mid] ^= 0x01;
    std::fs::write(newest, &bytes).expect("corrupt checkpoint");

    let metrics = Registry::new();
    let (fallback, info) = recover(&dir, stream_cfg(), train_cfg(), &metrics)
        .expect("recovery IO")
        .expect("fallback generation recovers");
    assert_eq!(info.gen, 2, "fell back one generation");
    assert_eq!(
        info.replayed_events, 4,
        "the longer tail (two flushed events + two buffered) replays"
    );
    assert_eq!(
        fingerprint(&fallback),
        want,
        "fallback + replay reproduces the identical state"
    );
    std::fs::remove_dir_all(&dir).expect("cleanup");
}

/// `MPREDICT` rides the per-row Top-N cache: priming a row via `TOPN`
/// lets `predict_many` answer from the cached per-band candidate lists,
/// bit-identically to the uncached score path; a column absent from the
/// lists (rated) misses all-or-nothing, and out-of-range columns come
/// back `None` on the cached path too.
#[test]
fn mpredict_answers_from_primed_cache_bit_identically() {
    let e = engine(SEED);
    let recs = e.top_n(2, 5);
    assert!(!recs.is_empty());
    let cols: Vec<u32> = recs.iter().map(|(j, _)| *j).collect();

    let (h0, m0) = e.cache().mpredict_counts();
    let got = e.predict_many(2, &cols).expect("row in range");
    let (h1, _) = e.cache().mpredict_counts();
    assert_eq!(h1, h0 + 1, "primed row answers MPREDICT from the cache");
    for (&j, p) in cols.iter().zip(&got) {
        assert_eq!(
            p.map(f32::to_bits),
            e.predict(2, j as usize).map(f32::to_bits),
            "cached score for col {j} drifted from the direct path"
        );
    }

    // a rated column is absent from the candidate lists: all-or-nothing
    // miss, the uncached path answers, parity still holds
    let rated: u32 = e.matrix().row(2).next().map(|(j, _)| j as u32).expect("row 2 has ratings");
    let mut with_rated = cols.clone();
    with_rated.push(rated);
    let got = e.predict_many(2, &with_rated).expect("row in range");
    let (_, m1) = e.cache().mpredict_counts();
    assert!(m1 > m0, "rated column forces the uncached path");
    for (&j, p) in with_rated.iter().zip(&got) {
        assert_eq!(p.map(f32::to_bits), e.predict(2, j as usize).map(f32::to_bits), "col {j}");
    }

    // out-of-range columns are None on the cached path, same as uncached
    let mut with_oob = cols.clone();
    with_oob.push(999);
    let got = e.predict_many(2, &with_oob).expect("row in range");
    assert_eq!(got.last(), Some(&None), "out-of-range col maps to None");
    let (h2, _) = e.cache().mpredict_counts();
    assert_eq!(h2, h1 + 1, "oob columns do not break the cache hit");

    // the concurrent flavour wires the same fast path
    let (shared, writer) = SharedEngine::spawn(engine(SEED));
    let recs = shared.top_n(2, 5);
    let cols: Vec<u32> = recs.iter().map(|(j, _)| *j).collect();
    let got = shared.predict_many(2, &cols).expect("row in range");
    for (&j, p) in cols.iter().zip(&got) {
        assert_eq!(
            p.map(f32::to_bits),
            shared.predict(2, j as usize).map(f32::to_bits),
            "shared flavour col {j}"
        );
    }
    writer.join();
}

/// Tier-2 smoke (run by ci.sh via `--ignored` behind its network gate):
/// a served engine persists over TCP, a second boot recovers the
/// flushed state from disk and serves reads from it.
#[test]
#[ignore = "tier-2 smoke: ci.sh runs it via `cargo test -q --test persist -- --ignored`"]
fn recovery_smoke_over_tcp() {
    let dir = scratch_dir("smoke");
    let first_boot_version;
    {
        let mut e = engine(SEED);
        let metrics = e.metrics().clone();
        let p = Persister::create(&dir, FsyncPolicy::PerFlush, 1, 1, &e, None, &metrics)
            .expect("create persister");
        e.attach_persister(p);
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let stop = Arc::new(AtomicBool::new(false));
        let server_thread = {
            let stop = stop.clone();
            std::thread::spawn(move || server::serve(e, listener, stop, 2).unwrap())
        };
        let mut conn = TcpStream::connect(addr).unwrap();
        let mut reader = BufReader::new(conn.try_clone().unwrap());
        for k in 0..6u32 {
            conn.write_all(format!("RATE {k} {} 4.0\n", k % 12).as_bytes()).unwrap();
            let mut line = String::new();
            reader.read_line(&mut line).unwrap();
            assert!(line.starts_with("OK"), "{line}");
        }
        conn.write_all(b"FLUSH\n").unwrap();
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        assert!(line.starts_with("OK flushed"), "{line}");
        conn.write_all(b"QUIT\n").unwrap();
        drop(conn);
        stop.store(true, Ordering::Relaxed);
        let _ = TcpStream::connect(addr);
        let engine = server_thread.join().unwrap();
        first_boot_version = engine.version();
        assert!(first_boot_version >= 2, "threshold + explicit flush both applied");
    }

    let metrics = Registry::new();
    let (e, info) = recover(&dir, stream_cfg(), train_cfg(), &metrics)
        .expect("recovery IO")
        .expect("persisted history recovers");
    assert!(info.gen >= 2, "flush-boundary checkpoints were written");
    assert_eq!(e.version(), first_boot_version, "resumes at the flushed version");
    assert_eq!(e.buffered(), 0, "everything was flushed before shutdown");

    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let stop = Arc::new(AtomicBool::new(false));
    let server_thread = {
        let stop = stop.clone();
        std::thread::spawn(move || server::serve(e, listener, stop, 2).unwrap())
    };
    let mut conn = TcpStream::connect(addr).unwrap();
    let mut reader = BufReader::new(conn.try_clone().unwrap());
    conn.write_all(b"PREDICT 0 0\n").unwrap();
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    assert!(line.starts_with("PRED "), "recovered server serves reads: {line}");
    conn.write_all(b"QUIT\n").unwrap();
    drop(conn);
    stop.store(true, Ordering::Relaxed);
    let _ = TcpStream::connect(addr);
    server_thread.join().unwrap();
    std::fs::remove_dir_all(&dir).expect("cleanup");
}
