//! Route-tier proof obligations: a [`Router`] over 2 and 3 live
//! backend `serve` processes answers **bit-identically** to one
//! monolithic engine across randomized rate/flush/growth/read scripts,
//! and a backend killed mid-conversation degrades to typed
//! `ERR unavailable` — never a hang — then recovers to full parity
//! after a restart (journaled writes replayed).
//!
//! The fault harness is a `FaultProxy` fronting the victim backend:
//! "kill" stops forwarding and severs every relayed connection (the
//! backend itself stays alive, exactly like a network partition), so
//! the router's failure detection — IO errors, read deadlines, capped
//! retries — is what the test exercises, not process teardown.

use lshmf::config::{RouteBackend, RouteConfig};
use lshmf::coordinator::protocol::{ErrorKind, Request, Response};
use lshmf::coordinator::server::{self, handle_line, Dispatch};
use lshmf::coordinator::stream::{StreamConfig, StreamOrchestrator};
use lshmf::coordinator::{Engine, Router};
use lshmf::lsh::{OnlineHashState, SimLsh};
use lshmf::metrics::Registry;
use lshmf::mf::neighbourhood::{train_culsh_logged, CulshConfig};
use lshmf::rng::Rng;
use lshmf::sparse::{Csc, Csr, Triples};
use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::thread;
use std::time::{Duration, Instant};

/// The props.rs serving fixture: 30x15, 180 nnz, deterministic per
/// seed — identical-seed engines are bit-identical replicas.
fn serving_engine(seed: u64, stream_cfg: StreamConfig) -> Engine {
    let mut rng = Rng::seeded(seed);
    let (m, n) = (30, 15);
    let mut t = Triples::new(m, n);
    let mut seen = std::collections::HashSet::new();
    while t.nnz() < 180 {
        let (i, j) = (rng.below(m), rng.below(n));
        if seen.insert((i, j)) {
            t.push(i, j, 1.0 + rng.f32() * 4.0);
        }
    }
    let csr = Csr::from_triples(&t);
    let csc = Csc::from_triples(&t);
    let lsh = SimLsh::new(1, 5, 8, 2);
    let hash_state = OnlineHashState::build(lsh, &csc);
    let (topk, _) = hash_state.topk(4, &mut rng);
    let cfg = CulshConfig { f: 4, k: 4, epochs: 4, ..Default::default() };
    let (model, _) = train_culsh_logged(&csr, topk, &cfg, &mut rng);
    let metrics = Registry::new();
    let orch = StreamOrchestrator::new(
        model,
        hash_state,
        t,
        stream_cfg,
        cfg,
        rng.split(1),
        metrics.clone(),
    );
    Engine::new(orch, (1.0, 5.0), metrics)
}

struct BackendProc {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    handle: thread::JoinHandle<Engine>,
}

fn spawn_backend(seed: u64, stream_cfg: StreamConfig) -> BackendProc {
    let engine = serving_engine(seed, stream_cfg);
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let stop = Arc::new(AtomicBool::new(false));
    let stop2 = Arc::clone(&stop);
    let handle = thread::spawn(move || server::serve(engine, listener, stop2, 2).unwrap());
    BackendProc { addr, stop, handle }
}

fn stop_backend(b: BackendProc) -> Engine {
    b.stop.store(true, Ordering::Relaxed);
    let _ = TcpStream::connect(b.addr);
    b.handle.join().unwrap()
}

/// Bit-exact reply comparison: float payloads by `to_bits`, everything
/// else structurally.
fn bits_eq(a: &Response, b: &Response) -> bool {
    match (a, b) {
        (Response::Pred(x), Response::Pred(y)) => x.to_bits() == y.to_bits(),
        (Response::Preds(xs), Response::Preds(ys)) => {
            xs.len() == ys.len()
                && xs.iter().zip(ys).all(|(x, y)| match (x, y) {
                    (Some(x), Some(y)) => x.to_bits() == y.to_bits(),
                    (None, None) => true,
                    _ => false,
                })
        }
        (Response::TopN(xs), Response::TopN(ys)) => {
            xs.len() == ys.len()
                && xs
                    .iter()
                    .zip(ys)
                    .all(|((ci, si), (cj, sj))| ci == cj && si.to_bits() == sj.to_bits())
        }
        _ => a == b,
    }
}

/// Randomized request mix mirroring the props.rs parity scripts:
/// reads across the (possibly grown) id range, rates with NaN/inf and
/// out-of-bounds poison, growth ids, MRATE batches, flushes. `STATS`
/// is exercised separately (its body differs by design: the router
/// aggregates).
fn gen_request(rng: &mut Rng) -> Request {
    match rng.below(12) {
        0 | 1 => Request::Predict { row: rng.below(36), col: rng.below(41) },
        2 | 3 => Request::TopN { row: rng.below(36), n: 1 + rng.below(8) },
        4 => Request::MPredict {
            row: rng.below(36),
            cols: (0..1 + rng.below(4)).map(|_| rng.below(41) as u32).collect(),
        },
        5 | 6 | 7 => {
            let value = match rng.below(9) {
                0 => f32::NAN,
                1 => f32::INFINITY,
                _ => 1.0 + rng.below(9) as f32 * 0.5,
            };
            let row = if rng.below(10) == 0 { 4_000_000_000 } else { rng.below(34) as u32 };
            Request::Rate { row, col: rng.below(19) as u32, value }
        }
        8 | 9 => Request::MRate {
            ratings: (0..1 + rng.below(4))
                .map(|_| {
                    let value = if rng.below(12) == 0 {
                        f32::NAN
                    } else {
                        1.0 + rng.below(9) as f32 * 0.5
                    };
                    (rng.below(34) as u32, rng.below(19) as u32, value)
                })
                .collect(),
        },
        _ => Request::Flush,
    }
}

fn route_cfg(addrs: Vec<String>, cols: usize) -> RouteConfig {
    RouteConfig {
        cols,
        probe_interval_ms: 40,
        retry_backoff_ms: 2,
        retry_backoff_max_ms: 25,
        retry_attempts: 2,
        io_timeout_ms: 2_000,
        backends: addrs.into_iter().map(|addr| RouteBackend { addr }).collect(),
    }
}

/// Drive one randomized script through a router over `n_backends`
/// identical-seed backends and a monolithic `Mutex<Engine>` reference;
/// every reply must be bit-identical, and `STATS` must cohere.
fn router_parity(n_backends: usize, seed: u64) {
    let stream_cfg = StreamConfig {
        batch_size: 5,
        max_rows: 200,
        max_cols: 200,
        ..Default::default()
    };
    let mono = Mutex::new(serving_engine(seed, stream_cfg.clone()));
    let backends: Vec<BackendProc> =
        (0..n_backends).map(|_| spawn_backend(seed, stream_cfg.clone())).collect();
    let cfg = route_cfg(backends.iter().map(|b| b.addr.to_string()).collect(), 200);
    let router = Router::new(&cfg, Registry::new());

    let mut rng = Rng::seeded(seed ^ 0x51AB);
    for step in 0..140 {
        let req = gen_request(&mut rng);
        let want = mono.handle(&req);
        let got = router.handle(&req);
        assert!(
            bits_eq(&got, &want),
            "step {step}: {req:?} answered {got:?}, monolith said {want:?}"
        );
    }
    // validation parity without any backend round-trip
    for req in [
        Request::TopN { row: 0, n: 0 },
        Request::MPredict { row: 0, cols: Vec::new() },
        Request::MRate { ratings: Vec::new() },
        Request::Subscribe,
    ] {
        assert!(bits_eq(&router.handle(&req), &mono.handle(&req)), "{req:?}");
    }
    // STATS coherence: the router aggregates; every backend must report
    // the monolith's (post-growth) dims under its own prefix.
    let dims = match mono.handle(&Request::Stats) {
        Response::Stats(body) => body
            .lines()
            .find(|l| l.starts_with("dims "))
            .expect("monolith stats carry dims")
            .to_string(),
        other => panic!("monolith STATS answered {other:?}"),
    };
    match router.handle(&Request::Stats) {
        Response::Stats(body) => {
            assert!(body.contains(&format!("router backends {n_backends}")), "{body}");
            assert!(body.contains(&format!("router up {n_backends}")), "{body}");
            for i in 0..n_backends {
                assert!(body.contains(&format!("backend{i}.{dims}")), "{body}");
            }
        }
        other => panic!("router STATS answered {other:?}"),
    }
    drop(router); // drains lanes, closes connections
    for b in backends {
        stop_backend(b);
    }
}

#[test]
fn router_parity_two_backends() {
    router_parity(2, 9001);
}

#[test]
fn router_parity_three_backends() {
    router_parity(3, 9002);
}

/// The router rides the same Dispatch-generic text path as an engine:
/// `handle_line` answers (and accounts) identically, down to
/// unknown-verb handling.
#[test]
fn router_shares_the_text_line_handler() {
    let stream_cfg = StreamConfig { batch_size: 8, max_rows: 64, max_cols: 64, ..Default::default() };
    let mono = Mutex::new(serving_engine(9003, stream_cfg.clone()));
    let backends: Vec<BackendProc> =
        (0..2).map(|_| spawn_backend(9003, stream_cfg.clone())).collect();
    let cfg = route_cfg(backends.iter().map(|b| b.addr.to_string()).collect(), 64);
    let registry = Registry::new();
    let router = Router::new(&cfg, registry.clone());
    for line in [
        "PREDICT 0 3",
        "TOPN 1 4",
        "RATE 2 3 4.5",
        "FLUSH",
        "BOGUS 1 2",
        "PREDICT not-a-number 3",
    ] {
        assert_eq!(handle_line(&router, line), handle_line(&mono, line), "{line}");
    }
    assert_eq!(
        registry.counter("server.unknown_verb").get(),
        1,
        "the router's registry carries the line-layer accounting"
    );
    drop(router);
    for b in backends {
        stop_backend(b);
    }
}

// ---------------------------------------------------------------------
// Fault injection
// ---------------------------------------------------------------------

/// A TCP proxy fronting one backend, with a kill switch. `kill()`
/// bumps the epoch (severing every relayed connection from the
/// router's side *and* the backend's side) and makes new connections
/// accept-then-drop — the router experiences a partitioned peer while
/// the backend itself stays healthy. `restart()` resumes forwarding on
/// the SAME front address, so the router's reconnect machinery (not a
/// new config) performs the recovery.
struct FaultProxy {
    front: SocketAddr,
    stop: Arc<AtomicBool>,
    forwarding: Arc<AtomicBool>,
    epoch: Arc<AtomicU64>,
    accept: Option<thread::JoinHandle<()>>,
}

fn relay(
    mut from: TcpStream,
    mut to: TcpStream,
    my_epoch: u64,
    epoch: Arc<AtomicU64>,
    stop: Arc<AtomicBool>,
) -> thread::JoinHandle<()> {
    from.set_read_timeout(Some(Duration::from_millis(10))).unwrap();
    thread::spawn(move || {
        let mut buf = [0u8; 4096];
        loop {
            if stop.load(Ordering::SeqCst) || epoch.load(Ordering::SeqCst) != my_epoch {
                break;
            }
            match from.read(&mut buf) {
                Ok(0) => break,
                Ok(n) => {
                    if to.write_all(&buf[..n]).is_err() {
                        break;
                    }
                }
                Err(e)
                    if matches!(
                        e.kind(),
                        std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
                    ) =>
                {
                    continue
                }
                Err(_) => break,
            }
        }
        // Sever both directions so neither peer is left blocked on a
        // half-open socket.
        let _ = to.shutdown(std::net::Shutdown::Both);
        let _ = from.shutdown(std::net::Shutdown::Both);
    })
}

impl FaultProxy {
    fn spawn(backend: SocketAddr) -> FaultProxy {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        listener.set_nonblocking(true).unwrap();
        let front = listener.local_addr().unwrap();
        let stop = Arc::new(AtomicBool::new(false));
        let forwarding = Arc::new(AtomicBool::new(true));
        let epoch = Arc::new(AtomicU64::new(0));
        let accept = {
            let stop = Arc::clone(&stop);
            let forwarding = Arc::clone(&forwarding);
            let epoch = Arc::clone(&epoch);
            thread::spawn(move || {
                let mut relays: Vec<thread::JoinHandle<()>> = Vec::new();
                while !stop.load(Ordering::SeqCst) {
                    match listener.accept() {
                        Ok((sock, _)) => {
                            if !forwarding.load(Ordering::SeqCst) {
                                drop(sock); // killed: instant disconnect
                                continue;
                            }
                            let Ok(upstream) = TcpStream::connect(backend) else {
                                drop(sock);
                                continue;
                            };
                            let e = epoch.load(Ordering::SeqCst);
                            relays.push(relay(
                                sock.try_clone().unwrap(),
                                upstream.try_clone().unwrap(),
                                e,
                                Arc::clone(&epoch),
                                Arc::clone(&stop),
                            ));
                            relays.push(relay(
                                upstream,
                                sock,
                                e,
                                Arc::clone(&epoch),
                                Arc::clone(&stop),
                            ));
                        }
                        Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                            thread::sleep(Duration::from_millis(5));
                        }
                        Err(_) => break,
                    }
                }
                for r in relays {
                    let _ = r.join();
                }
            })
        };
        FaultProxy { front, stop, forwarding, epoch, accept: Some(accept) }
    }

    fn kill(&self) {
        self.epoch.fetch_add(1, Ordering::SeqCst);
        self.forwarding.store(false, Ordering::SeqCst);
    }

    fn restart(&self) {
        self.forwarding.store(true, Ordering::SeqCst);
    }

    fn shutdown(mut self) {
        self.stop.store(true, Ordering::SeqCst);
        self.epoch.fetch_add(1, Ordering::SeqCst);
        if let Some(h) = self.accept.take() {
            h.join().unwrap();
        }
    }
}

fn wait_until(deadline: Duration, mut cond: impl FnMut() -> bool) -> bool {
    let start = Instant::now();
    while start.elapsed() < deadline {
        if cond() {
            return true;
        }
        thread::sleep(Duration::from_millis(20));
    }
    false
}

/// Kill the middle backend of a 3-node fleet mid-conversation: its
/// partition must answer typed `Unavailable` in bounded time (never a
/// hang), the surviving partitions must keep serving reads AND
/// acknowledged writes, retries must be counted — and after a restart
/// the journaled writes replay, the backend rejoins, and the whole
/// fleet is bit-identical to a monolith fed exactly the acknowledged
/// writes.
#[test]
fn killed_backend_degrades_typed_and_recovers_to_parity() {
    let seed = 7100;
    // batch_size > script length: flushes happen only on explicit FLUSH
    let stream_cfg = StreamConfig { batch_size: 64, max_rows: 64, max_cols: 64, ..Default::default() };
    let mono = Mutex::new(serving_engine(seed, stream_cfg.clone()));
    let b0 = spawn_backend(seed, stream_cfg.clone());
    let b1 = spawn_backend(seed, stream_cfg.clone());
    let b2 = spawn_backend(seed, stream_cfg.clone());
    let proxy = FaultProxy::spawn(b1.addr);
    // cols = 15 (the fixture's real extent): backend1 — behind the
    // proxy — owns the middle band, columns [5, 10).
    let cfg = RouteConfig {
        cols: 15,
        probe_interval_ms: 40,
        retry_backoff_ms: 2,
        retry_backoff_max_ms: 20,
        retry_attempts: 2,
        io_timeout_ms: 400,
        backends: vec![
            RouteBackend { addr: b0.addr.to_string() },
            RouteBackend { addr: proxy.front.to_string() },
            RouteBackend { addr: b2.addr.to_string() },
        ],
    };
    let registry = Registry::new();
    let router = Router::new(&cfg, registry.clone());
    let unavailable = Response::Error(ErrorKind::Unavailable);

    // Healthy phase: writes land on every replica, reads are
    // bit-identical to the monolith.
    for (row, col, value) in [(0u32, 6u32, 4.5f32), (1, 2, 3.0), (2, 12, 2.5)] {
        let req = Request::Rate { row, col, value };
        assert!(bits_eq(&router.handle(&req), &mono.handle(&req)), "{req:?}");
    }
    for req in [
        Request::Flush,
        Request::TopN { row: 0, n: 5 },
        Request::Predict { row: 0, col: 7 },
    ] {
        assert!(bits_eq(&router.handle(&req), &mono.handle(&req)), "{req:?}");
    }

    // Kill. The victim's partition must degrade to a typed error in
    // bounded time — the read path burns its capped retries and gives
    // up; nothing hangs, nothing panics.
    proxy.kill();
    let start = Instant::now();
    assert_eq!(router.handle(&Request::Predict { row: 0, col: 7 }), unavailable);
    assert!(
        start.elapsed() < Duration::from_secs(5),
        "detection took {:?} — not bounded",
        start.elapsed()
    );
    assert!(
        wait_until(Duration::from_secs(5), || !router.backend_up(1)),
        "victim never marked down"
    );

    // Down owner: rejected up front, applied on NO replica (lock-step
    // preserved).
    assert_eq!(router.handle(&Request::Rate { row: 3, col: 7, value: 2.0 }), unavailable);
    // Surviving partitions keep serving reads...
    let req = Request::Predict { row: 0, col: 2 };
    assert!(bits_eq(&router.handle(&req), &mono.handle(&req)));
    // ...and writes they own: acknowledged now, journaled for the
    // victim's catch-up. The monolith sees exactly the acknowledged
    // writes.
    let w = Request::Rate { row: 4, col: 1, value: 3.5 };
    let got = router.handle(&w);
    assert!(!matches!(got, Response::Error(_)), "{got:?}");
    assert!(bits_eq(&got, &mono.handle(&w)));
    // Scatter reads need every band: typed, not hanging.
    assert_eq!(router.handle(&Request::TopN { row: 0, n: 5 }), unavailable);
    assert!(registry.counter("router.retries").get() > 0, "retries uncounted");
    assert!(registry.counter("router.unavailable").get() > 0);
    match router.handle(&Request::Stats) {
        Response::Stats(body) => {
            assert!(body.contains("router up 2"), "{body}");
            assert!(body.contains("backend1 down"), "{body}");
        }
        other => panic!("STATS during outage answered {other:?}"),
    }

    // Restart on the same address: the probe loop reconnects, the lane
    // replays the journaled write, and only then does the victim count
    // as up again.
    proxy.restart();
    assert!(
        wait_until(Duration::from_secs(15), || router.backend_up(1)),
        "victim never recovered"
    );
    assert!(
        registry.counter("router.backend1.replayed").get() > 0,
        "catch-up replay not performed"
    );
    assert!(registry.counter("router.backend1.health_transitions").get() >= 2);

    // Post-recovery parity: every partition, every verb, bit-identical
    // to the monolith that saw only the acknowledged writes.
    for req in [Request::Flush, Request::TopN { row: 4, n: 8 }] {
        assert!(bits_eq(&router.handle(&req), &mono.handle(&req)), "{req:?}");
    }
    for col in 0..15usize {
        let req = Request::Predict { row: 4, col };
        assert!(bits_eq(&router.handle(&req), &mono.handle(&req)), "col {col}");
    }
    let req = Request::MPredict { row: 0, cols: (0..15).collect() };
    assert!(bits_eq(&router.handle(&req), &mono.handle(&req)));
    match router.handle(&Request::Stats) {
        Response::Stats(body) => assert!(body.contains("router up 3"), "{body}"),
        other => panic!("STATS after recovery answered {other:?}"),
    }

    // Teardown order matters: router first (its lanes hold the
    // connections), then the proxy (severs the victim's sockets), then
    // the backends.
    drop(router);
    proxy.shutdown();
    stop_backend(b0);
    stop_backend(b1);
    stop_backend(b2);
}
