//! Cross-module integration tests: the whole L3 pipeline on structured
//! synthetic data.

use lshmf::data::synth::SynthConfig;
use lshmf::gsm::Gsm;
use lshmf::lsh::{MinHash, NeighbourSearch, RandNeighbours, RpCos, SimLsh};
use lshmf::mf::neighbourhood::{train_culsh_logged, CulshConfig};
use lshmf::mf::sgd::{train_sgd_logged, SgdConfig};
use lshmf::rng::Rng;
use lshmf::sparse::{Csc, Csr, Triples};

/// Clustered low-rank data where neighbourhoods are real.
fn clustered(rng: &mut Rng, m: usize, n: usize, clusters: usize) -> (Csr, Csc, Vec<(u32, u32, f32)>) {
    let d = 3;
    let a: Vec<f32> = (0..m * d).map(|_| rng.normal_f32(0.0, 0.6)).collect();
    let cent: Vec<f32> = (0..clusters * d).map(|_| rng.normal_f32(0.0, 0.6)).collect();
    let mut vprof = vec![0f32; n * d];
    for j in 0..n {
        let cl = j % clusters;
        for x in 0..d {
            vprof[j * d + x] = cent[cl * d + x] + rng.normal_f32(0.0, 0.1);
        }
    }
    let mut t = Triples::new(m, n);
    let mut test = Vec::new();
    for j in 0..n {
        for i in 0..m {
            if rng.chance(0.35) {
                let dot: f32 = (0..d).map(|x| a[i * d + x] * vprof[j * d + x]).sum();
                let v = (2.75 + dot + rng.normal_f32(0.0, 0.25)).clamp(0.5, 5.0);
                if rng.chance(0.9) {
                    t.push(i, j, v);
                } else {
                    test.push((i as u32, j as u32, v));
                }
            }
        }
    }
    (Csr::from_triples(&t), Csc::from_triples(&t), test)
}

/// simLSH must pick *meaningfully better-than-random* neighbours at a
/// fraction of the GSM's memory. (Recall against the exact GSM is modest
/// by design — an 8-bit sign sketch over sparse supports only surfaces
/// the strongest pairs; the paper's Fig. 7 claim is end-model RMSE
/// parity, which `culsh_descends_faster_than_plain_sgd` plus the Table 7
/// bench cover. Here we assert neighbour *quality*: the mean GSM
/// similarity of simLSH-chosen neighbours must far exceed random's.)
#[test]
fn simlsh_picks_better_than_random_neighbours() {
    let mut rng = Rng::seeded(201);
    let (csr, csc, _) = clustered(&mut rng, 150, 60, 10);
    let k = 6;
    let gsm = Gsm::new(20.0);
    let (sims, _) = gsm.similarities(&csr, &mut rng);
    let mean_sim = |topk: &lshmf::lsh::TopK| -> f64 {
        let mut acc = 0.0;
        let mut cnt = 0usize;
        for j in 0..topk.n() {
            for &nb in topk.neighbours(j) {
                acc += sims[j].get(&nb).map(|ps| ps.similarity).unwrap_or(0.0);
                cnt += 1;
            }
        }
        acc / cnt as f64
    };
    // centered Ψ is the strongest variant on this dense-ish fixture
    let (sim_topk, sim_cost) = SimLsh::new(1, 60, 8, 2)
        .centered(2.75)
        .build(&csc, k, &mut rng);
    let (rand_topk, _) = RandNeighbours.build(&csc, k, &mut rng);
    let (gsm_topk, gsm_cost) = Gsm::new(20.0).build(&csc, k, &mut rng);

    let q_sim = mean_sim(&sim_topk);
    let q_rand = mean_sim(&rand_topk);
    let q_gsm = mean_sim(&gsm_topk);
    assert!(
        q_sim > q_rand * 1.5 && q_sim > q_rand + 0.02,
        "simLSH quality {q_sim:.4} vs random {q_rand:.4} (gsm {q_gsm:.4})"
    );
    // and the LSH memory cost must be far below the GSM's
    assert!(
        sim_cost.bytes < gsm_cost.bytes,
        "simLSH {} bytes vs GSM {} bytes",
        sim_cost.bytes,
        gsm_cost.bytes
    );
}

/// Same-cluster columns should be over-represented in value-aware
/// engines' Top-K lists; minHash (support-only) and the random control
/// must trail simLSH — the paper's motivation for simLSH over minHash.
#[test]
fn engines_find_cluster_structure() {
    let mut rng = Rng::seeded(202);
    let clusters = 10;
    // denser fixture: per-bit correlation needs support overlap to show
    let (_, csc, _) = clustered_dense(&mut rng, 150, 60, clusters, 0.6, 0.15);
    let k = 4;
    let same_cluster_rate = |topk: &lshmf::lsh::TopK| -> f64 {
        let mut hits = 0;
        let mut total = 0;
        for j in 0..topk.n() {
            for &nb in topk.neighbours(j) {
                total += 1;
                if nb as usize % clusters == j % clusters {
                    hits += 1;
                }
            }
        }
        hits as f64 / total as f64
    };
    let chance = 1.0 / clusters as f64;

    let (sim, _) = SimLsh::new(1, 60, 8, 2).build(&csc, k, &mut rng);
    let (simc, _) = SimLsh::new(1, 60, 8, 2).centered(2.75).build(&csc, k, &mut rng);
    let (mh, _) = MinHash::new(2, 40).build(&csc, k, &mut rng);
    let (rnd, _) = RandNeighbours.build(&csc, k, &mut rng);

    let (r_sim, r_simc, r_mh, r_rnd) = (
        same_cluster_rate(&sim),
        same_cluster_rate(&simc),
        same_cluster_rate(&mh),
        same_cluster_rate(&rnd),
    );
    assert!(r_sim > 1.7 * chance, "simLSH {r_sim}");
    assert!(r_simc >= r_sim - 0.02, "centered {r_simc} vs plain {r_sim}");
    // minHash sees only supports — clusters share VALUE structure, not
    // support structure, so it must trail simLSH (the paper's point).
    assert!(r_mh < r_sim, "minHash {r_mh} vs simLSH {r_sim}");
    assert!(r_rnd < 1.5 * chance, "random {r_rnd}");
    let _ = RpCos::new(1, 1, 1); // keep the import exercised
}

/// Denser variant of the fixture for hash-signal tests.
#[allow(clippy::too_many_arguments)]
fn clustered_dense(
    rng: &mut Rng,
    m: usize,
    n: usize,
    clusters: usize,
    density: f64,
    noise: f32,
) -> (Csr, Csc, Vec<(u32, u32, f32)>) {
    let d = 3;
    let a: Vec<f32> = (0..m * d).map(|_| rng.normal_f32(0.0, 0.6)).collect();
    let cent: Vec<f32> = (0..clusters * d).map(|_| rng.normal_f32(0.0, 0.6)).collect();
    let mut vprof = vec![0f32; n * d];
    for j in 0..n {
        let cl = j % clusters;
        for x in 0..d {
            vprof[j * d + x] = cent[cl * d + x] + rng.normal_f32(0.0, 0.1);
        }
    }
    let mut t = Triples::new(m, n);
    for j in 0..n {
        for i in 0..m {
            if rng.chance(density) {
                let dot: f32 = (0..d).map(|x| a[i * d + x] * vprof[j * d + x]).sum();
                let v = (2.75 + dot + rng.normal_f32(0.0, noise)).clamp(0.5, 5.0);
                t.push(i, j, v);
            }
        }
    }
    (Csr::from_triples(&t), Csc::from_triples(&t), Vec::new())
}

/// CULSH-MF with simLSH neighbours must beat plain biased SGD at a small
/// epoch budget on clustered data (the Fig. 10 shape).
#[test]
fn culsh_descends_faster_than_plain_sgd() {
    let mut rng = Rng::seeded(203);
    let (csr, csc, test) = clustered(&mut rng, 120, 60, 10);
    let (topk, _) = SimLsh::new(2, 30, 8, 2).build(&csc, 8, &mut rng);
    let epochs = 8;
    let culsh_cfg = CulshConfig {
        f: 8,
        k: 8,
        epochs,
        alpha: 0.04,
        alpha_wc: 0.01,
        beta: 0.02,
        lambda_u: 0.01,
        lambda_v: 0.01,
        lambda_b: 0.01,
        eval: test.clone(),
        ..Default::default()
    };
    let (_, culsh) = train_culsh_logged(&csr, topk, &culsh_cfg, &mut Rng::seeded(1));
    let sgd_cfg = SgdConfig {
        f: 8,
        epochs,
        alpha: 0.04,
        beta: 0.02,
        lambda_u: 0.01,
        lambda_v: 0.01,
        lambda_b: 0.01,
        eval: test,
        ..Default::default()
    };
    let (_, sgd) = train_sgd_logged(&csr, &sgd_cfg, &mut Rng::seeded(1));
    assert!(
        culsh.final_rmse() <= sgd.final_rmse() + 0.02,
        "culsh {} vs sgd {}",
        culsh.final_rmse(),
        sgd.final_rmse()
    );
}

/// The synthetic Table 2 generators hit their calibrated shapes.
#[test]
fn synth_generators_match_table2_shapes() {
    for (cfg, m, n) in [
        (SynthConfig::netflix_like(), 480_189, 17_770),
        (SynthConfig::movielens_like(), 69_878, 10_677),
        (SynthConfig::yahoo_like(), 586_250, 12_658),
    ] {
        assert_eq!(cfg.nrows, m);
        assert_eq!(cfg.ncols, n);
    }
    // generation at small scale preserves the rating range
    let mut rng = Rng::seeded(204);
    let ds = lshmf::data::synth::generate(&SynthConfig::yahoo_like().scaled(0.01), &mut rng);
    assert!(ds.max_value <= 100.0 && ds.min_value >= 0.5);
    assert!(ds.nnz() > 1000);
}

/// End-to-end config-driven run through the CLI helpers (the same path
/// `lshmf train` takes).
#[test]
fn cli_train_path_end_to_end() {
    let cfg = lshmf::config::ExperimentConfig::from_str(
        r#"
[dataset]
kind = "movielens"
scale = 0.012
seed = 77

[model]
f = 8
k = 8

[trainer]
kind = "culsh"
epochs = 3
threads = 2

[lsh]
kind = "simlsh"
p = 2
q = 6
"#,
    )
    .unwrap();
    let mut rng = Rng::seeded(cfg.dataset.seed);
    let ds = lshmf::cli::commands::build_dataset(&cfg, &mut rng).unwrap();
    let log = lshmf::cli::commands::run_trainer(&cfg, &ds, &mut rng).unwrap();
    assert!(log.final_rmse().is_finite());
    assert!(log.points.len() == 3);
}
