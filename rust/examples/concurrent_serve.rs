//! Concurrent serving scenario: train CULSH-MF, stand the pooled TCP
//! server up on a local port, then hammer it with parallel reader
//! connections while a writer connection streams live ratings through
//! the single-writer online path.
//!
//! Demonstrates the tentpole serving properties: `PREDICT`/`MPREDICT`/
//! `TOPN` latency stays flat *during* flushes because readers run on
//! epoch-swapped snapshots and never wait for the online update — and
//! with the snapshot sharded by column band, each flush republishes only
//! the bands it dirtied (watch `shared.publish_bytes_cloned` and the
//! `shared.shard<b>.publishes` counters in the stats dump).
//!
//! Run with: `cargo run --release --example concurrent_serve`

use lshmf::coordinator::server;
use lshmf::coordinator::stream::{StreamConfig, StreamOrchestrator};
use lshmf::coordinator::Engine;
use lshmf::data::synth::{generate, SynthConfig};
use lshmf::lsh::{OnlineHashState, SimLsh};
use lshmf::metrics::Registry;
use lshmf::mf::neighbourhood::{train_culsh_logged, CulshConfig};
use lshmf::rng::Rng;
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

const READERS: usize = 4;
const REQUESTS_PER_READER: usize = 400;
const RATES: usize = 512;
const SHARDS: usize = 4;

fn main() {
    let mut rng = Rng::seeded(13);
    let ds = generate(&SynthConfig::movielens_like().scaled(0.02), &mut rng);
    println!("catalog: {} users × {} items", ds.nrows(), ds.ncols());

    let lsh = SimLsh::new(2, 16, 8, 2);
    let hash_state = OnlineHashState::build(lsh, &ds.train_csc);
    let (topk, _) = hash_state.topk(16, &mut rng);
    let cfg = CulshConfig { f: 16, k: 16, epochs: 20, beta: 0.02, ..Default::default() };
    let (model, _) = train_culsh_logged(&ds.train, topk, &cfg, &mut rng);

    let metrics = Registry::new();
    let orch = StreamOrchestrator::new(
        model,
        hash_state,
        ds.train.to_triples(),
        // small batches so the reader traffic overlaps many flushes
        StreamConfig { batch_size: 64, ..Default::default() },
        cfg,
        rng.split(3),
        metrics.clone(),
    );
    let engine = Engine::new(orch, (ds.min_value, ds.max_value), metrics);

    let listener = TcpListener::bind("127.0.0.1:0").expect("bind");
    let addr = listener.local_addr().unwrap();
    let stop = Arc::new(AtomicBool::new(false));
    let server_thread = {
        let stop = stop.clone();
        std::thread::spawn(move || {
            server::serve_sharded(engine, listener, stop, READERS + 1, SHARDS)
        })
    };
    println!(
        "serving on {addr} with {} connection threads, {SHARDS} snapshot shards",
        READERS + 1
    );

    let (nrows, ncols) = (ds.nrows(), ds.ncols());
    let t0 = Instant::now();
    let mut reader_threads = Vec::new();
    for reader in 0..READERS {
        reader_threads.push(std::thread::spawn(move || {
            let stream = TcpStream::connect(addr).expect("connect");
            let mut tx = stream.try_clone().unwrap();
            let mut rx = BufReader::new(stream);
            let mut latencies = Vec::with_capacity(REQUESTS_PER_READER);
            for k in 0..REQUESTS_PER_READER {
                let line = if k % 10 == 0 {
                    format!("TOPN {} 10\n", (k * 31 + reader) % nrows)
                } else if k % 10 == 5 {
                    // batched lookups answer from one snapshot version
                    format!(
                        "MPREDICT {} {} {} {}\n",
                        (k * 17 + reader) % nrows,
                        (k * 13) % ncols,
                        (k * 13 + 1) % ncols,
                        (k * 13 + 2) % ncols
                    )
                } else {
                    format!("PREDICT {} {}\n", (k * 17 + reader) % nrows, (k * 13) % ncols)
                };
                let q0 = Instant::now();
                tx.write_all(line.as_bytes()).unwrap();
                let mut reply = String::new();
                rx.read_line(&mut reply).unwrap();
                latencies.push(q0.elapsed());
                assert!(!reply.starts_with("ERR"), "{line} -> {reply}");
            }
            tx.write_all(b"QUIT\n").unwrap();
            latencies
        }));
    }
    let writer_thread = std::thread::spawn(move || {
        let stream = TcpStream::connect(addr).expect("connect");
        let mut tx = stream.try_clone().unwrap();
        let mut rx = BufReader::new(stream);
        let mut flushes = 0usize;
        for k in 0..RATES {
            let (i, j) = ((k * 7) % nrows, (k * 11) % ncols);
            tx.write_all(format!("RATE {i} {j} 4.0\n").as_bytes()).unwrap();
            let mut reply = String::new();
            rx.read_line(&mut reply).unwrap();
            if reply.starts_with("OK flushed") {
                flushes += 1;
            }
        }
        tx.write_all(b"QUIT\n").unwrap();
        flushes
    });

    let mut latencies: Vec<Duration> = Vec::new();
    for t in reader_threads {
        latencies.extend(t.join().unwrap());
    }
    let flushes = writer_thread.join().unwrap();
    let wall = t0.elapsed().as_secs_f64();

    latencies.sort_unstable();
    let pct = |q: f64| latencies[((latencies.len() - 1) as f64 * q) as usize];
    let total = READERS * REQUESTS_PER_READER;
    println!(
        "{total} reads from {READERS} parallel connections in {wall:.2}s \
         ({:.0} req/s) while {RATES} RATEs drove {flushes} flushes",
        total as f64 / wall
    );
    println!(
        "read latency p50 {:?} p95 {:?} p99 {:?} max {:?} — flat through flushes",
        pct(0.50),
        pct(0.95),
        pct(0.99),
        pct(1.0)
    );

    // pull the server's own metrics before shutting down
    {
        let stream = TcpStream::connect(addr).expect("connect");
        let mut tx = stream.try_clone().unwrap();
        let mut rx = BufReader::new(stream);
        tx.write_all(b"STATS\n").unwrap();
        let mut line = String::new();
        println!("--- server stats ---");
        while rx.read_line(&mut line).unwrap() > 0 {
            if line.trim_end().ends_with("END") {
                break;
            }
            let keep =
                ["dims", "buffered", "version", "shards", "server.", "shared.", "stream."];
            if keep.iter().any(|p| line.contains(p)) {
                print!("{line}");
            }
            line.clear();
        }
        tx.write_all(b"QUIT\n").unwrap();
    }

    stop.store(true, Ordering::Relaxed);
    let _ = TcpStream::connect(addr);
    let engine = server_thread.join().unwrap().expect("server");
    println!("server stopped cleanly; final dims {:?}", engine.dims());
}
