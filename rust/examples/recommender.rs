//! Recommender scenario: train CULSH-MF, stand up the serving [`Engine`],
//! and issue the requests a recommendation frontend would: per-user top-N,
//! point predictions, and live rating ingestion (which flows through the
//! Algorithm-4 online path — no retraining).
//!
//! Run with: `cargo run --release --example recommender`

use lshmf::coordinator::stream::{StreamConfig, StreamOrchestrator};
use lshmf::coordinator::Engine;
use lshmf::data::synth::{generate, SynthConfig};
use lshmf::lsh::{OnlineHashState, SimLsh};
use lshmf::metrics::Registry;
use lshmf::mf::neighbourhood::{train_culsh_logged, CulshConfig};
use lshmf::rng::Rng;

fn main() {
    let mut rng = Rng::seeded(7);
    let ds = generate(&SynthConfig::movielens_like().scaled(0.02), &mut rng);
    println!("catalog: {} users × {} items", ds.nrows(), ds.ncols());

    let lsh = SimLsh::new(2, 20, 8, 2);
    let hash_state = OnlineHashState::build(lsh, &ds.train_csc);
    let (topk, _) = hash_state.topk(16, &mut rng);
    let cfg = CulshConfig { f: 32, k: 16, epochs: 30, beta: 0.02, ..Default::default() };
    let (model, _) = train_culsh_logged(&ds.train, topk, &cfg, &mut rng);

    let orch = StreamOrchestrator::new(
        model,
        hash_state,
        ds.train.to_triples(),
        StreamConfig { batch_size: 64, ..Default::default() },
        cfg,
        rng.split(1),
        Registry::new(),
    );
    let mut engine = Engine::new(orch, (ds.min_value, ds.max_value), Registry::new());

    // A few users' top-5 recommendations.
    for user in [0usize, 17, 42] {
        let recs = engine.top_n(user, 5);
        let pretty: Vec<String> = recs.iter().map(|(j, s)| format!("item{j}@{s:.2}")).collect();
        println!("user {user:>4} → {}", pretty.join("  "));
    }

    // Point predictions.
    for (u, i) in [(0usize, 3usize), (17, 100), (42, 7)] {
        println!("predict(user {u}, item {i}) = {:.3}", engine.predict(u, i).unwrap());
    }

    // A burst of live ratings — including a brand-new user — then fresh
    // recommendations for them without any retraining.
    let new_user = ds.nrows() as u32;
    for item in [0u32, 5, 9, 13, 21] {
        engine.rate(new_user, item, 5.0);
    }
    engine.flush();
    let recs = engine.top_n(new_user as usize, 5);
    let pretty: Vec<String> = recs.iter().map(|(j, s)| format!("item{j}@{s:.2}")).collect();
    println!("NEW user {new_user} (5 ratings, online-learned) → {}", pretty.join("  "));
    println!("--- engine stats ---\n{}", engine.stats());
}
