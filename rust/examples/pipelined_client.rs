//! The typed protocol client, end to end: train CULSH-MF, stand the
//! auto-codec TCP server up on a local port, then drive it three ways —
//!
//! 1. a **text** client, one verb per round-trip (the legacy wire
//!    usage every `telnet`/`nc` session gets);
//! 2. a **binary** client making the same calls synchronously (typed
//!    replies, no string parsing, still one round-trip per call);
//! 3. a **binary pipelined** client shipping 256-rating `MRATE` frames
//!    and 256-column `MPREDICT` frames with every frame in flight —
//!    the transfer format doing the work, per the cuMF lesson that
//!    batching and wire design decide end-to-end throughput.
//!
//! Run with: `cargo run --release --example pipelined_client`

use lshmf::coordinator::client::{ClientCodec, LshmfClient};
use lshmf::coordinator::protocol::{OkBody, Request, Response};
use lshmf::coordinator::server;
use lshmf::coordinator::stream::{StreamConfig, StreamOrchestrator};
use lshmf::coordinator::Engine;
use lshmf::data::synth::{generate, SynthConfig};
use lshmf::lsh::{OnlineHashState, SimLsh};
use lshmf::metrics::Registry;
use lshmf::mf::neighbourhood::{train_culsh_logged, CulshConfig};
use lshmf::rng::Rng;
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Instant;

const RATINGS: usize = 4096;
const FRAME: usize = 256;

fn main() {
    let mut rng = Rng::seeded(17);
    let ds = generate(&SynthConfig::movielens_like().scaled(0.02), &mut rng);
    println!("catalog: {} users × {} items", ds.nrows(), ds.ncols());

    let lsh = SimLsh::new(2, 16, 8, 2);
    let hash_state = OnlineHashState::build(lsh, &ds.train_csc);
    let (topk, _) = hash_state.topk(16, &mut rng);
    let cfg = CulshConfig { f: 16, k: 16, epochs: 10, beta: 0.02, ..Default::default() };
    let (model, _) = train_culsh_logged(&ds.train, topk, &cfg, &mut rng);

    let metrics = Registry::new();
    let orch = StreamOrchestrator::new(
        model,
        hash_state,
        ds.train.to_triples(),
        StreamConfig { batch_size: 8192, ..Default::default() },
        cfg,
        rng.split(3),
        metrics.clone(),
    );
    let engine = Engine::new(orch, (ds.min_value, ds.max_value), metrics);

    let listener = TcpListener::bind("127.0.0.1:0").expect("bind");
    let addr = listener.local_addr().unwrap();
    let stop = Arc::new(AtomicBool::new(false));
    let server_thread = {
        let stop = stop.clone();
        std::thread::spawn(move || server::serve(engine, listener, stop, 2))
    };
    println!("serving on {addr} (codec auto: text and binary on one port)\n");

    let (nrows, ncols) = (ds.nrows(), ds.ncols());
    let events: Vec<(u32, u32, f32)> = (0..RATINGS)
        .map(|k| (((k * 7) % nrows) as u32, ((k * 11) % ncols) as u32, 4.0))
        .collect();

    // 1) text, one verb per round-trip
    let mut text = LshmfClient::connect(addr, ClientCodec::Text).expect("connect");
    let t0 = Instant::now();
    for &(i, j, r) in &events {
        let reply = text.rate(i, j, r).expect("rate");
        assert!(matches!(reply, Response::Ok(_)), "{reply:?}");
    }
    let text_secs = t0.elapsed().as_secs_f64();
    println!(
        "text   RATE  one-per-round-trip: {RATINGS} ratings in {text_secs:.3}s \
         ({:.0}k ratings/s)",
        RATINGS as f64 / text_secs / 1e3
    );

    // 2) binary, synchronous (typed replies, still one round-trip each)
    let mut binary = LshmfClient::connect(addr, ClientCodec::Binary).expect("connect");
    let t0 = Instant::now();
    for &(i, j, r) in &events {
        binary.rate(i, j, r).expect("rate");
    }
    let sync_secs = t0.elapsed().as_secs_f64();
    println!(
        "binary RATE  one-per-round-trip: {RATINGS} ratings in {sync_secs:.3}s \
         ({:.0}k ratings/s)",
        RATINGS as f64 / sync_secs / 1e3
    );

    // 3) binary, pipelined MRATE frames — every frame in flight
    let t0 = Instant::now();
    let mut pipe = binary.pipeline();
    for chunk in events.chunks(FRAME) {
        pipe.push(&Request::MRate { ratings: chunk.to_vec() }).expect("push");
    }
    let replies = pipe.finish().expect("finish");
    let pipe_secs = t0.elapsed().as_secs_f64();
    assert_eq!(replies.len(), RATINGS / FRAME);
    println!(
        "binary MRATE pipelined ({FRAME}/frame): {RATINGS} ratings in {pipe_secs:.3}s \
         ({:.0}k ratings/s) — {:.1}x the text client",
        RATINGS as f64 / pipe_secs / 1e3,
        text_secs / pipe_secs
    );

    // pipelined batched reads from one snapshot per frame
    let cols: Vec<u32> = (0..FRAME.min(ncols) as u32).collect();
    let t0 = Instant::now();
    let mut pipe = binary.pipeline();
    for row in 0..16usize {
        pipe.push(&Request::MPredict { row: row % nrows, cols: cols.clone() }).expect("push");
    }
    let preds = pipe.finish().expect("finish");
    let read_secs = t0.elapsed().as_secs_f64();
    let scored: usize = preds
        .iter()
        .map(|r| match r {
            Response::Preds(ps) => ps.len(),
            other => panic!("{other:?}"),
        })
        .sum();
    println!(
        "binary MPREDICT pipelined: {scored} predictions in {read_secs:.3}s \
         ({:.0}k preds/s)",
        scored as f64 / read_secs / 1e3
    );

    // flush through the typed API and read the applied count
    match binary.flush().expect("flush") {
        Response::Ok(OkBody::Flushed { applied }) => {
            println!("FLUSH applied {applied} buffered ratings");
        }
        other => panic!("{other:?}"),
    }

    // one typed stats read; show the protocol counters
    if let Response::Stats(body) = binary.stats().expect("stats") {
        println!("--- server counters ---");
        for line in body.lines() {
            if line.contains("server.") {
                println!("{line}");
            }
        }
    }

    text.shutdown().expect("quit");
    binary.shutdown().expect("bye");
    stop.store(true, Ordering::Relaxed);
    let _ = TcpStream::connect(addr);
    let engine = server_thread.join().unwrap().expect("server");
    println!("\nserver stopped cleanly; final dims {:?}", engine.dims());
}
