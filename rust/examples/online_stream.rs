//! Online-learning scenario (Table 9 protocol): train on the base split,
//! then stream the increment through the bounded-queue orchestrator and
//! compare against full retraining — RMSE must match closely at a
//! fraction of the update time.
//!
//! Run with: `cargo run --release --example online_stream`

use lshmf::coordinator::stream::{Event, StreamConfig, StreamOrchestrator};
use lshmf::data::online::split_online;
use lshmf::data::synth::{generate_triples, SynthConfig};
use lshmf::lsh::{NeighbourSearch, OnlineHashState, SimLsh};
use lshmf::metrics::Registry;
use lshmf::mf::neighbourhood::{train_culsh_logged, CulshConfig};
use lshmf::rng::Rng;
use lshmf::sparse::{Csc, Csr, Triples};
use std::time::Instant;

fn main() {
    let mut rng = Rng::seeded(11);
    let full = generate_triples(&SynthConfig::movielens_like().scaled(0.02), &mut rng);
    let split = split_online(&full, 0.01, 0.01);
    let stats = split.stats(full.nrows(), full.ncols());
    println!(
        "online split (Table 9 shape): M={} N={} |Ω|={}  M̄={} N̄={} |Ω̄|={}",
        stats.m, stats.n, stats.omega, stats.m_bar, stats.n_bar, stats.omega_bar
    );

    // held-out test from the base part
    let n_test = split.base.nnz() / 100;
    let base_entries = split.base.entries().to_vec();
    let (test, train_entries) = base_entries.split_at(n_test);
    let base =
        Triples::from_entries(split.base.nrows(), split.base.ncols(), train_entries.to_vec());

    let lsh = SimLsh::new(2, 12, 8, 2);
    let cfg = CulshConfig { f: 16, k: 8, epochs: 25, beta: 0.02, eval: test.to_vec(), ..Default::default() };

    // --- base training
    let csr = Csr::from_triples(&base);
    let csc = Csc::from_triples(&base);
    let hash_state = OnlineHashState::build(lsh.clone(), &csc);
    let (topk, _) = hash_state.topk(cfg.k, &mut rng);
    let t0 = Instant::now();
    let (model, log) = train_culsh_logged(&csr, topk, &cfg, &mut rng);
    let base_secs = t0.elapsed().as_secs_f64();
    println!("base model: rmse {:.4} in {base_secs:.2}s", log.final_rmse());

    // --- stream the increment through the orchestrator
    let orch = StreamOrchestrator::new(
        model,
        hash_state,
        base.clone(),
        StreamConfig { batch_size: 2048, online_epochs: 5, ..Default::default() },
        cfg.clone(),
        rng.split(2),
        Registry::new(),
    );
    let (tx, rx) = std::sync::mpsc::channel();
    let feeder = std::thread::spawn({
        let increment = split.increment.clone();
        move || {
            for (i, j, r) in increment {
                tx.send(Event::Rate(i, j, r)).unwrap();
            }
            tx.send(Event::Shutdown).unwrap();
        }
    });
    let t1 = Instant::now();
    let orch = lshmf::coordinator::stream::run_channel(orch, rx);
    feeder.join().unwrap();
    let online_secs = t1.elapsed().as_secs_f64();
    let online_rmse = orch.model().rmse(orch.matrix(), test);
    println!(
        "online update: rmse {:.4} in {online_secs:.2}s ({} events)",
        online_rmse, stats.omega_bar
    );

    // --- full retrain comparison
    let combined = {
        let mut t = base.clone();
        t.grow_to(full.nrows(), full.ncols());
        for &(i, j, r) in &split.increment {
            t.push(i as usize, j as usize, r);
        }
        t
    };
    let csr2 = Csr::from_triples(&combined);
    let csc2 = Csc::from_triples(&combined);
    let (topk2, _) = SimLsh::new(2, 12, 8, 2).build(&csc2, cfg.k, &mut rng);
    let t2 = Instant::now();
    let (_, retrain_log) = train_culsh_logged(&csr2, topk2, &cfg, &mut rng);
    let retrain_secs = t2.elapsed().as_secs_f64();
    println!(
        "full retrain: rmse {:.4} in {retrain_secs:.2}s",
        retrain_log.final_rmse()
    );
    println!(
        "=> online Δrmse {:+.5} at {:.1}× less update time",
        online_rmse - retrain_log.final_rmse(),
        retrain_secs / online_secs.max(1e-9)
    );
}
