//! Multi-device scenario (Fig. 5): run MCULSH-MF's block-rotation schedule
//! on real worker threads, validate the Latin-square invariant, and report
//! the virtual-clock speedups that reproduce the paper's multi-GPU scaling
//! shape (1.6× / 2.4× / 3.2× on 2/3/4 devices).
//!
//! Run with: `cargo run --release --example multi_worker`

use lshmf::coordinator::rotation::RotationPlan;
use lshmf::data::synth::{generate, SynthConfig};
use lshmf::lsh::{NeighbourSearch, SimLsh};
use lshmf::mf::neighbourhood::{train_culsh_parallel_logged, CulshConfig};
use lshmf::rng::Rng;

fn main() {
    let mut rng = Rng::seeded(3);
    let ds = generate(&SynthConfig::movielens_like().scaled(0.03), &mut rng);
    let triples = ds.train.to_triples();
    println!(
        "workload: {}x{} with {} ratings",
        ds.nrows(),
        ds.ncols(),
        ds.nnz()
    );

    // --- virtual-clock scaling (the multi-GPU substitution; see DESIGN.md)
    println!("\ndevices  epoch(s)  speedup  imbalance  compute/transfer");
    // calibrate cost-per-nnz from a real single-thread epoch
    let (topk, _) = SimLsh::new(2, 20, 8, 2).build(&ds.train_csc, 16, &mut rng);
    let cfg = CulshConfig { f: 32, k: 16, epochs: 1, ..Default::default() };
    let t0 = std::time::Instant::now();
    let _ = lshmf::mf::neighbourhood::train_culsh_logged(
        &ds.train,
        topk.clone(),
        &cfg,
        &mut rng.split(1),
    );
    let cost_per_nnz = t0.elapsed().as_secs_f64() / ds.nnz() as f64;
    // P100-era NVLink-ish: shipping one F=32 row ≈ a few hundred ns
    let transfer_per_row = cost_per_nnz * 3.0;
    for d in [1usize, 2, 3, 4] {
        let plan = RotationPlan::new(&triples, d);
        plan.validate().expect("schedule must be a Latin square");
        let r = plan.virtual_clock(cost_per_nnz, transfer_per_row, true);
        println!(
            "{:>7}  {:>8.3}  {:>7.2}  {:>9.3}  {:.3}/{:.3}",
            d,
            r.epoch_seconds,
            r.speedup,
            plan.imbalance(),
            r.compute_seconds,
            r.transfer_seconds
        );
    }

    // --- real threaded execution of the same schedule
    println!("\nthreaded MCULSH-MF (correctness path):");
    for threads in [1usize, 2, 4] {
        let cfg = CulshConfig {
            f: 16,
            k: 16,
            epochs: 5,
            beta: 0.02,
            eval: ds.test.clone(),
            ..Default::default()
        };
        let (_, log) = train_culsh_parallel_logged(
            &ds.train,
            topk.clone(),
            &cfg,
            threads,
            &mut Rng::seeded(9),
        );
        println!(
            "  {threads} worker(s): rmse {:.4} in {:.2}s",
            log.final_rmse(),
            log.total_seconds()
        );
    }
    println!("\n(single-core host: wall-clock thread scaling is not expected; the\n virtual clock above is the multi-GPU reproduction vehicle)");
}
