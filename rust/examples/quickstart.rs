//! Quickstart: generate a small MovieLens-like dataset, build simLSH
//! neighbourhoods, train CULSH-MF, and report RMSE — the 60-second tour
//! of the public API.
//!
//! Run with: `cargo run --release --example quickstart`

use lshmf::data::synth::{generate, SynthConfig};
use lshmf::lsh::{NeighbourSearch, SimLsh};
use lshmf::mf::neighbourhood::{train_culsh_logged, CulshConfig};
use lshmf::rng::Rng;

fn main() {
    let mut rng = Rng::seeded(42);

    // 1. A scaled-down MovieLens-shaped dataset (Table 2 calibration).
    let ds = generate(&SynthConfig::movielens_like().scaled(0.03), &mut rng);
    println!(
        "dataset: {} — {}x{}, {} train ratings, {} test",
        ds.name,
        ds.nrows(),
        ds.ncols(),
        ds.nnz(),
        ds.test.len()
    );

    // 2. Top-K neighbourhoods via simLSH (Eq. 3 + p/q amplification) —
    //    the step that replaces the O(N²) GSM.
    let k = 16;
    let (topk, cost) = SimLsh::new(2, 30, 8, 2).build(&ds.train_csc, k, &mut rng);
    println!(
        "simLSH: built {}×{k} neighbour table in {:.3}s ({} KiB auxiliary)",
        topk.n(),
        cost.seconds,
        cost.bytes / 1024
    );

    // 3. Train the nonlinear neighbourhood model (Eq. 1 / Eq. 5).
    // NOTE on hyper-parameters: the paper's Table 5 schedule (β = 0.3)
    // is tuned for full-scale epochs of ~10M updates; at `scale(0.03)` an
    // epoch is ~300× smaller, so we slow the Eq. 7 decay accordingly.
    let cfg = CulshConfig {
        f: 32,
        k,
        epochs: 40,
        beta: 0.02,
        lambda_u: 0.01,
        lambda_v: 0.01,
        lambda_b: 0.01,
        eval: ds.test.clone(),
        ..Default::default()
    };
    let (model, log) = train_culsh_logged(&ds.train, topk, &cfg, &mut rng);

    println!("epoch  seconds   rmse");
    for p in &log.points {
        println!("{:>5}  {:>7.3}  {:.4}", p.epoch, p.seconds, p.rmse);
    }
    println!(
        "final rmse {:.4} | model parameters {:.1} MiB",
        log.final_rmse(),
        model.bytes() as f64 / (1024.0 * 1024.0)
    );
}
