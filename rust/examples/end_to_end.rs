//! END-TO-END DRIVER — exercises the full three-layer system on a real
//! small workload, proving all layers compose:
//!
//! 1. **Data**: a MovieLens-calibrated sparse workload (L3 substrate).
//! 2. **Neighbourhoods**: simLSH Top-K on the L3 path, cross-checked bit
//!    for bit against the **L1 Pallas hash kernel** executed through PJRT.
//! 3. **Training**: biased MF through the **AOT `mf_sgd_step` graph**
//!    (gather → PJRT execute → scatter), CULSH-MF on the native path;
//!    RMSE evaluated through the **`rmse_chunk_step` graph** and verified
//!    against native evaluation.
//! 4. **Serving**: batched PREDICT/TOPN/RATE requests against the TCP
//!    server, reporting latency percentiles and throughput.
//!
//! The headline numbers land in EXPERIMENTS.md §End-to-end.
//!
//! Run with: `make artifacts && cargo run --release --example end_to_end`

use lshmf::coordinator::server::handle_line;
use lshmf::coordinator::stream::{StreamConfig, StreamOrchestrator};
use lshmf::coordinator::Engine;
use lshmf::data::synth::{generate, SynthConfig};
use lshmf::lsh::{NeighbourSearch, OnlineHashState, SimLsh};
use lshmf::metrics::Registry;
use lshmf::mf::neighbourhood::{train_culsh_logged, CulshConfig};
use lshmf::mf::pjrt_trainer::{pjrt_rmse, train_pjrt_sgd_logged, PjrtSgdConfig};
use lshmf::rng::Rng;
use lshmf::runtime::Runtime;
use std::sync::Mutex;
use std::time::Instant;

fn main() {
    let mut rng = Rng::seeded(2024);

    // ---------------------------------------------------------- 1. data
    let ds = generate(&SynthConfig::movielens_like().scaled(0.03), &mut rng);
    println!(
        "[1/4] workload: {} — {}x{} with {} train / {} test ratings",
        ds.name,
        ds.nrows(),
        ds.ncols(),
        ds.nnz(),
        ds.test.len()
    );

    let dir = Runtime::default_dir();
    if !Runtime::available(&dir) {
        eprintln!("artifacts missing — run `make artifacts` first");
        std::process::exit(2);
    }
    let mut rt = Runtime::open(&dir).expect("open PJRT runtime");

    // ------------------------------------------- 2. L1 hash kernel parity
    let lsh = SimLsh::new(2, 20, 8, 2);
    let t0 = Instant::now();
    let hash_state = OnlineHashState::build(lsh.clone(), &ds.train_csc);
    let (topk, _) = hash_state.topk(16, &mut rng);
    let lsh_secs = t0.elapsed().as_secs_f64();

    // cross-check: hash a dense tile through the Pallas kernel artifact
    let (hn, hm, hg) = (rt.manifest.hash_n, rt.manifest.hash_m, rt.manifest.hash_g);
    let mut tile = vec![0f32; hn * hm];
    for j in 0..hn.min(ds.ncols()) {
        for (i, r) in ds.train_csc.col(j) {
            if i < hm {
                tile[j * hm + i] = lsh.weight(r);
            }
        }
    }
    // Φ from the same deterministic row codes the rust hasher uses
    let mut phi = vec![0f32; hm * hg];
    for (i, chunk) in phi.chunks_mut(hg).enumerate() {
        let code = lsh.row_code(i, 0, 0);
        for (g, slot) in chunk.iter_mut().enumerate() {
            *slot = if (code >> g) & 1 == 1 { 1.0 } else { -1.0 };
        }
    }
    let out = rt
        .run_f32("simlsh_hash_block", &[(&tile, &[hn, hm]), (&phi, &[hm, hg])])
        .expect("hash kernel");
    let mut mismatches = 0;
    let checked = hn.min(ds.ncols());
    for j in 0..checked {
        // native accumulator over the same truncated row range
        for g in 0..hg {
            let acc: f32 = (0..hm).map(|i| tile[j * hm + i] * phi[i * hg + g]).sum();
            let want = if acc >= 0.0 { 1.0 } else { 0.0 };
            if out[0][j * hg + g] != want {
                mismatches += 1;
            }
        }
    }
    println!(
        "[2/4] simLSH: {}×16 table in {lsh_secs:.2}s; Pallas hash kernel parity: {}/{} bits exact",
        topk.n(),
        checked * hg - mismatches,
        checked * hg
    );
    assert_eq!(mismatches, 0, "L1 kernel disagrees with L3 hasher");

    // --------------------------------------- 3. training across the stack
    let pjrt_cfg = PjrtSgdConfig {
        epochs: 6,
        alpha: 0.04,
        beta: 0.05,
        lambda_u: 0.01,
        lambda_v: 0.01,
        lambda_b: 0.01,
        eval: ds.test.clone(),
        ..Default::default()
    };
    let t1 = Instant::now();
    let (mf_model, pjrt_log) =
        train_pjrt_sgd_logged(&mut rt, &ds.train, &pjrt_cfg, &mut rng).expect("pjrt train");
    let pjrt_secs = t1.elapsed().as_secs_f64();
    // verify the PJRT evaluation path against native evaluation
    let rmse_native = mf_model.rmse(&ds.test);
    let rmse_pjrt = pjrt_rmse(&mut rt, &mf_model, &ds.test).expect("pjrt rmse");
    println!(
        "[3/4] PJRT-batched MF: rmse {:.4} in {pjrt_secs:.1}s ({} epochs); \
         eval parity native {rmse_native:.5} vs pjrt {rmse_pjrt:.5}",
        pjrt_log.final_rmse(),
        pjrt_cfg.epochs
    );
    assert!((rmse_native - rmse_pjrt).abs() < 1e-3, "evaluation paths disagree");

    let culsh_cfg = CulshConfig {
        f: 32,
        k: 16,
        epochs: 25,
        beta: 0.02,
        lambda_u: 0.01,
        lambda_v: 0.01,
        lambda_b: 0.01,
        eval: ds.test.clone(),
        ..Default::default()
    };
    let t2 = Instant::now();
    let (culsh_model, culsh_log) =
        train_culsh_logged(&ds.train, topk, &culsh_cfg, &mut rng);
    println!(
        "      CULSH-MF (native hot path): rmse {:.4} in {:.1}s",
        culsh_log.final_rmse(),
        t2.elapsed().as_secs_f64()
    );

    // ------------------------------------------------------- 4. serving
    let orch = StreamOrchestrator::new(
        culsh_model,
        hash_state,
        ds.train.to_triples(),
        StreamConfig { batch_size: 256, ..Default::default() },
        culsh_cfg,
        rng.split(5),
        Registry::new(),
    );
    let engine = Mutex::new(Engine::new(orch, (ds.min_value, ds.max_value), Registry::new()));

    let n_requests = 2000;
    let mut latencies = Vec::with_capacity(n_requests);
    let t3 = Instant::now();
    for k in 0..n_requests {
        let line = match k % 20 {
            0 => format!("TOPN {} 10", k % ds.nrows()),
            1..=3 => format!("RATE {} {} 4.0", k % ds.nrows(), (k * 7) % ds.ncols()),
            _ => format!("PREDICT {} {}", k % ds.nrows(), (k * 13) % ds.ncols()),
        };
        let q0 = Instant::now();
        let reply = handle_line(&engine, &line).expect("reply");
        latencies.push(q0.elapsed());
        assert!(!reply.starts_with("ERR"), "{line} -> {reply}");
    }
    let wall = t3.elapsed().as_secs_f64();
    latencies.sort_unstable();
    let pct = |q: f64| latencies[((latencies.len() - 1) as f64 * q) as usize];
    println!(
        "[4/4] served {n_requests} mixed requests in {wall:.2}s \
         ({:.0} req/s) | latency p50 {:?} p95 {:?} p99 {:?}",
        n_requests as f64 / wall,
        pct(0.50),
        pct(0.95),
        pct(0.99)
    );
    println!("\nall layers compose: L1 kernel parity ✔  L2 graph training ✔  L3 serving ✔");
}
