//! Hot-path microbenchmarks feeding EXPERIMENTS.md §Perf:
//!
//! * L3 SGD inner loop (updates/s) at F ∈ {32, 128};
//! * CULSH-MF inner loop (updates/s, includes the K-neighbour scan);
//! * dot-product kernel throughput;
//! * simLSH hashing throughput (columns/s) and GSM build;
//! * conflict-free batch assembly (the PJRT gather path);
//! * flush latency, exact vs relaxed mode at 1 vs 4 bands (the relaxed
//!   epoch must beat exact at 4 bands — asserted);
//! * warm per-row Top-N cache vs the full TOPN re-score (the warm read
//!   must win — asserted);
//! * out-of-order connection dispatch: a `TOPN` issued behind an
//!   in-flight slow `FLUSH` on the same binary connection must come
//!   back first (asserted) — the read never waits on the write lane;
//! * PJRT step latency (mf_sgd_step) when artifacts exist.

use lshmf::bench::exp::BenchEnv;
use lshmf::bench::Bencher;
use lshmf::coordinator::banded::BandedEngine;
use lshmf::coordinator::client::{ClientCodec, LshmfClient};
use lshmf::coordinator::protocol::{
    read_frame, FrameRead, OkBody, Request, Response, MAX_TOPN_ITEMS,
};
use lshmf::coordinator::server;
use lshmf::coordinator::shared::SharedEngine;
use lshmf::coordinator::stream::{FlushMode, StreamConfig, StreamOrchestrator};
use lshmf::coordinator::Engine;
use lshmf::lsh::{NeighbourSearch, OnlineHashState, SimLsh};
use lshmf::metrics::Registry;
use lshmf::mf::neighbourhood::{train_culsh_logged, CulshConfig};
use lshmf::mf::pjrt_trainer::conflict_free_batches;
use lshmf::mf::sgd::{train_sgd_logged, SgdConfig};
use lshmf::rng::Rng;
use lshmf::runtime::{mf_scalars, Runtime};
use lshmf::sparse::{Csc, Csr, Triples};

fn main() {
    let env = BenchEnv::from_env();
    println!("== hot-path microbenchmarks (scale {}) ==", env.scale);
    let mut rng = env.rng();
    let ds = env.dataset("movielens", &mut rng);
    let nnz = ds.nnz();
    let b = Bencher::default();

    // --- L3 SGD epoch
    for f in [32usize, 128] {
        let cfg = SgdConfig { f, epochs: 1, ..env.sgd_config("movielens", &ds) };
        let m = b.run(&format!("sgd epoch F={f}"), || {
            train_sgd_logged(&ds.train, &cfg, &mut Rng::seeded(1))
        });
        println!(
            "{}  |  {:.1}M updates/s",
            m.fmt_line(),
            nnz as f64 / m.p50.as_secs_f64() / 1e6
        );
    }

    // --- CULSH epoch (scan + Eq. 5 full update)
    {
        let (topk, _) = SimLsh::new(2, 20, 8, 2).build(&ds.train_csc, 32, &mut rng);
        let cfg = CulshConfig { epochs: 1, eval: Vec::new(), ..env.culsh_config("movielens", &ds) };
        let m = b.run("culsh epoch F=32 K=32", || {
            train_culsh_logged(&ds.train, topk.clone(), &cfg, &mut Rng::seeded(1))
        });
        println!(
            "{}  |  {:.1}M updates/s",
            m.fmt_line(),
            nnz as f64 / m.p50.as_secs_f64() / 1e6
        );
    }

    // --- dot kernel
    {
        let x: Vec<f32> = (0..128).map(|i| i as f32 * 0.01).collect();
        let y: Vec<f32> = (0..128).map(|i| 1.0 - i as f32 * 0.005).collect();
        let m = b.run("dot f32x128 x1e5", || {
            let mut acc = 0f32;
            for _ in 0..100_000 {
                acc += lshmf::linalg::dot(std::hint::black_box(&x), std::hint::black_box(&y));
            }
            acc
        });
        let flops = 2.0 * 128.0 * 1e5 / m.p50.as_secs_f64();
        println!("{}  |  {:.2} GFLOP/s", m.fmt_line(), flops / 1e9);
    }

    // --- simLSH hashing
    {
        let lsh = SimLsh::new(3, 1, 8, 2);
        let m = b.run("simLSH signatures (1 round, p=3)", || {
            lshmf::lsh::RoundHasher::signatures(&lsh, &ds.train_csc, 0, &mut Rng::seeded(1))
        });
        println!(
            "{}  |  {:.0}k cols/s",
            m.fmt_line(),
            ds.ncols() as f64 / m.p50.as_secs_f64() / 1e3
        );
    }

    // --- conflict-free batching (PJRT gather path)
    {
        let entries = ds.train.to_triples().entries().to_vec();
        let m = b.run("conflict-free batching B=1024", || {
            conflict_free_batches(&entries, 1024)
        });
        println!(
            "{}  |  {:.1}M entries/s",
            m.fmt_line(),
            entries.len() as f64 / m.p50.as_secs_f64() / 1e6
        );
    }

    // --- sharded snapshot publish (bytes cloned per flush, D=4)
    {
        // Fixture sized so the acceptance comparison is honest: a full
        // (model, matrix) clone — what the pre-sharding publish paid on
        // every flush — versus what the sharded publish actually copies
        // when one column band is dirtied.
        let (m, n) = (2048usize, 256usize);
        let mut fix_rng = Rng::seeded(77);
        let mut t = Triples::new(m, n);
        let mut seen = std::collections::HashSet::new();
        while t.nnz() < 40_000 {
            let (i, j) = (fix_rng.below(m), fix_rng.below(n));
            if seen.insert((i, j)) {
                t.push(i, j, 1.0 + fix_rng.f32() * 4.0);
            }
        }
        let csr = Csr::from_triples(&t);
        let csc = Csc::from_triples(&t);
        let hash_state = OnlineHashState::build(SimLsh::new(2, 8, 8, 2), &csc);
        let (topk, _) = hash_state.topk(8, &mut fix_rng);
        let cfg = CulshConfig { f: 32, k: 8, epochs: 1, eval: Vec::new(), ..Default::default() };
        let (model, _) = train_culsh_logged(&csr, topk, &cfg, &mut Rng::seeded(7));
        let metrics = Registry::new();
        let orch = StreamOrchestrator::new(
            model,
            hash_state,
            t,
            StreamConfig { batch_size: usize::MAX >> 1, ..Default::default() },
            cfg,
            Rng::seeded(9),
            metrics.clone(),
        );
        let engine = Engine::new(orch, (1.0, 5.0), metrics.clone());
        let full_bytes = engine.model().bytes() + engine.matrix().bytes();
        let (shared, writer) = SharedEngine::spawn_sharded(engine, 4);
        let band0_cols = n / 4;
        let mk = b.run("sharded publish D=4 (1-band flush)", || {
            // dirty only band 0: re-rate 8 of its columns, then flush
            for c in 0..8u32 {
                shared.rate(
                    c % m as u32,
                    c % band0_cols as u32,
                    2.0 + (c % 3) as f32,
                );
            }
            shared.flush()
        });
        let cloned = metrics.gauge("shared.publish_bytes_cloned").get();
        println!(
            "{}  |  {:.0} bytes cloned vs {} full clone ({:.1}% of baseline)",
            mk.fmt_line(),
            cloned,
            full_bytes,
            100.0 * cloned / full_bytes as f64
        );
        assert!(
            cloned < full_bytes as f64 / 2.0,
            "1-band publish must clone < 1/2 of the full (model, matrix) state: \
             {cloned} vs {full_bytes}"
        );
        writer.join();
    }

    // --- multi-writer ingest throughput (1 vs 4 band writers)
    {
        // Pure ingest routing cost: batch_size is effectively infinite,
        // so the timed section measures the RATE round-trip through the
        // band writers, not flush work. Four client threads each rate
        // into their own column band; with one writer every request
        // serializes on a single queue, with four each band's writer
        // drains its own.
        let (m, n) = (512usize, 256usize);
        let clients = 4usize;
        let per_client = 2_000usize;
        let mut results: Vec<(usize, f64)> = Vec::new();
        for writers in [1usize, 4] {
            let mut fix_rng = Rng::seeded(88);
            let mut t = Triples::new(m, n);
            let mut seen = std::collections::HashSet::new();
            while t.nnz() < 20_000 {
                let (i, j) = (fix_rng.below(m), fix_rng.below(n));
                if seen.insert((i, j)) {
                    t.push(i, j, 1.0 + fix_rng.f32() * 4.0);
                }
            }
            let csr = Csr::from_triples(&t);
            let csc = Csc::from_triples(&t);
            let hash_state = OnlineHashState::build(SimLsh::new(2, 6, 8, 2), &csc);
            let (topk, _) = hash_state.topk(8, &mut fix_rng);
            let cfg = CulshConfig {
                f: 16,
                k: 8,
                epochs: 1,
                eval: Vec::new(),
                ..Default::default()
            };
            let (model, _) = train_culsh_logged(&csr, topk, &cfg, &mut Rng::seeded(8));
            let metrics = Registry::new();
            let orch = StreamOrchestrator::new(
                model,
                hash_state,
                t,
                StreamConfig {
                    batch_size: usize::MAX >> 1,
                    queue_capacity: usize::MAX >> 1,
                    online_epochs: 1,
                    ..Default::default()
                },
                cfg,
                Rng::seeded(9),
                metrics.clone(),
            );
            let engine = Engine::new(orch, (1.0, 5.0), metrics);
            let (banded, handle) = BandedEngine::spawn(engine, writers);
            let mk = b.run(&format!("banded ingest writers={writers} clients=4"), || {
                std::thread::scope(|s| {
                    for c in 0..clients {
                        let banded = banded.clone();
                        s.spawn(move || {
                            let (lo, hi) = lshmf::sparse::band_range(c, n, clients);
                            let width = hi - lo;
                            for k in 0..per_client {
                                // few distinct cells per band so the
                                // final drain flush stays cheap
                                let i = (c * 8 + k % 8) as u32;
                                let j = (lo + k % width.min(64)) as u32;
                                banded.rate(i, j, 2.0 + (k % 3) as f32);
                            }
                        });
                    }
                })
            });
            let rate = (clients * per_client) as f64 / mk.p50.as_secs_f64();
            println!("{}  |  {:.2}M ratings/s", mk.fmt_line(), rate / 1e6);
            results.push((writers, rate));
            handle.join();
        }
        if let [(_, one), (_, four)] = results[..] {
            println!(
                "ingest scaling 4 writers vs 1: {:.2}x ({:.2}M vs {:.2}M ratings/s)",
                four / one,
                four / 1e6,
                one / 1e6
            );
        }
    }

    // --- flush latency: exact vs relaxed mode at 1 vs 4 bands
    {
        // The tentpole measurement: the flush epoch's training core
        // (Top-K re-search + Algorithm-4 updates) used to run on one
        // thread inside the cross-band barrier in every mode; relaxed
        // mode runs it band-parallel under the rotation schedule. Each
        // iteration buffers the same 64-new-rows × 24-ratings workload
        // (all trainable — new-row entries — so the epochs do real
        // work) and times `FLUSH` alone: ingest stays outside the
        // clock, so the number is flush latency, not queue throughput.
        let (m, n) = (1024usize, 256usize);
        let iters = 10usize;
        let mut p50s: Vec<(usize, FlushMode, std::time::Duration)> = Vec::new();
        for (writers, mode) in [
            (1usize, FlushMode::Exact),
            (1, FlushMode::Relaxed),
            (4, FlushMode::Exact),
            (4, FlushMode::Relaxed),
        ] {
            let mut fix_rng = Rng::seeded(111);
            let mut t = Triples::new(m, n);
            let mut seen = std::collections::HashSet::new();
            while t.nnz() < 30_000 {
                let (i, j) = (fix_rng.below(m), fix_rng.below(n));
                if seen.insert((i, j)) {
                    t.push(i, j, 1.0 + fix_rng.f32() * 4.0);
                }
            }
            let csr = Csr::from_triples(&t);
            let csc = Csc::from_triples(&t);
            let hash_state = OnlineHashState::build(SimLsh::new(2, 8, 8, 2), &csc);
            let (topk, _) = hash_state.topk(32, &mut fix_rng);
            let cfg = CulshConfig {
                f: 32,
                k: 32,
                epochs: 1,
                eval: Vec::new(),
                ..Default::default()
            };
            let (model, _) = train_culsh_logged(&csr, topk, &cfg, &mut Rng::seeded(12));
            let orch = StreamOrchestrator::new(
                model,
                hash_state,
                t,
                StreamConfig {
                    batch_size: usize::MAX >> 1,
                    queue_capacity: usize::MAX >> 1,
                    online_epochs: 5,
                    flush_mode: mode,
                    flush_bands: writers,
                    ..Default::default()
                },
                cfg,
                Rng::seeded(13),
                Registry::new(),
            );
            let engine = Engine::new(orch, (1.0, 5.0), Registry::new());
            let (banded, handle) = BandedEngine::spawn(engine, writers);
            let mut samples: Vec<std::time::Duration> = Vec::new();
            let mut next_row = m as u32;
            for iter in 0..iters as u32 {
                let mut events: Vec<(u32, u32, f32)> = Vec::with_capacity(64 * 24);
                for r in 0..64u32 {
                    let i = next_row;
                    next_row += 1;
                    for c in 0..24u32 {
                        // c*11 mod 256 is injective over c < 24, so the
                        // 24 cells of each fresh row are distinct and
                        // every flush applies exactly 1536 entries.
                        let j = (r * 37 + c * 11 + iter * 7) % n as u32;
                        events.push((i, j, 2.0 + ((c + r) % 3) as f32));
                    }
                }
                for chunk in events.chunks(256) {
                    banded.rate_many(chunk);
                }
                let t0 = std::time::Instant::now();
                let applied = banded.flush();
                samples.push(t0.elapsed());
                assert_eq!(applied, events.len(), "every buffered entry must apply");
            }
            samples.sort_unstable();
            let p50 = samples[samples.len() / 2];
            println!(
                "flush latency bands={writers} mode={:<7}  p50={:>10?} min={:>10?} max={:>10?} ({} flushes of 1536 new-row entries)",
                mode.name(),
                p50,
                samples[0],
                samples[samples.len() - 1],
                iters
            );
            p50s.push((writers, mode, p50));
            handle.join();
        }
        let find = |w: usize, mo: FlushMode| {
            p50s
                .iter()
                .find(|(ww, mm, _)| *ww == w && *mm == mo)
                .map(|(_, _, d)| *d)
                .unwrap()
        };
        let (e1, r1) = (find(1, FlushMode::Exact), find(1, FlushMode::Relaxed));
        let (e4, r4) = (find(4, FlushMode::Exact), find(4, FlushMode::Relaxed));
        println!(
            "relaxed vs exact flush p50: 1 band {:.2}x, 4 bands {:.2}x",
            e1.as_secs_f64() / r1.as_secs_f64().max(f64::MIN_POSITIVE),
            e4.as_secs_f64() / r4.as_secs_f64().max(f64::MIN_POSITIVE)
        );
        // The speedup claim needs the cores to exist: with fewer than 4,
        // the 4 rotation lanes time-slice and the barrier overhead can
        // legitimately eat the win, so report without aborting the rest
        // of the bench run.
        let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
        if cores >= 4 {
            assert!(
                r4 < e4,
                "relaxed flush must beat exact at 4 bands on {cores} cores \
                 ({r4:?} vs {e4:?})"
            );
        } else if r4 >= e4 {
            println!(
                "WARNING: relaxed did not beat exact at 4 bands ({r4:?} vs {e4:?}) — \
                 only {cores} core(s) available, speedup assertion skipped"
            );
        }
    }

    // --- wire codecs: pipelined binary MRATE/MPREDICT vs
    //     one-verb-per-round-trip text, same workload, same server
    {
        // The transfer-format experiment (cuMF's lesson applied to the
        // serving path): the same 2048-rating / 2048-prediction workload
        // against one auto-codec server, first as a text client paying a
        // full round-trip per verb, then as a binary client shipping
        // 256-element MRATE/MPREDICT frames with all frames in flight.
        let (m, n) = (512usize, 256usize);
        let mut fix_rng = Rng::seeded(99);
        let mut t = Triples::new(m, n);
        let mut seen = std::collections::HashSet::new();
        while t.nnz() < 20_000 {
            let (i, j) = (fix_rng.below(m), fix_rng.below(n));
            if seen.insert((i, j)) {
                t.push(i, j, 1.0 + fix_rng.f32() * 4.0);
            }
        }
        let csr = Csr::from_triples(&t);
        let csc = Csc::from_triples(&t);
        let hash_state = OnlineHashState::build(SimLsh::new(2, 6, 8, 2), &csc);
        let (topk, _) = hash_state.topk(8, &mut fix_rng);
        let cfg = CulshConfig { f: 16, k: 8, epochs: 1, eval: Vec::new(), ..Default::default() };
        let (model, _) = train_culsh_logged(&csr, topk, &cfg, &mut Rng::seeded(8));
        let orch = StreamOrchestrator::new(
            model,
            hash_state,
            t,
            // ingest-only in the timed loops: no flush noise
            StreamConfig {
                batch_size: usize::MAX >> 1,
                queue_capacity: usize::MAX >> 1,
                online_epochs: 1,
                ..Default::default()
            },
            cfg,
            Rng::seeded(9),
            Registry::new(),
        );
        let engine = Engine::new(orch, (1.0, 5.0), Registry::new());
        let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let stop = std::sync::Arc::new(std::sync::atomic::AtomicBool::new(false));
        let server_thread = {
            let stop = stop.clone();
            std::thread::spawn(move || server::serve(engine, listener, stop, 2).unwrap())
        };

        let events: Vec<(u32, u32, f32)> = (0..2048u32)
            .map(|k| (k / 32, k % 32, 2.0 + (k % 3) as f32))
            .collect();
        let frame = 256usize;

        let mut text = LshmfClient::connect(addr, ClientCodec::Text).unwrap();
        let m_text = b.run("text RATE x2048 (1 verb/round-trip)", || {
            for &(i, j, r) in &events {
                text.rate(i, j, r).unwrap();
            }
        });
        let text_ingest = events.len() as f64 / m_text.p50.as_secs_f64();
        println!("{}  |  {:.2}M ratings/s", m_text.fmt_line(), text_ingest / 1e6);

        let mut binary = LshmfClient::connect(addr, ClientCodec::Binary).unwrap();
        let m_bin = b.run("binary MRATE x2048 (256/frame, pipelined)", || {
            let mut pipe = binary.pipeline();
            for chunk in events.chunks(frame) {
                pipe.push(&Request::MRate { ratings: chunk.to_vec() }).unwrap();
            }
            pipe.finish().unwrap()
        });
        let bin_ingest = events.len() as f64 / m_bin.p50.as_secs_f64();
        println!("{}  |  {:.2}M ratings/s", m_bin.fmt_line(), bin_ingest / 1e6);

        let m_text_read = b.run("text PREDICT x2048 (1 verb/round-trip)", || {
            for k in 0..2048usize {
                text.predict(k % m, k % n).unwrap();
            }
        });
        let text_read = 2048.0 / m_text_read.p50.as_secs_f64();
        println!("{}  |  {:.2}M preds/s", m_text_read.fmt_line(), text_read / 1e6);

        let cols: Vec<u32> = (0..frame as u32).collect();
        let m_bin_read = b.run("binary MPREDICT x2048 (256/frame, pipelined)", || {
            let mut pipe = binary.pipeline();
            for row in 0..(2048 / frame) {
                pipe.push(&Request::MPredict { row, cols: cols.clone() }).unwrap();
            }
            pipe.finish().unwrap()
        });
        let bin_read = 2048.0 / m_bin_read.p50.as_secs_f64();
        println!("{}  |  {:.2}M preds/s", m_bin_read.fmt_line(), bin_read / 1e6);

        println!(
            "pipelined binary vs per-verb text: ingest {:.1}x, read {:.1}x",
            bin_ingest / text_ingest,
            bin_read / text_read
        );
        assert!(
            bin_ingest > text_ingest,
            "pipelined binary MRATE must beat per-verb text RATE \
             ({bin_ingest:.0} vs {text_ingest:.0} ratings/s)"
        );
        assert!(
            bin_read > text_read,
            "pipelined binary MPREDICT must beat per-verb text PREDICT \
             ({bin_read:.0} vs {text_read:.0} preds/s)"
        );

        text.shutdown().unwrap();
        binary.shutdown().unwrap();
        stop.store(true, std::sync::atomic::Ordering::Relaxed);
        let _ = std::net::TcpStream::connect(addr);
        server_thread.join().unwrap();
    }

    // --- TOPN: warm per-row cache vs the full re-score
    {
        // The read-path tentpole measurement. The baseline is a request
        // above MAX_TOPN_ITEMS, which bypasses the cache and re-scores
        // every unrated column of the row — exactly what every TOPN
        // paid before the per-row cache existed (scoring dominates; the
        // selection depth is noise). The warm loop re-reads rows whose
        // band lists are already cached at the current version, so each
        // reply is a k-way merge of cached lists.
        let (m, n) = (512usize, 256usize);
        let mut fix_rng = Rng::seeded(66);
        let mut t = Triples::new(m, n);
        let mut seen = std::collections::HashSet::new();
        while t.nnz() < 20_000 {
            let (i, j) = (fix_rng.below(m), fix_rng.below(n));
            if seen.insert((i, j)) {
                t.push(i, j, 1.0 + fix_rng.f32() * 4.0);
            }
        }
        let csr = Csr::from_triples(&t);
        let csc = Csc::from_triples(&t);
        let hash_state = OnlineHashState::build(SimLsh::new(2, 6, 8, 2), &csc);
        let (topk, _) = hash_state.topk(8, &mut fix_rng);
        let cfg = CulshConfig { f: 16, k: 8, epochs: 1, eval: Vec::new(), ..Default::default() };
        let (model, _) = train_culsh_logged(&csr, topk, &cfg, &mut Rng::seeded(6));
        let orch = StreamOrchestrator::new(
            model,
            hash_state,
            t,
            StreamConfig { batch_size: usize::MAX >> 1, ..Default::default() },
            cfg,
            Rng::seeded(7),
            Registry::new(),
        );
        let engine = Engine::new(orch, (1.0, 5.0), Registry::new());
        let (banded, handle) = BandedEngine::spawn(engine, 4);
        let rows = 256usize;
        let n_items = 10usize;
        for row in 0..rows {
            std::hint::black_box(banded.top_n(row, n_items));
        }
        let m_warm = b.run("TOPN n=10 x256 rows (warm cache)", || {
            for row in 0..rows {
                std::hint::black_box(banded.top_n(row, n_items));
            }
        });
        let m_full = b.run("TOPN x256 rows (full re-score)", || {
            for row in 0..rows {
                std::hint::black_box(banded.top_n(row, MAX_TOPN_ITEMS + 1));
            }
        });
        let (hits, misses, partial) = banded.cache().counts();
        println!(
            "warm-cache TOPN vs full re-score: {:.1}x (cache hits {hits} misses {misses} \
             partial {partial})",
            m_full.p50.as_secs_f64() / m_warm.p50.as_secs_f64().max(f64::MIN_POSITIVE)
        );
        assert!(hits > 0, "the warm loop must actually hit the cache");
        assert!(
            m_warm.p50 < m_full.p50,
            "warm-cache TOPN must beat the full re-score ({:?} vs {:?})",
            m_warm.p50,
            m_full.p50
        );
        handle.join();
    }

    // --- out-of-order dispatch: TOPN behind an in-flight slow FLUSH
    {
        // The connection-dispatch tentpole measurement: buffer a heavy
        // fresh-row batch (the flush-latency recipe — 64 new rows × 24
        // ratings, 5 online epochs, so the flush runs for milliseconds),
        // then send FLUSH immediately followed by TOPN on the SAME
        // binary connection. FLUSH runs on the connection's ordered
        // write lane; TOPN dispatches to a read worker and scores the
        // still-published snapshot lock-free, so its reply must arrive
        // first — the read does not wait out the write.
        let (m, n) = (1024usize, 256usize);
        let mut fix_rng = Rng::seeded(112);
        let mut t = Triples::new(m, n);
        let mut seen = std::collections::HashSet::new();
        while t.nnz() < 30_000 {
            let (i, j) = (fix_rng.below(m), fix_rng.below(n));
            if seen.insert((i, j)) {
                t.push(i, j, 1.0 + fix_rng.f32() * 4.0);
            }
        }
        let csr = Csr::from_triples(&t);
        let csc = Csc::from_triples(&t);
        let hash_state = OnlineHashState::build(SimLsh::new(2, 8, 8, 2), &csc);
        let (topk, _) = hash_state.topk(32, &mut fix_rng);
        let cfg =
            CulshConfig { f: 32, k: 32, epochs: 1, eval: Vec::new(), ..Default::default() };
        let (model, _) = train_culsh_logged(&csr, topk, &cfg, &mut Rng::seeded(14));
        let orch = StreamOrchestrator::new(
            model,
            hash_state,
            t,
            StreamConfig {
                batch_size: usize::MAX >> 1,
                queue_capacity: usize::MAX >> 1,
                online_epochs: 5,
                ..Default::default()
            },
            cfg,
            Rng::seeded(15),
            Registry::new(),
        );
        let engine = Engine::new(orch, (1.0, 5.0), Registry::new());
        let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let stop = std::sync::Arc::new(std::sync::atomic::AtomicBool::new(false));
        let server_thread = {
            let stop = stop.clone();
            std::thread::spawn(move || server::serve_banded(engine, listener, stop, 2, 4).unwrap())
        };

        let mut stream = std::net::TcpStream::connect(addr).unwrap();
        stream.set_nodelay(true).ok();
        let mut events: Vec<(u32, u32, f32)> = Vec::with_capacity(64 * 24);
        for r in 0..64u32 {
            for c in 0..24u32 {
                let j = (r * 37 + c * 11) % n as u32;
                events.push((m as u32 + r, j, 2.0 + ((c + r) % 3) as f32));
            }
        }
        use std::io::Write as _;
        for (seq, chunk) in events.chunks(256).enumerate() {
            let req = Request::MRate { ratings: chunk.to_vec() };
            stream.write_all(&req.encode_frame(seq as u32)).unwrap();
            let FrameRead::Frame(ack) = read_frame(&mut stream).unwrap() else {
                panic!("expected the MRATE ack");
            };
            assert!(matches!(
                Response::decode_frame(&ack),
                Ok(Response::Ok(OkBody::Buffered))
            ));
        }

        let t0 = std::time::Instant::now();
        stream.write_all(&Request::Flush.encode_frame(100)).unwrap();
        stream
            .write_all(&Request::TopN { row: 0, n: 10 }.encode_frame(101))
            .unwrap();
        let mut arrivals: Vec<(u32, std::time::Duration)> = Vec::new();
        while arrivals.len() < 2 {
            let FrameRead::Frame(f) = read_frame(&mut stream).unwrap() else {
                panic!("connection closed mid-race");
            };
            let at = t0.elapsed();
            match Response::decode_frame(&f).unwrap() {
                Response::TopN(items) => {
                    assert_eq!(f.seq, 101);
                    assert!(!items.is_empty(), "row 0 must have unrated columns");
                }
                Response::Ok(OkBody::Flushed { applied }) => {
                    assert_eq!(f.seq, 100);
                    assert_eq!(applied as usize, events.len());
                }
                other => panic!("unexpected reply in the race: {other:?}"),
            }
            arrivals.push((f.seq, at));
        }
        let lat = |seq: u32| arrivals.iter().find(|(s, _)| *s == seq).unwrap().1;
        println!(
            "TOPN behind in-flight FLUSH (same binary conn): topn at {:?}, flush at {:?} \
             (reply order {:?})",
            lat(101),
            lat(100),
            arrivals.iter().map(|(s, _)| *s).collect::<Vec<_>>()
        );
        assert_eq!(
            arrivals[0].0, 101,
            "TOPN must overtake the in-flight FLUSH on an out-of-order connection"
        );

        stream.write_all(&Request::Shutdown.encode_frame(200)).unwrap();
        let FrameRead::Frame(bye) = read_frame(&mut stream).unwrap() else {
            panic!("expected BYE");
        };
        assert!(matches!(Response::decode_frame(&bye), Ok(Response::Bye)));
        drop(stream);
        stop.store(true, std::sync::atomic::Ordering::Relaxed);
        let _ = std::net::TcpStream::connect(addr);
        server_thread.join().unwrap();
    }

    // --- PJRT step latency
    let dir = Runtime::default_dir();
    if Runtime::available(&dir) {
        let mut rt = Runtime::open(&dir).expect("runtime");
        let (bsz, f) = (rt.manifest.batch, rt.manifest.f);
        let scal = mf_scalars(3.0, 0.01, 0.01, 0.01, 0.01);
        let r = vec![3.5f32; bsz];
        let bi = vec![0.1f32; bsz];
        let bj = vec![0.1f32; bsz];
        let u = vec![0.05f32; bsz * f];
        let v = vec![0.05f32; bsz * f];
        let m = b.run("pjrt mf_sgd_step B=1024 F=32", || {
            rt.run_f32(
                "mf_sgd_step",
                &[
                    (&scal, &[5]),
                    (&r, &[bsz]),
                    (&bi, &[bsz]),
                    (&bj, &[bsz]),
                    (&u, &[bsz, f]),
                    (&v, &[bsz, f]),
                ],
            )
            .unwrap()
        });
        println!(
            "{}  |  {:.2}M updates/s through PJRT",
            m.fmt_line(),
            bsz as f64 / m.p50.as_secs_f64() / 1e6
        );
    } else {
        println!("(artifacts missing — PJRT step latency skipped)");
    }
}
