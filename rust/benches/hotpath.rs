//! Hot-path microbenchmarks feeding EXPERIMENTS.md §Perf:
//!
//! * L3 SGD inner loop (updates/s) at F ∈ {32, 128};
//! * CULSH-MF inner loop (updates/s, includes the K-neighbour scan);
//! * dot-product kernel throughput;
//! * simLSH hashing throughput (columns/s) and GSM build;
//! * conflict-free batch assembly (the PJRT gather path);
//! * PJRT step latency (mf_sgd_step) when artifacts exist.

use lshmf::bench::exp::BenchEnv;
use lshmf::bench::Bencher;
use lshmf::lsh::{NeighbourSearch, SimLsh};
use lshmf::mf::neighbourhood::{train_culsh_logged, CulshConfig};
use lshmf::mf::pjrt_trainer::conflict_free_batches;
use lshmf::mf::sgd::{train_sgd_logged, SgdConfig};
use lshmf::rng::Rng;
use lshmf::runtime::{mf_scalars, Runtime};

fn main() {
    let env = BenchEnv::from_env();
    println!("== hot-path microbenchmarks (scale {}) ==", env.scale);
    let mut rng = env.rng();
    let ds = env.dataset("movielens", &mut rng);
    let nnz = ds.nnz();
    let b = Bencher::default();

    // --- L3 SGD epoch
    for f in [32usize, 128] {
        let cfg = SgdConfig { f, epochs: 1, ..env.sgd_config("movielens", &ds) };
        let m = b.run(&format!("sgd epoch F={f}"), || {
            train_sgd_logged(&ds.train, &cfg, &mut Rng::seeded(1))
        });
        println!(
            "{}  |  {:.1}M updates/s",
            m.fmt_line(),
            nnz as f64 / m.p50.as_secs_f64() / 1e6
        );
    }

    // --- CULSH epoch (scan + Eq. 5 full update)
    {
        let (topk, _) = SimLsh::new(2, 20, 8, 2).build(&ds.train_csc, 32, &mut rng);
        let cfg = CulshConfig { epochs: 1, eval: Vec::new(), ..env.culsh_config("movielens", &ds) };
        let m = b.run("culsh epoch F=32 K=32", || {
            train_culsh_logged(&ds.train, topk.clone(), &cfg, &mut Rng::seeded(1))
        });
        println!(
            "{}  |  {:.1}M updates/s",
            m.fmt_line(),
            nnz as f64 / m.p50.as_secs_f64() / 1e6
        );
    }

    // --- dot kernel
    {
        let x: Vec<f32> = (0..128).map(|i| i as f32 * 0.01).collect();
        let y: Vec<f32> = (0..128).map(|i| 1.0 - i as f32 * 0.005).collect();
        let m = b.run("dot f32x128 x1e5", || {
            let mut acc = 0f32;
            for _ in 0..100_000 {
                acc += lshmf::linalg::dot(std::hint::black_box(&x), std::hint::black_box(&y));
            }
            acc
        });
        let flops = 2.0 * 128.0 * 1e5 / m.p50.as_secs_f64();
        println!("{}  |  {:.2} GFLOP/s", m.fmt_line(), flops / 1e9);
    }

    // --- simLSH hashing
    {
        let lsh = SimLsh::new(3, 1, 8, 2);
        let m = b.run("simLSH signatures (1 round, p=3)", || {
            lshmf::lsh::RoundHasher::signatures(&lsh, &ds.train_csc, 0, &mut Rng::seeded(1))
        });
        println!(
            "{}  |  {:.0}k cols/s",
            m.fmt_line(),
            ds.ncols() as f64 / m.p50.as_secs_f64() / 1e3
        );
    }

    // --- conflict-free batching (PJRT gather path)
    {
        let entries = ds.train.to_triples().entries().to_vec();
        let m = b.run("conflict-free batching B=1024", || {
            conflict_free_batches(&entries, 1024)
        });
        println!(
            "{}  |  {:.1}M entries/s",
            m.fmt_line(),
            entries.len() as f64 / m.p50.as_secs_f64() / 1e6
        );
    }

    // --- PJRT step latency
    let dir = Runtime::default_dir();
    if Runtime::available(&dir) {
        let mut rt = Runtime::open(&dir).expect("runtime");
        let (bsz, f) = (rt.manifest.batch, rt.manifest.f);
        let scal = mf_scalars(3.0, 0.01, 0.01, 0.01, 0.01);
        let r = vec![3.5f32; bsz];
        let bi = vec![0.1f32; bsz];
        let bj = vec![0.1f32; bsz];
        let u = vec![0.05f32; bsz * f];
        let v = vec![0.05f32; bsz * f];
        let m = b.run("pjrt mf_sgd_step B=1024 F=32", || {
            rt.run_f32(
                "mf_sgd_step",
                &[
                    (&scal, &[5]),
                    (&r, &[bsz]),
                    (&bi, &[bsz]),
                    (&bj, &[bsz]),
                    (&u, &[bsz, f]),
                    (&v, &[bsz, f]),
                ],
            )
            .unwrap()
        });
        println!(
            "{}  |  {:.2}M updates/s through PJRT",
            m.fmt_line(),
            bsz as f64 / m.p50.as_secs_f64() / 1e6
        );
    } else {
        println!("(artifacts missing — PJRT step latency skipped)");
    }
}
