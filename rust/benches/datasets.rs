//! Table 2 reproduction: generated dataset statistics vs the paper's.

use lshmf::bench::exp::BenchEnv;
use lshmf::bench::Table;

fn main() {
    let env = BenchEnv::from_env();
    println!("== Table 2: datasets (scale {}) ==", env.scale);
    let mut table = Table::new(&[
        "dataset", "M", "N", "|Omega|", "test", "min", "max", "paper M", "paper N", "paper |Omega|",
    ]);
    let paper = [
        ("netflix", 480_189usize, 17_770usize, 99_072_112usize),
        ("movielens", 69_878, 10_677, 9_900_054),
        ("yahoo", 586_250, 12_658, 91_970_212),
    ];
    for (name, pm, pn, pnnz) in paper {
        let mut rng = env.rng();
        let ds = env.dataset(name, &mut rng);
        table.row(&[
            name.into(),
            ds.nrows().to_string(),
            ds.ncols().to_string(),
            ds.nnz().to_string(),
            ds.test.len().to_string(),
            format!("{}", ds.min_value),
            format!("{}", ds.max_value),
            pm.to_string(),
            pn.to_string(),
            pnnz.to_string(),
        ]);
    }
    table.print();
    println!("(generated sizes = paper sizes x scale; nnz x scale^1.5 - see data::synth)");
}
