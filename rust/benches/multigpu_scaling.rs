//! §5.3 multi-GPU reproduction: MCULSH-MF speedups on D ∈ {2, 3, 4}
//! devices via the Fig. 5 rotation schedule.
//!
//! Paper: {1.6X, 2.4X, 3.2X}. On this single-core host the reproduction
//! vehicle is the virtual clock (compute ∝ nnz, transfer ∝ U-band bytes,
//! overlap enabled); the threaded path validates schedule correctness.

use lshmf::bench::exp::BenchEnv;
use lshmf::bench::Table;
use lshmf::coordinator::rotation::RotationPlan;
use lshmf::lsh::{NeighbourSearch, SimLsh};
use lshmf::mf::neighbourhood::train_culsh_parallel_logged;
use lshmf::rng::Rng;

fn main() {
    let env = BenchEnv::from_env();
    println!("== multi-device scaling (movielens, scale {}) ==", env.scale);
    let mut rng = env.rng();
    let ds = env.dataset("movielens", &mut rng);
    let triples = ds.train.to_triples();

    // calibrate the cost model from a real 1-thread epoch
    let psi = env.psi_power("movielens");
    let (topk, _) = SimLsh::new(2, 30, 8, psi).build(&ds.train_csc, 32, &mut rng);
    let mut cfg = env.culsh_config("movielens", &ds);
    cfg.epochs = 1;
    cfg.eval.clear();
    let t0 = std::time::Instant::now();
    let _ = lshmf::mf::neighbourhood::train_culsh_logged(
        &ds.train,
        topk.clone(),
        &cfg,
        &mut rng.split(1),
    );
    let cost_per_nnz = t0.elapsed().as_secs_f64() / ds.nnz() as f64;
    // transfer tuned so D=2 lands near the paper's 1.6X at full overlap:
    // the paper's deficit from ideal (2.0 → 1.6) comes from transfer +
    // imbalance; one U row of F=32 floats over NVLink-ish ≈ 6 nnz-times.
    let transfer_per_row = cost_per_nnz * 6.0;

    let mut table = Table::new(&[
        "devices", "epoch secs", "speedup", "paper", "imbalance", "threaded rmse",
    ]);
    let paper = ["1.0X", "1.6X", "2.4X", "3.2X"];
    for (di, d) in [1usize, 2, 3, 4].into_iter().enumerate() {
        let plan = RotationPlan::new(&triples, d);
        plan.validate().expect("latin square");
        let vc = plan.virtual_clock(cost_per_nnz, transfer_per_row, true);
        // threaded correctness run (short)
        let mut tcfg = env.culsh_config("movielens", &ds);
        tcfg.epochs = (env.epochs / 3).max(3);
        let (_, log) = train_culsh_parallel_logged(
            &ds.train,
            topk.clone(),
            &tcfg,
            d,
            &mut Rng::seeded(env.seed),
        );
        table.row(&[
            d.to_string(),
            format!("{:.4}", vc.epoch_seconds),
            format!("{:.2}X", vc.speedup),
            paper[di].into(),
            format!("{:.3}", plan.imbalance()),
            format!("{:.4}", log.final_rmse()),
        ]);
    }
    table.print();
    println!("(virtual clock: compute ∝ nnz, transfer ∝ band rows, overlapped)");
}
