//! Table 6 reproduction (MovieLens, F=K=32): running time of
//!
//! * **Serial** — GSM-based Top-K neighbourhood MF, single thread
//!   (construction + training);
//! * **LSH-MF** — the same model with simLSH neighbourhoods, single
//!   thread;
//! * **CULSH-MF** — simLSH neighbourhoods + the parallel trainer.
//!
//! Paper: 782.64s / 17.66s (44.3×) / 0.09s (196×, on a P100). Expected
//! shape here: the GSM construction dominates "Serial"; simLSH removes
//! it; parallel training shaves the rest (bounded by the single core).

use lshmf::bench::exp::BenchEnv;
use lshmf::bench::Table;
use lshmf::gsm::Gsm;
use lshmf::lsh::{NeighbourSearch, SimLsh};
use lshmf::mf::neighbourhood::{train_culsh_logged, train_culsh_parallel_logged};
use lshmf::rng::Rng;
use std::time::Instant;

fn main() {
    let env = BenchEnv::from_env();
    println!("== Table 6: Serial vs LSH-MF vs CULSH-MF (movielens, scale {}) ==", env.scale);
    let mut rng = env.rng();
    let ds = env.dataset("movielens", &mut rng);
    let cfg = env.culsh_config("movielens", &ds);
    let psi = env.psi_power("movielens");

    let mut table = Table::new(&["algorithm", "neighbour secs", "train secs", "total", "rmse", "speedup"]);

    // Serial: exact GSM + serial training
    let t0 = Instant::now();
    let (gsm_topk, gsm_cost) = Gsm::new(100.0).build(&ds.train_csc, cfg.k, &mut Rng::seeded(1));
    let (_, gsm_log) = train_culsh_logged(&ds.train, gsm_topk, &cfg, &mut Rng::seeded(2));
    let serial_total = t0.elapsed().as_secs_f64();

    // LSH-MF: simLSH + serial training
    let t1 = Instant::now();
    let (lsh_topk, lsh_cost) =
        SimLsh::new(3, 30, 8, psi).build(&ds.train_csc, cfg.k, &mut Rng::seeded(1));
    let (_, lsh_log) = train_culsh_logged(&ds.train, lsh_topk.clone(), &cfg, &mut Rng::seeded(2));
    let lshmf_total = t1.elapsed().as_secs_f64();

    // CULSH-MF: simLSH + parallel training
    let t2 = Instant::now();
    let (_, culsh_log) =
        train_culsh_parallel_logged(&ds.train, lsh_topk, &cfg, 4, &mut Rng::seeded(2));
    let culsh_total = t2.elapsed().as_secs_f64() + lsh_cost.seconds;

    for (name, nsecs, log, total) in [
        ("Serial (GSM)", gsm_cost.seconds, &gsm_log, serial_total),
        ("LSH-MF", lsh_cost.seconds, &lsh_log, lshmf_total),
        ("CULSH-MF", lsh_cost.seconds, &culsh_log, culsh_total),
    ] {
        table.row(&[
            name.into(),
            format!("{:.3}", nsecs),
            format!("{:.3}", log.total_seconds()),
            format!("{:.3}", total),
            format!("{:.4}", log.final_rmse()),
            format!("{:.1}X", serial_total / total.max(1e-9)),
        ]);
    }
    table.print();
    println!("(paper: 782.64 / 17.66 / 0.09 seconds — serial GSM construction dominates)");
}
