//! Fig. 9 reproduction: CULSH-MF RMSE over the (F, K) grid, plus the
//! CUSGD++ (no-neighbourhood) column. The paper's finding: increasing K
//! reduces RMSE more than increasing F.

use lshmf::bench::exp::BenchEnv;
use lshmf::bench::{csv_dump, Table};
use lshmf::lsh::{NeighbourSearch, SimLsh};
use lshmf::mf::neighbourhood::{train_culsh_logged, CulshConfig};
use lshmf::mf::parallel::train_parallel_sgd_logged;
use lshmf::mf::sgd::SgdConfig;
use lshmf::rng::Rng;

fn main() {
    let env = BenchEnv::from_env();
    println!("== Fig. 9: (F, K) sweep (movielens, scale {}) ==", env.scale);
    let mut rng = env.rng();
    let ds = env.dataset("movielens", &mut rng);
    let base_cfg = env.culsh_config("movielens", &ds);
    let psi = env.psi_power("movielens");

    let fs = [32usize, 64, 96, 128];
    let ks = [32usize, 64, 96, 128];
    let mut table = Table::new(&["F \\ K", "no-nbhd (CUSGD++)", "32", "64", "96", "128"]);
    let mut rows = Vec::new();
    for f in fs {
        let mut row = vec![f.to_string()];
        // CUSGD++ column (no neighbourhood)
        let sgd_cfg = SgdConfig { f, ..env.sgd_config("movielens", &ds) };
        let (_, plain) =
            train_parallel_sgd_logged(&ds.train, &sgd_cfg, 2, &mut Rng::seeded(env.seed));
        row.push(format!("{:.4}", plain.best_rmse()));
        rows.push(vec![f.to_string(), "0".into(), format!("{:.6}", plain.best_rmse())]);
        for k in ks {
            let (topk, _) =
                SimLsh::new(2, 60, 8, psi).build(&ds.train_csc, k, &mut Rng::seeded(env.seed));
            let cfg = CulshConfig { f, k, ..base_cfg.clone() };
            let (_, log) =
                train_culsh_logged(&ds.train, topk, &cfg, &mut Rng::seeded(env.seed ^ 1));
            row.push(format!("{:.4}", log.best_rmse()));
            rows.push(vec![f.to_string(), k.to_string(), format!("{:.6}", log.best_rmse())]);
        }
        table.row(&row);
    }
    table.print();
    csv_dump("fig9_fk_sweep", &["f", "k", "rmse"], &rows).ok();
    println!("(paper shape: K matters more than F; any K > 0 beats the no-neighbourhood column)");
}
