//! Table 10 reproduction: time to reach a baseline HR@10 for the NCF
//! family (GMF / MLP / NeuMF, trained through their AOT PJRT graphs)
//! versus CULSH-MF with a cross-entropy-style implicit objective.
//!
//! Paper (MovieLens-1m HR 0.65, Pinterest HR 0.85):
//! GMF 219.6s / MLP 940.4s / NeuMF 308.5s / CULSH-MF 0.034s.
//! Expected shape: CULSH-MF reaches comparable HR in orders of magnitude
//! less time; the neural models eventually match it.

use lshmf::bench::Table;
use lshmf::data::implicit::{generate_implicit, hit_ratio_at, ImplicitConfig};
use lshmf::lsh::{NeighbourSearch, SimLsh};
use lshmf::mf::neighbourhood::{train_culsh_logged, CulshConfig};
use lshmf::rng::Rng;
use lshmf::runtime::Runtime;
use lshmf::sparse::{Csc, Csr};
use std::time::Instant;

/// Train one neural model through its PJRT step graph until `target_hr`
/// or the epoch budget; returns (seconds, best HR).
#[allow(clippy::too_many_arguments)]
fn train_neural(
    rt: &mut Runtime,
    kind: &str,
    ds: &lshmf::data::implicit::ImplicitDataset,
    target_hr: f64,
    max_rounds: usize,
    rng: &mut Rng,
) -> (f64, f64) {
    let meta = rt.manifest.neural.clone();
    let spec = rt.manifest.graphs[&format!("{kind}_step")].params.clone();
    let n = spec.len();
    let mut params: Vec<Vec<f32>> = spec
        .iter()
        .map(|(_, shape)| {
            let len: usize = shape.iter().product();
            (0..len).map(|_| rng.normal_f32(0.0, 0.3)).collect()
        })
        .collect();
    let mut m_state: Vec<Vec<f32>> = params.iter().map(|p| vec![0.0; p.len()]).collect();
    let mut v_state: Vec<Vec<f32>> = params.iter().map(|p| vec![0.0; p.len()]).collect();
    let mut t_step = 0i32;
    let bsz = meta.batch;
    let positives: Vec<(u32, u32)> = ds
        .train
        .entries()
        .iter()
        .map(|&(u, i, _)| (u, i))
        .collect();
    let mut best_hr = 0.0f64;
    let mut elapsed = 0.0;
    let score_name = format!("{kind}_score");
    for _round in 0..max_rounds {
        let t0 = Instant::now();
        // one "round" = 40 steps with 50% sampled negatives
        for _ in 0..40 {
            let mut users = vec![0i32; bsz];
            let mut items = vec![0i32; bsz];
            let mut labels = vec![0f32; bsz];
            for s in 0..bsz {
                if rng.chance(0.5) {
                    let &(u, i) = &positives[rng.below(positives.len())];
                    users[s] = u as i32;
                    items[s] = i as i32;
                    labels[s] = 1.0;
                } else {
                    users[s] = rng.below(ds.n_users) as i32;
                    items[s] = rng.below(ds.n_items) as i32;
                    labels[s] = 0.0;
                }
            }
            t_step += 1;
            let t = [t_step as f32];
            let mut lits = vec![
                Runtime::lit_i32(&users, &[bsz]).unwrap(),
                Runtime::lit_i32(&items, &[bsz]).unwrap(),
                Runtime::lit_f32(&labels, &[bsz]).unwrap(),
                Runtime::lit_f32(&t, &[1]).unwrap(),
            ];
            for bank in [&params, &m_state, &v_state] {
                for (p, (_, shape)) in bank.iter().zip(&spec) {
                    lits.push(Runtime::lit_f32(p, shape).unwrap());
                }
            }
            let out = rt.run_literals(&format!("{kind}_step"), lits).unwrap();
            for (dst, src) in params.iter_mut().zip(&out[..n]) {
                dst.copy_from_slice(src);
            }
            for (dst, src) in m_state.iter_mut().zip(&out[n..2 * n]) {
                dst.copy_from_slice(src);
            }
            for (dst, src) in v_state.iter_mut().zip(&out[2 * n..3 * n]) {
                dst.copy_from_slice(src);
            }
        }
        elapsed += t0.elapsed().as_secs_f64();
        // score via the eval graph, batched
        let eb = meta.eval_batch;
        let mut pend: Vec<(u32, u32)> = Vec::new();
        for (u, pos, negs) in &ds.test {
            pend.push((*u, *pos));
            for &n in negs {
                pend.push((*u, n));
            }
        }
        let mut scores = Vec::with_capacity(pend.len());
        for chunk in pend.chunks(eb) {
            let mut users = vec![0i32; eb];
            let mut items = vec![0i32; eb];
            for (s, &(u, i)) in chunk.iter().enumerate() {
                users[s] = u as i32;
                items[s] = i as i32;
            }
            let mut lits = vec![
                Runtime::lit_i32(&users, &[eb]).unwrap(),
                Runtime::lit_i32(&items, &[eb]).unwrap(),
            ];
            for (p, (_, shape)) in params.iter().zip(&spec) {
                lits.push(Runtime::lit_f32(p, shape).unwrap());
            }
            let out = rt.run_literals(&score_name, lits).unwrap();
            scores.extend_from_slice(&out[0][..chunk.len()]);
        }
        // HR@10 from the flat score list
        let mut hits = 0usize;
        let mut cursor = 0usize;
        for (_, _, negs) in &ds.test {
            let pos_score = scores[cursor];
            let higher = scores[cursor + 1..cursor + 1 + negs.len()]
                .iter()
                .filter(|&&s| s > pos_score)
                .count();
            if higher < 10 {
                hits += 1;
            }
            cursor += 1 + negs.len();
        }
        let hr = hits as f64 / ds.test.len() as f64;
        best_hr = best_hr.max(hr);
        if best_hr >= target_hr {
            break;
        }
    }
    (elapsed, best_hr)
}

fn main() {
    println!("== Table 10: NCF family vs CULSH-MF on implicit feedback ==");
    let dir = Runtime::default_dir();
    if !Runtime::available(&dir) {
        eprintln!("artifacts missing — run `make artifacts`");
        std::process::exit(2);
    }
    let mut rt = Runtime::open(&dir).expect("runtime");
    let meta = rt.manifest.neural.clone();

    let mut rng = Rng::seeded(99);
    // dataset must fit the exported embedding tables
    let mut icfg = ImplicitConfig::movielens1m_like(0.25);
    icfg.n_users = icfg.n_users.min(meta.n_users);
    icfg.n_items = icfg.n_items.min(meta.n_items);
    let ds = generate_implicit(&icfg, &mut rng);
    println!(
        "dataset: {} — {} users × {} items, {} interactions, {} test users",
        ds.name,
        ds.n_users,
        ds.n_items,
        ds.train.nnz(),
        ds.test.len()
    );
    let target_hr = 0.55;

    let mut table = Table::new(&["algorithm", "secs to HR", "best HR@10", "target"]);

    for kind in ["gmf", "mlp", "neumf"] {
        let (secs, hr) = train_neural(&mut rt, kind, &ds, target_hr, 25, &mut Rng::seeded(5));
        table.row(&[
            kind.to_uppercase(),
            format!("{secs:.2}"),
            format!("{hr:.3}"),
            format!("{target_hr}"),
        ]);
    }

    // CULSH-MF on the implicit matrix. The paper switches CULSH-MF to a
    // cross-entropy objective for this comparison; the regression
    // equivalent is 1/0 targets with sampled negatives (4 per positive,
    // the NCF convention) so the model learns to *rank*.
    let t0 = Instant::now();
    let mut train = ds.train.clone();
    {
        let positive: std::collections::HashSet<(u32, u32)> =
            ds.train.entries().iter().map(|&(u, i, _)| (u, i)).collect();
        let n_neg = ds.train.nnz() * 4;
        let mut added = 0;
        let mut guard = 0;
        while added < n_neg && guard < n_neg * 20 {
            guard += 1;
            let u = rng.below(ds.n_users) as u32;
            let i = rng.below(ds.n_items) as u32;
            if !positive.contains(&(u, i)) {
                train.push(u as usize, i as usize, 0.0);
                added += 1;
            }
        }
    }
    let csr = Csr::from_triples(&train);
    let csc = Csc::from_triples(&ds.train);
    let (topk, _) = SimLsh::new(1, 20, 8, 1).build(&csc, 8, &mut rng);
    let cfg = CulshConfig {
        f: 16,
        k: 8,
        epochs: 12,
        alpha: 0.08,
        beta: 0.02,
        lambda_u: 0.005,
        lambda_v: 0.005,
        lambda_b: 0.005,
        ..Default::default()
    };
    let (model, _) = train_culsh_logged(&csr, topk, &cfg, &mut rng);
    let culsh_secs = t0.elapsed().as_secs_f64();
    let mut scratch = lshmf::mf::neighbourhood::NeighbourScratch::default();
    let hr = hit_ratio_at(&ds, 10, |u, i| {
        model.predict(&csr, u as usize, i as usize, &mut scratch)
    });
    table.row(&[
        "CULSH-MF".into(),
        format!("{culsh_secs:.2}"),
        format!("{hr:.3}"),
        format!("{target_hr}"),
    ]);
    table.print();
    println!("(paper shape: CULSH-MF reaches the target HR in a small fraction of NCF time)");
}
