//! Table 7 reproduction: the three sub-tables — optimal RMSE (top),
//! neighbour-construction time (middle), and space overhead (bottom) —
//! for Rand / GSM / simLSH(p,q) / RP_cos / minHash on all three datasets.

use lshmf::bench::exp::BenchEnv;
use lshmf::bench::Table;
use lshmf::gsm::Gsm;
use lshmf::lsh::{MinHash, NeighbourSearch, RandNeighbours, RpCos, SimLsh};
use lshmf::mf::neighbourhood::train_culsh_logged;
use lshmf::rng::Rng;

fn main() {
    let env = BenchEnv::from_env();
    println!("== Table 7: Top-K method cost/quality (scale {}) ==", env.scale);
    let datasets = ["netflix", "movielens", "yahoo"];
    let methods = [
        "Rand",
        "GSM",
        "simLSH(p=3,q=100)",
        "simLSH(p=3,q=200)",
        "RP_cos(p=3,q=200)",
        "minHash(p=3,q=200)",
    ];
    let mut rmse_t = Table::new(&["method", "netflix", "movielens", "yahoo"]);
    let mut time_t = Table::new(&["method", "netflix", "movielens", "yahoo"]);
    let mut space_t = Table::new(&["method", "netflix", "movielens", "yahoo"]);
    let mut rmse_rows: Vec<Vec<String>> = methods.iter().map(|m| vec![m.to_string()]).collect();
    let mut time_rows = rmse_rows.clone();
    let mut space_rows = rmse_rows.clone();

    for dataset in datasets {
        let mut rng = env.rng();
        let ds = env.dataset(dataset, &mut rng);
        let cfg = env.culsh_config(dataset, &ds);
        let psi = env.psi_power(dataset);
        for (mi, method) in methods.iter().enumerate() {
            let mut mrng = Rng::seeded(env.seed);
            let (topk, cost) = match *method {
                "Rand" => RandNeighbours.build(&ds.train_csc, cfg.k, &mut mrng),
                "GSM" => Gsm::new(100.0).build(&ds.train_csc, cfg.k, &mut mrng),
                "simLSH(p=3,q=100)" => {
                    SimLsh::new(3, 100, 8, psi).build(&ds.train_csc, cfg.k, &mut mrng)
                }
                "simLSH(p=3,q=200)" => {
                    SimLsh::new(3, 200, 8, psi).build(&ds.train_csc, cfg.k, &mut mrng)
                }
                "RP_cos(p=3,q=200)" => {
                    RpCos::new(3, 200, 8).build(&ds.train_csc, cfg.k, &mut mrng)
                }
                "minHash(p=3,q=200)" => {
                    MinHash::new(3, 200).build(&ds.train_csc, cfg.k, &mut mrng)
                }
                other => panic!("{other}"),
            };
            let (_, log) =
                train_culsh_logged(&ds.train, topk, &cfg, &mut Rng::seeded(env.seed ^ 1));
            rmse_rows[mi].push(format!("{:.4}", log.best_rmse() * env.rmse_scale(dataset)));
            time_rows[mi].push(format!("{:.3}", cost.seconds));
            space_rows[mi].push(format!("{:.2}", cost.bytes as f64 / (1024.0 * 1024.0)));
        }
    }
    println!("-- optimal RMSE (paper top) --");
    for r in rmse_rows {
        rmse_t.row(&r);
    }
    rmse_t.print();
    println!("-- construction time, seconds (paper middle) --");
    for r in time_rows {
        time_t.row(&r);
    }
    time_t.print();
    println!("-- space overhead, MB (paper bottom) --");
    for r in space_rows {
        space_t.row(&r);
    }
    space_t.print();
    println!("(paper shape: simLSH ~= GSM on RMSE; >=10x cheaper in time and space)");
}
