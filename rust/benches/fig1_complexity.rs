//! Fig. 1 reproduction: GSM's O(N^2) vs LSH's O(N) time AND space,
//! measured by sweeping the column count N at fixed per-column degree.

use lshmf::bench::{csv_dump, Table};
use lshmf::gsm::Gsm;
use lshmf::lsh::{NeighbourSearch, SimLsh};
use lshmf::rng::Rng;
use lshmf::sparse::{Csc, Triples};

fn workload(n: usize, rng: &mut Rng) -> Csc {
    // fixed row universe: as N grows, columns overlap more and the GSM's
    // co-rating pair enumeration grows ~quadratically (Fig. 1's point)
    let m = 2000;
    let per_col = 40;
    let mut t = Triples::new(m, n);
    let mut seen = std::collections::HashSet::new();
    for j in 0..n {
        for _ in 0..per_col {
            let i = rng.below(m);
            if seen.insert((i, j)) {
                t.push(i, j, 1.0 + rng.f32() * 4.0);
            }
        }
    }
    Csc::from_triples(&t)
}

fn main() {
    println!("== Fig. 1: GSM vs LSH complexity sweep ==");
    let mut table = Table::new(&[
        "N", "GSM secs", "GSM MB", "simLSH secs", "simLSH MB", "time ratio", "space ratio",
    ]);
    let mut rows = Vec::new();
    for n in [100usize, 200, 400, 800, 1600] {
        let mut rng = Rng::seeded(n as u64);
        let csc = workload(n, &mut rng);
        let (_, gsm_cost) = Gsm::new(100.0).build(&csc, 16, &mut rng);
        let (_, lsh_cost) = SimLsh::new(3, 20, 8, 2).build(&csc, 16, &mut rng);
        let mb = |b: usize| b as f64 / (1024.0 * 1024.0);
        table.row(&[
            n.to_string(),
            format!("{:.3}", gsm_cost.seconds),
            format!("{:.2}", mb(gsm_cost.bytes)),
            format!("{:.3}", lsh_cost.seconds),
            format!("{:.2}", mb(lsh_cost.bytes)),
            format!("{:.1}x", gsm_cost.seconds / lsh_cost.seconds.max(1e-9)),
            format!("{:.1}x", gsm_cost.bytes as f64 / lsh_cost.bytes.max(1) as f64),
        ]);
        rows.push(vec![
            n.to_string(),
            gsm_cost.seconds.to_string(),
            gsm_cost.bytes.to_string(),
            lsh_cost.seconds.to_string(),
            lsh_cost.bytes.to_string(),
        ]);
    }
    table.print();
    csv_dump("fig1_complexity", &["n", "gsm_s", "gsm_b", "lsh_s", "lsh_b"], &rows).ok();
    println!("expected shape: GSM columns grow ~quadratically in N, simLSH ~linearly");
}
