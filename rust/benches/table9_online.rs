//! Table 9 reproduction: the online split statistics plus the §5.3 claim
//! that online CULSH-MF's RMSE rises only marginally vs full retraining
//! ({0.00015, 0.00040, 0.00936} in the paper) at a fraction of the cost.

use lshmf::bench::exp::BenchEnv;
use lshmf::bench::Table;
use lshmf::data::online::split_online;
use lshmf::data::synth::{generate_triples, SynthConfig};
use lshmf::lsh::{NeighbourSearch, OnlineHashState, SimLsh};
use lshmf::mf::neighbourhood::train_culsh_logged;
use lshmf::mf::online::apply_online;
use lshmf::rng::Rng;
use lshmf::sparse::{Csc, Csr, Triples};

fn main() {
    let env = BenchEnv::from_env();
    println!("== Table 9: online learning (scale {}) ==", env.scale);
    let mut split_t = Table::new(&["dataset", "M", "N", "|Omega|", "M_bar", "N_bar", "|Omega_bar|"]);
    let mut result_t = Table::new(&[
        "dataset", "retrain rmse", "online rmse", "delta", "retrain secs", "online secs", "ratio",
    ]);
    for dataset in ["netflix", "movielens", "yahoo"] {
        let mut synth_cfg = SynthConfig::by_name(dataset).unwrap().scaled(env.scale);
        let mut rng = env.rng();
        let mut full = generate_triples(&synth_cfg, &mut rng);
        if dataset == "yahoo" {
            for e in full.entries_mut() {
                e.2 /= 20.0;
            }
            synth_cfg.min_value /= 20.0;
            synth_cfg.max_value /= 20.0;
        }
        let split = split_online(&full, 0.01, 0.01);
        let st = split.stats(full.nrows(), full.ncols());
        split_t.row(&[
            dataset.into(),
            st.m.to_string(),
            st.n.to_string(),
            st.omega.to_string(),
            st.m_bar.to_string(),
            st.n_bar.to_string(),
            st.omega_bar.to_string(),
        ]);

        // base test set from base entries
        let n_test = (split.base.nnz() / 100).max(1);
        let base_entries = split.base.entries().to_vec();
        let (test, train_entries) = base_entries.split_at(n_test);
        let base = Triples::from_entries(
            split.base.nrows(),
            split.base.ncols(),
            train_entries.to_vec(),
        );
        let psi = env.psi_power(dataset);
        let lsh = SimLsh::new(2, 12, 8, psi);
        let csr = Csr::from_triples(&base);
        let csc = Csc::from_triples(&base);
        let ds_view = lshmf::data::Dataset {
            name: dataset.into(),
            train: csr.clone(),
            train_csc: csc.clone(),
            test: test.to_vec(),
            max_value: synth_cfg.max_value,
            min_value: synth_cfg.min_value,
        };
        let cfg = env.culsh_config(dataset, &ds_view);

        let mut hash_state = OnlineHashState::build(lsh.clone(), &csc);
        let (topk, _) = hash_state.topk(cfg.k, &mut Rng::seeded(env.seed));
        let (model, _) = train_culsh_logged(&csr, topk, &cfg, &mut Rng::seeded(env.seed ^ 1));

        // online path
        let t0 = std::time::Instant::now();
        let out = apply_online(
            model,
            &mut hash_state,
            &base,
            &split.increment,
            full.nrows(),
            full.ncols(),
            &cfg,
            5,
            &mut Rng::seeded(env.seed ^ 2),
        );
        let online_secs = t0.elapsed().as_secs_f64();
        let online_rmse = out.model.rmse(&out.combined, test);

        // full retrain on combined data
        let mut combined = base.clone();
        combined.grow_to(full.nrows(), full.ncols());
        for &(i, j, r) in &split.increment {
            combined.push(i as usize, j as usize, r);
        }
        let csr2 = Csr::from_triples(&combined);
        let csc2 = Csc::from_triples(&combined);
        let t1 = std::time::Instant::now();
        let (topk2, _) = SimLsh::new(2, 12, 8, psi).build(&csc2, cfg.k, &mut Rng::seeded(env.seed));
        let (retrain_model, _) =
            train_culsh_logged(&csr2, topk2, &cfg, &mut Rng::seeded(env.seed ^ 1));
        let retrain_secs = t1.elapsed().as_secs_f64();
        let retrain_rmse = retrain_model.rmse(&csr2, test);

        let rs = env.rmse_scale(dataset);
        result_t.row(&[
            dataset.into(),
            format!("{:.5}", retrain_rmse * rs),
            format!("{:.5}", online_rmse * rs),
            format!("{:+.5}", (online_rmse - retrain_rmse) * rs),
            format!("{retrain_secs:.3}"),
            format!("{online_secs:.3}"),
            format!("{:.1}X", retrain_secs / online_secs.max(1e-9)),
        ]);
    }
    println!("-- split statistics (paper Table 9) --");
    split_t.print();
    println!("-- online vs retrain (paper: deltas {{1.5e-4, 4e-4, 9.4e-3}}) --");
    result_t.print();
}
