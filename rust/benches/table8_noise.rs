//! Table 8 reproduction: RMSE deviation between noisy and clean training
//! at noise rates {1%, 0.5%, 0.1%, 0.05%, 0.01%}, for CUSGD++ (F=128)
//! and CULSH-MF (F=32, K=32). The paper's finding: the neighbourhood
//! model is more robust (smaller deviations).

use lshmf::bench::exp::BenchEnv;
use lshmf::bench::Table;
use lshmf::data::synth::{generate_triples, inject_noise, SynthConfig};
use lshmf::data::Dataset;
use lshmf::lsh::{NeighbourSearch, SimLsh};
use lshmf::mf::neighbourhood::{train_culsh_parallel_logged, CulshConfig};
use lshmf::mf::parallel::train_parallel_sgd_logged;
use lshmf::mf::sgd::SgdConfig;
use lshmf::rng::Rng;

fn main() {
    let env = BenchEnv::from_env();
    println!("== Table 8: noise robustness (scale {}) ==", env.scale);
    let mut table = Table::new(&["noise", "algorithm", "netflix", "movielens", "yahoo"]);
    let rates = [0.01f64, 0.005, 0.001, 0.0005, 0.0001];
    let mut rows: Vec<Vec<String>> = Vec::new();
    for &rate in &rates {
        rows.push(vec![format!("{}%", rate * 100.0), "CUSGD++(F=128)".into()]);
        rows.push(vec![format!("{}%", rate * 100.0), "CULSH-MF(F=32,K=32)".into()]);
    }

    for dataset in ["netflix", "movielens", "yahoo"] {
        let mut synth_cfg = SynthConfig::by_name(dataset).unwrap().scaled(env.scale);
        let mut rng = env.rng();
        let mut clean_t = generate_triples(&synth_cfg, &mut rng);
        if dataset == "yahoo" {
            // §5.1: train on ratings/20, report ×20 (rmse_scale)
            for e in clean_t.entries_mut() {
                e.2 /= 20.0;
            }
            synth_cfg.min_value /= 20.0;
            synth_cfg.max_value /= 20.0;
        }
        let psi = env.psi_power(dataset);

        let run_pair = |t: &lshmf::sparse::Triples, env: &BenchEnv| -> (f64, f64) {
            let mut rng = Rng::seeded(env.seed ^ 7);
            let ds = Dataset::split(dataset, t.clone(), synth_cfg.test_fraction, &mut rng);
            let sgd_cfg = SgdConfig { f: 128, ..env.sgd_config(dataset, &ds) };
            let (_, plain) =
                train_parallel_sgd_logged(&ds.train, &sgd_cfg, 2, &mut Rng::seeded(env.seed));
            let (topk, _) =
                SimLsh::new(2, 40, 8, psi).build(&ds.train_csc, 32, &mut Rng::seeded(env.seed));
            let culsh_cfg = CulshConfig { f: 32, k: 32, ..env.culsh_config(dataset, &ds) };
            let (_, culsh) = train_culsh_parallel_logged(
                &ds.train,
                topk,
                &culsh_cfg,
                2,
                &mut Rng::seeded(env.seed),
            );
            (plain.best_rmse(), culsh.best_rmse())
        };

        let (clean_sgd, clean_culsh) = run_pair(&clean_t, &env);
        for (ri, &rate) in rates.iter().enumerate() {
            let mut noisy_t = clean_t.clone();
            let mut nrng = Rng::seeded(env.seed ^ 0xBAD);
            inject_noise(
                &mut noisy_t,
                rate,
                synth_cfg.min_value,
                synth_cfg.max_value,
                &mut nrng,
            );
            let (noisy_sgd, noisy_culsh) = run_pair(&noisy_t, &env);
            let rs = env.rmse_scale(dataset);
            rows[ri * 2].push(format!("{:.5}", (noisy_sgd - clean_sgd).abs() * rs));
            rows[ri * 2 + 1].push(format!("{:.5}", (noisy_culsh - clean_culsh).abs() * rs));
        }
    }
    for r in rows {
        table.row(&r);
    }
    table.print();
    println!("(paper shape: deviations shrink with the noise rate; CULSH-MF deviates less)");
}
