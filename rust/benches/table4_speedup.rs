//! Table 4 reproduction: time to reach an acceptable RMSE, with speedups
//! over the cuALS baseline. The paper's rows:
//!
//! ```text
//! cuALS     1.30         15.60        15.00
//! cuSGD     5.05 (3.0X)* 0.31 (4.2X)  1.92 (8.1X)      [*paper formatting]
//! CUSGD++   1.49 (10.1X) 0.15 (8.7X)  0.69 (22.6X)
//! ```
//!
//! On synthetic data the absolute target is `best-curve × (1+margin)`;
//! the expected *shape* is cuALS slowest wall-clock to target, CUSGD++
//! fastest, cuSGD between.

use lshmf::bench::exp::{fmt_speedup, target_rmse, BenchEnv};
use lshmf::bench::Table;
use lshmf::mf::als::{train_als_logged, AlsConfig};
use lshmf::mf::hogwild::train_hogwild_logged;
use lshmf::mf::parallel::train_parallel_sgd_logged;
use lshmf::mf::sgd::train_sgd_logged;
use lshmf::rng::Rng;

fn main() {
    let env = BenchEnv::from_env();
    println!("== Table 4: time-to-target speedups (scale {}) ==", env.scale);
    let mut table = Table::new(&["algorithm", "netflix", "movielens", "yahoo"]);
    let mut rows: Vec<Vec<String>> = vec![
        vec!["cuALS".into()],
        vec!["cuSGD".into()],
        vec!["CUSGD++".into()],
        vec!["CUSGD++ (nnz-sorted)".into()],
    ];
    for dataset in ["netflix", "movielens", "yahoo"] {
        let mut rng = env.rng();
        let ds = env.dataset(dataset, &mut rng);
        let sgd_cfg = env.sgd_config(dataset, &ds);
        let als_cfg = AlsConfig {
            f: 32,
            iterations: (env.epochs / 3).max(3),
            lambda: 0.05,
            threads: 2,
            eval: ds.test.clone(),
            ..Default::default()
        };
        let (_, als) = train_als_logged(&ds.train, &als_cfg, &mut Rng::seeded(env.seed));
        let (_, hw) = train_hogwild_logged(&ds.train, &sgd_cfg, 2, &mut Rng::seeded(env.seed));
        let (_, pp) = train_parallel_sgd_logged(&ds.train, &sgd_cfg, 2, &mut Rng::seeded(env.seed));
        let sorted_cfg = lshmf::mf::sgd::SgdConfig { sort_rows_by_nnz: true, ..sgd_cfg.clone() };
        let (_, pps) = train_sgd_logged(&ds.train, &sorted_cfg, &mut Rng::seeded(env.seed));

        let target = target_rmse(&[&als, &hw, &pp, &pps], 0.005);
        println!(
            "# {dataset}: target rmse {:.4} (paper scale)",
            target * env.rmse_scale(dataset)
        );
        let als_t = als.time_to(target);
        rows[0].push(fmt_speedup(als_t, als_t));
        rows[1].push(fmt_speedup(hw.time_to(target), als_t));
        rows[2].push(fmt_speedup(pp.time_to(target), als_t));
        rows[3].push(fmt_speedup(pps.time_to(target), als_t));
    }
    for row in rows {
        table.row(&row);
    }
    table.print();
    println!("(speedups relative to cuALS; paper shape: CUSGD++ > cuSGD > cuALS)");
}
