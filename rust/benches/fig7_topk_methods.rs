//! Fig. 7 reproduction: CULSH-MF RMSE-vs-time under each Top-K method —
//! exact GSM, simLSH at (p,q) settings (plus the centered-Ψ ablation),
//! RP_cos, minHash, and the random control.

use lshmf::bench::exp::BenchEnv;
use lshmf::bench::{csv_dump, Table};
use lshmf::gsm::Gsm;
use lshmf::lsh::{MinHash, NeighbourSearch, RandNeighbours, RpCos, SimLsh, TopK};
use lshmf::mf::neighbourhood::train_culsh_logged;
use lshmf::rng::Rng;
use lshmf::sparse::Csc;

fn main() {
    let env = BenchEnv::from_env();
    println!("== Fig. 7: Top-K methods comparison (movielens, scale {}) ==", env.scale);
    let mut rng = env.rng();
    let ds = env.dataset("movielens", &mut rng);
    let cfg = env.culsh_config("movielens", &ds);
    let psi = env.psi_power("movielens");
    let mu = ds.train.mean();

    let build = |name: &str, csc: &Csc, rng: &mut Rng| -> (TopK, f64, usize) {
        let (topk, cost) = match name {
            "GSM" => Gsm::new(100.0).build(csc, cfg.k, rng),
            "simLSH(p=3,q=100)" => SimLsh::new(3, 100, 8, psi).build(csc, cfg.k, rng),
            "simLSH(p=3,q=200)" => SimLsh::new(3, 200, 8, psi).build(csc, cfg.k, rng),
            "simLSH(p=1,q=100)" => SimLsh::new(1, 100, 8, psi).build(csc, cfg.k, rng),
            "simLSH-centered" => SimLsh::new(1, 100, 8, psi)
                .centered(mu)
                .build(csc, cfg.k, rng),
            "RP_cos(p=3,q=200)" => RpCos::new(3, 200, 8).build(csc, cfg.k, rng),
            "minHash(p=3,q=200)" => MinHash::new(3, 200).build(csc, cfg.k, rng),
            "Rand" => RandNeighbours.build(csc, cfg.k, rng),
            other => panic!("unknown method {other}"),
        };
        (topk, cost.seconds, cost.bytes)
    };

    let methods = [
        "Rand",
        "GSM",
        "simLSH(p=3,q=100)",
        "simLSH(p=3,q=200)",
        "simLSH(p=1,q=100)",
        "simLSH-centered",
        "RP_cos(p=3,q=200)",
        "minHash(p=3,q=200)",
    ];
    let mut summary = Table::new(&["method", "build secs", "build MB", "final rmse", "best rmse"]);
    let mut rows = Vec::new();
    for name in methods {
        let (topk, secs, bytes) = build(name, &ds.train_csc, &mut Rng::seeded(env.seed));
        let (_, log) = train_culsh_logged(&ds.train, topk, &cfg, &mut Rng::seeded(env.seed ^ 1));
        for p in &log.points {
            rows.push(vec![
                name.to_string(),
                p.epoch.to_string(),
                format!("{:.6}", p.seconds + secs),
                format!("{:.6}", p.rmse),
            ]);
        }
        summary.row(&[
            name.into(),
            format!("{:.3}", secs),
            format!("{:.2}", bytes as f64 / (1024.0 * 1024.0)),
            format!("{:.4}", log.final_rmse()),
            format!("{:.4}", log.best_rmse()),
        ]);
    }
    csv_dump("fig7_topk_methods", &["method", "epoch", "seconds", "rmse"], &rows).ok();
    summary.print();
    println!("(paper shape: simLSH ≈ GSM accuracy at ~10-30x less build time; Rand worst)");
}
