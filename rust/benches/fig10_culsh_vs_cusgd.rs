//! Fig. 10 + §5.3 speedups: CULSH-MF (K=32) vs CUSGD++ RMSE-vs-time at
//! F ∈ {32, 64, 128} on all three datasets, with the time-to-target
//! speedups the paper quotes as {2.67X, 2.97X, 1.36X}.

use lshmf::bench::exp::{target_rmse, BenchEnv};
use lshmf::bench::{csv_dump, Table};
use lshmf::lsh::{NeighbourSearch, SimLsh};
use lshmf::mf::neighbourhood::{train_culsh_parallel_logged, CulshConfig};
use lshmf::mf::parallel::train_parallel_sgd_logged;
use lshmf::mf::sgd::SgdConfig;
use lshmf::rng::Rng;

fn main() {
    let env = BenchEnv::from_env();
    println!("== Fig. 10: CULSH-MF vs CUSGD++ (scale {}) ==", env.scale);
    let mut table = Table::new(&[
        "dataset", "F", "CUSGD++ rmse", "CULSH rmse", "CUSGD++ t→target", "CULSH t→target", "speedup",
    ]);
    let mut rows = Vec::new();
    for dataset in ["movielens"] {
        let mut rng = env.rng();
        let ds = env.dataset(dataset, &mut rng);
        let psi = env.psi_power(dataset);
        let (topk, lsh_secs) = {
            let (t, c) = SimLsh::new(2, 60, 8, psi).build(&ds.train_csc, 32, &mut Rng::seeded(env.seed));
            (t, c.seconds)
        };
        for f in [32usize, 64, 128] {
            let sgd_cfg = SgdConfig { f, ..env.sgd_config(dataset, &ds) };
            let (_, plain) =
                train_parallel_sgd_logged(&ds.train, &sgd_cfg, 2, &mut Rng::seeded(env.seed));
            let culsh_cfg = CulshConfig { f, k: 32, ..env.culsh_config(dataset, &ds) };
            let (_, culsh) = train_culsh_parallel_logged(
                &ds.train,
                topk.clone(),
                &culsh_cfg,
                2,
                &mut Rng::seeded(env.seed),
            );
            let target = target_rmse(&[&plain, &culsh], 0.01);
            let t_plain = plain.time_to(target);
            let t_culsh = culsh.time_to(target).map(|t| t + lsh_secs);
            let speedup = match (t_plain, t_culsh) {
                (Some(a), Some(b)) if b > 0.0 => format!("{:.2}X", a / b),
                _ => "n/a".into(),
            };
            table.row(&[
                dataset.into(),
                f.to_string(),
                format!("{:.4}", plain.best_rmse()),
                format!("{:.4}", culsh.best_rmse()),
                t_plain.map(|t| format!("{t:.3}")).unwrap_or("n/a".into()),
                t_culsh.map(|t| format!("{t:.3}")).unwrap_or("n/a".into()),
                speedup,
            ]);
            for (name, log) in [("CUSGD++", &plain), ("CULSH-MF", &culsh)] {
                for p in &log.points {
                    rows.push(vec![
                        dataset.to_string(),
                        f.to_string(),
                        name.to_string(),
                        format!("{:.6}", p.seconds),
                        format!("{:.6}", p.rmse),
                    ]);
                }
            }
        }
    }
    table.print();
    csv_dump("fig10_culsh_vs_cusgd", &["dataset", "f", "algo", "seconds", "rmse"], &rows).ok();
    println!("(paper: CULSH-MF K=32 speedups {{2.67X, 2.97X, 1.36X}} at F={{32,64,128}})");
}
