//! Fig. 6 reproduction: RMSE-vs-time curves of CUSGD++ (block-parallel
//! SGD) vs cuSGD (hogwild) vs cuALS (parallel ALS) on all three datasets.
//!
//! CSV series land in `bench_out/fig6_<dataset>.csv`; the printed summary
//! shows the curve endpoints. Expected shape (paper): ALS descends
//! steeply per iteration but pays heavy per-iteration cost; the SGDs
//! iterate cheaply; CUSGD++ (locality-aware) beats cuSGD per iteration.

use lshmf::bench::exp::BenchEnv;
use lshmf::bench::{csv_dump, Table};
use lshmf::mf::als::{train_als_logged, AlsConfig};
use lshmf::mf::hogwild::train_hogwild_logged;
use lshmf::mf::parallel::train_parallel_sgd_logged;
use lshmf::rng::Rng;

fn main() {
    let env = BenchEnv::from_env();
    println!("== Fig. 6: RMSE vs time (scale {}) ==", env.scale);
    let mut summary = Table::new(&["dataset", "algorithm", "final rmse", "best rmse", "secs"]);
    for dataset in ["netflix", "movielens", "yahoo"] {
        let mut rng = env.rng();
        let ds = env.dataset(dataset, &mut rng);
        let sgd_cfg = env.sgd_config(dataset, &ds);
        let als_cfg = AlsConfig {
            f: 32,
            iterations: (env.epochs / 3).max(3),
            lambda: 0.05,
            threads: 2,
            eval: ds.test.clone(),
            ..Default::default()
        };

        let (_, cusgdpp) =
            train_parallel_sgd_logged(&ds.train, &sgd_cfg, 2, &mut Rng::seeded(env.seed));
        let (_, cusgd) = train_hogwild_logged(&ds.train, &sgd_cfg, 2, &mut Rng::seeded(env.seed));
        let (_, cuals) = train_als_logged(&ds.train, &als_cfg, &mut Rng::seeded(env.seed));

        let rscale = env.rmse_scale(dataset);
        let mut rows = Vec::new();
        for (name, log) in [("CUSGD++", &cusgdpp), ("cuSGD", &cusgd), ("cuALS", &cuals)] {
            for p in &log.points {
                rows.push(vec![
                    name.to_string(),
                    p.epoch.to_string(),
                    format!("{:.6}", p.seconds),
                    format!("{:.6}", p.rmse * rscale),
                ]);
            }
            summary.row(&[
                dataset.into(),
                name.into(),
                format!("{:.4}", log.final_rmse() * rscale),
                format!("{:.4}", log.best_rmse() * rscale),
                format!("{:.2}", log.total_seconds()),
            ]);
        }
        csv_dump(
            &format!("fig6_{dataset}"),
            &["algo", "epoch", "seconds", "rmse"],
            &rows,
        )
        .ok();
    }
    summary.print();
}
