//! Fig. 8 reproduction: CULSH-MF RMSE as a function of the amplification
//! parameters (p, q). The paper's finding: raising p sharpens precision
//! but loses recall (`1 − (1 − P₁ᵖ)^q` falls), so a moderate p with a
//! large q wins.

use lshmf::bench::exp::BenchEnv;
use lshmf::bench::{csv_dump, Table};
use lshmf::lsh::{NeighbourSearch, SimLsh};
use lshmf::mf::neighbourhood::train_culsh_logged;
use lshmf::rng::Rng;

fn main() {
    let env = BenchEnv::from_env();
    println!("== Fig. 8: (p, q) sweep (movielens, scale {}) ==", env.scale);
    let mut rng = env.rng();
    let ds = env.dataset("movielens", &mut rng);
    let cfg = env.culsh_config("movielens", &ds);
    let psi = env.psi_power("movielens");

    let ps = [1usize, 2, 3, 4];
    let qs = [25usize, 50, 100, 200];
    let mut table = Table::new(&["p \\ q", "25", "50", "100", "200"]);
    let mut rows = Vec::new();
    for p in ps {
        let mut row = vec![p.to_string()];
        for q in qs {
            let (topk, _) =
                SimLsh::new(p, q, 8, psi).build(&ds.train_csc, cfg.k, &mut Rng::seeded(env.seed));
            let (_, log) =
                train_culsh_logged(&ds.train, topk, &cfg, &mut Rng::seeded(env.seed ^ 1));
            row.push(format!("{:.4}", log.best_rmse()));
            rows.push(vec![p.to_string(), q.to_string(), format!("{:.6}", log.best_rmse())]);
        }
        table.row(&row);
    }
    table.print();
    csv_dump("fig8_pq_sweep", &["p", "q", "rmse"], &rows).ok();
    println!("(paper shape: accuracy improves with q; overly large p hurts recall)");
}
