//! Cholesky factorization and solver for the ALS normal equations.
//!
//! ALS (the cuALS comparator, Tan et al. 2016) solves per row/column
//! `(Σ v_j v_jᵀ + λ n I) u_i = Σ r_ij v_j` — an F×F SPD system with
//! F ∈ {32..128}. A dense right-looking Cholesky is exactly right at this
//! size; no pivoting needed for SPD.

/// In-place lower-triangular Cholesky of a row-major `n×n` SPD matrix.
/// Returns `Err` if the matrix is not positive definite.
pub fn cholesky_factor(a: &mut [f32], n: usize) -> Result<(), &'static str> {
    debug_assert_eq!(a.len(), n * n);
    for j in 0..n {
        // diagonal
        let mut d = a[j * n + j];
        for k in 0..j {
            d -= a[j * n + k] * a[j * n + k];
        }
        if d <= 0.0 || !d.is_finite() {
            return Err("matrix not positive definite");
        }
        let d = d.sqrt();
        a[j * n + j] = d;
        // column below the diagonal
        for i in (j + 1)..n {
            let mut s = a[i * n + j];
            for k in 0..j {
                s -= a[i * n + k] * a[j * n + k];
            }
            a[i * n + j] = s / d;
        }
        // zero the strictly-upper part for hygiene
        for k in (j + 1)..n {
            a[j * n + k] = 0.0;
        }
    }
    Ok(())
}

/// Solve `L Lᵀ x = b` given the Cholesky factor `l` (lower, row-major).
pub fn cholesky_solve(l: &[f32], n: usize, b: &mut [f32]) {
    debug_assert_eq!(l.len(), n * n);
    debug_assert_eq!(b.len(), n);
    // forward: L y = b
    for i in 0..n {
        let mut s = b[i];
        for k in 0..i {
            s -= l[i * n + k] * b[k];
        }
        b[i] = s / l[i * n + i];
    }
    // backward: Lᵀ x = y
    for i in (0..n).rev() {
        let mut s = b[i];
        for k in (i + 1)..n {
            s -= l[k * n + i] * b[k];
        }
        b[i] = s / l[i * n + i];
    }
}

/// Solve the SPD system `A x = b` in place (A is destroyed, b becomes x).
pub fn solve_normal_eq(a: &mut [f32], n: usize, b: &mut [f32]) -> Result<(), &'static str> {
    cholesky_factor(a, n)?;
    cholesky_solve(a, n, b);
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    /// Build a random SPD matrix `A = B Bᵀ + n I`.
    fn random_spd(n: usize, rng: &mut Rng) -> Vec<f32> {
        let b: Vec<f32> = (0..n * n).map(|_| rng.f32() - 0.5).collect();
        let mut a = vec![0f32; n * n];
        for i in 0..n {
            for j in 0..n {
                let mut s = 0.0;
                for k in 0..n {
                    s += b[i * n + k] * b[j * n + k];
                }
                a[i * n + j] = s + if i == j { n as f32 } else { 0.0 };
            }
        }
        a
    }

    #[test]
    fn solves_known_system() {
        // A = [[4,2],[2,3]], b = [10, 9] → x = [1.5, 2]
        let mut a = vec![4.0, 2.0, 2.0, 3.0];
        let mut b = vec![10.0, 9.0];
        solve_normal_eq(&mut a, 2, &mut b).unwrap();
        assert!((b[0] - 1.5).abs() < 1e-5);
        assert!((b[1] - 2.0).abs() < 1e-5);
    }

    #[test]
    fn random_spd_roundtrip() {
        let mut rng = Rng::seeded(7);
        for n in [1usize, 2, 5, 16, 32] {
            let a = random_spd(n, &mut rng);
            let x_true: Vec<f32> = (0..n).map(|_| rng.f32() * 2.0 - 1.0).collect();
            // b = A x
            let mut b = vec![0f32; n];
            for i in 0..n {
                for j in 0..n {
                    b[i] += a[i * n + j] * x_true[j];
                }
            }
            let mut a_work = a.clone();
            solve_normal_eq(&mut a_work, n, &mut b).unwrap();
            for i in 0..n {
                assert!((b[i] - x_true[i]).abs() < 1e-3, "n={n} i={i}");
            }
        }
    }

    #[test]
    fn rejects_indefinite() {
        let mut a = vec![1.0, 2.0, 2.0, 1.0]; // eigenvalues 3, -1
        assert!(cholesky_factor(&mut a, 2).is_err());
    }
}
