//! Dense linear-algebra substrate.
//!
//! Everything the trainers need and nothing more: contiguous factor
//! matrices ([`FactorMatrix`] — row-major `M×F` with aligned rows), the
//! fused vector kernels of the SGD hot loop ([`dot`], [`axpy_update`]),
//! and a Cholesky solver for the ALS normal equations.
//!
//! The vector kernels are written as 4-way unrolled loops over `f32`
//! slices; rustc/LLVM auto-vectorizes these to SSE/AVX on x86-64. This is
//! the CPU analogue of the paper's warp-shuffle dot product (§4.2): keep
//! the working vectors in the closest level of the hierarchy (registers /
//! L1) and avoid re-loading across the inner loop.

mod cholesky;

pub use cholesky::{cholesky_factor, cholesky_solve, solve_normal_eq};

/// Dot product of two equal-length slices, 8-way unrolled.
///
/// Eight independent accumulators let LLVM keep a full SIMD register of
/// partial sums (f32x8 on AVX) with no loop-carried dependence — measured
/// ~2.7× over the naive loop and ~1.5× over a 4-wide unroll on this host
/// (EXPERIMENTS.md §Perf).
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let n = a.len();
    let chunks = n / 8;
    let mut acc = [0f32; 8];
    for c in 0..chunks {
        let k = c * 8;
        // bounds-check-free slices help the vectorizer
        let (xa, xb) = (&a[k..k + 8], &b[k..k + 8]);
        for l in 0..8 {
            acc[l] += xa[l] * xb[l];
        }
    }
    let mut s = ((acc[0] + acc[1]) + (acc[2] + acc[3]))
        + ((acc[4] + acc[5]) + (acc[6] + acc[7]));
    for k in chunks * 8..n {
        s += a[k] * b[k];
    }
    s
}

/// The fused SGD factor update of Eq. (5):
/// `u ← u + γ (e·v − λ·u)` and `v ← v + γ (e·u_old − λ·v)` must use the
/// *pre-update* `u`, so the kernel computes both halves in one pass over
/// the registers.
#[inline]
pub fn sgd_pair_update(u: &mut [f32], v: &mut [f32], e: f32, gamma: f32, lu: f32, lv: f32) {
    debug_assert_eq!(u.len(), v.len());
    for k in 0..u.len() {
        let (uk, vk) = (u[k], v[k]);
        u[k] = uk + gamma * (e * vk - lu * uk);
        v[k] = vk + gamma * (e * uk - lv * vk);
    }
}

/// `y ← y + α x` (axpy).
#[inline]
pub fn axpy_update(y: &mut [f32], alpha: f32, x: &[f32]) {
    debug_assert_eq!(y.len(), x.len());
    for k in 0..y.len() {
        y[k] += alpha * x[k];
    }
}

/// `y ← y * (1 - s) + α x` — regularized gradient step.
#[inline]
pub fn scaled_axpy(y: &mut [f32], shrink: f32, alpha: f32, x: &[f32]) {
    debug_assert_eq!(y.len(), x.len());
    for k in 0..y.len() {
        y[k] = y[k] * (1.0 - shrink) + alpha * x[k];
    }
}

/// Squared L2 norm.
#[inline]
pub fn norm_sq(a: &[f32]) -> f32 {
    dot(a, a)
}

/// Row-major dense factor matrix (U ∈ ℝ^{M×F} or V ∈ ℝ^{N×F}).
#[derive(Clone, Debug)]
pub struct FactorMatrix {
    rows: usize,
    cols: usize,
    data: Vec<f32>,
}

impl FactorMatrix {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        FactorMatrix { rows, cols, data: vec![0.0; rows * cols] }
    }

    /// Conventional MF init: uniform in ±(1/sqrt(F)).
    pub fn random(rows: usize, cols: usize, rng: &mut crate::rng::Rng) -> Self {
        let scale = 1.0 / (cols as f32).sqrt();
        let mut m = FactorMatrix::zeros(rows, cols);
        rng.fill_uniform(&mut m.data, scale);
        m
    }

    pub fn rows(&self) -> usize {
        self.rows
    }

    pub fn cols(&self) -> usize {
        self.cols
    }

    #[inline]
    pub fn row(&self, i: usize) -> &[f32] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f32] {
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Disjoint mutable rows (SGD updates u_i and v_j simultaneously).
    /// Panics if `i == j` against the same matrix — callers never do that
    /// (rows come from different matrices or disjoint bands).
    pub fn data(&self) -> &[f32] {
        &self.data
    }

    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }

    /// Append `extra` new rows initialized uniform ±1/sqrt(F)
    /// (online learning: new variables enter the system).
    pub fn grow_rows(&mut self, extra: usize, rng: &mut crate::rng::Rng) {
        let scale = 1.0 / (self.cols as f32).sqrt();
        let mut tail = vec![0.0f32; extra * self.cols];
        rng.fill_uniform(&mut tail, scale);
        self.data.extend_from_slice(&tail);
        self.rows += extra;
    }

    pub fn bytes(&self) -> usize {
        self.data.len() * 4
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    #[test]
    fn dot_matches_naive() {
        let mut rng = Rng::seeded(1);
        for n in [0usize, 1, 3, 4, 7, 32, 33, 128] {
            let a: Vec<f32> = (0..n).map(|_| rng.f32() - 0.5).collect();
            let b: Vec<f32> = (0..n).map(|_| rng.f32() - 0.5).collect();
            let naive: f32 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
            assert!((dot(&a, &b) - naive).abs() < 1e-4, "n={n}");
        }
    }

    #[test]
    fn sgd_pair_update_uses_pre_update_u() {
        // Hand-computed: u=[1], v=[2], e=0.5, gamma=0.1, lambda=0.
        // u' = 1 + 0.1*(0.5*2) = 1.1 ; v' = 2 + 0.1*(0.5*1) = 2.05 (old u!)
        let mut u = [1.0f32];
        let mut v = [2.0f32];
        sgd_pair_update(&mut u, &mut v, 0.5, 0.1, 0.0, 0.0);
        assert!((u[0] - 1.1).abs() < 1e-6);
        assert!((v[0] - 2.05).abs() < 1e-6);
    }

    #[test]
    fn axpy_basic() {
        let mut y = [1.0f32, 2.0, 3.0];
        axpy_update(&mut y, 2.0, &[1.0, 1.0, 1.0]);
        assert_eq!(y, [3.0, 4.0, 5.0]);
    }

    #[test]
    fn factor_matrix_rows_disjoint() {
        let mut rng = Rng::seeded(2);
        let m = FactorMatrix::random(10, 8, &mut rng);
        assert_eq!(m.row(3).len(), 8);
        // init scale bound
        let bound = 1.0 / (8f32).sqrt() + 1e-6;
        assert!(m.data().iter().all(|&x| x.abs() <= bound));
    }

    #[test]
    fn grow_rows_extends() {
        let mut rng = Rng::seeded(3);
        let mut m = FactorMatrix::random(4, 4, &mut rng);
        let before = m.row(2).to_vec();
        m.grow_rows(3, &mut rng);
        assert_eq!(m.rows(), 7);
        assert_eq!(m.row(2), &before[..]);
        assert_eq!(m.row(6).len(), 4);
    }
}
