//! Configuration system: a TOML-subset parser plus the typed experiment
//! configuration the launcher consumes.
//!
//! The offline image has no `serde`/`toml`, so [`parse`] implements the
//! subset real configs need: `[section]` headers, `key = value` with
//! string / int / float / bool / flat arrays, comments, and blank lines.
//! Typed configs ([`ExperimentConfig`]) pull values out of the parsed tree
//! with defaulting and validation, so a config file only needs to state
//! what it overrides.

mod experiment;
mod route;
mod serve;
mod toml;

pub use experiment::{
    DatasetChoice, DatasetSection, ExperimentConfig, LshChoice, LshSection, ModelConfig,
    OnlineConfig, RotationConfig, TrainerChoice, TrainerSection,
};
pub use route::{RouteBackend, RouteConfig};
pub use serve::{
    parse_codec, parse_flush_mode, EngineMode, EngineSection, FlushSection, LimitsSection,
    MetricsSection, PersistSection, ServeConfig, ServerSection,
};
pub use toml::{parse, parse_spanned, Spans, Value};

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn end_to_end_config_file() {
        let text = r#"
# experiment config
[dataset]
kind = "movielens"
scale = 0.1
seed = 42

[model]
f = 32
k = 32

[trainer]
kind = "culsh"
epochs = 10
alpha = 0.035
beta = 0.3

[lsh]
kind = "simlsh"
p = 3
q = 100
g = 8

[rotation]
workers = 3
"#;
        let cfg = ExperimentConfig::from_str(text).unwrap();
        assert_eq!(cfg.model.f, 32);
        assert_eq!(cfg.lsh.p, 3);
        assert_eq!(cfg.rotation.workers, 3);
        assert!((cfg.dataset.scale - 0.1).abs() < 1e-9);
        assert!(matches!(cfg.trainer.kind, TrainerChoice::Culsh));
    }

    #[test]
    fn defaults_fill_missing_sections() {
        let cfg = ExperimentConfig::from_str("[model]\nf = 64\n").unwrap();
        assert_eq!(cfg.model.f, 64);
        assert_eq!(cfg.model.k, 32); // default
        assert_eq!(cfg.lsh.p, 3); // default
    }

    #[test]
    fn rejects_bad_values() {
        assert!(ExperimentConfig::from_str("[model]\nf = \"many\"\n").is_err());
        assert!(ExperimentConfig::from_str("[lsh]\nkind = \"bogus\"\n").is_err());
    }
}
