//! Minimal TOML-subset parser.
//!
//! Supported: `[section]` headers, `[[section]]` array-of-tables
//! headers, `key = value` pairs where value is a quoted string,
//! integer, float, boolean, or a flat array of those; `#` comments
//! (full-line or trailing); blank lines. Nested tables, datetimes and
//! multi-line strings are out of scope.
//!
//! Array-of-tables headers keep the flat [`Tree`] shape: the n-th
//! `[[route.backend]]` becomes the section `route.backend.{n}`, so
//! typed configs enumerate elements by numeric suffix.

use std::collections::BTreeMap;

/// A parsed configuration value.
#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
    Array(Vec<Value>),
}

impl Value {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_int(&self) -> Option<i64> {
        match self {
            Value::Int(i) => Some(*i),
            _ => None,
        }
    }

    /// Floats accept integer literals too (`scale = 1` means 1.0).
    pub fn as_float(&self) -> Option<f64> {
        match self {
            Value::Float(f) => Some(*f),
            Value::Int(i) => Some(*i as f64),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_array(&self) -> Option<&[Value]> {
        match self {
            Value::Array(a) => Some(a),
            _ => None,
        }
    }
}

/// `section -> key -> value`. Keys before any `[section]` land in `""`.
pub type Tree = BTreeMap<String, BTreeMap<String, Value>>;

/// Source positions for a parsed [`Tree`]: the 1-based line of every
/// `[section]` header and every `key = value` pair. Typed configs use
/// this to point rejection errors at the exact file:line instead of
/// merely naming the offending key.
#[derive(Clone, Debug, Default)]
pub struct Spans {
    /// `section -> header line` (the root section `""` is absent).
    pub sections: BTreeMap<String, usize>,
    /// `(section, key) -> line of the (last) assignment`.
    pub keys: BTreeMap<(String, String), usize>,
}

impl Spans {
    /// Line of `key` in `[section]`, if present.
    pub fn key_line(&self, section: &str, key: &str) -> Option<usize> {
        self.keys.get(&(section.to_string(), key.to_string())).copied()
    }

    /// Line of the `[section]` header, if present.
    pub fn section_line(&self, section: &str) -> Option<usize> {
        self.sections.get(section).copied()
    }
}

/// Parse a TOML-subset document.
pub fn parse(text: &str) -> Result<Tree, String> {
    parse_spanned(text).map(|(tree, _)| tree)
}

/// [`parse`], additionally returning the [`Spans`] line map.
pub fn parse_spanned(text: &str) -> Result<(Tree, Spans), String> {
    let mut tree: Tree = BTreeMap::new();
    let mut spans = Spans::default();
    let mut section = String::new();
    let mut array_counts: BTreeMap<String, usize> = BTreeMap::new();
    tree.entry(section.clone()).or_default();

    for (lineno, raw) in text.lines().enumerate() {
        let line = strip_comment(raw).trim();
        if line.is_empty() {
            continue;
        }
        if let Some(rest) = line.strip_prefix("[[") {
            let name = rest
                .strip_suffix("]]")
                .ok_or_else(|| format!("line {}: unterminated table-array header", lineno + 1))?
                .trim();
            if name.is_empty() {
                return Err(format!("line {}: empty table-array name", lineno + 1));
            }
            let slot = array_counts.entry(name.to_string()).or_insert(0);
            section = format!("{name}.{slot}");
            *slot += 1;
            tree.entry(section.clone()).or_default();
            spans.sections.entry(section.clone()).or_insert(lineno + 1);
            continue;
        }
        if let Some(rest) = line.strip_prefix('[') {
            let name = rest
                .strip_suffix(']')
                .ok_or_else(|| format!("line {}: unterminated section header", lineno + 1))?
                .trim();
            if name.is_empty() {
                return Err(format!("line {}: empty section name", lineno + 1));
            }
            section = name.to_string();
            tree.entry(section.clone()).or_default();
            spans.sections.entry(section.clone()).or_insert(lineno + 1);
            continue;
        }
        let eq = line
            .find('=')
            .ok_or_else(|| format!("line {}: expected `key = value`", lineno + 1))?;
        let key = line[..eq].trim();
        if key.is_empty() {
            return Err(format!("line {}: empty key", lineno + 1));
        }
        let value = parse_value(line[eq + 1..].trim())
            .map_err(|e| format!("line {}: {}", lineno + 1, e))?;
        tree.get_mut(&section).unwrap().insert(key.to_string(), value);
        spans
            .keys
            .insert((section.clone(), key.to_string()), lineno + 1);
    }
    Ok((tree, spans))
}

/// Strip a trailing `#` comment, respecting quoted strings.
fn strip_comment(line: &str) -> &str {
    let mut in_str = false;
    for (idx, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..idx],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str) -> Result<Value, String> {
    if s.is_empty() {
        return Err("empty value".into());
    }
    if let Some(rest) = s.strip_prefix('"') {
        let inner = rest
            .strip_suffix('"')
            .ok_or_else(|| "unterminated string".to_string())?;
        if inner.contains('"') {
            return Err("embedded quote in string".into());
        }
        return Ok(Value::Str(inner.to_string()));
    }
    if let Some(rest) = s.strip_prefix('[') {
        let inner = rest
            .strip_suffix(']')
            .ok_or_else(|| "unterminated array".to_string())?
            .trim();
        if inner.is_empty() {
            return Ok(Value::Array(Vec::new()));
        }
        let items = split_array_items(inner)?;
        let vals = items
            .iter()
            .map(|it| parse_value(it.trim()))
            .collect::<Result<Vec<_>, _>>()?;
        return Ok(Value::Array(vals));
    }
    match s {
        "true" => return Ok(Value::Bool(true)),
        "false" => return Ok(Value::Bool(false)),
        _ => {}
    }
    let cleaned = s.replace('_', "");
    if let Ok(i) = cleaned.parse::<i64>() {
        return Ok(Value::Int(i));
    }
    if let Ok(f) = cleaned.parse::<f64>() {
        return Ok(Value::Float(f));
    }
    Err(format!("cannot parse value `{s}`"))
}

/// Split a flat array body on commas, respecting quoted strings.
fn split_array_items(inner: &str) -> Result<Vec<String>, String> {
    let mut items = Vec::new();
    let mut cur = String::new();
    let mut in_str = false;
    for c in inner.chars() {
        match c {
            '"' => {
                in_str = !in_str;
                cur.push(c);
            }
            ',' if !in_str => {
                items.push(cur.trim().to_string());
                cur.clear();
            }
            '[' | ']' if !in_str => return Err("nested arrays unsupported".into()),
            _ => cur.push(c),
        }
    }
    if in_str {
        return Err("unterminated string in array".into());
    }
    if !cur.trim().is_empty() {
        items.push(cur.trim().to_string());
    }
    Ok(items)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_all_value_kinds() {
        let t = parse(
            r#"
top = 1
[s]
name = "hello"   # trailing comment
n = 42
x = 3.5
neg = -7
big = 1_000_000
flag = true
off = false
arr = [1, 2, 3]
mixed = ["a", 2.5]
empty = []
"#,
        )
        .unwrap();
        assert_eq!(t[""]["top"], Value::Int(1));
        let s = &t["s"];
        assert_eq!(s["name"], Value::Str("hello".into()));
        assert_eq!(s["n"], Value::Int(42));
        assert_eq!(s["x"], Value::Float(3.5));
        assert_eq!(s["neg"], Value::Int(-7));
        assert_eq!(s["big"], Value::Int(1_000_000));
        assert_eq!(s["flag"], Value::Bool(true));
        assert_eq!(s["off"], Value::Bool(false));
        assert_eq!(
            s["arr"],
            Value::Array(vec![Value::Int(1), Value::Int(2), Value::Int(3)])
        );
        assert_eq!(s["empty"], Value::Array(vec![]));
    }

    #[test]
    fn hash_inside_string_is_not_comment() {
        let t = parse("k = \"a#b\"\n").unwrap();
        assert_eq!(t[""]["k"], Value::Str("a#b".into()));
    }

    #[test]
    fn errors_are_reported_with_line() {
        let e = parse("[s]\nbad line\n").unwrap_err();
        assert!(e.contains("line 2"), "{e}");
        assert!(parse("[unclosed\n").is_err());
        assert!(parse("k = \n").is_err());
        assert!(parse("k = [1, [2]]\n").is_err());
    }

    #[test]
    fn later_keys_override() {
        let t = parse("[a]\nx = 1\nx = 2\n").unwrap();
        assert_eq!(t["a"]["x"], Value::Int(2));
    }

    #[test]
    fn spans_track_section_and_key_lines() {
        let (_, spans) = parse_spanned(
            "top = 1\n\n[server]\n# comment\nport = 7878\n\n[server]\nthreads = 2\n",
        )
        .unwrap();
        assert_eq!(spans.key_line("", "top"), Some(1));
        // first header wins for the section line; re-opened sections
        // keep adding keys with their own lines
        assert_eq!(spans.section_line("server"), Some(3));
        assert_eq!(spans.key_line("server", "port"), Some(5));
        assert_eq!(spans.key_line("server", "threads"), Some(8));
        assert_eq!(spans.key_line("server", "missing"), None);
        assert_eq!(spans.section_line(""), None);
        // a re-assigned key reports the last assignment
        let (_, spans) = parse_spanned("[a]\nx = 1\nx = 2\n").unwrap();
        assert_eq!(spans.key_line("a", "x"), Some(3));
    }

    #[test]
    fn table_arrays_become_numbered_sections() {
        let (t, spans) = parse_spanned(
            "[route]\ncols = 40\n\n[[route.backend]]\naddr = \"a:1\"\n\n[[route.backend]]\naddr = \"b:2\"\n",
        )
        .unwrap();
        assert_eq!(t["route"]["cols"], Value::Int(40));
        assert_eq!(t["route.backend.0"]["addr"], Value::Str("a:1".into()));
        assert_eq!(t["route.backend.1"]["addr"], Value::Str("b:2".into()));
        assert_eq!(spans.section_line("route.backend.1"), Some(7));
        // independent arrays count independently
        let t = parse("[[a]]\nx = 1\n[[b]]\ny = 2\n[[a]]\nx = 3\n").unwrap();
        assert_eq!(t["a.0"]["x"], Value::Int(1));
        assert_eq!(t["b.0"]["y"], Value::Int(2));
        assert_eq!(t["a.1"]["x"], Value::Int(3));
        // malformed headers are rejected with the line
        assert!(parse("[[a]\n").is_err());
        assert!(parse("[[ ]]\n").is_err());
    }

    #[test]
    fn float_accepts_int() {
        let t = parse("x = 3\n").unwrap();
        assert_eq!(t[""]["x"].as_float(), Some(3.0));
    }
}
