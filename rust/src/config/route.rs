//! Typed route-tier configuration: the `[route]` / `[[route.backend]]`
//! sections of `route --config lshmf.toml`.
//!
//! The route tier fronts N downstream `serve` processes (see
//! `coordinator::router`). Its sections are **closed** exactly like the
//! serve sections: an unknown key inside `[route]` or any
//! `[[route.backend]]` element is rejected with the `file:line` of the
//! offender. The front-end listener itself (`port`, `threads`, codec,
//! admission limits, metrics) is still configured by the `[server]` /
//! `[limits]` / `[metrics]` sections of the same file — `[route]` only
//! describes the backend fleet and the router's fault policy.

use super::toml::{parse_spanned, Spans, Tree, Value};
use crate::{Error, Result};

/// One downstream `serve` process (`[[route.backend]]`).
#[derive(Clone, Debug, PartialEq)]
pub struct RouteBackend {
    /// `host:port` of the backend's TCP listener.
    pub addr: String,
}

/// `[route]` + `[[route.backend]]` — the backend fleet and fault policy.
#[derive(Clone, Debug, PartialEq)]
pub struct RouteConfig {
    /// Column extent of the ownership map: col ids are banded over
    /// `0..cols` with `sparse::band_of`, one band per backend. Ids at
    /// or beyond `cols` clamp into the last band, so a grown matrix
    /// keeps routing (coarsely) rather than erroring.
    pub cols: usize,
    /// Health-probe cadence: every tick the router probes each backend
    /// (liveness check when up, reconnect attempt when down).
    pub probe_interval_ms: u64,
    /// Base reconnect/retry backoff; doubles per consecutive failure.
    pub retry_backoff_ms: u64,
    /// Backoff ceiling (jitter rides on top of the capped value).
    pub retry_backoff_max_ms: u64,
    /// Read-path attempts per request before answering `Unavailable`
    /// (the first try plus `retry_attempts - 1` retries).
    pub retry_attempts: usize,
    /// Read deadline on backend sockets: a backend that accepts bytes
    /// but never answers is indistinguishable from a dead one, so every
    /// router-side connection carries this timeout (0 disables).
    pub io_timeout_ms: u64,
    /// The fleet, in `[[route.backend]]` declaration order; backend `i`
    /// owns column band `i`.
    pub backends: Vec<RouteBackend>,
}

impl Default for RouteConfig {
    fn default() -> Self {
        RouteConfig {
            cols: 65_536,
            probe_interval_ms: 500,
            retry_backoff_ms: 50,
            retry_backoff_max_ms: 2_000,
            retry_attempts: 3,
            io_timeout_ms: 2_000,
            backends: Vec::new(),
        }
    }
}

const ROUTE_KEYS: &[&str] = &[
    "cols",
    "probe_interval_ms",
    "retry_backoff_ms",
    "retry_backoff_max_ms",
    "retry_attempts",
    "io_timeout_ms",
];
const BACKEND_KEYS: &[&str] = &["addr"];

fn get_u64(tree: &Tree, sec: &str, key: &str, default: u64) -> Result<u64> {
    match tree.get(sec).and_then(|s| s.get(key)) {
        None => Ok(default),
        Some(v) => match v.as_int() {
            Some(i) if i >= 0 => Ok(i as u64),
            Some(_) => Err(Error::Config(format!("[{sec}] {key} must not be negative"))),
            None => Err(Error::Config(format!("[{sec}] {key} must be an integer"))),
        },
    }
}

fn get_usize(tree: &Tree, sec: &str, key: &str, default: usize) -> Result<usize> {
    get_u64(tree, sec, key, default as u64).map(|v| v as usize)
}

impl RouteConfig {
    /// Does this tree carry route sections at all? `route` and `serve`
    /// share one file, so the CLI uses this to give a pointed error
    /// when `route` is started against a config with no fleet in it.
    pub fn present(tree: &Tree) -> bool {
        tree.keys()
            .any(|s| s == "route" || s.starts_with("route.backend."))
    }

    /// Parse from TOML-subset text, filling defaults and validating.
    pub fn from_str(text: &str) -> Result<Self> {
        Self::from_text(text, "<config>")
    }

    /// Load from a file path; rejection errors carry `path:line`.
    pub fn from_file(path: &std::path::Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)?;
        Self::from_text(&text, &path.display().to_string())
    }

    fn from_text(text: &str, origin: &str) -> Result<Self> {
        let (tree, spans) =
            parse_spanned(text).map_err(|e| Error::Config(format!("{origin}: {e}")))?;
        Self::from_tree(&tree, &spans, origin)
    }

    /// Build from a parsed tree (closed-world over the route sections;
    /// every other section is someone else's and ignored).
    pub fn from_tree(tree: &Tree, spans: &Spans, origin: &str) -> Result<Self> {
        reject_unknown(tree, spans, origin)?;
        let mut cfg = RouteConfig::default();

        cfg.cols = get_usize(tree, "route", "cols", cfg.cols)?;
        cfg.probe_interval_ms =
            get_u64(tree, "route", "probe_interval_ms", cfg.probe_interval_ms)?;
        cfg.retry_backoff_ms = get_u64(tree, "route", "retry_backoff_ms", cfg.retry_backoff_ms)?;
        cfg.retry_backoff_max_ms =
            get_u64(tree, "route", "retry_backoff_max_ms", cfg.retry_backoff_max_ms)?;
        cfg.retry_attempts = get_usize(tree, "route", "retry_attempts", cfg.retry_attempts)?;
        cfg.io_timeout_ms = get_u64(tree, "route", "io_timeout_ms", cfg.io_timeout_ms)?;

        // `[[route.backend]]` elements surface as `route.backend.{n}`
        // sections (see config::toml); sort the suffixes numerically —
        // the BTreeMap's lexicographic order would put `10` before `2`.
        let mut indices: Vec<usize> = Vec::new();
        for section in tree.keys() {
            if let Some(suffix) = section.strip_prefix("route.backend.") {
                match suffix.parse::<usize>() {
                    Ok(n) => indices.push(n),
                    Err(_) => {
                        return Err(Error::Config(format!(
                            "{origin}: unknown section [{section}]"
                        )))
                    }
                }
            }
        }
        indices.sort_unstable();
        for n in indices {
            let sec = format!("route.backend.{n}");
            let addr = match tree.get(&sec).and_then(|s| s.get("addr")) {
                Some(Value::Str(s)) => s.clone(),
                Some(_) => {
                    return Err(Error::Config(format!("[{sec}] addr must be a string")))
                }
                None => {
                    let line = spans
                        .section_line(&sec)
                        .map(|l| format!("{origin}:{l}"))
                        .unwrap_or_else(|| origin.to_string());
                    return Err(Error::Config(format!(
                        "{line}: [[route.backend]] requires `addr`"
                    )));
                }
            };
            cfg.backends.push(RouteBackend { addr });
        }

        cfg.validate()?;
        Ok(cfg)
    }

    /// Cross-field checks shared by file parsing and CLI overrides.
    pub fn validate(&self) -> Result<()> {
        if self.backends.is_empty() {
            return Err(Error::Config(
                "[route] requires at least one [[route.backend]]".into(),
            ));
        }
        for (i, b) in self.backends.iter().enumerate() {
            if b.addr.trim().is_empty() {
                return Err(Error::Config(format!(
                    "[[route.backend]] #{i} addr must not be empty"
                )));
            }
        }
        if self.cols == 0 {
            return Err(Error::Config("[route] cols must be > 0".into()));
        }
        if self.retry_attempts == 0 {
            return Err(Error::Config("[route] retry_attempts must be > 0".into()));
        }
        if self.retry_backoff_max_ms < self.retry_backoff_ms {
            return Err(Error::Config(
                "[route] retry_backoff_max_ms must be >= retry_backoff_ms".into(),
            ));
        }
        if self.probe_interval_ms == 0 {
            return Err(Error::Config("[route] probe_interval_ms must be > 0".into()));
        }
        Ok(())
    }
}

/// Closed-world check over the route sections only (the rest of the
/// file belongs to `ServeConfig` / `ExperimentConfig`).
fn reject_unknown(tree: &Tree, spans: &Spans, origin: &str) -> Result<()> {
    let at = |sec: &str, key: &str| -> String {
        spans
            .key_line(sec, key)
            .or_else(|| spans.section_line(sec))
            .map(|l| format!("{origin}:{l}"))
            .unwrap_or_else(|| origin.to_string())
    };
    for (section, keys) in tree {
        let allowed: &[&str] = if section == "route" {
            ROUTE_KEYS
        } else if section.starts_with("route.backend.") {
            BACKEND_KEYS
        } else if section == "route.backend" || section.starts_with("route.") {
            return Err(Error::Config(format!(
                "{}: unknown section [{section}]",
                spans
                    .section_line(section)
                    .map(|l| format!("{origin}:{l}"))
                    .unwrap_or_else(|| origin.to_string())
            )));
        } else {
            continue;
        };
        for key in keys.keys() {
            if !allowed.contains(&key.as_str()) {
                return Err(Error::Config(format!(
                    "{}: unknown key `{key}` in [{section}]",
                    at(section, key)
                )));
            }
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    const EXAMPLE: &str = r#"
[server]
port = 7900

[route]
cols = 40
probe_interval_ms = 100
retry_backoff_ms = 10
retry_backoff_max_ms = 80
retry_attempts = 2
io_timeout_ms = 500

[[route.backend]]
addr = "127.0.0.1:7878"

[[route.backend]]
addr = "127.0.0.1:7879"
"#;

    #[test]
    fn parses_fleet_in_declaration_order() {
        let cfg = RouteConfig::from_str(EXAMPLE).unwrap();
        assert_eq!(cfg.cols, 40);
        assert_eq!(cfg.probe_interval_ms, 100);
        assert_eq!(cfg.retry_attempts, 2);
        assert_eq!(cfg.io_timeout_ms, 500);
        assert_eq!(
            cfg.backends,
            vec![
                RouteBackend { addr: "127.0.0.1:7878".into() },
                RouteBackend { addr: "127.0.0.1:7879".into() },
            ]
        );
    }

    #[test]
    fn backend_order_is_numeric_not_lexicographic() {
        // 11 backends: lexicographic section order would visit
        // `route.backend.10` before `route.backend.2`.
        let mut text = String::from("[route]\ncols = 44\n");
        for i in 0..11 {
            text.push_str(&format!("[[route.backend]]\naddr = \"h:{}\"\n", 7000 + i));
        }
        let cfg = RouteConfig::from_str(&text).unwrap();
        let ports: Vec<String> = cfg
            .backends
            .iter()
            .map(|b| b.addr.rsplit(':').next().unwrap().to_string())
            .collect();
        let want: Vec<String> = (0..11).map(|i| (7000 + i).to_string()).collect();
        assert_eq!(ports, want);
    }

    #[test]
    fn rejects_unknown_keys_and_sections_with_location() {
        let e = RouteConfig::from_str("[route]\nbogus = 1\n[[route.backend]]\naddr = \"a:1\"\n")
            .unwrap_err();
        assert!(e.to_string().contains("unknown key `bogus`"), "{e}");
        assert!(e.to_string().contains(":2"), "{e}");
        let e = RouteConfig::from_str(
            "[route.frontend]\nx = 1\n[[route.backend]]\naddr = \"a:1\"\n",
        )
        .unwrap_err();
        assert!(e.to_string().contains("unknown section"), "{e}");
        let e = RouteConfig::from_str("[[route.backend]]\nhost = \"a\"\n").unwrap_err();
        assert!(e.to_string().contains("unknown key `host`"), "{e}");
    }

    #[test]
    fn validates_fleet_and_policy() {
        assert!(RouteConfig::from_str("[route]\ncols = 40\n").is_err());
        let e = RouteConfig::from_str("[[route.backend]]\n# no addr\n").unwrap_err();
        assert!(e.to_string().contains("requires `addr`"), "{e}");
        let e = RouteConfig::from_str(
            "[route]\ncols = 0\n[[route.backend]]\naddr = \"a:1\"\n",
        )
        .unwrap_err();
        assert!(e.to_string().contains("cols"), "{e}");
        let e = RouteConfig::from_str(
            "[route]\nretry_backoff_ms = 100\nretry_backoff_max_ms = 10\n[[route.backend]]\naddr = \"a:1\"\n",
        )
        .unwrap_err();
        assert!(e.to_string().contains("retry_backoff_max_ms"), "{e}");
    }

    #[test]
    fn presence_probe_sees_either_section_form() {
        let (tree, _) = parse_spanned("[route]\ncols = 1\n").unwrap();
        assert!(RouteConfig::present(&tree));
        let (tree, _) = parse_spanned("[[route.backend]]\naddr = \"a:1\"\n").unwrap();
        assert!(RouteConfig::present(&tree));
        let (tree, _) = parse_spanned("[server]\nport = 1\n").unwrap();
        assert!(!RouteConfig::present(&tree));
    }

    #[test]
    fn shipped_example_parses_route_tier() {
        // The repo-root lshmf.toml carries a live `[route]` block; keep
        // it parseable by the typed config, mirroring
        // `config::serve::tests::shipped_example_round_trips`.
        let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
            .parent()
            .expect("crate dir has a parent")
            .join("lshmf.toml");
        let cfg = RouteConfig::from_file(&path).expect("shipped lshmf.toml parses as RouteConfig");
        assert!(!cfg.backends.is_empty());
        assert!(cfg.cols > 0);
    }
}
