//! Typed experiment configuration with defaults matching the paper's
//! Tables 3 and 5, plus validation.

use super::toml::{parse, Tree, Value};
use crate::{Error, Result};

/// Which synthetic dataset family to generate (§Substitutions of
/// DESIGN.md: calibrated to the paper's Table 2).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DatasetChoice {
    Netflix,
    Movielens,
    YahooMusic,
    /// Small implicit-feedback set (Table 10 protocol).
    Implicit,
}

impl DatasetChoice {
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "netflix" => DatasetChoice::Netflix,
            "movielens" => DatasetChoice::Movielens,
            "yahoo" | "yahoomusic" | "yahoo_music" => DatasetChoice::YahooMusic,
            "implicit" => DatasetChoice::Implicit,
            other => return Err(Error::Config(format!("unknown dataset `{other}`"))),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            DatasetChoice::Netflix => "netflix",
            DatasetChoice::Movielens => "movielens",
            DatasetChoice::YahooMusic => "yahoo",
            DatasetChoice::Implicit => "implicit",
        }
    }
}

/// Neighbour-search engine choice (Fig. 7 comparators).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LshChoice {
    /// The paper's contribution (Eq. 3 + p/q amplification).
    SimLsh,
    /// Random projection on cosine distance.
    RpCos,
    /// minHash on Jaccard similarity.
    MinHash,
    /// Random Top-K control group.
    Rand,
    /// Exact O(N²) graph similarity matrix.
    Gsm,
}

impl LshChoice {
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "simlsh" => LshChoice::SimLsh,
            "rpcos" | "rp_cos" => LshChoice::RpCos,
            "minhash" => LshChoice::MinHash,
            "rand" | "random" => LshChoice::Rand,
            "gsm" => LshChoice::Gsm,
            other => return Err(Error::Config(format!("unknown lsh `{other}`"))),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            LshChoice::SimLsh => "simlsh",
            LshChoice::RpCos => "rp_cos",
            LshChoice::MinHash => "minhash",
            LshChoice::Rand => "rand",
            LshChoice::Gsm => "gsm",
        }
    }
}

/// Trainer selection (Table 4 / Table 6 rows).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TrainerChoice {
    /// Serial biased SGD (the paper's "Serial" baseline).
    Serial,
    /// Block-parallel SGD — the CUSGD++ analogue.
    Sgd,
    /// Lock-free data-parallel SGD — the cuSGD analogue.
    Hogwild,
    /// Alternating least squares — the cuALS analogue.
    Als,
    /// Cyclic coordinate descent (CCD++).
    Ccd,
    /// The headline neighbourhood model (CULSH-MF).
    Culsh,
}

impl TrainerChoice {
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "serial" => TrainerChoice::Serial,
            "sgd" | "cusgd" | "cusgd++" => TrainerChoice::Sgd,
            "hogwild" => TrainerChoice::Hogwild,
            "als" => TrainerChoice::Als,
            "ccd" => TrainerChoice::Ccd,
            "culsh" | "culsh-mf" | "culshmf" => TrainerChoice::Culsh,
            other => return Err(Error::Config(format!("unknown trainer `{other}`"))),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            TrainerChoice::Serial => "serial",
            TrainerChoice::Sgd => "sgd",
            TrainerChoice::Hogwild => "hogwild",
            TrainerChoice::Als => "als",
            TrainerChoice::Ccd => "ccd",
            TrainerChoice::Culsh => "culsh",
        }
    }
}

/// `[dataset]` section.
#[derive(Clone, Debug)]
pub struct DatasetSection {
    pub kind: DatasetChoice,
    /// Linear scale factor applied to (M, N); nnz scales quadratically.
    pub scale: f64,
    pub seed: u64,
    /// Fraction of values perturbed for robustness experiments (Table 8).
    pub noise_rate: f64,
}

impl Default for DatasetSection {
    fn default() -> Self {
        DatasetSection {
            kind: DatasetChoice::Movielens,
            scale: 0.1,
            seed: 42,
            noise_rate: 0.0,
        }
    }
}

/// `[model]` section: latent dims.
#[derive(Clone, Debug)]
pub struct ModelConfig {
    /// Latent factor dimension F.
    pub f: usize,
    /// Neighbourhood size K (Top-K).
    pub k: usize,
}

impl Default for ModelConfig {
    fn default() -> Self {
        ModelConfig { f: 32, k: 32 }
    }
}

/// `[trainer]` section — learning-rate schedule of Eq. (7) plus
/// regularization (paper Tables 3 & 5).
#[derive(Clone, Debug)]
pub struct TrainerSection {
    pub kind: TrainerChoice,
    pub epochs: usize,
    /// Initial learning rate α of Eq. (7).
    pub alpha: f64,
    /// Decay β of Eq. (7): γ_t = α / (1 + β t^1.5).
    pub beta: f64,
    pub lambda_u: f64,
    pub lambda_v: f64,
    pub lambda_b: f64,
    pub lambda_w: f64,
    pub lambda_c: f64,
    /// Learning-rate for the neighbourhood parameters (α_w, α_c).
    pub alpha_wc: f64,
    pub threads: usize,
}

impl Default for TrainerSection {
    fn default() -> Self {
        TrainerSection {
            kind: TrainerChoice::Culsh,
            epochs: 20,
            alpha: 0.035,
            beta: 0.3,
            lambda_u: 0.02,
            lambda_v: 0.02,
            lambda_b: 0.02,
            lambda_w: 0.002,
            lambda_c: 0.002,
            alpha_wc: 0.002,
            threads: 4,
        }
    }
}

/// `[lsh]` section (paper §5.3: G=8, p=3, q=100, λ_ρ=100, Ψ=r²).
#[derive(Clone, Debug)]
pub struct OnlineConfig {
    /// Fraction of rows/cols held out as the "new" variable sets (Table 9).
    pub holdout: f64,
    pub epochs: usize,
}

impl Default for OnlineConfig {
    fn default() -> Self {
        OnlineConfig { holdout: 0.01, epochs: 5 }
    }
}

#[derive(Clone, Debug)]
pub struct LshSection {
    pub kind: LshChoice,
    /// Coarse-grained AND width p.
    pub p: usize,
    /// Fine-grained OR count q.
    pub q: usize,
    /// Hash width in bits (G).
    pub g: usize,
    /// Pearson shrinkage λ_ρ for the GSM.
    pub lambda_rho: f64,
    /// Ψ(r) = r^psi_power (2 for Netflix/MovieLens, 4 for Yahoo).
    pub psi_power: u32,
}

impl Default for LshSection {
    fn default() -> Self {
        LshSection {
            kind: LshChoice::SimLsh,
            p: 3,
            q: 100,
            g: 8,
            lambda_rho: 100.0,
            psi_power: 2,
        }
    }
}

/// `[rotation]` section — multi-device simulation (Fig. 5).
#[derive(Clone, Debug)]
pub struct RotationConfig {
    /// Number of simulated devices D.
    pub workers: usize,
    /// Virtual transfer cost per factor byte relative to one nnz update.
    pub link_cost: f64,
}

impl Default for RotationConfig {
    fn default() -> Self {
        RotationConfig { workers: 1, link_cost: 0.05 }
    }
}

/// Whole-experiment configuration.
#[derive(Clone, Debug, Default)]
pub struct ExperimentConfig {
    pub dataset: DatasetSection,
    pub model: ModelConfig,
    pub trainer: TrainerSection,
    pub lsh: LshSection,
    pub online: OnlineConfig,
    pub rotation: RotationConfig,
}

fn get_int(tree: &Tree, sec: &str, key: &str, default: i64) -> Result<i64> {
    match tree.get(sec).and_then(|s| s.get(key)) {
        None => Ok(default),
        Some(v) => v
            .as_int()
            .ok_or_else(|| Error::Config(format!("[{sec}] {key} must be an integer"))),
    }
}

fn get_float(tree: &Tree, sec: &str, key: &str, default: f64) -> Result<f64> {
    match tree.get(sec).and_then(|s| s.get(key)) {
        None => Ok(default),
        Some(v) => v
            .as_float()
            .ok_or_else(|| Error::Config(format!("[{sec}] {key} must be a number"))),
    }
}

fn get_str<'t>(tree: &'t Tree, sec: &str, key: &str) -> Result<Option<&'t str>> {
    match tree.get(sec).and_then(|s| s.get(key)) {
        None => Ok(None),
        Some(Value::Str(s)) => Ok(Some(s)),
        Some(_) => Err(Error::Config(format!("[{sec}] {key} must be a string"))),
    }
}

impl ExperimentConfig {
    /// Parse from TOML-subset text, filling defaults and validating.
    pub fn from_str(text: &str) -> Result<Self> {
        let tree = parse(text).map_err(Error::Config)?;
        Self::from_tree(&tree)
    }

    /// Load from a file path.
    pub fn from_file(path: &std::path::Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)?;
        Self::from_str(&text)
    }

    pub fn from_tree(tree: &Tree) -> Result<Self> {
        let mut cfg = ExperimentConfig::default();

        if let Some(kind) = get_str(tree, "dataset", "kind")? {
            cfg.dataset.kind = DatasetChoice::parse(kind)?;
        }
        cfg.dataset.scale = get_float(tree, "dataset", "scale", cfg.dataset.scale)?;
        cfg.dataset.seed = get_int(tree, "dataset", "seed", cfg.dataset.seed as i64)? as u64;
        cfg.dataset.noise_rate = get_float(tree, "dataset", "noise_rate", cfg.dataset.noise_rate)?;

        cfg.model.f = get_int(tree, "model", "f", cfg.model.f as i64)? as usize;
        cfg.model.k = get_int(tree, "model", "k", cfg.model.k as i64)? as usize;

        if let Some(kind) = get_str(tree, "trainer", "kind")? {
            cfg.trainer.kind = TrainerChoice::parse(kind)?;
        }
        cfg.trainer.epochs = get_int(tree, "trainer", "epochs", cfg.trainer.epochs as i64)? as usize;
        cfg.trainer.alpha = get_float(tree, "trainer", "alpha", cfg.trainer.alpha)?;
        cfg.trainer.beta = get_float(tree, "trainer", "beta", cfg.trainer.beta)?;
        cfg.trainer.lambda_u = get_float(tree, "trainer", "lambda_u", cfg.trainer.lambda_u)?;
        cfg.trainer.lambda_v = get_float(tree, "trainer", "lambda_v", cfg.trainer.lambda_v)?;
        cfg.trainer.lambda_b = get_float(tree, "trainer", "lambda_b", cfg.trainer.lambda_b)?;
        cfg.trainer.lambda_w = get_float(tree, "trainer", "lambda_w", cfg.trainer.lambda_w)?;
        cfg.trainer.lambda_c = get_float(tree, "trainer", "lambda_c", cfg.trainer.lambda_c)?;
        cfg.trainer.alpha_wc = get_float(tree, "trainer", "alpha_wc", cfg.trainer.alpha_wc)?;
        cfg.trainer.threads = get_int(tree, "trainer", "threads", cfg.trainer.threads as i64)? as usize;

        if let Some(kind) = get_str(tree, "lsh", "kind")? {
            cfg.lsh.kind = LshChoice::parse(kind)?;
        }
        cfg.lsh.p = get_int(tree, "lsh", "p", cfg.lsh.p as i64)? as usize;
        cfg.lsh.q = get_int(tree, "lsh", "q", cfg.lsh.q as i64)? as usize;
        cfg.lsh.g = get_int(tree, "lsh", "g", cfg.lsh.g as i64)? as usize;
        cfg.lsh.lambda_rho = get_float(tree, "lsh", "lambda_rho", cfg.lsh.lambda_rho)?;
        cfg.lsh.psi_power = get_int(tree, "lsh", "psi_power", cfg.lsh.psi_power as i64)? as u32;

        cfg.online.holdout = get_float(tree, "online", "holdout", cfg.online.holdout)?;
        cfg.online.epochs = get_int(tree, "online", "epochs", cfg.online.epochs as i64)? as usize;

        cfg.rotation.workers =
            get_int(tree, "rotation", "workers", cfg.rotation.workers as i64)? as usize;
        cfg.rotation.link_cost =
            get_float(tree, "rotation", "link_cost", cfg.rotation.link_cost)?;

        cfg.validate()?;
        Ok(cfg)
    }

    pub fn validate(&self) -> Result<()> {
        let bad = |m: String| Err(Error::Config(m));
        if self.model.f == 0 {
            return bad("model.f must be positive".into());
        }
        if self.model.k == 0 {
            return bad("model.k must be positive".into());
        }
        if !(0.0..=1.0).contains(&self.dataset.noise_rate) {
            return bad("dataset.noise_rate must be in [0,1]".into());
        }
        if self.dataset.scale <= 0.0 || self.dataset.scale > 1.0 {
            return bad("dataset.scale must be in (0,1]".into());
        }
        if self.lsh.p == 0 || self.lsh.q == 0 {
            return bad("lsh.p and lsh.q must be positive".into());
        }
        if self.lsh.g == 0 || self.lsh.g > 64 {
            return bad("lsh.g must be in 1..=64".into());
        }
        if self.trainer.alpha <= 0.0 {
            return bad("trainer.alpha must be positive".into());
        }
        if self.rotation.workers == 0 {
            return bad("rotation.workers must be positive".into());
        }
        if !(0.0..1.0).contains(&self.online.holdout) {
            return bad("online.holdout must be in [0,1)".into());
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_are_valid() {
        ExperimentConfig::default().validate().unwrap();
    }

    #[test]
    fn parses_choices() {
        assert_eq!(TrainerChoice::parse("cusgd++").unwrap(), TrainerChoice::Sgd);
        assert_eq!(LshChoice::parse("rp_cos").unwrap(), LshChoice::RpCos);
        assert_eq!(
            DatasetChoice::parse("yahoo").unwrap(),
            DatasetChoice::YahooMusic
        );
        assert!(TrainerChoice::parse("nope").is_err());
    }

    #[test]
    fn validation_catches_bad_ranges() {
        let mut cfg = ExperimentConfig::default();
        cfg.lsh.g = 65;
        assert!(cfg.validate().is_err());
        let mut cfg = ExperimentConfig::default();
        cfg.dataset.scale = 0.0;
        assert!(cfg.validate().is_err());
        let mut cfg = ExperimentConfig::default();
        cfg.model.f = 0;
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn names_roundtrip() {
        for c in [
            TrainerChoice::Serial,
            TrainerChoice::Sgd,
            TrainerChoice::Hogwild,
            TrainerChoice::Als,
            TrainerChoice::Ccd,
            TrainerChoice::Culsh,
        ] {
            assert_eq!(TrainerChoice::parse(c.name()).unwrap(), c);
        }
        for l in [
            LshChoice::SimLsh,
            LshChoice::RpCos,
            LshChoice::MinHash,
            LshChoice::Rand,
            LshChoice::Gsm,
        ] {
            assert_eq!(LshChoice::parse(l.name()).unwrap(), l);
        }
    }
}
