//! Typed serving configuration: the `[server]` / `[engine]` / `[flush]`
//! / `[limits]` / `[metrics]` / `[persist]` sections of
//! `serve --config lshmf.toml`.
//!
//! The whole operational surface of the serving stack is one validated
//! struct ([`ServeConfig`]): which engine flavour to run, how wide the
//! connection pool and per-connection read lanes are, the flush policy,
//! per-client admission limits, and the Prometheus exporter. CLI flags
//! (`--port`, `--writers`, `--flush-mode`, …) desugar into the same
//! struct as overrides (see `cli::Args::serve_config`), so there is
//! exactly one place where serving knobs are defined, defaulted, and
//! cross-validated.
//!
//! Unlike [`ExperimentConfig`](super::ExperimentConfig) (which ignores
//! sections it does not own, so one file can carry both configs), the
//! serve sections are **closed**: an unknown key inside any of the six
//! serve sections, or an unknown section altogether, is rejected with
//! the exact `file:line` of the offender — the zero-dep analogue of
//! serde's `deny_unknown_fields`.

use super::toml::{parse_spanned, Spans, Tree, Value};
use crate::coordinator::protocol::CodecChoice;
use crate::coordinator::server::CONN_READ_WORKERS;
use crate::coordinator::shared::DEFAULT_SHARDS;
use crate::coordinator::stream::{FlushMode, StreamConfig};
use crate::persist::FsyncPolicy;
use crate::{Error, Result};

/// Which serving flavour `serve` runs (`[engine] mode`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EngineMode {
    /// The fully-serialized `Mutex<Engine>` reference flavour.
    Mutex,
    /// Epoch-swapped snapshots over a single writer thread (the
    /// default; `shards` column bands per publish).
    Sharded,
    /// Per-column-band multi-writer ingest (`writers` write queues).
    Banded,
}

impl EngineMode {
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "mutex" => EngineMode::Mutex,
            "sharded" => EngineMode::Sharded,
            "banded" => EngineMode::Banded,
            other => {
                return Err(Error::Config(format!(
                    "[engine] mode must be one of mutex|sharded|banded (got `{other}`)"
                )))
            }
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            EngineMode::Mutex => "mutex",
            EngineMode::Sharded => "sharded",
            EngineMode::Banded => "banded",
        }
    }
}

/// `[server]` — the TCP front end.
#[derive(Clone, Debug)]
pub struct ServerSection {
    /// Listen port.
    pub port: u16,
    /// Connection-pool width (how many connections are served at once).
    pub threads: usize,
    /// Read workers per binary connection (out-of-order read lanes);
    /// the former hard-coded `CONN_READ_WORKERS`.
    pub read_workers: usize,
    /// Wire codec policy (`auto` detects per connection).
    pub codec: CodecChoice,
}

impl Default for ServerSection {
    fn default() -> Self {
        ServerSection {
            port: 7878,
            threads: 4,
            read_workers: CONN_READ_WORKERS,
            codec: CodecChoice::Auto,
        }
    }
}

/// `[engine]` — serving flavour selection.
#[derive(Clone, Debug)]
pub struct EngineSection {
    pub mode: EngineMode,
    /// Band-writer count; meaningful (and required > 0) only in banded
    /// mode.
    pub writers: usize,
    /// Snapshot shard count for the sharded flavour.
    pub shards: usize,
}

impl Default for EngineSection {
    fn default() -> Self {
        EngineSection { mode: EngineMode::Sharded, writers: 0, shards: DEFAULT_SHARDS }
    }
}

/// `[flush]` — the stream orchestrator's batching and flush policy
/// (maps onto [`StreamConfig`] via [`ServeConfig::stream_config`]).
#[derive(Clone, Debug)]
pub struct FlushSection {
    pub mode: FlushMode,
    /// Relaxed-rotation lane count; `0` derives it (writers in banded
    /// mode, else the pool width) exactly like the legacy CLI did.
    pub bands: usize,
    pub batch_size: usize,
    pub queue_capacity: usize,
    pub online_epochs: usize,
    pub reject_when_full: bool,
}

impl Default for FlushSection {
    fn default() -> Self {
        let s = StreamConfig::default();
        FlushSection {
            mode: FlushMode::Exact,
            bands: 0,
            batch_size: s.batch_size,
            queue_capacity: s.queue_capacity,
            online_epochs: s.online_epochs,
            reject_when_full: s.reject_when_full,
        }
    }
}

/// `[limits]` — per-client admission control. Every limit defaults to
/// `0` = off, so a config without the section serves exactly like the
/// pre-admission server.
#[derive(Clone, Debug)]
pub struct LimitsSection {
    /// Token-bucket refill rate per connection, requests/second
    /// (`0` = unlimited). A drained bucket answers
    /// `ErrorKind::Overloaded`.
    pub rate_per_conn: u32,
    /// Token-bucket capacity (burst size); must be > 0 when
    /// `rate_per_conn` is set.
    pub burst: u32,
    /// Slow-reader eviction: a reply or push write blocked longer than
    /// this is abandoned and the connection dropped (`0` = wait
    /// forever).
    pub write_deadline_ms: u64,
    /// Load shedding: once a connection has this many reads queued and
    /// unfinished, further `TOPN`/`MPREDICT` are shed with
    /// `ErrorKind::Overloaded` while `RATE`/`MRATE` stay admitted
    /// (`0` = never shed).
    pub shed_highwater: usize,
}

impl Default for LimitsSection {
    fn default() -> Self {
        LimitsSection { rate_per_conn: 0, burst: 64, write_deadline_ms: 0, shed_highwater: 0 }
    }
}

/// `[metrics]` — the Prometheus text-format exporter.
#[derive(Clone, Debug)]
pub struct MetricsSection {
    /// Serve `GET /metrics` (exposition format) when true.
    pub enabled: bool,
    /// Exporter port (must differ from `[server] port`).
    pub port: u16,
}

impl Default for MetricsSection {
    fn default() -> Self {
        MetricsSection { enabled: false, port: 9878 }
    }
}

/// `[persist]` — durability: per-band write-ahead logs plus
/// checkpointed snapshots (see [`crate::persist`]). Off by default — an
/// empty `dir` disables the whole subsystem, so a config without the
/// section serves exactly like the pre-durability server.
#[derive(Clone, Debug)]
pub struct PersistSection {
    /// Directory for WAL segments and checkpoints; `""` = durability
    /// off.
    pub dir: String,
    /// WAL fsync policy; `None` defaults to `per_flush` when enabled.
    pub fsync: Option<FsyncPolicy>,
    /// Write a checkpoint every N applied flushes (must be >= 1).
    pub checkpoint_every_flushes: usize,
}

impl Default for PersistSection {
    fn default() -> Self {
        PersistSection { dir: String::new(), fsync: None, checkpoint_every_flushes: 1 }
    }
}

impl PersistSection {
    /// Durability is on iff a directory is configured.
    pub fn enabled(&self) -> bool {
        !self.dir.is_empty()
    }

    /// The effective fsync policy (`per_flush` unless overridden).
    pub fn fsync_policy(&self) -> FsyncPolicy {
        self.fsync.unwrap_or(FsyncPolicy::PerFlush)
    }
}

/// The whole typed serving configuration; see the module docs.
#[derive(Clone, Debug, Default)]
pub struct ServeConfig {
    pub server: ServerSection,
    pub engine: EngineSection,
    pub flush: FlushSection,
    pub limits: LimitsSection,
    pub metrics: MetricsSection,
    pub persist: PersistSection,
}

/// The closed serve sections and their allowed keys.
const SERVE_SECTIONS: [(&str, &[&str]); 6] = [
    ("server", &["port", "threads", "read_workers", "codec"]),
    ("engine", &["mode", "writers", "shards"]),
    (
        "flush",
        &["mode", "bands", "batch_size", "queue_capacity", "online_epochs", "reject_when_full"],
    ),
    ("limits", &["rate_per_conn", "burst", "write_deadline_ms", "shed_highwater"]),
    ("metrics", &["enabled", "port"]),
    ("persist", &["dir", "fsync", "checkpoint_every_flushes"]),
];

/// Sections owned by [`ExperimentConfig`](super::ExperimentConfig) —
/// tolerated so one `lshmf.toml` carries both configs. `""` is the
/// root section (keys before any header).
const EXPERIMENT_SECTIONS: [&str; 7] =
    ["", "dataset", "model", "trainer", "lsh", "online", "rotation"];

fn get_usize(tree: &Tree, sec: &str, key: &str, default: usize) -> Result<usize> {
    match tree.get(sec).and_then(|s| s.get(key)) {
        None => Ok(default),
        Some(v) => match v.as_int() {
            Some(i) if i >= 0 => Ok(i as usize),
            Some(_) => Err(Error::Config(format!("[{sec}] {key} must not be negative"))),
            None => Err(Error::Config(format!("[{sec}] {key} must be an integer"))),
        },
    }
}

fn get_port(tree: &Tree, sec: &str, key: &str, default: u16) -> Result<u16> {
    let v = get_usize(tree, sec, key, default as usize)?;
    if v == 0 || v > u16::MAX as usize {
        return Err(Error::Config(format!("[{sec}] {key} must be in 1..=65535")));
    }
    Ok(v as u16)
}

fn get_bool(tree: &Tree, sec: &str, key: &str, default: bool) -> Result<bool> {
    match tree.get(sec).and_then(|s| s.get(key)) {
        None => Ok(default),
        Some(v) => v
            .as_bool()
            .ok_or_else(|| Error::Config(format!("[{sec}] {key} must be true or false"))),
    }
}

fn get_str<'t>(tree: &'t Tree, sec: &str, key: &str) -> Result<Option<&'t str>> {
    match tree.get(sec).and_then(|s| s.get(key)) {
        None => Ok(None),
        Some(Value::Str(s)) => Ok(Some(s)),
        Some(_) => Err(Error::Config(format!("[{sec}] {key} must be a string"))),
    }
}

/// Parse a codec name (`[server] codec` / `--codec`).
pub fn parse_codec(s: &str) -> Result<CodecChoice> {
    Ok(match s {
        "text" => CodecChoice::Text,
        "binary" => CodecChoice::Binary,
        "auto" => CodecChoice::Auto,
        other => {
            return Err(Error::Config(format!(
                "codec must be one of text|binary|auto (got `{other}`)"
            )))
        }
    })
}

/// Parse a flush-mode name (`[flush] mode` / `--flush-mode`).
pub fn parse_flush_mode(s: &str) -> Result<FlushMode> {
    Ok(match s {
        "exact" => FlushMode::Exact,
        "relaxed" => FlushMode::Relaxed,
        other => {
            return Err(Error::Config(format!(
                "flush mode must be one of exact|relaxed (got `{other}`)"
            )))
        }
    })
}

impl ServeConfig {
    /// Parse from TOML-subset text, filling defaults and validating.
    pub fn from_str(text: &str) -> Result<Self> {
        Self::from_text(text, "<config>")
    }

    /// Load from a file path; rejection errors carry `path:line`.
    pub fn from_file(path: &std::path::Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)?;
        Self::from_text(&text, &path.display().to_string())
    }

    fn from_text(text: &str, origin: &str) -> Result<Self> {
        let (tree, spans) =
            parse_spanned(text).map_err(|e| Error::Config(format!("{origin}: {e}")))?;
        Self::from_tree(&tree, &spans, origin)
    }

    /// Build from a parsed tree. `origin` names the source (a path or
    /// `<config>`) in unknown-key/unknown-section rejections.
    pub fn from_tree(tree: &Tree, spans: &Spans, origin: &str) -> Result<Self> {
        reject_unknown(tree, spans, origin)?;
        let mut cfg = ServeConfig::default();

        cfg.server.port = get_port(tree, "server", "port", cfg.server.port)?;
        cfg.server.threads = get_usize(tree, "server", "threads", cfg.server.threads)?;
        cfg.server.read_workers =
            get_usize(tree, "server", "read_workers", cfg.server.read_workers)?;
        if let Some(codec) = get_str(tree, "server", "codec")? {
            cfg.server.codec = parse_codec(codec)?;
        }

        if let Some(mode) = get_str(tree, "engine", "mode")? {
            cfg.engine.mode = EngineMode::parse(mode)?;
        }
        cfg.engine.writers = get_usize(tree, "engine", "writers", cfg.engine.writers)?;
        cfg.engine.shards = get_usize(tree, "engine", "shards", cfg.engine.shards)?;

        if let Some(mode) = get_str(tree, "flush", "mode")? {
            cfg.flush.mode = parse_flush_mode(mode)?;
        }
        cfg.flush.bands = get_usize(tree, "flush", "bands", cfg.flush.bands)?;
        cfg.flush.batch_size = get_usize(tree, "flush", "batch_size", cfg.flush.batch_size)?;
        cfg.flush.queue_capacity =
            get_usize(tree, "flush", "queue_capacity", cfg.flush.queue_capacity)?;
        cfg.flush.online_epochs =
            get_usize(tree, "flush", "online_epochs", cfg.flush.online_epochs)?;
        cfg.flush.reject_when_full =
            get_bool(tree, "flush", "reject_when_full", cfg.flush.reject_when_full)?;

        cfg.limits.rate_per_conn =
            get_usize(tree, "limits", "rate_per_conn", cfg.limits.rate_per_conn as usize)? as u32;
        cfg.limits.burst = get_usize(tree, "limits", "burst", cfg.limits.burst as usize)? as u32;
        cfg.limits.write_deadline_ms =
            get_usize(tree, "limits", "write_deadline_ms", cfg.limits.write_deadline_ms as usize)?
                as u64;
        cfg.limits.shed_highwater =
            get_usize(tree, "limits", "shed_highwater", cfg.limits.shed_highwater)?;

        cfg.metrics.enabled = get_bool(tree, "metrics", "enabled", cfg.metrics.enabled)?;
        cfg.metrics.port = get_port(tree, "metrics", "port", cfg.metrics.port)?;

        if let Some(dir) = get_str(tree, "persist", "dir")? {
            cfg.persist.dir = dir.to_string();
        }
        if let Some(policy) = get_str(tree, "persist", "fsync")? {
            cfg.persist.fsync = Some(FsyncPolicy::parse(policy).ok_or_else(|| {
                Error::Config(format!(
                    "[persist] fsync must be one of per_record|per_flush|off (got `{policy}`)"
                ))
            })?);
        }
        cfg.persist.checkpoint_every_flushes = get_usize(
            tree,
            "persist",
            "checkpoint_every_flushes",
            cfg.persist.checkpoint_every_flushes,
        )?;

        cfg.validate()?;
        Ok(cfg)
    }

    /// Cross-field validation; every error names both fields it relates.
    pub fn validate(&self) -> Result<()> {
        let bad = |m: String| Err(Error::Config(m));
        if self.server.threads == 0 {
            return bad("[server] threads must be positive".into());
        }
        if self.server.read_workers == 0 {
            return bad("[server] read_workers must be positive".into());
        }
        if self.engine.shards == 0 {
            return bad("[engine] shards must be positive".into());
        }
        if self.engine.writers > 0 && self.engine.mode != EngineMode::Banded {
            return bad(format!(
                "[engine] writers > 0 requires mode = \"banded\" (got mode = \"{}\")",
                self.engine.mode.name()
            ));
        }
        if self.engine.mode == EngineMode::Banded && self.engine.writers == 0 {
            return bad("[engine] mode = \"banded\" requires writers > 0".into());
        }
        if self.flush.mode == FlushMode::Relaxed && self.engine.writers == 0 {
            return bad(
                "[flush] mode = \"relaxed\" requires banded mode with [engine] writers > 0"
                    .into(),
            );
        }
        if self.engine.mode == EngineMode::Banded
            && self.flush.bands > 0
            && self.flush.bands > self.engine.writers
        {
            return bad(format!(
                "[flush] bands ({}) must not exceed [engine] writers ({})",
                self.flush.bands, self.engine.writers
            ));
        }
        if self.flush.batch_size == 0 {
            return bad("[flush] batch_size must be positive".into());
        }
        if self.flush.queue_capacity < self.flush.batch_size {
            return bad(format!(
                "[flush] queue_capacity ({}) must be at least batch_size ({})",
                self.flush.queue_capacity, self.flush.batch_size
            ));
        }
        if self.limits.rate_per_conn > 0 && self.limits.burst == 0 {
            return bad("[limits] burst must be positive when rate_per_conn > 0".into());
        }
        if self.metrics.enabled && self.metrics.port == self.server.port {
            return bad(format!(
                "[metrics] port ({}) must differ from [server] port",
                self.metrics.port
            ));
        }
        if self.persist.fsync.is_some() && !self.persist.enabled() {
            return bad("[persist] fsync requires dir to be set".into());
        }
        if self.persist.checkpoint_every_flushes == 0 {
            return bad("[persist] checkpoint_every_flushes must be at least 1".into());
        }
        Ok(())
    }

    /// Resolved relaxed-rotation lane count: the explicit `[flush]
    /// bands` if set, else the band-writer count in banded mode, else
    /// the pool width — the derivation the legacy CLI flags used.
    pub fn flush_bands(&self) -> usize {
        if self.flush.bands > 0 {
            return self.flush.bands;
        }
        match self.engine.mode {
            EngineMode::Banded => self.engine.writers.max(1),
            _ => self.server.threads.max(1),
        }
    }

    /// The [`StreamConfig`] this serving configuration implies.
    pub fn stream_config(&self) -> StreamConfig {
        StreamConfig {
            batch_size: self.flush.batch_size,
            queue_capacity: self.flush.queue_capacity,
            online_epochs: self.flush.online_epochs,
            reject_when_full: self.flush.reject_when_full,
            flush_mode: self.flush.mode,
            flush_bands: self.flush_bands(),
            ..StreamConfig::default()
        }
    }
}

/// Closed-world check: unknown keys in serve sections and unknown
/// sections are rejected at their exact `origin:line`.
fn reject_unknown(tree: &Tree, spans: &Spans, origin: &str) -> Result<()> {
    for (section, keys) in tree {
        if let Some((_, allowed)) =
            SERVE_SECTIONS.iter().find(|(name, _)| name == section)
        {
            for key in keys.keys() {
                if !allowed.contains(&key.as_str()) {
                    let line = spans.key_line(section, key).unwrap_or(0);
                    return Err(Error::Config(format!(
                        "{origin}:{line}: unknown key `{key}` in [{section}]"
                    )));
                }
            }
        } else if section == "route" || section.starts_with("route.backend.") {
            // The route tier's sections share the file; they are closed
            // by `config::route::RouteConfig`, not here.
        } else if !EXPERIMENT_SECTIONS.contains(&section.as_str()) {
            let line = spans.section_line(section).unwrap_or(0);
            return Err(Error::Config(format!(
                "{origin}:{line}: unknown section [{section}]"
            )));
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_are_valid_and_legacy_shaped() {
        let cfg = ServeConfig::default();
        cfg.validate().unwrap();
        assert_eq!(cfg.server.port, 7878);
        assert_eq!(cfg.server.read_workers, CONN_READ_WORKERS);
        assert_eq!(cfg.engine.mode, EngineMode::Sharded);
        assert_eq!(cfg.engine.shards, DEFAULT_SHARDS);
        // no [limits] section -> admission entirely off
        assert_eq!(cfg.limits.rate_per_conn, 0);
        assert_eq!(cfg.limits.write_deadline_ms, 0);
        assert_eq!(cfg.limits.shed_highwater, 0);
        assert!(!cfg.metrics.enabled);
        // no [persist] section -> durability entirely off
        assert!(!cfg.persist.enabled());
        assert_eq!(cfg.persist.fsync_policy(), FsyncPolicy::PerFlush);
        assert_eq!(cfg.persist.checkpoint_every_flushes, 1);
        // derived stream config matches the legacy CLI derivation
        let s = cfg.stream_config();
        assert_eq!(s.flush_bands, cfg.server.threads);
        assert_eq!(s.flush_mode, FlushMode::Exact);
    }

    #[test]
    fn full_file_round_trips_every_section() {
        let text = r#"
# one file carries both experiment and serve config
[dataset]
kind = "movielens"

[server]
port = 9000
threads = 3
read_workers = 4
codec = "binary"

[engine]
mode = "banded"
writers = 2
shards = 16

[flush]
mode = "relaxed"
bands = 2
batch_size = 512
queue_capacity = 4096
online_epochs = 7
reject_when_full = true

[limits]
rate_per_conn = 100
burst = 16
write_deadline_ms = 1500
shed_highwater = 32

[metrics]
enabled = true
port = 9100

[persist]
dir = "/tmp/lshmf-wal"
fsync = "per_record"
checkpoint_every_flushes = 3
"#;
        let cfg = ServeConfig::from_str(text).unwrap();
        assert_eq!(cfg.server.port, 9000);
        assert_eq!(cfg.server.threads, 3);
        assert_eq!(cfg.server.read_workers, 4);
        assert_eq!(cfg.server.codec, CodecChoice::Binary);
        assert_eq!(cfg.engine.mode, EngineMode::Banded);
        assert_eq!(cfg.engine.writers, 2);
        assert_eq!(cfg.engine.shards, 16);
        assert_eq!(cfg.flush.mode, FlushMode::Relaxed);
        assert_eq!(cfg.flush.bands, 2);
        assert_eq!(cfg.flush.batch_size, 512);
        assert_eq!(cfg.flush.queue_capacity, 4096);
        assert_eq!(cfg.flush.online_epochs, 7);
        assert!(cfg.flush.reject_when_full);
        assert_eq!(cfg.limits.rate_per_conn, 100);
        assert_eq!(cfg.limits.burst, 16);
        assert_eq!(cfg.limits.write_deadline_ms, 1500);
        assert_eq!(cfg.limits.shed_highwater, 32);
        assert!(cfg.metrics.enabled);
        assert_eq!(cfg.metrics.port, 9100);
        assert!(cfg.persist.enabled());
        assert_eq!(cfg.persist.dir, "/tmp/lshmf-wal");
        assert_eq!(cfg.persist.fsync_policy(), FsyncPolicy::PerRecord);
        assert_eq!(cfg.persist.checkpoint_every_flushes, 3);
        let s = cfg.stream_config();
        assert_eq!(s.batch_size, 512);
        assert_eq!(s.flush_bands, 2);
        assert_eq!(s.flush_mode, FlushMode::Relaxed);
    }

    #[test]
    fn unknown_key_rejected_at_exact_line() {
        // line 1 is empty (leading newline), [server] on 2, port on 3,
        // the typo on line 4
        let text = "\n[server]\nport = 7878\nprot = 1\n";
        let err = ServeConfig::from_str(text).unwrap_err().to_string();
        assert!(err.contains("<config>:4: unknown key `prot` in [server]"), "{err}");
        // unknown keys in every other serve section carry their line too
        for (sec, line) in
            [("engine", 2), ("flush", 2), ("limits", 2), ("metrics", 2), ("persist", 2)]
        {
            let text = format!("[{sec}]\nbogus = 1\n");
            let err = ServeConfig::from_str(&text).unwrap_err().to_string();
            assert!(
                err.contains(&format!("<config>:{line}: unknown key `bogus` in [{sec}]")),
                "{err}"
            );
        }
    }

    #[test]
    fn unknown_section_rejected_at_header_line() {
        let err = ServeConfig::from_str("[server]\nport = 7878\n\n[serverr]\nx = 1\n")
            .unwrap_err()
            .to_string();
        assert!(err.contains("<config>:4: unknown section [serverr]"), "{err}");
        // experiment sections are tolerated: shared file
        ServeConfig::from_str("[dataset]\nkind = \"movielens\"\n[model]\nf = 8\n").unwrap();
        // route sections are tolerated too (closed by RouteConfig)
        ServeConfig::from_str(
            "[server]\nport = 7878\n[route]\ncols = 40\n[[route.backend]]\naddr = \"a:1\"\n",
        )
        .unwrap();
    }

    #[test]
    fn file_load_uses_the_path_in_rejections() {
        let dir = std::env::temp_dir().join("lshmf_serve_cfg_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad.toml");
        std::fs::write(&path, "[limits]\nrate = 5\n").unwrap();
        let err = ServeConfig::from_file(&path).unwrap_err().to_string();
        assert!(
            err.contains(&format!("{}:2: unknown key `rate` in [limits]", path.display())),
            "{err}"
        );
        std::fs::remove_file(&path).ok();
    }

    /// Every cross-field validation rule, by exact message.
    #[test]
    fn cross_field_validation_messages() {
        let cases: [(&str, &str); 13] = [
            ("[server]\nthreads = 0\n", "[server] threads must be positive"),
            ("[server]\nread_workers = 0\n", "[server] read_workers must be positive"),
            ("[engine]\nshards = 0\n", "[engine] shards must be positive"),
            (
                "[engine]\nwriters = 2\n",
                "[engine] writers > 0 requires mode = \"banded\" (got mode = \"sharded\")",
            ),
            (
                "[engine]\nmode = \"banded\"\n",
                "[engine] mode = \"banded\" requires writers > 0",
            ),
            (
                "[flush]\nmode = \"relaxed\"\n",
                "[flush] mode = \"relaxed\" requires banded mode with [engine] writers > 0",
            ),
            (
                "[engine]\nmode = \"banded\"\nwriters = 2\n[flush]\nbands = 3\n",
                "[flush] bands (3) must not exceed [engine] writers (2)",
            ),
            ("[flush]\nbatch_size = 0\n", "[flush] batch_size must be positive"),
            (
                "[flush]\nbatch_size = 100\nqueue_capacity = 10\n",
                "[flush] queue_capacity (10) must be at least batch_size (100)",
            ),
            (
                "[limits]\nrate_per_conn = 10\nburst = 0\n",
                "[limits] burst must be positive when rate_per_conn > 0",
            ),
            (
                "[server]\nport = 7878\n[metrics]\nenabled = true\nport = 7878\n",
                "[metrics] port (7878) must differ from [server] port",
            ),
            (
                "[persist]\nfsync = \"per_record\"\n",
                "[persist] fsync requires dir to be set",
            ),
            (
                "[persist]\ndir = \"/tmp/w\"\ncheckpoint_every_flushes = 0\n",
                "[persist] checkpoint_every_flushes must be at least 1",
            ),
        ];
        for (text, want) in cases {
            let err = ServeConfig::from_str(text).unwrap_err().to_string();
            assert!(err.contains(want), "config {text:?}: got {err}, want {want}");
        }
        // the valid variants of each rule parse
        ServeConfig::from_str("[engine]\nmode = \"banded\"\nwriters = 2\n[flush]\nbands = 2\n")
            .unwrap();
        ServeConfig::from_str(
            "[engine]\nmode = \"banded\"\nwriters = 2\n[flush]\nmode = \"relaxed\"\n",
        )
        .unwrap();
        ServeConfig::from_str("[persist]\ndir = \"/tmp/w\"\nfsync = \"off\"\n").unwrap();
    }

    #[test]
    fn bad_values_are_typed_errors() {
        assert!(ServeConfig::from_str("[server]\nport = \"x\"\n").is_err());
        assert!(ServeConfig::from_str("[server]\nport = 0\n").is_err());
        assert!(ServeConfig::from_str("[server]\nport = 70000\n").is_err());
        assert!(ServeConfig::from_str("[server]\ncodec = \"morse\"\n").is_err());
        assert!(ServeConfig::from_str("[engine]\nmode = \"warp\"\n").is_err());
        assert!(ServeConfig::from_str("[flush]\nmode = \"sloppy\"\n").is_err());
        assert!(ServeConfig::from_str("[flush]\nreject_when_full = 1\n").is_err());
        assert!(ServeConfig::from_str("[limits]\nrate_per_conn = -1\n").is_err());
        assert!(
            ServeConfig::from_str("[persist]\ndir = \"/tmp/w\"\nfsync = \"always\"\n").is_err()
        );
        assert!(ServeConfig::from_str("[persist]\ndir = 7\n").is_err());
    }

    /// The shipped example at the repository root must parse into both
    /// typed configs — ci.sh counts on this test so the example cannot
    /// rot.
    #[test]
    fn shipped_example_round_trips() {
        let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
            .parent()
            .expect("workspace root")
            .join("lshmf.toml");
        let cfg = ServeConfig::from_file(&path)
            .unwrap_or_else(|e| panic!("shipped lshmf.toml must parse: {e}"));
        assert_eq!(cfg.engine.mode, EngineMode::Banded);
        assert!(cfg.engine.writers > 0);
        assert!(cfg.metrics.enabled);
        assert!(cfg.limits.rate_per_conn > 0);
        // the [persist] block ships commented out: durability is opt-in
        assert!(!cfg.persist.enabled());
        // the same file is a valid experiment config (shared sections)
        let exp = super::super::ExperimentConfig::from_file(&path)
            .unwrap_or_else(|e| panic!("shipped lshmf.toml must parse as experiment: {e}"));
        assert!(exp.model.f > 0);
    }
}
