//! Exact Graph Similarity Matrix (GSM) construction — the O(N²) baseline
//! the paper's simLSH replaces (Definitions 3.1–3.2, Table 1).
//!
//! Similarity between columns `j1, j2` is the shrunk Pearson correlation
//! over their *common* support:
//!
//! ```text
//! S_{j1,j2} = n_{j1,j2} / (n_{j1,j2} + λ_ρ) · ρ_{j1,j2}      (Table 1)
//! ```
//!
//! where `n_{j1,j2} = |Ω̂_{j1} ∩ Ω̂_{j2}|`. Construction enumerates
//! co-rating pairs row by row (`Σ_i |Ω_i|²` work — quadratic in the dense
//! rows, the very cost Fig. 1 illustrates), accumulating the five Pearson
//! sufficient statistics per pair, then takes exact Top-K per column.
//!
//! The accumulator footprint is reported in the [`CostReport`] so Table 7
//! (space overhead) can contrast it against the LSH engines.

use crate::lsh::{finalize_row, CostReport, NeighbourSearch, TopK};
use crate::rng::Rng;
use crate::sparse::{Csc, Csr};
use std::collections::HashMap;

/// Pearson sufficient statistics for one column pair.
#[derive(Clone, Copy, Debug, Default)]
struct PairStats {
    n: u32,
    sx: f64,
    sy: f64,
    sxx: f64,
    syy: f64,
    sxy: f64,
}

impl PairStats {
    #[inline]
    fn add(&mut self, x: f64, y: f64) {
        self.n += 1;
        self.sx += x;
        self.sy += y;
        self.sxx += x * x;
        self.syy += y * y;
        self.sxy += x * y;
    }

    /// Pearson correlation over the common support (0 if degenerate).
    fn pearson(&self) -> f64 {
        if self.n < 2 {
            return 0.0;
        }
        let n = self.n as f64;
        let cov = self.sxy - self.sx * self.sy / n;
        let vx = self.sxx - self.sx * self.sx / n;
        let vy = self.syy - self.sy * self.sy / n;
        if vx <= 1e-12 || vy <= 1e-12 {
            return 0.0;
        }
        (cov / (vx * vy).sqrt()).clamp(-1.0, 1.0)
    }

    /// Shrunk similarity S = n/(n+λ) · ρ.
    fn similarity(&self, lambda_rho: f64) -> f64 {
        let n = self.n as f64;
        n / (n + lambda_rho) * self.pearson()
    }
}

/// Exact GSM Top-K engine.
#[derive(Clone, Debug)]
pub struct Gsm {
    /// Pearson shrinkage λ_ρ (the paper uses 100).
    pub lambda_rho: f64,
    /// Rows denser than this are subsampled during pair enumeration to
    /// bound the quadratic blowup (0 = exact). The paper's serial GSM is
    /// exact; benches use exact mode and eat the cost — that *is* the
    /// result.
    pub row_cap: usize,
}

impl Default for Gsm {
    fn default() -> Self {
        Gsm { lambda_rho: 100.0, row_cap: 0 }
    }
}

impl Gsm {
    pub fn new(lambda_rho: f64) -> Self {
        Gsm { lambda_rho, row_cap: 0 }
    }

    /// Compute all pairwise similarities (exact) as per-column maps.
    /// Exposed for tests; [`NeighbourSearch::build`] wraps it.
    pub fn similarities(&self, csr: &Csr, rng: &mut Rng) -> (Vec<HashMap<u32, PairStatsPub>>, usize) {
        let ncols = csr.ncols();
        let mut stats: HashMap<u64, PairStats> = HashMap::new();
        let mut scratch: Vec<(u32, f32)> = Vec::new();
        for i in 0..csr.nrows() {
            let (cols, vals) = csr.row_raw(i);
            scratch.clear();
            if self.row_cap > 0 && cols.len() > self.row_cap {
                // subsample without replacement
                let picks = rng.sample_distinct(cols.len(), self.row_cap);
                for &pidx in &picks {
                    scratch.push((cols[pidx], vals[pidx]));
                }
            } else {
                scratch.extend(cols.iter().copied().zip(vals.iter().copied()));
            }
            for (a_pos, &(ja, ra)) in scratch.iter().enumerate() {
                for &(jb, rb) in &scratch[a_pos + 1..] {
                    let (lo, hi, x, y) = if ja < jb {
                        (ja, jb, ra, rb)
                    } else {
                        (jb, ja, rb, ra)
                    };
                    stats
                        .entry(((lo as u64) << 32) | hi as u64)
                        .or_default()
                        .add(x as f64, y as f64);
                }
            }
        }
        let bytes = stats.len() * (8 + std::mem::size_of::<PairStats>() + 8);
        // re-bucket per column with similarity values
        let mut per_col: Vec<HashMap<u32, PairStatsPub>> = vec![HashMap::new(); ncols];
        for (key, st) in stats {
            let (j1, j2) = ((key >> 32) as u32, key as u32);
            let s = st.similarity(self.lambda_rho);
            let ps = PairStatsPub { n: st.n, similarity: s };
            per_col[j1 as usize].insert(j2, ps);
            per_col[j2 as usize].insert(j1, ps);
        }
        (per_col, bytes)
    }
}

/// Public slice of the pair statistics (co-count + shrunk similarity).
#[derive(Clone, Copy, Debug)]
pub struct PairStatsPub {
    pub n: u32,
    pub similarity: f64,
}

impl NeighbourSearch for Gsm {
    fn name(&self) -> String {
        format!("GSM(λ_ρ={})", self.lambda_rho)
    }

    fn build(&mut self, csc: &Csc, k: usize, rng: &mut Rng) -> (TopK, CostReport) {
        let t0 = std::time::Instant::now();
        // Pair enumeration wants rows; rebuild a CSR view.
        let csr = Csr::from_triples(&csc_to_triples(csc));
        let (per_col, stat_bytes) = self.similarities(&csr, rng);
        let n = csc.ncols();
        let mut rows = Vec::with_capacity(n);
        for (j, sims) in per_col.iter().enumerate() {
            let mut cands: Vec<(u32, f64)> =
                sims.iter().map(|(&c, ps)| (c, ps.similarity)).collect();
            cands.sort_unstable_by(|a, b| {
                b.1.partial_cmp(&a.1).unwrap().then(a.0.cmp(&b.0))
            });
            let ordered: Vec<u32> = cands.into_iter().map(|(c, _)| c).collect();
            rows.push(finalize_row(j, ordered, k, n, rng));
        }
        let topk = TopK::from_rows(rows, k);
        let per_col_bytes: usize = per_col.iter().map(|m| 48 + m.len() * 24).sum();
        (
            topk,
            CostReport {
                seconds: t0.elapsed().as_secs_f64(),
                bytes: stat_bytes + per_col_bytes,
            },
        )
    }
}

fn csc_to_triples(csc: &Csc) -> crate::sparse::Triples {
    let mut t = crate::sparse::Triples::new(csc.nrows(), csc.ncols());
    for j in 0..csc.ncols() {
        for (i, r) in csc.col(j) {
            t.push(i, j, r);
        }
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::Triples;

    #[test]
    fn pearson_of_identical_columns_is_one() {
        let mut st = PairStats::default();
        for v in [1.0, 2.0, 3.0, 4.0] {
            st.add(v, v);
        }
        assert!((st.pearson() - 1.0).abs() < 1e-9);
        // shrinkage: n=4, λ=4 → 4/8 * 1 = 0.5
        assert!((st.similarity(4.0) - 0.5).abs() < 1e-9);
    }

    #[test]
    fn pearson_of_anticorrelated_is_minus_one() {
        let mut st = PairStats::default();
        for (x, y) in [(1.0, 4.0), (2.0, 3.0), (3.0, 2.0), (4.0, 1.0)] {
            st.add(x, y);
        }
        assert!((st.pearson() + 1.0).abs() < 1e-9);
    }

    #[test]
    fn constant_column_has_zero_similarity() {
        let mut st = PairStats::default();
        for v in [1.0, 2.0, 3.0] {
            st.add(2.5, v);
        }
        assert_eq!(st.pearson(), 0.0);
    }

    #[test]
    fn finds_correlated_columns_exactly() {
        // columns 0,1 strongly correlated on 30 common rows; column 2
        // uncorrelated noise
        let mut rng = Rng::seeded(31);
        let mut t = Triples::new(40, 3);
        for i in 0..30 {
            let base = 1.0 + rng.f32() * 4.0;
            t.push(i, 0, base);
            t.push(i, 1, (base + 0.2).min(5.0));
            t.push(i, 2, 1.0 + rng.f32() * 4.0);
        }
        let csc = Csc::from_triples(&t);
        let mut gsm = Gsm::new(10.0);
        let (topk, cost) = gsm.build(&csc, 1, &mut rng);
        assert_eq!(topk.neighbours(0)[0], 1);
        assert_eq!(topk.neighbours(1)[0], 0);
        assert!(cost.bytes > 0);
    }

    #[test]
    fn shrinkage_prefers_well_supported_pairs() {
        // pair (0,1): ρ=1 on 2 common rows; pair (0,2): ρ≈0.9 on 30 rows.
        // With λ_ρ=25, shrunk sims: 2/27·1 ≈ 0.074 vs 30/55·0.9 ≈ 0.49.
        let mut t = Triples::new(64, 3);
        t.push(62, 0, 1.0);
        t.push(62, 1, 1.0);
        t.push(63, 0, 2.0);
        t.push(63, 1, 2.0);
        let mut rng = Rng::seeded(32);
        for i in 0..30 {
            let v = 1.0 + (i % 5) as f32;
            t.push(i, 0, v);
            t.push(i, 2, v + rng.f32() * 0.8);
        }
        let csc = Csc::from_triples(&t);
        let mut gsm = Gsm::new(25.0);
        let (topk, _) = gsm.build(&csc, 1, &mut rng);
        assert_eq!(topk.neighbours(0)[0], 2);
    }

    #[test]
    fn row_cap_bounds_work_but_keeps_signal() {
        let mut rng = Rng::seeded(33);
        let mut t = Triples::new(50, 4);
        for i in 0..50 {
            let v = 1.0 + rng.f32() * 4.0;
            t.push(i, 0, v);
            t.push(i, 1, (v + 0.1).min(5.0));
            if rng.chance(0.5) {
                t.push(i, 2, 1.0 + rng.f32() * 4.0);
            }
            if rng.chance(0.5) {
                t.push(i, 3, 1.0 + rng.f32() * 4.0);
            }
        }
        let csc = Csc::from_triples(&t);
        let mut gsm = Gsm { lambda_rho: 5.0, row_cap: 3 };
        let (topk, _) = gsm.build(&csc, 1, &mut rng);
        assert_eq!(topk.neighbours(0)[0], 1);
    }
}
