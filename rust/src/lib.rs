//! # lshmf — LSH-Aggregated Nonlinear Neighbourhood Matrix Factorization
//!
//! A reproduction of *"Locality Sensitive Hash Aggregated Nonlinear
//! Neighbourhood Matrix Factorization for Online Sparse Big Data Analysis"*
//! (Li et al., 2021) as a three-layer rust + JAX + Pallas system:
//!
//! * **Layer 3 (this crate)** — the coordinator: sparse-data substrates,
//!   the simLSH / GSM neighbourhood search engines, the full family of MF
//!   trainers (serial SGD, block-parallel SGD a.k.a. CUSGD++, hogwild
//!   a.k.a. cuSGD, ALS, CCD++, and the headline CULSH-MF neighbourhood
//!   model), the online-learning path, the multi-device block-rotation
//!   scheduler, a streaming ingestion orchestrator, and a serving engine.
//! * **Layer 2 (python/compile)** — JAX compute graphs (batched Eq. (1)
//!   prediction, fused minibatch SGD, RMSE evaluation, GMF/MLP/NeuMF
//!   baselines), AOT-lowered to HLO text at build time.
//! * **Layer 1 (python/compile/kernels)** — Pallas kernels for the compute
//!   hot-spots (tiled sign-projection hashing, fused MF batch kernels),
//!   lowered inside the L2 graphs.
//!
//! Python never runs at request time: [`runtime`] loads the AOT artifacts
//! through PJRT (`xla` crate) and executes them from rust.
//!
//! ## Quick start
//!
//! ```no_run
//! use lshmf::data::synth::{SynthConfig, generate};
//! use lshmf::mf::sgd::{SgdConfig, train_sgd};
//! use lshmf::rng::Rng;
//!
//! let mut rng = Rng::seeded(7);
//! let ds = generate(&SynthConfig::movielens_like().scaled(0.05), &mut rng);
//! let model = train_sgd(&ds.train, &SgdConfig::default(), &mut rng);
//! println!("rmse = {}", model.rmse(&ds.test));
//! ```

// Every unsafe operation must sit in an explicit `unsafe { … }` block
// with its own `// SAFETY:` justification, even inside `unsafe fn` —
// the lshmf-check gate enforces both the block comments and this lint's
// presence.
#![deny(unsafe_op_in_unsafe_fn)]

pub mod bench;
pub mod cli;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod gsm;
pub mod linalg;
pub mod lsh;
pub mod metrics;
pub mod mf;
pub mod persist;
pub mod prop;
pub mod rng;
pub mod runtime;
pub mod sparse;

/// Crate-wide error type (hand-rolled: the crate is dependency-free, so
/// no `thiserror`).
#[derive(Debug)]
pub enum Error {
    Config(String),
    Data(String),
    Runtime(String),
    Io(std::io::Error),
}

impl std::fmt::Display for Error {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Error::Config(msg) => write!(f, "config error: {msg}"),
            Error::Data(msg) => write!(f, "data error: {msg}"),
            Error::Runtime(msg) => write!(f, "runtime error: {msg}"),
            Error::Io(err) => write!(f, "io error: {err}"),
        }
    }
}

impl std::error::Error for Error {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            Error::Io(err) => Some(err),
            _ => None,
        }
    }
}

impl From<std::io::Error> for Error {
    fn from(err: std::io::Error) -> Self {
        Error::Io(err)
    }
}

pub type Result<T> = std::result::Result<T, Error>;
