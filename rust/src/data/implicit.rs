//! Implicit-feedback dataset + leave-one-out evaluation protocol for the
//! Table 10 comparison against the NCF family (He et al. 2017).
//!
//! Protocol: every user's interactions are binary; the latest (here: one
//! uniformly chosen) positive per user is held out; at evaluation time the
//! model ranks that positive against 99 sampled negatives and we report
//! HR@10 — the fraction of users whose held-out item lands in the top 10.

use crate::rng::Rng;
use crate::sparse::Triples;

/// An implicit-feedback dataset with leave-one-out test instances.
#[derive(Clone, Debug)]
pub struct ImplicitDataset {
    pub name: String,
    /// Binary training interactions as a sparse matrix (value 1.0).
    pub train: Triples,
    /// Per-user (user, held_out_item, negatives[99]).
    pub test: Vec<(u32, u32, Vec<u32>)>,
    pub n_users: usize,
    pub n_items: usize,
}

/// Generator config: cluster-structured implicit interactions so that a
/// factor model can actually learn preferences.
#[derive(Clone, Debug)]
pub struct ImplicitConfig {
    pub name: String,
    pub n_users: usize,
    pub n_items: usize,
    /// Mean interactions per user.
    pub per_user: usize,
    /// Number of latent taste clusters.
    pub clusters: usize,
    /// Probability an interaction comes from the user's cluster rather
    /// than uniform noise.
    pub affinity: f64,
    pub negatives: usize,
}

impl ImplicitConfig {
    /// MovieLens-1M-like (scaled): 6040 users × 3706 items.
    pub fn movielens1m_like(scale: f64) -> Self {
        ImplicitConfig {
            name: format!("movielens1m@{scale}"),
            n_users: ((6040 as f64 * scale) as usize).max(64),
            n_items: ((3706 as f64 * scale) as usize).max(64),
            per_user: 32,
            clusters: 24,
            affinity: 0.8,
            negatives: 99,
        }
    }

    /// Pinterest-like (scaled): 55187 users × 9916 items, denser per user.
    pub fn pinterest_like(scale: f64) -> Self {
        ImplicitConfig {
            name: format!("pinterest@{scale}"),
            n_users: ((55_187 as f64 * scale) as usize).max(64),
            n_items: ((9_916 as f64 * scale) as usize).max(64),
            per_user: 24,
            clusters: 32,
            affinity: 0.85,
            negatives: 99,
        }
    }
}

/// Generate an implicit dataset with the leave-one-out protocol.
pub fn generate_implicit(cfg: &ImplicitConfig, rng: &mut Rng) -> ImplicitDataset {
    let items_per_cluster = (cfg.n_items / cfg.clusters).max(1);
    let mut train = Triples::new(cfg.n_users, cfg.n_items);
    let mut test = Vec::with_capacity(cfg.n_users);

    for u in 0..cfg.n_users {
        let cluster = rng.below(cfg.clusters);
        let lo = cluster * items_per_cluster;
        let hi = ((cluster + 1) * items_per_cluster).min(cfg.n_items);
        let mut items = std::collections::HashSet::new();
        let want = cfg.per_user.max(2);
        let mut guard = 0;
        while items.len() < want && guard < want * 20 {
            guard += 1;
            let item = if rng.chance(cfg.affinity) && hi > lo {
                rng.range(lo, hi)
            } else {
                rng.below(cfg.n_items)
            };
            items.insert(item);
        }
        let mut items: Vec<usize> = items.into_iter().collect();
        items.sort_unstable();
        // hold out one positive uniformly
        let held_idx = rng.below(items.len());
        let held = items.remove(held_idx);
        for &it in &items {
            train.push(u, it, 1.0);
        }
        // negatives: items the user did NOT interact with
        let positive: std::collections::HashSet<usize> =
            items.iter().copied().chain(std::iter::once(held)).collect();
        let mut negs = Vec::with_capacity(cfg.negatives);
        let mut guard = 0;
        while negs.len() < cfg.negatives && guard < cfg.negatives * 100 {
            guard += 1;
            let cand = rng.below(cfg.n_items);
            if !positive.contains(&cand) {
                negs.push(cand as u32);
            }
        }
        test.push((u as u32, held as u32, negs));
    }

    ImplicitDataset {
        name: cfg.name.clone(),
        train,
        test,
        n_users: cfg.n_users,
        n_items: cfg.n_items,
    }
}

/// HR@k: fraction of test users whose held-out item is ranked in the top
/// `k` among `1 + negatives` candidates, under `score(user, item)`.
pub fn hit_ratio_at<F: FnMut(u32, u32) -> f32>(
    ds: &ImplicitDataset,
    k: usize,
    mut score: F,
) -> f64 {
    if ds.test.is_empty() {
        return 0.0;
    }
    let mut hits = 0usize;
    for (u, pos, negs) in &ds.test {
        let pos_score = score(*u, *pos);
        // rank = number of negatives scoring strictly higher
        let higher = negs.iter().filter(|&&n| score(*u, n) > pos_score).count();
        if higher < k {
            hits += 1;
        }
    }
    hits as f64 / ds.test.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> ImplicitConfig {
        ImplicitConfig {
            name: "tiny".into(),
            n_users: 50,
            n_items: 120,
            per_user: 8,
            clusters: 6,
            affinity: 0.9,
            negatives: 20,
        }
    }

    #[test]
    fn generates_protocol_shape() {
        let mut rng = Rng::seeded(1);
        let ds = generate_implicit(&tiny(), &mut rng);
        assert_eq!(ds.test.len(), 50);
        for (u, pos, negs) in &ds.test {
            assert!((*u as usize) < 50);
            assert!((*pos as usize) < 120);
            assert_eq!(negs.len(), 20);
            // held-out positive is not in training for that user
            assert!(!ds
                .train
                .entries()
                .iter()
                .any(|&(i, j, _)| i == *u && j == *pos));
        }
    }

    #[test]
    fn perfect_oracle_hits_everything() {
        let mut rng = Rng::seeded(2);
        let ds = generate_implicit(&tiny(), &mut rng);
        // oracle: score 1 for the held-out item, 0 otherwise
        let held: std::collections::HashMap<u32, u32> =
            ds.test.iter().map(|(u, p, _)| (*u, *p)).collect();
        let hr = hit_ratio_at(&ds, 10, |u, it| if held[&u] == it { 1.0 } else { 0.0 });
        assert!((hr - 1.0).abs() < 1e-9);
    }

    #[test]
    fn random_scorer_hits_about_k_over_candidates() {
        let mut rng = Rng::seeded(3);
        let ds = generate_implicit(&tiny(), &mut rng);
        let mut score_rng = Rng::seeded(99);
        let hr = hit_ratio_at(&ds, 10, |_, _| score_rng.f32());
        // expected 10/21 ≈ 0.476 with 20 negatives; loose bounds
        assert!(hr > 0.2 && hr < 0.8, "hr={hr}");
    }

    #[test]
    fn cluster_structure_exists() {
        let mut rng = Rng::seeded(4);
        let cfg = tiny();
        let ds = generate_implicit(&cfg, &mut rng);
        // most of a user's items should fall in one item band
        let band = |item: u32| (item as usize) / (cfg.n_items / cfg.clusters).max(1);
        let mut concentrated = 0;
        for u in 0..cfg.n_users as u32 {
            let items: Vec<u32> = ds
                .train
                .entries()
                .iter()
                .filter(|&&(i, _, _)| i == u)
                .map(|&(_, j, _)| j)
                .collect();
            if items.is_empty() {
                continue;
            }
            let mut counts = std::collections::HashMap::new();
            for &it in &items {
                *counts.entry(band(it)).or_insert(0usize) += 1;
            }
            let max = counts.values().max().copied().unwrap_or(0);
            if max * 2 > items.len() {
                concentrated += 1;
            }
        }
        assert!(concentrated > cfg.n_users / 2, "concentrated={concentrated}");
    }
}
