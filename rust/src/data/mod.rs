//! Dataset substrate: synthetic generators calibrated to the paper's
//! Table 2, train/test splitting, the online Ω/Ω̄ split of Table 9,
//! noise injection (Table 8), implicit-feedback sets (Table 10), and a
//! plain-text loader for externally supplied rating files.
//!
//! The evaluation image has no network access, so Netflix / MovieLens /
//! Yahoo!Music are **simulated**: [`synth::generate`] draws a
//! popularity-skewed sparse matrix whose values come from a planted
//! low-rank + bias model with observation noise. That preserves what the
//! paper's experiments exercise — skewed nnz marginals (load imbalance),
//! bounded rating scales, neighbourhood structure (columns that share a
//! latent profile correlate), and an RMSE floor set by the noise level.
//! See DESIGN.md §Substitutions.

pub mod implicit;
pub mod loader;
pub mod online;
pub mod synth;

use crate::sparse::{Csc, Csr, Triples};

/// A train/test split of an interaction matrix, with cached CSR/CSC views
/// of the training part.
#[derive(Clone, Debug)]
pub struct Dataset {
    pub name: String,
    pub train: Csr,
    pub train_csc: Csc,
    pub test: Vec<(u32, u32, f32)>,
    pub max_value: f32,
    pub min_value: f32,
}

impl Dataset {
    /// Build from triples with a `test_fraction` holdout chosen uniformly.
    pub fn split(
        name: &str,
        mut t: Triples,
        test_fraction: f64,
        rng: &mut crate::rng::Rng,
    ) -> Dataset {
        let (mut max_v, mut min_v) = (f32::NEG_INFINITY, f32::INFINITY);
        for &(_, _, r) in t.entries() {
            max_v = max_v.max(r);
            min_v = min_v.min(r);
        }
        rng.shuffle(t.entries_mut());
        let n_test = ((t.nnz() as f64) * test_fraction) as usize;
        let entries = std::mem::take(t.entries_mut());
        let (test, train_entries) = entries.split_at(n_test);
        let train_t = Triples::from_entries(t.nrows(), t.ncols(), train_entries.to_vec());
        Dataset {
            name: name.to_string(),
            train: Csr::from_triples(&train_t),
            train_csc: Csc::from_triples(&train_t),
            test: test.to_vec(),
            max_value: max_v,
            min_value: min_v,
        }
    }

    pub fn nrows(&self) -> usize {
        self.train.nrows()
    }

    pub fn ncols(&self) -> usize {
        self.train.ncols()
    }

    pub fn nnz(&self) -> usize {
        self.train.nnz()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    #[test]
    fn split_partitions_entries() {
        let mut rng = Rng::seeded(1);
        let mut t = Triples::new(50, 40);
        let mut seen = std::collections::HashSet::new();
        while t.nnz() < 500 {
            let (i, j) = (rng.below(50), rng.below(40));
            if seen.insert((i, j)) {
                t.push(i, j, 1.0 + rng.f32() * 4.0);
            }
        }
        let ds = Dataset::split("toy", t, 0.1, &mut rng);
        assert_eq!(ds.test.len(), 50);
        assert_eq!(ds.train.nnz(), 450);
        assert!(ds.max_value <= 5.0 && ds.min_value >= 1.0);
    }
}
