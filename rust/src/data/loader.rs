//! Plain-text rating-file loader.
//!
//! Accepts the common `user<sep>item<sep>rating[<sep>timestamp]` line
//! format used by the MovieLens distributions (separators: whitespace,
//! `,`, `::`, or tab). If real data is dropped into `data/`, the CLI can
//! run on it directly instead of the synthetic generators.

use crate::sparse::Triples;
use crate::{Error, Result};
use std::collections::HashMap;
use std::io::BufRead;
use std::path::Path;

/// Parse one line into (user, item, rating).
fn parse_line(line: &str) -> Option<(u64, u64, f32)> {
    let norm = line.replace("::", " ").replace(',', " ").replace('\t', " ");
    let mut it = norm.split_whitespace();
    let u = it.next()?.parse::<u64>().ok()?;
    let i = it.next()?.parse::<u64>().ok()?;
    let r = it.next()?.parse::<f32>().ok()?;
    Some((u, i, r))
}

/// Load ratings from a file, densifying user/item ids into 0-based
/// contiguous indices. Blank lines and `#`/`%` comment lines are skipped;
/// any other malformed line is an error (silent corruption is worse).
pub fn load_ratings(path: &Path) -> Result<Triples> {
    let file = std::fs::File::open(path)?;
    let reader = std::io::BufReader::new(file);
    let mut user_ids: HashMap<u64, u32> = HashMap::new();
    let mut item_ids: HashMap<u64, u32> = HashMap::new();
    let mut entries = Vec::new();
    for (lineno, line) in reader.lines().enumerate() {
        let line = line?;
        let trimmed = line.trim();
        if trimmed.is_empty() || trimmed.starts_with('#') || trimmed.starts_with('%') {
            continue;
        }
        let (u, i, r) = parse_line(trimmed).ok_or_else(|| {
            Error::Data(format!("{}:{}: malformed rating line", path.display(), lineno + 1))
        })?;
        let nu = user_ids.len() as u32;
        let uu = *user_ids.entry(u).or_insert(nu);
        let ni = item_ids.len() as u32;
        let ii = *item_ids.entry(i).or_insert(ni);
        entries.push((uu, ii, r));
    }
    if entries.is_empty() {
        return Err(Error::Data(format!("{}: no ratings found", path.display())));
    }
    Ok(Triples::from_entries(user_ids.len(), item_ids.len(), entries))
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write;

    fn write_tmp(content: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir();
        let path = dir.join(format!("lshmf_loader_{}.txt", std::process::id()));
        let mut f = std::fs::File::create(&path).unwrap();
        f.write_all(content.as_bytes()).unwrap();
        path
    }

    #[test]
    fn loads_multiple_separators() {
        let path = write_tmp("1,10,4.0\n2::20::3.5\n3\t10\t5.0\n# comment\n\n1 20 2.0 12345\n");
        let t = load_ratings(&path).unwrap();
        assert_eq!(t.nnz(), 4);
        assert_eq!(t.nrows(), 3); // users 1,2,3
        assert_eq!(t.ncols(), 2); // items 10,20
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn rejects_malformed() {
        let path = write_tmp("1,10,4.0\nnot a line\n");
        assert!(load_ratings(&path).is_err());
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn rejects_empty() {
        let path = write_tmp("# nothing\n");
        assert!(load_ratings(&path).is_err());
        std::fs::remove_file(path).ok();
    }
}
