//! The online Ω / Ω̄ split of Table 9.
//!
//! The paper holds out the *last* fraction of row and column variables as
//! the "new" sets Ī and J̄: the original system is trained on entries whose
//! row AND column are original, and the increment Ω̄ is everything that
//! touches a new variable. New variables may interact with each other
//! (the paper allows Ī×J̄ entries).

use crate::sparse::Triples;

/// Outcome of the online split.
#[derive(Clone, Debug)]
pub struct OnlineSplit {
    /// Original entries (both endpoints original).
    pub base: Triples,
    /// Incremental entries (at least one new endpoint).
    pub increment: Vec<(u32, u32, f32)>,
    /// Number of original rows / cols (ids < these bounds are original).
    pub base_rows: usize,
    pub base_cols: usize,
}

/// Split by declaring the top `row_holdout` fraction of row ids and
/// `col_holdout` of column ids as "new". Ids are assumed exchangeable
/// (the synthetic generators scatter popularity over the id space).
pub fn split_online(
    t: &Triples,
    row_holdout: f64,
    col_holdout: f64,
) -> OnlineSplit {
    assert!((0.0..1.0).contains(&row_holdout));
    assert!((0.0..1.0).contains(&col_holdout));
    let base_rows = ((t.nrows() as f64) * (1.0 - row_holdout)).ceil() as usize;
    let base_cols = ((t.ncols() as f64) * (1.0 - col_holdout)).ceil() as usize;
    let mut base = Triples::new(base_rows, base_cols);
    let mut increment = Vec::new();
    for &(i, j, r) in t.entries() {
        if (i as usize) < base_rows && (j as usize) < base_cols {
            base.push(i as usize, j as usize, r);
        } else {
            increment.push((i, j, r));
        }
    }
    OnlineSplit { base, increment, base_rows, base_cols }
}

/// Table 9 style summary of an online split.
#[derive(Clone, Debug, PartialEq)]
pub struct OnlineStats {
    pub m: usize,
    pub n: usize,
    pub omega: usize,
    pub m_bar: usize,
    pub n_bar: usize,
    pub omega_bar: usize,
}

impl OnlineSplit {
    pub fn stats(&self, total_rows: usize, total_cols: usize) -> OnlineStats {
        OnlineStats {
            m: self.base_rows,
            n: self.base_cols,
            omega: self.base.nnz(),
            m_bar: total_rows - self.base_rows,
            n_bar: total_cols - self.base_cols,
            omega_bar: self.increment.len(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    fn random_triples(rng: &mut Rng) -> Triples {
        let mut t = Triples::new(100, 80);
        let mut seen = std::collections::HashSet::new();
        while t.nnz() < 800 {
            let (i, j) = (rng.below(100), rng.below(80));
            if seen.insert((i, j)) {
                t.push(i, j, rng.f32() * 5.0);
            }
        }
        t
    }

    #[test]
    fn split_is_a_partition() {
        let mut rng = Rng::seeded(1);
        let t = random_triples(&mut rng);
        let s = split_online(&t, 0.05, 0.05);
        assert_eq!(s.base.nnz() + s.increment.len(), t.nnz());
        // base entries only touch original ids
        for &(i, j, _) in s.base.entries() {
            assert!((i as usize) < s.base_rows && (j as usize) < s.base_cols);
        }
        // increments touch at least one new id
        for &(i, j, _) in &s.increment {
            assert!((i as usize) >= s.base_rows || (j as usize) >= s.base_cols);
        }
    }

    #[test]
    fn stats_match_paper_shape() {
        let mut rng = Rng::seeded(2);
        let t = random_triples(&mut rng);
        let s = split_online(&t, 0.01, 0.01);
        let st = s.stats(t.nrows(), t.ncols());
        assert_eq!(st.m + st.m_bar, 100);
        assert_eq!(st.n + st.n_bar, 80);
        assert_eq!(st.omega + st.omega_bar, t.nnz());
        assert!(st.omega_bar < st.omega);
    }

    #[test]
    fn zero_holdout_keeps_everything() {
        let mut rng = Rng::seeded(3);
        let t = random_triples(&mut rng);
        let s = split_online(&t, 0.0, 0.0);
        assert_eq!(s.increment.len(), 0);
        assert_eq!(s.base.nnz(), t.nnz());
    }
}
