//! Synthetic rating-matrix generators calibrated to the paper's Table 2.
//!
//! Planted model: each row i has a latent profile `a_i ∈ ℝ^d`, each column
//! j a profile `b_j ∈ ℝ^d` drawn from `C` cluster centroids (columns in
//! the same cluster are genuine neighbours — this is what the GSM/LSH
//! methods must discover), plus row/column biases and Gaussian noise:
//!
//! ```text
//! r_ij = clamp( μ* + bi*_i + bj*_j + a_i·b_j + ε,  min_v, max_v )
//! ```
//!
//! The (i, j) support is sampled with Zipf-skewed marginals to reproduce
//! the popularity skew of the real datasets (and hence the paper's thread
//! load-imbalance effects).

use super::Dataset;
use crate::rng::{Rng, Zipf};
use crate::sparse::Triples;

/// Generator parameters.
#[derive(Clone, Debug)]
pub struct SynthConfig {
    pub name: String,
    pub nrows: usize,
    pub ncols: usize,
    pub nnz: usize,
    pub min_value: f32,
    pub max_value: f32,
    /// Rating quantization step (real sets use 0.5 or 1.0 stars).
    pub value_step: f32,
    /// Planted latent dimension.
    pub latent_dim: usize,
    /// Number of column clusters (neighbourhood structure).
    pub col_clusters: usize,
    /// Zipf exponents for row/column popularity.
    pub row_skew: f64,
    pub col_skew: f64,
    /// Observation noise stddev (sets the achievable RMSE floor).
    pub noise_std: f32,
    pub test_fraction: f64,
}

impl SynthConfig {
    /// Netflix-like: 480,189 × 17,770, |Ω| ≈ 99M, ratings 1–5.
    pub fn netflix_like() -> Self {
        SynthConfig {
            name: "netflix".into(),
            nrows: 480_189,
            ncols: 17_770,
            nnz: 99_072_112,
            min_value: 1.0,
            max_value: 5.0,
            value_step: 1.0,
            latent_dim: 12,
            col_clusters: 64,
            row_skew: 1.05,
            col_skew: 0.95,
            noise_std: 0.85,
            test_fraction: 0.0142, // 1.4M of 99M
        }
    }

    /// MovieLens-10M-like: 69,878 × 10,677, |Ω| ≈ 9.9M, ratings 0.5–5.
    pub fn movielens_like() -> Self {
        SynthConfig {
            name: "movielens".into(),
            nrows: 69_878,
            ncols: 10_677,
            nnz: 9_900_054,
            min_value: 0.5,
            max_value: 5.0,
            value_step: 0.5,
            latent_dim: 12,
            col_clusters: 48,
            row_skew: 1.0,
            col_skew: 0.9,
            noise_std: 0.72,
            test_fraction: 0.0101, // 100k of 9.9M
        }
    }

    /// Yahoo!Music-like: 586,250 × 12,658, |Ω| ≈ 92M, ratings 0.5–100.
    /// (The paper trains on ratings/20 and rescales for reporting; the
    /// benches do the same.)
    pub fn yahoo_like() -> Self {
        SynthConfig {
            name: "yahoo".into(),
            nrows: 586_250,
            ncols: 12_658,
            nnz: 91_970_212,
            min_value: 0.5,
            max_value: 100.0,
            value_step: 0.5,
            latent_dim: 12,
            col_clusters: 56,
            row_skew: 1.1,
            col_skew: 1.0,
            noise_std: 17.0,
            test_fraction: 0.0109, // 1M of 92M
        }
    }

    pub fn by_name(name: &str) -> Option<Self> {
        match name {
            "netflix" => Some(Self::netflix_like()),
            "movielens" => Some(Self::movielens_like()),
            "yahoo" | "yahoomusic" => Some(Self::yahoo_like()),
            _ => None,
        }
    }

    /// Scale the instance down by a linear factor on rows/cols; nnz scales
    /// by `scale^1.5` — between linear (constant per-row degree) and
    /// quadratic (constant density). Quadratic scaling leaves scaled rows
    /// with only a handful of ratings (unlearnable and unlike subsampling
    /// a real dataset); the 1.5 exponent keeps both the per-row degree
    /// and the density in realistic ranges. `scale = 1.0` reproduces the
    /// full Table 2 sizes.
    pub fn scaled(mut self, scale: f64) -> Self {
        assert!(scale > 0.0 && scale <= 1.0);
        if (scale - 1.0).abs() < f64::EPSILON {
            return self;
        }
        self.name = format!("{}@{scale}", self.name);
        self.nrows = ((self.nrows as f64 * scale) as usize).max(16);
        self.ncols = ((self.ncols as f64 * scale) as usize).max(16);
        self.nnz = ((self.nnz as f64 * scale.powf(1.5)) as usize).max(256);
        // cap density at 30%
        self.nnz = self.nnz.min(self.nrows * self.ncols * 3 / 10);
        self
    }
}

/// Generate a split dataset from the planted model.
pub fn generate(cfg: &SynthConfig, rng: &mut Rng) -> Dataset {
    let t = generate_triples(cfg, rng);
    Dataset::split(&cfg.name, t, cfg.test_fraction, rng)
}

/// Generate raw triples (no split) — used by the online experiments that
/// need custom Ω/Ω̄ partitions.
pub fn generate_triples(cfg: &SynthConfig, rng: &mut Rng) -> Triples {
    let d = cfg.latent_dim;
    // Planted factors. Row profiles are i.i.d.; column profiles are
    // cluster centroids plus a small within-cluster perturbation so that
    // same-cluster columns are genuine nearest neighbours.
    let mut row_profiles = vec![0f32; cfg.nrows * d];
    for x in row_profiles.iter_mut() {
        *x = rng.normal_f32(0.0, 1.0);
    }
    let mut centroids = vec![0f32; cfg.col_clusters * d];
    for x in centroids.iter_mut() {
        *x = rng.normal_f32(0.0, 1.0);
    }
    let mut col_profiles = vec![0f32; cfg.ncols * d];
    let mut col_cluster = vec![0u32; cfg.ncols];
    for j in 0..cfg.ncols {
        let c = rng.below(cfg.col_clusters);
        col_cluster[j] = c as u32;
        for k in 0..d {
            col_profiles[j * d + k] = centroids[c * d + k] + rng.normal_f32(0.0, 0.25);
        }
    }

    let span = cfg.max_value - cfg.min_value;
    let mid = 0.5 * (cfg.max_value + cfg.min_value);
    // Scale factor choosing the interaction strength relative to range.
    let gain = span / (4.0 * (d as f32).sqrt());

    let mut row_bias = vec![0f32; cfg.nrows];
    for b in row_bias.iter_mut() {
        *b = rng.normal_f32(0.0, span * 0.08);
    }
    let mut col_bias = vec![0f32; cfg.ncols];
    for b in col_bias.iter_mut() {
        *b = rng.normal_f32(0.0, span * 0.08);
    }

    // Zipf-skewed support sampling with a permutation so "popular" ids are
    // scattered over the index space like in the real data.
    let row_zipf = Zipf::new(cfg.nrows, cfg.row_skew);
    let col_zipf = Zipf::new(cfg.ncols, cfg.col_skew);
    let mut row_perm: Vec<u32> = (0..cfg.nrows as u32).collect();
    let mut col_perm: Vec<u32> = (0..cfg.ncols as u32).collect();
    rng.shuffle(&mut row_perm);
    rng.shuffle(&mut col_perm);

    let mut seen = std::collections::HashSet::with_capacity(cfg.nnz * 2);
    let mut t = Triples::new(cfg.nrows, cfg.ncols);
    let mut attempts: usize = 0;
    let max_attempts = cfg.nnz.saturating_mul(40).max(1 << 16);
    while t.nnz() < cfg.nnz && attempts < max_attempts {
        attempts += 1;
        let i = row_perm[row_zipf.sample(rng)] as usize;
        let j = col_perm[col_zipf.sample(rng)] as usize;
        if !seen.insert(((i as u64) << 32) | j as u64) {
            continue;
        }
        let mut v = mid + row_bias[i] + col_bias[j] + rng.normal_f32(0.0, cfg.noise_std);
        let a = &row_profiles[i * d..(i + 1) * d];
        let b = &col_profiles[j * d..(j + 1) * d];
        v += gain * crate::linalg::dot(a, b);
        // quantize to the rating scale
        let q = ((v - cfg.min_value) / cfg.value_step).round() * cfg.value_step + cfg.min_value;
        t.push(i, j, q.clamp(cfg.min_value, cfg.max_value));
    }
    t
}

/// Perturb a fraction of training values with uniform noise over the full
/// rating range (the Table 8 robustness protocol).
pub fn inject_noise(t: &mut Triples, rate: f64, min_v: f32, max_v: f32, rng: &mut Rng) -> usize {
    let mut flipped = 0;
    let n = t.nnz();
    let entries = t.entries_mut();
    let count = ((n as f64) * rate).round() as usize;
    for _ in 0..count {
        let k = rng.below(n);
        entries[k].2 = rng.range_f32(min_v, max_v);
        flipped += 1;
    }
    flipped
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> SynthConfig {
        SynthConfig::movielens_like().scaled(0.02)
    }

    #[test]
    fn respects_sizes_and_range() {
        let cfg = tiny();
        let mut rng = Rng::seeded(1);
        let t = generate_triples(&cfg, &mut rng);
        assert_eq!(t.nrows(), cfg.nrows);
        assert_eq!(t.ncols(), cfg.ncols);
        // generator may fall slightly short on very dense configs; here it
        // should hit the target
        assert!(t.nnz() as f64 > cfg.nnz as f64 * 0.99, "nnz={}", t.nnz());
        for &(_, _, r) in t.entries() {
            assert!(r >= cfg.min_value && r <= cfg.max_value);
            // quantization check
            let steps = (r - cfg.min_value) / cfg.value_step;
            assert!((steps - steps.round()).abs() < 1e-3);
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let cfg = tiny();
        let a = generate_triples(&cfg, &mut Rng::seeded(9));
        let b = generate_triples(&cfg, &mut Rng::seeded(9));
        assert_eq!(a.entries(), b.entries());
    }

    #[test]
    fn popularity_is_skewed() {
        let cfg = tiny();
        let mut rng = Rng::seeded(2);
        let t = generate_triples(&cfg, &mut rng);
        let mut col_counts = vec![0usize; cfg.ncols];
        for &(_, j, _) in t.entries() {
            col_counts[j as usize] += 1;
        }
        col_counts.sort_unstable_by(|a, b| b.cmp(a));
        let top_decile: usize = col_counts[..cfg.ncols / 10].iter().sum();
        let share = top_decile as f64 / t.nnz() as f64;
        assert!(share > 0.3, "top-decile share {share}");
    }

    #[test]
    fn same_cluster_columns_correlate() {
        // Columns in the same planted cluster should have higher rating
        // correlation than random pairs — this is the signal GSM/LSH mine.
        let mut cfg = tiny();
        cfg.noise_std = 0.3;
        let mut rng = Rng::seeded(3);
        let d = cfg.latent_dim;
        // regenerate profiles the same way generate_triples does is not
        // accessible; instead verify via the matrix itself on dense cols.
        let t = generate_triples(&cfg, &mut rng);
        let csc = crate::sparse::Csc::from_triples(&t);
        // mean rating per column as a crude profile signal
        let col_mean = |j: usize| -> f32 {
            let (rows, vals) = csc.col_raw(j);
            if rows.is_empty() {
                return 0.0;
            }
            vals.iter().sum::<f32>() / vals.len() as f32
        };
        // Spread of column means should be substantial (cluster structure)
        let means: Vec<f32> = (0..cfg.ncols).map(col_mean).collect();
        let nonzero: Vec<f32> = means.iter().copied().filter(|m| *m != 0.0).collect();
        let avg = nonzero.iter().sum::<f32>() / nonzero.len() as f32;
        let var =
            nonzero.iter().map(|m| (m - avg) * (m - avg)).sum::<f32>() / nonzero.len() as f32;
        assert!(var > 0.05, "column-mean variance {var} too small — no structure");
        let _ = d;
    }

    #[test]
    fn noise_injection_counts() {
        let cfg = tiny();
        let mut rng = Rng::seeded(4);
        let mut t = generate_triples(&cfg, &mut rng);
        let n = inject_noise(&mut t, 0.01, cfg.min_value, cfg.max_value, &mut rng);
        assert_eq!(n, ((t.nnz() as f64) * 0.01).round() as usize);
    }

    #[test]
    fn scaled_keeps_density_reasonable() {
        let cfg = SynthConfig::netflix_like().scaled(0.01);
        assert!(cfg.nnz <= cfg.nrows * cfg.ncols * 3 / 10);
        assert!(cfg.nrows >= 16 && cfg.ncols >= 16);
    }
}
