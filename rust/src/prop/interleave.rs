//! Deterministic interleaving explorer — a mini-loom for the banded
//! ingest path (the offline image has no `loom`).
//!
//! [`schedules`] enumerates **every** order in which a set of logical
//! threads can interleave their operation sequences (each thread's own
//! order preserved — the multinomial coefficient of the counts), and
//! [`interleave`] replays one such schedule into a single flat op
//! sequence. The tests in this module drive multi-writer
//! [`crate::coordinator::banded::BandedEngine`] scenarios — flush
//! participants, a universe-growing writer, and a SUBSCRIBEd reader —
//! through *all* schedules of a tiny ingest scenario and assert the
//! published snapshot is **bit-identical** to a sequential `Engine`
//! reference fed the same arrival order — executing the "race-free and
//! deterministic" claim of the banded module's `# Invariants` section
//! instead of merely documenting it. A relaxed-flush scenario holds the
//! same bar with a *relaxed* single-writer reference: bounded-divergence
//! mode is still schedule-independent (see
//! `relaxed_flush_bit_identical_to_relaxed_reference_under_every_schedule`). Every banded run also carries a
//! push subscriber, so each schedule additionally checks that the
//! subscriber observes every publish, in order, ending at the final
//! published version.
//!
//! Granularity note: ops are replayed one at a time from the exploring
//! thread, so each schedule exercises one complete linearization of the
//! real seq-stamp/buffer/flush-epoch machinery (every `rate` round-trips
//! through its owning band's writer thread). This explores all
//! *operation* orders exhaustively; sub-operation overlap is the
//! sanitizer jobs' department (see ci.yml).

/// All distinct interleavings of `counts[t]` ops per thread `t`,
/// preserving each thread's internal order. A schedule is a sequence of
/// thread ids; the k-th occurrence of `t` means "thread t's k-th op".
/// The result has `(Σcounts)! / Π(counts[t]!)` entries.
pub fn schedules(counts: &[usize]) -> Vec<Vec<usize>> {
    let total: usize = counts.iter().sum();
    let mut out = Vec::new();
    let mut cur = Vec::with_capacity(total);
    let mut remaining = counts.to_vec();
    rec(&mut remaining, &mut cur, total, &mut out);
    out
}

fn rec(remaining: &mut [usize], cur: &mut Vec<usize>, total: usize, out: &mut Vec<Vec<usize>>) {
    if cur.len() == total {
        out.push(cur.clone());
        return;
    }
    for t in 0..remaining.len() {
        if remaining[t] > 0 {
            remaining[t] -= 1;
            cur.push(t);
            rec(remaining, cur, total, out);
            cur.pop();
            remaining[t] += 1;
        }
    }
}

/// Replay `schedule` (a sequence of thread ids from [`schedules`]) over
/// per-thread op slices into one flat arrival-order sequence.
pub fn interleave<T: Clone>(schedule: &[usize], threads: &[&[T]]) -> Vec<T> {
    let mut cursors = vec![0usize; threads.len()];
    schedule
        .iter()
        .map(|&t| {
            let op = threads[t][cursors[t]].clone();
            cursors[t] += 1;
            op
        })
        .collect()
}

/// `(Σcounts)! / Π(counts[t]!)` — the expected schedule count, computed
/// multiplicatively so intermediate values stay exact binomials.
pub fn schedule_count(counts: &[usize]) -> u128 {
    let mut total = 0u128;
    let mut result = 1u128;
    for &c in counts {
        for k in 1..=c as u128 {
            total += 1;
            result = result * total / k;
        }
    }
    result
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::banded::BandedEngine;
    use crate::coordinator::engine::Engine;
    use crate::coordinator::stream::{FlushMode, StreamConfig, StreamOrchestrator};
    use crate::lsh::{OnlineHashState, SimLsh};
    use crate::metrics::Registry;
    use crate::mf::neighbourhood::{train_culsh_logged, CulshConfig};
    use crate::rng::Rng;
    use crate::sparse::{Csc, Csr, Triples};
    use std::collections::HashSet;
    use std::sync::{Arc, Mutex};

    #[test]
    fn enumeration_is_exhaustive_and_distinct() {
        let all = schedules(&[2, 2, 1]);
        assert_eq!(all.len(), 30);
        assert_eq!(schedule_count(&[2, 2, 1]), 30);
        let distinct: HashSet<&Vec<usize>> = all.iter().collect();
        assert_eq!(distinct.len(), all.len(), "duplicate schedules");
        for s in &all {
            assert_eq!(s.iter().filter(|&&t| t == 0).count(), 2);
            assert_eq!(s.iter().filter(|&&t| t == 1).count(), 2);
            assert_eq!(s.iter().filter(|&&t| t == 2).count(), 1);
        }
        assert_eq!(schedules(&[3, 2]).len(), 10);
        assert_eq!(schedule_count(&[3, 2]), 10);
        assert_eq!(schedules(&[0, 0]), vec![Vec::<usize>::new()]);
        assert_eq!(schedule_count(&[4, 4, 2]), 3150);
    }

    #[test]
    fn interleave_preserves_per_thread_order() {
        let a = [1, 2, 3];
        let b = [10, 20];
        for s in schedules(&[a.len(), b.len()]) {
            let flat = interleave(&s, &[&a, &b]);
            let from_a: Vec<i32> = flat.iter().copied().filter(|x| *x < 10).collect();
            let from_b: Vec<i32> = flat.iter().copied().filter(|x| *x >= 10).collect();
            assert_eq!(from_a, a);
            assert_eq!(from_b, b);
        }
    }

    /// One logical step of a writer, the flush participant, or a
    /// reader observing the published state mid-stream.
    #[derive(Clone, Copy, Debug)]
    enum WriterOp {
        Rate(u32, u32, f32),
        /// A burst of ratings submitted as one schedule step — how the
        /// relaxed scenario gets past `RELAXED_ROTATION_CUTOFF`
        /// trainable entries per flush without exploding the factorial
        /// schedule count.
        Rates(&'static [(u32, u32, f32)]),
        Flush,
        /// Top-3 read of the row; the reply is recorded bit-exactly, so
        /// a stale cache entry diverges from the reference.
        Read(u32),
    }

    /// The flush policy every pre-existing scenario runs: exact mode,
    /// batches large enough that flushes happen only where the schedule
    /// says.
    fn exact_cfg() -> StreamConfig {
        StreamConfig { batch_size: 64, ..Default::default() }
    }

    /// The banded test engine recipe (same tiny scale as banded.rs
    /// tests).
    fn engine_with(seed: u64, stream_cfg: StreamConfig) -> Engine {
        let mut rng = Rng::seeded(seed);
        let (m, n) = (25, 12);
        let mut t = Triples::new(m, n);
        let mut seen = std::collections::HashSet::new();
        while t.nnz() < 140 {
            let (i, j) = (rng.below(m), rng.below(n));
            if seen.insert((i, j)) {
                t.push(i, j, 1.0 + rng.f32() * 4.0);
            }
        }
        let csr = Csr::from_triples(&t);
        let csc = Csc::from_triples(&t);
        let lsh = SimLsh::new(1, 4, 8, 2);
        let hash_state = OnlineHashState::build(lsh, &csc);
        let (topk, _) = hash_state.topk(3, &mut rng);
        let cfg = CulshConfig { f: 4, k: 3, epochs: 3, ..Default::default() };
        let (model, _) = train_culsh_logged(&csr, topk, &cfg, &mut rng);
        let registry = Registry::new();
        let orch = StreamOrchestrator::new(
            model,
            hash_state,
            t,
            stream_cfg,
            cfg,
            rng.split(1),
            registry.clone(),
        );
        Engine::new(orch, (1.0, 5.0), registry)
    }

    /// Replay the flat op sequence into the sequential reference.
    fn run_reference(ops: &[WriterOp], cfg: &StreamConfig) -> (Engine, Vec<String>) {
        let mut e = engine_with(77, cfg.clone());
        let mut replies = Vec::new();
        for op in ops {
            match *op {
                WriterOp::Rate(i, j, r) => replies.push(format!("{:?}", e.rate(i, j, r))),
                WriterOp::Rates(batch) => replies.push(format!(
                    "{:?}",
                    batch.iter().map(|&(i, j, r)| e.rate(i, j, r)).collect::<Vec<_>>()
                )),
                WriterOp::Flush => replies.push(format!("flushed {}", e.flush())),
                WriterOp::Read(i) => replies.push(top3(e.top_n(i as usize, 3))),
            }
        }
        e.flush();
        (e, replies)
    }

    /// Bit-exact rendering of a top-3 reply.
    fn top3(items: Vec<(u32, f32)>) -> String {
        let bits: Vec<(u32, u32)> = items.into_iter().map(|(j, s)| (j, s.to_bits())).collect();
        format!("top {bits:?}")
    }

    /// One banded replay: the engine, its writer handle, the recorded
    /// replies, and what the push subscriber observed.
    struct BandedRun {
        engine: BandedEngine,
        handle: crate::coordinator::banded::BandedHandle,
        replies: Vec<String>,
        /// Version returned by `subscribe_push` (the SUBSCRIBED ack).
        subscribed_at: u64,
        /// Every `(version, dirty bands)` push, in arrival order.
        pushes: Arc<Mutex<Vec<(u64, Vec<u32>)>>>,
    }

    /// Replay the same sequence against a fresh multi-writer banded
    /// engine; every `rate` round-trips through the owning band's writer
    /// thread, and a push subscriber records every publish.
    fn run_banded(ops: &[WriterOp], cfg: &StreamConfig, writers: usize) -> BandedRun {
        let (banded, handle) = BandedEngine::spawn(engine_with(77, cfg.clone()), writers);
        let pushes: Arc<Mutex<Vec<(u64, Vec<u32>)>>> = Arc::new(Mutex::new(Vec::new()));
        let sink_pushes = Arc::clone(&pushes);
        let subscribed_at = banded.subscribe_push(Box::new(move |v, dirty| {
            sink_pushes.lock().unwrap().push((v, dirty.to_vec()));
            true
        }));
        let mut replies = Vec::new();
        for op in ops {
            match *op {
                WriterOp::Rate(i, j, r) => replies.push(format!("{:?}", banded.rate(i, j, r))),
                WriterOp::Rates(batch) => replies.push(format!(
                    "{:?}",
                    batch.iter().map(|&(i, j, r)| banded.rate(i, j, r)).collect::<Vec<_>>()
                )),
                WriterOp::Flush => replies.push(format!("flushed {}", banded.flush())),
                WriterOp::Read(i) => replies.push(top3(banded.top_n(i as usize, 3))),
            }
        }
        banded.flush();
        BandedRun { engine: banded, handle, replies, subscribed_at, pushes }
    }

    /// Full-grid bit-identity between the banded snapshot and the
    /// sequential reference: dims, every prediction (compared through
    /// `f32::to_bits`, so "close" is not good enough) and every top-5.
    fn assert_bit_identical(banded: &BandedEngine, reference: &Engine, sched: &[usize]) {
        assert_eq!(banded.dims(), reference.dims(), "dims diverge under {sched:?}");
        let (m, n) = reference.dims();
        let cols: Vec<u32> = (0..n as u32).collect();
        for i in 0..m {
            let got = banded
                .predict_many(i, &cols)
                .map(|v| v.iter().map(|p| p.map(f32::to_bits)).collect::<Vec<_>>());
            let want = reference
                .predict_many(i, &cols)
                .map(|v| v.iter().map(|p| p.map(f32::to_bits)).collect::<Vec<_>>());
            assert_eq!(got, want, "row {i} predictions diverge under {sched:?}");
            let got_top: Vec<(u32, u32)> =
                banded.top_n(i, 5).into_iter().map(|(j, s)| (j, s.to_bits())).collect();
            let want_top: Vec<(u32, u32)> =
                reference.top_n(i, 5).into_iter().map(|(j, s)| (j, s.to_bits())).collect();
            assert_eq!(got_top, want_top, "row {i} top-n diverges under {sched:?}");
        }
    }

    /// Every pre-existing scenario: exact flush mode, 2 writers.
    fn explore(threads: &[&[WriterOp]]) {
        explore_with(threads, &exact_cfg(), 2);
    }

    fn explore_with(threads: &[&[WriterOp]], cfg: &StreamConfig, writers: usize) {
        let counts: Vec<usize> = threads.iter().map(|t| t.len()).collect();
        let all = schedules(&counts);
        assert_eq!(all.len() as u128, schedule_count(&counts));
        for sched in &all {
            let ops = interleave(sched, threads);
            let (reference, want_replies) = run_reference(&ops, cfg);
            let run = run_banded(&ops, cfg, writers);
            assert_eq!(run.replies, want_replies, "replies diverge under {sched:?}");
            assert_bit_identical(&run.engine, &reference, sched);

            // The subscriber saw every publish, in order, ending at the
            // final published version. Dirty band lists are sorted and
            // in range; an empty list is the growth "everything
            // changed" signal.
            let pushes = run.pushes.lock().unwrap();
            assert!(!pushes.is_empty(), "no publish observed under {sched:?}");
            let mut prev = run.subscribed_at;
            for (v, dirty) in pushes.iter() {
                assert!(*v > prev, "push versions not increasing under {sched:?}: {pushes:?}");
                prev = *v;
                assert!(dirty.windows(2).all(|w| w[0] < w[1]), "unsorted dirty: {dirty:?}");
                let d = run.engine.writers() as u32;
                assert!(dirty.iter().all(|&b| b < d), "dirty band out of range: {dirty:?}");
            }
            assert_eq!(
                prev,
                run.engine.version(),
                "subscriber missed the final publish under {sched:?}"
            );
            drop(pushes);

            let BandedRun { engine: banded, handle, .. } = run;
            drop(banded);
            handle.join();
        }
    }

    /// The bounded 2-writer ingest+flush scenario: writer A and writer B
    /// race a re-rating of the same cell (last-write-wins order is
    /// arrival order, so every schedule's reference differs), B grows
    /// the column universe mid-stream, and the flush participant's one
    /// op lands in every possible position — 30 schedules, each held to
    /// bit-identical snapshots.
    #[test]
    fn two_writers_and_flush_bit_identical_under_every_schedule() {
        let a: &[WriterOp] = &[WriterOp::Rate(0, 0, 4.5), WriterOp::Rate(1, 11, 3.0)];
        let b: &[WriterOp] = &[WriterOp::Rate(0, 0, 2.0), WriterOp::Rate(2, 13, 5.0)];
        let flusher: &[WriterOp] = &[WriterOp::Flush];
        explore(&[a, b, flusher]);
    }

    /// A writer whose own sequence embeds a flush between its ratings
    /// (the batch-trigger shape): 10 schedules against a second writer.
    #[test]
    fn embedded_flush_schedules_bit_identical() {
        let a: &[WriterOp] = &[
            WriterOp::Rate(3, 1, 1.5),
            WriterOp::Flush,
            WriterOp::Rate(3, 13, 4.0),
        ];
        let b: &[WriterOp] = &[WriterOp::Rate(4, 6, 2.5), WriterOp::Rate(3, 1, 5.0)];
        explore(&[a, b]);
    }

    /// Three writers — two racing a re-rating of the same cell, one
    /// growing the column universe and flushing mid-stream — plus a
    /// SUBSCRIBEd reader whose top-n read lands in every possible
    /// position: 180 schedules. Each schedule checks the read reply is
    /// bit-identical to the sequential reference at the same arrival
    /// position (a stale Top-N cache entry would diverge), and the
    /// `explore` push assertions hold the subscriber to observing every
    /// publish — including the growth publish with its empty
    /// "everything changed" dirty set.
    #[test]
    fn three_writers_with_subscribed_reader_bit_identical() {
        let a: &[WriterOp] = &[WriterOp::Rate(0, 0, 4.5), WriterOp::Rate(1, 11, 3.0)];
        let b: &[WriterOp] = &[WriterOp::Rate(0, 0, 2.0)];
        let c: &[WriterOp] = &[WriterOp::Rate(2, 13, 5.0), WriterOp::Flush];
        let reader: &[WriterOp] = &[WriterOp::Read(0)];
        explore(&[a, b, c, reader]);
    }

    /// Growth bursts onto new rows 25-27 of the 25×12 seed universe. 18
    /// trainable entries each, so any flush containing either burst
    /// clears `RELAXED_ROTATION_CUTOFF` (16) and the relaxed rotation
    /// actually spins up its lane threads instead of taking the
    /// bit-exact straggler path. Both bursts touch cell (25, 0) with
    /// different values, so last-write-wins order is arrival order and
    /// every schedule's reference genuinely differs.
    static GROWTH_BURST_A: [(u32, u32, f32); 18] = [
        (25, 0, 4.5), (25, 1, 3.0), (25, 2, 2.0), (25, 3, 5.0), (25, 4, 1.5), (25, 5, 4.0),
        (25, 6, 2.5), (25, 7, 3.5), (25, 8, 1.0), (25, 9, 4.5), (25, 10, 2.0), (25, 11, 3.0),
        (26, 0, 5.0), (26, 1, 1.5), (26, 2, 4.0), (26, 3, 2.5), (26, 4, 3.5), (26, 5, 1.0),
    ];
    static GROWTH_BURST_B: [(u32, u32, f32); 18] = [
        (25, 0, 2.0), (26, 6, 4.5), (26, 7, 3.0), (26, 8, 2.0), (26, 9, 5.0), (26, 10, 1.5),
        (26, 11, 4.0), (27, 0, 2.5), (27, 1, 3.5), (27, 2, 1.0), (27, 3, 4.5), (27, 4, 2.0),
        (27, 5, 3.0), (27, 6, 5.0), (27, 7, 1.5), (27, 8, 4.0), (27, 9, 2.5), (27, 10, 3.5),
    ];

    /// The relaxed-flush scenario (`serve --flush-mode relaxed`): a
    /// 2-writer banded engine with `flush_bands == writers` must stay
    /// **schedule-independent** — under every interleaving, its replies
    /// and published snapshot are bit-identical to a relaxed
    /// single-writer reference fed the same arrival order. Relaxation
    /// trades exactness against the *exact* reference (bounded
    /// divergence, property-tested in `tests/props.rs`), never
    /// determinism: the Latin-square rotation is a fixed schedule, so
    /// arrival order alone decides the bits. Two 18-entry growth bursts
    /// keep every flush above `RELAXED_ROTATION_CUTOFF`, so the lane
    /// rotation itself — not its sequential straggler fallback — is
    /// what every one of the 12 schedules exercises, with a SUBSCRIBEd
    /// reader's top-3 of a new row landing in every position.
    #[test]
    fn relaxed_flush_bit_identical_to_relaxed_reference_under_every_schedule() {
        let cfg = StreamConfig {
            batch_size: 64,
            flush_mode: FlushMode::Relaxed,
            flush_bands: 2,
            ..Default::default()
        };
        let a: &[WriterOp] = &[WriterOp::Rates(&GROWTH_BURST_A)];
        let b: &[WriterOp] = &[WriterOp::Rates(&GROWTH_BURST_B), WriterOp::Flush];
        let reader: &[WriterOp] = &[WriterOp::Read(25)];
        explore_with(&[a, b, reader], &cfg, 2);
    }
}
