//! Mini property-testing framework (the offline image has no `proptest`).
//!
//! [`check`] runs a property against `cases` randomly generated inputs;
//! on failure it re-runs the generator with a binary-search over the
//! generator's *size budget* to report a smaller counterexample (sized
//! shrinking rather than structural shrinking — enough to localize most
//! failures), then panics with the seed so the case is reproducible.
//!
//! ```
//! use lshmf::prop::{check, Gen};
//! check("reverse twice is identity", 100, |g| {
//!     let xs: Vec<u32> = g.vec(0..=64, |g| g.u32(0..1000));
//!     let mut ys = xs.clone();
//!     ys.reverse();
//!     ys.reverse();
//!     ys == xs
//! });
//! ```
//!
//! [`interleave`] is the companion *deterministic* tool: instead of
//! sampling random inputs it exhaustively enumerates thread
//! interleavings for the banded ingest path (a mini-loom).

pub mod interleave;

use crate::rng::Rng;
use std::ops::RangeInclusive;

/// Input generator handed to properties: seeded randomness plus a size
/// budget that shrinks on failure.
pub struct Gen {
    rng: Rng,
    /// Scale in (0, 1]; generators multiply their max sizes by this.
    pub size: f64,
}

impl Gen {
    pub fn new(seed: u64, size: f64) -> Self {
        Gen { rng: Rng::seeded(seed), size }
    }

    pub fn rng(&mut self) -> &mut Rng {
        &mut self.rng
    }

    /// Integer in range, biased toward the low end as size shrinks.
    pub fn usize(&mut self, range: RangeInclusive<usize>) -> usize {
        let (lo, hi) = (*range.start(), *range.end());
        let span = ((hi - lo) as f64 * self.size).round() as usize;
        lo + self.rng.below(span.max(0) + 1)
    }

    pub fn u32(&mut self, range: std::ops::Range<u32>) -> u32 {
        let span = ((range.end - range.start) as f64 * self.size).ceil() as u32;
        range.start + (self.rng.below(span.max(1) as usize) as u32)
    }

    pub fn f32(&mut self, lo: f32, hi: f32) -> f32 {
        self.rng.range_f32(lo, hi)
    }

    pub fn bool(&mut self) -> bool {
        self.rng.chance(0.5)
    }

    /// A vector with size-scaled length.
    pub fn vec<T>(&mut self, len: RangeInclusive<usize>, mut item: impl FnMut(&mut Gen) -> T) -> Vec<T> {
        let n = self.usize(len);
        (0..n).map(|_| item(self)).collect()
    }

    /// Pick one element of a slice.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.rng.below(xs.len())]
    }
}

/// Environment knob: `LSHMF_PROP_SEED` pins the base seed.
fn base_seed() -> u64 {
    std::env::var("LSHMF_PROP_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0x5EED_CAFE)
}

/// Run `prop` against `cases` generated inputs. Panics on the first
/// failure after attempting size-shrinking, reporting the failing seed.
pub fn check(name: &str, cases: u32, prop: impl Fn(&mut Gen) -> bool) {
    let base = base_seed();
    for case in 0..cases {
        let seed = base ^ ((case as u64) << 17) ^ 0x9E37_79B9;
        let mut g = Gen::new(seed, 1.0);
        if prop(&mut g) {
            continue;
        }
        // Shrink: find the smallest size in {1/16, 2/16, ...} that fails.
        let mut failing_size = 1.0;
        for step in 1..=16 {
            let size = step as f64 / 16.0;
            let mut g = Gen::new(seed, size);
            if !prop(&mut g) {
                failing_size = size;
                break;
            }
        }
        panic!(
            "property `{name}` failed (case {case}, seed {seed:#x}, \
             shrunk size {failing_size:.3}); rerun with LSHMF_PROP_SEED={base}"
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check("sort is idempotent", 50, |g| {
            let mut xs = g.vec(0..=32, |g| g.u32(0..100));
            xs.sort_unstable();
            let once = xs.clone();
            xs.sort_unstable();
            xs == once
        });
    }

    #[test]
    #[should_panic(expected = "property `always fails`")]
    fn failing_property_panics_with_seed() {
        check("always fails", 10, |_| false);
    }

    #[test]
    fn generators_respect_ranges() {
        check("usize in range", 100, |g| {
            let x = g.usize(5..=10);
            (5..=10).contains(&x)
        });
        check("u32 in range", 100, |g| {
            let x = g.u32(3..30);
            (3..30).contains(&x)
        });
        check("vec len in range", 100, |g| {
            let v = g.vec(2..=8, |g| g.bool());
            (2..=8).contains(&v.len())
        });
    }

    #[test]
    fn deterministic_given_env_seed() {
        // Same seed, same draws.
        let mut a = Gen::new(1234, 1.0);
        let mut b = Gen::new(1234, 1.0);
        for _ in 0..32 {
            assert_eq!(a.u32(0..1000), b.u32(0..1000));
        }
    }
}
