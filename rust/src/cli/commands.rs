//! Command implementations for the launcher.

use super::Args;
use crate::config::{ExperimentConfig, LshChoice, TrainerChoice};
use crate::coordinator::stream::StreamOrchestrator;
use crate::coordinator::Engine;
use crate::data::synth::{self, SynthConfig};
use crate::data::Dataset;
use crate::gsm::Gsm;
use crate::lsh::{
    MinHash, NeighbourSearch, OnlineHashState, RandNeighbours, RpCos, SimLsh, TopK,
};
use crate::metrics::Registry;
use crate::mf::als::AlsConfig;
use crate::mf::ccd::CcdConfig;
use crate::mf::neighbourhood::{train_culsh_parallel_logged, CulshConfig};
use crate::mf::sgd::SgdConfig;
use crate::mf::TrainLog;
use crate::rng::Rng;
use crate::{Error, Result};

/// Build the dataset named by the config.
pub fn build_dataset(cfg: &ExperimentConfig, rng: &mut Rng) -> Result<Dataset> {
    let synth_cfg = SynthConfig::by_name(cfg.dataset.kind.name())
        .ok_or_else(|| Error::Config(format!("dataset `{}` has no synth generator", cfg.dataset.kind.name())))?
        .scaled(cfg.dataset.scale);
    let mut t = synth::generate_triples(&synth_cfg, rng);
    if cfg.dataset.noise_rate > 0.0 {
        synth::inject_noise(
            &mut t,
            cfg.dataset.noise_rate,
            synth_cfg.min_value,
            synth_cfg.max_value,
            rng,
        );
    }
    Ok(Dataset::split(&synth_cfg.name, t, synth_cfg.test_fraction, rng))
}

/// Build the neighbour table named by the config.
pub fn build_topk(cfg: &ExperimentConfig, ds: &Dataset, rng: &mut Rng) -> (TopK, f64) {
    let k = cfg.model.k;
    let (topk, cost) = match cfg.lsh.kind {
        LshChoice::SimLsh => SimLsh::new(cfg.lsh.p, cfg.lsh.q, cfg.lsh.g, cfg.lsh.psi_power)
            .build(&ds.train_csc, k, rng),
        LshChoice::RpCos => RpCos::new(cfg.lsh.p, cfg.lsh.q, cfg.lsh.g).build(&ds.train_csc, k, rng),
        LshChoice::MinHash => MinHash::new(cfg.lsh.p, cfg.lsh.q).build(&ds.train_csc, k, rng),
        LshChoice::Rand => RandNeighbours.build(&ds.train_csc, k, rng),
        LshChoice::Gsm => Gsm::new(cfg.lsh.lambda_rho).build(&ds.train_csc, k, rng),
    };
    (topk, cost.seconds)
}

/// Run the configured trainer; returns its RMSE-vs-time log.
pub fn run_trainer(cfg: &ExperimentConfig, ds: &Dataset, rng: &mut Rng) -> Result<TrainLog> {
    let t = &cfg.trainer;
    let sgd_cfg = SgdConfig {
        f: cfg.model.f,
        epochs: t.epochs,
        alpha: t.alpha as f32,
        beta: t.beta as f32,
        lambda_u: t.lambda_u as f32,
        lambda_v: t.lambda_v as f32,
        lambda_b: t.lambda_b as f32,
        eval: ds.test.clone(),
        ..Default::default()
    };
    let log = match t.kind {
        TrainerChoice::Serial => {
            crate::mf::sgd::train_sgd_logged(&ds.train, &sgd_cfg, rng).1
        }
        TrainerChoice::Sgd => {
            crate::mf::parallel::train_parallel_sgd_logged(&ds.train, &sgd_cfg, t.threads, rng).1
        }
        TrainerChoice::Hogwild => {
            crate::mf::hogwild::train_hogwild_logged(&ds.train, &sgd_cfg, t.threads, rng).1
        }
        TrainerChoice::Als => {
            let als_cfg = AlsConfig {
                f: cfg.model.f,
                iterations: t.epochs,
                lambda: t.lambda_u as f32,
                threads: t.threads,
                eval: ds.test.clone(),
                ..Default::default()
            };
            crate::mf::als::train_als_logged(&ds.train, &als_cfg, rng).1
        }
        TrainerChoice::Ccd => {
            let ccd_cfg = CcdConfig {
                f: cfg.model.f,
                iterations: t.epochs,
                lambda: t.lambda_u as f32,
                eval: ds.test.clone(),
                ..Default::default()
            };
            crate::mf::ccd::train_ccd_logged(&ds.train, &ccd_cfg, rng).1
        }
        TrainerChoice::Culsh => {
            let (topk, lsh_secs) = build_topk(cfg, ds, rng);
            eprintln!("# neighbour table built in {lsh_secs:.3}s ({})", cfg.lsh.kind.name());
            let culsh_cfg = culsh_config(cfg, ds.test.clone());
            train_culsh_parallel_logged(&ds.train, topk, &culsh_cfg, t.threads, rng).1
        }
    };
    Ok(log)
}

pub fn culsh_config(cfg: &ExperimentConfig, eval: Vec<(u32, u32, f32)>) -> CulshConfig {
    let t = &cfg.trainer;
    CulshConfig {
        f: cfg.model.f,
        k: cfg.model.k,
        epochs: t.epochs,
        alpha: t.alpha as f32,
        alpha_wc: t.alpha_wc as f32,
        beta: t.beta as f32,
        lambda_u: t.lambda_u as f32,
        lambda_v: t.lambda_v as f32,
        lambda_b: t.lambda_b as f32,
        lambda_w: t.lambda_w as f32,
        lambda_c: t.lambda_c as f32,
        eval,
        seed: cfg.dataset.seed,
    }
}

// ------------------------------------------------------------- commands

pub fn gen_data(args: &mut Args) -> Result<()> {
    let cfg = args.experiment_config()?;
    let out = args.get("out").unwrap_or("ratings.txt").to_string();
    let mut rng = Rng::seeded(cfg.dataset.seed);
    let synth_cfg = SynthConfig::by_name(cfg.dataset.kind.name())
        .ok_or_else(|| Error::Config("dataset has no generator".into()))?
        .scaled(cfg.dataset.scale);
    let t = synth::generate_triples(&synth_cfg, &mut rng);
    let mut body = String::with_capacity(t.nnz() * 16);
    for &(i, j, r) in t.entries() {
        body.push_str(&format!("{i}\t{j}\t{r}\n"));
    }
    std::fs::write(&out, body)?;
    println!(
        "wrote {} ratings ({}x{}) to {out}",
        t.nnz(),
        t.nrows(),
        t.ncols()
    );
    Ok(())
}

pub fn train(args: &mut Args) -> Result<()> {
    let cfg = args.experiment_config()?;
    let mut rng = Rng::seeded(cfg.dataset.seed);
    eprintln!(
        "# dataset={} scale={} trainer={} f={} k={}",
        cfg.dataset.kind.name(),
        cfg.dataset.scale,
        cfg.trainer.kind.name(),
        cfg.model.f,
        cfg.model.k
    );
    let ds = build_dataset(&cfg, &mut rng)?;
    eprintln!("# {}x{}, {} train / {} test", ds.nrows(), ds.ncols(), ds.nnz(), ds.test.len());
    let log = run_trainer(&cfg, &ds, &mut rng)?;
    println!("epoch\tseconds\trmse");
    for p in &log.points {
        println!("{}\t{:.4}\t{:.5}", p.epoch, p.seconds, p.rmse);
    }
    println!("# final rmse {:.5} in {:.3}s", log.final_rmse(), log.total_seconds());
    Ok(())
}

pub fn online(args: &mut Args) -> Result<()> {
    let cfg = args.experiment_config()?;
    let mut rng = Rng::seeded(cfg.dataset.seed);
    let synth_cfg = SynthConfig::by_name(cfg.dataset.kind.name())
        .ok_or_else(|| Error::Config("dataset has no generator".into()))?
        .scaled(cfg.dataset.scale);
    let full = synth::generate_triples(&synth_cfg, &mut rng);
    let split = crate::data::online::split_online(&full, cfg.online.holdout, cfg.online.holdout);
    let stats = split.stats(full.nrows(), full.ncols());
    println!(
        "# online split: M={} N={} |Ω|={}  M̄={} N̄={} |Ω̄|={}",
        stats.m, stats.n, stats.omega, stats.m_bar, stats.n_bar, stats.omega_bar
    );

    // test set: last 1% of base entries
    let n_test = (split.base.nnz() / 100).max(1);
    let base_entries = split.base.entries().to_vec();
    let (test, train_entries) = base_entries.split_at(n_test);
    let base_train = crate::sparse::Triples::from_entries(
        split.base.nrows(),
        split.base.ncols(),
        train_entries.to_vec(),
    );

    let csr = crate::sparse::Csr::from_triples(&base_train);
    let csc = crate::sparse::Csc::from_triples(&base_train);
    let lsh = SimLsh::new(cfg.lsh.p, cfg.lsh.q, cfg.lsh.g, cfg.lsh.psi_power);
    let mut hash_state = OnlineHashState::build(lsh, &csc);
    let (topk, _) = hash_state.topk(cfg.model.k, &mut rng);
    let culsh_cfg = culsh_config(&cfg, test.to_vec());
    let (model, log) =
        crate::mf::neighbourhood::train_culsh_logged(&csr, topk, &culsh_cfg, &mut rng);
    let rmse_before = log.final_rmse();
    println!("# base model rmse {rmse_before:.5}");

    let outcome = crate::mf::online::apply_online(
        model,
        &mut hash_state,
        &base_train,
        &split.increment,
        full.nrows(),
        full.ncols(),
        &culsh_cfg,
        cfg.online.epochs,
        &mut rng,
    );
    let rmse_after = outcome.model.rmse(&outcome.combined, test);
    println!("# after online update rmse {rmse_after:.5} (Δ {:+.5})", rmse_after - rmse_before);
    println!("# online update took {:.3}s for {} increments", outcome.seconds, stats.omega_bar);
    Ok(())
}

/// `serve`: recover the engine from the `[persist]` directory when one
/// holds a valid checkpoint, otherwise train a model from the
/// experiment config; then hand it to the one config-driven server
/// entry point. Every serving knob lives in
/// [`ServeConfig`](crate::config::ServeConfig) — the `[server]` /
/// `[engine]` / `[flush]` / `[limits]` / `[metrics]` / `[persist]`
/// sections of `--config lshmf.toml`, with CLI flags (`--port`,
/// `--writers`, `--codec`, `--flush-mode`, `--read-workers`, …)
/// desugaring into the same struct as overrides.
pub fn serve(args: &mut Args) -> Result<()> {
    let cfg = args.experiment_config()?;
    let serve_cfg = args.serve_config()?;
    // One registry across orchestrator, engine, server, and exporter so
    // STATS and GET /metrics report the whole pipeline in one dump.
    let metrics = Registry::new();
    let culsh_cfg = culsh_config(&cfg, Vec::new());

    // Recovery-first: a valid checkpoint (plus WAL tails) replaces the
    // whole training step — the learned state follows disk, the tuning
    // (flush policy, limits, cadence) follows the current config.
    let mut recovered = None;
    if serve_cfg.persist.enabled() {
        let dir = std::path::Path::new(&serve_cfg.persist.dir);
        if let Some((engine, info)) = crate::persist::recover(
            dir,
            serve_cfg.stream_config(),
            culsh_cfg.clone(),
            &metrics,
        )? {
            eprintln!(
                "# recovered from {}: checkpoint gen {}, replayed {} event(s){}",
                serve_cfg.persist.dir,
                info.gen,
                info.replayed_events,
                if info.torn_tails > 0 {
                    format!(", {} torn WAL tail(s) skipped", info.torn_tails)
                } else {
                    String::new()
                },
            );
            recovered = Some((engine, info));
        }
    }
    let (mut engine, recover_info) = match recovered {
        Some((engine, info)) => (engine, Some(info)),
        None => {
            let mut rng = Rng::seeded(cfg.dataset.seed);
            let ds = build_dataset(&cfg, &mut rng)?;
            eprintln!("# training {} on {} ...", cfg.trainer.kind.name(), ds.name);
            let (topk, _) = build_topk(&cfg, &ds, &mut rng);
            let (model, _) = crate::mf::neighbourhood::train_culsh_logged(
                &ds.train,
                topk,
                &culsh_cfg,
                &mut rng,
            );
            let lsh = SimLsh::new(cfg.lsh.p, cfg.lsh.q, cfg.lsh.g, cfg.lsh.psi_power);
            let hash_state = OnlineHashState::build(lsh, &ds.train_csc);
            let orch = StreamOrchestrator::new(
                model,
                hash_state,
                ds.train.to_triples(),
                serve_cfg.stream_config(),
                culsh_cfg,
                rng.split(7),
                metrics.clone(),
            );
            (Engine::new(orch, (ds.min_value, ds.max_value), metrics.clone()), None)
        }
    };
    if serve_cfg.persist.enabled() {
        let nbands = match serve_cfg.engine.mode {
            crate::config::EngineMode::Banded => serve_cfg.engine.writers.max(1),
            _ => 1,
        };
        let persister = crate::persist::Persister::create(
            std::path::Path::new(&serve_cfg.persist.dir),
            serve_cfg.persist.fsync_policy(),
            serve_cfg.persist.checkpoint_every_flushes,
            nbands,
            &engine,
            recover_info.as_ref(),
            &metrics,
        )?;
        engine.attach_persister(persister);
    }
    let listener = std::net::TcpListener::bind(("0.0.0.0", serve_cfg.server.port))?;
    let stop = std::sync::Arc::new(std::sync::atomic::AtomicBool::new(false));
    eprintln!(
        "# serving on port {} ({} mode, {} conn thread(s), codec {}, flush mode {}{}) \
         (PREDICT/MPREDICT/TOPN/RATE/MRATE/FLUSH/STATS/SUBSCRIBE/QUIT)",
        serve_cfg.server.port,
        serve_cfg.engine.mode.name(),
        serve_cfg.server.threads,
        serve_cfg.server.codec.name(),
        serve_cfg.flush.mode.name(),
        if serve_cfg.metrics.enabled {
            format!(", metrics on port {}", serve_cfg.metrics.port)
        } else {
            String::new()
        },
    );
    crate::coordinator::server::serve_with(engine, listener, stop, &serve_cfg)?;
    Ok(())
}

/// `route`: the multi-node tier. No model is trained here — the
/// downstream `serve` processes own the engines; the router owns write
/// ordering, scatter/gather, and fault handling. The
/// `[server]`/`[limits]`/`[metrics]` sections of the same `--config`
/// file govern the front-end listener (port, pool width, codec,
/// admission, Prometheus export) exactly as they do for `serve`;
/// `[route]` + `[[route.backend]]` describe the backend fleet and the
/// router's fault policy.
pub fn route(args: &mut Args) -> Result<()> {
    let route_cfg = args.route_config()?;
    let serve_cfg = args.serve_config()?;
    let metrics = Registry::new();
    let router = crate::coordinator::Router::new(&route_cfg, metrics);
    let listener = std::net::TcpListener::bind(("0.0.0.0", serve_cfg.server.port))?;
    let stop = std::sync::Arc::new(std::sync::atomic::AtomicBool::new(false));
    let d = route_cfg.backends.len();
    eprintln!(
        "# routing on port {} over {} backend(s) ({} conn thread(s), codec {}{})",
        serve_cfg.server.port,
        d,
        serve_cfg.server.threads,
        serve_cfg.server.codec.name(),
        if serve_cfg.metrics.enabled {
            format!(", metrics on port {}", serve_cfg.metrics.port)
        } else {
            String::new()
        },
    );
    for (i, b) in route_cfg.backends.iter().enumerate() {
        // Band boundaries mirror sparse::band_of: backend i owns
        // columns [ceil(i*cols/d), ceil((i+1)*cols/d)).
        let lo = (i * route_cfg.cols + d - 1) / d;
        let hi = ((i + 1) * route_cfg.cols + d - 1) / d;
        eprintln!("#   backend{i} {} owns cols [{lo}, {hi})", b.addr);
    }
    crate::coordinator::server::serve_route(router, listener, stop, &serve_cfg)?;
    Ok(())
}

pub fn info(_args: &mut Args) -> Result<()> {
    let dir = crate::runtime::Runtime::default_dir();
    if !crate::runtime::Runtime::available(&dir) {
        println!("artifacts: NOT FOUND at {} (run `make artifacts`)", dir.display());
        return Ok(());
    }
    let rt = crate::runtime::Runtime::open(&dir)?;
    println!("artifacts: {}", dir.display());
    println!(
        "shapes: batch={} f={} k={} hash=[{}x{}->{} bits]",
        rt.manifest.batch, rt.manifest.f, rt.manifest.k, rt.manifest.hash_n, rt.manifest.hash_m, rt.manifest.hash_g
    );
    println!("graphs:");
    for (name, entry) in &rt.manifest.graphs {
        println!("  {name:<24} {} ({} inputs)", entry.file, entry.inputs.len());
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(xs: &[&str]) -> Args {
        Args::parse(&xs.iter().map(|s| s.to_string()).collect::<Vec<_>>()).unwrap()
    }

    #[test]
    fn build_dataset_respects_scale() {
        let cfg = args(&["train", "--dataset", "movielens", "--scale", "0.02"])
            .experiment_config()
            .unwrap();
        let mut rng = Rng::seeded(1);
        let ds = build_dataset(&cfg, &mut rng).unwrap();
        assert!(ds.nrows() > 500 && ds.nrows() < 2500);
        assert!(ds.test.len() > 0);
    }

    #[test]
    fn all_trainers_run_one_epoch() {
        for trainer in ["serial", "sgd", "hogwild", "als", "ccd"] {
            let cfg = args(&[
                "train", "--dataset", "movielens", "--scale", "0.01", "--epochs", "1",
                "--trainer", trainer, "--f", "8", "--threads", "2",
            ])
            .experiment_config()
            .unwrap();
            let mut rng = Rng::seeded(2);
            let ds = build_dataset(&cfg, &mut rng).unwrap();
            let log = run_trainer(&cfg, &ds, &mut rng).unwrap();
            assert!(log.final_rmse().is_finite(), "{trainer}");
        }
    }

    #[test]
    fn culsh_trainer_runs_with_each_lsh() {
        for lsh in ["simlsh", "rand"] {
            let cfg = args(&[
                "train", "--dataset", "movielens", "--scale", "0.01", "--epochs", "2",
                "--trainer", "culsh", "--f", "8", "--k", "8", "--lsh", lsh, "--q", "4",
            ])
            .experiment_config()
            .unwrap();
            let mut rng = Rng::seeded(3);
            let ds = build_dataset(&cfg, &mut rng).unwrap();
            let log = run_trainer(&cfg, &ds, &mut rng).unwrap();
            assert!(log.final_rmse().is_finite(), "{lsh}");
        }
    }
}
