//! Flag parsing: `<command> [--key value]... [--flag]...`.

use crate::config::{parse_codec, parse_flush_mode, EngineMode, ExperimentConfig, ServeConfig};
use crate::{Error, Result};
use std::collections::BTreeMap;

/// Parsed command line.
#[derive(Clone, Debug, Default)]
pub struct Args {
    pub command: String,
    flags: BTreeMap<String, String>,
}

impl Args {
    pub fn parse(argv: &[String]) -> Result<Args> {
        let mut args = Args::default();
        let mut it = argv.iter().peekable();
        if let Some(first) = it.next() {
            if first.starts_with("--") {
                return Err(Error::Config("expected a command before flags".into()));
            }
            args.command = first.clone();
        }
        while let Some(tok) = it.next() {
            let key = tok
                .strip_prefix("--")
                .ok_or_else(|| Error::Config(format!("expected --flag, got `{tok}`")))?;
            if key.is_empty() {
                return Err(Error::Config("empty flag".into()));
            }
            // value = next token unless it is another flag (bool flags)
            let value = match it.peek() {
                Some(v) if !v.starts_with("--") => it.next().unwrap().clone(),
                _ => "true".to_string(),
            };
            args.flags.insert(key.to_string(), value);
        }
        Ok(args)
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    pub fn get_usize(&self, key: &str) -> Result<Option<usize>> {
        self.get(key)
            .map(|v| {
                v.parse()
                    .map_err(|_| Error::Config(format!("--{key} must be an integer")))
            })
            .transpose()
    }

    /// Value of `--key`, constrained to one of `allowed` — a typed CLI
    /// error (naming the choices) instead of a downstream mismatch.
    pub fn get_choice(&self, key: &str, allowed: &[&str]) -> Result<Option<&str>> {
        match self.get(key) {
            None => Ok(None),
            Some(v) if allowed.contains(&v) => Ok(Some(v)),
            Some(v) => Err(Error::Config(format!(
                "--{key} must be one of {} (got `{v}`)",
                allowed.join("|")
            ))),
        }
    }

    pub fn get_f64(&self, key: &str) -> Result<Option<f64>> {
        self.get(key)
            .map(|v| {
                v.parse()
                    .map_err(|_| Error::Config(format!("--{key} must be a number")))
            })
            .transpose()
    }

    /// Build the experiment config: file (if given) + flag overrides.
    pub fn experiment_config(&self) -> Result<ExperimentConfig> {
        let mut cfg = match self.get("config") {
            Some(path) => ExperimentConfig::from_file(std::path::Path::new(path))?,
            None => ExperimentConfig::default(),
        };
        if let Some(ds) = self.get("dataset") {
            cfg.dataset.kind = crate::config::DatasetChoice::parse(ds)?;
        }
        if let Some(s) = self.get_f64("scale")? {
            cfg.dataset.scale = s;
        }
        if let Some(s) = self.get_usize("seed")? {
            cfg.dataset.seed = s as u64;
        }
        if let Some(t) = self.get("trainer") {
            cfg.trainer.kind = crate::config::TrainerChoice::parse(t)?;
        }
        if let Some(l) = self.get("lsh") {
            cfg.lsh.kind = crate::config::LshChoice::parse(l)?;
        }
        if let Some(v) = self.get_usize("f")? {
            cfg.model.f = v;
        }
        if let Some(v) = self.get_usize("k")? {
            cfg.model.k = v;
        }
        if let Some(v) = self.get_usize("epochs")? {
            cfg.trainer.epochs = v;
        }
        if let Some(v) = self.get_usize("threads")? {
            cfg.trainer.threads = v;
        }
        if let Some(v) = self.get_usize("p")? {
            cfg.lsh.p = v;
        }
        if let Some(v) = self.get_usize("q")? {
            cfg.lsh.q = v;
        }
        cfg.validate()?;
        Ok(cfg)
    }

    /// Build the serving config: `--config` file (if given) + flag
    /// overrides, re-validated after the overrides land. Flags beat the
    /// file; the file beats the defaults. `--writers N` (N > 0) implies
    /// banded mode, exactly like the legacy CLI.
    pub fn serve_config(&self) -> Result<ServeConfig> {
        let mut cfg = match self.get("config") {
            Some(path) => ServeConfig::from_file(std::path::Path::new(path))?,
            None => ServeConfig::default(),
        };
        if let Some(v) = self.get_usize("port")? {
            if v == 0 || v > u16::MAX as usize {
                return Err(Error::Config("--port must be in 1..=65535".into()));
            }
            cfg.server.port = v as u16;
        }
        if let Some(v) = self.get_usize("threads")? {
            cfg.server.threads = v;
        }
        if let Some(v) = self.get_usize("read-workers")? {
            cfg.server.read_workers = v;
        }
        if let Some(c) = self.get("codec") {
            cfg.server.codec = parse_codec(c)?;
        }
        if let Some(v) = self.get_usize("shards")? {
            cfg.engine.shards = v;
        }
        if let Some(v) = self.get_usize("writers")? {
            cfg.engine.writers = v;
            if v > 0 {
                cfg.engine.mode = EngineMode::Banded;
            }
        }
        if let Some(m) = self.get("mode") {
            cfg.engine.mode = EngineMode::parse(m)?;
        }
        if let Some(m) = self.get("flush-mode") {
            cfg.flush.mode = parse_flush_mode(m)?;
        }
        cfg.validate()?;
        Ok(cfg)
    }

    /// Build the route-tier config from the `--config` file. The fleet
    /// layout is file-only (no flag overrides), so a missing file or a
    /// config carrying no route sections is a pointed error instead of
    /// an empty-fleet validation failure.
    pub fn route_config(&self) -> Result<crate::config::RouteConfig> {
        let path = self.get("config").ok_or_else(|| {
            Error::Config(
                "route needs --config <file> carrying a [route] section and at least one \
                 [[route.backend]]"
                    .into(),
            )
        })?;
        let path = std::path::Path::new(path);
        let text = std::fs::read_to_string(path)?;
        let origin = path.display().to_string();
        let (tree, spans) = crate::config::parse_spanned(&text)
            .map_err(|e| Error::Config(format!("{origin}: {e}")))?;
        if !crate::config::RouteConfig::present(&tree) {
            return Err(Error::Config(format!(
                "{origin}: no [route] section — the route tier is configured by [route] plus \
                 one [[route.backend]] per downstream serve process"
            )));
        }
        crate::config::RouteConfig::from_tree(&tree, &spans, &origin)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sv(xs: &[&str]) -> Vec<String> {
        xs.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parses_command_and_flags() {
        let a = Args::parse(&sv(&["train", "--f", "64", "--verbose", "--scale", "0.2"])).unwrap();
        assert_eq!(a.command, "train");
        assert_eq!(a.get_usize("f").unwrap(), Some(64));
        assert_eq!(a.get("verbose"), Some("true"));
        assert_eq!(a.get_f64("scale").unwrap(), Some(0.2));
        assert_eq!(a.get("missing"), None);
    }

    #[test]
    fn rejects_flag_before_command_and_bad_numbers() {
        assert!(Args::parse(&sv(&["--f", "64"])).is_err());
        let a = Args::parse(&sv(&["train", "--f", "lots"])).unwrap();
        assert!(a.get_usize("f").is_err());
    }

    #[test]
    fn get_choice_constrains_values() {
        let a = Args::parse(&sv(&["serve", "--codec", "binary"])).unwrap();
        assert_eq!(
            a.get_choice("codec", &["text", "binary", "auto"]).unwrap(),
            Some("binary")
        );
        assert_eq!(a.get_choice("missing", &["x"]).unwrap(), None);
        let a = Args::parse(&sv(&["serve", "--codec", "morse"])).unwrap();
        assert!(a.get_choice("codec", &["text", "binary", "auto"]).is_err());
    }

    #[test]
    fn experiment_config_overrides() {
        let a = Args::parse(&sv(&[
            "train", "--dataset", "netflix", "--trainer", "als", "--f", "16", "--epochs", "3",
        ]))
        .unwrap();
        let cfg = a.experiment_config().unwrap();
        assert_eq!(cfg.model.f, 16);
        assert_eq!(cfg.trainer.epochs, 3);
        assert_eq!(cfg.trainer.kind, crate::config::TrainerChoice::Als);
        assert_eq!(cfg.dataset.kind, crate::config::DatasetChoice::Netflix);
    }

    #[test]
    fn bad_choice_is_an_error() {
        let a = Args::parse(&sv(&["train", "--trainer", "magic"])).unwrap();
        assert!(a.experiment_config().is_err());
    }

    #[test]
    fn serve_config_defaults_without_flags() {
        let a = Args::parse(&sv(&["serve"])).unwrap();
        let cfg = a.serve_config().unwrap();
        assert_eq!(cfg.server.port, 7878);
        assert_eq!(cfg.engine.mode, EngineMode::Sharded);
    }

    #[test]
    fn serve_flags_override_config_file() {
        let dir = std::env::temp_dir().join("lshmf-args-serve-test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("serve.toml");
        std::fs::write(
            &path,
            "[server]\nport = 9000\nthreads = 3\n\n[engine]\nmode = \"banded\"\nwriters = 2\n",
        )
        .unwrap();
        let p = path.to_str().unwrap();

        // file alone: its values beat the defaults
        let a = Args::parse(&sv(&["serve", "--config", p])).unwrap();
        let cfg = a.serve_config().unwrap();
        assert_eq!(cfg.server.port, 9000);
        assert_eq!(cfg.server.threads, 3);
        assert_eq!(cfg.engine.mode, EngineMode::Banded);
        assert_eq!(cfg.engine.writers, 2);

        // flags beat the file, untouched file values survive
        let a = Args::parse(&sv(&[
            "serve", "--config", p, "--port", "9001", "--writers", "4", "--read-workers", "3",
            "--codec", "binary", "--flush-mode", "relaxed",
        ]))
        .unwrap();
        let cfg = a.serve_config().unwrap();
        assert_eq!(cfg.server.port, 9001, "flag beats file");
        assert_eq!(cfg.server.threads, 3, "file value survives");
        assert_eq!(cfg.engine.writers, 4);
        assert_eq!(cfg.server.read_workers, 3);
        assert_eq!(
            cfg.server.codec,
            crate::coordinator::protocol::CodecChoice::Binary
        );
        assert_eq!(cfg.flush.mode, crate::coordinator::FlushMode::Relaxed);

        // overrides re-validate: forcing writers to 0 breaks banded mode
        let a = Args::parse(&sv(&["serve", "--config", p, "--writers", "0"])).unwrap();
        let err = a.serve_config().unwrap_err();
        assert!(
            err.to_string().contains("requires writers > 0"),
            "{err}"
        );
        std::fs::remove_file(&path).ok();
    }
}
