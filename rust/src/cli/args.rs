//! Flag parsing: `<command> [--key value]... [--flag]...`.

use crate::config::ExperimentConfig;
use crate::{Error, Result};
use std::collections::BTreeMap;

/// Parsed command line.
#[derive(Clone, Debug, Default)]
pub struct Args {
    pub command: String,
    flags: BTreeMap<String, String>,
}

impl Args {
    pub fn parse(argv: &[String]) -> Result<Args> {
        let mut args = Args::default();
        let mut it = argv.iter().peekable();
        if let Some(first) = it.next() {
            if first.starts_with("--") {
                return Err(Error::Config("expected a command before flags".into()));
            }
            args.command = first.clone();
        }
        while let Some(tok) = it.next() {
            let key = tok
                .strip_prefix("--")
                .ok_or_else(|| Error::Config(format!("expected --flag, got `{tok}`")))?;
            if key.is_empty() {
                return Err(Error::Config("empty flag".into()));
            }
            // value = next token unless it is another flag (bool flags)
            let value = match it.peek() {
                Some(v) if !v.starts_with("--") => it.next().unwrap().clone(),
                _ => "true".to_string(),
            };
            args.flags.insert(key.to_string(), value);
        }
        Ok(args)
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    pub fn get_usize(&self, key: &str) -> Result<Option<usize>> {
        self.get(key)
            .map(|v| {
                v.parse()
                    .map_err(|_| Error::Config(format!("--{key} must be an integer")))
            })
            .transpose()
    }

    /// Value of `--key`, constrained to one of `allowed` — a typed CLI
    /// error (naming the choices) instead of a downstream mismatch.
    pub fn get_choice(&self, key: &str, allowed: &[&str]) -> Result<Option<&str>> {
        match self.get(key) {
            None => Ok(None),
            Some(v) if allowed.contains(&v) => Ok(Some(v)),
            Some(v) => Err(Error::Config(format!(
                "--{key} must be one of {} (got `{v}`)",
                allowed.join("|")
            ))),
        }
    }

    pub fn get_f64(&self, key: &str) -> Result<Option<f64>> {
        self.get(key)
            .map(|v| {
                v.parse()
                    .map_err(|_| Error::Config(format!("--{key} must be a number")))
            })
            .transpose()
    }

    /// Build the experiment config: file (if given) + flag overrides.
    pub fn experiment_config(&self) -> Result<ExperimentConfig> {
        let mut cfg = match self.get("config") {
            Some(path) => ExperimentConfig::from_file(std::path::Path::new(path))?,
            None => ExperimentConfig::default(),
        };
        if let Some(ds) = self.get("dataset") {
            cfg.dataset.kind = crate::config::DatasetChoice::parse(ds)?;
        }
        if let Some(s) = self.get_f64("scale")? {
            cfg.dataset.scale = s;
        }
        if let Some(s) = self.get_usize("seed")? {
            cfg.dataset.seed = s as u64;
        }
        if let Some(t) = self.get("trainer") {
            cfg.trainer.kind = crate::config::TrainerChoice::parse(t)?;
        }
        if let Some(l) = self.get("lsh") {
            cfg.lsh.kind = crate::config::LshChoice::parse(l)?;
        }
        if let Some(v) = self.get_usize("f")? {
            cfg.model.f = v;
        }
        if let Some(v) = self.get_usize("k")? {
            cfg.model.k = v;
        }
        if let Some(v) = self.get_usize("epochs")? {
            cfg.trainer.epochs = v;
        }
        if let Some(v) = self.get_usize("threads")? {
            cfg.trainer.threads = v;
        }
        if let Some(v) = self.get_usize("p")? {
            cfg.lsh.p = v;
        }
        if let Some(v) = self.get_usize("q")? {
            cfg.lsh.q = v;
        }
        cfg.validate()?;
        Ok(cfg)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sv(xs: &[&str]) -> Vec<String> {
        xs.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parses_command_and_flags() {
        let a = Args::parse(&sv(&["train", "--f", "64", "--verbose", "--scale", "0.2"])).unwrap();
        assert_eq!(a.command, "train");
        assert_eq!(a.get_usize("f").unwrap(), Some(64));
        assert_eq!(a.get("verbose"), Some("true"));
        assert_eq!(a.get_f64("scale").unwrap(), Some(0.2));
        assert_eq!(a.get("missing"), None);
    }

    #[test]
    fn rejects_flag_before_command_and_bad_numbers() {
        assert!(Args::parse(&sv(&["--f", "64"])).is_err());
        let a = Args::parse(&sv(&["train", "--f", "lots"])).unwrap();
        assert!(a.get_usize("f").is_err());
    }

    #[test]
    fn get_choice_constrains_values() {
        let a = Args::parse(&sv(&["serve", "--codec", "binary"])).unwrap();
        assert_eq!(
            a.get_choice("codec", &["text", "binary", "auto"]).unwrap(),
            Some("binary")
        );
        assert_eq!(a.get_choice("missing", &["x"]).unwrap(), None);
        let a = Args::parse(&sv(&["serve", "--codec", "morse"])).unwrap();
        assert!(a.get_choice("codec", &["text", "binary", "auto"]).is_err());
    }

    #[test]
    fn experiment_config_overrides() {
        let a = Args::parse(&sv(&[
            "train", "--dataset", "netflix", "--trainer", "als", "--f", "16", "--epochs", "3",
        ]))
        .unwrap();
        let cfg = a.experiment_config().unwrap();
        assert_eq!(cfg.model.f, 16);
        assert_eq!(cfg.trainer.epochs, 3);
        assert_eq!(cfg.trainer.kind, crate::config::TrainerChoice::Als);
        assert_eq!(cfg.dataset.kind, crate::config::DatasetChoice::Netflix);
    }

    #[test]
    fn bad_choice_is_an_error() {
        let a = Args::parse(&sv(&["train", "--trainer", "magic"])).unwrap();
        assert!(a.experiment_config().is_err());
    }
}
