//! Command-line launcher.
//!
//! ```text
//! lshmf gen-data  --dataset movielens --scale 0.05 --seed 42 --out ratings.txt
//! lshmf train     [--config exp.toml] [--dataset movielens] [--scale 0.05]
//!                 [--trainer culsh|sgd|hogwild|als|ccd|serial] [--f 32] [--k 32]
//!                 [--epochs 20] [--threads 4] [--lsh simlsh|gsm|rpcos|minhash|rand]
//! lshmf online    [--config exp.toml] — Table 9 protocol: base train,
//!                 increment via Algorithm 4, report the RMSE delta
//! lshmf serve     [--config lshmf.toml] [--port 7878] [--threads 4]
//!                 [--shards 8] [--writers N] [--mode mutex|sharded|banded]
//!                 [--read-workers 2] [--codec text|binary|auto]
//!                 [--flush-mode exact|relaxed]
//!                 — train, then serve TCP with a bounded reader pool
//!                 (snapshots sharded by column band, writes
//!                 single-writer or per-band multi-writer; the wire
//!                 protocol is typed Request/Response over a text or
//!                 pipelined binary codec — see coordinator::protocol;
//!                 relaxed flush mode trains band-parallel inside the
//!                 epoch — see coordinator::stream::FlushMode). The
//!                 config file's [server]/[engine]/[flush]/[limits]/
//!                 [metrics] sections cover the whole serving surface
//!                 (admission control, Prometheus export); flags are
//!                 overrides into the same ServeConfig.
//! lshmf route     --config lshmf.toml — multi-node route tier: front a
//!                 fleet of `serve` processes ([[route.backend]]) with
//!                 replicated writes and column-band scatter/gather
//!                 reads, bit-identical to one monolithic engine (the
//!                 [server]/[limits]/[metrics] sections govern the
//!                 front-end listener exactly as for serve)
//! lshmf info      — artifact bundle status (PJRT graphs available?)
//! ```
//!
//! Flags override config-file values; defaults come from
//! [`ExperimentConfig`] (the paper's Tables 3/5 hyper-parameters).

mod args;
pub mod commands;

pub use args::Args;

/// Entry point (returns the process exit code).
pub fn main() -> i32 {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    match run(&argv) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e}");
            2
        }
    }
}

/// Dispatch a parsed command line (separated from `main` for tests).
pub fn run(argv: &[String]) -> crate::Result<()> {
    let mut args = Args::parse(argv)?;
    let cmd = args.command.clone();
    match cmd.as_str() {
        "gen-data" => commands::gen_data(&mut args),
        "train" => commands::train(&mut args),
        "online" => commands::online(&mut args),
        "serve" => commands::serve(&mut args),
        "route" => commands::route(&mut args),
        "info" => commands::info(&mut args),
        "help" | "" => {
            print!("{}", HELP);
            Ok(())
        }
        other => Err(crate::Error::Config(format!(
            "unknown command `{other}` (try `lshmf help`)"
        ))),
    }
}

pub const HELP: &str = "\
lshmf — LSH-aggregated nonlinear neighbourhood MF (CULSH-MF reproduction)

USAGE: lshmf <command> [flags]

COMMANDS:
  gen-data   generate a synthetic rating file (Table 2 calibrated)
  train      train a model and report the RMSE-vs-time curve
  online     run the Table 9 online-learning protocol
  serve      train, then serve predictions over TCP (see server.rs verbs)
  route      front a fleet of serve processes (the [route] and
             [[route.backend]] config sections) with the same wire
             protocol: writes replicate in one global order, reads
             scatter/gather by column band, dead backends answer typed
             ERR unavailable and replay back to parity on recovery
  info       show the AOT artifact bundle status
  help       this text

COMMON FLAGS:
  --config <file>      TOML config (flags override). One file carries the
                       experiment sections ([dataset]/[model]/...) and, for
                       serve, the closed serving sections ([server]/[engine]/
                       [flush]/[limits]/[metrics]) — see lshmf.toml at the
                       repo root for a commented example
  --dataset <name>     netflix | movielens | yahoo (synthetic, calibrated)
  --scale <0..1>       linear size factor (default 0.1)
  --seed <u64>         RNG seed
  --trainer <name>     culsh | sgd | hogwild | als | ccd | serial
  --lsh <name>         simlsh | gsm | rpcos | minhash | rand
  --f / --k <int>      latent dim / neighbourhood size
  --epochs <int>       training epochs
  --threads <int>      worker threads (training block-rotation; serve
                       uses it as the connection-pool width)
  --port <int>         serve: TCP port (default 7878)
  --shards <int>       serve: snapshot column-band shard count (default 8)
  --writers <int>      serve: per-band multi-writer ingest (N queues == N
                       shards; implies --mode banded)
  --mode <name>        serve: mutex | sharded | banded engine flavour
                       (default sharded)
  --read-workers <int> serve: out-of-order read lanes per binary
                       connection (default 2)
  --codec <name>       serve: text | binary | auto (default auto — per-
                       connection detection by first byte)
  --flush-mode <name>  serve: exact | relaxed (default exact — bit-identical
                       replies; relaxed trains band-parallel inside the
                       flush epoch, trading bit-identity for a bounded,
                       property-tested divergence and lower flush latency)
  --out <file>         gen-data: output path
";

#[cfg(test)]
mod tests {
    #[test]
    fn help_runs() {
        super::run(&["help".to_string()]).unwrap();
    }

    #[test]
    fn unknown_command_errors() {
        assert!(super::run(&["frobnicate".to_string()]).is_err());
    }
}
