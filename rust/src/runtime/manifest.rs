//! Minimal JSON parser for the AOT manifest (no serde offline).
//!
//! Full JSON value model (objects, arrays, strings with escapes, numbers,
//! booleans, null) — small, recursive-descent, and fully tested. Only the
//! manifest reader consumes it, but it is a general parser.

use std::collections::BTreeMap;

/// A parsed JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|f| f as usize)
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }
}

/// Parse a JSON document.
pub fn parse_json(text: &str) -> Result<Json, String> {
    let bytes = text.as_bytes();
    let mut pos = 0usize;
    let v = parse_value(bytes, &mut pos)?;
    skip_ws(bytes, &mut pos);
    if pos != bytes.len() {
        return Err(format!("trailing garbage at byte {pos}"));
    }
    Ok(v)
}

fn skip_ws(b: &[u8], pos: &mut usize) {
    while *pos < b.len() && matches!(b[*pos], b' ' | b'\t' | b'\n' | b'\r') {
        *pos += 1;
    }
}

fn expect(b: &[u8], pos: &mut usize, c: u8) -> Result<(), String> {
    if *pos < b.len() && b[*pos] == c {
        *pos += 1;
        Ok(())
    } else {
        Err(format!("expected `{}` at byte {}", c as char, pos))
    }
}

fn parse_value(b: &[u8], pos: &mut usize) -> Result<Json, String> {
    skip_ws(b, pos);
    if *pos >= b.len() {
        return Err("unexpected end of input".into());
    }
    match b[*pos] {
        b'{' => parse_obj(b, pos),
        b'[' => parse_arr(b, pos),
        b'"' => Ok(Json::Str(parse_string(b, pos)?)),
        b't' => parse_lit(b, pos, "true", Json::Bool(true)),
        b'f' => parse_lit(b, pos, "false", Json::Bool(false)),
        b'n' => parse_lit(b, pos, "null", Json::Null),
        _ => parse_num(b, pos),
    }
}

fn parse_lit(b: &[u8], pos: &mut usize, lit: &str, v: Json) -> Result<Json, String> {
    if b[*pos..].starts_with(lit.as_bytes()) {
        *pos += lit.len();
        Ok(v)
    } else {
        Err(format!("bad literal at byte {pos}"))
    }
}

fn parse_num(b: &[u8], pos: &mut usize) -> Result<Json, String> {
    let start = *pos;
    while *pos < b.len()
        && matches!(b[*pos], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
    {
        *pos += 1;
    }
    std::str::from_utf8(&b[start..*pos])
        .ok()
        .and_then(|s| s.parse::<f64>().ok())
        .map(Json::Num)
        .ok_or_else(|| format!("bad number at byte {start}"))
}

fn parse_string(b: &[u8], pos: &mut usize) -> Result<String, String> {
    expect(b, pos, b'"')?;
    let mut out = String::new();
    while *pos < b.len() {
        match b[*pos] {
            b'"' => {
                *pos += 1;
                return Ok(out);
            }
            b'\\' => {
                *pos += 1;
                if *pos >= b.len() {
                    break;
                }
                match b[*pos] {
                    b'"' => out.push('"'),
                    b'\\' => out.push('\\'),
                    b'/' => out.push('/'),
                    b'n' => out.push('\n'),
                    b't' => out.push('\t'),
                    b'r' => out.push('\r'),
                    b'b' => out.push('\u{8}'),
                    b'f' => out.push('\u{c}'),
                    b'u' => {
                        if *pos + 4 >= b.len() {
                            return Err("bad \\u escape".into());
                        }
                        let hex = std::str::from_utf8(&b[*pos + 1..*pos + 5])
                            .map_err(|_| "bad \\u escape")?;
                        let code =
                            u32::from_str_radix(hex, 16).map_err(|_| "bad \\u escape")?;
                        out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                        *pos += 4;
                    }
                    c => return Err(format!("bad escape \\{}", c as char)),
                }
                *pos += 1;
            }
            c => {
                // handle multi-byte UTF-8 transparently
                let s = &b[*pos..];
                let ch_len = match c {
                    0x00..=0x7f => 1,
                    0xc0..=0xdf => 2,
                    0xe0..=0xef => 3,
                    _ => 4,
                };
                out.push_str(
                    std::str::from_utf8(&s[..ch_len.min(s.len())])
                        .map_err(|_| "bad utf8")?,
                );
                *pos += ch_len;
            }
        }
    }
    Err("unterminated string".into())
}

fn parse_arr(b: &[u8], pos: &mut usize) -> Result<Json, String> {
    expect(b, pos, b'[')?;
    let mut items = Vec::new();
    skip_ws(b, pos);
    if *pos < b.len() && b[*pos] == b']' {
        *pos += 1;
        return Ok(Json::Arr(items));
    }
    loop {
        items.push(parse_value(b, pos)?);
        skip_ws(b, pos);
        if *pos < b.len() && b[*pos] == b',' {
            *pos += 1;
            continue;
        }
        expect(b, pos, b']')?;
        return Ok(Json::Arr(items));
    }
}

fn parse_obj(b: &[u8], pos: &mut usize) -> Result<Json, String> {
    expect(b, pos, b'{')?;
    let mut map = BTreeMap::new();
    skip_ws(b, pos);
    if *pos < b.len() && b[*pos] == b'}' {
        *pos += 1;
        return Ok(Json::Obj(map));
    }
    loop {
        skip_ws(b, pos);
        let key = parse_string(b, pos)?;
        skip_ws(b, pos);
        expect(b, pos, b':')?;
        let val = parse_value(b, pos)?;
        map.insert(key, val);
        skip_ws(b, pos);
        if *pos < b.len() && b[*pos] == b',' {
            *pos += 1;
            continue;
        }
        expect(b, pos, b'}')?;
        return Ok(Json::Obj(map));
    }
}

/// Typed view of `artifacts/manifest.json`.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub batch: usize,
    pub f: usize,
    pub k: usize,
    pub hash_n: usize,
    pub hash_m: usize,
    pub hash_g: usize,
    pub graphs: BTreeMap<String, GraphEntry>,
    pub neural: NeuralMeta,
}

#[derive(Clone, Debug)]
pub struct GraphEntry {
    pub file: String,
    pub inputs: Vec<(Vec<usize>, String)>,
    /// Parameter names for neural steps (empty otherwise).
    pub params: Vec<(String, Vec<usize>)>,
}

#[derive(Clone, Debug, Default)]
pub struct NeuralMeta {
    pub n_users: usize,
    pub n_items: usize,
    pub embed: usize,
    pub batch: usize,
    pub eval_batch: usize,
}

impl Manifest {
    pub fn parse(text: &str) -> Result<Manifest, String> {
        let j = parse_json(text)?;
        let need = |k: &str| j.get(k).and_then(Json::as_usize).ok_or(format!("missing {k}"));
        let mut graphs = BTreeMap::new();
        for (name, entry) in j
            .get("graphs")
            .and_then(Json::as_obj)
            .ok_or("missing graphs")?
        {
            let file = entry
                .get("file")
                .and_then(Json::as_str)
                .ok_or("graph missing file")?
                .to_string();
            let mut inputs = Vec::new();
            for spec in entry.get("inputs").and_then(Json::as_arr).unwrap_or(&[]) {
                let shape = spec
                    .get("shape")
                    .and_then(Json::as_arr)
                    .map(|a| a.iter().filter_map(Json::as_usize).collect())
                    .unwrap_or_default();
                let dtype = spec
                    .get("dtype")
                    .and_then(Json::as_str)
                    .unwrap_or("float32")
                    .to_string();
                inputs.push((shape, dtype));
            }
            let mut params = Vec::new();
            for p in entry.get("params").and_then(Json::as_arr).unwrap_or(&[]) {
                let name = p.get("name").and_then(Json::as_str).unwrap_or("").to_string();
                let shape = p
                    .get("shape")
                    .and_then(Json::as_arr)
                    .map(|a| a.iter().filter_map(Json::as_usize).collect())
                    .unwrap_or_default();
                params.push((name, shape));
            }
            graphs.insert(name.clone(), GraphEntry { file, inputs, params });
        }
        let neural_j = j.get("neural");
        let nm = |k: &str| {
            neural_j
                .and_then(|n| n.get(k))
                .and_then(Json::as_usize)
                .unwrap_or(0)
        };
        Ok(Manifest {
            batch: need("batch")?,
            f: need("f")?,
            k: need("k")?,
            hash_n: need("hash_n")?,
            hash_m: need("hash_m")?,
            hash_g: need("hash_g")?,
            graphs,
            neural: NeuralMeta {
                n_users: nm("n_users"),
                n_items: nm("n_items"),
                embed: nm("embed"),
                batch: nm("batch"),
                eval_batch: nm("eval_batch"),
            },
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars_arrays_objects() {
        let j = parse_json(r#"{"a": 1.5, "b": [1, 2, 3], "c": {"d": "x"}, "e": true, "f": null}"#)
            .unwrap();
        assert_eq!(j.get("a").unwrap().as_f64(), Some(1.5));
        assert_eq!(j.get("b").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(j.get("c").unwrap().get("d").unwrap().as_str(), Some("x"));
        assert_eq!(j.get("e"), Some(&Json::Bool(true)));
        assert_eq!(j.get("f"), Some(&Json::Null));
    }

    #[test]
    fn parses_escapes_and_unicode() {
        let j = parse_json(r#"["a\nb", "q\"q", "A", "héllo"]"#).unwrap();
        let a = j.as_arr().unwrap();
        assert_eq!(a[0].as_str(), Some("a\nb"));
        assert_eq!(a[1].as_str(), Some("q\"q"));
        assert_eq!(a[2].as_str(), Some("A"));
        assert_eq!(a[3].as_str(), Some("héllo"));
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse_json("{").is_err());
        assert!(parse_json("[1, 2,, 3]").is_err());
        assert!(parse_json("{} extra").is_err());
        assert!(parse_json(r#"{"a" 1}"#).is_err());
    }

    #[test]
    fn negative_and_exponent_numbers() {
        let j = parse_json("[-1.5e3, 0.25, 7]").unwrap();
        let a = j.as_arr().unwrap();
        assert_eq!(a[0].as_f64(), Some(-1500.0));
        assert_eq!(a[1].as_f64(), Some(0.25));
        assert_eq!(a[2].as_usize(), Some(7));
    }

    #[test]
    fn parses_manifest_shape() {
        let text = r#"{
            "format": "hlo-text", "batch": 1024, "f": 32, "k": 32,
            "hash_n": 256, "hash_m": 512, "hash_g": 8,
            "neural": {"n_users": 2048, "n_items": 1024, "embed": 16,
                       "batch": 512, "eval_batch": 512},
            "graphs": {
                "mf_sgd_step": {"file": "mf_sgd_step.hlo.txt",
                    "inputs": [{"shape": [5], "dtype": "float32"},
                               {"shape": [1024], "dtype": "float32"}]},
                "gmf_step": {"file": "gmf_step.hlo.txt",
                    "inputs": [{"shape": [512], "dtype": "int32"}],
                    "params": [{"name": "item", "shape": [1024, 16]}]}
            }
        }"#;
        let m = Manifest::parse(text).unwrap();
        assert_eq!(m.batch, 1024);
        assert_eq!(m.hash_g, 8);
        assert_eq!(m.graphs["mf_sgd_step"].inputs[1].0, vec![1024]);
        assert_eq!(m.graphs["gmf_step"].params[0].0, "item");
        assert_eq!(m.neural.n_users, 2048);
    }
}
