//! PJRT runtime: load the AOT artifacts and execute them from rust.
//!
//! Build-time python lowers every L2 graph to HLO **text** (see
//! `python/compile/aot.py`); this module compiles those files on the PJRT
//! CPU client once ([`Runtime::load`] caches executables by name) and
//! exposes typed entry points whose buffers are plain `&[f32]` slices —
//! the coordinator never touches XLA types.
//!
//! Python is never invoked here: after `make artifacts`, the rust binary
//! is self-contained.

pub mod manifest;

pub use manifest::{GraphEntry, Manifest};

use crate::{Error, Result};
use std::collections::HashMap;
use std::path::{Path, PathBuf};

/// A loaded artifact bundle: PJRT client + compiled executables.
pub struct Runtime {
    client: xla::PjRtClient,
    dir: PathBuf,
    pub manifest: Manifest,
    executables: HashMap<String, xla::PjRtLoadedExecutable>,
}

fn xerr(e: xla::Error) -> Error {
    Error::Runtime(e.to_string())
}

impl Runtime {
    /// Default artifact directory (next to the workspace root), overridable
    /// with `LSHMF_ARTIFACTS`.
    pub fn default_dir() -> PathBuf {
        if let Ok(dir) = std::env::var("LSHMF_ARTIFACTS") {
            return PathBuf::from(dir);
        }
        // cargo test/bench runs with cwd = crate dir (rust/); the bundle
        // lives at the workspace root.
        for cand in ["artifacts", "../artifacts"] {
            let p = PathBuf::from(cand);
            if Self::available(&p) {
                return p;
            }
        }
        PathBuf::from("artifacts")
    }

    /// True if the artifact bundle exists (tests skip PJRT paths if not).
    pub fn available(dir: &Path) -> bool {
        dir.join("manifest.json").exists()
    }

    /// Open the bundle and create the PJRT CPU client. Executables are
    /// compiled lazily on first use.
    pub fn open(dir: &Path) -> Result<Runtime> {
        let manifest_text = std::fs::read_to_string(dir.join("manifest.json"))
            .map_err(|e| Error::Runtime(format!("manifest: {e}")))?;
        let manifest = Manifest::parse(&manifest_text).map_err(Error::Runtime)?;
        let client = xla::PjRtClient::cpu().map_err(xerr)?;
        Ok(Runtime { client, dir: dir.to_path_buf(), manifest, executables: HashMap::new() })
    }

    /// Compile (or fetch the cached) executable for a graph.
    pub fn load(&mut self, name: &str) -> Result<&xla::PjRtLoadedExecutable> {
        if !self.executables.contains_key(name) {
            let entry = self
                .manifest
                .graphs
                .get(name)
                .ok_or_else(|| Error::Runtime(format!("unknown graph `{name}`")))?;
            let path = self.dir.join(&entry.file);
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().ok_or_else(|| Error::Runtime("bad path".into()))?,
            )
            .map_err(xerr)?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self.client.compile(&comp).map_err(xerr)?;
            self.executables.insert(name.to_string(), exe);
        }
        Ok(&self.executables[name])
    }

    /// Execute a graph on f32 inputs with the given shapes; returns the
    /// flat f32 contents of every output leaf (jax lowers with
    /// `return_tuple=True`, so the single result literal is a tuple).
    pub fn run_f32(
        &mut self,
        name: &str,
        inputs: &[(&[f32], &[usize])],
    ) -> Result<Vec<Vec<f32>>> {
        let lits = inputs
            .iter()
            .map(|(data, shape)| Self::lit_f32(data, shape))
            .collect::<Result<Vec<_>>>()?;
        self.run_literals(name, lits)
    }

    /// Execute with pre-built literals (used when inputs mix dtypes).
    pub fn run_literals(
        &mut self,
        name: &str,
        inputs: Vec<xla::Literal>,
    ) -> Result<Vec<Vec<f32>>> {
        let exe = self.load(name)?;
        let result = exe.execute::<xla::Literal>(&inputs).map_err(xerr)?[0][0]
            .to_literal_sync()
            .map_err(xerr)?;
        let leaves = result.to_tuple().map_err(xerr)?;
        leaves
            .into_iter()
            .map(|l| l.to_vec::<f32>().map_err(xerr))
            .collect()
    }

    /// Build an i32 literal (neural index inputs).
    pub fn lit_i32(data: &[i32], shape: &[usize]) -> Result<xla::Literal> {
        let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
        xla::Literal::vec1(data).reshape(&dims).map_err(xerr)
    }

    /// Build an f32 literal.
    pub fn lit_f32(data: &[f32], shape: &[usize]) -> Result<xla::Literal> {
        let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
        xla::Literal::vec1(data).reshape(&dims).map_err(xerr)
    }
}

/// Scalar buffer layout for `mf_sgd_step` / `rmse_chunk_step`.
pub fn mf_scalars(mu: f32, gamma: f32, lambda_b: f32, lambda_u: f32, lambda_v: f32) -> [f32; 5] {
    [mu, gamma, lambda_b, lambda_u, lambda_v]
}

/// Scalar buffer layout for `culsh_sgd_step`.
#[allow(clippy::too_many_arguments)]
pub fn culsh_scalars(
    mu: f32,
    gamma: f32,
    gamma_wc: f32,
    lambda_b: f32,
    lambda_u: f32,
    lambda_v: f32,
    lambda_w: f32,
    lambda_c: f32,
) -> [f32; 8] {
    [mu, gamma, gamma_wc, lambda_b, lambda_u, lambda_v, lambda_w, lambda_c]
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Artifact-gated: most runtime behaviour is exercised in
    /// `rust/tests/runtime_parity.rs`; here we only check the negative
    /// paths that need no PJRT.
    #[test]
    fn missing_dir_is_unavailable() {
        assert!(!Runtime::available(Path::new("/nonexistent")));
    }

    #[test]
    fn scalar_layouts() {
        assert_eq!(mf_scalars(1., 2., 3., 4., 5.), [1., 2., 3., 4., 5.]);
        let s = culsh_scalars(1., 2., 3., 4., 5., 6., 7., 8.);
        assert_eq!(s[2], 3.0);
        assert_eq!(s.len(), 8);
    }
}
