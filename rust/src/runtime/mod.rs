//! PJRT runtime facade: load the AOT artifact bundle and (when a PJRT
//! backend is linked in) execute the compiled graphs from rust.
//!
//! Build-time python lowers every L2 graph to HLO **text** (see
//! `python/compile/aot.py`). This module owns the manifest
//! ([`Manifest`]) and the typed entry points whose buffers are plain
//! `&[f32]` / `&[i32]` slices — the coordinator never touches XLA types.
//!
//! **Offline stub:** the crate is dependency-free and the `xla` PJRT
//! bindings are not vendored, so graph *execution* is stubbed: manifest
//! parsing, artifact discovery, and literal construction all work, but
//! [`Runtime::run_f32`] / [`Runtime::run_literals`] return
//! [`Error::Runtime`]. Everything artifact-driven (the PJRT trainer, the
//! parity tests in `tests/runtime_parity.rs`) is gated on artifact
//! availability / `LSHMF_AOT_DIR`, so offline builds and tests stay
//! green. Re-enabling real execution means vendoring an `xla` crate and
//! re-implementing `execute()` over it; the call-site contracts
//! (tuple-of-f32-leaves outputs) are documented on each method.

pub mod manifest;

pub use manifest::{GraphEntry, Manifest};

use crate::{Error, Result};
use std::path::{Path, PathBuf};

/// A typed host buffer — the stand-in for `xla::Literal` in the stub
/// backend, so callers that mix dtypes (the neural steps feed `i32`
/// index tensors) compile unchanged.
#[derive(Clone, Debug)]
pub struct Literal {
    data: LiteralData,
    shape: Vec<usize>,
}

#[derive(Clone, Debug)]
enum LiteralData {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

impl Literal {
    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    pub fn len(&self) -> usize {
        match &self.data {
            LiteralData::F32(v) => v.len(),
            LiteralData::I32(v) => v.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// A loaded artifact bundle: manifest plus (in a PJRT-enabled build) the
/// compiled executables.
pub struct Runtime {
    #[allow(dead_code)]
    dir: PathBuf,
    pub manifest: Manifest,
}

impl Runtime {
    /// Default artifact directory (next to the workspace root),
    /// overridable with `LSHMF_AOT_DIR` (preferred) or the legacy
    /// `LSHMF_ARTIFACTS`.
    pub fn default_dir() -> PathBuf {
        for var in ["LSHMF_AOT_DIR", "LSHMF_ARTIFACTS"] {
            if let Ok(dir) = std::env::var(var) {
                return PathBuf::from(dir);
            }
        }
        // cargo test/bench runs with cwd = crate dir (rust/); the bundle
        // lives at the workspace root.
        for cand in ["artifacts", "../artifacts"] {
            let p = PathBuf::from(cand);
            if Self::available(&p) {
                return p;
            }
        }
        PathBuf::from("artifacts")
    }

    /// True if the artifact bundle exists (tests skip PJRT paths if not).
    pub fn available(dir: &Path) -> bool {
        dir.join("manifest.json").exists()
    }

    /// Open the bundle: parse the manifest and remember the directory.
    /// Succeeds in the stub build (the `info` CLI command and artifact
    /// introspection need it); only execution is stubbed.
    pub fn open(dir: &Path) -> Result<Runtime> {
        let manifest_text = std::fs::read_to_string(dir.join("manifest.json"))
            .map_err(|e| Error::Runtime(format!("manifest: {e}")))?;
        let manifest = Manifest::parse(&manifest_text).map_err(Error::Runtime)?;
        Ok(Runtime { dir: dir.to_path_buf(), manifest })
    }

    /// Execute a graph on f32 inputs with the given shapes; returns the
    /// flat f32 contents of every output leaf (jax lowers with
    /// `return_tuple=True`, so a real backend unpacks one tuple literal).
    pub fn run_f32(&mut self, name: &str, inputs: &[(&[f32], &[usize])]) -> Result<Vec<Vec<f32>>> {
        let lits = inputs
            .iter()
            .map(|(data, shape)| Self::lit_f32(data, shape))
            .collect::<Result<Vec<_>>>()?;
        self.run_literals(name, lits)
    }

    /// Execute with pre-built literals (used when inputs mix dtypes).
    ///
    /// Stub backend: validates the graph name against the manifest, then
    /// reports that no PJRT backend is linked.
    pub fn run_literals(&mut self, name: &str, inputs: Vec<Literal>) -> Result<Vec<Vec<f32>>> {
        if !self.manifest.graphs.contains_key(name) {
            return Err(Error::Runtime(format!("unknown graph `{name}`")));
        }
        let _ = inputs;
        Err(Error::Runtime(format!(
            "graph `{name}`: no PJRT backend linked in this build — vendor the \
             `xla` crate and restore the execution path to run AOT artifacts"
        )))
    }

    /// Build an i32 literal (neural index inputs).
    pub fn lit_i32(data: &[i32], shape: &[usize]) -> Result<Literal> {
        Self::check_shape(data.len(), shape)?;
        Ok(Literal { data: LiteralData::I32(data.to_vec()), shape: shape.to_vec() })
    }

    /// Build an f32 literal.
    pub fn lit_f32(data: &[f32], shape: &[usize]) -> Result<Literal> {
        Self::check_shape(data.len(), shape)?;
        Ok(Literal { data: LiteralData::F32(data.to_vec()), shape: shape.to_vec() })
    }

    fn check_shape(len: usize, shape: &[usize]) -> Result<()> {
        let want: usize = shape.iter().product();
        if want == len {
            Ok(())
        } else {
            Err(Error::Runtime(format!(
                "literal shape {shape:?} wants {want} elements, got {len}"
            )))
        }
    }
}

/// Scalar buffer layout for `mf_sgd_step` / `rmse_chunk_step`.
pub fn mf_scalars(mu: f32, gamma: f32, lambda_b: f32, lambda_u: f32, lambda_v: f32) -> [f32; 5] {
    [mu, gamma, lambda_b, lambda_u, lambda_v]
}

/// Scalar buffer layout for `culsh_sgd_step`.
#[allow(clippy::too_many_arguments)]
pub fn culsh_scalars(
    mu: f32,
    gamma: f32,
    gamma_wc: f32,
    lambda_b: f32,
    lambda_u: f32,
    lambda_v: f32,
    lambda_w: f32,
    lambda_c: f32,
) -> [f32; 8] {
    [mu, gamma, gamma_wc, lambda_b, lambda_u, lambda_v, lambda_w, lambda_c]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn missing_dir_is_unavailable() {
        assert!(!Runtime::available(Path::new("/nonexistent")));
    }

    #[test]
    fn scalar_layouts() {
        assert_eq!(mf_scalars(1., 2., 3., 4., 5.), [1., 2., 3., 4., 5.]);
        let s = culsh_scalars(1., 2., 3., 4., 5., 6., 7., 8.);
        assert_eq!(s[2], 3.0);
        assert_eq!(s.len(), 8);
    }

    #[test]
    fn literal_shape_checks() {
        let l = Runtime::lit_f32(&[1.0, 2.0, 3.0, 4.0], &[2, 2]).unwrap();
        assert_eq!(l.shape(), &[2, 2]);
        assert_eq!(l.len(), 4);
        assert!(!l.is_empty());
        assert!(Runtime::lit_f32(&[1.0], &[2, 2]).is_err());
        assert!(Runtime::lit_i32(&[1, 2], &[2]).is_ok());
    }

    #[test]
    fn stub_open_parses_manifest_and_execution_errors() {
        let dir = std::env::temp_dir().join(format!("lshmf-rt-test-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("manifest.json"),
            r#"{"batch": 8, "f": 4, "k": 4, "hash_n": 8, "hash_m": 8, "hash_g": 8,
                "graphs": {"mf_sgd_step": {"file": "mf_sgd_step.hlo.txt", "inputs": []}}}"#,
        )
        .unwrap();
        assert!(Runtime::available(&dir));
        let mut rt = Runtime::open(&dir).unwrap();
        assert_eq!(rt.manifest.batch, 8);
        // known graph: execution is stubbed
        let err = rt.run_f32("mf_sgd_step", &[]).unwrap_err();
        assert!(err.to_string().contains("no PJRT backend"), "{err}");
        // unknown graph: still caught before the stub
        let err = rt.run_f32("bogus", &[]).unwrap_err();
        assert!(err.to_string().contains("unknown graph"), "{err}");
        std::fs::remove_dir_all(&dir).ok();
    }
}
