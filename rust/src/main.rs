//! lshmf launcher binary.
fn main() {
    std::process::exit(lshmf::cli::main());
}
