//! Lightweight metrics: counters, gauges, timers, and log-scaled
//! histograms, shared across coordinator threads.
//!
//! Everything is lock-free (`AtomicU64`) so the SGD hot loop and the
//! streaming ingest path can record without contention. A [`Registry`]
//! renders a human-readable snapshot for the CLI / server `STATS` verb.

pub mod prometheus;

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// Monotonic counter.
#[derive(Default)]
pub struct Counter(AtomicU64);

impl Counter {
    pub fn inc(&self) {
        self.0.fetch_add(1, Ordering::Relaxed);
    }

    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Last-write-wins gauge (bit-cast f64).
#[derive(Default)]
pub struct Gauge(AtomicU64);

impl Gauge {
    pub fn set(&self, v: f64) {
        self.0.store(v.to_bits(), Ordering::Relaxed);
    }

    pub fn get(&self) -> f64 {
        f64::from_bits(self.0.load(Ordering::Relaxed))
    }
}

/// Power-of-two bucketed latency histogram (ns), 1ns .. ~36s.
pub struct Histogram {
    buckets: [AtomicU64; 56],
    count: AtomicU64,
    sum_ns: AtomicU64,
}

impl Default for Histogram {
    fn default() -> Self {
        Histogram {
            buckets: std::array::from_fn(|_| AtomicU64::new(0)),
            count: AtomicU64::new(0),
            sum_ns: AtomicU64::new(0),
        }
    }
}

impl Histogram {
    pub fn record(&self, d: Duration) {
        let ns = d.as_nanos().min(u64::MAX as u128) as u64;
        let b = (64 - ns.max(1).leading_zeros() as usize - 1).min(55);
        self.buckets[b].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum_ns.fetch_add(ns, Ordering::Relaxed);
    }

    /// Time a closure and record it.
    pub fn time<T>(&self, f: impl FnOnce() -> T) -> T {
        let t0 = Instant::now();
        let out = f();
        self.record(t0.elapsed());
        out
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    /// Total recorded time in nanoseconds (the exporter's `_sum`).
    pub fn sum_ns(&self) -> u64 {
        self.sum_ns.load(Ordering::Relaxed)
    }

    /// Per-bucket sample counts; bucket `b` holds samples in
    /// `(2^b, 2^(b+1)]` ns (b = 0 additionally catches 0..=2 ns).
    pub fn bucket_counts(&self) -> Vec<u64> {
        self.buckets.iter().map(|b| b.load(Ordering::Relaxed)).collect()
    }

    pub fn mean_ns(&self) -> f64 {
        let c = self.count();
        if c == 0 {
            0.0
        } else {
            self.sum_ns.load(Ordering::Relaxed) as f64 / c as f64
        }
    }

    /// Approximate quantile from the log buckets (upper bound of the
    /// bucket holding the q-th sample).
    pub fn quantile_ns(&self, q: f64) -> u64 {
        let total = self.count();
        if total == 0 {
            return 0;
        }
        let target = ((total as f64) * q).ceil() as u64;
        let mut seen = 0;
        for (b, bucket) in self.buckets.iter().enumerate() {
            seen += bucket.load(Ordering::Relaxed);
            if seen >= target {
                return 1u64 << (b + 1);
            }
        }
        u64::MAX
    }
}

/// Named metric registry shared by coordinator components.
#[derive(Default, Clone)]
pub struct Registry {
    inner: Arc<Inner>,
}

#[derive(Default)]
struct Inner {
    counters: Mutex<BTreeMap<String, Arc<Counter>>>,
    gauges: Mutex<BTreeMap<String, Arc<Gauge>>>,
    histograms: Mutex<BTreeMap<String, Arc<Histogram>>>,
}

impl Registry {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn counter(&self, name: &str) -> Arc<Counter> {
        self.inner
            .counters
            .lock()
            .unwrap()
            .entry(name.to_string())
            .or_default()
            .clone()
    }

    pub fn gauge(&self, name: &str) -> Arc<Gauge> {
        self.inner
            .gauges
            .lock()
            .unwrap()
            .entry(name.to_string())
            .or_default()
            .clone()
    }

    pub fn histogram(&self, name: &str) -> Arc<Histogram> {
        self.inner
            .histograms
            .lock()
            .unwrap()
            .entry(name.to_string())
            .or_default()
            .clone()
    }

    /// RAII wait/latency timer: records into `name` when the guard drops.
    /// Used by the serving path to account lock-wait and queue-wait time
    /// without sprinkling `Instant` bookkeeping through the hot path.
    pub fn timer(&self, name: &str) -> TimerGuard {
        TimerGuard { histogram: self.histogram(name), start: Instant::now() }
    }

    /// All counters by name, snapshotted (the exporter's iteration
    /// surface — names sort deterministically via the `BTreeMap`).
    pub fn counters(&self) -> Vec<(String, u64)> {
        self.inner
            .counters
            .lock()
            .unwrap()
            .iter()
            .map(|(name, c)| (name.clone(), c.get()))
            .collect()
    }

    /// All gauges by name, snapshotted.
    pub fn gauges(&self) -> Vec<(String, f64)> {
        self.inner
            .gauges
            .lock()
            .unwrap()
            .iter()
            .map(|(name, g)| (name.clone(), g.get()))
            .collect()
    }

    /// All histograms by name (shared handles, cheap to clone).
    pub fn histograms(&self) -> Vec<(String, Arc<Histogram>)> {
        self.inner
            .histograms
            .lock()
            .unwrap()
            .iter()
            .map(|(name, h)| (name.clone(), Arc::clone(h)))
            .collect()
    }

    /// Render all metrics as `name value` lines.
    pub fn snapshot(&self) -> String {
        let mut out = String::new();
        for (name, c) in self.inner.counters.lock().unwrap().iter() {
            out.push_str(&format!("counter {name} {}\n", c.get()));
        }
        for (name, g) in self.inner.gauges.lock().unwrap().iter() {
            out.push_str(&format!("gauge {name} {:.6}\n", g.get()));
        }
        for (name, h) in self.inner.histograms.lock().unwrap().iter() {
            out.push_str(&format!(
                "hist {name} count={} mean_ns={:.0} p50_ns={} p99_ns={}\n",
                h.count(),
                h.mean_ns(),
                h.quantile_ns(0.50),
                h.quantile_ns(0.99),
            ));
        }
        out
    }
}

/// Guard returned by [`Registry::timer`]; records elapsed time on drop.
pub struct TimerGuard {
    histogram: Arc<Histogram>,
    start: Instant,
}

impl Drop for TimerGuard {
    fn drop(&mut self) {
        self.histogram.record(self.start.elapsed());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_and_gauge() {
        let r = Registry::new();
        let c = r.counter("reqs");
        c.inc();
        c.add(4);
        assert_eq!(c.get(), 5);
        // same name -> same counter
        assert_eq!(r.counter("reqs").get(), 5);
        let g = r.gauge("rmse");
        g.set(0.92);
        assert!((r.gauge("rmse").get() - 0.92).abs() < 1e-12);
    }

    #[test]
    fn histogram_buckets() {
        let h = Histogram::default();
        for _ in 0..100 {
            h.record(Duration::from_micros(10));
        }
        h.record(Duration::from_millis(50));
        assert_eq!(h.count(), 101);
        assert!(h.mean_ns() > 9_000.0);
        // p50 bucket bound should be near 10us (within 2x log-bucket)
        let p50 = h.quantile_ns(0.5);
        assert!((8_192..=16_384).contains(&p50), "p50={p50}");
        // p99.9 catches the 50ms outlier's bucket
        let p999 = h.quantile_ns(0.999);
        assert!(p999 >= 33_000_000, "p999={p999}");
    }

    #[test]
    fn snapshot_renders() {
        let r = Registry::new();
        r.counter("a").inc();
        r.gauge("b").set(1.0);
        r.histogram("c").record(Duration::from_nanos(100));
        let s = r.snapshot();
        assert!(s.contains("counter a 1"));
        assert!(s.contains("gauge b"));
        assert!(s.contains("hist c count=1"));
    }

    #[test]
    fn timer_guard_records_on_drop() {
        let r = Registry::new();
        {
            let _t = r.timer("lock.wait");
            std::thread::sleep(Duration::from_millis(1));
        }
        assert_eq!(r.histogram("lock.wait").count(), 1);
        assert!(r.histogram("lock.wait").mean_ns() >= 500_000.0);
    }

    #[test]
    fn threads_share_counter() {
        let r = Registry::new();
        let c = r.counter("x");
        std::thread::scope(|s| {
            for _ in 0..4 {
                let c = c.clone();
                s.spawn(move || {
                    for _ in 0..1000 {
                        c.inc();
                    }
                });
            }
        });
        assert_eq!(c.get(), 4000);
    }
}
