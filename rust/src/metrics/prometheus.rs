//! Prometheus text-format export of a [`Registry`]: the exposition
//! renderer plus a tiny hand-rolled HTTP listener answering
//! `GET /metrics` (format version 0.0.4, the text scrape format every
//! Prometheus server speaks — no dependencies, ~one screen of HTTP).
//!
//! Metric names are derived mechanically from the registry's dotted
//! names: `server.rate_limited` exports as `lshmf_server_rate_limited`.
//! The `lshmf-check` metrics-names pass verifies statically that every
//! dotted name in the tree survives this rewrite as a valid, collision
//! free Prometheus name, so the mapping can stay rule-based forever.
//! Histograms are power-of-two nanosecond buckets internally and export
//! in seconds (cumulative `_bucket{le="…"}` plus `_sum`/`_count`), per
//! Prometheus convention.

use super::{Histogram, Registry};
use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

/// The mechanical dotted-name → Prometheus-name rewrite. Keep this in
/// lockstep with `check/src/checks/metrics.rs`, which proves at lint
/// time that the rewrite is collision-free over the real tree.
pub fn prom_name(dotted: &str) -> String {
    format!("lshmf_{}", dotted.replace('.', "_"))
}

fn push_histogram(out: &mut String, name: &str, h: &Histogram) {
    out.push_str(&format!("# TYPE {name} histogram\n"));
    let mut cumulative = 0u64;
    for (b, count) in h.bucket_counts().iter().enumerate() {
        if *count == 0 {
            continue; // sparse: 56 log buckets, a handful populated
        }
        cumulative += count;
        let le = (1u64 << (b + 1)) as f64 / 1e9;
        out.push_str(&format!("{name}_bucket{{le=\"{le}\"}} {cumulative}\n"));
    }
    out.push_str(&format!("{name}_bucket{{le=\"+Inf\"}} {}\n", h.count()));
    out.push_str(&format!("{name}_sum {}\n", h.sum_ns() as f64 / 1e9));
    out.push_str(&format!("{name}_count {}\n", h.count()));
}

/// Render the whole registry in exposition format. Every counter,
/// gauge, and histogram the registry holds appears; ordering is the
/// registry's deterministic name order.
pub fn render(registry: &Registry) -> String {
    let mut out = String::new();
    for (dotted, value) in registry.counters() {
        let name = prom_name(&dotted);
        out.push_str(&format!("# TYPE {name} counter\n{name} {value}\n"));
    }
    for (dotted, value) in registry.gauges() {
        let name = prom_name(&dotted);
        out.push_str(&format!("# TYPE {name} gauge\n{name} {value}\n"));
    }
    for (dotted, h) in registry.histograms() {
        push_histogram(&mut out, &prom_name(&dotted), &h);
    }
    out
}

/// Most bytes of HTTP request head the scrape listener will buffer; a
/// scrape request is one line plus a few headers.
const MAX_REQUEST_BYTES: usize = 8 * 1024;

/// Answer one HTTP connection: `GET /metrics` scrapes, anything else
/// is a 404. The request head is read up to the blank line (bounded),
/// and the connection closes after one response — scrapers reconnect
/// per scrape, so keep-alive buys nothing here.
pub fn handle_scrape(mut stream: TcpStream, registry: &Registry) -> std::io::Result<()> {
    stream.set_read_timeout(Some(Duration::from_millis(500)))?;
    stream.set_write_timeout(Some(Duration::from_millis(500)))?;
    let mut head = Vec::new();
    let mut byte = [0u8; 1];
    while !head.ends_with(b"\r\n\r\n") && !head.ends_with(b"\n\n") {
        if head.len() >= MAX_REQUEST_BYTES || stream.read(&mut byte)? == 0 {
            break;
        }
        head.push(byte[0]);
    }
    let request_line = std::str::from_utf8(&head)
        .unwrap_or("")
        .lines()
        .next()
        .unwrap_or("");
    let mut parts = request_line.split_whitespace();
    let (method, path) = (parts.next().unwrap_or(""), parts.next().unwrap_or(""));
    let (status, body) = if method == "GET" && path == "/metrics" {
        ("200 OK", render(registry))
    } else {
        ("404 Not Found", "not found\n".to_string())
    };
    let response = format!(
        "HTTP/1.1 {status}\r\nContent-Type: text/plain; version=0.0.4; charset=utf-8\r\n\
         Content-Length: {}\r\nConnection: close\r\n\r\n{body}",
        body.len()
    );
    stream.write_all(response.as_bytes())?;
    stream.flush()
}

/// Run the scrape listener on its own thread until `stop` flips true.
/// The listener polls non-blockingly so shutdown needs no poke
/// connection; one scrape is served at a time (Prometheus scrapes are
/// serial per target anyway).
pub fn spawn_exporter(
    listener: TcpListener,
    registry: Registry,
    stop: Arc<AtomicBool>,
) -> std::io::Result<std::thread::JoinHandle<()>> {
    listener.set_nonblocking(true)?;
    Ok(std::thread::spawn(move || loop {
        match listener.accept() {
            Ok((stream, _)) => {
                // Accepted sockets can inherit non-blocking mode; the
                // scrape handler wants plain blocking reads with its
                // own timeouts.
                if stream.set_nonblocking(false).is_ok() {
                    let _ = handle_scrape(stream, &registry);
                }
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(Duration::from_millis(25));
            }
            Err(_) => std::thread::sleep(Duration::from_millis(25)),
        }
        if stop.load(Ordering::Relaxed) {
            break;
        }
    }))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn prom_name_rewrite_is_mechanical() {
        assert_eq!(prom_name("server.rate_limited"), "lshmf_server_rate_limited");
        assert_eq!(
            prom_name("flush.band0.train_micros"),
            "lshmf_flush_band0_train_micros"
        );
    }

    #[test]
    fn render_covers_every_metric_kind() {
        let r = Registry::new();
        r.counter("server.requests").add(7);
        r.gauge("model.rmse").set(0.5);
        r.histogram("flush.apply_wait").record(Duration::from_micros(3));
        r.histogram("flush.apply_wait").record(Duration::from_millis(40));
        let text = render(&r);
        assert!(text.contains("# TYPE lshmf_server_requests counter\n"), "{text}");
        assert!(text.contains("lshmf_server_requests 7\n"), "{text}");
        assert!(text.contains("# TYPE lshmf_model_rmse gauge\n"), "{text}");
        assert!(text.contains("lshmf_model_rmse 0.5\n"), "{text}");
        assert!(text.contains("# TYPE lshmf_flush_apply_wait histogram\n"), "{text}");
        assert!(text.contains("lshmf_flush_apply_wait_count 2\n"), "{text}");
        assert!(
            text.contains("lshmf_flush_apply_wait_bucket{le=\"+Inf\"} 2\n"),
            "{text}"
        );
        // cumulative: the +Inf bucket equals the count, the sum is in
        // seconds (3us + 40ms ≈ 0.040003s)
        let sum_line = text
            .lines()
            .find(|l| l.starts_with("lshmf_flush_apply_wait_sum "))
            .unwrap();
        let sum: f64 = sum_line.split_whitespace().nth(1).unwrap().parse().unwrap();
        assert!((sum - 0.040_003).abs() < 1e-6, "{sum_line}");
    }

    #[test]
    fn histogram_buckets_are_cumulative_and_bounded() {
        let h = Histogram::default();
        h.record(Duration::from_nanos(100)); // bucket 6: (64, 128]
        h.record(Duration::from_nanos(100));
        h.record(Duration::from_nanos(1000)); // bucket 9: (512, 1024]
        let mut out = String::new();
        push_histogram(&mut out, "lshmf_x", &h);
        let bucket_lines: Vec<&str> =
            out.lines().filter(|l| l.starts_with("lshmf_x_bucket")).collect();
        // two populated buckets + the +Inf line
        assert_eq!(bucket_lines.len(), 3, "{out}");
        assert!(bucket_lines[0].ends_with(" 2"), "{out}");
        assert!(bucket_lines[1].ends_with(" 3"), "{out}");
        assert_eq!(bucket_lines[2], "lshmf_x_bucket{le=\"+Inf\"} 3", "{out}");
        // le bounds are seconds: bucket 6's upper bound is 128ns
        assert!(bucket_lines[0].contains("le=\"0.000000128\""), "{out}");
    }

    #[test]
    fn scrape_endpoint_serves_exposition_text() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let registry = Registry::new();
        registry.counter("server.requests").add(3);
        let stop = Arc::new(AtomicBool::new(false));
        let handle = spawn_exporter(listener, registry, Arc::clone(&stop)).unwrap();

        let scrape = |path: &str| -> String {
            let mut conn = TcpStream::connect(addr).unwrap();
            conn.write_all(format!("GET {path} HTTP/1.1\r\nHost: x\r\n\r\n").as_bytes())
                .unwrap();
            let mut reply = String::new();
            conn.read_to_string(&mut reply).unwrap();
            reply
        };
        let reply = scrape("/metrics");
        assert!(reply.starts_with("HTTP/1.1 200 OK\r\n"), "{reply}");
        assert!(reply.contains("text/plain; version=0.0.4"), "{reply}");
        assert!(reply.contains("lshmf_server_requests 3\n"), "{reply}");
        let missing = scrape("/other");
        assert!(missing.starts_with("HTTP/1.1 404"), "{missing}");

        stop.store(true, Ordering::Relaxed);
        handle.join().unwrap();
    }
}
