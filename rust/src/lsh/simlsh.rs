//! simLSH — the paper's sparse-data hash (Eq. 3 + Fig. 3).
//!
//! Each row variable `I_i` draws a random G-bit code `H_i`; the hash of a
//! column variable `J_j` is
//!
//! ```text
//! H̄_j = Υ( Σ_{i ∈ Ω̂_j}  Ψ(r_ij) · Φ(H_i) )           (Eq. 3)
//! ```
//!
//! where `Φ` maps bits {0,1} → {−1,+1}, `Ψ(r) = r^ψ` spreads the rating
//! scale (ψ=2 for Netflix/MovieLens, ψ=4 for the denser Yahoo!Music per
//! §5.3), and `Υ` maps sign → bit. Unlike minHash, the *values* of the
//! interactions weight the projection, not just their existence — that is
//! the paper's fix for sparse data.
//!
//! The accumulation is bit-parallel: the G accumulators of one base hash
//! are updated lane-wise from the packed row code, and the L1 Pallas
//! kernel (`python/compile/kernels/simlsh.py`) implements the identical
//! computation as a `Ψ(Rᵀ)·(2H−1)` matmul for the TPU path. Numerical
//! parity between the two is asserted in `rust/tests/runtime_parity.rs`.

use super::amplify::{collision_topk, combine, RoundHasher};
use super::{CostReport, NeighbourSearch, TopK};
use crate::rng::Rng;
use crate::sparse::Csc;

/// Ψ(r) = r^power with integer power (1, 2 or 4 in the paper's setups).
#[inline]
pub fn psi(r: f32, power: u32) -> f32 {
    match power {
        1 => r,
        2 => r * r,
        4 => {
            let r2 = r * r;
            r2 * r2
        }
        p => r.powi(p as i32),
    }
}

/// simLSH engine configuration.
#[derive(Clone, Debug)]
pub struct SimLsh {
    /// Coarse-grained AND width p.
    pub p: usize,
    /// Fine-grained OR rounds q.
    pub q: usize,
    /// Bits per base hash (G ≤ 64; the paper uses a byte, G = 8).
    pub g: usize,
    /// Ψ exponent.
    pub psi_power: u32,
    /// Optional centering (extension, off in the paper): Ψ is applied to
    /// `r − center` sign-preservingly, which removes the positive-mean
    /// bias that otherwise makes *support overlap* dominate the sign
    /// projection on dense-ish data. Benched as an ablation
    /// (`cargo bench --bench fig7_topk_methods`).
    pub center: f32,
    /// Base seed for the hash family (kept so online updates can re-derive
    /// the same row codes).
    pub seed: u64,
}

impl Default for SimLsh {
    fn default() -> Self {
        SimLsh { p: 3, q: 100, g: 8, psi_power: 2, center: 0.0, seed: 0x51A4_B0DE }
    }
}

impl SimLsh {
    pub fn new(p: usize, q: usize, g: usize, psi_power: u32) -> Self {
        SimLsh { p, q, g, psi_power, ..Default::default() }
    }

    /// Centered variant (see the `center` field).
    pub fn centered(mut self, center: f32) -> Self {
        self.center = center;
        self
    }

    /// The Ψ weight of one rating under this configuration.
    #[inline]
    pub fn weight(&self, r: f32) -> f32 {
        if self.center == 0.0 {
            psi(r, self.psi_power)
        } else {
            let d = r - self.center;
            d.signum() * psi(d.abs(), self.psi_power)
        }
    }

    /// Deterministic G-bit row code for row `i` under base-hash index
    /// `(round, slot)`. Re-derivable at any time — the online path counts
    /// on this instead of storing p·q·M codes.
    #[inline]
    pub fn row_code(&self, i: usize, round: u64, slot: usize) -> u64 {
        let mut s = self.seed
            ^ (round.wrapping_mul(0xA24BAED4963EE407))
            ^ ((slot as u64).wrapping_mul(0x9FB21C651E98DF25))
            ^ ((i as u64).wrapping_mul(0xD1B54A32D192ED03));
        let full = crate::rng::splitmix64(&mut s);
        if self.g >= 64 {
            full
        } else {
            full & ((1u64 << self.g) - 1)
        }
    }

    /// Eq. 3 accumulators for one column under base-hash `(round, slot)`:
    /// `acc[g] = Σ_i Ψ(r_ij)·Φ(H_i[g])`. Exposed for the online path.
    pub fn accumulate(&self, csc: &Csc, j: usize, round: u64, slot: usize) -> Vec<f32> {
        let mut acc = vec![0f32; self.g];
        let (rows, vals) = csc.col_raw(j);
        for (&i, &r) in rows.iter().zip(vals) {
            let w = self.weight(r);
            let code = self.row_code(i as usize, round, slot);
            for (gbit, a) in acc.iter_mut().enumerate() {
                // Φ: bit 1 → +1, bit 0 → −1
                let sign = if (code >> gbit) & 1 == 1 { w } else { -w };
                *a += sign;
            }
        }
        acc
    }

    /// Υ: sign-threshold an accumulator vector into a packed G-bit hash.
    #[inline]
    pub fn threshold(&self, acc: &[f32]) -> u64 {
        let mut h = 0u64;
        for (gbit, &a) in acc.iter().enumerate() {
            if a >= 0.0 {
                h |= 1 << gbit;
            }
        }
        h
    }

    /// The full hash of one column for base-hash `(round, slot)`.
    pub fn hash_column(&self, csc: &Csc, j: usize, round: u64, slot: usize) -> u64 {
        self.threshold(&self.accumulate(csc, j, round, slot))
    }
}

impl RoundHasher for SimLsh {
    fn name(&self) -> String {
        format!("simLSH(p={},q={},G={},psi=r^{})", self.p, self.q, self.g, self.psi_power)
    }

    fn p(&self) -> usize {
        self.p
    }

    fn signatures(&self, csc: &Csc, round: u64, _rng: &mut Rng) -> Vec<u64> {
        let n = csc.ncols();
        let mut sigs = vec![0u64; n];
        // Bit-parallel accumulation: for each of the p slots, walk every
        // column's nonzeros once.
        for slot in 0..self.p {
            for (j, sig) in sigs.iter_mut().enumerate() {
                let h = self.hash_column(csc, j, round, slot);
                *sig = combine(*sig, h);
            }
        }
        sigs
    }
}

impl NeighbourSearch for SimLsh {
    fn name(&self) -> String {
        RoundHasher::name(self)
    }

    fn build(&mut self, csc: &Csc, k: usize, rng: &mut Rng) -> (TopK, CostReport) {
        collision_topk(self, csc, k, self.q, rng)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::Triples;

    /// Fig. 3 worked example: one column with ratings {3,4,5} on rows
    /// whose codes are {001, 010, 100}; Ψ = identity. Accumulators are
    /// {(−3−4+5), (−3+4−5), (3−4−5)} = {−2,−4,−6} → hash 000.
    #[test]
    fn fig3_worked_example() {
        // Build a 3x1 matrix with values 3,4,5.
        let t = Triples::from_entries(3, 1, vec![(0, 0, 3.0), (1, 0, 4.0), (2, 0, 5.0)]);
        let csc = Csc::from_triples(&t);
        // A SimLsh whose row codes we control: impossible through the
        // seed, so test `accumulate` semantics via a hand computation.
        let lsh = SimLsh { p: 1, q: 1, g: 3, psi_power: 1, center: 0.0, seed: 0 };
        // emulate: codes 001, 010, 100 for rows 0,1,2
        let codes = [0b001u64, 0b010, 0b100];
        let mut acc = vec![0f32; 3];
        for (i, &r) in [3.0f32, 4.0, 5.0].iter().enumerate() {
            for g in 0..3 {
                let sign = if (codes[i] >> g) & 1 == 1 { r } else { -r };
                acc[g] += sign;
            }
        }
        // The paper prints the positions as {−2, −4, −6} reading its bit
        // strings MSB-first; with LSB-first packing the same accumulators
        // come out reversed. Either way Υ maps all-negative → hash 000.
        assert_eq!(acc, vec![-6.0, -4.0, -2.0]);
        assert_eq!(lsh.threshold(&acc), 0b000);
        let _ = csc;
    }

    #[test]
    fn row_codes_are_g_bits_and_deterministic() {
        let lsh = SimLsh::new(3, 10, 8, 2);
        for i in 0..100 {
            let c = lsh.row_code(i, 5, 2);
            assert!(c < 256);
            assert_eq!(c, lsh.row_code(i, 5, 2));
        }
        // different slots/rounds give different code streams
        let same = (0..64)
            .filter(|&i| lsh.row_code(i, 0, 0) == lsh.row_code(i, 1, 0))
            .count();
        assert!(same < 32);
    }

    /// Identical columns must always hash identically; scaled columns too
    /// (sign projection is scale-invariant for Ψ(cr) = c^ψ Ψ(r), c>0).
    #[test]
    fn identical_and_scaled_columns_collide() {
        let mut entries = Vec::new();
        for i in 0..20u32 {
            entries.push((i, 0, 1.0 + (i % 5) as f32));
            entries.push((i, 1, 1.0 + (i % 5) as f32)); // identical
            entries.push((i, 2, 2.0 * (1.0 + (i % 5) as f32))); // scaled 2x
        }
        let t = Triples::from_entries(20, 3, entries);
        let csc = Csc::from_triples(&t);
        let lsh = SimLsh::new(2, 4, 16, 2);
        for round in 0..4 {
            for slot in 0..2 {
                let h0 = lsh.hash_column(&csc, 0, round, slot);
                let h1 = lsh.hash_column(&csc, 1, round, slot);
                let h2 = lsh.hash_column(&csc, 2, round, slot);
                assert_eq!(h0, h1);
                assert_eq!(h0, h2);
            }
        }
    }

    /// Columns with disjoint supports and opposite value patterns should
    /// rarely share all bits.
    #[test]
    fn dissimilar_columns_usually_differ() {
        let mut rng = Rng::seeded(7);
        let mut entries = Vec::new();
        for i in 0..200u32 {
            if rng.chance(0.5) {
                entries.push((i, 0, 1.0 + rng.f32() * 4.0));
            } else {
                entries.push((i, 1, 1.0 + rng.f32() * 4.0));
            }
        }
        let t = Triples::from_entries(200, 2, entries);
        let csc = Csc::from_triples(&t);
        let lsh = SimLsh::new(1, 1, 16, 2);
        let mut agree = 0;
        let rounds = 50;
        for round in 0..rounds {
            if lsh.hash_column(&csc, 0, round, 0) == lsh.hash_column(&csc, 1, round, 0) {
                agree += 1;
            }
        }
        // With 16 independent random bits, two independent random columns
        // agree on all bits with prob 2^-16.
        assert!(agree < rounds / 4, "agree={agree}");
    }

    /// End-to-end: planted duplicate columns must be found as neighbours.
    #[test]
    fn finds_planted_neighbours() {
        let mut rng = Rng::seeded(11);
        let n_rows = 300;
        let mut entries = Vec::new();
        // 8 columns: pairs (0,1), (2,3), (4,5), (6,7) are near-duplicates;
        // cross-pair patterns are independent.
        for pair in 0..4u32 {
            for i in 0..n_rows as u32 {
                if rng.chance(0.3) {
                    let v = 1.0 + rng.f32() * 4.0;
                    entries.push((i, pair * 2, v));
                    // near-duplicate with small perturbation
                    entries.push((i, pair * 2 + 1, (v + 0.25).min(5.0)));
                }
            }
        }
        let t = Triples::from_entries(n_rows, 8, entries);
        let csc = Csc::from_triples(&t);
        let mut lsh = SimLsh::new(2, 30, 8, 2);
        let (topk, _) = lsh.build(&csc, 1, &mut rng);
        let mut hits = 0;
        for j in 0..8usize {
            let partner = (j ^ 1) as u32;
            if topk.neighbours(j)[0] == partner {
                hits += 1;
            }
        }
        assert!(hits >= 6, "only {hits}/8 planted pairs found");
    }
}
