//! Coarse/fine-grained amplification shared by all LSH engines
//! (Algorithm 1 of the paper, lines 1–12, hash-family-agnostic).
//!
//! A [`RoundHasher`] produces one **round signature** per column: the
//! concatenation of `p` base hashes (coarse-grained AND — two columns are
//! candidates in a round only if *all p* hashes agree, probability
//! `P₁ᵖ`). The pipeline runs `q` independent rounds (fine-grained OR —
//! candidates in *any* round are kept, probability `1 − (1 − P₁ᵖ)^q`),
//! counts per-pair collision frequency, and keeps the K most frequent
//! co-collisioners per column, random-supplemented to exactly K.
//!
//! Giant buckets (e.g. columns with near-empty support hashing alike) are
//! enumeration-capped: per round, a column accumulates at most
//! [`MAX_BUCKET_SCAN`] sampled bucketmates instead of the full O(B²)
//! pair walk — the standard LSH implementation trade that bounds worst
//! case while leaving the frequency ranking intact.

use super::{finalize_row, CostReport, TopK};
use crate::rng::Rng;
use crate::sparse::Csc;
use std::collections::HashMap;

/// Cap on bucketmates scanned per column per round.
pub const MAX_BUCKET_SCAN: usize = 64;

/// One LSH family: produces the concatenated p-hash signature of every
/// column for a given round.
pub trait RoundHasher {
    /// Engine name for reports.
    fn name(&self) -> String;
    /// `p` — the AND width (for cost accounting / reports).
    fn p(&self) -> usize;
    /// Compute the signature of every column for round `round`.
    /// Signatures are opaque u64s; equal signature ⇔ all p hashes agree
    /// (up to a negligible 2⁻⁶⁴ mixing collision).
    fn signatures(&self, csc: &Csc, round: u64, rng: &mut Rng) -> Vec<u64>;
}

/// Mix a base hash into a running signature (boost-style combiner).
#[inline]
pub fn combine(sig: u64, h: u64) -> u64 {
    // splitmix-style avalanche of the incoming hash, xor-rotated in
    let mut z = h.wrapping_add(0x9E3779B97F4A7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    sig.rotate_left(13) ^ (z ^ (z >> 31))
}

/// Run the q-round collision-counting pipeline and emit the Top-K table.
///
/// Returns the table plus a [`CostReport`] whose `bytes` is the peak size
/// of the per-column collision counters plus one round's signature and
/// bucket table (the transient state Fig. 1 contrasts with the O(N²) GSM).
pub fn collision_topk<H: RoundHasher>(
    hasher: &H,
    csc: &Csc,
    k: usize,
    q: usize,
    rng: &mut Rng,
) -> (TopK, CostReport) {
    collision_topk_sigs(
        csc.ncols(),
        |round, rng| hasher.signatures(csc, round, rng),
        k,
        q,
        rng,
    )
}

/// Signature-closure variant of [`collision_topk`] — used by the online
/// hash state, which derives signatures from stored accumulators rather
/// than from a matrix.
pub fn collision_topk_sigs<F: FnMut(u64, &mut Rng) -> Vec<u64>>(
    n: usize,
    mut sig_fn: F,
    k: usize,
    q: usize,
    rng: &mut Rng,
) -> (TopK, CostReport) {
    let t0 = std::time::Instant::now();
    // Per-column collision counters.
    let mut counts: Vec<HashMap<u32, u32>> = vec![HashMap::new(); n];
    let mut bucket_bytes_peak = 0usize;

    for round in 0..q as u64 {
        let sigs = sig_fn(round, rng);
        debug_assert_eq!(sigs.len(), n);
        // Bucket by signature.
        let mut buckets: HashMap<u64, Vec<u32>> = HashMap::new();
        for (j, &s) in sigs.iter().enumerate() {
            buckets.entry(s).or_default().push(j as u32);
        }
        let round_bytes = n * 8
            + buckets.len() * (8 + 24)
            + buckets.values().map(|b| b.len() * 4).sum::<usize>();
        bucket_bytes_peak = bucket_bytes_peak.max(round_bytes);
        // Count bucketmates (capped per column).
        for members in buckets.values() {
            if members.len() < 2 {
                continue;
            }
            if members.len() <= MAX_BUCKET_SCAN {
                for (a_pos, &a) in members.iter().enumerate() {
                    for &b in &members[a_pos + 1..] {
                        *counts[a as usize].entry(b).or_insert(0) += 1;
                        *counts[b as usize].entry(a).or_insert(0) += 1;
                    }
                }
            } else {
                // sample MAX_BUCKET_SCAN partners per member
                for &a in members.iter() {
                    for _ in 0..MAX_BUCKET_SCAN {
                        let b = members[rng.below(members.len())];
                        if b != a {
                            *counts[a as usize].entry(b).or_insert(0) += 1;
                        }
                    }
                }
            }
        }
    }

    let counter_bytes: usize = counts
        .iter()
        .map(|m| 48 + m.len() * (4 + 4 + 8)) // rough HashMap entry cost
        .sum();

    // Top-K by collision frequency (ties broken by smaller id for
    // determinism), then random supplement.
    let mut rows = Vec::with_capacity(n);
    for (j, cnt) in counts.iter().enumerate() {
        let mut cands: Vec<(u32, u32)> = cnt.iter().map(|(&c, &f)| (c, f)).collect();
        cands.sort_unstable_by(|a, b| b.1.cmp(&a.1).then(a.0.cmp(&b.0)));
        let ordered: Vec<u32> = cands.into_iter().map(|(c, _)| c).collect();
        rows.push(finalize_row(j, ordered, k, n, rng));
    }
    let topk = TopK::from_rows(rows, k);
    let cost = CostReport {
        seconds: t0.elapsed().as_secs_f64(),
        bytes: bucket_bytes_peak + counter_bytes,
    };
    (topk, cost)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::Triples;

    /// A fake hasher that buckets columns by `j % groups` every round —
    /// columns in the same residue class must end up neighbours.
    struct ModHasher {
        groups: u64,
    }

    impl RoundHasher for ModHasher {
        fn name(&self) -> String {
            "mod".into()
        }

        fn p(&self) -> usize {
            1
        }

        fn signatures(&self, csc: &Csc, _round: u64, _rng: &mut Rng) -> Vec<u64> {
            (0..csc.ncols() as u64).map(|j| j % self.groups).collect()
        }
    }

    fn empty_csc(ncols: usize) -> Csc {
        Csc::from_triples(&Triples::new(4, ncols))
    }

    #[test]
    fn bucketmates_become_neighbours() {
        let csc = empty_csc(12);
        let mut rng = Rng::seeded(1);
        let (topk, _) = collision_topk(&ModHasher { groups: 3 }, &csc, 3, 5, &mut rng);
        // column 0's residue class is {0,3,6,9}; its 3 neighbours must be
        // exactly {3,6,9}
        let mut nb: Vec<u32> = topk.neighbours(0).to_vec();
        nb.sort_unstable();
        assert_eq!(nb, vec![3, 6, 9]);
    }

    #[test]
    fn supplements_when_bucket_too_small() {
        let csc = empty_csc(12);
        let mut rng = Rng::seeded(2);
        // groups=12 → singleton buckets → all neighbours random
        let (topk, _) = collision_topk(&ModHasher { groups: 12 }, &csc, 4, 3, &mut rng);
        for j in 0..12 {
            let nb = topk.neighbours(j);
            assert_eq!(nb.len(), 4);
            assert!(nb.iter().all(|&c| c != j as u32));
            let set: std::collections::HashSet<_> = nb.iter().collect();
            assert_eq!(set.len(), 4);
        }
    }

    #[test]
    fn cost_report_nonzero() {
        let csc = empty_csc(20);
        let mut rng = Rng::seeded(3);
        let (_, cost) = collision_topk(&ModHasher { groups: 4 }, &csc, 2, 2, &mut rng);
        assert!(cost.bytes > 0);
        assert!(cost.seconds >= 0.0);
    }

    #[test]
    fn combine_disambiguates_order() {
        let a = combine(combine(0, 1), 2);
        let b = combine(combine(0, 2), 1);
        assert_ne!(a, b);
    }
}
