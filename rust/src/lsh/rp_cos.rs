//! Random-projection cosine LSH (the `RP_cos` comparator of Fig. 7).
//!
//! Classic SimHash/sign-random-projection: each base hash draws a
//! Gaussian vector `w ∈ ℝ^M` and emits `sign(Σ_i r_ij · w_i)` per bit.
//! Equivalent to simLSH with Ψ = identity and Gaussian (not ±1) row
//! weights; the paper's point is that on sparse integer-ish ratings the
//! Ψ-spread ±1 projection is both cheaper and slightly more accurate.

use super::amplify::{collision_topk, combine, RoundHasher};
use super::{CostReport, NeighbourSearch, TopK};
use crate::rng::Rng;
use crate::sparse::Csc;

/// Random-projection cosine LSH engine.
#[derive(Clone, Debug)]
pub struct RpCos {
    pub p: usize,
    pub q: usize,
    /// Bits per base hash.
    pub g: usize,
    pub seed: u64,
}

impl RpCos {
    pub fn new(p: usize, q: usize, g: usize) -> Self {
        RpCos { p, q, g, seed: 0xC0_51_4E }
    }

    /// Deterministic Gaussian weight for (row, bit, round, slot) via a
    /// counter-based generator (two splitmix draws → Box–Muller).
    #[inline]
    fn gauss_weight(&self, i: usize, gbit: usize, round: u64, slot: usize) -> f32 {
        let mut s = self.seed
            ^ round.wrapping_mul(0xA24BAED4963EE407)
            ^ (slot as u64).wrapping_mul(0x9FB21C651E98DF25)
            ^ (i as u64).wrapping_mul(0xD1B54A32D192ED03)
            ^ (gbit as u64).wrapping_mul(0x2545F4914F6CDD1D);
        let u1 = (crate::rng::splitmix64(&mut s) >> 11) as f64 * (1.0 / (1u64 << 53) as f64);
        let u2 = (crate::rng::splitmix64(&mut s) >> 11) as f64 * (1.0 / (1u64 << 53) as f64);
        let r = (-2.0 * (u1.max(1e-300)).ln()).sqrt();
        (r * (2.0 * std::f64::consts::PI * u2).cos()) as f32
    }

    /// One base hash of one column.
    pub fn hash_column(&self, csc: &Csc, j: usize, round: u64, slot: usize) -> u64 {
        let (rows, vals) = csc.col_raw(j);
        let mut h = 0u64;
        for gbit in 0..self.g {
            let mut acc = 0f32;
            for (&i, &r) in rows.iter().zip(vals) {
                acc += r * self.gauss_weight(i as usize, gbit, round, slot);
            }
            if acc >= 0.0 {
                h |= 1 << gbit;
            }
        }
        h
    }
}

impl RoundHasher for RpCos {
    fn name(&self) -> String {
        format!("RP_cos(p={},q={},G={})", self.p, self.q, self.g)
    }

    fn p(&self) -> usize {
        self.p
    }

    fn signatures(&self, csc: &Csc, round: u64, _rng: &mut Rng) -> Vec<u64> {
        let n = csc.ncols();
        let mut sigs = vec![0u64; n];
        for slot in 0..self.p {
            for (j, sig) in sigs.iter_mut().enumerate() {
                *sig = combine(*sig, self.hash_column(csc, j, round, slot));
            }
        }
        sigs
    }
}

impl NeighbourSearch for RpCos {
    fn name(&self) -> String {
        RoundHasher::name(self)
    }

    fn build(&mut self, csc: &Csc, k: usize, rng: &mut Rng) -> (TopK, CostReport) {
        collision_topk(self, csc, k, self.q, rng)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::Triples;

    #[test]
    fn scale_invariance() {
        // cosine LSH ignores positive scaling
        let mut entries = Vec::new();
        for i in 0..30u32 {
            let v = 1.0 + (i % 7) as f32 * 0.5;
            entries.push((i, 0, v));
            entries.push((i, 1, 3.0 * v));
        }
        let t = Triples::from_entries(30, 2, entries);
        let csc = Csc::from_triples(&t);
        let lsh = RpCos::new(1, 1, 16);
        for round in 0..8 {
            assert_eq!(
                lsh.hash_column(&csc, 0, round, 0),
                lsh.hash_column(&csc, 1, round, 0)
            );
        }
    }

    #[test]
    fn opposite_columns_anti_collide() {
        // r and -r flip every bit
        let mut entries = Vec::new();
        for i in 0..30u32 {
            let v = 1.0 + (i % 5) as f32;
            entries.push((i, 0, v));
            entries.push((i, 1, -v));
        }
        let t = Triples::from_entries(30, 2, entries);
        let csc = Csc::from_triples(&t);
        let lsh = RpCos::new(1, 1, 16);
        let h0 = lsh.hash_column(&csc, 0, 3, 0);
        let h1 = lsh.hash_column(&csc, 1, 3, 0);
        // accumulators are exact negatives; sign(a) != sign(-a) except a=0
        let mask = (1u64 << 16) - 1;
        assert_eq!(h0 ^ h1, mask, "h0={h0:016b} h1={h1:016b}");
    }

    #[test]
    fn gaussian_weights_deterministic() {
        let lsh = RpCos::new(2, 2, 8);
        assert_eq!(
            lsh.gauss_weight(3, 4, 1, 0).to_bits(),
            lsh.gauss_weight(3, 4, 1, 0).to_bits()
        );
        assert_ne!(
            lsh.gauss_weight(3, 4, 1, 0).to_bits(),
            lsh.gauss_weight(3, 4, 2, 0).to_bits()
        );
    }

    #[test]
    fn finds_duplicate_columns() {
        let mut rng = Rng::seeded(3);
        let mut entries = Vec::new();
        for i in 0..200u32 {
            if rng.chance(0.3) {
                let v = 1.0 + rng.f32() * 4.0;
                entries.push((i, 0, v));
                entries.push((i, 1, v));
            }
            if rng.chance(0.3) {
                entries.push((i, 2, 1.0 + rng.f32() * 4.0));
            }
        }
        let t = Triples::from_entries(200, 3, entries);
        let csc = Csc::from_triples(&t);
        let mut lsh = RpCos::new(2, 20, 8);
        let (topk, _) = lsh.build(&csc, 1, &mut rng);
        assert_eq!(topk.neighbours(0)[0], 1);
        assert_eq!(topk.neighbours(1)[0], 0);
    }
}
