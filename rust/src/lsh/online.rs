//! Online hash maintenance for incremental data (Algorithm 4, lines 1–9).
//!
//! The paper's trick: keep, for every column `J_j` and every base hash,
//! the *pre-threshold accumulator* `Σ_i Ψ(r_ij)·Φ(H_i)` (Eq. 3 before Υ).
//! When increment entries arrive — new rows Ī rating existing columns,
//! and entirely new columns J̄ — each affected accumulator absorbs only
//! the new terms, O(|Ω̄|·p·q·G) instead of a full O(|Ω|·p·q·G) re-hash,
//! and re-thresholding yields the refreshed hash values.
//!
//! Accumulators are f64 to keep incremental and from-scratch sums within
//! rounding distance of each other (the invariant tested below).

use super::amplify::{collision_topk_sigs, combine};
use super::simlsh::SimLsh;
use super::{CostReport, TopK};
use crate::rng::Rng;
use crate::sparse::{band_range, Csc};

/// Persistent accumulator state: `acc[round][slot][col][gbit]`, flattened.
#[derive(Clone, Debug)]
pub struct OnlineHashState {
    lsh: SimLsh,
    n_cols: usize,
    acc: Vec<f64>,
}

impl OnlineHashState {
    /// Build from the base matrix (the Ω part of the online split).
    pub fn build(lsh: SimLsh, csc: &Csc) -> Self {
        let n = csc.ncols();
        let mut state = OnlineHashState {
            acc: vec![0f64; lsh.q * lsh.p * n * lsh.g],
            lsh,
            n_cols: n,
        };
        for j in 0..n {
            let (rows, vals) = csc.col_raw(j);
            for (&i, &r) in rows.iter().zip(vals) {
                state.absorb(i as usize, j, r);
            }
        }
        state
    }

    #[inline]
    fn idx(&self, round: usize, slot: usize, j: usize, gbit: usize) -> usize {
        ((round * self.lsh.p + slot) * self.n_cols + j) * self.lsh.g + gbit
    }

    /// Decompose into checkpointable parts `(lsh, n_cols, accumulators)`.
    pub(crate) fn to_parts(&self) -> (SimLsh, usize, &[f64]) {
        (self.lsh.clone(), self.n_cols, &self.acc)
    }

    /// Rebuild from checkpointed parts; the accumulator length must match
    /// the `q·p·n_cols·g` layout exactly.
    pub(crate) fn from_parts(lsh: SimLsh, n_cols: usize, acc: Vec<f64>) -> Self {
        assert_eq!(
            acc.len(),
            lsh.q * lsh.p * n_cols * lsh.g,
            "accumulator length does not match the q*p*n_cols*g layout"
        );
        OnlineHashState { lsh, n_cols, acc }
    }

    /// Add one interaction's contribution to every base hash of column j.
    fn absorb(&mut self, i: usize, j: usize, r: f32) {
        let w = self.lsh.weight(r) as f64;
        self.absorb_weight(i, j, w);
    }

    /// Add a pre-computed Ψ-weight contribution (the accumulators are
    /// linear in Ψ(r), so signed weight deltas compose exactly).
    fn absorb_weight(&mut self, i: usize, j: usize, w: f64) {
        for round in 0..self.lsh.q {
            for slot in 0..self.lsh.p {
                let code = self.lsh.row_code(i, round as u64, slot);
                let base = self.idx(round, slot, j, 0);
                for gbit in 0..self.lsh.g {
                    let sign = if (code >> gbit) & 1 == 1 { w } else { -w };
                    self.acc[base + gbit] += sign;
                }
            }
        }
    }

    /// Replace a previously absorbed rating's contribution with a new
    /// value — the last-write-wins re-rating path. Because every
    /// accumulator is a linear sum of Ψ(r)·Φ(H_i) terms, adding the
    /// weight delta `Ψ(r_new) − Ψ(r_old)` reproduces exactly the state a
    /// from-scratch build over the re-rated matrix would hold.
    pub fn reabsorb(&mut self, i: usize, j: usize, r_old: f32, r_new: f32) {
        assert!(j < self.n_cols, "column {j} out of range");
        let delta = self.lsh.weight(r_new) as f64 - self.lsh.weight(r_old) as f64;
        self.absorb_weight(i, j, delta);
    }

    /// Remove one previously absorbed interaction's contribution
    /// entirely (used when deduplicating a base matrix that listed the
    /// same cell more than once).
    pub fn retract(&mut self, i: usize, j: usize, r: f32) {
        assert!(j < self.n_cols, "column {j} out of range");
        let w = self.lsh.weight(r) as f64;
        self.absorb_weight(i, j, -w);
    }

    /// Grow the state to `new_n_cols` columns (new columns start at zero
    /// accumulators) and absorb increment entries. Entries are in the
    /// grown coordinate space; row ids may exceed the base row count —
    /// row codes are derived on demand so new rows need no registration.
    pub fn apply_increment(&mut self, entries: &[(u32, u32, f32)], new_n_cols: usize) {
        assert!(new_n_cols >= self.n_cols);
        if new_n_cols > self.n_cols {
            // Re-layout: the col dimension is in the middle of the index
            // space, so rebuild the flat vec with the new stride.
            let (q, p, g) = (self.lsh.q, self.lsh.p, self.lsh.g);
            let mut grown = vec![0f64; q * p * new_n_cols * g];
            for round in 0..q {
                for slot in 0..p {
                    for j in 0..self.n_cols {
                        let old = self.idx(round, slot, j, 0);
                        let new = ((round * p + slot) * new_n_cols + j) * g;
                        grown[new..new + g].copy_from_slice(&self.acc[old..old + g]);
                    }
                }
            }
            self.acc = grown;
            self.n_cols = new_n_cols;
        }
        for &(i, j, r) in entries {
            assert!((j as usize) < self.n_cols, "column {j} out of range");
            self.absorb(i as usize, j as usize, r);
        }
    }

    /// Current hash of column `j` under base hash `(round, slot)`.
    pub fn hash(&self, round: usize, slot: usize, j: usize) -> u64 {
        let base = self.idx(round, slot, j, 0);
        let mut h = 0u64;
        for gbit in 0..self.lsh.g {
            if self.acc[base + gbit] >= 0.0 {
                h |= 1 << gbit;
            }
        }
        h
    }

    /// Round signature of every column (p hashes combined).
    pub fn signatures(&self, round: usize) -> Vec<u64> {
        let mut sigs = vec![0u64; self.n_cols];
        for slot in 0..self.lsh.p {
            for (j, sig) in sigs.iter_mut().enumerate() {
                *sig = combine(*sig, self.hash(round, slot, j));
            }
        }
        sigs
    }

    /// Top-K search over the *current* state (original + absorbed data).
    pub fn topk(&self, k: usize, rng: &mut Rng) -> (TopK, CostReport) {
        let mut cost_bytes = self.bytes();
        let (topk, mut cost) = collision_topk_sigs(
            self.n_cols,
            |round, _| self.signatures(round as usize),
            k,
            self.lsh.q,
            rng,
        );
        cost_bytes += cost.bytes;
        cost.bytes = cost_bytes;
        (topk, cost)
    }

    pub fn n_cols(&self) -> usize {
        self.n_cols
    }

    pub fn lsh(&self) -> &SimLsh {
        &self.lsh
    }

    /// Persistent state footprint (the online method's space cost).
    pub fn bytes(&self) -> usize {
        self.acc.len() * 8
    }

    /// Split the accumulator state into `d` contiguous column bands
    /// (the same [`band_range`] tiling the rotation schedule and the
    /// sharded snapshot publish use), each band's columns re-indexed
    /// band-locally. This is the per-band ownership unit of the
    /// multi-writer ingest path: band `b`'s writer absorbs only its own
    /// columns' deltas. Accumulators are copied bit-for-bit, so a
    /// search over the split ([`topk_banded`]) or over the re-assembled
    /// state ([`assemble_bands`]) reproduces this state's search
    /// exactly.
    pub fn split_bands(&self, d: usize) -> Vec<OnlineHashState> {
        let d = d.max(1);
        let (q, p, g) = (self.lsh.q, self.lsh.p, self.lsh.g);
        (0..d)
            .map(|b| {
                let (lo, hi) = band_range(b, self.n_cols, d);
                let n = hi - lo;
                let mut acc = vec![0f64; q * p * n * g];
                for round in 0..q {
                    for slot in 0..p {
                        if n == 0 {
                            continue;
                        }
                        let src = self.idx(round, slot, lo, 0);
                        let dst = (round * p + slot) * n * g;
                        acc[dst..dst + n * g].copy_from_slice(&self.acc[src..src + n * g]);
                    }
                }
                OnlineHashState { lsh: self.lsh.clone(), n_cols: n, acc }
            })
            .collect()
    }
}

/// Reassemble a [`OnlineHashState::split_bands`] partition into one
/// monolithic state — the inverse operation, exact to the bit. The
/// multi-writer path's cross-band growth barrier uses it: growing the
/// column universe relays out the whole accumulator set, so the barrier
/// assembles, runs the monolithic growth path once, and re-splits on
/// the new band boundaries.
pub fn assemble_bands(bands: &[&OnlineHashState]) -> OnlineHashState {
    assert!(!bands.is_empty(), "assemble_bands needs at least one band");
    let lsh = bands[0].lsh.clone();
    let (q, p, g) = (lsh.q, lsh.p, lsh.g);
    let n: usize = bands.iter().map(|b| b.n_cols).sum();
    let mut acc = vec![0f64; q * p * n * g];
    for round in 0..q {
        for slot in 0..p {
            let mut lo = 0usize;
            for band in bands {
                let nb = band.n_cols;
                if nb > 0 {
                    let src = (round * p + slot) * nb * g;
                    let dst = ((round * p + slot) * n + lo) * g;
                    acc[dst..dst + nb * g].copy_from_slice(&band.acc[src..src + nb * g]);
                }
                lo += nb;
            }
        }
    }
    OnlineHashState { lsh, n_cols: n, acc }
}

/// Top-K search across a banded split, bit-identical to
/// [`OnlineHashState::topk`] on the assembled state: a round's
/// signatures are the band signatures concatenated in band order
/// (accumulators are partitioned by column, so each band computes its
/// columns' signatures from exactly the state the monolithic search
/// would read), and the collision search plus random supplement consume
/// the caller's rng exactly as the monolithic search does.
pub fn topk_banded(bands: &[&OnlineHashState], k: usize, rng: &mut Rng) -> (TopK, CostReport) {
    assert!(!bands.is_empty(), "topk_banded needs at least one band");
    let q = bands[0].lsh.q;
    let n: usize = bands.iter().map(|b| b.n_cols).sum();
    let mut cost_bytes: usize = bands.iter().map(|b| b.bytes()).sum();
    let (topk, mut cost) = collision_topk_sigs(
        n,
        |round, _| {
            let mut sigs = Vec::with_capacity(n);
            for b in bands {
                sigs.extend(b.signatures(round as usize));
            }
            sigs
        },
        k,
        q,
        rng,
    );
    cost_bytes += cost.bytes;
    cost.bytes = cost_bytes;
    (topk, cost)
}

/// [`topk_banded`] with the signature computation fanned out on one
/// scoped thread per band — the relaxed flush mode's band-local
/// re-search. Each band derives **all q rounds'** signatures from its
/// own accumulator slice (signatures are pure functions of the
/// accumulators, no rng), the per-round signature vectors concatenate
/// in band order, and the collision search + random supplement then
/// consume the caller's rng exactly as the monolithic search does — so
/// the result is **bit-identical** to [`topk_banded`] and
/// [`OnlineHashState::topk`] on the assembled state; only the wall
/// clock changes. (Exact-mode flushes keep the sequential search so
/// their thread profile stays untouched.)
pub fn topk_banded_parallel(
    bands: &[&OnlineHashState],
    k: usize,
    rng: &mut Rng,
) -> (TopK, CostReport) {
    assert!(!bands.is_empty(), "topk_banded_parallel needs at least one band");
    let q = bands[0].lsh.q;
    let n: usize = bands.iter().map(|b| b.n_cols).sum();
    let per_band: Vec<Vec<Vec<u64>>> = std::thread::scope(|s| {
        let handles: Vec<_> = bands
            .iter()
            .map(|b| {
                let b: &OnlineHashState = b;
                s.spawn(move || (0..q).map(|round| b.signatures(round)).collect::<Vec<_>>())
            })
            .collect();
        handles
            .into_iter()
            .map(|h| h.join().expect("signature worker panicked"))
            .collect()
    });
    let sigs: Vec<Vec<u64>> = (0..q)
        .map(|round| {
            let mut v = Vec::with_capacity(n);
            for pb in &per_band {
                v.extend_from_slice(&pb[round]);
            }
            v
        })
        .collect();
    let mut cost_bytes: usize = bands.iter().map(|b| b.bytes()).sum();
    let (topk, mut cost) =
        collision_topk_sigs(n, |round, _| sigs[round as usize].clone(), k, q, rng);
    cost_bytes += cost.bytes;
    cost.bytes = cost_bytes;
    (topk, cost)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::Triples;

    fn lsh_small() -> SimLsh {
        SimLsh { p: 2, q: 6, g: 8, psi_power: 2, center: 0.0, seed: 77 }
    }

    fn random_triples(nrows: usize, ncols: usize, nnz: usize, rng: &mut Rng) -> Triples {
        let mut t = Triples::new(nrows, ncols);
        let mut seen = std::collections::HashSet::new();
        while t.nnz() < nnz {
            let (i, j) = (rng.below(nrows), rng.below(ncols));
            if seen.insert((i, j)) {
                t.push(i, j, 1.0 + rng.f32() * 4.0);
            }
        }
        t
    }

    /// THE online invariant: absorbing increments must reproduce the
    /// from-scratch state on the combined matrix.
    #[test]
    fn online_update_equals_rebuild() {
        let mut rng = Rng::seeded(21);
        let full = random_triples(60, 25, 400, &mut rng);
        // split: entries touching the last 5 columns or last 10 rows are
        // the increment
        let mut base = Triples::new(50, 20);
        let mut inc = Vec::new();
        for &(i, j, r) in full.entries() {
            if (i as usize) < 50 && (j as usize) < 20 {
                base.push(i as usize, j as usize, r);
            } else {
                inc.push((i, j, r));
            }
        }
        let base_csc = Csc::from_triples(&base);
        let mut online = OnlineHashState::build(lsh_small(), &base_csc);
        online.apply_increment(&inc, 25);

        let full_csc = Csc::from_triples(&full);
        let scratch = OnlineHashState::build(lsh_small(), &full_csc);

        // accumulators agree to rounding; hashes agree except possibly
        // at near-zero accumulators
        let mut flips = 0;
        let mut total = 0;
        for round in 0..6 {
            for slot in 0..2 {
                for j in 0..25 {
                    total += 1;
                    if online.hash(round, slot, j) != scratch.hash(round, slot, j) {
                        flips += 1;
                    }
                }
            }
        }
        assert!(
            flips * 100 <= total,
            "{flips}/{total} hash mismatches between online and rebuild"
        );
    }

    #[test]
    fn increment_only_touches_affected_columns() {
        let mut rng = Rng::seeded(22);
        let base = random_triples(40, 10, 150, &mut rng);
        let csc = Csc::from_triples(&base);
        let mut online = OnlineHashState::build(lsh_small(), &csc);
        let before: Vec<u64> = (0..10).map(|j| online.hash(0, 0, j)).collect();
        // increment touching only column 3 (new row 40)
        online.apply_increment(&[(40, 3, 5.0)], 10);
        for j in 0..10 {
            if j != 3 {
                assert_eq!(online.hash(0, 0, j), before[j], "column {j} changed");
            }
        }
    }

    #[test]
    fn grows_columns() {
        let mut rng = Rng::seeded(23);
        let base = random_triples(30, 8, 100, &mut rng);
        let csc = Csc::from_triples(&base);
        let mut online = OnlineHashState::build(lsh_small(), &csc);
        let keep: Vec<u64> = (0..8).map(|j| online.hash(1, 1, j)).collect();
        online.apply_increment(&[(2, 9, 4.0), (5, 8, 3.0)], 10);
        assert_eq!(online.n_cols(), 10);
        // old columns unchanged
        for j in 0..8 {
            assert_eq!(online.hash(1, 1, j), keep[j]);
        }
        // new columns have live hashes and can be searched
        let (topk, _) = online.topk(3, &mut rng);
        assert_eq!(topk.n(), 10);
        assert_eq!(topk.neighbours(9).len(), 3);
    }

    /// Re-rating through `reabsorb` must land on the same accumulators a
    /// from-scratch build over the edited matrix holds (up to rounding at
    /// near-zero accumulators, as with additive increments).
    #[test]
    fn reabsorb_matches_rebuild_with_new_value() {
        let mut rng = Rng::seeded(27);
        let base = random_triples(40, 10, 150, &mut rng);
        let csc = Csc::from_triples(&base);
        let mut online = OnlineHashState::build(lsh_small(), &csc);
        let mut edited = base.clone();
        let (i, j, r_old) = edited.entries()[0];
        let r_new = 0.5f32;
        edited.entries_mut()[0].2 = r_new;
        online.reabsorb(i as usize, j as usize, r_old, r_new);
        let scratch = OnlineHashState::build(lsh_small(), &Csc::from_triples(&edited));
        let mut flips = 0;
        let mut total = 0;
        for round in 0..6 {
            for slot in 0..2 {
                for col in 0..10 {
                    total += 1;
                    if online.hash(round, slot, col) != scratch.hash(round, slot, col) {
                        flips += 1;
                    }
                }
            }
        }
        assert!(flips * 100 <= total, "{flips}/{total} hash mismatches after reabsorb");
    }

    /// Splitting into bands and re-assembling is the identity, and the
    /// banded Top-K search reproduces the monolithic search exactly
    /// (same accumulators, same signatures, same rng consumption).
    #[test]
    fn split_assemble_roundtrip_and_banded_topk_match() {
        let mut rng = Rng::seeded(28);
        let t = random_triples(50, 23, 300, &mut rng);
        let csc = Csc::from_triples(&t);
        let whole = OnlineHashState::build(lsh_small(), &csc);
        for d in [1usize, 2, 3, 5] {
            let bands = whole.split_bands(d);
            assert_eq!(bands.len(), d);
            assert_eq!(bands.iter().map(|b| b.n_cols).sum::<usize>(), 23);
            let refs: Vec<&OnlineHashState> = bands.iter().collect();
            let back = assemble_bands(&refs);
            assert_eq!(back.n_cols, whole.n_cols);
            assert_eq!(back.acc, whole.acc, "d={d}: accumulators must round-trip exactly");
            let (a, _) = whole.topk(4, &mut Rng::seeded(5));
            let (b, _) = topk_banded(&refs, 4, &mut Rng::seeded(5));
            for j in 0..23 {
                assert_eq!(a.neighbours(j), b.neighbours(j), "d={d} col {j}");
            }
        }
    }

    /// The parallel band-local search is a wall-clock optimization, not
    /// a semantic one: for every band count it reproduces the
    /// sequential banded search (and hence the monolithic search) bit
    /// for bit, including the rng-consuming random supplement.
    #[test]
    fn parallel_banded_topk_is_bit_identical() {
        let mut rng = Rng::seeded(30);
        let t = random_triples(60, 29, 350, &mut rng);
        let csc = Csc::from_triples(&t);
        let whole = OnlineHashState::build(lsh_small(), &csc);
        for d in [1usize, 2, 4, 6] {
            let bands = whole.split_bands(d);
            let refs: Vec<&OnlineHashState> = bands.iter().collect();
            let (a, cost_a) = topk_banded(&refs, 5, &mut Rng::seeded(9));
            let (b, cost_b) = topk_banded_parallel(&refs, 5, &mut Rng::seeded(9));
            for j in 0..t.ncols() {
                assert_eq!(a.neighbours(j), b.neighbours(j), "d={d} col {j}");
            }
            assert_eq!(cost_a.bytes, cost_b.bytes, "d={d}: same accounting");
        }
    }

    /// Band-local absorption is exact: an increment absorbed band-by-band
    /// (each band taking its own columns' entries, order preserved)
    /// matches the monolithic absorption bit-for-bit.
    #[test]
    fn per_band_absorb_matches_monolithic() {
        let mut rng = Rng::seeded(29);
        let base = random_triples(40, 12, 150, &mut rng);
        let csc = Csc::from_triples(&base);
        let mut whole = OnlineHashState::build(lsh_small(), &csc);
        let mut bands = whole.split_bands(3);
        let bounds: Vec<(usize, usize)> = (0..3).map(|b| band_range(b, 12, 3)).collect();
        let inc = [(40u32, 2u32, 4.0f32), (41, 7, 2.0), (40, 11, 3.5), (5, 2, 1.5)];
        whole.apply_increment(&inc, 12);
        for (b, &(lo, hi)) in bounds.iter().enumerate() {
            let local: Vec<(u32, u32, f32)> = inc
                .iter()
                .filter(|&&(_, j, _)| (j as usize) >= lo && (j as usize) < hi)
                .map(|&(i, j, r)| (i, j - lo as u32, r))
                .collect();
            bands[b].apply_increment(&local, hi - lo);
        }
        let refs: Vec<&OnlineHashState> = bands.iter().collect();
        let back = assemble_bands(&refs);
        assert_eq!(back.acc, whole.acc, "banded absorb must equal monolithic absorb");
    }

    #[test]
    fn topk_matches_simlsh_on_static_data() {
        // With no increments, the online state's topk should closely agree
        // with running SimLsh directly (same seed → same row codes).
        let mut rng = Rng::seeded(24);
        let t = random_triples(80, 15, 300, &mut rng);
        let csc = Csc::from_triples(&t);
        let lsh = lsh_small();
        let online = OnlineHashState::build(lsh.clone(), &csc);
        let (a, _) = online.topk(4, &mut Rng::seeded(1));
        let mut direct = lsh;
        let (b, _) = crate::lsh::NeighbourSearch::build(&mut direct, &csc, 4, &mut Rng::seeded(1));
        // identical hash family → identical buckets → identical counts;
        // the only nondeterminism is random supplement, same rng seed
        assert!(a.overlap(&b) > 0.95, "overlap {}", a.overlap(&b));
    }
}
