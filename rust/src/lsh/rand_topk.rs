//! Randomized control group: K uniformly random "neighbours" per column
//! (the `Rand` rows of Fig. 7 / Table 7). The paper includes it to show
//! the neighbourhood term helps *because* the neighbours are real, not
//! merely because the model has 2K extra parameters per column.

use super::{finalize_row, CostReport, NeighbourSearch, TopK};
use crate::rng::Rng;
use crate::sparse::Csc;

/// Uniform random Top-K selector.
#[derive(Clone, Copy, Debug, Default)]
pub struct RandNeighbours;

impl NeighbourSearch for RandNeighbours {
    fn name(&self) -> String {
        "Rand".into()
    }

    fn build(&mut self, csc: &Csc, k: usize, rng: &mut Rng) -> (TopK, CostReport) {
        let t0 = std::time::Instant::now();
        let n = csc.ncols();
        let rows: Vec<Vec<u32>> = (0..n)
            .map(|j| finalize_row(j, Vec::new(), k, n, rng))
            .collect();
        (
            TopK::from_rows(rows, k),
            CostReport { seconds: t0.elapsed().as_secs_f64(), bytes: 0 },
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::Triples;

    #[test]
    fn produces_valid_rows() {
        let csc = Csc::from_triples(&Triples::new(5, 40));
        let mut rng = Rng::seeded(1);
        let (topk, cost) = RandNeighbours.build(&csc, 8, &mut rng);
        assert_eq!(topk.n(), 40);
        for j in 0..40 {
            let nb = topk.neighbours(j);
            assert_eq!(nb.len(), 8);
            assert!(nb.iter().all(|&c| (c as usize) < 40 && c as usize != j));
            let set: std::collections::HashSet<_> = nb.iter().collect();
            assert_eq!(set.len(), 8);
        }
        assert_eq!(cost.bytes, 0);
    }

    #[test]
    fn different_seeds_differ() {
        let csc = Csc::from_triples(&Triples::new(5, 30));
        let (a, _) = RandNeighbours.build(&csc, 4, &mut Rng::seeded(1));
        let (b, _) = RandNeighbours.build(&csc, 4, &mut Rng::seeded(2));
        assert!(a.overlap(&b) < 0.6);
    }
}
