//! Locality-sensitive neighbourhood search (§4.1 of the paper).
//!
//! All engines produce the same artifact: the **Top-K nearest-neighbour
//! matrix** `J^K ∈ ℕ^{N×K}` ([`TopK`]) over the column variable set `J`,
//! plus a [`CostReport`] (build seconds + peak auxiliary bytes) so the
//! Table 7 cost comparison falls out of the same interface.
//!
//! Engines:
//! * [`simlsh::SimLsh`] — the paper's contribution: sign hashing of
//!   Ψ-weighted ratings (Eq. 3) with coarse-grained (p AND) /
//!   fine-grained (q OR) amplification;
//! * [`rp_cos::RpCos`] — random-projection cosine LSH;
//! * [`minhash::MinHash`] — Jaccard minHash over the column supports;
//! * [`rand_topk::RandNeighbours`] — the randomized control group;
//! * [`crate::gsm::Gsm`] — the exact O(N²) similarity matrix baseline.
//!
//! The LSH engines share the collision-counting amplification pipeline in
//! [`amplify`], differing only in their per-round signature functions.

pub mod amplify;
pub mod minhash;
pub mod online;
pub mod rand_topk;
pub mod rp_cos;
pub mod simlsh;

pub use amplify::{collision_topk, collision_topk_sigs, RoundHasher};
pub use minhash::MinHash;
pub use online::{assemble_bands, topk_banded, topk_banded_parallel, OnlineHashState};
pub use rand_topk::RandNeighbours;
pub use rp_cos::RpCos;
pub use simlsh::SimLsh;

use crate::rng::Rng;
use crate::sparse::Csc;

/// Top-K nearest-neighbour matrix `J^K`: row `j` lists the K neighbours
/// of column variable `J_j` (most-similar first where the engine ranks).
#[derive(Clone, Debug)]
pub struct TopK {
    k: usize,
    idx: Vec<u32>,
}

impl TopK {
    pub fn new(n: usize, k: usize) -> Self {
        TopK { k, idx: vec![u32::MAX; n * k] }
    }

    pub fn from_rows(rows: Vec<Vec<u32>>, k: usize) -> Self {
        let mut t = TopK::new(rows.len(), k);
        for (j, row) in rows.iter().enumerate() {
            assert_eq!(row.len(), k, "row {j} has {} neighbours, want {k}", row.len());
            t.idx[j * k..(j + 1) * k].copy_from_slice(row);
        }
        t
    }

    #[inline]
    pub fn neighbours(&self, j: usize) -> &[u32] {
        &self.idx[j * self.k..(j + 1) * self.k]
    }

    pub fn k(&self) -> usize {
        self.k
    }

    pub fn n(&self) -> usize {
        if self.k == 0 {
            0
        } else {
            self.idx.len() / self.k
        }
    }

    pub fn bytes(&self) -> usize {
        self.idx.len() * 4
    }

    /// Append rows for new column variables (online learning).
    pub fn push_row(&mut self, row: &[u32]) {
        assert_eq!(row.len(), self.k);
        self.idx.extend_from_slice(row);
    }

    /// Sort every row ascending (the CULSH merge-scan precondition; slot
    /// order is semantically free — see `CulshModel::init`).
    pub fn sort_rows(&mut self) {
        for j in 0..self.n() {
            self.idx[j * self.k..(j + 1) * self.k].sort_unstable();
        }
    }

    /// Replace an existing row.
    pub fn set_row(&mut self, j: usize, row: &[u32]) {
        assert_eq!(row.len(), self.k);
        self.idx[j * self.k..(j + 1) * self.k].copy_from_slice(row);
    }

    /// Overlap |A∩B| / K between two neighbour tables — the recall metric
    /// used to validate LSH engines against the exact GSM.
    pub fn overlap(&self, other: &TopK) -> f64 {
        assert_eq!(self.n(), other.n());
        assert_eq!(self.k, other.k);
        if self.n() == 0 {
            return 1.0;
        }
        let mut inter = 0usize;
        for j in 0..self.n() {
            let a: std::collections::HashSet<u32> =
                self.neighbours(j).iter().copied().collect();
            inter += other.neighbours(j).iter().filter(|x| a.contains(x)).count();
        }
        inter as f64 / (self.n() * self.k) as f64
    }
}

/// Build-cost accounting for the Table 7 comparison.
#[derive(Clone, Copy, Debug, Default)]
pub struct CostReport {
    pub seconds: f64,
    /// Peak auxiliary memory (hash tables / similarity accumulators),
    /// excluding the input matrix and the output TopK.
    pub bytes: usize,
}

/// A neighbourhood-search engine: anything that can produce `J^K`.
pub trait NeighbourSearch {
    fn name(&self) -> String;
    fn build(&mut self, csc: &Csc, k: usize, rng: &mut Rng) -> (TopK, CostReport);
}

/// Fill a neighbour row to exactly `k` entries: dedupe, drop self, then
/// random-supplement from `[0, n)` (the paper's "random supplement if the
/// number is less than K").
pub fn finalize_row(j: usize, mut cands: Vec<u32>, k: usize, n: usize, rng: &mut Rng) -> Vec<u32> {
    let mut seen = std::collections::HashSet::with_capacity(k * 2);
    cands.retain(|&c| c as usize != j && seen.insert(c));
    cands.truncate(k);
    if n > 0 {
        let mut guard = 0usize;
        while cands.len() < k && guard < 100 * k + 100 {
            guard += 1;
            let c = rng.below(n) as u32;
            if c as usize != j && seen.insert(c) {
                cands.push(c);
            }
        }
        // tiny-n fallback: allow duplicates rather than loop forever
        while cands.len() < k {
            cands.push(rng.below(n) as u32);
        }
    }
    cands
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn topk_accessors() {
        let t = TopK::from_rows(vec![vec![1, 2], vec![0, 2], vec![0, 1]], 2);
        assert_eq!(t.n(), 3);
        assert_eq!(t.k(), 2);
        assert_eq!(t.neighbours(1), &[0, 2]);
        assert_eq!(t.bytes(), 24);
    }

    #[test]
    fn overlap_metric() {
        let a = TopK::from_rows(vec![vec![1, 2], vec![0, 3]], 2);
        let b = TopK::from_rows(vec![vec![2, 3], vec![0, 3]], 2);
        // row0 shares {2} (1 of 2), row1 shares {0,3} (2 of 2) -> 3/4
        assert!((a.overlap(&b) - 0.75).abs() < 1e-9);
        assert!((a.overlap(&a) - 1.0).abs() < 1e-9);
    }

    #[test]
    fn push_and_set_row() {
        let mut t = TopK::from_rows(vec![vec![1, 2]], 2);
        t.push_row(&[0, 1]);
        assert_eq!(t.n(), 2);
        assert_eq!(t.neighbours(1), &[0, 1]);
        t.set_row(0, &[3, 4]);
        assert_eq!(t.neighbours(0), &[3, 4]);
    }

    #[test]
    fn finalize_row_invariants() {
        let mut rng = Rng::seeded(1);
        for _ in 0..100 {
            let n = rng.range(2, 50);
            let k = rng.range(1, n.min(10));
            let j = rng.below(n);
            let cands: Vec<u32> = (0..rng.below(30)).map(|_| rng.below(n) as u32).collect();
            let row = finalize_row(j, cands, k, n, &mut rng);
            assert_eq!(row.len(), k);
            if n > k {
                // no self, unique
                assert!(row.iter().all(|&c| c as usize != j));
                let set: std::collections::HashSet<_> = row.iter().collect();
                assert_eq!(set.len(), k);
            }
        }
    }

    #[test]
    fn finalize_row_keeps_candidate_order() {
        let mut rng = Rng::seeded(2);
        let row = finalize_row(9, vec![5, 5, 3, 9, 7], 3, 100, &mut rng);
        assert_eq!(&row[..3], &[5, 3, 7]);
    }
}
