//! minHash over column supports (the Jaccard comparator of Fig. 7).
//!
//! One base hash of column `j` is `min_{i ∈ Ω̂_j} h(i)` under a random
//! permutation-ish hash `h` of the row universe; two columns agree with
//! probability equal to their Jaccard similarity of supports. As the
//! paper notes, minHash "only considers the existence of the elements and
//! neglects the real value" — which is exactly why simLSH beats it on
//! rating data.

use super::amplify::{collision_topk, combine, RoundHasher};
use super::{CostReport, NeighbourSearch, TopK};
use crate::rng::Rng;
use crate::sparse::Csc;

/// minHash engine.
#[derive(Clone, Debug)]
pub struct MinHash {
    pub p: usize,
    pub q: usize,
    pub seed: u64,
}

impl MinHash {
    pub fn new(p: usize, q: usize) -> Self {
        MinHash { p, q, seed: 0x31A5_4A5E }
    }

    /// Hash of row index `i` under base hash `(round, slot)`.
    #[inline]
    fn row_hash(&self, i: usize, round: u64, slot: usize) -> u64 {
        let mut s = self.seed
            ^ round.wrapping_mul(0xBF58476D1CE4E5B9)
            ^ (slot as u64).wrapping_mul(0x94D049BB133111EB)
            ^ (i as u64).wrapping_mul(0x9E3779B97F4A7C15);
        crate::rng::splitmix64(&mut s)
    }

    /// One base minhash of one column. Empty columns hash to a sentinel
    /// derived from their id so they don't all collide.
    pub fn hash_column(&self, csc: &Csc, j: usize, round: u64, slot: usize) -> u64 {
        let (rows, _) = csc.col_raw(j);
        if rows.is_empty() {
            return self.row_hash(usize::MAX - j, round, slot);
        }
        rows.iter()
            .map(|&i| self.row_hash(i as usize, round, slot))
            .min()
            .unwrap()
    }
}

impl RoundHasher for MinHash {
    fn name(&self) -> String {
        format!("minHash(p={},q={})", self.p, self.q)
    }

    fn p(&self) -> usize {
        self.p
    }

    fn signatures(&self, csc: &Csc, round: u64, _rng: &mut Rng) -> Vec<u64> {
        let n = csc.ncols();
        let mut sigs = vec![0u64; n];
        for slot in 0..self.p {
            for (j, sig) in sigs.iter_mut().enumerate() {
                *sig = combine(*sig, self.hash_column(csc, j, round, slot));
            }
        }
        sigs
    }
}

impl NeighbourSearch for MinHash {
    fn name(&self) -> String {
        RoundHasher::name(self)
    }

    fn build(&mut self, csc: &Csc, k: usize, rng: &mut Rng) -> (TopK, CostReport) {
        collision_topk(self, csc, k, self.q, rng)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::Triples;

    #[test]
    fn identical_supports_always_collide() {
        let mut entries = Vec::new();
        for i in [2u32, 5, 9, 14] {
            entries.push((i, 0, 1.0));
            entries.push((i, 1, 5.0)); // different VALUES, same support
        }
        let t = Triples::from_entries(20, 2, entries);
        let csc = Csc::from_triples(&t);
        let mh = MinHash::new(1, 1);
        for round in 0..16 {
            assert_eq!(
                mh.hash_column(&csc, 0, round, 0),
                mh.hash_column(&csc, 1, round, 0)
            );
        }
    }

    #[test]
    fn collision_rate_estimates_jaccard() {
        // supports: A = {0..20}, B = {10..30} → J = 10/30 ≈ 0.333
        let mut entries = Vec::new();
        for i in 0..20u32 {
            entries.push((i, 0, 1.0));
        }
        for i in 10..30u32 {
            entries.push((i, 1, 1.0));
        }
        let t = Triples::from_entries(30, 2, entries);
        let csc = Csc::from_triples(&t);
        let mh = MinHash::new(1, 1);
        let rounds = 3000;
        let mut coll = 0;
        for round in 0..rounds {
            if mh.hash_column(&csc, 0, round, 0) == mh.hash_column(&csc, 1, round, 0) {
                coll += 1;
            }
        }
        let rate = coll as f64 / rounds as f64;
        assert!((rate - 1.0 / 3.0).abs() < 0.04, "rate={rate}");
    }

    #[test]
    fn empty_columns_do_not_all_collide() {
        let t = Triples::new(10, 5);
        let csc = Csc::from_triples(&t);
        let mh = MinHash::new(1, 1);
        let h: Vec<u64> = (0..5).map(|j| mh.hash_column(&csc, j, 0, 0)).collect();
        let set: std::collections::HashSet<_> = h.iter().collect();
        assert_eq!(set.len(), 5);
    }

    #[test]
    fn end_to_end_neighbours_by_support() {
        let mut rng = Rng::seeded(5);
        let mut entries = Vec::new();
        // columns 0,1 share support; 2 disjoint
        for i in 0..100u32 {
            if i % 3 == 0 {
                entries.push((i, 0, rng.f32() * 5.0));
                entries.push((i, 1, rng.f32() * 5.0));
            } else if i % 3 == 1 {
                entries.push((i, 2, rng.f32() * 5.0));
            }
        }
        let t = Triples::from_entries(100, 3, entries);
        let csc = Csc::from_triples(&t);
        let mut mh = MinHash::new(2, 25);
        let (topk, _) = mh.build(&csc, 1, &mut rng);
        assert_eq!(topk.neighbours(0)[0], 1);
        assert_eq!(topk.neighbours(1)[0], 0);
    }
}
