//! Deterministic pseudo-random number generation.
//!
//! The offline image carries no `rand` crate, so this module is a small,
//! fully-tested substrate: [`Rng`] is xoshiro256++ (Blackman & Vigna)
//! seeded through SplitMix64, plus the distributions the rest of the crate
//! needs — uniform ints/floats, Box–Muller normals, Zipf (for the
//! popularity skew of rating datasets), Fisher–Yates shuffles and
//! reservoir/partial sampling.
//!
//! Everything is reproducible from a `u64` seed; parallel workers derive
//! independent streams with [`Rng::split`] (SplitMix64 jump on the seed).

mod zipf;

pub use zipf::{Alias, Zipf};

/// SplitMix64 step — used for seeding and stream-splitting.
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

/// xoshiro256++ PRNG.
///
/// Fast (sub-ns per u64), passes BigCrush, and is `Clone` so tests can
/// snapshot streams.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// Cached second Box–Muller variate.
    gauss_spare: Option<f64>,
}

impl Rng {
    /// Seed deterministically from a single `u64` via SplitMix64.
    pub fn seeded(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s, gauss_spare: None }
    }

    /// Raw generator state for checkpointing: the xoshiro words plus the
    /// cached Box–Muller spare (bit-exact resume requires both).
    pub(crate) fn state(&self) -> ([u64; 4], Option<f64>) {
        (self.s, self.gauss_spare)
    }

    /// Rebuild from a [`Rng::state`] snapshot.
    pub(crate) fn from_state(s: [u64; 4], gauss_spare: Option<f64>) -> Rng {
        Rng { s, gauss_spare }
    }

    /// Derive the `idx`-th independent child stream (for worker threads).
    pub fn split(&self, idx: u64) -> Rng {
        // Mix the current state with the index through SplitMix64; children
        // with different `idx` are decorrelated.
        let mut sm = self
            .s
            .iter()
            .fold(0x243F6A8885A308D3u64 ^ idx.wrapping_mul(0x9E3779B97F4A7C15), |a, &b| {
                a.rotate_left(17) ^ b.wrapping_mul(0xD1B54A32D192ED03)
            });
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s, gauss_spare: None }
    }

    /// Next raw 64-bit value.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0]
            .wrapping_add(s[3])
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Next 32-bit value (upper bits of a 64-bit draw).
    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in `[0, 1)` with 53 random bits.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in `[0, 1)` as f32.
    #[inline]
    pub fn f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform in `[lo, hi)`.
    #[inline]
    pub fn range_f32(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.f32()
    }

    /// Uniform integer in `[0, n)` (Lemire's bounded-rejection method).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        let n = n as u64;
        let mut x = self.next_u64();
        let mut m = (x as u128) * (n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as usize
    }

    /// Uniform integer in `[lo, hi)`.
    #[inline]
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        lo + self.below(hi - lo)
    }

    /// Bernoulli draw.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box–Muller (cached spare variate).
    pub fn gaussian(&mut self) -> f64 {
        if let Some(g) = self.gauss_spare.take() {
            return g;
        }
        loop {
            let u = 2.0 * self.f64() - 1.0;
            let v = 2.0 * self.f64() - 1.0;
            let s = u * u + v * v;
            if s > 0.0 && s < 1.0 {
                let k = (-2.0 * s.ln() / s).sqrt();
                self.gauss_spare = Some(v * k);
                return u * k;
            }
        }
    }

    /// Normal with the given mean/stddev, as f32.
    #[inline]
    pub fn normal_f32(&mut self, mean: f32, std: f32) -> f32 {
        mean + std * self.gaussian() as f32
    }

    /// In-place Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from `[0, n)` (Floyd's algorithm).
    pub fn sample_distinct(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n, "cannot sample {k} distinct from {n}");
        let mut chosen = std::collections::HashSet::with_capacity(k);
        let mut out = Vec::with_capacity(k);
        for j in (n - k)..n {
            let t = self.below(j + 1);
            let v = if chosen.contains(&t) { j } else { t };
            chosen.insert(v);
            out.push(v);
        }
        out
    }

    /// Fill a slice with small uniform initial values in `[-scale, scale]`,
    /// the conventional MF factor initialization.
    pub fn fill_uniform(&mut self, xs: &mut [f32], scale: f32) {
        for x in xs.iter_mut() {
            *x = self.range_f32(-scale, scale);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_from_seed() {
        let mut a = Rng::seeded(42);
        let mut b = Rng::seeded(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::seeded(1);
        let mut b = Rng::seeded(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn split_streams_are_decorrelated() {
        let root = Rng::seeded(7);
        let mut c0 = root.split(0);
        let mut c1 = root.split(1);
        let same = (0..64).filter(|_| c0.next_u64() == c1.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::seeded(3);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_is_bounded_and_covers() {
        let mut r = Rng::seeded(11);
        let mut seen = [false; 10];
        for _ in 0..10_000 {
            let x = r.below(10);
            assert!(x < 10);
            seen[x] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn below_uniformity_chi_square() {
        let mut r = Rng::seeded(5);
        let n = 100_000;
        let k = 16;
        let mut counts = vec![0f64; k];
        for _ in 0..n {
            counts[r.below(k)] += 1.0;
        }
        let expect = n as f64 / k as f64;
        let chi2: f64 = counts.iter().map(|c| (c - expect).powi(2) / expect).sum();
        // 15 dof, p=0.001 critical value ~37.7
        assert!(chi2 < 37.7, "chi2={chi2}");
    }

    #[test]
    fn gaussian_moments() {
        let mut r = Rng::seeded(9);
        let n = 200_000;
        let (mut sum, mut sq) = (0.0, 0.0);
        for _ in 0..n {
            let g = r.gaussian();
            sum += g;
            sq += g * g;
        }
        let mean = sum / n as f64;
        let var = sq / n as f64 - mean * mean;
        assert!(mean.abs() < 0.01, "mean={mean}");
        assert!((var - 1.0).abs() < 0.02, "var={var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::seeded(13);
        let mut xs: Vec<usize> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>()); // astronomically unlikely
    }

    #[test]
    fn sample_distinct_properties() {
        let mut r = Rng::seeded(17);
        for _ in 0..50 {
            let n = r.range(1, 100);
            let k = r.below(n + 1);
            let s = r.sample_distinct(n, k);
            assert_eq!(s.len(), k);
            let set: std::collections::HashSet<_> = s.iter().collect();
            assert_eq!(set.len(), k, "duplicates in sample");
            assert!(s.iter().all(|&x| x < n));
        }
    }
}
