//! Zipf-distributed sampling for popularity-skewed synthetic datasets.
//!
//! Real rating matrices (Netflix, MovieLens, Yahoo!Music) have heavily
//! skewed marginals: a few items collect most ratings. The paper's thread
//! load-imbalance discussion (§5.2) only manifests under that skew, so the
//! synthetic generators sample rows/columns from a Zipf(s) law.
//!
//! Implementation: Walker/Vose **alias method** — exact distribution, O(n)
//! setup, O(1) per draw. Dataset generation draws ~|Ω| samples, so constant
//! per-draw cost matters more than setup.

use super::Rng;

/// Discrete distribution over `{0, .., n-1}` sampled via the alias method.
#[derive(Clone, Debug)]
pub struct Alias {
    prob: Vec<f64>,
    alias: Vec<u32>,
}

impl Alias {
    /// Build from unnormalized non-negative weights.
    pub fn new(weights: &[f64]) -> Self {
        let n = weights.len();
        assert!(n > 0, "alias table needs at least one weight");
        assert!(n <= u32::MAX as usize);
        let total: f64 = weights.iter().sum();
        assert!(total > 0.0 && total.is_finite(), "weights must sum to a positive finite value");
        let scale = n as f64 / total;
        let mut prob: Vec<f64> = weights.iter().map(|w| w * scale).collect();
        let mut alias = vec![0u32; n];
        let mut small: Vec<u32> = Vec::new();
        let mut large: Vec<u32> = Vec::new();
        for (i, &p) in prob.iter().enumerate() {
            if p < 1.0 {
                small.push(i as u32);
            } else {
                large.push(i as u32);
            }
        }
        while let (Some(&s), Some(&l)) = (small.last(), large.last()) {
            small.pop();
            alias[s as usize] = l;
            // large donor loses (1 - prob[s]) of its mass
            prob[l as usize] -= 1.0 - prob[s as usize];
            if prob[l as usize] < 1.0 {
                large.pop();
                small.push(l);
            }
        }
        // Remaining entries are numerically == 1.
        for &i in small.iter().chain(large.iter()) {
            prob[i as usize] = 1.0;
            alias[i as usize] = i;
        }
        Alias { prob, alias }
    }

    /// Draw an index.
    #[inline]
    pub fn sample(&self, rng: &mut Rng) -> usize {
        let i = rng.below(self.prob.len());
        if rng.f64() < self.prob[i] {
            i
        } else {
            self.alias[i] as usize
        }
    }

    pub fn len(&self) -> usize {
        self.prob.len()
    }

    pub fn is_empty(&self) -> bool {
        self.prob.is_empty()
    }
}

/// Zipf distribution over `{0, 1, ..., n-1}` with exponent `s > 0`:
/// P(k) ∝ 1/(k+1)^s. Rank 0 is the most popular.
#[derive(Clone, Debug)]
pub struct Zipf {
    table: Alias,
}

impl Zipf {
    pub fn new(n: usize, s: f64) -> Self {
        assert!(n >= 1, "Zipf needs n >= 1");
        assert!(s > 0.0, "Zipf exponent must be positive");
        let weights: Vec<f64> = (0..n).map(|k| 1.0 / ((k + 1) as f64).powf(s)).collect();
        Zipf { table: Alias::new(&weights) }
    }

    /// Draw a rank in `[0, n)`.
    #[inline]
    pub fn sample(&self, rng: &mut Rng) -> usize {
        self.table.sample(rng)
    }

    pub fn n(&self) -> usize {
        self.table.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn alias_matches_weights() {
        let w = [1.0, 2.0, 3.0, 4.0];
        let a = Alias::new(&w);
        let mut r = Rng::seeded(1);
        let n = 200_000;
        let mut counts = [0f64; 4];
        for _ in 0..n {
            counts[a.sample(&mut r)] += 1.0;
        }
        let total: f64 = w.iter().sum();
        for i in 0..4 {
            let expect = w[i] / total;
            let got = counts[i] / n as f64;
            assert!((got - expect).abs() < 0.01, "i={i} got={got} expect={expect}");
        }
    }

    #[test]
    fn alias_single_weight() {
        let a = Alias::new(&[5.0]);
        let mut r = Rng::seeded(2);
        for _ in 0..10 {
            assert_eq!(a.sample(&mut r), 0);
        }
    }

    #[test]
    fn in_range() {
        let z = Zipf::new(100, 1.1);
        let mut r = Rng::seeded(1);
        for _ in 0..10_000 {
            assert!(z.sample(&mut r) < 100);
        }
    }

    #[test]
    fn rank_zero_is_most_popular() {
        let z = Zipf::new(1000, 1.2);
        let mut r = Rng::seeded(2);
        let mut counts = vec![0usize; 1000];
        for _ in 0..100_000 {
            counts[z.sample(&mut r)] += 1;
        }
        assert!(counts[0] > counts[10]);
        assert!(counts[0] > counts[100]);
        let head: usize = counts[..10].iter().sum();
        assert!(head > 30_000, "head mass {head}");
    }

    #[test]
    fn skew_increases_with_s() {
        let mut r = Rng::seeded(3);
        let mut head_share = |s: f64, r: &mut Rng| {
            let z = Zipf::new(500, s);
            let mut c = vec![0usize; 500];
            for _ in 0..50_000 {
                c[z.sample(r)] += 1;
            }
            c[..5].iter().sum::<usize>()
        };
        let light = head_share(0.8, &mut r);
        let heavy = head_share(1.8, &mut r);
        assert!(heavy > light, "heavy={heavy} light={light}");
    }

    #[test]
    fn zipf_marginal_matches_analytic() {
        let n = 50;
        let s = 1.3;
        let z = Zipf::new(n, s);
        let mut r = Rng::seeded(4);
        let draws = 200_000;
        let mut counts = vec![0f64; n];
        for _ in 0..draws {
            counts[z.sample(&mut r)] += 1.0;
        }
        let norm: f64 = (0..n).map(|k| 1.0 / ((k + 1) as f64).powf(s)).sum();
        for k in [0usize, 1, 5, 20] {
            let expect = 1.0 / ((k + 1) as f64).powf(s) / norm;
            let got = counts[k] / draws as f64;
            assert!(
                (got - expect).abs() < 0.01,
                "k={k} got={got} expect={expect}"
            );
        }
    }

    #[test]
    fn n_one_always_zero() {
        let z = Zipf::new(1, 1.3);
        let mut r = Rng::seeded(4);
        for _ in 0..100 {
            assert_eq!(z.sample(&mut r), 0);
        }
    }
}
