//! Sparse-matrix substrate: COO triples, CSR/CSC views, block partitions.
//!
//! The interaction matrix **R ∈ ℝ^{M×N}** (paper notation: rows are the
//! `I` variable set, columns the `J` variable set) is stored as:
//!
//! * [`Triples`] — the raw (i, j, r) stream, the format produced by data
//!   generators and consumed by the streaming coordinator;
//! * [`Csr`] — row-compressed, the layout the row-wise SGD pass wants
//!   (all `{r_ij | j ∈ Ω_i}` contiguous);
//! * [`Csc`] — column-compressed, the layout the column-wise CULSH-MF
//!   pass (Alg. 3) and the GSM/LSH neighbourhood constructions want
//!   (all `{r_ij | i ∈ Ω̂_j}` contiguous);
//! * [`BlockGrid`] — the D×D partition of Fig. 5 used by the multi-device
//!   rotation scheduler.

mod blocks;
mod matrix;

pub use blocks::{band_of, band_range, Block, BlockGrid};
pub use matrix::{Csc, Csr, Triples};

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    fn toy() -> Triples {
        // 3x4 matrix:
        //   [5 . 3 .]
        //   [. 2 . .]
        //   [1 . . 4]
        Triples::from_entries(
            3,
            4,
            vec![(0, 0, 5.0), (0, 2, 3.0), (1, 1, 2.0), (2, 0, 1.0), (2, 3, 4.0)],
        )
    }

    #[test]
    fn csr_rows() {
        let csr = Csr::from_triples(&toy());
        assert_eq!(csr.nrows(), 3);
        assert_eq!(csr.ncols(), 4);
        assert_eq!(csr.nnz(), 5);
        let r0: Vec<_> = csr.row(0).collect();
        assert_eq!(r0, vec![(0, 5.0), (2, 3.0)]);
        let r1: Vec<_> = csr.row(1).collect();
        assert_eq!(r1, vec![(1, 2.0)]);
        let r2: Vec<_> = csr.row(2).collect();
        assert_eq!(r2, vec![(0, 1.0), (3, 4.0)]);
    }

    #[test]
    fn csc_cols() {
        let csc = Csc::from_triples(&toy());
        let c0: Vec<_> = csc.col(0).collect();
        assert_eq!(c0, vec![(0, 5.0), (2, 1.0)]);
        let c3: Vec<_> = csc.col(3).collect();
        assert_eq!(c3, vec![(2, 4.0)]);
        assert_eq!(csc.col(1).count(), 1);
    }

    #[test]
    fn csr_csc_roundtrip() {
        let mut rng = Rng::seeded(5);
        let mut entries = Vec::new();
        let mut seen = std::collections::HashSet::new();
        for _ in 0..500 {
            let i = rng.below(40);
            let j = rng.below(60);
            if seen.insert((i, j)) {
                entries.push((i as u32, j as u32, rng.f32() * 5.0));
            }
        }
        let t = Triples::from_entries(40, 60, entries.clone());
        let csr = Csr::from_triples(&t);
        let csc = Csc::from_triples(&t);
        // Every entry must appear in both views.
        for &(i, j, r) in &entries {
            assert!(csr.row(i as usize).any(|(jj, rr)| jj == j as usize && rr == r));
            assert!(csc.col(j as usize).any(|(ii, rr)| ii == i as usize && rr == r));
        }
        assert_eq!(csr.nnz(), entries.len());
        assert_eq!(csc.nnz(), entries.len());
    }

    #[test]
    fn csr_to_triples_roundtrip() {
        let t = toy();
        let csr = Csr::from_triples(&t);
        let back = csr.to_triples();
        let mut a = t.entries().to_vec();
        let mut b = back.entries().to_vec();
        a.sort_by_key(|&(i, j, _)| (i, j));
        b.sort_by_key(|&(i, j, _)| (i, j));
        assert_eq!(a, b);
    }

    #[test]
    fn mean_and_counts() {
        let csr = Csr::from_triples(&toy());
        assert!((csr.mean() - 3.0).abs() < 1e-6);
        assert_eq!(csr.row_nnz(0), 2);
        assert_eq!(csr.row_nnz(1), 1);
    }

    #[test]
    fn block_grid_covers_everything() {
        let t = toy();
        let grid = BlockGrid::partition(&t, 2);
        let total: usize = grid.blocks().iter().map(|b| b.entries.len()).sum();
        assert_eq!(total, t.nnz());
        for b in grid.blocks() {
            for &(i, j, _) in &b.entries {
                assert!(grid.row_owner(i as usize) == b.row_band);
                assert!(grid.col_owner(j as usize) == b.col_band);
            }
        }
    }

    #[test]
    fn empty_rows_ok() {
        let t = Triples::from_entries(5, 5, vec![(4, 4, 1.0)]);
        let csr = Csr::from_triples(&t);
        assert_eq!(csr.row(0).count(), 0);
        assert_eq!(csr.row(4).count(), 1);
    }
}
