//! D×D block partition of the interaction matrix (Fig. 5 of the paper).
//!
//! The multi-device schedule needs R split into a D×D grid of blocks
//! `R_{d1,d2}` such that device `d2` owns column band `d2` permanently and
//! row bands rotate. Bands are *contiguous index ranges*; rows/cols are
//! assigned by `idx * D / extent`, which keeps bands balanced in index
//! count (value-count balance is the scheduler's job to measure, mirroring
//! the paper's load-imbalance discussion).

use super::Triples;

/// One block of the grid: every entry with `row ∈ band(row_band)` and
/// `col ∈ band(col_band)`.
#[derive(Clone, Debug)]
pub struct Block {
    pub row_band: usize,
    pub col_band: usize,
    pub entries: Vec<(u32, u32, f32)>,
}

/// A D×D partition of a [`Triples`] matrix.
#[derive(Clone, Debug)]
pub struct BlockGrid {
    d: usize,
    nrows: usize,
    ncols: usize,
    blocks: Vec<Block>, // row-major: blocks[row_band * d + col_band]
}

impl BlockGrid {
    /// Partition `t` into a `d × d` grid.
    pub fn partition(t: &Triples, d: usize) -> Self {
        assert!(d >= 1);
        let (nrows, ncols) = (t.nrows(), t.ncols());
        let mut blocks: Vec<Block> = (0..d * d)
            .map(|k| Block { row_band: k / d, col_band: k % d, entries: Vec::new() })
            .collect();
        for &(i, j, r) in t.entries() {
            let rb = band_of(i as usize, nrows, d);
            let cb = band_of(j as usize, ncols, d);
            blocks[rb * d + cb].entries.push((i, j, r));
        }
        BlockGrid { d, nrows, ncols, blocks }
    }

    pub fn d(&self) -> usize {
        self.d
    }

    pub fn blocks(&self) -> &[Block] {
        &self.blocks
    }

    pub fn block(&self, row_band: usize, col_band: usize) -> &Block {
        &self.blocks[row_band * self.d + col_band]
    }

    pub fn row_owner(&self, i: usize) -> usize {
        band_of(i, self.nrows, self.d)
    }

    pub fn col_owner(&self, j: usize) -> usize {
        band_of(j, self.ncols, self.d)
    }

    /// Index range `[lo, hi)` of row band `b`.
    pub fn row_band_range(&self, b: usize) -> (usize, usize) {
        band_range(b, self.nrows, self.d)
    }

    /// Index range `[lo, hi)` of column band `b`.
    pub fn col_band_range(&self, b: usize) -> (usize, usize) {
        band_range(b, self.ncols, self.d)
    }

    /// nnz per block — the scheduler's load model input.
    pub fn load_matrix(&self) -> Vec<Vec<usize>> {
        (0..self.d)
            .map(|rb| (0..self.d).map(|cb| self.block(rb, cb).entries.len()).collect())
            .collect()
    }
}

/// Band owning index `idx` of an axis of `extent` indices split into `d`
/// contiguous bands. Public because the sharded serving snapshot keys its
/// per-shard dirty sets off the same assignment the rotation schedule
/// uses (`coordinator/shared.rs`).
#[inline]
pub fn band_of(idx: usize, extent: usize, d: usize) -> usize {
    if extent == 0 {
        return 0;
    }
    // Equivalent to floor(idx * d / extent), robust at the upper edge.
    ((idx as u64 * d as u64) / extent as u64) as usize
}

/// Index range `[lo, hi)` of band `b` under the same split as [`band_of`].
#[inline]
pub fn band_range(b: usize, extent: usize, d: usize) -> (usize, usize) {
    let lo = (b as u64 * extent as u64).div_ceil(d as u64) as usize;
    let hi = ((b as u64 + 1) * extent as u64).div_ceil(d as u64) as usize;
    (lo, hi.min(extent))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    #[test]
    fn band_ranges_tile_the_axis() {
        for extent in [1usize, 2, 7, 100, 101, 1024] {
            for d in 1..=6 {
                let mut covered = 0;
                for b in 0..d {
                    let (lo, hi) = band_range(b, extent, d);
                    assert_eq!(lo, covered, "extent={extent} d={d} b={b}");
                    covered = hi;
                    // ownership consistency
                    for i in lo..hi {
                        assert_eq!(band_of(i, extent, d), b);
                    }
                }
                assert_eq!(covered, extent);
            }
        }
    }

    #[test]
    fn partition_preserves_nnz_random() {
        let mut rng = Rng::seeded(8);
        let mut t = Triples::new(97, 53);
        for _ in 0..1000 {
            t.push(rng.below(97), rng.below(53), rng.f32());
        }
        for d in [1, 2, 3, 4] {
            let g = BlockGrid::partition(&t, d);
            let total: usize = g.blocks().iter().map(|b| b.entries.len()).sum();
            assert_eq!(total, t.nnz());
        }
    }

    #[test]
    fn load_matrix_shape() {
        let t = Triples::from_entries(10, 10, vec![(0, 0, 1.0), (9, 9, 1.0)]);
        let g = BlockGrid::partition(&t, 2);
        let lm = g.load_matrix();
        assert_eq!(lm.len(), 2);
        assert_eq!(lm[0][0], 1);
        assert_eq!(lm[1][1], 1);
        assert_eq!(lm[0][1] + lm[1][0], 0);
    }
}
