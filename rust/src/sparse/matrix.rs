//! COO / CSR / CSC storage for the sparse interaction matrix.

/// Raw coordinate-format triples `(i, j, r)` with matrix dimensions.
///
/// Indices are `u32` (the paper's largest dataset has M < 2^20) which
/// halves the memory traffic of the SGD hot loop versus `usize`.
#[derive(Clone, Debug, Default)]
pub struct Triples {
    nrows: usize,
    ncols: usize,
    entries: Vec<(u32, u32, f32)>,
}

impl Triples {
    pub fn new(nrows: usize, ncols: usize) -> Self {
        Triples { nrows, ncols, entries: Vec::new() }
    }

    pub fn from_entries(nrows: usize, ncols: usize, entries: Vec<(u32, u32, f32)>) -> Self {
        debug_assert!(entries
            .iter()
            .all(|&(i, j, _)| (i as usize) < nrows && (j as usize) < ncols));
        Triples { nrows, ncols, entries }
    }

    pub fn push(&mut self, i: usize, j: usize, r: f32) {
        debug_assert!(i < self.nrows && j < self.ncols);
        self.entries.push((i as u32, j as u32, r));
    }

    /// Grow the logical dimensions (online learning appends new variables).
    pub fn grow_to(&mut self, nrows: usize, ncols: usize) {
        self.nrows = self.nrows.max(nrows);
        self.ncols = self.ncols.max(ncols);
    }

    pub fn nrows(&self) -> usize {
        self.nrows
    }

    pub fn ncols(&self) -> usize {
        self.ncols
    }

    pub fn nnz(&self) -> usize {
        self.entries.len()
    }

    pub fn entries(&self) -> &[(u32, u32, f32)] {
        &self.entries
    }

    pub fn entries_mut(&mut self) -> &mut Vec<(u32, u32, f32)> {
        &mut self.entries
    }

    /// Global mean of the stored values (μ in the paper).
    pub fn mean(&self) -> f32 {
        if self.entries.is_empty() {
            return 0.0;
        }
        let sum: f64 = self.entries.iter().map(|&(_, _, r)| r as f64).sum();
        (sum / self.entries.len() as f64) as f32
    }

    /// Memory footprint of the triple store in bytes.
    pub fn bytes(&self) -> usize {
        self.entries.len() * std::mem::size_of::<(u32, u32, f32)>()
    }
}

/// Compressed sparse row view: per-row contiguous `(col, value)` pairs.
#[derive(Clone, Debug)]
pub struct Csr {
    nrows: usize,
    ncols: usize,
    row_ptr: Vec<u32>,
    col_idx: Vec<u32>,
    values: Vec<f32>,
}

impl Csr {
    pub fn from_triples(t: &Triples) -> Self {
        let (nrows, ncols) = (t.nrows(), t.ncols());
        let mut row_ptr = vec![0u32; nrows + 1];
        for &(i, _, _) in t.entries() {
            row_ptr[i as usize + 1] += 1;
        }
        for i in 0..nrows {
            row_ptr[i + 1] += row_ptr[i];
        }
        let nnz = t.nnz();
        let mut col_idx = vec![0u32; nnz];
        let mut values = vec![0f32; nnz];
        let mut cursor = row_ptr.clone();
        for &(i, j, r) in t.entries() {
            let p = cursor[i as usize] as usize;
            col_idx[p] = j;
            values[p] = r;
            cursor[i as usize] += 1;
        }
        // Sort each row by column for deterministic iteration.
        let mut csr = Csr { nrows, ncols, row_ptr, col_idx, values };
        csr.sort_rows();
        csr
    }

    fn sort_rows(&mut self) {
        for i in 0..self.nrows {
            let (lo, hi) = (self.row_ptr[i] as usize, self.row_ptr[i + 1] as usize);
            let mut pairs: Vec<(u32, f32)> = (lo..hi)
                .map(|p| (self.col_idx[p], self.values[p]))
                .collect();
            pairs.sort_unstable_by_key(|&(j, _)| j);
            for (off, (j, v)) in pairs.into_iter().enumerate() {
                self.col_idx[lo + off] = j;
                self.values[lo + off] = v;
            }
        }
    }

    pub fn nrows(&self) -> usize {
        self.nrows
    }

    pub fn ncols(&self) -> usize {
        self.ncols
    }

    pub fn nnz(&self) -> usize {
        self.values.len()
    }

    /// Iterate `(col, value)` over row `i` — the set `{r_ij | j ∈ Ω_i}`.
    pub fn row(&self, i: usize) -> impl Iterator<Item = (usize, f32)> + '_ {
        let (lo, hi) = (self.row_ptr[i] as usize, self.row_ptr[i + 1] as usize);
        self.col_idx[lo..hi]
            .iter()
            .zip(&self.values[lo..hi])
            .map(|(&j, &v)| (j as usize, v))
    }

    /// Raw slices for the hot loop (avoids iterator overhead).
    #[inline]
    pub fn row_raw(&self, i: usize) -> (&[u32], &[f32]) {
        let (lo, hi) = (self.row_ptr[i] as usize, self.row_ptr[i + 1] as usize);
        (&self.col_idx[lo..hi], &self.values[lo..hi])
    }

    pub fn row_nnz(&self, i: usize) -> usize {
        (self.row_ptr[i + 1] - self.row_ptr[i]) as usize
    }

    pub fn mean(&self) -> f32 {
        if self.values.is_empty() {
            return 0.0;
        }
        let sum: f64 = self.values.iter().map(|&r| r as f64).sum();
        (sum / self.values.len() as f64) as f32
    }

    /// Row indices sorted by descending nnz — the paper's §5.2 scheduling
    /// trick (process heavy rows first to reduce tail latency; 1.02–1.06×).
    pub fn rows_by_nnz_desc(&self) -> Vec<u32> {
        let mut order: Vec<u32> = (0..self.nrows as u32).collect();
        order.sort_by_key(|&i| std::cmp::Reverse(self.row_nnz(i as usize)));
        order
    }

    pub fn to_triples(&self) -> Triples {
        let mut t = Triples::new(self.nrows, self.ncols);
        for i in 0..self.nrows {
            for (j, r) in self.row(i) {
                t.push(i, j, r);
            }
        }
        t
    }

    pub fn bytes(&self) -> usize {
        self.row_ptr.len() * 4 + self.col_idx.len() * 4 + self.values.len() * 4
    }
}

/// Compressed sparse column view: per-column contiguous `(row, value)`
/// pairs — the set `{r_ij | i ∈ Ω̂_j}` the hash coding (Eq. 3) and the
/// column-major CULSH-MF pass iterate over.
#[derive(Clone, Debug)]
pub struct Csc {
    nrows: usize,
    ncols: usize,
    col_ptr: Vec<u32>,
    row_idx: Vec<u32>,
    values: Vec<f32>,
}

impl Csc {
    pub fn from_triples(t: &Triples) -> Self {
        let (nrows, ncols) = (t.nrows(), t.ncols());
        let mut col_ptr = vec![0u32; ncols + 1];
        for &(_, j, _) in t.entries() {
            col_ptr[j as usize + 1] += 1;
        }
        for j in 0..ncols {
            col_ptr[j + 1] += col_ptr[j];
        }
        let nnz = t.nnz();
        let mut row_idx = vec![0u32; nnz];
        let mut values = vec![0f32; nnz];
        let mut cursor = col_ptr.clone();
        for &(i, j, r) in t.entries() {
            let p = cursor[j as usize] as usize;
            row_idx[p] = i;
            values[p] = r;
            cursor[j as usize] += 1;
        }
        let mut csc = Csc { nrows, ncols, col_ptr, row_idx, values };
        csc.sort_cols();
        csc
    }

    fn sort_cols(&mut self) {
        for j in 0..self.ncols {
            let (lo, hi) = (self.col_ptr[j] as usize, self.col_ptr[j + 1] as usize);
            let mut pairs: Vec<(u32, f32)> = (lo..hi)
                .map(|p| (self.row_idx[p], self.values[p]))
                .collect();
            pairs.sort_unstable_by_key(|&(i, _)| i);
            for (off, (i, v)) in pairs.into_iter().enumerate() {
                self.row_idx[lo + off] = i;
                self.values[lo + off] = v;
            }
        }
    }

    pub fn nrows(&self) -> usize {
        self.nrows
    }

    pub fn ncols(&self) -> usize {
        self.ncols
    }

    pub fn nnz(&self) -> usize {
        self.values.len()
    }

    /// Iterate `(row, value)` over column `j`.
    pub fn col(&self, j: usize) -> impl Iterator<Item = (usize, f32)> + '_ {
        let (lo, hi) = (self.col_ptr[j] as usize, self.col_ptr[j + 1] as usize);
        self.row_idx[lo..hi]
            .iter()
            .zip(&self.values[lo..hi])
            .map(|(&i, &v)| (i as usize, v))
    }

    /// Raw slices for the hot loop.
    #[inline]
    pub fn col_raw(&self, j: usize) -> (&[u32], &[f32]) {
        let (lo, hi) = (self.col_ptr[j] as usize, self.col_ptr[j + 1] as usize);
        (&self.row_idx[lo..hi], &self.values[lo..hi])
    }

    pub fn col_nnz(&self, j: usize) -> usize {
        (self.col_ptr[j + 1] - self.col_ptr[j]) as usize
    }

    pub fn bytes(&self) -> usize {
        self.col_ptr.len() * 4 + self.row_idx.len() * 4 + self.values.len() * 4
    }
}
