//! Serving engine: predictions, top-N recommendation and live ingestion
//! over a trained CULSH-MF model.
//!
//! The engine wraps a [`StreamOrchestrator`] (so every `rate` call flows
//! through the Algorithm-4 online path) and adds the read-side API the
//! TCP server and the examples consume. Predictions are clamped to the
//! rating scale; top-N excludes columns the row has already rated.

use super::stream::{Event, IngestResult, StreamOrchestrator};
use crate::metrics::Registry;
use crate::mf::neighbourhood::{CulshModel, NeighbourScratch};
use crate::sparse::Csr;

/// Score every unrated column of `matrix` for row `i` and return the top
/// `n_items` by clamped prediction (ties broken by ascending column id).
///
/// Shared by the single-threaded [`Engine`] and the lock-free read path
/// of [`super::shared::SharedEngine`], so both serving flavours rank
/// identically. `i` must be in range.
pub(crate) fn rank_unrated(
    model: &CulshModel,
    matrix: &Csr,
    i: usize,
    n_items: usize,
    clamp: (f32, f32),
) -> Vec<(u32, f32)> {
    let n = matrix.ncols();
    let rated: std::collections::HashSet<usize> = matrix.row(i).map(|(j, _)| j).collect();
    let mut scored: Vec<(u32, f32)> = Vec::with_capacity(n - rated.len());
    let mut scratch = NeighbourScratch::default();
    for j in 0..n {
        if rated.contains(&j) {
            continue;
        }
        let s = model.predict(matrix, i, j, &mut scratch).clamp(clamp.0, clamp.1);
        scored.push((j as u32, s));
    }
    scored.sort_unstable_by(|a, b| b.1.partial_cmp(&a.1).unwrap().then(a.0.cmp(&b.0)));
    scored.truncate(n_items);
    scored
}

/// The serving facade.
pub struct Engine {
    orch: StreamOrchestrator,
    metrics: Registry,
    clamp: (f32, f32),
}

impl Engine {
    pub fn new(orch: StreamOrchestrator, clamp: (f32, f32), metrics: Registry) -> Self {
        Engine { orch, metrics, clamp }
    }

    pub fn dims(&self) -> (usize, usize) {
        self.orch.dims()
    }

    /// The current model (last-flushed state).
    pub fn model(&self) -> &CulshModel {
        self.orch.model()
    }

    /// The combined training matrix (last-flushed state).
    pub fn matrix(&self) -> &Csr {
        self.orch.matrix()
    }

    /// Events buffered but not yet applied.
    pub fn buffered(&self) -> usize {
        self.orch.buffered()
    }

    /// The rating-scale clamp applied to predictions.
    pub fn clamp(&self) -> (f32, f32) {
        self.clamp
    }

    /// The engine's metric registry (shared with the concurrent server).
    pub fn metrics(&self) -> &Registry {
        &self.metrics
    }

    /// Predict the interaction value for (row, col).
    pub fn predict(&self, i: usize, j: usize) -> Option<f32> {
        let (m, n) = self.dims();
        if i >= m || j >= n {
            return None;
        }
        self.metrics.counter("engine.predict").inc();
        let mut scratch = NeighbourScratch::default();
        let raw = self
            .orch
            .model()
            .predict(self.orch.matrix(), i, j, &mut scratch);
        Some(raw.clamp(self.clamp.0, self.clamp.1))
    }

    /// Top-N highest-predicted unrated columns for a row.
    pub fn top_n(&self, i: usize, n_items: usize) -> Vec<(u32, f32)> {
        let (m, _) = self.dims();
        if i >= m {
            return Vec::new();
        }
        self.metrics.counter("engine.topn").inc();
        rank_unrated(self.orch.model(), self.orch.matrix(), i, n_items, self.clamp)
    }

    /// Ingest a rating through the online path.
    pub fn rate(&mut self, i: u32, j: u32, r: f32) -> IngestResult {
        self.orch.ingest(Event::Rate(i, j, r))
    }

    /// Force-apply buffered ratings.
    pub fn flush(&mut self) -> usize {
        self.orch.flush()
    }

    /// Metrics snapshot (server `STATS` verb).
    pub fn stats(&self) -> String {
        let (m, n) = self.dims();
        format!(
            "dims {m}x{n}\nbuffered {}\n{}",
            self.orch.buffered(),
            self.metrics.snapshot()
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::stream::{StreamConfig, StreamOrchestrator};
    use crate::lsh::{OnlineHashState, SimLsh};
    use crate::mf::neighbourhood::{train_culsh_logged, CulshConfig};
    use crate::rng::Rng;
    use crate::sparse::{Csc, Csr, Triples};

    fn engine(rng: &mut Rng) -> Engine {
        let (m, n) = (30, 15);
        let mut t = Triples::new(m, n);
        let mut seen = std::collections::HashSet::new();
        while t.nnz() < 180 {
            let (i, j) = (rng.below(m), rng.below(n));
            if seen.insert((i, j)) {
                t.push(i, j, 1.0 + rng.f32() * 4.0);
            }
        }
        let csr = Csr::from_triples(&t);
        let csc = Csc::from_triples(&t);
        let lsh = SimLsh::new(2, 5, 8, 2);
        let hash_state = OnlineHashState::build(lsh, &csc);
        let (topk, _) = hash_state.topk(4, rng);
        let cfg = CulshConfig { f: 4, k: 4, epochs: 5, ..Default::default() };
        let (model, _) = train_culsh_logged(&csr, topk, &cfg, rng);
        let orch = StreamOrchestrator::new(
            model,
            hash_state,
            t,
            StreamConfig { batch_size: 4, ..Default::default() },
            cfg,
            rng.split(1),
            Registry::new(),
        );
        Engine::new(orch, (1.0, 5.0), Registry::new())
    }

    #[test]
    fn predictions_are_clamped_and_bounded() {
        let mut rng = Rng::seeded(61);
        let e = engine(&mut rng);
        for i in 0..30 {
            for j in 0..15 {
                let p = e.predict(i, j).unwrap();
                assert!((1.0..=5.0).contains(&p));
            }
        }
        assert!(e.predict(99, 0).is_none());
        assert!(e.predict(0, 99).is_none());
    }

    #[test]
    fn top_n_excludes_rated_and_is_sorted() {
        let mut rng = Rng::seeded(62);
        let e = engine(&mut rng);
        let rated: std::collections::HashSet<usize> =
            e.orch.matrix().row(3).map(|(j, _)| j).collect();
        let recs = e.top_n(3, 5);
        assert!(recs.len() <= 5);
        for win in recs.windows(2) {
            assert!(win[0].1 >= win[1].1);
        }
        for (j, _) in &recs {
            assert!(!rated.contains(&(*j as usize)));
        }
    }

    #[test]
    fn rate_flush_expands_universe() {
        let mut rng = Rng::seeded(63);
        let mut e = engine(&mut rng);
        assert!(e.predict(0, 20).is_none());
        e.rate(0, 20, 5.0);
        e.flush();
        let p = e.predict(0, 20).unwrap();
        assert!((1.0..=5.0).contains(&p));
        assert!(e.stats().contains("dims 30x21"));
    }
}
