//! Serving engine: predictions, top-N recommendation and live ingestion
//! over a trained CULSH-MF model.
//!
//! The engine wraps a [`StreamOrchestrator`] (so every `rate` call flows
//! through the Algorithm-4 online path) and adds the read-side API the
//! TCP server and the examples consume. Predictions are clamped to the
//! rating scale; top-N excludes columns the row has already rated.

use super::cache::TopNCache;
use super::shared::dirty_bands;
use super::stream::{Event, IngestResult, StreamOrchestrator};
use crate::metrics::Registry;
use crate::mf::neighbourhood::{CulshModel, NeighbourScratch};
use crate::persist::Persister;
use crate::sparse::{band_range, Csr};
use std::sync::Arc;

/// The one ranking order every Top-N path sorts and merges by:
/// descending score (`f32::total_cmp`), ties broken by ascending column
/// id, NaN scores sinking to the tail (a poisoned column must never
/// lead the recommendations; under plain descending `total_cmp`
/// positive NaN would sort above +inf). Total over distinct column ids,
/// which is what makes the cache's per-band k-way merge bit-identical
/// to a full re-sort.
#[inline]
pub(crate) fn rank_cmp(a: &(u32, f32), b: &(u32, f32)) -> std::cmp::Ordering {
    match (a.1.is_nan(), b.1.is_nan()) {
        (true, true) => a.0.cmp(&b.0),
        (true, false) => std::cmp::Ordering::Greater,
        (false, true) => std::cmp::Ordering::Less,
        (false, false) => b.1.total_cmp(&a.1).then(a.0.cmp(&b.0)),
    }
}

/// Score every unrated column of `matrix` for row `i` with `score` and
/// return the top `n_items` (ties broken by ascending column id).
///
/// Shared by the single-threaded [`Engine`] and the sharded read path of
/// [`super::shared::SharedEngine`], so both serving flavours rank
/// identically. Ordering uses `f32::total_cmp`, not
/// `partial_cmp().unwrap()`: a NaN score out of a degenerate model state
/// must sort deterministically instead of panicking the connection
/// thread. `i` must be in range.
pub(crate) fn rank_unrated_by(
    matrix: &Csr,
    i: usize,
    n_items: usize,
    mut score: impl FnMut(usize) -> f32,
) -> Vec<(u32, f32)> {
    let n = matrix.ncols();
    let rated: std::collections::HashSet<usize> = matrix.row(i).map(|(j, _)| j).collect();
    let mut scored: Vec<(u32, f32)> = Vec::with_capacity(n - rated.len());
    for j in 0..n {
        if rated.contains(&j) {
            continue;
        }
        scored.push((j as u32, score(j)));
    }
    scored.sort_unstable_by(rank_cmp);
    scored.truncate(n_items);
    scored
}

/// Score one column band's unrated columns for row `i` — the unit the
/// per-row Top-N cache memoizes. Returns band `[lo, hi)`'s candidates
/// sorted by [`rank_cmp`] and truncated to
/// [`MAX_TOPN_ITEMS`](super::protocol::MAX_TOPN_ITEMS): a global Top-N
/// of `n ≤ MAX_TOPN_ITEMS` items can draw at most that many entries
/// from one band, so the truncated prefix is lossless for every legal
/// request. `i` must be in range.
pub(crate) fn band_candidates(
    matrix: &Csr,
    i: usize,
    lo: usize,
    hi: usize,
    mut score: impl FnMut(usize) -> f32,
) -> Vec<(u32, f32)> {
    let rated: std::collections::HashSet<usize> =
        matrix.row(i).map(|(j, _)| j).filter(|&j| j >= lo && j < hi).collect();
    let mut scored: Vec<(u32, f32)> = Vec::with_capacity((hi - lo).saturating_sub(rated.len()));
    for j in lo..hi {
        if rated.contains(&j) {
            continue;
        }
        scored.push((j as u32, score(j)));
    }
    scored.sort_unstable_by(rank_cmp);
    scored.truncate(super::protocol::MAX_TOPN_ITEMS);
    scored
}

/// Score the requested columns of an `n`-column state with `score`,
/// mapping out-of-range columns to `None` (the `MPREDICT` body). Shared
/// by both serving flavours so their replies cannot drift.
pub(crate) fn predict_many_by(
    n: usize,
    cols: &[u32],
    mut score: impl FnMut(usize) -> f32,
) -> Vec<Option<f32>> {
    cols.iter()
        .map(|&j| {
            let j = j as usize;
            if j >= n {
                None
            } else {
                Some(score(j))
            }
        })
        .collect()
}

/// [`rank_unrated_by`] over a model's clamped Eq. (1) predictions.
pub(crate) fn rank_unrated(
    model: &CulshModel,
    matrix: &Csr,
    i: usize,
    n_items: usize,
    clamp: (f32, f32),
) -> Vec<(u32, f32)> {
    let mut scratch = NeighbourScratch::default();
    rank_unrated_by(matrix, i, n_items, |j| {
        model.predict(matrix, i, j, &mut scratch).clamp(clamp.0, clamp.1)
    })
}

/// The serving facade.
pub struct Engine {
    orch: StreamOrchestrator,
    metrics: Registry,
    clamp: (f32, f32),
    /// Per-row Top-N result cache over the flushed state. Banded with
    /// `flush_bands` so invalidation keys off the same dirty-band
    /// report the sharded publish uses.
    cache: TopNCache,
    /// Flush counter stamping cache entries: bumped once per applied
    /// flush, so a cached band list is valid exactly while no flush
    /// dirtied its band (or the row) since it was scored.
    version: u64,
    /// Optional durability: when attached, accepted events append to
    /// the WAL *before* ingesting and every applied flush runs the
    /// fsync/checkpoint policy (see [`crate::persist`]).
    persist: Option<Arc<Persister>>,
}

impl Engine {
    pub fn new(orch: StreamOrchestrator, clamp: (f32, f32), metrics: Registry) -> Self {
        let cache = TopNCache::new(orch.config().flush_bands, &metrics);
        Engine { orch, metrics, clamp, cache, version: 0, persist: None }
    }

    /// Attach a durability coordinator; subsequent writes WAL-append
    /// before ingesting and flushes follow its checkpoint cadence.
    pub fn attach_persister(&mut self, persister: Arc<Persister>) {
        self.persist = Some(persister);
    }

    /// Detach and surrender the persister (the banded spawn moves it
    /// into the orchestrator so epoch-time hooks run under its locks).
    pub(crate) fn take_persister(&mut self) -> Option<Arc<Persister>> {
        self.persist.take()
    }

    /// Restore a recovered flush version (recovery resumes serving at
    /// the version the checkpoint recorded, not at zero).
    pub(crate) fn set_version(&mut self, version: u64) {
        self.version = version;
    }

    /// The wrapped orchestrator (checkpoint serialization source).
    pub(crate) fn orchestrator(&self) -> &StreamOrchestrator {
        &self.orch
    }

    pub fn dims(&self) -> (usize, usize) {
        self.orch.dims()
    }

    /// The current model (last-flushed state).
    pub fn model(&self) -> &CulshModel {
        self.orch.model()
    }

    /// The combined training matrix (last-flushed state).
    pub fn matrix(&self) -> &Csr {
        self.orch.matrix()
    }

    /// Shared handle to the combined matrix (zero-copy snapshot publish).
    pub fn matrix_arc(&self) -> std::sync::Arc<Csr> {
        self.orch.matrix_arc()
    }

    /// Column ids applied by the most recent flush (the sharded
    /// publish's dirty-band source).
    pub fn last_flush_cols(&self) -> &[u32] {
        self.orch.last_flush_cols()
    }

    /// Old columns whose Top-K row the most recent flush's re-search
    /// moved (the publish's other dirty-band source — O(report) clean-
    /// band detection instead of an O(N·K) scan per publish).
    pub fn last_flush_topk_moved(&self) -> &[u32] {
        self.orch.last_flush_topk_moved()
    }

    /// Row ids applied by the most recent flush (the per-row Top-N
    /// cache's row-invalidation source).
    pub fn last_flush_rows(&self) -> &[u32] {
        self.orch.last_flush_rows()
    }

    /// The engine's per-row Top-N cache (push-subscription surface).
    pub fn cache(&self) -> &TopNCache {
        &self.cache
    }

    /// Flushes applied so far — the version cached rankings are keyed
    /// by, and the version `SUBSCRIBED`/`PUSH` frames carry.
    pub fn version(&self) -> u64 {
        self.version
    }

    /// Surrender the orchestrator (the multi-writer spawn dismantles it
    /// into per-band state).
    pub(crate) fn into_orchestrator(self) -> StreamOrchestrator {
        self.orch
    }

    /// Events buffered but not yet applied.
    pub fn buffered(&self) -> usize {
        self.orch.buffered()
    }

    /// The rating-scale clamp applied to predictions.
    pub fn clamp(&self) -> (f32, f32) {
        self.clamp
    }

    /// The engine's metric registry (shared with the concurrent server).
    pub fn metrics(&self) -> &Registry {
        &self.metrics
    }

    /// Predict the interaction value for (row, col).
    pub fn predict(&self, i: usize, j: usize) -> Option<f32> {
        let (m, n) = self.dims();
        if i >= m || j >= n {
            return None;
        }
        self.metrics.counter("engine.predict").inc();
        let mut scratch = NeighbourScratch::default();
        let raw = self
            .orch
            .model()
            .predict(self.orch.matrix(), i, j, &mut scratch);
        Some(raw.clamp(self.clamp.0, self.clamp.1))
    }

    /// Top-N highest-predicted unrated columns for a row. Requests up
    /// to [`MAX_TOPN_ITEMS`](super::protocol::MAX_TOPN_ITEMS) go
    /// through the per-row cache (the per-band truncation is lossless
    /// only up to that bound — exactly the server's `TOPN` limit);
    /// larger programmatic requests fall back to a full re-score.
    pub fn top_n(&self, i: usize, n_items: usize) -> Vec<(u32, f32)> {
        let (m, n) = self.dims();
        if i >= m {
            return Vec::new();
        }
        self.metrics.counter("engine.topn").inc();
        if n_items > super::protocol::MAX_TOPN_ITEMS {
            return rank_unrated(self.orch.model(), self.orch.matrix(), i, n_items, self.clamp);
        }
        let model = self.orch.model();
        let matrix = self.orch.matrix();
        let d = self.cache.nbands();
        let clamp = self.clamp;
        let mut scratch = NeighbourScratch::default();
        self.cache.top_n(self.version, i as u32, n_items, |b| {
            let (lo, hi) = band_range(b, n, d);
            band_candidates(matrix, i, lo, hi, |j| {
                model.predict(matrix, i, j, &mut scratch).clamp(clamp.0, clamp.1)
            })
        })
    }

    /// Batched prediction against one engine state (the `MPREDICT`
    /// verb). `None` if the row is out of range; per-column `None` for
    /// out-of-range columns.
    pub fn predict_many(&self, i: usize, cols: &[u32]) -> Option<Vec<Option<f32>>> {
        let (m, n) = self.dims();
        if i >= m {
            return None;
        }
        self.metrics.counter("engine.mpredict").inc();
        if let Some(hit) = self.cache.lookup_scores(self.version, i as u32, n, cols) {
            return Some(hit);
        }
        let mut scratch = NeighbourScratch::default();
        Some(predict_many_by(n, cols, |j| {
            self.orch
                .model()
                .predict(self.orch.matrix(), i, j, &mut scratch)
                .clamp(self.clamp.0, self.clamp.1)
        }))
    }

    /// Ingest a rating through the online path. With a persister
    /// attached the event is WAL-appended first — append-before-apply,
    /// so a checkpoint can never reflect an unlogged event (a rejected
    /// or invalid event logs too and re-rejects identically on replay).
    pub fn rate(&mut self, i: u32, j: u32, r: f32) -> IngestResult {
        if let Some(p) = &self.persist {
            let seq = p.alloc_seq();
            p.append_rate(j as usize % p.nbands(), seq, i, j, r);
        }
        let old = self.dims();
        let res = self.orch.ingest(Event::Rate(i, j, r));
        if let IngestResult::Flushed { applied } = res {
            self.note_flush(applied, old);
        }
        res
    }

    /// Vectorized ingest (the `MRATE` verb): the whole batch is
    /// validated and admitted as one unit, with backpressure capacity
    /// reserved once — see [`StreamOrchestrator::ingest_batch`]. One
    /// WAL record logs the whole batch under contiguous seqs.
    pub fn rate_many(&mut self, batch: &[(u32, u32, f32)]) -> IngestResult {
        if let Some(p) = &self.persist {
            if !batch.is_empty() {
                let base = p.alloc_seqs(batch.len() as u64);
                p.append_batch(batch[0].1 as usize % p.nbands(), base, batch);
            }
        }
        let old = self.dims();
        let res = self.orch.ingest_batch(batch);
        if let IngestResult::Flushed { applied } = res {
            self.note_flush(applied, old);
        }
        res
    }

    /// Force-apply buffered ratings. An explicit flush with work to do
    /// is logged as a WAL marker (replay must re-run it at the same
    /// point — the re-search draws from the RNG); empty flushes are
    /// no-ops on both sides and never logged.
    pub fn flush(&mut self) -> usize {
        if let Some(p) = &self.persist {
            if self.orch.buffered() > 0 {
                let seq = p.alloc_seq();
                p.append_flush(0, seq);
            }
        }
        let old = self.dims();
        let applied = self.orch.flush();
        self.note_flush(applied, old);
        applied
    }

    /// Bump the flush version and invalidate the Top-N cache off the
    /// flush report: dirty column bands + rated rows, or everything on
    /// growth (band boundaries shift when `ncols` changes, so band
    /// stamps stop describing the same columns).
    fn note_flush(&mut self, applied: usize, old_dims: (usize, usize)) {
        if applied == 0 {
            return;
        }
        self.version += 1;
        let dims = self.dims();
        let grew = dims != old_dims;
        let dirty: Vec<u32> = if grew {
            Vec::new()
        } else {
            let mut bands: Vec<u32> = dirty_bands(
                self.orch.last_flush_cols(),
                self.orch.last_flush_topk_moved(),
                dims.1,
                self.cache.nbands(),
            )
            .into_iter()
            .map(|b| b as u32)
            .collect();
            bands.sort_unstable();
            bands
        };
        self.cache.invalidate(self.version, &dirty, self.orch.last_flush_rows(), grew);
        if let Some(p) = self.persist.clone() {
            p.on_flush(self);
        }
    }

    /// Metrics snapshot (server `STATS` verb).
    pub fn stats(&self) -> String {
        let (m, n) = self.dims();
        format!(
            "dims {m}x{n}\nbuffered {}\n{}",
            self.orch.buffered(),
            self.metrics.snapshot()
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::stream::{StreamConfig, StreamOrchestrator};
    use crate::lsh::{OnlineHashState, SimLsh};
    use crate::mf::neighbourhood::{train_culsh_logged, CulshConfig};
    use crate::rng::Rng;
    use crate::sparse::{Csc, Csr, Triples};

    fn engine(rng: &mut Rng) -> Engine {
        let (m, n) = (30, 15);
        let mut t = Triples::new(m, n);
        let mut seen = std::collections::HashSet::new();
        while t.nnz() < 180 {
            let (i, j) = (rng.below(m), rng.below(n));
            if seen.insert((i, j)) {
                t.push(i, j, 1.0 + rng.f32() * 4.0);
            }
        }
        let csr = Csr::from_triples(&t);
        let csc = Csc::from_triples(&t);
        let lsh = SimLsh::new(2, 5, 8, 2);
        let hash_state = OnlineHashState::build(lsh, &csc);
        let (topk, _) = hash_state.topk(4, rng);
        let cfg = CulshConfig { f: 4, k: 4, epochs: 5, ..Default::default() };
        let (model, _) = train_culsh_logged(&csr, topk, &cfg, rng);
        let orch = StreamOrchestrator::new(
            model,
            hash_state,
            t,
            StreamConfig { batch_size: 4, ..Default::default() },
            cfg,
            rng.split(1),
            Registry::new(),
        );
        Engine::new(orch, (1.0, 5.0), Registry::new())
    }

    #[test]
    fn predictions_are_clamped_and_bounded() {
        let mut rng = Rng::seeded(61);
        let e = engine(&mut rng);
        for i in 0..30 {
            for j in 0..15 {
                let p = e.predict(i, j).unwrap();
                assert!((1.0..=5.0).contains(&p));
            }
        }
        assert!(e.predict(99, 0).is_none());
        assert!(e.predict(0, 99).is_none());
    }

    #[test]
    fn top_n_excludes_rated_and_is_sorted() {
        let mut rng = Rng::seeded(62);
        let e = engine(&mut rng);
        let rated: std::collections::HashSet<usize> =
            e.orch.matrix().row(3).map(|(j, _)| j).collect();
        let recs = e.top_n(3, 5);
        assert!(recs.len() <= 5);
        for win in recs.windows(2) {
            assert!(win[0].1 >= win[1].1);
        }
        for (j, _) in &recs {
            assert!(!rated.contains(&(*j as usize)));
        }
    }

    /// Regression: a NaN-producing model state (poisoned bias) must not
    /// panic the ranking — `partial_cmp().unwrap()` panicked the
    /// connection thread — and NaN scores must never lead the reply.
    #[test]
    fn rank_survives_nan_scores() {
        let mut rng = Rng::seeded(64);
        let e = engine(&mut rng);
        let mut model = e.orch.model().clone();
        model.base.bj[0] = f32::NAN;
        model.base.bi[2] = f32::NAN;
        let recs = rank_unrated(&model, e.orch.matrix(), 2, 5, (1.0, 5.0));
        assert!(recs.len() <= 5);
        // every unrated column scored NaN for row 2; ties broken by id
        for win in recs.windows(2) {
            assert!(win[0].0 < win[1].0);
        }
        // a single NaN column among finite scores sinks to the tail
        let recs = rank_unrated(&model, e.orch.matrix(), 3, 15, (1.0, 5.0));
        assert!(!recs.is_empty());
        for win in recs.windows(2) {
            assert!(
                !win[0].1.is_nan() || win[1].1.is_nan(),
                "NaN score ranked above a finite one: {recs:?}"
            );
        }
    }

    #[test]
    fn predict_many_matches_predict() {
        let mut rng = Rng::seeded(65);
        let e = engine(&mut rng);
        let cols: Vec<u32> = vec![0, 3, 7, 99, 14];
        let got = e.predict_many(2, &cols).unwrap();
        for (&j, p) in cols.iter().zip(&got) {
            assert_eq!(*p, e.predict(2, j as usize), "col {j}");
        }
        assert_eq!(got[3], None, "out-of-range column maps to None");
        assert!(e.predict_many(99, &cols).is_none(), "out-of-range row");
    }

    /// The cached read path must be bit-identical to the full re-score,
    /// cold and warm, across re-rates and universe growth.
    #[test]
    fn cached_top_n_is_bit_identical_to_full_rescore() {
        let mut rng = Rng::seeded(66);
        let mut e = engine(&mut rng);
        for round in 0..6u32 {
            for i in [0usize, 3, 7] {
                let cached = e.top_n(i, 10);
                let oracle = rank_unrated(e.orch.model(), e.orch.matrix(), i, 10, e.clamp);
                assert_eq!(
                    cached.iter().map(|(j, s)| (*j, s.to_bits())).collect::<Vec<_>>(),
                    oracle.iter().map(|(j, s)| (*j, s.to_bits())).collect::<Vec<_>>(),
                    "round {round} row {i}"
                );
                let warm = e.top_n(i, 10);
                assert_eq!(warm, cached, "warm re-read drifted (round {round} row {i})");
            }
            // Mutate between rounds: in-range re-rates first, then growth.
            let j = if round >= 4 { 14 + round } else { rng.below(15) as u32 };
            e.rate(rng.below(30) as u32, j, 1.0 + rng.f32() * 4.0);
            e.flush();
        }
        let (hits, misses, _) = e.cache.counts();
        assert!(hits > 0, "warm re-reads must hit the cache");
        assert!(misses > 0, "cold reads must miss the cache");
    }

    #[test]
    fn rate_flush_expands_universe() {
        let mut rng = Rng::seeded(63);
        let mut e = engine(&mut rng);
        assert!(e.predict(0, 20).is_none());
        e.rate(0, 20, 5.0);
        e.flush();
        let p = e.predict(0, 20).unwrap();
        assert!((1.0..=5.0).contains(&p));
        assert!(e.stats().contains("dims 30x21"));
    }
}
