//! The typed wire protocol: one [`Request`] / [`Response`] pair over two
//! interchangeable codecs.
//!
//! Before this module the wire API lived as ad-hoc string matching
//! inside `server.rs:handle_line` — one verb per round-trip, replies
//! hand-formatted at every call site. Following the cuMF line of work
//! (Tan et al.), where transfer-format and batching design decide
//! end-to-end throughput as much as kernel speed, the protocol is now a
//! first-class layer:
//!
//! * [`Request`] / [`Response`] are the single source of truth for the
//!   protocol surface. The server parses a wire message into a
//!   `Request` exactly once, dispatches it generically over the
//!   [`Serving`](super::server::Serving) trait, and encodes the typed
//!   `Response` back — a new verb is added in exactly one place.
//! * The **text codec** is the original line protocol, kept
//!   wire-compatible byte for byte (`PREDICT 3 7\n` → `PRED 3.4000\n`):
//!   every reply string existing clients or tests depend on is produced
//!   by [`Response::encode_text`], and round-trip property tests in
//!   `tests/props.rs` pin `parse_text ∘ encode_text = id`.
//! * The **binary codec** is a length-prefixed frame format that
//!   supports *pipelining*: many requests in flight per connection,
//!   each response tagged with its request's sequence id. A frame is
//!   `[0xB1][opcode u8][seq u32 le][len u32 le][payload]`; the first
//!   byte can never be the start of a text verb (all verbs are ASCII
//!   uppercase), so a server in `auto` codec mode detects the codec per
//!   connection from the first byte.
//! * [`ErrorKind`] types every protocol error — out-of-range,
//!   too-many-cols, backpressure, invalid-value, out-of-bounds, unknown
//!   verb, malformed frame, … — with one text form and one binary code
//!   per kind, so error handling is uniform across codecs and serving
//!   flavours.
//!
//! Batch ingest rides on [`Request::MRate`]: up to [`MAX_MRATE_EVENTS`]
//! ratings per message, validated and admitted as one unit (backpressure
//! capacity is reserved once per batch — see
//! [`StreamOrchestrator::ingest_batch`](super::stream::StreamOrchestrator::ingest_batch)).
//!
//! The client side of this layer lives in [`super::client`].
//!
//! # Invariants
//!
//! (Machine-checked: `cargo run -p lshmf-check` verifies both encoders
//! and the server dispatch stay exhaustive over these enums.)
//!
//! * **One decode, one dispatch, one encode.** Every wire message
//!   becomes a [`Request`] exactly once and every reply is an encoded
//!   [`Response`]; reply semantics live in the server's single
//!   `dispatch`, never per codec or per serving flavour.
//! * **Both codecs are total inverses on the protocol surface**:
//!   `parse_text ∘ encode_text = id` and `decode_frame ∘ encode_frame =
//!   id`, property-tested over randomized requests, responses and every
//!   [`ErrorKind`] wire form (`tests/props.rs`).
//! * **Resource caps are parse-time.** A binary frame's payload is
//!   capped at [`MAX_FRAME_PAYLOAD`] (1 MiB) before any allocation; a
//!   text request line is capped symmetrically at 64 KiB by the server's
//!   bounded line reader (`server::MAX_TEXT_LINE_BYTES`); per-verb item
//!   caps ([`MAX_MPREDICT_COLS`], [`MAX_TOPN_ITEMS`],
//!   [`MAX_MRATE_EVENTS`]) bound the work one request can demand.
//! * **Replies are seq-correlated, not order-correlated.** Every
//!   pipelined binary response carries its request's sequence id, and
//!   that tag — not arrival order — is the correlation key. Writes
//!   (`RATE`/`MRATE`/`FLUSH`/`SHUTDOWN`) execute, and are answered, in
//!   arrival order per connection; reads (`PREDICT`/`MPREDICT`/`TOPN`/
//!   `STATS`) dispatch concurrently and their replies may overtake the
//!   reply to an earlier frame. Clients must match replies by seq (the
//!   bundled `Pipeline` reorders transparently). The client's
//!   `Pipeline` still bounds its in-flight window so both TCP
//!   directions can always drain (`client::PIPELINE_WINDOW`).
//! * **Push frames are server-initiated and carry [`PUSH_SEQ`].** On a
//!   `SUBSCRIBE`d binary connection a [`Response::Push`] frame — the
//!   published snapshot version plus the dirty column-band set — may
//!   appear between any two replies. The reserved sequence id keeps
//!   push frames disjoint from request/reply correlation; clients must
//!   never send a request tagged [`PUSH_SEQ`].

use super::stream::IngestResult;
use std::io::{self, Read};

/// Most columns one `MPREDICT` request may score. Bounds the work and
/// allocation a single request can demand — the read-side analogue of
/// the `RATE` path's `max_rows`/`max_cols` hardening.
pub const MAX_MPREDICT_COLS: usize = 256;

/// Most items one `TOPN` request may ask for. Oversized `n` used to be
/// silently satisfied (scoring every column); it is now a typed
/// [`ErrorKind::TooManyItems`] error.
pub const MAX_TOPN_ITEMS: usize = 256;

/// Most ratings one `MRATE` batch may carry.
pub const MAX_MRATE_EVENTS: usize = 256;

/// First byte of every binary frame. Deliberately ≥ 0x80: no text verb
/// (ASCII uppercase) can start with it, so codec auto-detection needs
/// exactly one byte.
pub const BINARY_FRAME_BYTE: u8 = 0xB1;

/// Hard ceiling on a binary frame's payload length. A frame announcing
/// more is malformed — the decoder must never allocate unbounded memory
/// on behalf of one length field.
pub const MAX_FRAME_PAYLOAD: usize = 1 << 20;

/// Usage strings, shared by the text parser and the dispatcher so both
/// codecs report identical [`ErrorKind::Usage`] errors.
pub const PREDICT_USAGE: &str = "PREDICT <row> <col>";
pub const MPREDICT_USAGE: &str = "MPREDICT <row> <col> [<col> ...]";
pub const TOPN_USAGE: &str = "TOPN <row> <n>";
pub const RATE_USAGE: &str = "RATE <row> <col> <value>";
pub const MRATE_USAGE: &str = "MRATE <row> <col> <value> [<row> <col> <value> ...]";
pub const SUBSCRIBE_USAGE: &str = "SUBSCRIBE (binary-codec connections only)";

/// Reserved sequence id of server-initiated [`Response::Push`] frames.
/// Requests must never carry it: the client's seq allocator skips it,
/// so push frames can be told apart from replies by seq alone.
pub const PUSH_SEQ: u32 = u32::MAX;

/// Which codec a server endpoint speaks.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CodecChoice {
    /// Text line protocol only.
    Text,
    /// Binary framed protocol only (a text greeting is a malformed frame).
    Binary,
    /// Detect per connection from the first byte (the default):
    /// [`BINARY_FRAME_BYTE`] → binary, anything else → text.
    Auto,
}

impl CodecChoice {
    pub fn name(self) -> &'static str {
        match self {
            CodecChoice::Text => "text",
            CodecChoice::Binary => "binary",
            CodecChoice::Auto => "auto",
        }
    }
}

/// A parsed protocol request — every verb of the serving API.
#[derive(Clone, Debug, PartialEq)]
pub enum Request {
    /// `PREDICT <row> <col>`
    Predict { row: usize, col: usize },
    /// `MPREDICT <row> <col> [<col> ...]` — batched prediction against
    /// one consistent snapshot.
    MPredict { row: usize, cols: Vec<u32> },
    /// `TOPN <row> <n>` — top-n unrated columns (`1 ≤ n ≤ MAX_TOPN_ITEMS`).
    TopN { row: usize, n: usize },
    /// `RATE <row> <col> <value>` — single-event online ingest.
    Rate { row: u32, col: u32, value: f32 },
    /// `MRATE <row> <col> <value> ...` — batch ingest, admitted as one
    /// unit (validation and backpressure reservation happen once for
    /// the whole batch).
    MRate { ratings: Vec<(u32, u32, f32)> },
    /// `FLUSH` — force-apply buffered ratings.
    Flush,
    /// `STATS` — metrics snapshot.
    Stats,
    /// `SUBSCRIBE` — request push-invalidation frames on this
    /// connection. Binary codec only: the server answers
    /// [`Response::Subscribed`] and thereafter emits a
    /// [`Response::Push`] frame (seq [`PUSH_SEQ`]) at every snapshot
    /// publish. On a text connection the verb parses but dispatch
    /// answers a [`ErrorKind::Usage`] error — the line protocol has no
    /// frame to interleave pushes on.
    Subscribe,
    /// `QUIT` / `SHUTDOWN` — close the connection (binary connections
    /// receive a [`Response::Bye`] ack first).
    Shutdown,
}

/// Typed protocol errors. One text form and one binary code per kind;
/// both codecs and all serving flavours report errors through this enum.
#[derive(Clone, Debug, PartialEq)]
pub enum ErrorKind {
    /// Row (or row+col) outside the served universe.
    OutOfRange,
    /// `MPREDICT` with more than [`MAX_MPREDICT_COLS`] columns.
    TooManyCols,
    /// `TOPN` asking for more than [`MAX_TOPN_ITEMS`] items.
    TooManyItems,
    /// `MRATE` with more than [`MAX_MRATE_EVENTS`] ratings.
    TooManyEvents,
    /// Ingest queue full (`reject_when_full` backpressure).
    Backpressure,
    /// Non-finite rating value.
    InvalidValue,
    /// Rating ids at or beyond the configured `max_rows`/`max_cols`.
    OutOfBounds,
    /// Empty request line.
    Empty,
    /// Admission control refused the request: the client is over its
    /// per-connection rate limit, or the server is shedding read load
    /// (`TOPN`/`MPREDICT` shed first). Back off and retry.
    Overloaded,
    /// The backend holding the requested partition is down or
    /// unreachable (route tier only: a monolithic `serve` never emits
    /// it). Transient by design — the router's probe loop keeps trying
    /// to reconnect, so back off and retry.
    Unavailable,
    /// Unrecognized verb (text) or opcode (binary).
    UnknownVerb(String),
    /// Malformed arguments; carries the verb's usage string.
    Usage(String),
    /// Unreadable binary frame (bad frame byte, truncated or oversized
    /// frame, undecodable payload). Fatal per connection: framing is
    /// lost, so the server replies once and closes.
    MalformedFrame(String),
}

impl ErrorKind {
    /// The text wire form (the exact legacy `ERR …` strings).
    pub fn to_line(&self) -> String {
        match self {
            ErrorKind::OutOfRange => "ERR out-of-range".into(),
            ErrorKind::TooManyCols => "ERR too-many-cols".into(),
            ErrorKind::TooManyItems => "ERR too-many-items".into(),
            ErrorKind::TooManyEvents => "ERR too-many-events".into(),
            ErrorKind::Backpressure => "ERR backpressure".into(),
            ErrorKind::InvalidValue => "ERR invalid-value".into(),
            ErrorKind::OutOfBounds => "ERR out-of-bounds".into(),
            ErrorKind::Empty => "ERR empty".into(),
            ErrorKind::Overloaded => "ERR overloaded".into(),
            ErrorKind::Unavailable => "ERR unavailable".into(),
            ErrorKind::UnknownVerb(verb) => format!("ERR unknown verb `{verb}`"),
            ErrorKind::Usage(usage) => format!("ERR usage: {usage}"),
            ErrorKind::MalformedFrame(detail) => format!("ERR malformed-frame: {detail}"),
        }
    }

    /// Inverse of [`ErrorKind::to_line`]; `None` if `line` is not an
    /// `ERR` form this layer produces.
    pub fn parse_line(line: &str) -> Option<ErrorKind> {
        let body = line.strip_prefix("ERR ")?;
        Some(match body {
            "out-of-range" => ErrorKind::OutOfRange,
            "too-many-cols" => ErrorKind::TooManyCols,
            "too-many-items" => ErrorKind::TooManyItems,
            "too-many-events" => ErrorKind::TooManyEvents,
            "backpressure" => ErrorKind::Backpressure,
            "invalid-value" => ErrorKind::InvalidValue,
            "out-of-bounds" => ErrorKind::OutOfBounds,
            "empty" => ErrorKind::Empty,
            "overloaded" => ErrorKind::Overloaded,
            "unavailable" => ErrorKind::Unavailable,
            _ => {
                if let Some(usage) = body.strip_prefix("usage: ") {
                    ErrorKind::Usage(usage.to_string())
                } else if let Some(detail) = body.strip_prefix("malformed-frame: ") {
                    ErrorKind::MalformedFrame(detail.to_string())
                } else if let Some(verb) = body
                    .strip_prefix("unknown verb `")
                    .and_then(|v| v.strip_suffix('`'))
                {
                    ErrorKind::UnknownVerb(verb.to_string())
                } else {
                    return None;
                }
            }
        })
    }

    /// The binary wire code (payload byte 0 of an error response).
    fn code(&self) -> u8 {
        match self {
            ErrorKind::OutOfRange => 1,
            ErrorKind::TooManyCols => 2,
            ErrorKind::TooManyItems => 3,
            ErrorKind::TooManyEvents => 4,
            ErrorKind::Backpressure => 5,
            ErrorKind::InvalidValue => 6,
            ErrorKind::OutOfBounds => 7,
            ErrorKind::Empty => 8,
            ErrorKind::UnknownVerb(_) => 9,
            ErrorKind::Usage(_) => 10,
            ErrorKind::MalformedFrame(_) => 11,
            ErrorKind::Overloaded => 12,
            ErrorKind::Unavailable => 13,
        }
    }

    /// The detail string carried after the code byte (empty for
    /// detail-free kinds).
    fn detail(&self) -> &str {
        // Exhaustive on purpose: a new detail-carrying kind must name
        // itself here or fail to compile, instead of silently encoding
        // an empty payload through a `_` arm.
        match self {
            ErrorKind::UnknownVerb(s) | ErrorKind::Usage(s) | ErrorKind::MalformedFrame(s) => s,
            ErrorKind::OutOfRange
            | ErrorKind::TooManyCols
            | ErrorKind::TooManyItems
            | ErrorKind::TooManyEvents
            | ErrorKind::Backpressure
            | ErrorKind::InvalidValue
            | ErrorKind::OutOfBounds
            | ErrorKind::Empty
            | ErrorKind::Overloaded
            | ErrorKind::Unavailable => "",
        }
    }

    fn from_code(code: u8, detail: String) -> Option<ErrorKind> {
        Some(match code {
            1 => ErrorKind::OutOfRange,
            2 => ErrorKind::TooManyCols,
            3 => ErrorKind::TooManyItems,
            4 => ErrorKind::TooManyEvents,
            5 => ErrorKind::Backpressure,
            6 => ErrorKind::InvalidValue,
            7 => ErrorKind::OutOfBounds,
            8 => ErrorKind::Empty,
            9 => ErrorKind::UnknownVerb(detail),
            10 => ErrorKind::Usage(detail),
            11 => ErrorKind::MalformedFrame(detail),
            12 => ErrorKind::Overloaded,
            13 => ErrorKind::Unavailable,
            _ => return None,
        })
    }
}

/// The non-error body of an ingest reply (`RATE` / `MRATE` / `FLUSH`).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum OkBody {
    /// Accepted into the buffer; will apply at the next flush.
    Buffered,
    /// A flush ran; `applied` events landed in the model.
    Flushed { applied: u64 },
    /// The request carried nothing to ingest (empty batch): nothing was
    /// buffered and nothing was applied — both write paths answer this
    /// identically.
    Ignored,
}

/// A typed protocol response.
#[derive(Clone, Debug, PartialEq)]
pub enum Response {
    /// `PRED <value>` — a single clamped prediction.
    Pred(f32),
    /// `PREDS <v|-> ...` — batched predictions; `None` per
    /// out-of-range column.
    Preds(Vec<Option<f32>>),
    /// `TOPN <col>:<score> ...`
    TopN(Vec<(u32, f32)>),
    /// `OK buffered` | `OK flushed <n>` | `OK ignored`.
    Ok(OkBody),
    /// Multi-line stats body, text-terminated by `END`.
    Stats(String),
    /// `SUBSCRIBED <version>` — ack for [`Request::Subscribe`],
    /// carrying the currently-published snapshot version so the client
    /// can seed its cache validity.
    Subscribed { version: u64 },
    /// `PUSH <version> [<band> ...]` — server-initiated invalidation:
    /// snapshot `version` was published and the listed column bands
    /// changed (an empty list means *everything* changed — growth).
    /// On the wire it is only ever sent as a binary frame tagged
    /// [`PUSH_SEQ`]; the text form exists so every `Response`
    /// round-trips on both codecs.
    Push { version: u64, dirty: Vec<u32> },
    /// `ERR …` — any [`ErrorKind`].
    Error(ErrorKind),
    /// Shutdown ack. Binary connections receive it before the server
    /// closes; text connections close silently on `QUIT` (legacy wire
    /// behaviour), so `BYE` never appears on a text socket.
    Bye,
}

/// Map an ingest outcome onto the wire.
impl From<IngestResult> for Response {
    fn from(result: IngestResult) -> Response {
        match result {
            IngestResult::Buffered => Response::Ok(OkBody::Buffered),
            IngestResult::Flushed { applied } => {
                Response::Ok(OkBody::Flushed { applied: applied as u64 })
            }
            IngestResult::Rejected => Response::Error(ErrorKind::Backpressure),
            IngestResult::InvalidValue => Response::Error(ErrorKind::InvalidValue),
            IngestResult::OutOfBounds => Response::Error(ErrorKind::OutOfBounds),
            IngestResult::Ignored => Response::Ok(OkBody::Ignored),
        }
    }
}

fn parse<T: std::str::FromStr>(s: Option<&str>) -> Option<T> {
    s.and_then(|x| x.parse().ok())
}

impl Request {
    /// Parse one text protocol line. Exactly the legacy `handle_line`
    /// grammar: unknown trailing tokens on fixed-arity verbs are
    /// ignored, `MPREDICT` caps its column list while parsing (a flood
    /// line cannot demand unbounded allocation), and every malformed
    /// form maps to the same `ERR` reply the string matcher produced.
    pub fn parse_text(line: &str) -> Result<Request, ErrorKind> {
        let mut parts = line.split_whitespace();
        let verb = parts.next().unwrap_or("");
        match verb {
            "PREDICT" => {
                let (Some(row), Some(col)) = (parse(parts.next()), parse(parts.next())) else {
                    return Err(ErrorKind::Usage(PREDICT_USAGE.into()));
                };
                Ok(Request::Predict { row, col })
            }
            "MPREDICT" => {
                let Some(row) = parse::<usize>(parts.next()) else {
                    return Err(ErrorKind::Usage(MPREDICT_USAGE.into()));
                };
                let mut cols: Vec<u32> = Vec::new();
                for p in parts {
                    if cols.len() >= MAX_MPREDICT_COLS {
                        return Err(ErrorKind::TooManyCols);
                    }
                    match p.parse::<u32>() {
                        Ok(j) => cols.push(j),
                        Err(_) => return Err(ErrorKind::Usage(MPREDICT_USAGE.into())),
                    }
                }
                if cols.is_empty() {
                    return Err(ErrorKind::Usage(MPREDICT_USAGE.into()));
                }
                Ok(Request::MPredict { row, cols })
            }
            "TOPN" => {
                let (Some(row), Some(n)) = (parse(parts.next()), parse(parts.next())) else {
                    return Err(ErrorKind::Usage(TOPN_USAGE.into()));
                };
                Ok(Request::TopN { row, n })
            }
            "RATE" => {
                let (Some(row), Some(col), Some(value)) = (
                    parse::<u32>(parts.next()),
                    parse::<u32>(parts.next()),
                    parse::<f32>(parts.next()),
                ) else {
                    return Err(ErrorKind::Usage(RATE_USAGE.into()));
                };
                Ok(Request::Rate { row, col, value })
            }
            "MRATE" => {
                let mut ratings: Vec<(u32, u32, f32)> = Vec::new();
                let mut parts = parts.peekable();
                while parts.peek().is_some() {
                    if ratings.len() >= MAX_MRATE_EVENTS {
                        return Err(ErrorKind::TooManyEvents);
                    }
                    let (Some(i), Some(j), Some(r)) = (
                        parse::<u32>(parts.next()),
                        parse::<u32>(parts.next()),
                        parse::<f32>(parts.next()),
                    ) else {
                        return Err(ErrorKind::Usage(MRATE_USAGE.into()));
                    };
                    ratings.push((i, j, r));
                }
                if ratings.is_empty() {
                    return Err(ErrorKind::Usage(MRATE_USAGE.into()));
                }
                Ok(Request::MRate { ratings })
            }
            "FLUSH" => Ok(Request::Flush),
            "STATS" => Ok(Request::Stats),
            "SUBSCRIBE" => Ok(Request::Subscribe),
            "QUIT" | "SHUTDOWN" => Ok(Request::Shutdown),
            "" => Err(ErrorKind::Empty),
            other => Err(ErrorKind::UnknownVerb(other.to_string())),
        }
    }

    /// Encode as one text protocol line (no trailing newline). Floats
    /// use `Display`, whose shortest-round-trip form re-parses to the
    /// identical bits, so `parse_text ∘ encode_text = id` for every
    /// finite-valued request.
    pub fn encode_text(&self) -> String {
        match self {
            Request::Predict { row, col } => format!("PREDICT {row} {col}"),
            Request::MPredict { row, cols } => {
                let mut s = format!("MPREDICT {row}");
                for j in cols {
                    s.push(' ');
                    s.push_str(&j.to_string());
                }
                s
            }
            Request::TopN { row, n } => format!("TOPN {row} {n}"),
            Request::Rate { row, col, value } => format!("RATE {row} {col} {value}"),
            Request::MRate { ratings } => {
                let mut s = String::from("MRATE");
                for (i, j, r) in ratings {
                    s.push_str(&format!(" {i} {j} {r}"));
                }
                s
            }
            Request::Flush => "FLUSH".into(),
            Request::Stats => "STATS".into(),
            Request::Subscribe => "SUBSCRIBE".into(),
            Request::Shutdown => "QUIT".into(),
        }
    }

    /// Encode as one binary frame (header + payload).
    pub fn encode_frame(&self, seq: u32) -> Vec<u8> {
        let mut payload = Vec::new();
        let opcode = match self {
            Request::Predict { row, col } => {
                put_u64(&mut payload, *row as u64);
                put_u64(&mut payload, *col as u64);
                op::PREDICT
            }
            Request::MPredict { row, cols } => {
                put_u64(&mut payload, *row as u64);
                put_u32(&mut payload, cols.len() as u32);
                for j in cols {
                    put_u32(&mut payload, *j);
                }
                op::MPREDICT
            }
            Request::TopN { row, n } => {
                put_u64(&mut payload, *row as u64);
                put_u64(&mut payload, *n as u64);
                op::TOPN
            }
            Request::Rate { row, col, value } => {
                put_u32(&mut payload, *row);
                put_u32(&mut payload, *col);
                put_f32(&mut payload, *value);
                op::RATE
            }
            Request::MRate { ratings } => {
                put_u32(&mut payload, ratings.len() as u32);
                for (i, j, r) in ratings {
                    put_u32(&mut payload, *i);
                    put_u32(&mut payload, *j);
                    put_f32(&mut payload, *r);
                }
                op::MRATE
            }
            Request::Flush => op::FLUSH,
            Request::Stats => op::STATS,
            Request::Subscribe => op::SUBSCRIBE,
            Request::Shutdown => op::SHUTDOWN,
        };
        frame(opcode, seq, payload)
    }

    /// Decode a binary request frame. Count fields are validated against
    /// both the protocol caps and the actual payload length before any
    /// allocation.
    pub fn decode_frame(f: &Frame) -> Result<Request, ErrorKind> {
        let mut c = Cur::new(&f.payload);
        let req = match f.opcode {
            op::PREDICT => Request::Predict {
                row: c.u64().ok_or_else(|| malformed("PREDICT"))? as usize,
                col: c.u64().ok_or_else(|| malformed("PREDICT"))? as usize,
            },
            op::MPREDICT => {
                let row = c.u64().ok_or_else(|| malformed("MPREDICT"))? as usize;
                let count = c.u32().ok_or_else(|| malformed("MPREDICT"))? as usize;
                if count > MAX_MPREDICT_COLS {
                    return Err(ErrorKind::TooManyCols);
                }
                if count * 4 > c.remaining() {
                    return Err(malformed("MPREDICT"));
                }
                let mut cols = Vec::with_capacity(count);
                for _ in 0..count {
                    cols.push(c.u32().ok_or_else(|| malformed("MPREDICT"))?);
                }
                Request::MPredict { row, cols }
            }
            op::TOPN => Request::TopN {
                row: c.u64().ok_or_else(|| malformed("TOPN"))? as usize,
                n: c.u64().ok_or_else(|| malformed("TOPN"))? as usize,
            },
            op::RATE => Request::Rate {
                row: c.u32().ok_or_else(|| malformed("RATE"))?,
                col: c.u32().ok_or_else(|| malformed("RATE"))?,
                value: c.f32().ok_or_else(|| malformed("RATE"))?,
            },
            op::MRATE => {
                let count = c.u32().ok_or_else(|| malformed("MRATE"))? as usize;
                if count > MAX_MRATE_EVENTS {
                    return Err(ErrorKind::TooManyEvents);
                }
                if count * 12 > c.remaining() {
                    return Err(malformed("MRATE"));
                }
                let mut ratings = Vec::with_capacity(count);
                for _ in 0..count {
                    let i = c.u32().ok_or_else(|| malformed("MRATE"))?;
                    let j = c.u32().ok_or_else(|| malformed("MRATE"))?;
                    let r = c.f32().ok_or_else(|| malformed("MRATE"))?;
                    ratings.push((i, j, r));
                }
                Request::MRate { ratings }
            }
            op::FLUSH => Request::Flush,
            op::STATS => Request::Stats,
            op::SUBSCRIBE => Request::Subscribe,
            op::SHUTDOWN => Request::Shutdown,
            other => return Err(ErrorKind::UnknownVerb(format!("opcode {other:#04x}"))),
        };
        if !c.done() {
            return Err(ErrorKind::MalformedFrame("trailing payload bytes".into()));
        }
        Ok(req)
    }
}

impl Response {
    /// Encode as text — the exact legacy reply strings (`{:.4}` floats,
    /// `-` placeholders, `END`-terminated stats).
    pub fn encode_text(&self) -> String {
        match self {
            Response::Pred(p) => format!("PRED {p:.4}"),
            Response::Preds(preds) => {
                let body: Vec<String> = preds
                    .iter()
                    .map(|p| match p {
                        Some(v) => format!("{v:.4}"),
                        None => "-".into(),
                    })
                    .collect();
                format!("PREDS {}", body.join(" "))
            }
            Response::TopN(recs) => {
                let body: Vec<String> =
                    recs.iter().map(|(j, s)| format!("{j}:{s:.4}")).collect();
                format!("TOPN {}", body.join(" "))
            }
            Response::Ok(OkBody::Buffered) => "OK buffered".into(),
            Response::Ok(OkBody::Flushed { applied }) => format!("OK flushed {applied}"),
            Response::Ok(OkBody::Ignored) => "OK ignored".into(),
            Response::Stats(body) => format!("{body}END"),
            Response::Subscribed { version } => format!("SUBSCRIBED {version}"),
            Response::Push { version, dirty } => {
                let mut s = format!("PUSH {version}");
                for b in dirty {
                    s.push(' ');
                    s.push_str(&b.to_string());
                }
                s
            }
            Response::Error(kind) => kind.to_line(),
            // Never sent on a text socket (QUIT closes silently); the
            // form exists so every Response round-trips on both codecs.
            Response::Bye => "BYE".into(),
        }
    }

    /// Decode a text reply. For `STATS`, pass the full multi-line body
    /// including the trailing `END` (the client accumulates lines until
    /// the terminator — see [`super::client`]).
    pub fn decode_text(text: &str) -> Result<Response, String> {
        if let Some(rest) = text.strip_prefix("PRED ") {
            let v: f32 = rest.parse().map_err(|_| format!("bad PRED value `{rest}`"))?;
            return Ok(Response::Pred(v));
        }
        if let Some(rest) = text.strip_prefix("PREDS") {
            let mut preds = Vec::new();
            for tok in rest.split_whitespace() {
                if tok == "-" {
                    preds.push(None);
                } else {
                    let v: f32 =
                        tok.parse().map_err(|_| format!("bad PREDS value `{tok}`"))?;
                    preds.push(Some(v));
                }
            }
            return Ok(Response::Preds(preds));
        }
        if let Some(rest) = text.strip_prefix("TOPN") {
            let mut recs = Vec::new();
            for tok in rest.split_whitespace() {
                let (j, s) = tok
                    .split_once(':')
                    .ok_or_else(|| format!("bad TOPN entry `{tok}`"))?;
                let j: u32 = j.parse().map_err(|_| format!("bad TOPN col `{tok}`"))?;
                let s: f32 = s.parse().map_err(|_| format!("bad TOPN score `{tok}`"))?;
                recs.push((j, s));
            }
            return Ok(Response::TopN(recs));
        }
        if text == "OK buffered" {
            return Ok(Response::Ok(OkBody::Buffered));
        }
        if text == "OK ignored" {
            return Ok(Response::Ok(OkBody::Ignored));
        }
        if let Some(rest) = text.strip_prefix("OK flushed ") {
            let applied: u64 =
                rest.parse().map_err(|_| format!("bad flush count `{rest}`"))?;
            return Ok(Response::Ok(OkBody::Flushed { applied }));
        }
        if text == "BYE" {
            return Ok(Response::Bye);
        }
        if let Some(rest) = text.strip_prefix("SUBSCRIBED ") {
            let version: u64 =
                rest.parse().map_err(|_| format!("bad SUBSCRIBED version `{rest}`"))?;
            return Ok(Response::Subscribed { version });
        }
        if let Some(rest) = text.strip_prefix("PUSH ") {
            let mut toks = rest.split_whitespace();
            let version: u64 = toks
                .next()
                .and_then(|v| v.parse().ok())
                .ok_or_else(|| format!("bad PUSH version `{rest}`"))?;
            let mut dirty = Vec::new();
            for tok in toks {
                let b: u32 = tok.parse().map_err(|_| format!("bad PUSH band `{tok}`"))?;
                dirty.push(b);
            }
            return Ok(Response::Push { version, dirty });
        }
        if let Some(kind) = ErrorKind::parse_line(text) {
            return Ok(Response::Error(kind));
        }
        if let Some(body) = text.strip_suffix("END") {
            return Ok(Response::Stats(body.to_string()));
        }
        Err(format!("undecodable reply `{text}`"))
    }

    /// Encode as one binary frame tagged with the request's `seq`.
    pub fn encode_frame(&self, seq: u32) -> Vec<u8> {
        let mut payload = Vec::new();
        let opcode = match self {
            Response::Pred(v) => {
                put_f32(&mut payload, *v);
                op::R_PRED
            }
            Response::Preds(preds) => {
                put_u32(&mut payload, preds.len() as u32);
                for p in preds {
                    match p {
                        Some(v) => {
                            payload.push(1);
                            put_f32(&mut payload, *v);
                        }
                        None => payload.push(0),
                    }
                }
                op::R_PREDS
            }
            Response::TopN(recs) => {
                put_u32(&mut payload, recs.len() as u32);
                for (j, s) in recs {
                    put_u32(&mut payload, *j);
                    put_f32(&mut payload, *s);
                }
                op::R_TOPN
            }
            Response::Ok(OkBody::Buffered) => {
                payload.push(0);
                op::R_OK
            }
            Response::Ok(OkBody::Flushed { applied }) => {
                payload.push(1);
                put_u64(&mut payload, *applied);
                op::R_OK
            }
            Response::Ok(OkBody::Ignored) => {
                payload.push(2);
                op::R_OK
            }
            Response::Stats(body) => {
                payload.extend_from_slice(body.as_bytes());
                op::R_STATS
            }
            Response::Subscribed { version } => {
                put_u64(&mut payload, *version);
                op::R_SUBSCRIBED
            }
            Response::Push { version, dirty } => {
                put_u64(&mut payload, *version);
                put_u32(&mut payload, dirty.len() as u32);
                for b in dirty {
                    put_u32(&mut payload, *b);
                }
                op::R_PUSH
            }
            Response::Error(kind) => {
                payload.push(kind.code());
                payload.extend_from_slice(kind.detail().as_bytes());
                op::R_ERR
            }
            Response::Bye => op::R_BYE,
        };
        frame(opcode, seq, payload)
    }

    /// Decode a binary response frame (client side).
    pub fn decode_frame(f: &Frame) -> Result<Response, String> {
        let mut c = Cur::new(&f.payload);
        let short = || "truncated response payload".to_string();
        let resp = match f.opcode {
            op::R_PRED => Response::Pred(c.f32().ok_or_else(short)?),
            op::R_PREDS => {
                let count = c.u32().ok_or_else(short)? as usize;
                if count > c.remaining() {
                    return Err("PREDS count exceeds payload".into());
                }
                let mut preds = Vec::with_capacity(count);
                for _ in 0..count {
                    match c.u8().ok_or_else(short)? {
                        0 => preds.push(None),
                        1 => preds.push(Some(c.f32().ok_or_else(short)?)),
                        t => return Err(format!("bad PREDS tag {t}")),
                    }
                }
                Response::Preds(preds)
            }
            op::R_TOPN => {
                let count = c.u32().ok_or_else(short)? as usize;
                if count * 8 > c.remaining() {
                    return Err("TOPN count exceeds payload".into());
                }
                let mut recs = Vec::with_capacity(count);
                for _ in 0..count {
                    let j = c.u32().ok_or_else(short)?;
                    let s = c.f32().ok_or_else(short)?;
                    recs.push((j, s));
                }
                Response::TopN(recs)
            }
            op::R_OK => match c.u8().ok_or_else(short)? {
                0 => Response::Ok(OkBody::Buffered),
                1 => Response::Ok(OkBody::Flushed { applied: c.u64().ok_or_else(short)? }),
                2 => Response::Ok(OkBody::Ignored),
                t => return Err(format!("bad OK tag {t}")),
            },
            op::R_STATS => {
                let body = String::from_utf8(c.rest().to_vec())
                    .map_err(|_| "non-utf8 stats body".to_string())?;
                return Ok(Response::Stats(body));
            }
            op::R_ERR => {
                let code = c.u8().ok_or_else(short)?;
                let detail = String::from_utf8(c.rest().to_vec())
                    .map_err(|_| "non-utf8 error detail".to_string())?;
                return Ok(Response::Error(
                    ErrorKind::from_code(code, detail)
                        .ok_or_else(|| format!("bad error code {code}"))?,
                ));
            }
            op::R_SUBSCRIBED => Response::Subscribed { version: c.u64().ok_or_else(short)? },
            op::R_PUSH => {
                let version = c.u64().ok_or_else(short)?;
                let count = c.u32().ok_or_else(short)? as usize;
                if count * 4 > c.remaining() {
                    return Err("PUSH count exceeds payload".into());
                }
                let mut dirty = Vec::with_capacity(count);
                for _ in 0..count {
                    dirty.push(c.u32().ok_or_else(short)?);
                }
                Response::Push { version, dirty }
            }
            op::R_BYE => Response::Bye,
            other => return Err(format!("unknown response opcode {other:#04x}")),
        };
        if !c.done() {
            return Err("trailing response payload bytes".into());
        }
        Ok(resp)
    }
}

fn malformed(what: &str) -> ErrorKind {
    ErrorKind::MalformedFrame(format!("truncated {what} payload"))
}

/// Binary opcodes. Requests are < 0x80, responses ≥ 0x80.
mod op {
    pub const PREDICT: u8 = 0x01;
    pub const MPREDICT: u8 = 0x02;
    pub const TOPN: u8 = 0x03;
    pub const RATE: u8 = 0x04;
    pub const MRATE: u8 = 0x05;
    pub const FLUSH: u8 = 0x06;
    pub const STATS: u8 = 0x07;
    pub const SHUTDOWN: u8 = 0x08;
    pub const SUBSCRIBE: u8 = 0x09;

    pub const R_PRED: u8 = 0x81;
    pub const R_PREDS: u8 = 0x82;
    pub const R_TOPN: u8 = 0x83;
    pub const R_OK: u8 = 0x84;
    pub const R_STATS: u8 = 0x85;
    pub const R_ERR: u8 = 0x86;
    pub const R_BYE: u8 = 0x87;
    pub const R_SUBSCRIBED: u8 = 0x88;
    pub const R_PUSH: u8 = 0x89;
}

/// One decoded binary frame.
#[derive(Clone, Debug, PartialEq)]
pub struct Frame {
    pub opcode: u8,
    pub seq: u32,
    pub payload: Vec<u8>,
}

/// Assemble a full frame: `[0xB1][opcode][seq le][len le][payload]`.
fn frame(opcode: u8, seq: u32, payload: Vec<u8>) -> Vec<u8> {
    let mut out = Vec::with_capacity(10 + payload.len());
    out.push(BINARY_FRAME_BYTE);
    out.push(opcode);
    out.extend_from_slice(&seq.to_le_bytes());
    out.extend_from_slice(&(payload.len() as u32).to_le_bytes());
    out.extend_from_slice(&payload);
    out
}

/// Outcome of reading one frame off a stream.
#[derive(Debug)]
pub enum FrameRead {
    Frame(Frame),
    /// Clean EOF on the frame boundary (peer closed).
    Eof,
    /// Unreadable framing: bad frame byte, truncated header/payload, or
    /// an oversized length field. Framing is lost — the caller should
    /// report once and close.
    Malformed(String),
}

/// Read one binary frame. EOF *between* frames is a clean close; EOF
/// inside a frame is malformed.
pub fn read_frame(r: &mut impl Read) -> io::Result<FrameRead> {
    let mut magic = [0u8; 1];
    match r.read(&mut magic) {
        Ok(0) => return Ok(FrameRead::Eof),
        Ok(_) => {}
        Err(e) if e.kind() == io::ErrorKind::UnexpectedEof => return Ok(FrameRead::Eof),
        Err(e) => return Err(e),
    }
    if magic[0] != BINARY_FRAME_BYTE {
        return Ok(FrameRead::Malformed(format!(
            "bad frame byte {:#04x} (expected {BINARY_FRAME_BYTE:#04x})",
            magic[0]
        )));
    }
    let mut head = [0u8; 9];
    if !try_read_exact(r, &mut head)? {
        return Ok(FrameRead::Malformed("truncated frame header".into()));
    }
    let opcode = head[0];
    let seq = u32::from_le_bytes([head[1], head[2], head[3], head[4]]);
    let len = u32::from_le_bytes([head[5], head[6], head[7], head[8]]) as usize;
    if len > MAX_FRAME_PAYLOAD {
        return Ok(FrameRead::Malformed(format!(
            "oversized frame payload ({len} > {MAX_FRAME_PAYLOAD} bytes)"
        )));
    }
    let mut payload = vec![0u8; len];
    if !try_read_exact(r, &mut payload)? {
        return Ok(FrameRead::Malformed("truncated frame payload".into()));
    }
    Ok(FrameRead::Frame(Frame { opcode, seq, payload }))
}

fn try_read_exact(r: &mut impl Read, buf: &mut [u8]) -> io::Result<bool> {
    match r.read_exact(buf) {
        Ok(()) => Ok(true),
        Err(e) if e.kind() == io::ErrorKind::UnexpectedEof => Ok(false),
        Err(e) => Err(e),
    }
}

pub(crate) fn put_u32(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_le_bytes());
}

pub(crate) fn put_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}

pub(crate) fn put_f32(out: &mut Vec<u8>, v: f32) {
    out.extend_from_slice(&v.to_le_bytes());
}

/// Payload cursor: every read is bounds-checked, `done` enforces exact
/// consumption. Shared with the persist subsystem's WAL/checkpoint
/// codecs, which reuse the same little-endian framing primitives.
pub(crate) struct Cur<'a> {
    b: &'a [u8],
}

impl<'a> Cur<'a> {
    pub(crate) fn new(b: &'a [u8]) -> Self {
        Cur { b }
    }

    pub(crate) fn take(&mut self, n: usize) -> Option<&'a [u8]> {
        if self.b.len() < n {
            return None;
        }
        let (head, tail) = self.b.split_at(n);
        self.b = tail;
        Some(head)
    }

    pub(crate) fn u8(&mut self) -> Option<u8> {
        self.take(1).map(|b| b[0])
    }

    pub(crate) fn u32(&mut self) -> Option<u32> {
        self.take(4).map(|b| u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    pub(crate) fn u64(&mut self) -> Option<u64> {
        self.take(8)
            .map(|b| u64::from_le_bytes([b[0], b[1], b[2], b[3], b[4], b[5], b[6], b[7]]))
    }

    pub(crate) fn f32(&mut self) -> Option<f32> {
        self.take(4).map(|b| f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    pub(crate) fn rest(&mut self) -> &'a [u8] {
        std::mem::take(&mut self.b)
    }

    pub(crate) fn remaining(&self) -> usize {
        self.b.len()
    }

    pub(crate) fn done(&self) -> bool {
        self.b.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip_binary_req(req: &Request) -> Request {
        let bytes = req.encode_frame(7);
        let mut cursor = &bytes[..];
        match read_frame(&mut cursor).unwrap() {
            FrameRead::Frame(f) => {
                assert_eq!(f.seq, 7);
                Request::decode_frame(&f).unwrap()
            }
            other => panic!("expected frame, got {other:?}"),
        }
    }

    fn roundtrip_binary_resp(resp: &Response) -> Response {
        let bytes = resp.encode_frame(42);
        let mut cursor = &bytes[..];
        match read_frame(&mut cursor).unwrap() {
            FrameRead::Frame(f) => {
                assert_eq!(f.seq, 42);
                Response::decode_frame(&f).unwrap()
            }
            other => panic!("expected frame, got {other:?}"),
        }
    }

    #[test]
    fn text_request_grammar_matches_legacy_strings() {
        assert_eq!(
            Request::parse_text("PREDICT 3 7"),
            Ok(Request::Predict { row: 3, col: 7 })
        );
        assert_eq!(
            Request::parse_text("MPREDICT 1 2 3"),
            Ok(Request::MPredict { row: 1, cols: vec![2, 3] })
        );
        assert_eq!(Request::parse_text("TOPN 0 5"), Ok(Request::TopN { row: 0, n: 5 }));
        assert_eq!(
            Request::parse_text("RATE 0 5 4.5"),
            Ok(Request::Rate { row: 0, col: 5, value: 4.5 })
        );
        assert_eq!(
            Request::parse_text("MRATE 0 1 2.5 3 4 1.0"),
            Ok(Request::MRate { ratings: vec![(0, 1, 2.5), (3, 4, 1.0)] })
        );
        assert_eq!(Request::parse_text("FLUSH"), Ok(Request::Flush));
        assert_eq!(Request::parse_text("STATS"), Ok(Request::Stats));
        assert_eq!(Request::parse_text("SUBSCRIBE"), Ok(Request::Subscribe));
        assert_eq!(Request::parse_text("QUIT"), Ok(Request::Shutdown));
        assert_eq!(Request::parse_text("SHUTDOWN"), Ok(Request::Shutdown));
        // legacy grammar: trailing tokens on fixed-arity verbs ignored
        assert_eq!(
            Request::parse_text("PREDICT 1 2 junk"),
            Ok(Request::Predict { row: 1, col: 2 })
        );
        // malformed forms
        assert_eq!(
            Request::parse_text("PREDICT x y"),
            Err(ErrorKind::Usage(PREDICT_USAGE.into()))
        );
        assert_eq!(
            Request::parse_text("MPREDICT 0"),
            Err(ErrorKind::Usage(MPREDICT_USAGE.into()))
        );
        assert_eq!(
            Request::parse_text("MRATE 1 2"),
            Err(ErrorKind::Usage(MRATE_USAGE.into()))
        );
        assert_eq!(Request::parse_text(""), Err(ErrorKind::Empty));
        assert_eq!(
            Request::parse_text("BOGUS"),
            Err(ErrorKind::UnknownVerb("BOGUS".into()))
        );
        // parse-time caps: a flood line never allocates past the limit
        let flood = format!("MPREDICT 0{}", " 1".repeat(MAX_MPREDICT_COLS + 1));
        assert_eq!(Request::parse_text(&flood), Err(ErrorKind::TooManyCols));
        let flood = format!("MRATE{}", " 1 1 1.0".repeat(MAX_MRATE_EVENTS + 1));
        assert_eq!(Request::parse_text(&flood), Err(ErrorKind::TooManyEvents));
    }

    #[test]
    fn response_text_forms_match_legacy_strings() {
        assert_eq!(Response::Pred(3.25).encode_text(), "PRED 3.2500");
        assert_eq!(
            Response::Preds(vec![Some(1.5), None, Some(2.0)]).encode_text(),
            "PREDS 1.5000 - 2.0000"
        );
        assert_eq!(
            Response::TopN(vec![(7, 4.5), (2, 3.0)]).encode_text(),
            "TOPN 7:4.5000 2:3.0000"
        );
        // an empty TOPN keeps the legacy trailing space
        assert_eq!(Response::TopN(vec![]).encode_text(), "TOPN ");
        assert_eq!(Response::Ok(OkBody::Buffered).encode_text(), "OK buffered");
        assert_eq!(
            Response::Ok(OkBody::Flushed { applied: 12 }).encode_text(),
            "OK flushed 12"
        );
        assert_eq!(Response::Ok(OkBody::Ignored).encode_text(), "OK ignored");
        assert_eq!(
            Response::Stats("dims 3x4\n".into()).encode_text(),
            "dims 3x4\nEND"
        );
        assert_eq!(Response::Error(ErrorKind::OutOfRange).encode_text(), "ERR out-of-range");
        assert_eq!(
            Response::Error(ErrorKind::UnknownVerb("BOGUS".into())).encode_text(),
            "ERR unknown verb `BOGUS`"
        );
        assert_eq!(
            Response::Error(ErrorKind::Usage(RATE_USAGE.into())).encode_text(),
            "ERR usage: RATE <row> <col> <value>"
        );
    }

    #[test]
    fn every_error_kind_roundtrips_on_both_codecs() {
        let kinds = [
            ErrorKind::OutOfRange,
            ErrorKind::TooManyCols,
            ErrorKind::TooManyItems,
            ErrorKind::TooManyEvents,
            ErrorKind::Backpressure,
            ErrorKind::InvalidValue,
            ErrorKind::OutOfBounds,
            ErrorKind::Empty,
            ErrorKind::Overloaded,
            ErrorKind::Unavailable,
            ErrorKind::UnknownVerb("FROB".into()),
            ErrorKind::Usage(TOPN_USAGE.into()),
            ErrorKind::MalformedFrame("truncated frame header".into()),
        ];
        for kind in kinds {
            let line = kind.to_line();
            assert_eq!(ErrorKind::parse_line(&line), Some(kind.clone()), "{line}");
            let resp = Response::Error(kind.clone());
            assert_eq!(roundtrip_binary_resp(&resp), resp, "{line}");
            assert_eq!(Response::decode_text(&line), Ok(resp), "{line}");
        }
    }

    #[test]
    fn binary_request_roundtrip() {
        let reqs = [
            Request::Predict { row: 3, col: 7_000_000 },
            Request::MPredict { row: 9, cols: vec![0, 1, u32::MAX] },
            Request::TopN { row: 2, n: 256 },
            Request::Rate { row: 1, col: 2, value: -3.75 },
            Request::MRate { ratings: vec![(0, 1, 2.5), (u32::MAX, 0, 1e-20)] },
            Request::Flush,
            Request::Stats,
            Request::Subscribe,
            Request::Shutdown,
        ];
        for req in reqs {
            assert_eq!(roundtrip_binary_req(&req), req, "{req:?}");
        }
    }

    #[test]
    fn binary_response_roundtrip() {
        let resps = [
            Response::Pred(2.125),
            Response::Preds(vec![Some(1.0), None]),
            Response::TopN(vec![(3, 0.5)]),
            Response::TopN(vec![]),
            Response::Ok(OkBody::Buffered),
            Response::Ok(OkBody::Flushed { applied: u64::MAX }),
            Response::Ok(OkBody::Ignored),
            Response::Stats("dims 2x2\ncounter server.rate 3\n".into()),
            Response::Subscribed { version: u64::MAX },
            Response::Push { version: 17, dirty: vec![0, 2, 7] },
            Response::Push { version: 3, dirty: vec![] },
            Response::Bye,
        ];
        for resp in resps {
            assert_eq!(roundtrip_binary_resp(&resp), resp, "{resp:?}");
        }
    }

    #[test]
    fn text_decode_inverts_encode() {
        // quantized floats: exact at 4 decimals, so the lossy `{:.4}`
        // reply forms round-trip bit-exactly
        let resps = [
            Response::Pred(3.0625),
            Response::Preds(vec![Some(-2.5), None, Some(0.0625)]),
            Response::TopN(vec![(9, 4.9375), (0, -1.5)]),
            Response::TopN(vec![]),
            Response::Ok(OkBody::Flushed { applied: 7 }),
            Response::Stats("dims 30x15\nbuffered 2\ncounter stream.flushes 4\n".into()),
            Response::Subscribed { version: 9 },
            Response::Push { version: 4, dirty: vec![1, 3] },
            Response::Bye,
        ];
        for resp in resps {
            assert_eq!(
                Response::decode_text(&resp.encode_text()),
                Ok(resp.clone()),
                "{resp:?}"
            );
        }
    }

    #[test]
    fn frame_reader_rejects_bad_framing() {
        // bad frame byte
        let mut cursor = &b"PREDICT 0 0\n"[..];
        assert!(matches!(read_frame(&mut cursor).unwrap(), FrameRead::Malformed(_)));
        // clean EOF between frames
        let mut cursor = &b""[..];
        assert!(matches!(read_frame(&mut cursor).unwrap(), FrameRead::Eof));
        // truncated header
        let mut cursor = &[BINARY_FRAME_BYTE, 0x01, 0x00][..];
        assert!(matches!(read_frame(&mut cursor).unwrap(), FrameRead::Malformed(_)));
        // oversized length field never allocates
        let mut bytes = vec![BINARY_FRAME_BYTE, 0x01];
        bytes.extend_from_slice(&0u32.to_le_bytes());
        bytes.extend_from_slice(&(u32::MAX).to_le_bytes());
        let mut cursor = &bytes[..];
        assert!(matches!(read_frame(&mut cursor).unwrap(), FrameRead::Malformed(_)));
        // truncated payload
        let full = Request::Predict { row: 1, col: 2 }.encode_frame(0);
        let mut cursor = &full[..full.len() - 3];
        assert!(matches!(read_frame(&mut cursor).unwrap(), FrameRead::Malformed(_)));
    }

    #[test]
    fn decode_rejects_bad_payloads() {
        // unknown request opcode
        let f = Frame { opcode: 0x66, seq: 0, payload: vec![] };
        assert!(matches!(
            Request::decode_frame(&f),
            Err(ErrorKind::UnknownVerb(_))
        ));
        // truncated PREDICT payload
        let f = Frame { opcode: 0x01, seq: 0, payload: vec![1, 2, 3] };
        assert!(matches!(
            Request::decode_frame(&f),
            Err(ErrorKind::MalformedFrame(_))
        ));
        // MPREDICT count exceeding the cap is a typed protocol error
        let mut payload = Vec::new();
        put_u64(&mut payload, 0);
        put_u32(&mut payload, (MAX_MPREDICT_COLS + 1) as u32);
        let f = Frame { opcode: 0x02, seq: 0, payload };
        assert_eq!(Request::decode_frame(&f), Err(ErrorKind::TooManyCols));
        // MRATE count exceeding the cap likewise
        let mut payload = Vec::new();
        put_u32(&mut payload, (MAX_MRATE_EVENTS + 1) as u32);
        let f = Frame { opcode: 0x05, seq: 0, payload };
        assert_eq!(Request::decode_frame(&f), Err(ErrorKind::TooManyEvents));
        // a count field larger than the actual payload is malformed,
        // not an allocation
        let mut payload = Vec::new();
        put_u64(&mut payload, 0);
        put_u32(&mut payload, 100);
        let f = Frame { opcode: 0x02, seq: 0, payload };
        assert!(matches!(
            Request::decode_frame(&f),
            Err(ErrorKind::MalformedFrame(_))
        ));
        // trailing bytes are malformed
        let mut bytes = Vec::new();
        put_u64(&mut bytes, 1);
        put_u64(&mut bytes, 2);
        bytes.push(0xFF);
        let f = Frame { opcode: 0x01, seq: 0, payload: bytes };
        assert!(matches!(
            Request::decode_frame(&f),
            Err(ErrorKind::MalformedFrame(_))
        ));
    }
}
