//! Streaming ingestion orchestrator — the online-learning pipeline.
//!
//! Incremental ratings arrive as [`Event`]s; the orchestrator validates
//! them (non-finite values and ids beyond the configured universe bounds
//! never enter the buffer), buffers them in a bounded queue
//! (backpressure: [`IngestResult::Rejected`] once the buffer holds
//! `queue_capacity` un-flushed events and auto-flush is disabled),
//! batches them to amortize the hash/parameter update, and on flush runs
//! Algorithm 4: fold the batch into the combined matrix and the saved
//! simLSH accumulators (re-ratings are last-write-wins — they overwrite
//! in place instead of accumulating duplicate CSR entries), refresh the
//! Top-K table, and train only the new variables' parameters.
//!
//! The design is caller-driven (deterministic, testable); [`run_channel`]
//! adapts it to a `std::sync::mpsc` feed for the threaded serving path.
//!
//! # Invariants
//!
//! (Machine-checked: `cargo run -p lshmf-check` gates this section's
//! presence in tier-1 CI; the `prop::interleave` explorer checks the
//! arrival-order claim bit-for-bit under every bounded schedule.)
//!
//! * **Buffer order is arrival order.** `flush` applies the buffered
//!   events exactly in the order `ingest` accepted them; the
//!   multi-writer path reproduces this by merging its per-band buffers
//!   on sequence stamps before entering the same flush computation.
//! * **Validation precedes buffering.** A non-finite value or an id at
//!   or beyond `max_rows`/`max_cols` never enters the buffer, in the
//!   fixed value-then-bounds order (batch ingest checks per event in
//!   that same order, all-or-nothing).
//! * **Re-rating is last-write-wins.** The `cells` index maps every
//!   stored cell to exactly one CSR entry; re-rates overwrite in place
//!   and feed the hash accumulators a weight delta, so `nnz` is stable
//!   under re-rating traffic.
//! * **Flush-mode contract** ([`FlushMode`]): `Exact` (the default)
//!   runs the Algorithm-4 core single-threaded in batch order — the
//!   bit-pinned reference all serving-parity property tests compare
//!   against. `Relaxed` runs the same update rule on `flush_bands`
//!   threads under the Latin-square rotation (see
//!   [`crate::mf::online::online_update_relaxed_with_topk`]):
//!   deterministic and race-free, but entry order changes, so factors
//!   carry f32-rounding-scale divergence from the exact reference —
//!   bounded by the property test in `tests/props.rs`. Both modes
//!   consume the training rng identically, so switching modes never
//!   desynchronizes the stream of Top-K random supplements.
//! * **The flush report feeds the publish.** `last_flush_cols` ∪
//!   `last_flush_topk_moved` is exactly the set of columns whose served
//!   state may have changed; the sharded snapshot publish keys its
//!   dirty-band set off this report (O(report) per publish) in both
//!   flush modes. `last_flush_rows` is the row-side half of the same
//!   report: the rows whose rating row changed, which the per-row Top-N
//!   cache uses to drop entries whose Eq. (1) neighbourhood scan inputs
//!   moved (a rating shifts the row's predictions in *clean* column
//!   bands too — the scan reads the full rating row).

use super::super::mf::neighbourhood::{CulshConfig, CulshModel};
use super::super::mf::online::{online_update, online_update_relaxed_with_topk};
use crate::lsh::OnlineHashState;
use crate::metrics::Registry;
use crate::rng::Rng;
use crate::sparse::{Csr, Triples};
use std::collections::HashMap;
use std::sync::Arc;

/// A streaming event.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Event {
    /// A new interaction (row, col, value); ids may exceed current dims —
    /// that is how new variables enter the system.
    Rate(u32, u32, f32),
    /// Force a flush.
    Flush,
    /// Stop a channel-driven run.
    Shutdown,
}

/// How a flush executes the Algorithm-4 training core
/// (`serve --flush-mode`). See the module invariants for the full
/// contract.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum FlushMode {
    /// Single-threaded, batch order — bit-identical across all three
    /// serving flavours (the default).
    #[default]
    Exact,
    /// Band-parallel under the Latin-square rotation — deterministic,
    /// but factors diverge from the exact reference at f32-rounding
    /// scale (bounded-divergence property-tested).
    Relaxed,
}

impl FlushMode {
    /// CLI / log name.
    pub fn name(&self) -> &'static str {
        match self {
            FlushMode::Exact => "exact",
            FlushMode::Relaxed => "relaxed",
        }
    }
}

/// Orchestrator tuning.
#[derive(Clone, Debug)]
pub struct StreamConfig {
    /// Maximum buffered (un-flushed) events.
    pub queue_capacity: usize,
    /// Auto-flush threshold.
    pub batch_size: usize,
    /// Epochs of incremental training per flush.
    pub online_epochs: usize,
    /// Reject instead of auto-flushing when the buffer fills (used to
    /// exercise backpressure; servers keep it false).
    pub reject_when_full: bool,
    /// Hard ceiling on accepted row ids (`i < max_rows`). Without it one
    /// malicious `RATE 4000000000 …` makes the next flush allocate
    /// multi-GB parameter vectors.
    pub max_rows: usize,
    /// Hard ceiling on accepted column ids (`j < max_cols`).
    pub max_cols: usize,
    /// Flush execution mode (`serve --flush-mode`, default exact).
    pub flush_mode: FlushMode,
    /// Rotation width for relaxed-mode training on the single-writer
    /// path — and on the multi-writer *growth* barrier, which runs the
    /// single-writer flush on a reassembled orchestrator. The
    /// multi-writer in-place flush uses its band-writer count instead
    /// (one rotation lane per band).
    pub flush_bands: usize,
}

impl Default for StreamConfig {
    fn default() -> Self {
        StreamConfig {
            queue_capacity: 65_536,
            batch_size: 1_024,
            online_epochs: 5,
            reject_when_full: false,
            max_rows: 1 << 24,
            max_cols: 1 << 24,
            flush_mode: FlushMode::Exact,
            flush_bands: 4,
        }
    }
}

/// Outcome of an ingest call.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum IngestResult {
    Buffered,
    Flushed { applied: usize },
    Rejected,
    /// Non-finite rating value (NaN/±inf) — never enters the buffer.
    InvalidValue,
    /// Row or column id at or beyond `max_rows`/`max_cols`.
    OutOfBounds,
    /// The event carried nothing to ingest (e.g. [`Event::Shutdown`]
    /// handed to the caller-driven orchestrator): nothing was buffered
    /// and nothing was applied. Previously this lied `Buffered`.
    Ignored,
}

/// The streaming orchestrator: owns the model, the hash state, and the
/// combined training matrix.
pub struct StreamOrchestrator {
    /// `Option` so flush() can move the model through `online_update`.
    model: Option<CulshModel>,
    hash_state: OnlineHashState,
    combined_t: Triples,
    /// `Arc` so the serving snapshot publish shares the flushed matrix
    /// instead of deep-cloning it.
    combined: Arc<Csr>,
    /// Position of each stored cell in `combined_t`'s entry vec — the
    /// last-write-wins re-rating index.
    cells: HashMap<(u32, u32), u32>,
    buffer: Vec<(u32, u32, f32)>,
    /// Column ids the most recent flush applied — the sharded snapshot
    /// publish keys its dirty-band set off this, straight from the
    /// source instead of re-deriving it from ingest ordering.
    last_flush_cols: Vec<u32>,
    /// Old columns whose Top-K row the most recent flush's re-search
    /// moved ([`crate::mf::online::OnlineReport::topk_moved_cols`]) —
    /// the publish's other dirty-band source, O(report) per publish.
    last_flush_topk_moved: Vec<u32>,
    /// Row ids the most recent flush applied — the per-row Top-N
    /// cache's row-invalidation source (see the module invariants).
    last_flush_rows: Vec<u32>,
    cfg: StreamConfig,
    train_cfg: CulshConfig,
    rng: Rng,
    metrics: Registry,
}

/// The orchestrator's owned state, dismantled — the multi-writer
/// [`crate::coordinator::banded::BandedOrchestrator`] splits these
/// internals per column band at spawn and reassembles them at shutdown.
pub(crate) struct StreamParts {
    pub model: CulshModel,
    pub hash_state: OnlineHashState,
    pub combined_t: Triples,
    pub combined: Arc<Csr>,
    pub cells: HashMap<(u32, u32), u32>,
    pub buffer: Vec<(u32, u32, f32)>,
    pub last_flush_cols: Vec<u32>,
    pub last_flush_topk_moved: Vec<u32>,
    pub last_flush_rows: Vec<u32>,
    pub cfg: StreamConfig,
    pub train_cfg: CulshConfig,
    pub rng: Rng,
    pub metrics: Registry,
}

/// Record one relaxed flush epoch's metrics — the `flush.relaxed_epochs`
/// counter plus every band's `flush.band<b>.train_micros` — shared by
/// the single-writer and multi-writer flush paths so the metric names
/// cannot drift. Unlike the publish path's pre-resolved handles
/// (`PublishMetrics`), these lookups may allocate: a relaxed flush just
/// ran full training epochs, so the `format!` is noise, and the band
/// count can change at a growth barrier, which pre-resolution would
/// have to chase.
pub(crate) fn record_relaxed_flush_metrics(metrics: &Registry, band_train_micros: &[u64]) {
    metrics.counter("flush.relaxed_epochs").inc();
    for (b, micros) in band_train_micros.iter().enumerate() {
        metrics
            .counter(&format!("flush.band{b}.train_micros"))
            .add(*micros);
    }
}

/// Within-batch dedup, last write wins: one surviving entry per cell, at
/// its first position, carrying the final value. Shared by the single-
/// and multi-writer flush paths so their batch semantics cannot drift.
pub(crate) fn dedup_batch(raw: Vec<(u32, u32, f32)>) -> Vec<(u32, u32, f32)> {
    let mut increment: Vec<(u32, u32, f32)> = Vec::with_capacity(raw.len());
    let mut pos_of: HashMap<(u32, u32), usize> = HashMap::with_capacity(raw.len());
    for (i, j, r) in raw {
        match pos_of.entry((i, j)) {
            std::collections::hash_map::Entry::Occupied(e) => increment[*e.get()].2 = r,
            std::collections::hash_map::Entry::Vacant(e) => {
                e.insert(increment.len());
                increment.push((i, j, r));
            }
        }
    }
    increment
}

impl StreamOrchestrator {
    pub fn new(
        model: CulshModel,
        mut hash_state: OnlineHashState,
        mut base: Triples,
        cfg: StreamConfig,
        train_cfg: CulshConfig,
        rng: Rng,
        metrics: Registry,
    ) -> Self {
        // Dedup pre-existing duplicate cells (last write wins, first
        // position) so the re-rating index maps each cell to exactly one
        // stored entry — otherwise a later re-rating would overwrite one
        // duplicate and leave a stale sibling in the CSR. Dropped
        // occurrences are retracted from the hash accumulators, which
        // the caller built over the duplicated matrix.
        let mut cells: HashMap<(u32, u32), u32> = HashMap::with_capacity(base.nnz());
        let mut deduped: Vec<(u32, u32, f32)> = Vec::with_capacity(base.nnz());
        let mut dropped: Vec<(u32, u32, f32)> = Vec::new();
        for &(i, j, r) in base.entries() {
            match cells.entry((i, j)) {
                std::collections::hash_map::Entry::Occupied(e) => {
                    let pos = *e.get() as usize;
                    dropped.push((i, j, deduped[pos].2));
                    deduped[pos].2 = r;
                }
                std::collections::hash_map::Entry::Vacant(e) => {
                    e.insert(deduped.len() as u32);
                    deduped.push((i, j, r));
                }
            }
        }
        if !dropped.is_empty() {
            for &(i, j, r) in &dropped {
                hash_state.retract(i as usize, j as usize, r);
            }
            *base.entries_mut() = deduped;
        }
        let combined = Arc::new(Csr::from_triples(&base));
        StreamOrchestrator {
            model: Some(model),
            hash_state,
            combined_t: base,
            combined,
            cells,
            buffer: Vec::new(),
            last_flush_cols: Vec::new(),
            last_flush_topk_moved: Vec::new(),
            last_flush_rows: Vec::new(),
            cfg,
            train_cfg,
            rng,
            metrics,
        }
    }

    /// Dismantle into the parts the multi-writer path splits per band.
    pub(crate) fn into_parts(self) -> StreamParts {
        StreamParts {
            model: self.model.expect("model present outside flush"),
            hash_state: self.hash_state,
            combined_t: self.combined_t,
            combined: self.combined,
            cells: self.cells,
            buffer: self.buffer,
            last_flush_cols: self.last_flush_cols,
            last_flush_topk_moved: self.last_flush_topk_moved,
            last_flush_rows: self.last_flush_rows,
            cfg: self.cfg,
            train_cfg: self.train_cfg,
            rng: self.rng,
            metrics: self.metrics,
        }
    }

    /// Reassemble from [`StreamParts`] — a direct field constructor: no
    /// re-dedup, no matrix rebuild (the parts are already coherent).
    pub(crate) fn from_parts(p: StreamParts) -> Self {
        StreamOrchestrator {
            model: Some(p.model),
            hash_state: p.hash_state,
            combined_t: p.combined_t,
            combined: p.combined,
            cells: p.cells,
            buffer: p.buffer,
            last_flush_cols: p.last_flush_cols,
            last_flush_topk_moved: p.last_flush_topk_moved,
            last_flush_rows: p.last_flush_rows,
            cfg: p.cfg,
            train_cfg: p.train_cfg,
            rng: p.rng,
            metrics: p.metrics,
        }
    }

    /// Column ids applied by the most recent flush (empty before any).
    pub fn last_flush_cols(&self) -> &[u32] {
        &self.last_flush_cols
    }

    /// Old columns whose Top-K row the most recent flush's re-search
    /// moved (empty before any flush).
    pub fn last_flush_topk_moved(&self) -> &[u32] {
        &self.last_flush_topk_moved
    }

    /// Row ids applied by the most recent flush (empty before any) —
    /// the per-row Top-N cache's row-invalidation source.
    pub fn last_flush_rows(&self) -> &[u32] {
        &self.last_flush_rows
    }

    /// The orchestrator's tuning (read-only).
    pub fn config(&self) -> &StreamConfig {
        &self.cfg
    }

    pub fn model(&self) -> &CulshModel {
        self.model.as_ref().expect("model present outside flush")
    }

    pub fn matrix(&self) -> &Csr {
        &self.combined
    }

    /// Shared handle to the combined matrix (zero-copy snapshot publish).
    pub fn matrix_arc(&self) -> Arc<Csr> {
        Arc::clone(&self.combined)
    }

    pub fn buffered(&self) -> usize {
        self.buffer.len()
    }

    pub fn dims(&self) -> (usize, usize) {
        (self.combined_t.nrows(), self.combined_t.ncols())
    }

    /// Online hash accumulators (checkpoint serialization source).
    pub(crate) fn hash_state(&self) -> &OnlineHashState {
        &self.hash_state
    }

    /// Raw triple store behind the combined matrix (checkpoint source;
    /// entry order is part of the bit-exact state — the re-rating index
    /// maps cells to positions in this exact order).
    pub(crate) fn triples(&self) -> &Triples {
        &self.combined_t
    }

    /// Flush-path RNG (checkpoint source).
    pub(crate) fn rng(&self) -> &Rng {
        &self.rng
    }

    /// Buffered-but-unflushed events (checkpoint source).
    pub(crate) fn buffer(&self) -> &[(u32, u32, f32)] {
        &self.buffer
    }

    /// Training hyper-parameters (checkpoint reconstruction input).
    pub(crate) fn train_config(&self) -> &CulshConfig {
        &self.train_cfg
    }

    /// Ingest one event.
    pub fn ingest(&mut self, event: Event) -> IngestResult {
        match event {
            Event::Shutdown => IngestResult::Ignored,
            Event::Flush => IngestResult::Flushed { applied: self.flush() },
            Event::Rate(i, j, r) => {
                if !r.is_finite() {
                    self.metrics.counter("stream.invalid_value").inc();
                    return IngestResult::InvalidValue;
                }
                if i as usize >= self.cfg.max_rows || j as usize >= self.cfg.max_cols {
                    self.metrics.counter("stream.out_of_bounds").inc();
                    return IngestResult::OutOfBounds;
                }
                if self.buffer.len() >= self.cfg.queue_capacity {
                    if self.cfg.reject_when_full {
                        self.metrics.counter("stream.rejected").inc();
                        return IngestResult::Rejected;
                    }
                    let applied = self.flush();
                    self.buffer.push((i, j, r));
                    self.metrics.counter("stream.ingested").inc();
                    return IngestResult::Flushed { applied };
                }
                self.buffer.push((i, j, r));
                self.metrics.counter("stream.ingested").inc();
                if self.buffer.len() >= self.cfg.batch_size {
                    let applied = self.flush();
                    return IngestResult::Flushed { applied };
                }
                IngestResult::Buffered
            }
        }
    }

    /// Vectorized ingest (the `MRATE` verb): admit a whole batch as one
    /// unit. Validation is all-or-nothing — one non-finite value or
    /// out-of-bounds id refuses the entire batch with nothing buffered
    /// (per-event checks run in the same value-then-bounds order as
    /// [`StreamOrchestrator::ingest`], so a batch's reply matches the
    /// first single-event reply its events would produce) — and
    /// backpressure capacity is reserved **once per batch**: with
    /// `reject_when_full`, the batch is rejected unless the buffer can
    /// hold all of it. An empty batch is [`IngestResult::Ignored`] —
    /// nothing buffered, nothing applied — on every write path.
    pub fn ingest_batch(&mut self, batch: &[(u32, u32, f32)]) -> IngestResult {
        if batch.is_empty() {
            return IngestResult::Ignored;
        }
        for &(i, j, r) in batch {
            if !r.is_finite() {
                self.metrics.counter("stream.invalid_value").inc();
                return IngestResult::InvalidValue;
            }
            if i as usize >= self.cfg.max_rows || j as usize >= self.cfg.max_cols {
                self.metrics.counter("stream.out_of_bounds").inc();
                return IngestResult::OutOfBounds;
            }
        }
        let mut applied = 0usize;
        if self.buffer.len() + batch.len() > self.cfg.queue_capacity {
            if self.cfg.reject_when_full {
                self.metrics.counter("stream.rejected").inc();
                return IngestResult::Rejected;
            }
            applied += self.flush();
        }
        self.buffer.extend_from_slice(batch);
        self.metrics.counter("stream.ingested").add(batch.len() as u64);
        if self.buffer.len() >= self.cfg.batch_size {
            applied += self.flush();
        }
        if applied > 0 {
            IngestResult::Flushed { applied }
        } else {
            IngestResult::Buffered
        }
    }

    /// Apply all buffered events through Algorithm 4. Re-ratings of a
    /// stored cell are last-write-wins: they overwrite the stored value
    /// (stable `nnz`, unskewed `mean()`, no duplicate neighbourhood
    /// contributions) and feed the hash accumulators a weight delta.
    pub fn flush(&mut self) -> usize {
        if self.buffer.is_empty() {
            return 0;
        }
        let raw = std::mem::take(&mut self.buffer);
        let increment = dedup_batch(raw);

        let old_rows = self.combined_t.nrows();
        let old_cols = self.combined_t.ncols();
        let new_rows = increment
            .iter()
            .map(|&(i, _, _)| i as usize + 1)
            .chain(std::iter::once(old_rows))
            .max()
            .unwrap();
        let new_cols = increment
            .iter()
            .map(|&(_, j, _)| j as usize + 1)
            .chain(std::iter::once(old_cols))
            .max()
            .unwrap();

        // Fold the batch into the combined store and the hash
        // accumulators: re-ratings overwrite in place, fresh cells
        // append.
        self.combined_t.grow_to(new_rows, new_cols);
        let mut fresh: Vec<(u32, u32, f32)> = Vec::with_capacity(increment.len());
        let mut rerated = 0u64;
        for &(i, j, r) in &increment {
            if let Some(&pos) = self.cells.get(&(i, j)) {
                let old = self.combined_t.entries()[pos as usize].2;
                self.combined_t.entries_mut()[pos as usize].2 = r;
                self.hash_state.reabsorb(i as usize, j as usize, old, r);
                rerated += 1;
            } else {
                self.cells.insert((i, j), self.combined_t.nnz() as u32);
                self.combined_t.push(i as usize, j as usize, r);
                fresh.push((i, j, r));
            }
        }
        self.hash_state.apply_increment(&fresh, new_cols);
        self.metrics.counter("stream.rerated").add(rerated);

        let combined = Arc::new(Csr::from_triples(&self.combined_t));
        let model = self.model.take().expect("model present");
        let k = model.k();
        let timer = self.metrics.histogram("stream.flush_seconds");
        let hash_state = &mut self.hash_state;
        let train_cfg = &self.train_cfg;
        let epochs = self.cfg.online_epochs;
        let flush_mode = self.cfg.flush_mode;
        let flush_bands = self.cfg.flush_bands;
        let rng = &mut self.rng;
        // Train on the fresh cells only: a re-rated cell has both
        // endpoints inside the old universe, so Algorithm 4 (which moves
        // only NEW variables' parameters) would scan it `epochs` times
        // for a provable no-op. Both modes run the Top-K re-search and
        // the parameter growth in the same rng order, so the mode choice
        // never desynchronizes later random supplements.
        let report = timer.time(|| match flush_mode {
            FlushMode::Exact => online_update(
                model,
                hash_state,
                &combined,
                &fresh,
                old_rows,
                old_cols,
                train_cfg,
                epochs,
                rng,
            ),
            FlushMode::Relaxed => {
                let (topk, _) = hash_state.topk(k, rng);
                online_update_relaxed_with_topk(
                    model,
                    topk,
                    &combined,
                    &fresh,
                    old_rows,
                    old_cols,
                    train_cfg,
                    epochs,
                    flush_bands,
                    rng,
                )
            }
        });
        if flush_mode == FlushMode::Relaxed {
            record_relaxed_flush_metrics(&self.metrics, &report.band_train_micros);
        }
        self.model = Some(report.model);
        self.combined = combined;
        self.last_flush_cols = increment.iter().map(|&(_, j, _)| j).collect();
        self.last_flush_rows = increment.iter().map(|&(i, _, _)| i).collect();
        self.last_flush_topk_moved = report.topk_moved_cols;
        self.metrics.counter("stream.flushes").inc();
        self.metrics
            .counter("stream.applied")
            .add(increment.len() as u64);
        increment.len()
    }
}

/// Drive an orchestrator from an mpsc channel until [`Event::Shutdown`];
/// returns the orchestrator for inspection. The shutdown drain's
/// outcome is not discarded: the number of events it applied lands in
/// the `stream.drain_applied` counter, so a caller (or an operator
/// reading `STATS`) can tell a clean drain from one that flushed a
/// backlog.
pub fn run_channel(
    mut orch: StreamOrchestrator,
    rx: std::sync::mpsc::Receiver<Event>,
) -> StreamOrchestrator {
    for event in rx {
        if event == Event::Shutdown {
            break;
        }
        orch.ingest(event);
    }
    let applied = orch.flush();
    orch.metrics.counter("stream.drain_applied").add(applied as u64);
    orch
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lsh::{NeighbourSearch, SimLsh};
    use crate::mf::neighbourhood::train_culsh_logged;
    use crate::sparse::Csc;

    fn setup(rng: &mut Rng) -> StreamOrchestrator {
        let (m, n) = (40, 20);
        let mut t = Triples::new(m, n);
        let mut seen = std::collections::HashSet::new();
        while t.nnz() < 250 {
            let (i, j) = (rng.below(m), rng.below(n));
            if seen.insert((i, j)) {
                t.push(i, j, 1.0 + rng.f32() * 4.0);
            }
        }
        let csr = Csr::from_triples(&t);
        let csc = Csc::from_triples(&t);
        let lsh = SimLsh::new(2, 6, 8, 2);
        let hash_state = OnlineHashState::build(lsh, &csc);
        let (topk, _) = hash_state.topk(4, rng);
        let cfg = CulshConfig { f: 4, k: 4, epochs: 5, ..Default::default() };
        let (model, _) = train_culsh_logged(&csr, topk, &cfg, rng);
        StreamOrchestrator::new(
            model,
            hash_state,
            t,
            StreamConfig { batch_size: 8, queue_capacity: 16, ..Default::default() },
            cfg,
            rng.split(99),
            Registry::new(),
        )
    }

    #[test]
    fn batching_flushes_at_threshold() {
        let mut rng = Rng::seeded(51);
        let mut orch = setup(&mut rng);
        for k in 0..7 {
            assert_eq!(orch.ingest(Event::Rate(1, 1 + k, 3.0)), IngestResult::Buffered);
        }
        // 8th event hits batch_size
        match orch.ingest(Event::Rate(2, 2, 4.0)) {
            IngestResult::Flushed { applied } => assert_eq!(applied, 8),
            other => panic!("expected flush, got {other:?}"),
        }
        assert_eq!(orch.buffered(), 0);
    }

    #[test]
    fn new_variables_grow_dims() {
        let mut rng = Rng::seeded(52);
        let mut orch = setup(&mut rng);
        let (m0, n0) = orch.dims();
        orch.ingest(Event::Rate(m0 as u32 + 2, n0 as u32 + 5, 4.5));
        orch.ingest(Event::Flush);
        let (m1, n1) = orch.dims();
        assert_eq!(m1, m0 + 3);
        assert_eq!(n1, n0 + 6);
        // model grew too
        assert_eq!(orch.model().base.bi.len(), m1);
        assert_eq!(orch.model().base.bj.len(), n1);
        assert_eq!(orch.model().topk.n(), n1);
    }

    #[test]
    fn backpressure_rejects_when_configured() {
        let mut rng = Rng::seeded(53);
        let mut orch = setup(&mut rng);
        orch.cfg.reject_when_full = true;
        orch.cfg.queue_capacity = 4;
        orch.cfg.batch_size = 100; // no auto-flush
        for k in 0..4 {
            assert_eq!(orch.ingest(Event::Rate(0, k, 3.0)), IngestResult::Buffered);
        }
        assert_eq!(orch.ingest(Event::Rate(0, 9, 3.0)), IngestResult::Rejected);
        orch.ingest(Event::Flush);
        assert_eq!(orch.ingest(Event::Rate(0, 9, 3.0)), IngestResult::Buffered);
    }

    #[test]
    fn non_finite_ratings_are_refused() {
        let mut rng = Rng::seeded(55);
        let mut orch = setup(&mut rng);
        for bad in [f32::NAN, f32::INFINITY, f32::NEG_INFINITY] {
            assert_eq!(orch.ingest(Event::Rate(0, 1, bad)), IngestResult::InvalidValue);
        }
        assert_eq!(orch.buffered(), 0, "invalid values must not buffer");
        // sane traffic still flows
        assert_eq!(orch.ingest(Event::Rate(0, 1, 3.0)), IngestResult::Buffered);
    }

    #[test]
    fn out_of_bounds_ids_are_refused() {
        let mut rng = Rng::seeded(56);
        let mut orch = setup(&mut rng);
        orch.cfg.max_rows = 100;
        orch.cfg.max_cols = 50;
        assert_eq!(orch.ingest(Event::Rate(100, 0, 3.0)), IngestResult::OutOfBounds);
        assert_eq!(orch.ingest(Event::Rate(0, 50, 3.0)), IngestResult::OutOfBounds);
        assert_eq!(
            orch.ingest(Event::Rate(4_000_000_000, 4_000_000_000, 5.0)),
            IngestResult::OutOfBounds
        );
        assert_eq!(orch.buffered(), 0);
        // the boundary itself is accepted
        assert_eq!(orch.ingest(Event::Rate(99, 49, 3.0)), IngestResult::Buffered);
        orch.ingest(Event::Flush);
        assert_eq!(orch.dims(), (100, 50));
    }

    #[test]
    fn rerating_is_last_write_wins() {
        let mut rng = Rng::seeded(57);
        let mut orch = setup(&mut rng);
        orch.ingest(Event::Rate(1, 2, 2.0));
        orch.ingest(Event::Flush);
        let nnz0 = orch.matrix().nnz();
        // re-rate the same cell 100× across many flushes: nnz stays
        // stable (no duplicate CSR entries, no leak) …
        for k in 0..100u32 {
            orch.ingest(Event::Rate(1, 2, 1.0 + (k % 5) as f32));
            orch.ingest(Event::Flush);
        }
        assert_eq!(orch.matrix().nnz(), nnz0, "re-ratings must not grow nnz");
        // … and the stored value is the last write
        let stored = orch
            .matrix()
            .row(1)
            .find(|&(j, _)| j == 2)
            .map(|(_, r)| r)
            .unwrap();
        assert_eq!(stored, 1.0 + (99 % 5) as f32);
    }

    #[test]
    fn within_batch_rerates_dedup_to_one_entry() {
        let mut rng = Rng::seeded(58);
        let mut orch = setup(&mut rng);
        let nnz0 = orch.matrix().nnz();
        for k in 0..5u32 {
            assert_eq!(orch.ingest(Event::Rate(3, 4, k as f32)), IngestResult::Buffered);
        }
        // five buffered writes to one cell apply as a single entry
        assert_eq!(orch.ingest(Event::Flush), IngestResult::Flushed { applied: 1 });
        assert!(orch.matrix().nnz() <= nnz0 + 1);
        let stored = orch
            .matrix()
            .row(3)
            .find(|&(j, _)| j == 4)
            .map(|(_, r)| r)
            .unwrap();
        assert_eq!(stored, 4.0);
    }

    /// A base matrix listing the same cell twice collapses to one stored
    /// entry at construction (last write wins), so later re-ratings
    /// cannot leave a stale duplicate sibling in the CSR.
    #[test]
    fn duplicate_base_cells_are_deduped_at_construction() {
        let mut rng = Rng::seeded(59);
        let (m, n) = (40usize, 20usize);
        let mut t = Triples::new(m, n);
        let mut seen = std::collections::HashSet::new();
        while t.nnz() < 200 {
            let (i, j) = (rng.below(m), rng.below(n));
            if seen.insert((i, j)) {
                t.push(i, j, 1.0 + rng.f32() * 4.0);
            }
        }
        t.push(5, 6, 1.0);
        t.push(5, 6, 4.0);
        let unique = seen.len() + usize::from(!seen.contains(&(5, 6)));
        let csr = Csr::from_triples(&t);
        let csc = Csc::from_triples(&t);
        let lsh = SimLsh::new(2, 6, 8, 2);
        let hash_state = OnlineHashState::build(lsh, &csc);
        let (topk, _) = hash_state.topk(4, &mut rng);
        let cfg = CulshConfig { f: 4, k: 4, epochs: 2, ..Default::default() };
        let (model, _) = train_culsh_logged(&csr, topk, &cfg, &mut rng);
        let orch = StreamOrchestrator::new(
            model,
            hash_state,
            t,
            StreamConfig::default(),
            cfg,
            rng.split(99),
            Registry::new(),
        );
        assert_eq!(orch.matrix().nnz(), unique, "duplicates collapsed");
        let stored = orch
            .matrix()
            .row(5)
            .find(|&(j, _)| j == 6)
            .map(|(_, r)| r)
            .unwrap();
        assert_eq!(stored, 4.0, "last write wins");
    }

    /// `ingest_batch` admits a batch as one unit: all-or-nothing
    /// validation, capacity reserved once, and a reply equivalent to the
    /// event-by-event sequence when nothing rejects.
    #[test]
    fn batch_ingest_is_all_or_nothing() {
        let mut rng = Rng::seeded(61);
        let mut orch = setup(&mut rng);
        // empty batch: nothing to ingest, and it says so
        assert_eq!(orch.ingest_batch(&[]), IngestResult::Ignored);
        // one bad value poisons the whole batch — nothing buffers
        assert_eq!(
            orch.ingest_batch(&[(0, 1, 3.0), (0, 2, f32::NAN)]),
            IngestResult::InvalidValue
        );
        assert_eq!(orch.buffered(), 0);
        orch.cfg.max_cols = 50;
        assert_eq!(
            orch.ingest_batch(&[(0, 1, 3.0), (0, 50, 3.0)]),
            IngestResult::OutOfBounds
        );
        assert_eq!(orch.buffered(), 0);
        // value check wins over the bounds check, per-event in order,
        // exactly like the single-event path
        assert_eq!(
            orch.ingest_batch(&[(0, 50, f32::NAN), (0, 1, 3.0)]),
            IngestResult::InvalidValue
        );
        // a clean batch buffers wholesale (batch_size 8 not yet hit)
        assert_eq!(
            orch.ingest_batch(&[(0, 1, 3.0), (0, 2, 4.0), (0, 3, 5.0)]),
            IngestResult::Buffered
        );
        assert_eq!(orch.buffered(), 3);
        // crossing batch_size flushes everything buffered
        let batch: Vec<(u32, u32, f32)> = (0..5).map(|k| (1, k, 2.0)).collect();
        assert_eq!(orch.ingest_batch(&batch), IngestResult::Flushed { applied: 8 });
        assert_eq!(orch.buffered(), 0);
    }

    /// Backpressure is reserved once per batch: a batch that cannot fit
    /// in its entirety is rejected in its entirety.
    #[test]
    fn batch_ingest_reserves_capacity_once() {
        let mut rng = Rng::seeded(62);
        let mut orch = setup(&mut rng);
        orch.cfg.reject_when_full = true;
        orch.cfg.queue_capacity = 4;
        orch.cfg.batch_size = 100;
        assert_eq!(orch.ingest_batch(&[(0, 0, 3.0), (0, 1, 3.0)]), IngestResult::Buffered);
        // 3 more would make 5 > 4: whole batch rejected, nothing partial
        assert_eq!(
            orch.ingest_batch(&[(0, 2, 3.0), (0, 3, 3.0), (0, 4, 3.0)]),
            IngestResult::Rejected
        );
        assert_eq!(orch.buffered(), 2);
        // exactly filling the remaining capacity is accepted
        assert_eq!(orch.ingest_batch(&[(0, 2, 3.0), (0, 3, 3.0)]), IngestResult::Buffered);
        assert_eq!(orch.buffered(), 4);
        assert_eq!(orch.ingest_batch(&[(0, 9, 3.0)]), IngestResult::Rejected);
        orch.ingest(Event::Flush);
        assert_eq!(orch.ingest_batch(&[(0, 9, 3.0)]), IngestResult::Buffered);
    }

    /// Without `reject_when_full`, an oversized batch flushes the
    /// backlog first (the capacity contract) and reports the total it
    /// caused to apply.
    #[test]
    fn batch_ingest_auto_flushes_at_capacity() {
        let mut rng = Rng::seeded(63);
        let mut orch = setup(&mut rng);
        orch.cfg.queue_capacity = 4;
        orch.cfg.batch_size = 100;
        assert_eq!(
            orch.ingest_batch(&[(0, 0, 3.0), (0, 1, 3.0), (0, 2, 3.0)]),
            IngestResult::Buffered
        );
        // 3 buffered + 2 new > 4: the backlog flushes, the batch buffers
        assert_eq!(
            orch.ingest_batch(&[(0, 3, 3.0), (1, 0, 2.0)]),
            IngestResult::Flushed { applied: 3 }
        );
        assert_eq!(orch.buffered(), 2);
    }

    /// A batch applies identically to the equivalent event sequence
    /// (same dims, same flush totals) — `MRATE` is a transport
    /// optimization, not a semantic fork.
    #[test]
    fn batch_ingest_matches_event_sequence() {
        let script: Vec<(u32, u32, f32)> =
            (0..12).map(|k| (k % 5, (k * 3) % 25, 1.0 + (k % 4) as f32)).collect();
        let applied_of = |r: IngestResult| match r {
            IngestResult::Flushed { applied } => applied,
            _ => 0,
        };
        let mut rng_a = Rng::seeded(64);
        let mut one = setup(&mut rng_a);
        let mut total_one = 0usize;
        for &(i, j, r) in &script {
            total_one += applied_of(one.ingest(Event::Rate(i, j, r)));
        }
        total_one += one.flush();
        let mut rng_b = Rng::seeded(64);
        let mut batched = setup(&mut rng_b);
        let mut total_batch = applied_of(batched.ingest_batch(&script));
        total_batch += batched.flush();
        // 12 distinct cells at batch_size 8: the single path flushes
        // mid-stream (8) then on drain (4), the batch path at admission
        // (12); totals and resulting universes must agree
        assert_eq!(total_one, 12);
        assert_eq!(total_batch, 12);
        assert_eq!(one.dims(), batched.dims());
        assert_eq!(one.matrix().nnz(), batched.matrix().nnz());
    }

    #[test]
    fn channel_runner_drains_and_stops() {
        let mut rng = Rng::seeded(54);
        let orch = setup(&mut rng);
        let (tx, rx) = std::sync::mpsc::channel();
        let handle = std::thread::spawn(move || run_channel(orch, rx));
        for k in 0..5 {
            tx.send(Event::Rate(3, k, 2.5)).unwrap();
        }
        tx.send(Event::Shutdown).unwrap();
        let orch = handle.join().unwrap();
        assert_eq!(orch.buffered(), 0);
        assert!(orch.metrics_snapshot_contains("stream.applied"));
        // the drain outcome is asserted, not discarded: all 5 buffered
        // events were applied by the shutdown flush
        assert!(
            orch.metrics_snapshot_contains("stream.drain_applied 5"),
            "{}",
            orch.metrics.snapshot()
        );
    }

    /// `Shutdown` handed to the caller-driven orchestrator is a no-op
    /// and says so — it used to claim `Buffered` with nothing buffered.
    #[test]
    fn shutdown_event_is_ignored_not_buffered() {
        let mut rng = Rng::seeded(60);
        let mut orch = setup(&mut rng);
        assert_eq!(orch.ingest(Event::Shutdown), IngestResult::Ignored);
        assert_eq!(orch.buffered(), 0);
        // and it does not disturb a live buffer either
        assert_eq!(orch.ingest(Event::Rate(0, 1, 3.0)), IngestResult::Buffered);
        assert_eq!(orch.ingest(Event::Shutdown), IngestResult::Ignored);
        assert_eq!(orch.buffered(), 1);
    }

    /// Relaxed flush mode: the same events apply (dims and nnz agree
    /// with an exact twin), predictions stay within the bounded-
    /// divergence contract, and the `flush.relaxed_epochs` /
    /// `flush.band<b>.train_micros` metrics surface in the registry —
    /// the `STATS` documentation contract for the new mode.
    #[test]
    fn relaxed_flush_mode_applies_and_reports_metrics() {
        let mut rng_a = Rng::seeded(67);
        let mut exact = setup(&mut rng_a);
        let mut rng_b = Rng::seeded(67);
        let mut relaxed = setup(&mut rng_b);
        for orch in [&mut exact, &mut relaxed] {
            orch.cfg.batch_size = 1_000;
            orch.cfg.queue_capacity = 100_000;
        }
        relaxed.cfg.flush_mode = FlushMode::Relaxed;
        relaxed.cfg.flush_bands = 3;
        // One growth batch well above the rotation cutoff, spread over
        // new rows and a mix of old/new columns in every band.
        let script: Vec<(u32, u32, f32)> = (0..24u32)
            .map(|q| (40 + q % 6, (q * 7) % 26, 1.0 + (q % 5) as f32))
            .collect();
        for &(i, j, r) in &script {
            assert_eq!(exact.ingest(Event::Rate(i, j, r)), relaxed.ingest(Event::Rate(i, j, r)));
        }
        assert_eq!(exact.flush(), relaxed.flush());
        assert_eq!(exact.dims(), relaxed.dims());
        assert_eq!(exact.matrix().nnz(), relaxed.matrix().nnz());
        let mut sa = crate::mf::neighbourhood::NeighbourScratch::default();
        let mut sb = crate::mf::neighbourhood::NeighbourScratch::default();
        let (m, n) = exact.dims();
        for i in (0..m).step_by(5) {
            for j in (0..n).step_by(3) {
                let a = exact.model().predict(exact.matrix(), i, j, &mut sa);
                let b = relaxed.model().predict(relaxed.matrix(), i, j, &mut sb);
                assert!(
                    (a - b).abs() < 0.05,
                    "predict({i},{j}): exact {a} vs relaxed {b}"
                );
            }
        }
        assert!(relaxed.metrics_snapshot_contains("flush.relaxed_epochs 1"));
        for b in 0..3 {
            assert!(
                relaxed.metrics_snapshot_contains(&format!("flush.band{b}.train_micros")),
                "{}",
                relaxed.metrics.snapshot()
            );
        }
        assert!(
            !exact.metrics_snapshot_contains("flush.relaxed_epochs"),
            "exact mode must leave the relaxed metrics (and STATS) untouched"
        );
    }

    /// The flush's moved-Top-K report agrees exactly with the O(N·K)
    /// band scan it replaces: a band passes `topk_band_matches` iff the
    /// report names none of its columns.
    #[test]
    fn topk_moved_report_matches_band_scan() {
        let mut rng = Rng::seeded(66);
        let mut orch = setup(&mut rng);
        let (_, n) = orch.dims();
        let d = 4usize;
        for _ in 0..3 {
            // snapshot the bands before, then flush a batch of re-rates
            // (no growth, so band boundaries are stable)
            let bands: Vec<_> = (0..d)
                .map(|b| {
                    let (lo, hi) = crate::sparse::band_range(b, n, d);
                    orch.model().col_band(lo, hi)
                })
                .collect();
            for k in 0..4u32 {
                orch.ingest(Event::Rate(k % 7, (k * 5) % n as u32, 1.5 + k as f32));
            }
            orch.ingest(Event::Flush);
            let moved = orch.last_flush_topk_moved().to_vec();
            assert!(moved.iter().all(|&j| (j as usize) < n), "{moved:?}");
            for (b, band) in bands.iter().enumerate() {
                let band_moved = moved
                    .iter()
                    .any(|&j| (j as usize) >= band.lo && (j as usize) < band.hi);
                assert_eq!(
                    orch.model().topk_band_matches(band),
                    !band_moved,
                    "band {b}: scan and report disagree (moved: {moved:?})"
                );
            }
        }
    }

    impl StreamOrchestrator {
        fn metrics_snapshot_contains(&self, name: &str) -> bool {
            self.metrics.snapshot().contains(name)
        }
    }
}
