//! Streaming ingestion orchestrator — the online-learning pipeline.
//!
//! Incremental ratings arrive as [`Event`]s; the orchestrator buffers them
//! in a bounded queue (backpressure: [`IngestResult::Rejected`] once the
//! buffer holds `queue_capacity` un-flushed events and auto-flush is
//! disabled), batches them to amortize the hash/parameter update, and on
//! flush runs Algorithm 4: absorb the batch into the saved simLSH
//! accumulators, refresh the Top-K table, and train only the new
//! variables' parameters.
//!
//! The design is caller-driven (deterministic, testable); [`run_channel`]
//! adapts it to a `std::sync::mpsc` feed for the threaded serving path.

use super::super::mf::neighbourhood::{CulshConfig, CulshModel};
use super::super::mf::online::apply_online;
use crate::lsh::OnlineHashState;
use crate::metrics::Registry;
use crate::rng::Rng;
use crate::sparse::{Csr, Triples};

/// A streaming event.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Event {
    /// A new interaction (row, col, value); ids may exceed current dims —
    /// that is how new variables enter the system.
    Rate(u32, u32, f32),
    /// Force a flush.
    Flush,
    /// Stop a channel-driven run.
    Shutdown,
}

/// Orchestrator tuning.
#[derive(Clone, Debug)]
pub struct StreamConfig {
    /// Maximum buffered (un-flushed) events.
    pub queue_capacity: usize,
    /// Auto-flush threshold.
    pub batch_size: usize,
    /// Epochs of incremental training per flush.
    pub online_epochs: usize,
    /// Reject instead of auto-flushing when the buffer fills (used to
    /// exercise backpressure; servers keep it false).
    pub reject_when_full: bool,
}

impl Default for StreamConfig {
    fn default() -> Self {
        StreamConfig {
            queue_capacity: 65_536,
            batch_size: 1_024,
            online_epochs: 5,
            reject_when_full: false,
        }
    }
}

/// Outcome of an ingest call.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum IngestResult {
    Buffered,
    Flushed { applied: usize },
    Rejected,
}

/// The streaming orchestrator: owns the model, the hash state, and the
/// combined training matrix.
pub struct StreamOrchestrator {
    /// `Option` so flush() can move the model through `apply_online`.
    model: Option<CulshModel>,
    hash_state: OnlineHashState,
    combined_t: Triples,
    combined: Csr,
    buffer: Vec<(u32, u32, f32)>,
    cfg: StreamConfig,
    train_cfg: CulshConfig,
    rng: Rng,
    metrics: Registry,
}

impl StreamOrchestrator {
    pub fn new(
        model: CulshModel,
        hash_state: OnlineHashState,
        base: Triples,
        cfg: StreamConfig,
        train_cfg: CulshConfig,
        rng: Rng,
        metrics: Registry,
    ) -> Self {
        let combined = Csr::from_triples(&base);
        StreamOrchestrator {
            model: Some(model),
            hash_state,
            combined_t: base,
            combined,
            buffer: Vec::new(),
            cfg,
            train_cfg,
            rng,
            metrics,
        }
    }

    pub fn model(&self) -> &CulshModel {
        self.model.as_ref().expect("model present outside flush")
    }

    pub fn matrix(&self) -> &Csr {
        &self.combined
    }

    pub fn buffered(&self) -> usize {
        self.buffer.len()
    }

    pub fn dims(&self) -> (usize, usize) {
        (self.combined_t.nrows(), self.combined_t.ncols())
    }

    /// Ingest one event.
    pub fn ingest(&mut self, event: Event) -> IngestResult {
        match event {
            Event::Shutdown => IngestResult::Buffered,
            Event::Flush => IngestResult::Flushed { applied: self.flush() },
            Event::Rate(i, j, r) => {
                if self.buffer.len() >= self.cfg.queue_capacity {
                    if self.cfg.reject_when_full {
                        self.metrics.counter("stream.rejected").inc();
                        return IngestResult::Rejected;
                    }
                    let applied = self.flush();
                    self.buffer.push((i, j, r));
                    self.metrics.counter("stream.ingested").inc();
                    return IngestResult::Flushed { applied };
                }
                self.buffer.push((i, j, r));
                self.metrics.counter("stream.ingested").inc();
                if self.buffer.len() >= self.cfg.batch_size {
                    let applied = self.flush();
                    return IngestResult::Flushed { applied };
                }
                IngestResult::Buffered
            }
        }
    }

    /// Apply all buffered events through Algorithm 4.
    pub fn flush(&mut self) -> usize {
        if self.buffer.is_empty() {
            return 0;
        }
        let increment = std::mem::take(&mut self.buffer);
        let new_rows = increment
            .iter()
            .map(|&(i, _, _)| i as usize + 1)
            .chain(std::iter::once(self.combined_t.nrows()))
            .max()
            .unwrap();
        let new_cols = increment
            .iter()
            .map(|&(_, j, _)| j as usize + 1)
            .chain(std::iter::once(self.combined_t.ncols()))
            .max()
            .unwrap();

        let model = self.model.take().expect("model present");
        let timer = self.metrics.histogram("stream.flush_seconds");
        let outcome = timer.time(|| {
            apply_online(
                model,
                &mut self.hash_state,
                &self.combined_t,
                &increment,
                new_rows,
                new_cols,
                &self.train_cfg,
                self.cfg.online_epochs,
                &mut self.rng,
            )
        });
        self.model = Some(outcome.model);
        self.combined = outcome.combined;
        self.combined_t.grow_to(new_rows, new_cols);
        for &(i, j, r) in &increment {
            self.combined_t.push(i as usize, j as usize, r);
        }
        self.metrics.counter("stream.flushes").inc();
        self.metrics
            .counter("stream.applied")
            .add(increment.len() as u64);
        increment.len()
    }
}

/// Drive an orchestrator from an mpsc channel until [`Event::Shutdown`];
/// returns the orchestrator for inspection.
pub fn run_channel(
    mut orch: StreamOrchestrator,
    rx: std::sync::mpsc::Receiver<Event>,
) -> StreamOrchestrator {
    for event in rx {
        if event == Event::Shutdown {
            break;
        }
        orch.ingest(event);
    }
    orch.flush();
    orch
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lsh::{NeighbourSearch, SimLsh};
    use crate::mf::neighbourhood::train_culsh_logged;
    use crate::sparse::Csc;

    fn setup(rng: &mut Rng) -> StreamOrchestrator {
        let (m, n) = (40, 20);
        let mut t = Triples::new(m, n);
        let mut seen = std::collections::HashSet::new();
        while t.nnz() < 250 {
            let (i, j) = (rng.below(m), rng.below(n));
            if seen.insert((i, j)) {
                t.push(i, j, 1.0 + rng.f32() * 4.0);
            }
        }
        let csr = Csr::from_triples(&t);
        let csc = Csc::from_triples(&t);
        let lsh = SimLsh::new(2, 6, 8, 2);
        let hash_state = OnlineHashState::build(lsh, &csc);
        let (topk, _) = hash_state.topk(4, rng);
        let cfg = CulshConfig { f: 4, k: 4, epochs: 5, ..Default::default() };
        let (model, _) = train_culsh_logged(&csr, topk, &cfg, rng);
        StreamOrchestrator::new(
            model,
            hash_state,
            t,
            StreamConfig { batch_size: 8, queue_capacity: 16, ..Default::default() },
            cfg,
            rng.split(99),
            Registry::new(),
        )
    }

    #[test]
    fn batching_flushes_at_threshold() {
        let mut rng = Rng::seeded(51);
        let mut orch = setup(&mut rng);
        for k in 0..7 {
            assert_eq!(orch.ingest(Event::Rate(1, 1 + k, 3.0)), IngestResult::Buffered);
        }
        // 8th event hits batch_size
        match orch.ingest(Event::Rate(2, 2, 4.0)) {
            IngestResult::Flushed { applied } => assert_eq!(applied, 8),
            other => panic!("expected flush, got {other:?}"),
        }
        assert_eq!(orch.buffered(), 0);
    }

    #[test]
    fn new_variables_grow_dims() {
        let mut rng = Rng::seeded(52);
        let mut orch = setup(&mut rng);
        let (m0, n0) = orch.dims();
        orch.ingest(Event::Rate(m0 as u32 + 2, n0 as u32 + 5, 4.5));
        orch.ingest(Event::Flush);
        let (m1, n1) = orch.dims();
        assert_eq!(m1, m0 + 3);
        assert_eq!(n1, n0 + 6);
        // model grew too
        assert_eq!(orch.model().base.bi.len(), m1);
        assert_eq!(orch.model().base.bj.len(), n1);
        assert_eq!(orch.model().topk.n(), n1);
    }

    #[test]
    fn backpressure_rejects_when_configured() {
        let mut rng = Rng::seeded(53);
        let mut orch = setup(&mut rng);
        orch.cfg.reject_when_full = true;
        orch.cfg.queue_capacity = 4;
        orch.cfg.batch_size = 100; // no auto-flush
        for k in 0..4 {
            assert_eq!(orch.ingest(Event::Rate(0, k, 3.0)), IngestResult::Buffered);
        }
        assert_eq!(orch.ingest(Event::Rate(0, 9, 3.0)), IngestResult::Rejected);
        orch.ingest(Event::Flush);
        assert_eq!(orch.ingest(Event::Rate(0, 9, 3.0)), IngestResult::Buffered);
    }

    #[test]
    fn channel_runner_drains_and_stops() {
        let mut rng = Rng::seeded(54);
        let orch = setup(&mut rng);
        let (tx, rx) = std::sync::mpsc::channel();
        let handle = std::thread::spawn(move || run_channel(orch, rx));
        for k in 0..5 {
            tx.send(Event::Rate(3, k, 2.5)).unwrap();
        }
        tx.send(Event::Shutdown).unwrap();
        let orch = handle.join().unwrap();
        assert_eq!(orch.buffered(), 0);
        assert_eq!(orch.metrics_snapshot_contains("stream.applied"), true);
    }

    impl StreamOrchestrator {
        fn metrics_snapshot_contains(&self, name: &str) -> bool {
            self.metrics.snapshot().contains(name)
        }
    }
}
