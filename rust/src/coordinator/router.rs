//! The route tier: one [`Router`] fronts N downstream `serve` processes
//! over the binary codec, so the serving surface scales across nodes
//! instead of only across threads.
//!
//! **Partition map.** Column ids are banded over `[0, cols)` with
//! [`band_of`](crate::sparse::band_of) — the same Latin-square split
//! every in-process layer shards by — one band per backend, declared in
//! `[[route.backend]]` order. Ids at or beyond `cols` clamp into the
//! last band.
//!
//! **Writes replicate, reads partition.** The Eq. (1) neighbourhood
//! scan reads the *whole* rating row, so a backend holding only its
//! band's ratings could not answer bit-identically to a monolith.
//! Every mutating verb (`RATE`/`MRATE`/`FLUSH`) is therefore fanned out
//! to **all** backends in one global arrival order (deterministic
//! lock-step replicas — the "replicate for read fan-out" arm of the
//! ROADMAP item); column-band ownership governs the *read* path and
//! which replica's write reply is authoritative. `PREDICT` routes to
//! the owner of its column; `MPREDICT` splits its columns by owner and
//! reassembles by position; `TOPN` scatters, keeps each backend's items
//! that it owns, and merges under the engine's `rank_cmp`; `STATS`
//! aggregates; `FLUSH` is a cross-backend barrier.
//!
//! **Fault surface.** Each backend has one ordered *write lane* thread
//! (persistent pipelined [`LshmfClient`]) and a small read-connection
//! pool. A dead backend answers typed
//! [`ErrorKind::Unavailable`] — never a hang: router connections carry
//! a read deadline (`[route] io_timeout_ms`), reads retry with capped
//! jittered backoff before giving up, and a probe loop keeps poking
//! down backends so recovery is automatic. Writes a down replica missed
//! are kept in its lane's replay queue and re-applied in order on
//! reconnect (at-least-once: a batch that failed mid-pipeline may be
//! partially applied, then replayed; `RATE` re-application is
//! last-write-wins per cell, and the replica is marked up only once the
//! replay drains).
//!
//! # Invariants
//!
//! * **No lock is held across backend IO.** The global order lock is
//!   held only while enqueueing a write into every lane (in-memory
//!   channel sends); lane IO runs on the lane threads, and the read
//!   path checks a connection out of the pool before touching the
//!   socket. A slow or dead backend can therefore never wedge requests
//!   for the others.
//! * **Merge determinism.** Scatter/gather replies are merged under
//!   the same total order the engines rank by (`rank_cmp`: score desc,
//!   NaN last, col id asc) after filtering each backend's reply to the
//!   columns it owns, so a merged `TOPN` is bit-identical to a
//!   monolith's.
//! * **Write order is global.** All lanes see mutating verbs in the
//!   same relative order (the order lock), and each lane is a single
//!   thread draining a FIFO — replicas that stay connected apply the
//!   identical event sequence, and the barrier reply waits for every
//!   lane so a subsequent read cannot observe a half-applied write.
//! * **Health-state transitions are counted and monotonic per
//!   observation.** `up -> down` happens where a failure is proven (IO
//!   error after retries, lane batch failure); `down -> up` only where
//!   recovery is proven (lane reconnected *and* drained its replay
//!   queue). Each flip increments `router.backend{i}.health_transitions`.

use super::client::{ClientCodec, LshmfClient};
use super::engine::rank_cmp;
use super::protocol::{
    ErrorKind, Request, Response, MAX_MPREDICT_COLS, MAX_MRATE_EVENTS, MAX_TOPN_ITEMS,
    MPREDICT_USAGE, MRATE_USAGE, SUBSCRIBE_USAGE, TOPN_USAGE,
};
use crate::config::RouteConfig;
use crate::coordinator::cache::PushSink;
use crate::coordinator::server::Dispatch;
use crate::metrics::{Counter, Gauge, Registry};
use crate::rng::Rng;
use crate::sparse::band_of;
use std::collections::VecDeque;
use std::io;
use std::net::TcpStream;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Most write jobs one lane batch drains into a single pipeline flush.
const WRITE_BATCH: usize = 32;
/// Read connections kept per backend.
const READ_POOL_CAP: usize = 4;

/// One queued mutating request plus the slot its reply must land in.
/// The lane **always** fulfils the slot (with the backend's reply or
/// `Unavailable`) — a dropped sender would strand the write barrier.
struct WriteJob {
    req: Request,
    reply: Sender<Response>,
}

enum Job {
    Write(WriteJob),
    /// Probe tick: liveness-check an up backend, drive reconnect +
    /// replay on a down one.
    Probe,
}

/// Per-backend shared state (the lane thread holds its own `Arc`s to
/// the pieces it needs, so dropping the core never races the lane).
struct Backend {
    addr: String,
    up: Arc<AtomicBool>,
    lane: Mutex<Option<Sender<Job>>>,
    pool: Mutex<Vec<LshmfClient>>,
    transitions: Arc<Counter>,
}

/// Connect with the router's socket policy: binary codec, read deadline
/// so a silent backend surfaces as an IO timeout instead of a hang.
fn connect_backend(addr: &str, io_timeout_ms: u64) -> io::Result<LshmfClient> {
    let stream = TcpStream::connect(addr)?;
    if io_timeout_ms > 0 {
        stream.set_read_timeout(Some(Duration::from_millis(io_timeout_ms)))?;
    }
    LshmfClient::from_stream(stream, ClientCodec::Binary)
}

/// Flip the health flag, counting actual transitions only.
fn set_health(up: &AtomicBool, transitions: &Counter, healthy: bool) {
    if up.swap(healthy, Ordering::SeqCst) != healthy {
        transitions.inc();
    }
}

/// The write-lane thread: owns the persistent pipelined connection to
/// one backend, drains its FIFO in batches, and runs the
/// reconnect/replay machinery. Everything it shares with the core is
/// behind `Arc`s — it never holds the core itself.
struct Lane {
    index: usize,
    addr: String,
    rx: Receiver<Job>,
    up: Arc<AtomicBool>,
    transitions: Arc<Counter>,
    replayed: Arc<Counter>,
    retries: Arc<Counter>,
    depth: Arc<Gauge>,
    backoff_ms: u64,
    backoff_max_ms: u64,
    io_timeout_ms: u64,
    rng: Rng,
}

impl Lane {
    fn run(mut self) {
        let mut client: Option<LshmfClient> = None;
        let mut replay: VecDeque<Request> = VecDeque::new();
        let mut fails: u32 = 0;
        let mut next_attempt = Instant::now();
        loop {
            let first = match self.rx.recv() {
                Ok(job) => job,
                Err(_) => break, // all senders gone: shut down
            };
            let mut batch: Vec<WriteJob> = Vec::new();
            let mut probed = matches!(first, Job::Probe);
            if let Job::Write(w) = first {
                batch.push(w);
            }
            while batch.len() < WRITE_BATCH {
                match self.rx.try_recv() {
                    Ok(Job::Write(w)) => batch.push(w),
                    Ok(Job::Probe) => probed = true,
                    Err(_) => break,
                }
            }
            self.depth.set((batch.len() + replay.len()) as f64);

            // (Re)connect, gated by the jittered backoff deadline so a
            // flapping backend is not hammered.
            if client.is_none() && Instant::now() >= next_attempt {
                if fails > 0 {
                    self.retries.inc();
                }
                match connect_backend(&self.addr, self.io_timeout_ms) {
                    Ok(c) => {
                        client = Some(c);
                        fails = 0;
                    }
                    Err(_) => {
                        fails += 1;
                        next_attempt = Instant::now() + self.backoff(fails);
                    }
                }
            }
            // Catch-up before any new work: the replica must re-apply
            // everything it missed, in order, before it counts as up.
            if !replay.is_empty() {
                if let Some(c) = client.as_mut() {
                    match replay_all(c, &mut replay) {
                        Ok(n) => self.replayed.add(n),
                        Err(_) => {
                            client = None;
                            fails += 1;
                            next_attempt = Instant::now() + self.backoff(fails);
                        }
                    }
                }
            }
            let ready = client.is_some() && replay.is_empty();
            set_health(&self.up, &self.transitions, ready);

            if batch.is_empty() {
                // Pure probe tick on a healthy lane: one cheap STATS
                // round-trip proves the connection still answers.
                if probed && ready {
                    if let Some(c) = client.as_mut() {
                        if c.request(&Request::Stats).is_err() {
                            self.retries.inc();
                            client = None;
                            fails += 1;
                            next_attempt = Instant::now() + self.backoff(fails);
                            set_health(&self.up, &self.transitions, false);
                        }
                    }
                }
                self.depth.set(replay.len() as f64);
                continue;
            }
            if !ready {
                // Answer now (typed, never a hang) and journal for the
                // at-least-once catch-up.
                for w in batch {
                    let _ = w.reply.send(Response::Error(ErrorKind::Unavailable));
                    replay.push_back(w.req);
                }
                self.depth.set(replay.len() as f64);
                continue;
            }
            let c = client.as_mut().expect("ready implies connected");
            match send_batch(c, &batch) {
                Ok(replies) => {
                    for (w, r) in batch.into_iter().zip(replies) {
                        let _ = w.reply.send(r);
                    }
                    self.depth.set(0.0);
                }
                Err(_) => {
                    self.retries.inc();
                    for w in batch {
                        let _ = w.reply.send(Response::Error(ErrorKind::Unavailable));
                        replay.push_back(w.req);
                    }
                    client = None;
                    fails += 1;
                    next_attempt = Instant::now() + self.backoff(fails);
                    set_health(&self.up, &self.transitions, false);
                    self.depth.set(replay.len() as f64);
                }
            }
        }
        // Drain-on-shutdown: one last attempt to land journaled writes
        // on a backend that is reachable again.
        if !replay.is_empty() {
            if client.is_none() {
                client = connect_backend(&self.addr, self.io_timeout_ms).ok();
            }
            if let Some(c) = client.as_mut() {
                if let Ok(n) = replay_all(c, &mut replay) {
                    self.replayed.add(n);
                }
            }
        }
        let _ = self.index;
    }

    /// Exponential, capped, jittered: `base * 2^(fails-1)` up to the
    /// cap, plus up to half a base of jitter so a fleet of lanes does
    /// not reconnect in lock-step.
    fn backoff(&mut self, fails: u32) -> Duration {
        let base = self.backoff_ms.max(1);
        let exp = base.saturating_mul(1u64 << fails.saturating_sub(1).min(6));
        let capped = exp.min(self.backoff_max_ms.max(base));
        let jitter = self.rng.below((base / 2 + 1) as usize) as u64;
        Duration::from_millis(capped + jitter)
    }
}

/// Pipeline `replay` into the backend until drained; on success the
/// queue is empty. Replies are discarded — their slots were already
/// answered `Unavailable` when the writes were journaled.
fn replay_all(c: &mut LshmfClient, replay: &mut VecDeque<Request>) -> io::Result<u64> {
    let mut applied = 0u64;
    while !replay.is_empty() {
        let take = replay.len().min(WRITE_BATCH);
        let mut pipe = c.pipeline();
        for req in replay.iter().take(take) {
            pipe.push(req)?;
        }
        let replies = pipe.finish()?;
        if replies.len() != take {
            return Err(io::Error::new(
                io::ErrorKind::UnexpectedEof,
                "short pipeline reply during replay",
            ));
        }
        for _ in 0..take {
            replay.pop_front();
        }
        applied += take as u64;
    }
    Ok(applied)
}

/// One pipelined flush of a write batch; exactly one reply per job.
fn send_batch(c: &mut LshmfClient, batch: &[WriteJob]) -> io::Result<Vec<Response>> {
    let mut pipe = c.pipeline();
    for w in batch {
        pipe.push(&w.req)?;
    }
    let replies = pipe.finish()?;
    if replies.len() != batch.len() {
        return Err(io::Error::new(
            io::ErrorKind::UnexpectedEof,
            "short pipeline reply",
        ));
    }
    Ok(replies)
}

struct RouterCore {
    cfg: RouteConfig,
    registry: Registry,
    backends: Vec<Backend>,
    /// The global write-order lock (see module invariants): held only
    /// around the in-memory enqueue into every lane.
    order: Mutex<()>,
    retries: Arc<Counter>,
    unavailable: Arc<Counter>,
    divergence: Arc<Counter>,
    jitter: Mutex<Rng>,
    stop: Arc<AtomicBool>,
    probe: Mutex<Option<JoinHandle<()>>>,
    lanes: Mutex<Vec<JoinHandle<()>>>,
}

/// The scatter/gather front end over a `[[route.backend]]` fleet.
/// Cheaply cloneable (one shared core); implements
/// [`Dispatch`], so [`serve_route`](super::server::serve_route) runs it
/// behind the same connection pool, codecs, and admission as any
/// engine. Dropping the last clone drains the write lanes and joins
/// every router thread.
#[derive(Clone)]
pub struct Router {
    core: Arc<RouterCore>,
}

impl Router {
    /// Spawn the lane and probe threads for `cfg.backends`. Backends
    /// start optimistically `up`; the first proven failure flips them.
    pub fn new(cfg: &RouteConfig, registry: Registry) -> Router {
        let retries = registry.counter("router.retries");
        let unavailable = registry.counter("router.unavailable");
        let divergence = registry.counter("router.divergence");
        let mut backends = Vec::with_capacity(cfg.backends.len());
        let mut lane_threads = Vec::with_capacity(cfg.backends.len());
        let mut probe_senders = Vec::with_capacity(cfg.backends.len());
        for (i, spec) in cfg.backends.iter().enumerate() {
            let up = Arc::new(AtomicBool::new(true));
            let transitions =
                registry.counter(&format!("router.backend{i}.health_transitions"));
            let replayed = registry.counter(&format!("router.backend{i}.replayed"));
            let depth = registry.gauge(&format!("router.backend{i}.depth"));
            let (tx, rx) = channel();
            let lane = Lane {
                index: i,
                addr: spec.addr.clone(),
                rx,
                up: Arc::clone(&up),
                transitions: Arc::clone(&transitions),
                replayed,
                retries: Arc::clone(&retries),
                depth,
                backoff_ms: cfg.retry_backoff_ms,
                backoff_max_ms: cfg.retry_backoff_max_ms,
                io_timeout_ms: cfg.io_timeout_ms,
                rng: Rng::seeded(0x9070_5e5e ^ i as u64),
            };
            lane_threads.push(std::thread::spawn(move || lane.run()));
            probe_senders.push(tx.clone());
            backends.push(Backend {
                addr: spec.addr.clone(),
                up,
                lane: Mutex::new(Some(tx)),
                pool: Mutex::new(Vec::new()),
                transitions,
            });
        }
        let stop = Arc::new(AtomicBool::new(false));
        let probe = {
            let stop = Arc::clone(&stop);
            let interval = Duration::from_millis(cfg.probe_interval_ms.max(1));
            std::thread::spawn(move || {
                let tick = Duration::from_millis(10);
                let mut waited = Duration::ZERO;
                while !stop.load(Ordering::Relaxed) {
                    std::thread::sleep(tick.min(interval));
                    waited += tick;
                    if waited >= interval {
                        waited = Duration::ZERO;
                        for lane in &probe_senders {
                            let _ = lane.send(Job::Probe);
                        }
                    }
                }
            })
        };
        Router {
            core: Arc::new(RouterCore {
                cfg: cfg.clone(),
                registry,
                backends,
                order: Mutex::new(()),
                retries,
                unavailable,
                divergence,
                jitter: Mutex::new(Rng::seeded(0x9070_5e5f)),
                stop,
                probe: Mutex::new(Some(probe)),
                lanes: Mutex::new(lane_threads),
            }),
        }
    }

    /// The registry the `router.*` metrics (and the front end's
    /// `server.*` counters) land in.
    pub fn registry(&self) -> &Registry {
        &self.core.registry
    }

    /// Fleet width (one column band per backend).
    pub fn backend_count(&self) -> usize {
        self.core.backends.len()
    }

    /// Is backend `i` currently considered healthy?
    pub fn backend_up(&self, i: usize) -> bool {
        self.core.backends[i].up.load(Ordering::SeqCst)
    }
}

impl Dispatch for Router {
    fn handle(&self, req: &Request) -> Response {
        self.core.handle(req)
    }

    fn metrics(&self) -> Registry {
        self.core.registry.clone()
    }

    fn subscribe(&self, _sink: PushSink) -> Option<u64> {
        // The router has no publish stream of its own to tap; the
        // connection layer answers the typed SUBSCRIBE usage error.
        None
    }
}

impl RouterCore {
    /// Which backend owns column `col` (clamping ids beyond the
    /// configured extent into the last band).
    fn owner(&self, col: usize) -> usize {
        let d = self.backends.len();
        band_of(col.min(self.cfg.cols.saturating_sub(1)), self.cfg.cols, d).min(d - 1)
    }

    /// Request-level validation mirrors [`dispatch`]
    /// (`super::server::dispatch`) exactly — caps and usage errors must
    /// not depend on which tier answers. The router parity test drives
    /// the same scripts through both and catches drift.
    fn handle(&self, req: &Request) -> Response {
        match req {
            Request::Predict { row: _, col } => self.read_at(self.owner(*col), req),
            Request::MPredict { row, cols } => {
                if cols.is_empty() {
                    return Response::Error(ErrorKind::Usage(MPREDICT_USAGE.into()));
                }
                if cols.len() > MAX_MPREDICT_COLS {
                    return Response::Error(ErrorKind::TooManyCols);
                }
                self.mpredict(*row, cols)
            }
            Request::TopN { row: _, n } => {
                if *n == 0 {
                    return Response::Error(ErrorKind::Usage(TOPN_USAGE.into()));
                }
                if *n > MAX_TOPN_ITEMS {
                    return Response::Error(ErrorKind::TooManyItems);
                }
                self.topn_scatter(req, *n)
            }
            Request::Rate { col, .. } => self.write_all(req, Some(self.owner(*col as usize))),
            Request::MRate { ratings } => {
                if ratings.is_empty() {
                    return Response::Error(ErrorKind::Usage(MRATE_USAGE.into()));
                }
                if ratings.len() > MAX_MRATE_EVENTS {
                    return Response::Error(ErrorKind::TooManyEvents);
                }
                let owner = self.owner(ratings[0].1 as usize);
                self.write_all(req, Some(owner))
            }
            Request::Flush => self.write_all(req, None),
            Request::Stats => self.stats(),
            Request::Subscribe => Response::Error(ErrorKind::Usage(SUBSCRIBE_USAGE.into())),
            Request::Shutdown => Response::Bye,
        }
    }

    /// One read against backend `b`: pool checkout, IO unlocked, retry
    /// with capped jittered backoff, typed `Unavailable` when the
    /// backend is (or becomes) down.
    fn read_at(&self, b: usize, req: &Request) -> Response {
        let backend = &self.backends[b];
        if !backend.up.load(Ordering::SeqCst) {
            self.unavailable.inc();
            return Response::Error(ErrorKind::Unavailable);
        }
        let attempts = self.cfg.retry_attempts.max(1);
        for attempt in 0..attempts as u32 {
            if attempt > 0 {
                self.retries.inc();
                std::thread::sleep(self.read_backoff(attempt));
            }
            let pooled = backend.pool.lock().unwrap_or_else(|e| e.into_inner()).pop();
            let mut client = match pooled {
                Some(c) => c,
                None => match connect_backend(&backend.addr, self.cfg.io_timeout_ms) {
                    Ok(c) => c,
                    Err(_) => continue,
                },
            };
            match client.request(req) {
                Ok(resp) => {
                    let mut pool = backend.pool.lock().unwrap_or_else(|e| e.into_inner());
                    if pool.len() < READ_POOL_CAP {
                        pool.push(client);
                    }
                    return resp;
                }
                Err(_) => continue, // poisoned connection: drop, retry fresh
            }
        }
        set_health(&backend.up, &backend.transitions, false);
        self.unavailable.inc();
        Response::Error(ErrorKind::Unavailable)
    }

    fn read_backoff(&self, attempt: u32) -> Duration {
        let base = self.cfg.retry_backoff_ms.max(1);
        let exp = base.saturating_mul(1u64 << attempt.saturating_sub(1).min(6));
        let capped = exp.min(self.cfg.retry_backoff_max_ms.max(base));
        let jitter = {
            let mut rng = self.jitter.lock().unwrap_or_else(|e| e.into_inner());
            rng.below((base / 2 + 1) as usize) as u64
        };
        Duration::from_millis(capped + jitter)
    }

    /// `MPREDICT`: split the columns by owner (positions remembered),
    /// sub-request each owner, reassemble in request order. Any
    /// sub-error is the whole reply's error — replicas agree on
    /// row-level errors, so this matches the monolith.
    fn mpredict(&self, row: usize, cols: &[u32]) -> Response {
        let d = self.backends.len();
        let mut per: Vec<Vec<u32>> = vec![Vec::new(); d];
        let mut pos: Vec<Vec<usize>> = vec![Vec::new(); d];
        for (i, &c) in cols.iter().enumerate() {
            let b = self.owner(c as usize);
            per[b].push(c);
            pos[b].push(i);
        }
        let mut out: Vec<Option<f32>> = vec![None; cols.len()];
        for b in 0..d {
            if per[b].is_empty() {
                continue;
            }
            let sub = Request::MPredict { row, cols: per[b].clone() };
            match self.read_at(b, &sub) {
                Response::Preds(preds) if preds.len() == pos[b].len() => {
                    for (slot, p) in pos[b].iter().zip(preds) {
                        out[*slot] = p;
                    }
                }
                Response::Error(kind) => return Response::Error(kind),
                _ => return Response::Error(ErrorKind::Unavailable),
            }
        }
        Response::Preds(out)
    }

    /// `TOPN`: scatter the full request, keep from each reply only the
    /// columns that backend owns, merge under `rank_cmp`, truncate.
    /// Each replica's reply is the *global* top-n, so the owned
    /// fragments cover the monolith's list and the merge reproduces it
    /// bit for bit (see module invariants).
    fn topn_scatter(&self, req: &Request, n_items: usize) -> Response {
        let mut merged: Vec<(u32, f32)> = Vec::new();
        for b in 0..self.backends.len() {
            match self.read_at(b, req) {
                Response::TopN(items) => {
                    merged.extend(
                        items
                            .into_iter()
                            .filter(|(c, _)| self.owner(*c as usize) == b),
                    );
                }
                Response::Error(kind) => return Response::Error(kind),
                _ => return Response::Error(ErrorKind::Unavailable),
            }
        }
        merged.sort_by(rank_cmp);
        merged.truncate(n_items);
        Response::TopN(merged)
    }

    /// Replicated write: enqueue into every lane under the order lock,
    /// then wait for every reply (the lock-step barrier). The owner's
    /// reply is authoritative; `FLUSH` (no owner) answers with the
    /// lowest-indexed live reply. Replicas answering differently is a
    /// replication bug — counted into `router.divergence`.
    fn write_all(&self, req: &Request, owner: Option<usize>) -> Response {
        if let Some(o) = owner {
            if !self.backends[o].up.load(Ordering::SeqCst) {
                // Reject up front, enqueuing nowhere: the replicas stay
                // mutually identical (none of them sees this write).
                self.unavailable.inc();
                return Response::Error(ErrorKind::Unavailable);
            }
        }
        let mut waits: Vec<Option<Receiver<Response>>> =
            Vec::with_capacity(self.backends.len());
        {
            let _order = self.order.lock().unwrap_or_else(|e| e.into_inner());
            for backend in &self.backends {
                let (tx, rx) = channel();
                let sent = backend
                    .lane
                    .lock()
                    .unwrap_or_else(|e| e.into_inner())
                    .as_ref()
                    .map(|lane| {
                        lane.send(Job::Write(WriteJob { req: req.clone(), reply: tx }))
                            .is_ok()
                    })
                    .unwrap_or(false);
                waits.push(if sent { Some(rx) } else { None });
            }
        }
        let replies: Vec<Response> = waits
            .into_iter()
            .map(|rx| match rx {
                // A lane always fulfils its slot; a dropped sender
                // (shutdown race) degrades to the typed error.
                Some(rx) => rx
                    .recv()
                    .unwrap_or(Response::Error(ErrorKind::Unavailable)),
                None => Response::Error(ErrorKind::Unavailable),
            })
            .collect();
        let mut canon: Option<&Response> = None;
        for r in &replies {
            if matches!(r, Response::Error(ErrorKind::Unavailable)) {
                continue;
            }
            match canon {
                None => canon = Some(r),
                Some(c) if c != r => self.divergence.inc(),
                _ => {}
            }
        }
        let reply = match owner {
            Some(o) => replies[o].clone(),
            None => replies
                .iter()
                .find(|r| !matches!(r, Response::Error(ErrorKind::Unavailable)))
                .cloned()
                .unwrap_or(Response::Error(ErrorKind::Unavailable)),
        };
        if matches!(reply, Response::Error(ErrorKind::Unavailable)) {
            self.unavailable.inc();
        }
        reply
    }

    /// `STATS`: the router's own registry snapshot plus every
    /// reachable backend's stats body, each line prefixed
    /// `backend{i}.`; down backends report `backend{i} down`.
    fn stats(&self) -> Response {
        let d = self.backends.len();
        let mut up_count = 0usize;
        let mut lines: Vec<String> = Vec::new();
        for i in 0..d {
            match self.read_at(i, &Request::Stats) {
                Response::Stats(body) => {
                    up_count += 1;
                    lines.push(format!("backend{i} up"));
                    for l in body.lines() {
                        lines.push(format!("backend{i}.{l}"));
                    }
                }
                _ => lines.push(format!("backend{i} down")),
            }
        }
        let mut body = format!("router backends {d}\nrouter up {up_count}\n");
        body.push_str(self.registry.snapshot().trim_end());
        body.push('\n');
        for l in lines {
            body.push_str(&l);
            body.push('\n');
        }
        while body.ends_with('\n') {
            body.pop();
        }
        Response::Stats(body)
    }
}

impl Drop for RouterCore {
    fn drop(&mut self) {
        // Stop the probe first — it holds lane-sender clones, so the
        // lanes cannot drain until it exits.
        self.stop.store(true, Ordering::SeqCst);
        // Take the handle in its own statement so the lock temporary
        // dies before the join — a guard never spans a blocking join.
        let probe = self.probe.lock().unwrap_or_else(|e| e.into_inner()).take();
        if let Some(probe) = probe {
            let _ = probe.join();
        }
        for backend in &self.backends {
            *backend.lane.lock().unwrap_or_else(|e| e.into_inner()) = None;
        }
        let lanes =
            std::mem::take(&mut *self.lanes.lock().unwrap_or_else(|e| e.into_inner()));
        for lane in lanes {
            let _ = lane.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::RouteBackend;

    fn cfg(addrs: &[&str], cols: usize) -> RouteConfig {
        RouteConfig {
            cols,
            probe_interval_ms: 25,
            retry_backoff_ms: 2,
            retry_backoff_max_ms: 20,
            retry_attempts: 2,
            io_timeout_ms: 500,
            backends: addrs.iter().map(|a| RouteBackend { addr: a.to_string() }).collect(),
        }
    }

    #[test]
    fn owner_map_covers_and_clamps() {
        let router = Router::new(&cfg(&["127.0.0.1:1", "127.0.0.1:2", "127.0.0.1:3"], 30),
                                 Registry::new());
        let core = &router.core;
        for col in 0..30 {
            let b = core.owner(col);
            assert!(b < 3, "col {col} -> band {b}");
            assert_eq!(b, crate::sparse::band_of(col, 30, 3));
        }
        // beyond the extent clamps into the last band
        assert_eq!(core.owner(30), 2);
        assert_eq!(core.owner(1_000_000), 2);
    }

    #[test]
    fn dead_fleet_answers_typed_unavailable_not_hangs() {
        // Nothing listens on these ports: every verb must come back as
        // a typed error (reads via connect failure, writes via the
        // lane's journal path), and shutdown must join cleanly.
        let router = Router::new(&cfg(&["127.0.0.1:9", "127.0.0.1:9"], 10), Registry::new());
        let unavailable = Response::Error(ErrorKind::Unavailable);
        assert_eq!(router.handle(&Request::Predict { row: 0, col: 1 }), unavailable);
        assert_eq!(
            router.handle(&Request::Rate { row: 0, col: 1, value: 1.0 }),
            unavailable
        );
        assert_eq!(router.handle(&Request::Flush), unavailable);
        assert_eq!(router.handle(&Request::TopN { row: 0, n: 3 }), unavailable);
        // validation still answers locally, exactly like dispatch
        assert!(matches!(
            router.handle(&Request::TopN { row: 0, n: 0 }),
            Response::Error(ErrorKind::Usage(_))
        ));
        assert!(matches!(
            router.handle(&Request::MRate { ratings: vec![] }),
            Response::Error(ErrorKind::Usage(_))
        ));
        assert_eq!(router.handle(&Request::Shutdown), Response::Bye);
        // STATS aggregates even with the whole fleet down
        match router.handle(&Request::Stats) {
            Response::Stats(body) => {
                assert!(body.contains("router backends 2"), "{body}");
                assert!(body.contains("router up 0"), "{body}");
                assert!(body.contains("backend0 down"), "{body}");
            }
            other => panic!("STATS answered {other:?}"),
        }
        assert!(router.registry().counter("router.unavailable").get() > 0);
    }
}
