//! Concurrent serving core: epoch-swapped read snapshots over a single
//! writer thread.
//!
//! The original server serialized *every* request — reads included —
//! behind one `Mutex<Engine>`, so a flush (incremental retraining, tens
//! of milliseconds and up) stalled all traffic. Following the cuMF line
//! of work (Tan et al.), throughput comes from separating the
//! read-mostly factor state from the serialized update stream:
//!
//! * **Reads** (`PREDICT` / `TOPN` / `STATS`) clone an `Arc<Snapshot>`
//!   out of an `RwLock` held for nanoseconds, then compute entirely
//!   lock-free on the immutable snapshot. Any number of connections read
//!   in parallel, *including while a flush is running*.
//! * **Writes** (`RATE` / `FLUSH`) are funnelled through an `mpsc`
//!   channel into one writer thread that owns the [`Engine`] (and with
//!   it the [`super::stream::StreamOrchestrator`] online path), exactly
//!   preserving the paper's single-writer online model. After each
//!   flush the writer publishes a fresh snapshot by swapping the `Arc`.
//!
//! Readers therefore always see a complete, internally consistent
//! (model, matrix) pair — torn reads are impossible by construction —
//! and snapshot `version`s increase monotonically.
//!
//! Metrics (all in the engine's [`Registry`]): per-verb counters
//! (`server.predict`, `server.topn`, `server.rate`, `server.flush`,
//! `server.stats`), lock/queue wait histograms (`shared.read_wait`,
//! `shared.write_wait`, `shared.publish_wait`) and the
//! `shared.read_wait_last_ns` gauge.

use super::engine::{rank_unrated, Engine};
use super::stream::IngestResult;
use crate::metrics::Registry;
use crate::mf::neighbourhood::{CulshModel, NeighbourScratch};
use crate::sparse::Csr;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, RwLock};
use std::thread::JoinHandle;
use std::time::Instant;

/// An immutable view of the factor state, published by the writer after
/// every flush.
pub struct Snapshot {
    /// The CULSH-MF model as of the last flush.
    pub model: CulshModel,
    /// The combined training matrix the model was flushed against.
    pub matrix: Csr,
    /// Monotonic publication counter (0 at spawn, +1 per flush).
    pub version: u64,
}

impl Snapshot {
    pub fn dims(&self) -> (usize, usize) {
        (self.matrix.nrows(), self.matrix.ncols())
    }
}

/// A write-path request for the single writer thread.
enum WriteCmd {
    Rate { i: u32, j: u32, r: f32, reply: Sender<IngestResult> },
    Flush { reply: Sender<usize> },
    Shutdown,
}

/// Cloneable handle to the concurrent serving core. Each connection
/// thread clones one; reads are lock-free after an `Arc` clone, writes
/// round-trip through the writer thread.
#[derive(Clone)]
pub struct SharedEngine {
    state: Arc<RwLock<Arc<Snapshot>>>,
    tx: Sender<WriteCmd>,
    buffered: Arc<AtomicUsize>,
    clamp: (f32, f32),
    metrics: Registry,
}

/// Owns the writer thread; [`WriterHandle::join`] stops it (flushing any
/// buffered events) and returns the engine for inspection.
pub struct WriterHandle {
    handle: JoinHandle<Engine>,
    tx: Sender<WriteCmd>,
}

impl WriterHandle {
    /// Request shutdown and wait for the writer to drain.
    pub fn join(self) -> Engine {
        let _ = self.tx.send(WriteCmd::Shutdown);
        self.handle.join().expect("writer thread panicked")
    }
}

impl SharedEngine {
    /// Split an [`Engine`] into a concurrent read handle plus its single
    /// writer thread. Uses the engine's own metric registry, so engine-
    /// and server-level counters land in one `STATS` report.
    pub fn spawn(engine: Engine) -> (SharedEngine, WriterHandle) {
        let clamp = engine.clamp();
        let metrics = engine.metrics().clone();
        let initial = Arc::new(Snapshot {
            model: engine.model().clone(),
            matrix: engine.matrix().clone(),
            version: 0,
        });
        let state = Arc::new(RwLock::new(initial));
        let buffered = Arc::new(AtomicUsize::new(engine.buffered()));
        let (tx, rx) = channel();
        let handle = {
            let state = Arc::clone(&state);
            let buffered = Arc::clone(&buffered);
            let metrics = metrics.clone();
            std::thread::spawn(move || writer_loop(engine, rx, state, buffered, metrics))
        };
        let shared = SharedEngine { state, tx: tx.clone(), buffered, clamp, metrics };
        (shared, WriterHandle { handle, tx })
    }

    /// Clone the current snapshot out of the lock (held only for the
    /// `Arc` clone; all computation afterwards is lock-free).
    pub fn snapshot(&self) -> Arc<Snapshot> {
        let t0 = Instant::now();
        let guard = self.state.read().unwrap_or_else(|e| e.into_inner());
        let snap = Arc::clone(&guard);
        drop(guard);
        let waited = t0.elapsed();
        self.metrics.histogram("shared.read_wait").record(waited);
        self.metrics.gauge("shared.read_wait_last_ns").set(waited.as_nanos() as f64);
        snap
    }

    /// Dimensions of the last-published snapshot.
    pub fn dims(&self) -> (usize, usize) {
        self.snapshot().dims()
    }

    /// Version of the last-published snapshot (monotonic).
    pub fn version(&self) -> u64 {
        self.snapshot().version
    }

    /// Predict the interaction value for (row, col) on the current
    /// snapshot. `None` if out of range.
    pub fn predict(&self, i: usize, j: usize) -> Option<f32> {
        self.metrics.counter("server.predict").inc();
        let snap = self.snapshot();
        let (m, n) = snap.dims();
        if i >= m || j >= n {
            return None;
        }
        let mut scratch = NeighbourScratch::default();
        let raw = snap.model.predict(&snap.matrix, i, j, &mut scratch);
        Some(raw.clamp(self.clamp.0, self.clamp.1))
    }

    /// Top-N highest-predicted unrated columns for a row, on the current
    /// snapshot.
    pub fn top_n(&self, i: usize, n_items: usize) -> Vec<(u32, f32)> {
        self.metrics.counter("server.topn").inc();
        let snap = self.snapshot();
        let (m, _) = snap.dims();
        if i >= m {
            return Vec::new();
        }
        rank_unrated(&snap.model, &snap.matrix, i, n_items, self.clamp)
    }

    /// Ingest a rating through the single-writer online path. Blocks
    /// until the writer replies, so backpressure (`Rejected`) and flush
    /// outcomes surface synchronously — the protocol semantics match the
    /// single-threaded engine exactly.
    pub fn rate(&self, i: u32, j: u32, r: f32) -> IngestResult {
        self.metrics.counter("server.rate").inc();
        let timer = self.metrics.timer("shared.write_wait");
        let (reply_tx, reply_rx) = channel();
        if self.tx.send(WriteCmd::Rate { i, j, r, reply: reply_tx }).is_err() {
            // Writer is gone (shutdown): surface as backpressure rather
            // than panicking a connection thread.
            return IngestResult::Rejected;
        }
        let result = reply_rx.recv().unwrap_or(IngestResult::Rejected);
        drop(timer);
        result
    }

    /// Force-apply buffered ratings; returns the number applied.
    pub fn flush(&self) -> usize {
        self.metrics.counter("server.flush").inc();
        let (reply_tx, reply_rx) = channel();
        if self.tx.send(WriteCmd::Flush { reply: reply_tx }).is_err() {
            return 0;
        }
        reply_rx.recv().unwrap_or(0)
    }

    /// Metrics snapshot (server `STATS` verb). Same leading lines as the
    /// single-threaded engine (`dims`, `buffered`) plus the snapshot
    /// version and the full registry dump.
    pub fn stats(&self) -> String {
        self.metrics.counter("server.stats").inc();
        let snap = self.snapshot();
        let (m, n) = snap.dims();
        format!(
            "dims {m}x{n}\nbuffered {}\nversion {}\n{}",
            self.buffered.load(Ordering::Relaxed),
            snap.version,
            self.metrics.snapshot()
        )
    }
}

/// The single writer: owns the engine, applies every write command in
/// arrival order, republishes the snapshot after each flush.
fn writer_loop(
    mut engine: Engine,
    rx: Receiver<WriteCmd>,
    state: Arc<RwLock<Arc<Snapshot>>>,
    buffered: Arc<AtomicUsize>,
    metrics: Registry,
) -> Engine {
    let mut version = 1u64;
    for cmd in rx {
        match cmd {
            WriteCmd::Rate { i, j, r, reply } => {
                let result = engine.rate(i, j, r);
                if matches!(result, IngestResult::Flushed { .. }) {
                    publish(&state, &engine, version, &metrics);
                    version += 1;
                }
                buffered.store(engine.buffered(), Ordering::Relaxed);
                let _ = reply.send(result);
            }
            WriteCmd::Flush { reply } => {
                let applied = engine.flush();
                // No-op flushes (idle FLUSH probes) publish nothing: a
                // publish deep-clones the model and matrix, which is
                // wasteful when state hasn't changed.
                if applied > 0 {
                    publish(&state, &engine, version, &metrics);
                    version += 1;
                }
                buffered.store(engine.buffered(), Ordering::Relaxed);
                let _ = reply.send(applied);
            }
            WriteCmd::Shutdown => break,
        }
    }
    // Drain on shutdown so no accepted rating is silently dropped.
    engine.flush();
    buffered.store(engine.buffered(), Ordering::Relaxed);
    engine
}

/// Swap in a fresh snapshot. The (brief) write lock only covers the
/// pointer swap — model/matrix cloning happens before taking it.
fn publish(state: &RwLock<Arc<Snapshot>>, engine: &Engine, version: u64, metrics: &Registry) {
    let snap = Arc::new(Snapshot {
        model: engine.model().clone(),
        matrix: engine.matrix().clone(),
        version,
    });
    let timer = metrics.timer("shared.publish_wait");
    let mut guard = state.write().unwrap_or_else(|e| e.into_inner());
    *guard = snap;
    drop(guard);
    drop(timer);
    metrics.counter("shared.publishes").inc();
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::stream::{StreamConfig, StreamOrchestrator};
    use crate::lsh::{OnlineHashState, SimLsh};
    use crate::mf::neighbourhood::{train_culsh_logged, CulshConfig};
    use crate::rng::Rng;
    use crate::sparse::{Csc, Csr, Triples};

    fn engine(rng: &mut Rng, stream_cfg: StreamConfig) -> Engine {
        let (m, n) = (25, 12);
        let mut t = Triples::new(m, n);
        let mut seen = std::collections::HashSet::new();
        while t.nnz() < 140 {
            let (i, j) = (rng.below(m), rng.below(n));
            if seen.insert((i, j)) {
                t.push(i, j, 1.0 + rng.f32() * 4.0);
            }
        }
        let csr = Csr::from_triples(&t);
        let csc = Csc::from_triples(&t);
        let lsh = SimLsh::new(1, 4, 8, 2);
        let hash_state = OnlineHashState::build(lsh, &csc);
        let (topk, _) = hash_state.topk(3, rng);
        let cfg = CulshConfig { f: 4, k: 3, epochs: 3, ..Default::default() };
        let (model, _) = train_culsh_logged(&csr, topk, &cfg, rng);
        let registry = Registry::new();
        let orch = StreamOrchestrator::new(
            model,
            hash_state,
            t,
            stream_cfg,
            cfg,
            rng.split(1),
            registry.clone(),
        );
        Engine::new(orch, (1.0, 5.0), registry)
    }

    #[test]
    fn reads_match_single_threaded_engine() {
        let mut rng = Rng::seeded(91);
        let e = engine(&mut rng, StreamConfig::default());
        // ground truth from the engine before it moves into the writer
        let want_p = e.predict(2, 3);
        let want_top = e.top_n(2, 4);
        let (shared, writer) = SharedEngine::spawn(e);
        assert_eq!(shared.predict(2, 3), want_p);
        assert_eq!(shared.top_n(2, 4), want_top);
        assert!(shared.predict(999, 0).is_none());
        assert!(shared.top_n(999, 4).is_empty());
        assert_eq!(shared.version(), 0);
        writer.join();
    }

    #[test]
    fn rate_flush_publishes_new_snapshot() {
        let mut rng = Rng::seeded(92);
        let e = engine(&mut rng, StreamConfig { batch_size: 4, ..Default::default() });
        let (shared, writer) = SharedEngine::spawn(e);
        let (m0, n0) = shared.dims();
        // out-of-universe prediction is None until the rating flushes
        assert!(shared.predict(0, n0 + 2).is_none());
        for k in 0..3 {
            assert_eq!(shared.rate(0, (n0 + k) as u32, 5.0), IngestResult::Buffered);
        }
        // 4th rating hits batch_size -> flush -> publish
        let res = shared.rate(0, (n0 + 2) as u32, 4.0);
        assert!(matches!(res, IngestResult::Flushed { applied: 4 }), "{res:?}");
        assert_eq!(shared.version(), 1);
        assert_eq!(shared.dims(), (m0, n0 + 3));
        let p = shared.predict(0, n0 + 2).unwrap();
        assert!((1.0..=5.0).contains(&p));
        let engine = writer.join();
        assert_eq!(engine.dims(), (m0, n0 + 3));
    }

    #[test]
    fn explicit_flush_and_stats() {
        let mut rng = Rng::seeded(93);
        let e = engine(&mut rng, StreamConfig::default());
        let (shared, writer) = SharedEngine::spawn(e);
        assert_eq!(shared.rate(1, 2, 4.0), IngestResult::Buffered);
        let stats = shared.stats();
        assert!(stats.contains("buffered 1"), "{stats}");
        assert_eq!(shared.flush(), 1);
        let stats = shared.stats();
        assert!(stats.contains("buffered 0"), "{stats}");
        assert!(stats.contains("version 1"), "{stats}");
        assert!(stats.contains("server.rate"), "{stats}");
        writer.join();
    }

    #[test]
    fn backpressure_round_trips_through_writer() {
        let mut rng = Rng::seeded(94);
        let e = engine(
            &mut rng,
            StreamConfig {
                queue_capacity: 2,
                batch_size: 100,
                reject_when_full: true,
                ..Default::default()
            },
        );
        let (shared, writer) = SharedEngine::spawn(e);
        assert_eq!(shared.rate(0, 1, 3.0), IngestResult::Buffered);
        assert_eq!(shared.rate(0, 2, 3.0), IngestResult::Buffered);
        assert_eq!(shared.rate(0, 3, 3.0), IngestResult::Rejected);
        shared.flush();
        assert_eq!(shared.rate(0, 3, 3.0), IngestResult::Buffered);
        writer.join();
    }
}
