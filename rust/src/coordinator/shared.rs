//! Concurrent serving core: epoch-swapped, column-band-sharded read
//! snapshots over a single writer thread.
//!
//! The original server serialized *every* request — reads included —
//! behind one `Mutex<Engine>`, so a flush (incremental retraining, tens
//! of milliseconds and up) stalled all traffic. PR 1 split reads onto
//! epoch-swapped snapshots, but still republished the *entire* (model,
//! matrix) pair on every flush — a deep clone growing linearly with
//! state size. Following the cuMF line of work (Tan et al.), this core
//! now shards the published state by **column band** (the same
//! contiguous-band split the block-rotation schedule uses, via
//! [`crate::sparse::band_of`]):
//!
//! * **Reads** (`PREDICT` / `MPREDICT` / `TOPN` / `STATS`) clone one
//!   `Arc<Snapshot>` out of an `RwLock` held for nanoseconds, then
//!   compute entirely lock-free on the immutable sharded view. A
//!   snapshot holds `Arc`s to the row factors, the training matrix, and
//!   one [`ColBand`] per shard — always a complete, internally
//!   consistent state, so torn reads stay impossible by construction.
//! * **Writes** (`RATE` / `FLUSH`) are funnelled through an `mpsc`
//!   channel into one writer thread that owns the [`Engine`], exactly
//!   preserving the paper's single-writer online model. Each flush
//!   reports the column ids it applied *and* the columns whose Top-K
//!   row its LSH re-search moved; `publish` keys the per-shard dirty
//!   set off those reports — O(report) per publish, no re-scan of the
//!   previous snapshot's N·K neighbour ids — and clones **only the
//!   dirty bands**, reference-sharing the clean ones across versions.
//!   The matrix `Arc` is shared with the orchestrator outright —
//!   publishing it copies nothing.
//!
//! The per-shard dirty sets follow the same band assignment the
//! rotation schedule uses; [`super::banded`] completes that seam with
//! one write queue + writer thread per band (this module stays the
//! single-writer flavour, and both share [`Snapshot`] and the publish
//! plumbing below).
//!
//! Metrics (all in the engine's [`Registry`]): per-verb counters
//! (`server.predict`, `server.mpredict`, `server.topn`, `server.rate`,
//! `server.flush`, `server.stats`), wait histograms (`shared.read_wait`,
//! `shared.write_wait`, `shared.publish_wait`), the publish-cost gauges
//! `shared.publish_bytes_cloned` / counter
//! `shared.publish_bytes_cloned_total`, the per-shard counters
//! `shared.shard<b>.publishes`, and `shared.shards_cloned`. The
//! publish-path handles are resolved once at spawn (`PublishMetrics`)
//! so a flush never allocates metric-name strings under write load.
//! Relaxed-mode flushes (`serve --flush-mode relaxed`, see
//! [`super::stream::FlushMode`]) additionally count
//! `flush.relaxed_epochs` and per-band `flush.band<b>.train_micros` —
//! their reports merge into the same dirty-shard keying below, so the
//! publish path is mode-agnostic.
//!
//! # Invariants
//!
//! (Machine-checked: `cargo run -p lshmf-check` audits metric names and
//! this section's presence in tier-1 CI.)
//!
//! * **A snapshot is immutable and complete.** Readers compute on one
//!   `Arc<Snapshot>`; the only post-publish mutation is the relaxed
//!   `buffered` counter, which is written solely while its snapshot is
//!   the currently-published one — a reader's (version, buffered) pair
//!   is always coherent, and torn reads are impossible by construction.
//! * **Versions are monotonic**: one writer thread owns the version
//!   counter; every publish is a single pointer swap under the write
//!   lock, held only for the swap.
//! * **Dirty-band keying is O(report)**: the per-shard dirty set comes
//!   from the flush's own applied-column and moved-Top-K reports
//!   (`dirty_bands` documents the exact rule), never from re-scanning
//!   the previous snapshot. This holds for both flush modes — exact and
//!   relaxed flushes emit the same report shape.
//! * **Superseded snapshots are never written again** — the shutdown
//!   drain republishes the drained state *before* the buffered counter
//!   zeroes (the PR 3 coherence fix, regression-tested below).
//! * **Cache invalidation follows the swap.** Every publish calls
//!   [`invalidate_from_report`] *after* the snapshot pointer swap, with
//!   the same dirty-band report the publish itself keyed off (plus the
//!   flush's rated rows); `SUBSCRIBE` push frames fan out from there,
//!   so a subscriber that re-reads on a push always sees the new state.

use super::cache::{PushSink, TopNCache};
use super::engine::{band_candidates, predict_many_by, rank_unrated_by, Engine};
use super::protocol::MAX_TOPN_ITEMS;
use super::stream::IngestResult;
use crate::metrics::{Counter, Gauge, Histogram, Registry};
use crate::mf::neighbourhood::{ColBand, NeighbourScratch, RowFactors, ShardedFactors};
use crate::sparse::{band_of, band_range, Csr};
use std::collections::HashSet;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, RwLock};
use std::thread::JoinHandle;
use std::time::Instant;

/// Default column-band shard count for [`SharedEngine::spawn`].
pub const DEFAULT_SHARDS: usize = 8;

/// An immutable sharded view of the factor state, published by the
/// writer after every flush. Clean shards are reference-shared with the
/// previous version; `buffered` rides inside so `STATS` reads a
/// coherent (version, buffered) pair from one pointer load.
pub struct Snapshot {
    rows: Arc<RowFactors>,
    shards: Arc<[Arc<ColBand>]>,
    matrix: Arc<Csr>,
    /// Monotonic publication counter (0 at spawn, +1 per flush).
    pub version: u64,
    /// Events buffered but not yet applied. The writer stores into the
    /// *current* snapshot's counter on every buffered rating (one
    /// relaxed store — no lock, no republish) and never into a
    /// superseded snapshot's, so a reader holding version `v` always
    /// sees a buffered count that belongs to `v`: a pre-flush version
    /// can never pair with a post-flush count.
    buffered: AtomicUsize,
}

impl Snapshot {
    /// Direct constructor for an already-built sharded state — the
    /// multi-writer publish assembles its per-band shard contributions
    /// through this.
    pub(crate) fn assemble(
        rows: Arc<RowFactors>,
        shards: Arc<[Arc<ColBand>]>,
        matrix: Arc<Csr>,
        version: u64,
        buffered: usize,
    ) -> Snapshot {
        Snapshot { rows, shards, matrix, version, buffered: AtomicUsize::new(buffered) }
    }

    pub fn dims(&self) -> (usize, usize) {
        (self.matrix.nrows(), self.matrix.ncols())
    }

    /// Events buffered but not yet applied, as of this version.
    pub fn buffered(&self) -> usize {
        self.buffered.load(Ordering::Relaxed)
    }

    /// Row-side factors shared by every band.
    pub fn rows(&self) -> &RowFactors {
        &self.rows
    }

    /// The column-band shards.
    pub fn shards(&self) -> &[Arc<ColBand>] {
        &self.shards
    }

    /// The combined training matrix this state was flushed against.
    pub fn matrix(&self) -> &Csr {
        &self.matrix
    }

    /// Shared handle to the row factors (reference-sharing publishes).
    pub(crate) fn rows_arc(&self) -> Arc<RowFactors> {
        Arc::clone(&self.rows)
    }

    /// Store a fresh buffered count into **this** snapshot's counter.
    /// Callers must only ever do this on the currently-published
    /// snapshot — superseded snapshots are never written again, which is
    /// what keeps a reader's (version, buffered) pair coherent.
    pub(crate) fn note_buffered(&self, n: usize) {
        self.buffered.store(n, Ordering::Relaxed);
    }

    /// Assemble the consistent sharded read view.
    fn view(&self) -> ShardedFactors<'_> {
        ShardedFactors { rows: &self.rows, bands: &self.shards, matrix: &self.matrix }
    }

    /// Clamped Eq. (1) prediction for `(i, j)` on this snapshot; `None`
    /// out of range. Both serving front ends (single- and multi-writer)
    /// read through these helpers, so their replies cannot drift.
    pub(crate) fn predict_clamped(&self, i: usize, j: usize, clamp: (f32, f32)) -> Option<f32> {
        let (m, n) = self.dims();
        if i >= m || j >= n {
            return None;
        }
        let mut scratch = NeighbourScratch::default();
        Some(self.view().predict(i, j, &mut scratch).clamp(clamp.0, clamp.1))
    }

    /// Batched clamped prediction (the `MPREDICT` body) on this
    /// snapshot; `None` for an out-of-range row.
    pub(crate) fn predict_many_clamped(
        &self,
        i: usize,
        cols: &[u32],
        clamp: (f32, f32),
    ) -> Option<Vec<Option<f32>>> {
        let (m, n) = self.dims();
        if i >= m {
            return None;
        }
        let view = self.view();
        let mut scratch = NeighbourScratch::default();
        Some(predict_many_by(n, cols, |j| {
            view.predict(i, j, &mut scratch).clamp(clamp.0, clamp.1)
        }))
    }

    /// Top-N highest-predicted unrated columns for a row on this
    /// snapshot (empty for an out-of-range row).
    pub(crate) fn top_n_clamped(
        &self,
        i: usize,
        n_items: usize,
        clamp: (f32, f32),
    ) -> Vec<(u32, f32)> {
        let (m, _) = self.dims();
        if i >= m {
            return Vec::new();
        }
        let view = self.view();
        let mut scratch = NeighbourScratch::default();
        rank_unrated_by(&self.matrix, i, n_items, |j| {
            view.predict(i, j, &mut scratch).clamp(clamp.0, clamp.1)
        })
    }

    /// One shard's scored Top-N candidates for row `i` — the unit the
    /// per-row cache memoizes ([`band_candidates`] over this snapshot's
    /// clamped predictions). `b` indexes this snapshot's shards.
    pub(crate) fn score_band(&self, i: usize, b: usize, clamp: (f32, f32)) -> Vec<(u32, f32)> {
        let n = self.matrix.ncols();
        let d = self.shards.len();
        let (lo, hi) = band_range(b, n, d);
        let view = self.view();
        let mut scratch = NeighbourScratch::default();
        band_candidates(&self.matrix, i, lo, hi, |j| {
            view.predict(i, j, &mut scratch).clamp(clamp.0, clamp.1)
        })
    }
}

/// Publish-path metric handles, resolved once at spawn: the hot flush
/// path must not allocate (`format!` shard names) or take the registry
/// lock per publish.
pub(crate) struct PublishMetrics {
    publishes: Arc<Counter>,
    shards_cloned: Arc<Counter>,
    bytes_gauge: Arc<Gauge>,
    bytes_total: Arc<Counter>,
    publish_wait: Arc<Histogram>,
    shard_publishes: Vec<Arc<Counter>>,
}

impl PublishMetrics {
    pub(crate) fn new(metrics: &Registry, d: usize) -> Self {
        PublishMetrics {
            publishes: metrics.counter("shared.publishes"),
            shards_cloned: metrics.counter("shared.shards_cloned"),
            bytes_gauge: metrics.gauge("shared.publish_bytes_cloned"),
            bytes_total: metrics.counter("shared.publish_bytes_cloned_total"),
            publish_wait: metrics.histogram("shared.publish_wait"),
            shard_publishes: (0..d)
                .map(|b| metrics.counter(&format!("shared.shard{b}.publishes")))
                .collect(),
        }
    }

    /// Record one publish's cost: per-shard counters for each freshly
    /// cloned band, plus the aggregate clone accounting.
    pub(crate) fn record(&self, cloned_bands: &[bool], bytes_cloned: usize) {
        let mut shards_cloned = 0u64;
        for (b, &cloned) in cloned_bands.iter().enumerate() {
            if cloned {
                self.shard_publishes[b].inc();
                shards_cloned += 1;
            }
        }
        self.publishes.inc();
        self.shards_cloned.add(shards_cloned);
        self.bytes_gauge.set(bytes_cloned as f64);
        self.bytes_total.add(bytes_cloned as u64);
    }

    /// The swap-wait histogram (publishers time the write-lock hold).
    pub(crate) fn publish_wait(&self) -> &Histogram {
        &self.publish_wait
    }
}

/// A write-path request for the single writer thread.
enum WriteCmd {
    Rate { i: u32, j: u32, r: f32, reply: Sender<IngestResult> },
    RateMany { batch: Vec<(u32, u32, f32)>, reply: Sender<IngestResult> },
    Flush { reply: Sender<usize> },
    Shutdown,
}

/// Cloneable handle to the concurrent serving core. Each connection
/// thread clones one; reads are lock-free after an `Arc` clone, writes
/// round-trip through the writer thread.
#[derive(Clone)]
pub struct SharedEngine {
    state: Arc<RwLock<Arc<Snapshot>>>,
    tx: Sender<WriteCmd>,
    clamp: (f32, f32),
    metrics: Registry,
    /// Per-row Top-N cache over published snapshots, shared by every
    /// connection handle; the writer invalidates it right after each
    /// snapshot swap (see [`super::cache`]'s ordering invariant).
    cache: Arc<TopNCache>,
}

/// Owns the writer thread; [`WriterHandle::join`] stops it (flushing any
/// buffered events) and returns the engine for inspection.
pub struct WriterHandle {
    handle: JoinHandle<Engine>,
    tx: Sender<WriteCmd>,
}

impl WriterHandle {
    /// Request shutdown and wait for the writer to drain.
    pub fn join(self) -> Engine {
        let _ = self.tx.send(WriteCmd::Shutdown);
        self.handle.join().expect("writer thread panicked")
    }
}

impl SharedEngine {
    /// [`SharedEngine::spawn_sharded`] with [`DEFAULT_SHARDS`] bands.
    pub fn spawn(engine: Engine) -> (SharedEngine, WriterHandle) {
        Self::spawn_sharded(engine, DEFAULT_SHARDS)
    }

    /// Split an [`Engine`] into a concurrent read handle plus its single
    /// writer thread, sharding the published state into `shards` column
    /// bands. Uses the engine's own metric registry, so engine- and
    /// server-level counters land in one `STATS` report.
    pub fn spawn_sharded(engine: Engine, shards: usize) -> (SharedEngine, WriterHandle) {
        let d = shards.max(1);
        let clamp = engine.clamp();
        let metrics = engine.metrics().clone();
        let cache = Arc::new(TopNCache::new(d, &metrics));
        let initial = Arc::new(full_snapshot(&engine, d, engine.version()));
        let state = Arc::new(RwLock::new(initial));
        let (tx, rx) = channel();
        let handle = {
            let state = Arc::clone(&state);
            let metrics = metrics.clone();
            let cache = Arc::clone(&cache);
            std::thread::spawn(move || writer_loop(engine, rx, state, metrics, d, cache))
        };
        let shared = SharedEngine { state, tx: tx.clone(), clamp, metrics, cache };
        (shared, WriterHandle { handle, tx })
    }

    /// The per-row Top-N cache (push-subscription surface for the
    /// server's `SUBSCRIBE` verb and the tests).
    pub fn cache(&self) -> &TopNCache {
        &self.cache
    }

    /// Register a push sink fired at every publish; returns the
    /// currently-published snapshot version (the `SUBSCRIBED` reply).
    pub fn subscribe_push(&self, sink: PushSink) -> u64 {
        self.cache.subscribe(sink);
        self.version()
    }

    /// The engine's metric registry (shared with the writer thread and
    /// the TCP front end).
    pub fn metrics(&self) -> &Registry {
        &self.metrics
    }

    /// Clone the current snapshot out of the lock (held only for the
    /// `Arc` clone; all computation afterwards is lock-free).
    pub fn snapshot(&self) -> Arc<Snapshot> {
        let t0 = Instant::now();
        let guard = self.state.read().unwrap_or_else(|e| e.into_inner());
        let snap = Arc::clone(&guard);
        drop(guard);
        let waited = t0.elapsed();
        self.metrics.histogram("shared.read_wait").record(waited);
        self.metrics.gauge("shared.read_wait_last_ns").set(waited.as_nanos() as f64);
        snap
    }

    /// Dimensions of the last-published snapshot.
    pub fn dims(&self) -> (usize, usize) {
        self.snapshot().dims()
    }

    /// Version of the last-published snapshot (monotonic).
    pub fn version(&self) -> u64 {
        self.snapshot().version
    }

    /// Buffered-event count of the last-published snapshot.
    pub fn buffered(&self) -> usize {
        self.snapshot().buffered()
    }

    /// Predict the interaction value for (row, col) on the current
    /// snapshot. `None` if out of range.
    pub fn predict(&self, i: usize, j: usize) -> Option<f32> {
        self.metrics.counter("server.predict").inc();
        self.snapshot().predict_clamped(i, j, self.clamp)
    }

    /// Batched prediction — the whole batch reads one snapshot, so every
    /// answer comes from the same published version (the `MPREDICT`
    /// consistency contract).
    pub fn predict_many(&self, i: usize, cols: &[u32]) -> Option<Vec<Option<f32>>> {
        self.metrics.counter("server.mpredict").inc();
        let snap = self.snapshot();
        let (m, n) = snap.dims();
        if i < m {
            if let Some(hit) = self.cache.lookup_scores(snap.version, i as u32, n, cols) {
                return Some(hit);
            }
        }
        snap.predict_many_clamped(i, cols, self.clamp)
    }

    /// Top-N highest-predicted unrated columns for a row, on the current
    /// snapshot. Requests up to [`MAX_TOPN_ITEMS`] (the server's `TOPN`
    /// bound) go through the per-row cache; larger programmatic
    /// requests fall back to the full lock-free re-score.
    pub fn top_n(&self, i: usize, n_items: usize) -> Vec<(u32, f32)> {
        self.metrics.counter("server.topn").inc();
        let snap = self.snapshot();
        let (m, _) = snap.dims();
        if i >= m {
            return Vec::new();
        }
        if n_items > MAX_TOPN_ITEMS {
            return snap.top_n_clamped(i, n_items, self.clamp);
        }
        let clamp = self.clamp;
        self.cache.top_n(snap.version, i as u32, n_items, |b| snap.score_band(i, b, clamp))
    }

    /// Ingest a rating through the single-writer online path. Blocks
    /// until the writer replies, so backpressure (`Rejected`),
    /// validation (`InvalidValue` / `OutOfBounds`) and flush outcomes
    /// surface synchronously — the protocol semantics match the
    /// single-threaded engine exactly.
    pub fn rate(&self, i: u32, j: u32, r: f32) -> IngestResult {
        self.metrics.counter("server.rate").inc();
        let timer = self.metrics.timer("shared.write_wait");
        let (reply_tx, reply_rx) = channel();
        if self.tx.send(WriteCmd::Rate { i, j, r, reply: reply_tx }).is_err() {
            // Writer is gone (shutdown): surface as backpressure rather
            // than panicking a connection thread.
            return IngestResult::Rejected;
        }
        let result = reply_rx.recv().unwrap_or(IngestResult::Rejected);
        drop(timer);
        result
    }

    /// Batch-ingest ratings through the single-writer online path (the
    /// `MRATE` verb): one writer round-trip for the whole batch, which
    /// is validated and admitted as a unit with backpressure capacity
    /// reserved once ([`Engine::rate_many`]). An empty batch answers
    /// [`IngestResult::Ignored`] — the same no-payload contract as the
    /// multi-writer path.
    pub fn rate_many(&self, batch: &[(u32, u32, f32)]) -> IngestResult {
        self.metrics.counter("server.mrate").inc();
        let timer = self.metrics.timer("shared.write_wait");
        let (reply_tx, reply_rx) = channel();
        if self
            .tx
            .send(WriteCmd::RateMany { batch: batch.to_vec(), reply: reply_tx })
            .is_err()
        {
            return IngestResult::Rejected;
        }
        let result = reply_rx.recv().unwrap_or(IngestResult::Rejected);
        drop(timer);
        result
    }

    /// Force-apply buffered ratings; returns the number applied.
    pub fn flush(&self) -> usize {
        self.metrics.counter("server.flush").inc();
        let (reply_tx, reply_rx) = channel();
        if self.tx.send(WriteCmd::Flush { reply: reply_tx }).is_err() {
            return 0;
        }
        reply_rx.recv().unwrap_or(0)
    }

    /// Metrics snapshot (server `STATS` verb). Same leading lines as the
    /// single-threaded engine (`dims`, `buffered`) plus the snapshot
    /// version and shard count, then the full registry dump. All header
    /// lines come from **one** snapshot clone, so `STATS` can never pair
    /// a pre-flush version with a post-flush buffered count.
    pub fn stats(&self) -> String {
        self.metrics.counter("server.stats").inc();
        let snap = self.snapshot();
        let (m, n) = snap.dims();
        format!(
            "dims {m}x{n}\nbuffered {}\nversion {}\nshards {}\n{}",
            snap.buffered(),
            snap.version,
            snap.shards.len(),
            self.metrics.snapshot()
        )
    }
}

/// Build a complete snapshot (every shard fresh) — the spawn-time state
/// of both serving flavours.
pub(crate) fn full_snapshot(engine: &Engine, d: usize, version: u64) -> Snapshot {
    let model = engine.model();
    let matrix = engine.matrix_arc();
    let ncols = matrix.ncols();
    let shards: Vec<Arc<ColBand>> = (0..d)
        .map(|b| {
            let (lo, hi) = band_range(b, ncols, d);
            Arc::new(model.col_band(lo, hi))
        })
        .collect();
    Snapshot {
        rows: Arc::new(model.row_factors()),
        shards: shards.into(),
        matrix,
        version,
        buffered: AtomicUsize::new(engine.buffered()),
    }
}

/// The single writer: owns the engine, applies every write command in
/// arrival order, republishes the (partially shared) snapshot after
/// each flush — the dirty-band set comes straight from the flush's own
/// applied-column report ([`Engine::last_flush_cols`]). Between
/// publishes it keeps the *current* snapshot's buffered counter fresh
/// with one relaxed store per buffered rating — superseded snapshots
/// are never written again, which is what keeps a reader's (version,
/// buffered) pair coherent.
fn writer_loop(
    mut engine: Engine,
    rx: Receiver<WriteCmd>,
    state: Arc<RwLock<Arc<Snapshot>>>,
    metrics: Registry,
    shards: usize,
    cache: Arc<TopNCache>,
) -> Engine {
    let pm = PublishMetrics::new(&metrics, shards);
    // Resume numbering past a recovered engine's flush count so cached
    // rankings and `SUBSCRIBE` pushes stay monotonic across a restart.
    let mut version = engine.version() + 1;
    let mut current = Arc::clone(&state.read().unwrap_or_else(|e| e.into_inner()));
    for cmd in rx {
        match cmd {
            WriteCmd::Rate { i, j, r, reply } => {
                let prev_dims = current.dims();
                let result = engine.rate(i, j, r);
                match result {
                    IngestResult::Buffered => {
                        current.note_buffered(engine.buffered());
                    }
                    IngestResult::Flushed { .. } => {
                        current = publish(&state, &engine, version, &pm);
                        invalidate_from_report(&cache, &engine, version, prev_dims, shards);
                        version += 1;
                    }
                    // Rejected / InvalidValue / OutOfBounds never enter
                    // the buffer: nothing to track or republish.
                    _ => {}
                }
                let _ = reply.send(result);
            }
            WriteCmd::RateMany { batch, reply } => {
                let prev_dims = current.dims();
                let result = engine.rate_many(&batch);
                match result {
                    IngestResult::Buffered => {
                        current.note_buffered(engine.buffered());
                    }
                    IngestResult::Flushed { .. } => {
                        current = publish(&state, &engine, version, &pm);
                        invalidate_from_report(&cache, &engine, version, prev_dims, shards);
                        version += 1;
                    }
                    // Rejected / InvalidValue / OutOfBounds / Ignored
                    // leave the buffer untouched: nothing to publish.
                    _ => {}
                }
                let _ = reply.send(result);
            }
            WriteCmd::Flush { reply } => {
                let prev_dims = current.dims();
                let applied = engine.flush();
                // No-op flushes (idle FLUSH probes) publish nothing: a
                // publish clones the dirty shards, which is wasteful
                // when state hasn't changed.
                if applied > 0 {
                    current = publish(&state, &engine, version, &pm);
                    invalidate_from_report(&cache, &engine, version, prev_dims, shards);
                    version += 1;
                }
                let _ = reply.send(applied);
            }
            WriteCmd::Shutdown => break,
        }
    }
    // Drain on shutdown so no accepted rating is silently dropped — and
    // PUBLISH the drained state before the buffered counter drops:
    // zeroing the counter on the superseded snapshot (the old behaviour)
    // handed a reader holding it a (pre-drain factors, buffered 0) pair,
    // violating the (version, buffered) coherence contract.
    let prev_dims = current.dims();
    if engine.flush() > 0 {
        current = publish(&state, &engine, version, &pm);
        invalidate_from_report(&cache, &engine, version, prev_dims, shards);
    }
    current.note_buffered(engine.buffered());
    engine
}

/// Invalidate (and push-notify) a serving cache off one flush's report:
/// dirty bands + rated rows, or everything when the universe grew.
/// Must run *after* the snapshot swap (see [`super::cache`]'s ordering
/// invariant — a subscriber re-reading on the push must see the new
/// state). Both sharded flavours' publish paths funnel through this so
/// their invalidation semantics cannot drift.
pub(crate) fn invalidate_from_report(
    cache: &TopNCache,
    engine: &Engine,
    version: u64,
    prev_dims: (usize, usize),
    d: usize,
) {
    let dims = engine.dims();
    let grew = dims != prev_dims;
    let dirty: Vec<u32> = if grew {
        Vec::new()
    } else {
        let mut bands: Vec<u32> =
            dirty_bands(engine.last_flush_cols(), engine.last_flush_topk_moved(), dims.1, d)
                .into_iter()
                .map(|b| b as u32)
                .collect();
        bands.sort_unstable();
        bands
    };
    cache.invalidate(version, &dirty, engine.last_flush_rows(), grew);
}

/// The per-shard dirty set of one flush, in O(report): a band is dirty
/// when the flush rated one of its columns ([`Engine::last_flush_cols`]),
/// or when the flush's own Top-K re-search reported moving one of its
/// rows ([`Engine::last_flush_topk_moved`]). A flush-rated band is
/// treated as dirty even though today's Algorithm 4 freezes old columns'
/// parameters (re-rated values live in the matrix, which is Arc-shared):
/// the publish contract must not bake in that freeze, or a future online
/// trainer that nudges a re-rated column's {b̂, v, w, c} would silently
/// serve stale bands. (The moved-Top-K report replaced the previous
/// O(N·K) `topk_band_matches` scan over every clean-candidate band —
/// the report is computed where both tables are hot, inside the flush's
/// re-search.)
pub(crate) fn dirty_bands(
    rated: &[u32],
    topk_moved: &[u32],
    ncols: usize,
    d: usize,
) -> HashSet<usize> {
    rated
        .iter()
        .chain(topk_moved)
        .map(|&j| band_of(j as usize, ncols, d))
        .collect()
}

/// Swap in a fresh snapshot, cloning **only the dirty column bands**
/// ([`dirty_bands`]; every band when the column universe grew, since
/// band boundaries move). Clean bands, the row factors (when no row
/// appeared) and the matrix `Arc` are shared with the previous version.
/// The (brief) write lock only covers the pointer swap — all cloning
/// happens before taking it. Returns the published snapshot so the
/// writer can keep its buffered counter fresh.
fn publish(
    state: &RwLock<Arc<Snapshot>>,
    engine: &Engine,
    version: u64,
    pm: &PublishMetrics,
) -> Arc<Snapshot> {
    let prev = Arc::clone(&state.read().unwrap_or_else(|e| e.into_inner()));
    let model = engine.model();
    let matrix = engine.matrix_arc();
    let (nrows, ncols) = (matrix.nrows(), matrix.ncols());
    let (prev_rows, prev_cols) = prev.dims();
    let d = prev.shards.len();
    let mut bytes_cloned = 0usize;

    let rows = if nrows != prev_rows {
        let rf = model.row_factors();
        bytes_cloned += rf.bytes();
        Arc::new(rf)
    } else {
        Arc::clone(&prev.rows)
    };

    let touched_bands =
        dirty_bands(engine.last_flush_cols(), engine.last_flush_topk_moved(), ncols, d);
    let mut cloned_bands = vec![false; d];
    let shards: Vec<Arc<ColBand>> = (0..d)
        .map(|b| {
            let clean = ncols == prev_cols && !touched_bands.contains(&b);
            if clean {
                Arc::clone(&prev.shards[b])
            } else {
                let (lo, hi) = band_range(b, ncols, d);
                let band = model.col_band(lo, hi);
                bytes_cloned += band.bytes();
                cloned_bands[b] = true;
                Arc::new(band)
            }
        })
        .collect();

    let snap = Arc::new(Snapshot {
        rows,
        shards: shards.into(),
        matrix,
        version,
        buffered: AtomicUsize::new(engine.buffered()),
    });
    let swap = Instant::now();
    let mut guard = state.write().unwrap_or_else(|e| e.into_inner());
    *guard = Arc::clone(&snap);
    drop(guard);
    pm.publish_wait().record(swap.elapsed());
    pm.record(&cloned_bands, bytes_cloned);
    snap
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::stream::{StreamConfig, StreamOrchestrator};
    use crate::lsh::{OnlineHashState, SimLsh};
    use crate::mf::neighbourhood::{train_culsh_logged, CulshConfig};
    use crate::rng::Rng;
    use crate::sparse::{Csc, Csr, Triples};

    fn engine(rng: &mut Rng, stream_cfg: StreamConfig) -> Engine {
        let (m, n) = (25, 12);
        let mut t = Triples::new(m, n);
        let mut seen = std::collections::HashSet::new();
        while t.nnz() < 140 {
            let (i, j) = (rng.below(m), rng.below(n));
            if seen.insert((i, j)) {
                t.push(i, j, 1.0 + rng.f32() * 4.0);
            }
        }
        let csr = Csr::from_triples(&t);
        let csc = Csc::from_triples(&t);
        let lsh = SimLsh::new(1, 4, 8, 2);
        let hash_state = OnlineHashState::build(lsh, &csc);
        let (topk, _) = hash_state.topk(3, rng);
        let cfg = CulshConfig { f: 4, k: 3, epochs: 3, ..Default::default() };
        let (model, _) = train_culsh_logged(&csr, topk, &cfg, rng);
        let registry = Registry::new();
        let orch = StreamOrchestrator::new(
            model,
            hash_state,
            t,
            stream_cfg,
            cfg,
            rng.split(1),
            registry.clone(),
        );
        Engine::new(orch, (1.0, 5.0), registry)
    }

    #[test]
    fn reads_match_single_threaded_engine() {
        let mut rng = Rng::seeded(91);
        let e = engine(&mut rng, StreamConfig::default());
        // ground truth from the engine before it moves into the writer
        let want_p = e.predict(2, 3);
        let want_top = e.top_n(2, 4);
        let want_many = e.predict_many(2, &[0, 3, 99]);
        for d in [1usize, 3, 4, 8] {
            let mut rng2 = Rng::seeded(91);
            let e = engine(&mut rng2, StreamConfig::default());
            let (shared, writer) = SharedEngine::spawn_sharded(e, d);
            assert_eq!(shared.predict(2, 3), want_p, "d={d}");
            assert_eq!(shared.top_n(2, 4), want_top, "d={d}");
            assert_eq!(shared.predict_many(2, &[0, 3, 99]), want_many, "d={d}");
            assert!(shared.predict(999, 0).is_none());
            assert!(shared.top_n(999, 4).is_empty());
            assert!(shared.predict_many(999, &[0]).is_none());
            assert_eq!(shared.version(), 0);
            writer.join();
        }
    }

    #[test]
    fn rate_flush_publishes_new_snapshot() {
        let mut rng = Rng::seeded(92);
        let e = engine(&mut rng, StreamConfig { batch_size: 4, ..Default::default() });
        let (shared, writer) = SharedEngine::spawn(e);
        let (m0, n0) = shared.dims();
        // out-of-universe prediction is None until the rating flushes
        assert!(shared.predict(0, n0 + 2).is_none());
        for k in 0..3 {
            assert_eq!(shared.rate(0, (n0 + k) as u32, 5.0), IngestResult::Buffered);
        }
        // 4th rating hits batch_size -> flush -> publish; it re-rates
        // the 3rd cell, so last-write-wins dedup applies 3 entries
        let res = shared.rate(0, (n0 + 2) as u32, 4.0);
        assert!(matches!(res, IngestResult::Flushed { applied: 3 }), "{res:?}");
        assert_eq!(shared.version(), 1);
        assert_eq!(shared.dims(), (m0, n0 + 3));
        let p = shared.predict(0, n0 + 2).unwrap();
        assert!((1.0..=5.0).contains(&p));
        let engine = writer.join();
        assert_eq!(engine.dims(), (m0, n0 + 3));
    }

    #[test]
    fn explicit_flush_and_stats() {
        let mut rng = Rng::seeded(93);
        let e = engine(&mut rng, StreamConfig::default());
        let (shared, writer) = SharedEngine::spawn(e);
        assert_eq!(shared.rate(1, 2, 4.0), IngestResult::Buffered);
        let stats = shared.stats();
        assert!(stats.contains("buffered 1"), "{stats}");
        assert!(stats.contains("version 0"), "{stats}");
        assert_eq!(shared.flush(), 1);
        let stats = shared.stats();
        assert!(stats.contains("buffered 0"), "{stats}");
        assert!(stats.contains("version 1"), "{stats}");
        assert!(stats.contains("server.rate"), "{stats}");
        writer.join();
    }

    /// Regression (shutdown coherence): `WriterHandle::join` drains the
    /// buffer, and the drained state must be REPUBLISHED — the old code
    /// zeroed `buffered` on the superseded snapshot without publishing,
    /// so a reader holding a `SharedEngine` clone saw `buffered 0`
    /// paired with pre-drain factors (stale dims, stale predictions).
    #[test]
    fn shutdown_drain_republishes_before_zeroing_buffered() {
        let mut rng = Rng::seeded(97);
        let e = engine(&mut rng, StreamConfig::default());
        let (shared, writer) = SharedEngine::spawn(e);
        let (m0, n0) = shared.dims();
        assert_eq!(shared.rate(0, n0 as u32, 5.0), IngestResult::Buffered);
        assert_eq!(shared.buffered(), 1);
        assert!(shared.predict(0, n0).is_none(), "not applied before the drain");
        let engine = writer.join();
        assert_eq!(engine.dims(), (m0, n0 + 1), "join drained the rating");
        // read back through the surviving handle: (version, buffered)
        // must be coherent — buffered 0 only alongside the drained state
        assert_eq!(shared.buffered(), 0);
        assert_eq!(shared.version(), 1, "the drain must publish");
        assert_eq!(shared.dims(), (m0, n0 + 1), "snapshot must hold the drained state");
        let p = shared.predict(0, n0).expect("drained rating must be servable");
        assert!((1.0..=5.0).contains(&p));
    }

    /// `MRATE` through the writer: the batch is one round-trip, one
    /// validation unit, one backpressure reservation — and a flush it
    /// triggers publishes exactly like the single-event path.
    #[test]
    fn rate_many_round_trips_and_publishes() {
        let mut rng = Rng::seeded(98);
        let e = engine(&mut rng, StreamConfig { batch_size: 4, ..Default::default() });
        let (shared, writer) = SharedEngine::spawn(e);
        assert_eq!(shared.rate_many(&[]), IngestResult::Ignored);
        assert_eq!(shared.buffered(), 0);
        assert_eq!(
            shared.rate_many(&[(0, 0, 3.0), (0, 1, f32::NAN)]),
            IngestResult::InvalidValue,
            "one bad value refuses the whole batch"
        );
        assert_eq!(shared.buffered(), 0);
        assert_eq!(
            shared.rate_many(&[(0, 0, 3.0), (1, 1, 4.0)]),
            IngestResult::Buffered
        );
        assert_eq!(shared.buffered(), 2);
        assert_eq!(shared.version(), 0);
        // crossing batch_size inside one batch flushes and publishes
        assert_eq!(
            shared.rate_many(&[(2, 2, 2.0), (3, 3, 5.0)]),
            IngestResult::Flushed { applied: 4 }
        );
        assert_eq!(shared.version(), 1);
        assert_eq!(shared.buffered(), 0);
        writer.join();
    }

    /// Batch backpressure through the writer: reserved once, rejected
    /// whole.
    #[test]
    fn rate_many_backpressure_is_batch_atomic() {
        let mut rng = Rng::seeded(99);
        let e = engine(
            &mut rng,
            StreamConfig {
                queue_capacity: 3,
                batch_size: 100,
                reject_when_full: true,
                ..Default::default()
            },
        );
        let (shared, writer) = SharedEngine::spawn(e);
        assert_eq!(shared.rate_many(&[(0, 1, 3.0), (0, 2, 3.0)]), IngestResult::Buffered);
        assert_eq!(
            shared.rate_many(&[(0, 3, 3.0), (0, 4, 3.0)]),
            IngestResult::Rejected,
            "2 buffered + 2 > 3: the whole batch must reject"
        );
        assert_eq!(shared.buffered(), 2, "no partial admission");
        assert_eq!(shared.rate_many(&[(0, 3, 3.0)]), IngestResult::Buffered);
        shared.flush();
        writer.join();
    }

    #[test]
    fn backpressure_round_trips_through_writer() {
        let mut rng = Rng::seeded(94);
        let e = engine(
            &mut rng,
            StreamConfig {
                queue_capacity: 2,
                batch_size: 100,
                reject_when_full: true,
                ..Default::default()
            },
        );
        let (shared, writer) = SharedEngine::spawn(e);
        assert_eq!(shared.rate(0, 1, 3.0), IngestResult::Buffered);
        assert_eq!(shared.rate(0, 2, 3.0), IngestResult::Buffered);
        assert_eq!(shared.rate(0, 3, 3.0), IngestResult::Rejected);
        shared.flush();
        assert_eq!(shared.rate(0, 3, 3.0), IngestResult::Buffered);
        writer.join();
    }

    #[test]
    fn validation_round_trips_through_writer() {
        let mut rng = Rng::seeded(95);
        let e = engine(
            &mut rng,
            StreamConfig { max_rows: 1000, max_cols: 1000, ..Default::default() },
        );
        let (shared, writer) = SharedEngine::spawn(e);
        assert_eq!(shared.rate(0, 1, f32::NAN), IngestResult::InvalidValue);
        assert_eq!(shared.rate(4_000_000_000, 0, 5.0), IngestResult::OutOfBounds);
        assert_eq!(shared.buffered(), 0);
        writer.join();
    }

    /// A flush that touches a single column band clones only that shard
    /// (plus any band whose Top-K rows the re-search moved); the matrix
    /// and row factors republish by reference when rows didn't grow.
    #[test]
    fn publish_shares_clean_shards() {
        let mut rng = Rng::seeded(96);
        let e = engine(&mut rng, StreamConfig::default());
        let metrics = e.metrics().clone();
        let full_bytes = e.model().bytes() + e.matrix().bytes();
        let (shared, writer) = SharedEngine::spawn_sharded(e, 4);
        let before = shared.snapshot();
        // re-rate inside band 0 only (cols 0..3 of 12 at d=4)
        assert_eq!(shared.rate(0, 0, 3.5), IngestResult::Buffered);
        assert_eq!(shared.rate(1, 1, 2.5), IngestResult::Buffered);
        assert_eq!(shared.flush(), 2);
        let after = shared.snapshot();
        assert_eq!(after.version, 1);
        // band 0 must be a fresh clone
        assert!(
            !Arc::ptr_eq(&before.shards[0], &after.shards[0]),
            "dirty band republished by reference"
        );
        // row factors and matrix arcs: rows shared (no growth), matrix
        // swapped to the new flushed state but never deep-cloned by the
        // publish (it is the orchestrator's own Arc).
        assert!(Arc::ptr_eq(&before.rows, &after.rows), "row factors should be shared");
        let cloned = metrics.gauge("shared.publish_bytes_cloned").get();
        assert!(cloned > 0.0);
        assert!(
            cloned < full_bytes as f64,
            "partial publish ({cloned}) must beat the full clone ({full_bytes})"
        );
        // at least the dirty band was counted
        assert!(metrics.counter("shared.shard0.publishes").get() >= 1);
        writer.join();
    }
}
