//! `LshmfClient` — the typed protocol client, on either codec.
//!
//! Synchronous calls round-trip one [`Request`] per call;
//! [`LshmfClient::pipeline`] batches: push any number of requests (they
//! encode into the pipeline's local buffer), then [`Pipeline::finish`]
//! ships them in bounded in-flight windows — draining replies between
//! windows, so even an arbitrarily large pipeline cannot wedge the
//! duplex socket — and returns every reply in push order. Dropping an
//! unfinished pipeline abandons its requests without touching the
//! socket, so the connection stays usable.
//! On the binary codec each reply frame correlates by its request's
//! sequence id, and the server dispatches out of order (reads overtake
//! writes), so the client stashes ahead-of-order frames until their
//! turn; on the text codec ordering *is* the framing (the server
//! answers a text connection's requests in order), and the pipeline
//! tracks which replies are multi-line (`STATS`).
//!
//! [`LshmfClient::subscribe`] (binary only) turns on the client-side
//! Top-N cache: the server pushes a [`Response::Push`] frame (seq
//! `PUSH_SEQ`) at every publish, and the client serves repeat
//! [`LshmfClient::top_n`] calls from memory until a push lands —
//! a warm read costs zero network round-trips. Pushes carry the dirty
//! band set, but the client cannot map bands to the rows whose rated
//! sets changed, so any push conservatively clears the whole client
//! cache; the server-side per-row cache does the fine-grained work.
//!
//! Pipelining is where the binary codec earns its keep: a
//! one-verb-per-round-trip text client pays a full network round-trip
//! plus two syscalls per rating, while a pipelined `MRATE` client ships
//! hundreds of ratings per frame with many frames in flight —
//! `benches/hotpath.rs` quantifies the gap on the same workload.
//!
//! ```no_run
//! use lshmf::coordinator::client::{ClientCodec, LshmfClient};
//! use lshmf::coordinator::protocol::{Request, Response};
//!
//! # let ratings: Vec<(u32, u32, f32)> = vec![(0, 1, 4.5), (2, 3, 3.0)];
//! let mut client = LshmfClient::connect("127.0.0.1:7878", ClientCodec::Binary)?;
//! // sync call
//! let _pred = client.predict(3, 7)?;
//! // pipelined batch ingest: many requests in flight, one flush
//! let mut pipe = client.pipeline();
//! for chunk in ratings.chunks(256) {
//!     pipe.push(&Request::MRate { ratings: chunk.to_vec() })?;
//! }
//! let _replies: Vec<Response> = pipe.finish()?;
//! # Ok::<(), std::io::Error>(())
//! ```

use super::protocol::{read_frame, FrameRead, Request, Response, PUSH_SEQ};
use std::collections::HashMap;
use std::io::{self, BufRead, BufReader, BufWriter, Write};
use std::net::{TcpStream, ToSocketAddrs};

/// Which codec the client speaks. There is no `Auto` on the client
/// side: the client decides, and a server in auto mode follows from the
/// first byte.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ClientCodec {
    Text,
    Binary,
}

/// The client half of the `SUBSCRIBE` contract: remembered `TOPN`
/// replies, valid until the next push frame. Entries are only ever
/// inserted at the version the cache currently sits at (see the
/// in-flight guard in [`LshmfClient::top_n`]), so a push clearing the
/// map is sufficient invalidation.
struct ClientCache {
    /// Highest publish version observed (from the `SUBSCRIBED` ack,
    /// then each push frame).
    version: u64,
    /// `(row, n) → ranked items` — exactly what `TOPN` replied.
    entries: HashMap<(usize, usize), Vec<(u32, f32)>>,
    hits: u64,
}

/// A connected protocol client.
pub struct LshmfClient {
    reader: BufReader<TcpStream>,
    writer: BufWriter<TcpStream>,
    codec: ClientCodec,
    next_seq: u32,
    /// Reply frames that arrived ahead of the seq being waited on —
    /// the server dispatches out of order, the client reorders.
    /// Bounded by the pipeline's in-flight window.
    stash: HashMap<u32, Response>,
    /// `Some` once [`LshmfClient::subscribe`] succeeded.
    push_cache: Option<ClientCache>,
}

fn invalid(msg: impl Into<String>) -> io::Error {
    io::Error::new(io::ErrorKind::InvalidData, msg.into())
}

fn eof(msg: &str) -> io::Error {
    io::Error::new(io::ErrorKind::UnexpectedEof, msg.to_string())
}

impl LshmfClient {
    /// Connect to a server. Works against any `serve --codec` mode that
    /// admits `codec` (`auto` admits both).
    pub fn connect(addr: impl ToSocketAddrs, codec: ClientCodec) -> io::Result<LshmfClient> {
        Self::from_stream(TcpStream::connect(addr)?, codec)
    }

    /// Build a client over an already-connected stream — the router
    /// tier connects on its own terms (read timeouts, backoff) and
    /// hands the socket over here.
    pub fn from_stream(stream: TcpStream, codec: ClientCodec) -> io::Result<LshmfClient> {
        let reader = BufReader::new(stream.try_clone()?);
        let writer = BufWriter::new(stream);
        Ok(LshmfClient {
            reader,
            writer,
            codec,
            next_seq: 0,
            stash: HashMap::new(),
            push_cache: None,
        })
    }

    /// The codec this client speaks.
    pub fn codec(&self) -> ClientCodec {
        self.codec
    }

    /// Start a pipelined batch: push requests, then
    /// [`Pipeline::finish`] to flush and collect every reply in order.
    /// A pipeline buffers locally — nothing touches the socket until
    /// `finish` — so dropping one abandons its requests cleanly and the
    /// connection stays usable.
    pub fn pipeline(&mut self) -> Pipeline<'_> {
        Pipeline { client: self, buf: Vec::new(), pending: Vec::new() }
    }

    /// One synchronous round-trip (a pipeline of one).
    pub fn request(&mut self, req: &Request) -> io::Result<Response> {
        let mut pipe = self.pipeline();
        pipe.push(req)?;
        let mut replies = pipe.finish()?;
        replies.pop().ok_or_else(|| eof("no reply"))
    }

    /// `PREDICT <row> <col>`.
    pub fn predict(&mut self, row: usize, col: usize) -> io::Result<Response> {
        self.request(&Request::Predict { row, col })
    }

    /// `MPREDICT <row> <col>...` — one consistent snapshot answers the
    /// whole batch.
    pub fn predict_many(&mut self, row: usize, cols: &[u32]) -> io::Result<Response> {
        self.request(&Request::MPredict { row, cols: cols.to_vec() })
    }

    /// `TOPN <row> <n>` — served from the client-side cache when
    /// [`subscribe`](LshmfClient::subscribe)d and no publish has been
    /// pushed since the ranking was fetched.
    pub fn top_n(&mut self, row: usize, n: usize) -> io::Result<Response> {
        if let Some(cache) = &mut self.push_cache {
            if let Some(items) = cache.entries.get(&(row, n)) {
                cache.hits += 1;
                return Ok(Response::TopN(items.clone()));
            }
        }
        // Remember which publish the cache sat at when the request
        // left: if a push lands while the reply is in flight, the
        // reply may predate the publish, so it must not be cached.
        let sent_version = self.push_cache.as_ref().map(|c| c.version);
        let resp = self.request(&Request::TopN { row, n })?;
        if let (Some(cache), Response::TopN(items)) = (&mut self.push_cache, &resp) {
            if Some(cache.version) == sent_version {
                cache.entries.insert((row, n), items.clone());
            }
        }
        Ok(resp)
    }

    /// `SUBSCRIBE` (binary codec only): ask the server to push an
    /// invalidation frame at every publish, and turn on the client-side
    /// Top-N cache it invalidates. Returns the publish version the
    /// cache starts from.
    pub fn subscribe(&mut self) -> io::Result<u64> {
        if self.codec != ClientCodec::Binary {
            return Err(invalid("SUBSCRIBE requires the binary codec"));
        }
        match self.request(&Request::Subscribe)? {
            Response::Subscribed { version } => {
                self.push_cache =
                    Some(ClientCache { version, entries: HashMap::new(), hits: 0 });
                Ok(version)
            }
            other => Err(invalid(format!("expected SUBSCRIBED, got {other:?}"))),
        }
    }

    /// `TOPN` calls answered from the client cache since
    /// [`subscribe`](LshmfClient::subscribe) (zero network round-trips
    /// each).
    pub fn cache_hits(&self) -> u64 {
        self.push_cache.as_ref().map_or(0, |c| c.hits)
    }

    /// Highest publish version this client has observed via the
    /// `SUBSCRIBED` ack and push frames (`None` before `subscribe`).
    pub fn observed_version(&self) -> Option<u64> {
        self.push_cache.as_ref().map(|c| c.version)
    }

    /// A push frame landed: the snapshot moved, so every remembered
    /// ranking may be stale. The push carries dirty *bands*, but rated
    /// rows invalidate rankings in clean bands too (the Eq. (1) scan
    /// reads the whole rating row) and the client cannot see which rows
    /// were rated — so the client cache clears wholesale.
    fn handle_push(&mut self, version: u64) {
        if let Some(cache) = &mut self.push_cache {
            cache.version = cache.version.max(version);
            cache.entries.clear();
        }
    }

    /// `RATE <row> <col> <value>`.
    pub fn rate(&mut self, row: u32, col: u32, value: f32) -> io::Result<Response> {
        self.request(&Request::Rate { row, col, value })
    }

    /// `MRATE` — batch ingest, admitted by the server as one unit.
    pub fn rate_many(&mut self, ratings: &[(u32, u32, f32)]) -> io::Result<Response> {
        self.request(&Request::MRate { ratings: ratings.to_vec() })
    }

    /// `FLUSH`.
    pub fn flush(&mut self) -> io::Result<Response> {
        self.request(&Request::Flush)
    }

    /// `STATS` (multi-line on the text codec; handled transparently).
    pub fn stats(&mut self) -> io::Result<Response> {
        self.request(&Request::Stats)
    }

    /// Close the connection. Binary connections are acked with
    /// [`Response::Bye`] before the server closes; text connections
    /// close silently on `QUIT` (the legacy wire behaviour).
    pub fn shutdown(mut self) -> io::Result<()> {
        let seq = self.send(&Request::Shutdown)?;
        self.writer.flush()?;
        match self.codec {
            ClientCodec::Text => Ok(()),
            ClientCodec::Binary => match self.read_binary_response(seq)? {
                Response::Bye => Ok(()),
                other => Err(invalid(format!("expected BYE, got {other:?}"))),
            },
        }
    }

    /// Encode one request into `out`; returns the sequence id it was
    /// stamped with (meaningful on the binary codec). The allocator
    /// skips [`PUSH_SEQ`] — that id is reserved for server-initiated
    /// push frames, so a request must never carry it.
    fn encode_into(&mut self, req: &Request, out: &mut Vec<u8>) -> u32 {
        let seq = self.next_seq;
        self.next_seq = self.next_seq.wrapping_add(1);
        if self.next_seq == PUSH_SEQ {
            self.next_seq = 0;
        }
        match self.codec {
            ClientCodec::Text => {
                out.extend_from_slice(req.encode_text().as_bytes());
                out.push(b'\n');
            }
            ClientCodec::Binary => {
                out.extend_from_slice(&req.encode_frame(seq));
            }
        }
        seq
    }

    /// Encode and write one request straight to the socket buffer (the
    /// synchronous, non-pipelined path).
    fn send(&mut self, req: &Request) -> io::Result<u32> {
        let mut bytes = Vec::new();
        let seq = self.encode_into(req, &mut bytes);
        self.writer.write_all(&bytes)?;
        Ok(seq)
    }

    /// Read one text reply. `stats` replies span multiple lines up to
    /// the `END` terminator; everything else is one line.
    fn read_text_response(&mut self, stats: bool) -> io::Result<Response> {
        if !stats {
            let mut line = String::new();
            if self.reader.read_line(&mut line)? == 0 {
                return Err(eof("connection closed mid-reply"));
            }
            let trimmed = line.trim_end_matches('\n').trim_end_matches('\r');
            return Response::decode_text(trimmed).map_err(invalid);
        }
        let mut text = String::new();
        loop {
            let mut line = String::new();
            if self.reader.read_line(&mut line)? == 0 {
                return Err(eof("connection closed mid-stats"));
            }
            let done = line.trim_end().ends_with("END");
            text.push_str(&line);
            if done {
                break;
            }
        }
        // the wire reply is `{body}END\n`; decode wants `{body}END`
        let text = text.strip_suffix('\n').unwrap_or(&text);
        Response::decode_text(text).map_err(invalid)
    }

    /// Read binary frames until the reply for `want_seq` arrives. The
    /// server dispatches out of order, so frames for other in-flight
    /// requests may arrive first — they stash for their own turn — and
    /// push frames (seq [`PUSH_SEQ`]) may appear between any two
    /// replies — they invalidate the client cache and are consumed
    /// here, never surfaced as a reply.
    fn read_binary_response(&mut self, want_seq: u32) -> io::Result<Response> {
        if let Some(resp) = self.stash.remove(&want_seq) {
            return Ok(resp);
        }
        loop {
            let frame = match read_frame(&mut self.reader)? {
                FrameRead::Eof => return Err(eof("connection closed mid-reply")),
                FrameRead::Malformed(detail) => {
                    return Err(invalid(format!("malformed response frame: {detail}")))
                }
                FrameRead::Frame(frame) => frame,
            };
            let resp = Response::decode_frame(&frame)
                .map_err(|e| invalid(format!("undecodable response: {e}")))?;
            if frame.seq == PUSH_SEQ {
                match resp {
                    Response::Push { version, .. } => self.handle_push(version),
                    other => {
                        return Err(invalid(format!("non-push frame on PUSH_SEQ: {other:?}")))
                    }
                }
                continue;
            }
            if frame.seq == want_seq {
                return Ok(resp);
            }
            self.stash.insert(frame.seq, resp);
        }
    }
}

/// Most requests one `finish` write phase keeps in flight before
/// draining their replies. An unbounded write-everything-then-read
/// strategy can wedge both TCP directions once the kernel buffers fill
/// (client blocked writing requests, server blocked writing replies —
/// the server's dispatch lanes are finite, so replies back up the
/// moment the client stops reading). With a window of 8 the
/// outstanding reply volume stays far below any kernel's socket
/// buffering (worst non-`STATS` reply is ~2.3 KiB), so the server
/// never blocks on its replies and the client's writes always drain —
/// deadlock-free for pipelines of any size. `STATS` replies are
/// unbounded, so a window also ends right after one.
const PIPELINE_WINDOW: usize = 8;

/// An in-flight request batch. Requests are encoded into the
/// pipeline's own buffer on push; [`Pipeline::finish`] writes them in
/// bounded in-flight windows (draining replies between windows) and
/// returns every reply in push order. Dropping a pipeline without
/// `finish` abandons its requests without ever writing them — the
/// connection stays in sync.
pub struct Pipeline<'c> {
    client: &'c mut LshmfClient,
    /// Encoded wire bytes, written at `finish`.
    buf: Vec<u8>,
    /// (sequence id, reply-is-multi-line, end offset in `buf`) per
    /// pushed request.
    pending: Vec<(u32, bool, usize)>,
}

impl Pipeline<'_> {
    /// Buffer one request. `Shutdown` is refused — it closes the
    /// connection mid-pipeline; use [`LshmfClient::shutdown`].
    /// `Subscribe` is refused likewise: it changes connection-level
    /// state the client must mirror; use [`LshmfClient::subscribe`].
    pub fn push(&mut self, req: &Request) -> io::Result<()> {
        if matches!(req, Request::Shutdown) {
            return Err(io::Error::new(
                io::ErrorKind::InvalidInput,
                "Shutdown in a pipeline; use LshmfClient::shutdown",
            ));
        }
        if matches!(req, Request::Subscribe) {
            return Err(io::Error::new(
                io::ErrorKind::InvalidInput,
                "Subscribe in a pipeline; use LshmfClient::subscribe",
            ));
        }
        let is_stats = matches!(req, Request::Stats);
        let seq = self.client.encode_into(req, &mut self.buf);
        self.pending.push((seq, is_stats, self.buf.len()));
        Ok(())
    }

    /// Requests pushed so far.
    pub fn len(&self) -> usize {
        self.pending.len()
    }

    pub fn is_empty(&self) -> bool {
        self.pending.is_empty()
    }

    /// Write the buffered requests and collect every reply, in push
    /// order. Writes proceed in `PIPELINE_WINDOW`-sized in-flight
    /// windows with the replies drained between windows, so a pipeline
    /// of any size is deadlock-free against the one-reply-per-request
    /// server loop.
    pub fn finish(self) -> io::Result<Vec<Response>> {
        let Pipeline { client, buf, pending } = self;
        let mut replies = Vec::with_capacity(pending.len());
        let mut off = 0usize;
        let mut sent = 0usize;
        while sent < pending.len() {
            // write phase: up to a window of requests (ending early
            // after a STATS, whose reply size is unbounded)
            let phase_start = sent;
            while sent < pending.len() && sent - phase_start < PIPELINE_WINDOW {
                let (_, is_stats, end) = pending[sent];
                client.writer.write_all(&buf[off..end])?;
                off = end;
                sent += 1;
                if is_stats {
                    break;
                }
            }
            client.writer.flush()?;
            // drain phase: read every reply the window produced
            for &(seq, is_stats, _) in &pending[phase_start..sent] {
                let response = match client.codec {
                    ClientCodec::Text => client.read_text_response(is_stats)?,
                    ClientCodec::Binary => client.read_binary_response(seq)?,
                };
                replies.push(response);
            }
        }
        Ok(replies)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::protocol::{ErrorKind, OkBody};
    use crate::coordinator::server;
    use crate::coordinator::stream::{StreamConfig, StreamOrchestrator};
    use crate::coordinator::Engine;
    use crate::lsh::{OnlineHashState, SimLsh};
    use crate::metrics::Registry;
    use crate::mf::neighbourhood::{train_culsh_logged, CulshConfig};
    use crate::rng::Rng;
    use crate::sparse::{Csc, Csr, Triples};
    use std::net::{TcpListener, TcpStream};
    use std::sync::atomic::{AtomicBool, Ordering};
    use std::sync::Arc;

    fn engine(seed: u64) -> Engine {
        let mut rng = Rng::seeded(seed);
        let (m, n) = (20, 10);
        let mut t = Triples::new(m, n);
        let mut seen = std::collections::HashSet::new();
        while t.nnz() < 100 {
            let (i, j) = (rng.below(m), rng.below(n));
            if seen.insert((i, j)) {
                t.push(i, j, 1.0 + rng.f32() * 4.0);
            }
        }
        let csr = Csr::from_triples(&t);
        let csc = Csc::from_triples(&t);
        let lsh = SimLsh::new(1, 4, 8, 2);
        let hash_state = OnlineHashState::build(lsh, &csc);
        let (topk, _) = hash_state.topk(3, &mut rng);
        let cfg = CulshConfig { f: 4, k: 3, epochs: 3, ..Default::default() };
        let (model, _) = train_culsh_logged(&csr, topk, &cfg, &mut rng);
        let metrics = Registry::new();
        let orch = StreamOrchestrator::new(
            model,
            hash_state,
            t,
            StreamConfig::default(),
            cfg,
            rng.split(1),
            metrics.clone(),
        );
        Engine::new(orch, (1.0, 5.0), metrics)
    }

    /// Stand a server up on a loopback port; returns (addr, stop, join).
    fn spawn_server(
        seed: u64,
    ) -> (
        std::net::SocketAddr,
        Arc<AtomicBool>,
        std::thread::JoinHandle<Engine>,
    ) {
        let e = engine(seed);
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = stop.clone();
        let handle =
            std::thread::spawn(move || server::serve(e, listener, stop2, 2).unwrap());
        (addr, stop, handle)
    }

    fn stop_server(
        addr: std::net::SocketAddr,
        stop: Arc<AtomicBool>,
        handle: std::thread::JoinHandle<Engine>,
    ) -> Engine {
        stop.store(true, Ordering::Relaxed);
        let _ = TcpStream::connect(addr);
        handle.join().unwrap()
    }

    /// Both codecs drive the same auto-detecting server and agree on
    /// every typed reply.
    #[test]
    fn both_codecs_roundtrip_against_auto_server() {
        let (addr, stop, handle) = spawn_server(101);
        for codec in [ClientCodec::Text, ClientCodec::Binary] {
            let mut client = LshmfClient::connect(addr, codec).unwrap();
            let pred = client.predict(0, 0).unwrap();
            assert!(matches!(pred, Response::Pred(_)), "{codec:?}: {pred:?}");
            assert_eq!(
                client.predict(999, 0).unwrap(),
                Response::Error(ErrorKind::OutOfRange),
                "{codec:?}"
            );
            let preds = client.predict_many(0, &[0, 1, 999]).unwrap();
            match preds {
                Response::Preds(ps) => {
                    assert_eq!(ps.len(), 3);
                    assert!(ps[0].is_some() && ps[1].is_some() && ps[2].is_none());
                }
                other => panic!("{codec:?}: {other:?}"),
            }
            let top = client.top_n(0, 3).unwrap();
            assert!(matches!(top, Response::TopN(ref recs) if recs.len() <= 3), "{top:?}");
            assert_eq!(
                client.rate(0, 5, 4.5).unwrap(),
                Response::Ok(OkBody::Buffered),
                "{codec:?}"
            );
            assert_eq!(
                client.rate_many(&[(1, 2, 3.0), (2, 3, 2.0)]).unwrap(),
                Response::Ok(OkBody::Buffered),
                "{codec:?}"
            );
            assert_eq!(
                client.flush().unwrap(),
                Response::Ok(OkBody::Flushed { applied: 3 }),
                "{codec:?}"
            );
            match client.stats().unwrap() {
                Response::Stats(body) => {
                    assert!(body.contains("dims"), "{codec:?}: {body}");
                    assert!(body.contains("version"), "{codec:?}: {body}");
                }
                other => panic!("{codec:?}: {other:?}"),
            }
            client.shutdown().unwrap();
        }
        stop_server(addr, stop, handle);
    }

    /// A pipeline much larger than the in-flight window completes (the
    /// windowed finish crosses many write/drain phases) with every
    /// reply in push order.
    #[test]
    fn pipeline_larger_than_window_completes_in_order() {
        let (addr, stop, handle) = spawn_server(103);
        for codec in [ClientCodec::Text, ClientCodec::Binary] {
            let mut client = LshmfClient::connect(addr, codec).unwrap();
            let n = PIPELINE_WINDOW * 12 + 3;
            let mut pipe = client.pipeline();
            for k in 0..n {
                // alternate verbs so drained replies must line up with
                // their requests, not just count out
                if k % 2 == 0 {
                    pipe.push(&Request::Predict { row: k % 20, col: k % 10 }).unwrap();
                } else {
                    pipe.push(&Request::TopN { row: k % 20, n: 3 }).unwrap();
                }
            }
            let replies = pipe.finish().unwrap();
            assert_eq!(replies.len(), n);
            for (k, reply) in replies.iter().enumerate() {
                if k % 2 == 0 {
                    assert!(matches!(reply, Response::Pred(_)), "{codec:?} #{k}: {reply:?}");
                } else {
                    assert!(matches!(reply, Response::TopN(_)), "{codec:?} #{k}: {reply:?}");
                }
            }
            client.shutdown().unwrap();
        }
        stop_server(addr, stop, handle);
    }

    /// The full `SUBSCRIBE` loop against a live sharded server: a
    /// repeat `TOPN` is served from client memory (zero round-trips),
    /// the publish push arrives before the `FLUSH` reply that caused
    /// it (the sink fires inside the publish), and the push clears the
    /// client cache so the next `TOPN` refetches.
    #[test]
    fn subscribe_cache_serves_warm_topn_and_invalidates_on_push() {
        let (addr, stop, handle) = spawn_server(104);
        let mut client = LshmfClient::connect(addr, ClientCodec::Binary).unwrap();
        let v0 = client.subscribe().unwrap();
        let cold = client.top_n(0, 3).unwrap();
        assert!(matches!(cold, Response::TopN(_)), "{cold:?}");
        let warm = client.top_n(0, 3).unwrap();
        assert_eq!(cold, warm, "warm read must replay the cached ranking");
        assert_eq!(client.cache_hits(), 1);
        // a buffered rate does not publish: the cache stays warm
        client.rate(0, 5, 4.5).unwrap();
        assert_eq!(client.top_n(0, 3).unwrap(), warm);
        assert_eq!(client.cache_hits(), 2);
        // the flush publishes; its push precedes the flush reply on
        // the wire, so by the time flush() returns the cache is cold
        assert_eq!(
            client.flush().unwrap(),
            Response::Ok(OkBody::Flushed { applied: 1 })
        );
        assert_eq!(client.observed_version(), Some(v0 + 1));
        let after = client.top_n(0, 3).unwrap();
        assert!(matches!(after, Response::TopN(_)), "{after:?}");
        assert_eq!(client.cache_hits(), 2, "push must clear the cache");
        // subscribe is binary-only (client-side refusal on text), and
        // cannot ride inside a pipeline
        let mut pipe = client.pipeline();
        assert!(pipe.push(&Request::Subscribe).is_err());
        drop(pipe);
        client.shutdown().unwrap();
        let mut text = LshmfClient::connect(addr, ClientCodec::Text).unwrap();
        assert!(text.subscribe().is_err());
        text.shutdown().unwrap();
        stop_server(addr, stop, handle);
    }

    /// Pipelining: many requests written before any reply is read, all
    /// replies collected in order (binary additionally seq-checked).
    #[test]
    fn pipeline_collects_replies_in_order() {
        let (addr, stop, handle) = spawn_server(102);
        for codec in [ClientCodec::Text, ClientCodec::Binary] {
            let mut client = LshmfClient::connect(addr, codec).unwrap();
            let mut pipe = client.pipeline();
            for k in 0..10u32 {
                pipe.push(&Request::Rate { row: k % 5, col: k % 7, value: 3.0 }).unwrap();
            }
            pipe.push(&Request::Stats).unwrap();
            pipe.push(&Request::Predict { row: 0, col: 1 }).unwrap();
            assert_eq!(pipe.len(), 12);
            let replies = pipe.finish().unwrap();
            assert_eq!(replies.len(), 12);
            for reply in &replies[..10] {
                assert!(matches!(reply, Response::Ok(_)), "{codec:?}: {reply:?}");
            }
            assert!(matches!(replies[10], Response::Stats(_)), "{codec:?}");
            assert!(matches!(replies[11], Response::Pred(_)), "{codec:?}");
            // a Shutdown cannot ride inside a pipeline
            let mut pipe = client.pipeline();
            assert!(pipe.push(&Request::Shutdown).is_err());
            drop(pipe);
            // abandoning a pipeline mid-build must not desynchronize
            // the connection: pushes buffer locally until finish()
            let mut pipe = client.pipeline();
            for k in 0..3u32 {
                pipe.push(&Request::Rate { row: k, col: k, value: 2.0 }).unwrap();
            }
            drop(pipe); // never finished: nothing reached the socket
            let reply = client.predict(0, 1).unwrap();
            assert!(
                matches!(reply, Response::Pred(_)),
                "{codec:?}: abandoned pipeline desynchronized the stream: {reply:?}"
            );
            client.flush().unwrap();
            client.shutdown().unwrap();
        }
        stop_server(addr, stop, handle);
    }

    /// A scripted raw-socket peer: accepts one connection, waits for
    /// the client's first write, answers with `reply` verbatim, and
    /// closes. Lets the error-path tests put arbitrary (including
    /// corrupt) bytes on the wire.
    fn fake_server(reply: Vec<u8>) -> (std::net::SocketAddr, std::thread::JoinHandle<()>) {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let handle = std::thread::spawn(move || {
            let (mut sock, _) = listener.accept().unwrap();
            let mut buf = [0u8; 4096];
            let _ = std::io::Read::read(&mut sock, &mut buf);
            if !reply.is_empty() {
                std::io::Write::write_all(&mut sock, &reply).unwrap();
            }
            // dropping the socket closes the connection mid-conversation
        });
        (addr, handle)
    }

    /// The server dying mid-`finish` (requests written, no replies)
    /// surfaces as a typed `UnexpectedEof` — never a hang, never a
    /// panic.
    #[test]
    fn pipeline_finish_surfaces_server_close_as_typed_eof() {
        let (addr, handle) = fake_server(Vec::new());
        let mut client = LshmfClient::connect(addr, ClientCodec::Binary).unwrap();
        let mut pipe = client.pipeline();
        pipe.push(&Request::Predict { row: 0, col: 0 }).unwrap();
        pipe.push(&Request::Flush).unwrap();
        let err = pipe.finish().unwrap_err();
        assert_eq!(err.kind(), std::io::ErrorKind::UnexpectedEof, "{err}");
        handle.join().unwrap();
    }

    /// A reply frame whose header claims more payload than arrives
    /// before the close is `InvalidData` (the malformed-frame path),
    /// not a wedge waiting for bytes that never come.
    #[test]
    fn truncated_reply_frame_is_invalid_data_not_a_hang() {
        let mut reply = Response::Pred(1.0).encode_frame(0);
        reply[6] = 8; // header now promises an 8-byte payload...
        reply.truncate(10 + 3); // ...but only 3 bytes precede the close
        let (addr, handle) = fake_server(reply);
        let mut client = LshmfClient::connect(addr, ClientCodec::Binary).unwrap();
        let err = client.predict(0, 0).unwrap_err();
        assert_eq!(err.kind(), std::io::ErrorKind::InvalidData, "{err}");
        handle.join().unwrap();
    }

    /// A reply stamped with a sequence id the client never issued is
    /// stashed for a request that does not exist; the close that
    /// follows becomes a typed EOF for the request actually waiting.
    #[test]
    fn wrong_seq_reply_then_close_errors_instead_of_hanging() {
        let reply = Response::Pred(2.5).encode_frame(5);
        let (addr, handle) = fake_server(reply);
        let mut client = LshmfClient::connect(addr, ClientCodec::Binary).unwrap();
        let err = client.predict(0, 0).unwrap_err();
        assert_eq!(err.kind(), std::io::ErrorKind::UnexpectedEof, "{err}");
        handle.join().unwrap();
    }

    /// `PUSH_SEQ` is reserved for `Push` frames; anything else riding
    /// that id is a protocol violation the client rejects as
    /// `InvalidData` instead of mistaking it for a reply.
    #[test]
    fn non_push_frame_on_push_seq_is_protocol_error() {
        let reply = Response::Pred(2.5).encode_frame(PUSH_SEQ);
        let (addr, handle) = fake_server(reply);
        let mut client = LshmfClient::connect(addr, ClientCodec::Binary).unwrap();
        let err = client.predict(0, 0).unwrap_err();
        assert_eq!(err.kind(), std::io::ErrorKind::InvalidData, "{err}");
        handle.join().unwrap();
    }
}
