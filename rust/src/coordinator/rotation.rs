//! Multi-device block-rotation scheduling (Fig. 5: MCUSGD++ / MCULSH-MF).
//!
//! The sparse matrix is split into a D×D [`BlockGrid`]; device `d` owns
//! column band `d` (and its V/W/C parameters) permanently, and the D row
//! bands (with their U blocks) rotate: in step `s`, device `d` processes
//! block `(row_band = (d + s) mod D, col_band = d)`, then passes its U
//! block to the next device. No two devices ever share a row or column
//! band within a step — the schedule is a Latin square.
//!
//! Two execution paths:
//! * [`RotationPlan::execute_threads`] — real worker threads (exercises
//!   the schedule's correctness on this host);
//! * [`RotationPlan::virtual_clock`] — the cost model that reproduces the
//!   paper's multi-GPU *speedup shape* (1.6×/2.4×/3.2× on 2/3/4 GPUs):
//!   per-step makespan = max over devices of (compute + transfer), where
//!   compute ∝ block nnz and transfer ∝ U-block bytes × link cost. A
//!   single host with one core cannot show real multi-device scaling, so
//!   the simulated clock is the reproduction vehicle (DESIGN.md
//!   §Substitutions).
//!
//! The serving stack reuses this schedule's column-band partition
//! (`sparse::band_of` — the same split [`BlockGrid`] uses): the sharded
//! snapshot publish keys its dirty sets off it, and the multi-writer
//! ingest path ([`super::banded`]) assigns one write queue + writer per
//! column band. The Latin-square property is exactly why that split is
//! conflict-free — no step of the schedule, and no band writer, ever
//! shares a column with another — and the barrier between rotation
//! sub-steps is the same epoch structure the banded path's cross-band
//! growth barrier encodes. The relaxed flush mode
//! ([`super::stream::FlushMode::Relaxed`]) runs this exact schedule
//! *inside* a flush epoch: lane thread `b` trains its share of the
//! new columns while the new-row lanes rotate through `(b + s) mod D`
//! across barrier-separated sub-steps, so the online update's
//! row-parameter coupling is resolved by scheduling instead of locks
//! ([`crate::mf::online::online_update_relaxed_with_topk`]).
//!
//! # Invariants
//!
//! (Machine-checked: `cargo run -p lshmf-check` gates this section's
//! presence in tier-1 CI.)
//!
//! * **The schedule is a Latin square** ([`RotationPlan::validate`],
//!   property-tested): every step touches each row band and each column
//!   band exactly once, and an epoch covers all D² blocks exactly once.
//! * **The column split is the serving split**: `band_of(j, n, d)`
//!   resolves column `j` to the same band in the block grid, the
//!   sharded snapshot, the per-band write queues, and the relaxed
//!   flush's rotation lanes (pinned by
//!   `rotation_col_bands_match_serving_band_split` below).

use crate::sparse::{BlockGrid, Triples};

/// A D-device rotation schedule over a block grid.
#[derive(Clone, Debug)]
pub struct RotationPlan {
    d: usize,
    /// `steps[s][device] = (row_band, col_band)` assignments.
    steps: Vec<Vec<(usize, usize)>>,
    /// nnz per block (load model).
    load: Vec<Vec<usize>>,
    /// rows per row band (U-block transfer sizes).
    band_rows: Vec<usize>,
}

impl RotationPlan {
    /// Build the Fig. 5 schedule for `d` devices over `t`.
    pub fn new(t: &Triples, d: usize) -> Self {
        assert!(d >= 1);
        let grid = BlockGrid::partition(t, d);
        let load = grid.load_matrix();
        let band_rows = (0..d)
            .map(|b| {
                let (lo, hi) = grid.row_band_range(b);
                hi - lo
            })
            .collect();
        let steps = (0..d)
            .map(|s| (0..d).map(|dev| ((dev + s) % d, dev)).collect())
            .collect();
        RotationPlan { d, steps, load, band_rows }
    }

    pub fn d(&self) -> usize {
        self.d
    }

    pub fn steps(&self) -> &[Vec<(usize, usize)>] {
        &self.steps
    }

    /// Schedule validity: every step touches each row band and each column
    /// band exactly once, and all D² blocks are covered exactly once per
    /// epoch. (Property-tested too.)
    pub fn validate(&self) -> Result<(), String> {
        let d = self.d;
        let mut seen = vec![false; d * d];
        for (s, assignments) in self.steps.iter().enumerate() {
            let mut rows = vec![false; d];
            let mut cols = vec![false; d];
            for &(rb, cb) in assignments {
                if rows[rb] {
                    return Err(format!("step {s}: row band {rb} assigned twice"));
                }
                if cols[cb] {
                    return Err(format!("step {s}: col band {cb} assigned twice"));
                }
                rows[rb] = true;
                cols[cb] = true;
                if seen[rb * d + cb] {
                    return Err(format!("block ({rb},{cb}) scheduled twice"));
                }
                seen[rb * d + cb] = true;
            }
        }
        if !seen.iter().all(|&s| s) {
            return Err("not all blocks covered".into());
        }
        Ok(())
    }

    /// Run the cost model for one epoch.
    ///
    /// * `cost_per_nnz` — seconds per rating update on one device;
    /// * `transfer_cost_per_row` — seconds to ship one U row between
    ///   devices (captures F × 4 bytes / link bandwidth); devices overlap
    ///   compute of step s with the transfer from step s−1 only when
    ///   `overlap` is set (the paper's "properly distributing
    ///   communications can shorten the computation time").
    pub fn virtual_clock(
        &self,
        cost_per_nnz: f64,
        transfer_cost_per_row: f64,
        overlap: bool,
    ) -> VirtualClockReport {
        let d = self.d;
        let mut total = 0f64;
        let mut compute_total = 0f64;
        let mut transfer_total = 0f64;
        for assignments in &self.steps {
            let mut step_compute = 0f64;
            let mut step_transfer = 0f64;
            for &(rb, cb) in assignments {
                let c = self.load[rb][cb] as f64 * cost_per_nnz;
                step_compute = step_compute.max(c);
                // after the step, each device ships its current U band
                let tr = if d > 1 {
                    self.band_rows[rb] as f64 * transfer_cost_per_row
                } else {
                    0.0
                };
                step_transfer = step_transfer.max(tr);
            }
            compute_total += step_compute;
            transfer_total += step_transfer;
            total += if overlap {
                step_compute.max(step_transfer)
            } else {
                step_compute + step_transfer
            };
        }
        let serial: f64 = self
            .load
            .iter()
            .flatten()
            .map(|&nnz| nnz as f64 * cost_per_nnz)
            .sum();
        VirtualClockReport {
            devices: d,
            epoch_seconds: total,
            serial_seconds: serial,
            compute_seconds: compute_total,
            transfer_seconds: transfer_total,
            speedup: serial / total.max(f64::MIN_POSITIVE),
        }
    }

    /// Execute one epoch of a user-supplied block handler on real threads,
    /// with the barrier-separated sub-steps the schedule requires. The
    /// handler receives `(device, row_band, col_band)`.
    pub fn execute_threads<Fh: Fn(usize, usize, usize) + Sync>(&self, handler: Fh) {
        let barrier = std::sync::Barrier::new(self.d);
        std::thread::scope(|scope| {
            for dev in 0..self.d {
                let handler = &handler;
                let barrier = &barrier;
                let steps = &self.steps;
                scope.spawn(move || {
                    for step in steps {
                        let (rb, cb) = step[dev];
                        handler(dev, rb, cb);
                        barrier.wait();
                    }
                });
            }
        });
    }

    /// Load imbalance of the schedule: max/mean block nnz per step,
    /// averaged over steps — 1.0 is perfectly balanced.
    pub fn imbalance(&self) -> f64 {
        let d = self.d;
        let mut acc = 0f64;
        for assignments in &self.steps {
            let loads: Vec<f64> = assignments
                .iter()
                .map(|&(rb, cb)| self.load[rb][cb] as f64)
                .collect();
            let max = loads.iter().cloned().fold(0f64, f64::max);
            let mean = loads.iter().sum::<f64>() / d as f64;
            if mean > 0.0 {
                acc += max / mean;
            } else {
                acc += 1.0;
            }
        }
        acc / d as f64
    }
}

/// Output of the virtual-clock cost model.
#[derive(Clone, Copy, Debug)]
pub struct VirtualClockReport {
    pub devices: usize,
    pub epoch_seconds: f64,
    pub serial_seconds: f64,
    pub compute_seconds: f64,
    pub transfer_seconds: f64,
    pub speedup: f64,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    fn random_triples(m: usize, n: usize, nnz: usize, rng: &mut Rng) -> Triples {
        let mut t = Triples::new(m, n);
        let mut seen = std::collections::HashSet::new();
        while t.nnz() < nnz {
            let (i, j) = (rng.below(m), rng.below(n));
            if seen.insert((i, j)) {
                t.push(i, j, rng.f32());
            }
        }
        t
    }

    #[test]
    fn schedule_is_latin_square() {
        let mut rng = Rng::seeded(41);
        for d in 1..=5 {
            let t = random_triples(50, 40, 300, &mut rng);
            let plan = RotationPlan::new(&t, d);
            plan.validate().unwrap();
        }
    }

    #[test]
    fn virtual_clock_speedup_grows_with_devices_then_saturates() {
        let mut rng = Rng::seeded(42);
        let t = random_triples(400, 300, 20_000, &mut rng);
        let mut speedups = Vec::new();
        for d in [1usize, 2, 3, 4] {
            let plan = RotationPlan::new(&t, d);
            let r = plan.virtual_clock(1e-7, 5e-7, true);
            speedups.push(r.speedup);
        }
        assert!((speedups[0] - 1.0).abs() < 1e-9);
        assert!(speedups[1] > 1.3, "2 devices: {}", speedups[1]);
        assert!(speedups[2] > speedups[1], "3 devices: {speedups:?}");
        assert!(speedups[3] > speedups[2], "4 devices: {speedups:?}");
        // sub-linear: communication keeps it under ideal
        assert!(speedups[3] < 4.0, "{speedups:?}");
    }

    #[test]
    fn transfer_cost_hurts_speedup() {
        let mut rng = Rng::seeded(43);
        let t = random_triples(200, 200, 5_000, &mut rng);
        let plan = RotationPlan::new(&t, 3);
        let fast_link = plan.virtual_clock(1e-7, 1e-8, true).speedup;
        let slow_link = plan.virtual_clock(1e-7, 1e-5, true).speedup;
        assert!(fast_link > slow_link);
    }

    #[test]
    fn overlap_helps() {
        let mut rng = Rng::seeded(44);
        let t = random_triples(200, 200, 5_000, &mut rng);
        let plan = RotationPlan::new(&t, 3);
        let with = plan.virtual_clock(1e-7, 2e-7, true).epoch_seconds;
        let without = plan.virtual_clock(1e-7, 2e-7, false).epoch_seconds;
        assert!(with < without);
    }

    #[test]
    fn execute_threads_visits_every_block_once() {
        let mut rng = Rng::seeded(45);
        let t = random_triples(60, 60, 500, &mut rng);
        for d in [2usize, 3, 4] {
            let plan = RotationPlan::new(&t, d);
            let visited = std::sync::Mutex::new(std::collections::HashSet::new());
            plan.execute_threads(|_dev, rb, cb| {
                assert!(visited.lock().unwrap().insert((rb, cb)), "block revisited");
            });
            assert_eq!(visited.lock().unwrap().len(), d * d);
        }
    }

    /// The serving stack's band split (`sparse::band_of`) and the
    /// rotation schedule's column bands are one partition: the band the
    /// per-band write queues route column `j` to is exactly the column
    /// band device `d` owns in the block grid. (This shared split is
    /// the foundation of the multi-writer path's conflict-freedom.)
    #[test]
    fn rotation_col_bands_match_serving_band_split() {
        use crate::sparse::{band_of, BlockGrid};
        let mut rng = Rng::seeded(47);
        for d in [1usize, 2, 3, 5] {
            let t = random_triples(40, 37, 250, &mut rng);
            let grid = BlockGrid::partition(&t, d);
            for j in 0..t.ncols() {
                let b = band_of(j, t.ncols(), d);
                let (lo, hi) = grid.col_band_range(b);
                assert!(lo <= j && j < hi, "d={d} col {j}: band {b} is [{lo},{hi})");
            }
        }
    }

    #[test]
    fn imbalance_is_at_least_one() {
        let mut rng = Rng::seeded(46);
        let t = random_triples(100, 100, 2_000, &mut rng);
        let plan = RotationPlan::new(&t, 4);
        assert!(plan.imbalance() >= 1.0);
    }
}
