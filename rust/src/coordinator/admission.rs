//! Per-client admission control for the serving front end, driven by
//! the `[limits]` section of [`ServeConfig`](crate::config::ServeConfig):
//! token-bucket rate limiting per connection, load shedding that drops
//! expensive reads (`TOPN`/`MPREDICT`) before ingest verbs when a
//! connection's read lane backs up, and slow-reader eviction so a
//! blocked reply writer or push sink cannot wedge a worker. Every
//! refusal is the typed [`ErrorKind::Overloaded`] so clients can back
//! off; every limit defaults to off, making an unconfigured server
//! behave exactly like the pre-admission one.
//!
//! # Invariants
//!
//! * **Admission decisions run on the connection's reader thread,
//!   before a request is enqueued.** A shed or rate-limited request
//!   never occupies a worker slot; its `Overloaded` reply is written
//!   directly from the reader. `SUBSCRIBE` and `SHUTDOWN` are exempt —
//!   throttling the control verbs could strand a connection that is
//!   trying to wind down.
//! * **The read-lane depth counts admitted-but-unfinished reads.** It
//!   is incremented by [`ConnAdmission::track_read`] at enqueue and
//!   decremented when the corresponding [`DepthGuard`] drops after the
//!   reply is written, so shedding keys off real in-flight pressure,
//!   not queue residency — a gated dispatch keeps the depth high no
//!   matter how workers are scheduled.
//! * **Only `TOPN`/`MPREDICT` are sheddable.** `RATE`/`MRATE` carry
//!   client state the server has not seen; dropping reads is a retry,
//!   dropping writes is data loss, so ingest is only ever refused by
//!   the rate limiter or the queue's own backpressure.
//! * **An evicted writer stays evicted.** The first write failure
//!   poisons [`EvictingWriter`] permanently: a frame that timed out
//!   mid-write has already corrupted framing, so later frames must not
//!   reach the wire. Deadline expiries (`TimedOut`/`WouldBlock` from
//!   the socket's write timeout) count into `server.evictions`; the
//!   poisoned writer makes the push sink unsubscribe itself and the
//!   connection workers drain, which is what "evicted, not waited on"
//!   means — publish fan-out never blocks on the dead peer.

use super::protocol::{ErrorKind, Request};
use crate::config::LimitsSection;
use crate::metrics::Registry;
use std::io::Write;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

/// Classic token bucket: `rate` tokens/second refill, `burst` capacity,
/// one token per admitted request. Time is passed in explicitly so the
/// refill arithmetic is deterministic under test.
pub struct TokenBucket {
    rate: f64,
    burst: f64,
    tokens: f64,
    last: Instant,
}

impl TokenBucket {
    pub fn new(rate_per_sec: u32, burst: u32, now: Instant) -> Self {
        TokenBucket {
            rate: rate_per_sec as f64,
            burst: burst as f64,
            tokens: burst as f64,
            last: now,
        }
    }

    /// Take one token if available, refilling for the time elapsed
    /// since the last call first.
    pub fn try_take(&mut self, now: Instant) -> bool {
        let dt = now.saturating_duration_since(self.last).as_secs_f64();
        self.last = now;
        self.tokens = (self.tokens + dt * self.rate).min(self.burst);
        if self.tokens >= 1.0 {
            self.tokens -= 1.0;
            true
        } else {
            false
        }
    }
}

/// Reads the shedder may refuse under pressure: the expensive ranking
/// and batch-prediction verbs. Point reads stay cheap enough to serve.
pub fn is_sheddable(req: &Request) -> bool {
    matches!(req, Request::TopN { .. } | Request::MPredict { .. })
}

/// Per-connection admission state, created once per accepted socket
/// from the server's `[limits]`.
pub struct ConnAdmission {
    bucket: Option<Mutex<TokenBucket>>,
    shed_highwater: usize,
    depth: AtomicUsize,
    registry: Registry,
}

impl ConnAdmission {
    pub fn new(limits: &LimitsSection, registry: Registry) -> Self {
        let bucket = (limits.rate_per_conn > 0).then(|| {
            Mutex::new(TokenBucket::new(limits.rate_per_conn, limits.burst, Instant::now()))
        });
        ConnAdmission {
            bucket,
            shed_highwater: limits.shed_highwater,
            depth: AtomicUsize::new(0),
            registry,
        }
    }

    /// Decide whether `req` may proceed. `Err(Overloaded)` means the
    /// reader should answer the typed refusal itself and move on.
    pub fn admit(&self, req: &Request) -> Result<(), ErrorKind> {
        if matches!(req, Request::Subscribe | Request::Shutdown) {
            return Ok(());
        }
        if let Some(bucket) = &self.bucket {
            let mut b = bucket.lock().unwrap_or_else(|e| e.into_inner());
            if !b.try_take(Instant::now()) {
                self.registry.counter("server.rate_limited").inc();
                return Err(ErrorKind::Overloaded);
            }
        }
        if self.shed_highwater > 0
            && is_sheddable(req)
            && self.depth.load(Ordering::Acquire) >= self.shed_highwater
        {
            self.registry.counter("server.shed_reads").inc();
            return Err(ErrorKind::Overloaded);
        }
        Ok(())
    }

    /// Register one admitted read in flight; the returned guard drops
    /// the depth back down when the read's reply has been written.
    pub fn track_read(self: &Arc<Self>) -> DepthGuard {
        self.depth.fetch_add(1, Ordering::AcqRel);
        DepthGuard(Arc::clone(self))
    }

    /// Current in-flight read count (admitted, reply not yet written).
    pub fn read_depth(&self) -> usize {
        self.depth.load(Ordering::Acquire)
    }
}

/// RAII handle for one in-flight read; see [`ConnAdmission::track_read`].
pub struct DepthGuard(Arc<ConnAdmission>);

impl Drop for DepthGuard {
    fn drop(&mut self) {
        self.0.depth.fetch_sub(1, Ordering::AcqRel);
    }
}

/// A `Write` wrapper enforcing slow-reader eviction: the first write or
/// flush failure poisons it permanently (framing is already lost), and
/// deadline expiries — the `TimedOut`/`WouldBlock` a socket write
/// timeout surfaces — count into `server.evictions`. Wrapped around
/// every connection writer, so both reply writes and push-sink writes
/// stop dead instead of waiting on a blocked peer.
pub struct EvictingWriter<W> {
    inner: W,
    evicted: bool,
    registry: Registry,
}

impl<W: Write> EvictingWriter<W> {
    pub fn new(inner: W, registry: Registry) -> Self {
        EvictingWriter { inner, evicted: false, registry }
    }

    fn poison(&mut self, e: std::io::Error) -> std::io::Error {
        if matches!(
            e.kind(),
            std::io::ErrorKind::TimedOut | std::io::ErrorKind::WouldBlock
        ) {
            self.registry.counter("server.evictions").inc();
        }
        self.evicted = true;
        e
    }

    fn refused() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::BrokenPipe, "connection evicted")
    }
}

impl<W: Write> Write for EvictingWriter<W> {
    fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
        if self.evicted {
            return Err(Self::refused());
        }
        match self.inner.write(buf) {
            Err(e) => Err(self.poison(e)),
            ok => ok,
        }
    }

    fn flush(&mut self) -> std::io::Result<()> {
        if self.evicted {
            return Err(Self::refused());
        }
        match self.inner.flush() {
            Err(e) => Err(self.poison(e)),
            ok => ok,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    #[test]
    fn token_bucket_burst_then_refill() {
        let t0 = Instant::now();
        let mut b = TokenBucket::new(10, 3, t0);
        // burst capacity drains without any elapsed time
        assert!(b.try_take(t0));
        assert!(b.try_take(t0));
        assert!(b.try_take(t0));
        assert!(!b.try_take(t0));
        // 10/s refill: 100ms buys exactly one token
        assert!(b.try_take(t0 + Duration::from_millis(100)));
        assert!(!b.try_take(t0 + Duration::from_millis(100)));
        // refill never exceeds the burst capacity
        let mut b = TokenBucket::new(1000, 2, t0);
        assert!(b.try_take(t0 + Duration::from_secs(60)));
        assert!(b.try_take(t0 + Duration::from_secs(60)));
        assert!(!b.try_take(t0 + Duration::from_secs(60)));
    }

    #[test]
    fn admit_rate_limits_and_counts() {
        let limits = LimitsSection { rate_per_conn: 1000, burst: 2, ..Default::default() };
        let registry = Registry::new();
        let adm = ConnAdmission::new(&limits, registry.clone());
        let read = Request::TopN { row: 0, n: 3 };
        assert!(adm.admit(&read).is_ok());
        assert!(adm.admit(&read).is_ok());
        // the burst is gone and ~no time has passed
        assert_eq!(adm.admit(&read), Err(ErrorKind::Overloaded));
        assert_eq!(registry.counter("server.rate_limited").get(), 1);
        // control verbs bypass the bucket even when it is empty
        assert!(adm.admit(&Request::Subscribe).is_ok());
        assert!(adm.admit(&Request::Shutdown).is_ok());
    }

    #[test]
    fn shedding_prefers_writes_and_tracks_depth() {
        let limits = LimitsSection { shed_highwater: 1, ..Default::default() };
        let registry = Registry::new();
        let adm = Arc::new(ConnAdmission::new(&limits, registry.clone()));
        let topn = Request::TopN { row: 0, n: 3 };
        let rate = Request::Rate { row: 0, col: 0, value: 3.0 };
        assert!(adm.admit(&topn).is_ok());
        let guard = adm.track_read();
        assert_eq!(adm.read_depth(), 1);
        // at the high-water mark: expensive reads shed, ingest admitted
        assert_eq!(adm.admit(&topn), Err(ErrorKind::Overloaded));
        assert_eq!(
            adm.admit(&Request::MPredict { row: 0, cols: vec![1] }),
            Err(ErrorKind::Overloaded)
        );
        assert!(adm.admit(&rate).is_ok());
        assert!(adm.admit(&Request::Predict { row: 0, col: 0 }).is_ok());
        assert_eq!(registry.counter("server.shed_reads").get(), 2);
        // the guard's drop reopens admission
        drop(guard);
        assert_eq!(adm.read_depth(), 0);
        assert!(adm.admit(&topn).is_ok());
    }

    /// A writer that accepts `budget` bytes, then times out forever —
    /// an in-memory stand-in for a peer that stopped reading.
    struct StallingWriter {
        budget: usize,
    }

    impl Write for StallingWriter {
        fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
            if self.budget == 0 {
                return Err(std::io::Error::new(
                    std::io::ErrorKind::TimedOut,
                    "send buffer full",
                ));
            }
            let n = buf.len().min(self.budget);
            self.budget -= n;
            Ok(n)
        }

        fn flush(&mut self) -> std::io::Result<()> {
            Ok(())
        }
    }

    #[test]
    fn evicting_writer_poisons_once_and_counts() {
        let registry = Registry::new();
        let mut w = EvictingWriter::new(StallingWriter { budget: 4 }, registry.clone());
        assert_eq!(w.write(b"abcd").unwrap(), 4);
        // deadline expiry: counted once, poisoned forever
        assert_eq!(
            w.write(b"more").unwrap_err().kind(),
            std::io::ErrorKind::TimedOut
        );
        assert_eq!(w.write(b"more").unwrap_err().kind(), std::io::ErrorKind::BrokenPipe);
        assert!(w.flush().is_err());
        assert_eq!(registry.counter("server.evictions").get(), 1);
        // a non-deadline failure poisons but is not an eviction
        struct Broken;
        impl Write for Broken {
            fn write(&mut self, _: &[u8]) -> std::io::Result<usize> {
                Err(std::io::Error::new(std::io::ErrorKind::BrokenPipe, "peer gone"))
            }
            fn flush(&mut self) -> std::io::Result<()> {
                Ok(())
            }
        }
        let registry = Registry::new();
        let mut w = EvictingWriter::new(Broken, registry.clone());
        assert!(w.write(b"x").is_err());
        assert_eq!(registry.counter("server.evictions").get(), 0);
    }
}
