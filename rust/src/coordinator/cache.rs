//! Per-row Top-N result cache with dirty-band partial re-scoring — the
//! incremental read path.
//!
//! `TOPN` used to score every column on every request even though the
//! sharded publish reports exactly which column bands a flush moved.
//! This module closes that gap: each cached row holds one *candidate
//! list per column band* (every unrated column of the band, scored and
//! sorted under the shared ranking comparator, truncated to
//! [`MAX_TOPN_ITEMS`]). A read merges the per-band lists k-way; bands
//! untouched since they were scored are served from memory, and only
//! bands a publish dirtied are re-scored. Because the global Top-N of
//! `n ≤ MAX_TOPN_ITEMS` items can draw at most `MAX_TOPN_ITEMS` entries
//! from any single band, the merge of per-band prefixes is bit-identical
//! to ranking the full catalog (`engine::rank_unrated_by`) — the
//! property tests in `tests/cache.rs` hold all three serving flavours
//! to that.
//!
//! The same structure drives `SUBSCRIBE` push-invalidation: the
//! publisher calls [`TopNCache::invalidate`] once per snapshot publish,
//! and every subscriber sink registered via [`TopNCache::subscribe`]
//! receives the `(version, dirty bands)` pair that the server forwards
//! to `SUBSCRIBE`d connections as [`Response::Push`] frames.
//!
//! # Invariants
//!
//! * **A band list is usable for a snapshot `v` iff the band's content
//!   is identical at `v` and at the list's stamp.** `band_stamp[b]`
//!   records the version at which band `b` last changed; a list stamped
//!   `u` is merged into a read at version `v` only when
//!   `band_stamp[b] ≤ min(u, v)`. Stamps only advance, so the check is
//!   exact, never heuristic.
//! * **A rating to row `i` invalidates *all* of row `i`'s cached bands,
//!   not just the rated column's band.** The Eq. (1) neighbourhood scan
//!   reads row `i`'s full rating row, so a new rating shifts
//!   predictions in clean bands too. `invalidate` drops the row's entry
//!   and records the version in `row_stamp`; an insert computed from an
//!   older snapshot is refused against it.
//! * **Universe growth clears everything.** Growth shifts `band_of`
//!   boundaries, re-slices every shard and may re-baseline, so
//!   `invalidate(.., grew=true)` drops all entries, advances every band
//!   stamp, and blocks inserts from pre-growth snapshots.
//! * **Inserts are validated under the lock — a stale entry can never
//!   survive a publish.** A list scored against snapshot `v` is stored
//!   only if, at insert time, `band_stamp[b] ≤ v`, `grew_stamp ≤ v`,
//!   and row `i` has not been rated after `v`. A publish that races a
//!   read therefore loses the cache write, never the correctness.
//! * **`row_stamp` pruning is horizon-bounded.** Entries older than
//!   [`STALE_HORIZON`] publishes are pruned, and symmetrically any
//!   insert whose snapshot lags the current version by more than the
//!   horizon is refused — pruned history can never admit a stale list.
//! * **Subscribers are notified after the cache state is updated**, so
//!   a client that re-reads on a push can never observe a pre-push
//!   cache. Sinks returning `false` (dead connections) are dropped.
//!
//! [`MAX_TOPN_ITEMS`]: super::protocol::MAX_TOPN_ITEMS
//! [`Response::Push`]: super::protocol::Response::Push

use super::engine::rank_cmp;
use super::protocol::MAX_TOPN_ITEMS;
use crate::metrics::{Counter, Registry};
use crate::sparse::band_of;
use std::collections::HashMap;
use std::sync::{Arc, Mutex};

/// Most cached rows per engine. Bounds memory at roughly
/// `MAX_CACHED_ROWS × nbands × MAX_TOPN_ITEMS × 8` bytes; rated-row
/// invalidation recycles slots under write traffic.
pub const MAX_CACHED_ROWS: usize = 4096;

/// How many publishes a `row_stamp` tombstone outlives (and the maximum
/// snapshot lag an insert may have). See the module invariants.
const STALE_HORIZON: u64 = 64;

/// A subscriber sink: called with `(version, dirty bands)` at each
/// publish (`dirty` empty ⇒ growth, everything changed). Return `false`
/// to unsubscribe (e.g. the connection closed).
pub type PushSink = Box<dyn Fn(u64, &[u32]) -> bool + Send + Sync>;

/// One band's scored candidates: every unrated column of the band for
/// this row, sorted by [`rank_cmp`], truncated to [`MAX_TOPN_ITEMS`].
struct BandList {
    /// Snapshot version the list was scored against.
    stamp: u64,
    items: Vec<(u32, f32)>,
}

struct RowEntry {
    /// One optional list per column band (`None` = never scored or
    /// dropped).
    bands: Vec<Option<BandList>>,
}

struct CacheState {
    /// Latest version `invalidate` has seen.
    version: u64,
    /// Version at which band `b`'s content last changed.
    band_stamp: Vec<u64>,
    /// Version of the last universe growth.
    grew_stamp: u64,
    /// Version at which a row was last rated (insert guard; pruned past
    /// [`STALE_HORIZON`]).
    row_stamp: HashMap<u32, u64>,
    rows: HashMap<u32, RowEntry>,
    subs: Vec<PushSink>,
}

/// Outcome class of one cached read (drives the `cache.*` metrics).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CacheOutcome {
    /// Every band served from memory.
    Hit,
    /// Some bands served from memory, dirty bands re-scored.
    Partial,
    /// No usable entry; every band scored.
    Miss,
}

/// The shared, thread-safe Top-N cache. One per serving engine; all
/// flavours (`Mutex<Engine>`, `SharedEngine`, `BandedEngine`) route
/// their `TOPN` reads through [`TopNCache::top_n`].
pub struct TopNCache {
    nbands: usize,
    state: Mutex<CacheState>,
    hits: Arc<Counter>,
    misses: Arc<Counter>,
    partial: Arc<Counter>,
    invalidations: Arc<Counter>,
    mpredict_hits: Arc<Counter>,
    mpredict_misses: Arc<Counter>,
}

impl TopNCache {
    pub fn new(nbands: usize, metrics: &Registry) -> Self {
        assert!(nbands >= 1, "cache needs at least one band");
        TopNCache {
            nbands,
            state: Mutex::new(CacheState {
                version: 0,
                band_stamp: vec![0; nbands],
                grew_stamp: 0,
                row_stamp: HashMap::new(),
                rows: HashMap::new(),
                subs: Vec::new(),
            }),
            hits: metrics.counter("cache.hits"),
            misses: metrics.counter("cache.misses"),
            partial: metrics.counter("cache.partial"),
            invalidations: metrics.counter("cache.invalidations"),
            mpredict_hits: metrics.counter("cache.mpredict_hits"),
            mpredict_misses: metrics.counter("cache.mpredict_misses"),
        }
    }

    pub fn nbands(&self) -> usize {
        self.nbands
    }

    /// Register a push sink; it fires on every subsequent publish.
    pub fn subscribe(&self, sink: PushSink) {
        self.state.lock().unwrap_or_else(|e| e.into_inner()).subs.push(sink);
    }

    /// Publish notification: snapshot `version` is now visible with the
    /// given dirty column bands and flush-rated rows. Must be called
    /// *after* the snapshot swap so subscribers re-reading on the push
    /// see the new state. `grew` ⇒ the universe dimensions changed.
    pub fn invalidate(&self, version: u64, dirty: &[u32], rated_rows: &[u32], grew: bool) {
        let mut st = self.state.lock().unwrap_or_else(|e| e.into_inner());
        self.invalidations.inc();
        if version > st.version {
            st.version = version;
        }
        if grew {
            st.grew_stamp = st.grew_stamp.max(version);
            for s in &mut st.band_stamp {
                *s = (*s).max(version);
            }
            st.rows.clear();
            // Inserts from pre-growth snapshots are blocked by
            // `grew_stamp`, so rating history before the growth can be
            // forgotten wholesale.
            st.row_stamp.clear();
        } else {
            for &b in dirty {
                if let Some(s) = st.band_stamp.get_mut(b as usize) {
                    *s = (*s).max(version);
                }
            }
            for &i in rated_rows {
                st.rows.remove(&i);
                st.row_stamp.insert(i, version);
            }
            let floor = version.saturating_sub(STALE_HORIZON);
            st.row_stamp.retain(|_, s| *s >= floor);
        }
        // Notify after the state update (see module invariants). Growth
        // pushes an empty dirty set: the protocol's "everything changed".
        let bands: &[u32] = if grew { &[] } else { dirty };
        st.subs.retain(|sink| sink(version, bands));
    }

    /// The cache-aware Top-N read. `version` is the snapshot the caller
    /// is serving from; `score_band(b)` must return band `b`'s full
    /// candidate list for this row, scored against that same snapshot,
    /// sorted by [`rank_cmp`] and truncated to [`MAX_TOPN_ITEMS`]
    /// (`engine::band_candidates` does exactly this). The returned
    /// ranking is bit-identical to `engine::rank_unrated_by` over the
    /// whole catalog for any `n_items ≤ MAX_TOPN_ITEMS`.
    pub fn top_n(
        &self,
        version: u64,
        row: u32,
        n_items: usize,
        mut score_band: impl FnMut(usize) -> Vec<(u32, f32)>,
    ) -> Vec<(u32, f32)> {
        // Phase 1 (locked): pull usable band lists.
        let mut lists: Vec<Option<Vec<(u32, f32)>>> = vec![None; self.nbands];
        {
            let st = self.state.lock().unwrap_or_else(|e| e.into_inner());
            if let Some(entry) = st.rows.get(&row) {
                for (b, slot) in entry.bands.iter().enumerate() {
                    if let Some(list) = slot {
                        let stamp_ok =
                            st.band_stamp[b] <= version && st.band_stamp[b] <= list.stamp;
                        if stamp_ok {
                            lists[b] = Some(list.items.clone());
                        }
                    }
                }
            }
        }

        // Phase 2 (unlocked): score the bands the cache could not serve.
        let cached = lists.iter().filter(|l| l.is_some()).count();
        let mut fresh: Vec<(usize, Vec<(u32, f32)>)> = Vec::new();
        for b in 0..self.nbands {
            if lists[b].is_none() {
                let scored = score_band(b);
                debug_assert!(scored.len() <= MAX_TOPN_ITEMS);
                lists[b] = Some(scored.clone());
                fresh.push((b, scored));
            }
        }
        match cached {
            0 => self.misses.inc(),
            c if c == self.nbands => self.hits.inc(),
            _ => self.partial.inc(),
        }

        // Phase 3 (locked): store the freshly scored bands, but only if
        // no publish invalidated them while we were scoring.
        if !fresh.is_empty() {
            let mut st = self.state.lock().unwrap_or_else(|e| e.into_inner());
            let admissible = st.grew_stamp <= version
                && st.row_stamp.get(&row).map_or(true, |&s| s <= version)
                && version.saturating_add(STALE_HORIZON) >= st.version
                && (st.rows.len() < MAX_CACHED_ROWS || st.rows.contains_key(&row));
            if admissible {
                let nbands = self.nbands;
                let entry = st
                    .rows
                    .entry(row)
                    .or_insert_with(|| RowEntry { bands: (0..nbands).map(|_| None).collect() });
                for (b, items) in fresh {
                    // Re-checked per band: a publish during scoring may
                    // have dirtied exactly this band.
                    if st.band_stamp[b] <= version {
                        let newer = entry.bands[b]
                            .as_ref()
                            .map_or(true, |old| old.stamp <= version);
                        if newer {
                            entry.bands[b] = Some(BandList { stamp: version, items });
                        }
                    }
                }
            }
        }

        merge_ranked(&lists, n_items)
    }

    /// `MPREDICT` riding the Top-N candidate lists: resolve every
    /// requested column of `row` from cached band lists scored against
    /// exactly `version`, or `None` if any in-range column cannot be.
    ///
    /// All-or-nothing on purpose: a column absent from a *valid* band
    /// list is ambiguous — it may be rated (lists hold only unrated
    /// columns) or truncated past [`MAX_TOPN_ITEMS`] — so partial
    /// answers cannot be assembled without re-scoring anyway. A cached
    /// score is admissible under the same predicate as a Top-N merge
    /// (`band_stamp[b] ≤ min(version, list.stamp)`), which makes the
    /// fast path bit-identical to the full prediction (the lists were
    /// produced by the same clamped predict the slow path runs).
    /// Out-of-range columns resolve to `None` without touching a band.
    pub fn lookup_scores(
        &self,
        version: u64,
        row: u32,
        ncols: usize,
        cols: &[u32],
    ) -> Option<Vec<Option<f32>>> {
        let st = self.state.lock().unwrap_or_else(|e| e.into_inner());
        let entry = st.rows.get(&row);
        let mut out = Vec::with_capacity(cols.len());
        for &j in cols {
            if j as usize >= ncols {
                out.push(None);
                continue;
            }
            let b = band_of(j as usize, ncols, self.nbands);
            let hit = entry
                .and_then(|e| e.bands[b].as_ref())
                .filter(|list| st.band_stamp[b] <= version && st.band_stamp[b] <= list.stamp)
                .and_then(|list| list.items.iter().find(|(c, _)| *c == j))
                .map(|&(_, s)| s);
            match hit {
                Some(s) => out.push(Some(s)),
                None => {
                    self.mpredict_misses.inc();
                    return None;
                }
            }
        }
        self.mpredict_hits.inc();
        Some(out)
    }

    /// Test/bench visibility into the metric counters.
    pub fn counts(&self) -> (u64, u64, u64) {
        (self.hits.get(), self.misses.get(), self.partial.get())
    }

    /// Test/bench visibility into the `MPREDICT` fast-path counters.
    pub fn mpredict_counts(&self) -> (u64, u64) {
        (self.mpredict_hits.get(), self.mpredict_misses.get())
    }
}

/// K-way merge of per-band candidate lists under [`rank_cmp`],
/// truncated to `n_items`. Each input list is sorted by `rank_cmp`;
/// column ids are globally unique across lists, and `rank_cmp` is a
/// total order, so the merge reproduces exactly the prefix of the
/// globally sorted sequence.
fn merge_ranked(lists: &[Option<Vec<(u32, f32)>>], n_items: usize) -> Vec<(u32, f32)> {
    let mut heads: Vec<usize> = vec![0; lists.len()];
    let mut out = Vec::with_capacity(n_items);
    while out.len() < n_items {
        let mut best: Option<(usize, (u32, f32))> = None;
        for (b, list) in lists.iter().enumerate() {
            let Some(items) = list else { continue };
            let Some(&cand) = items.get(heads[b]) else { continue };
            let better = match best {
                None => true,
                Some((_, cur)) => rank_cmp(&cand, &cur) == std::cmp::Ordering::Less,
            };
            if better {
                best = Some((b, cand));
            }
        }
        let Some((b, cand)) = best else { break };
        heads[b] += 1;
        out.push(cand);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    fn band_list(items: &[(u32, f32)]) -> Vec<(u32, f32)> {
        let mut v = items.to_vec();
        v.sort_unstable_by(rank_cmp);
        v
    }

    #[test]
    fn merge_matches_global_sort() {
        let a = band_list(&[(0, 3.0), (1, 5.0), (2, f32::NAN)]);
        let b = band_list(&[(3, 5.0), (4, 4.0)]);
        let merged = merge_ranked(&[Some(a.clone()), Some(b.clone())], 10);
        let mut all = [a, b].concat();
        all.sort_unstable_by(rank_cmp);
        assert_eq!(
            merged.iter().map(|(j, s)| (*j, s.to_bits())).collect::<Vec<_>>(),
            all.iter().map(|(j, s)| (*j, s.to_bits())).collect::<Vec<_>>()
        );
    }

    #[test]
    fn hit_partial_miss_accounting() {
        let cache = TopNCache::new(2, &Registry::new());
        let score = |_b: usize| vec![(0u32, 1.0f32)];
        cache.top_n(1, 7, 1, score); // miss: both bands scored
        cache.top_n(1, 7, 1, score); // hit: both bands cached
        cache.invalidate(2, &[1], &[], false); // band 1 dirty
        cache.top_n(2, 7, 1, score); // partial: band 0 cached, band 1 re-scored
        assert_eq!(cache.counts(), (1, 1, 1));
    }

    #[test]
    fn rated_row_drops_whole_entry() {
        let cache = TopNCache::new(2, &Registry::new());
        let calls = AtomicUsize::new(0);
        let score = |_b: usize| {
            calls.fetch_add(1, Ordering::Relaxed);
            vec![(0u32, 1.0f32)]
        };
        cache.top_n(1, 7, 1, score);
        cache.invalidate(2, &[0], &[7], false); // row 7 rated: entry gone
        cache.top_n(2, 7, 1, score);
        assert_eq!(calls.load(Ordering::Relaxed), 4, "both bands re-scored");
        assert_eq!(cache.counts(), (0, 2, 0));
    }

    #[test]
    fn growth_clears_everything() {
        let cache = TopNCache::new(2, &Registry::new());
        let score = |_b: usize| vec![(0u32, 1.0f32)];
        cache.top_n(1, 7, 1, score);
        cache.top_n(1, 8, 1, score);
        cache.invalidate(2, &[], &[], true);
        cache.top_n(2, 7, 1, score);
        cache.top_n(2, 8, 1, score);
        assert_eq!(cache.counts(), (0, 4, 0));
    }

    #[test]
    fn stale_insert_is_refused_after_publish() {
        // A read against snapshot 1 that completes after row 7 was rated
        // at publish 2 must not leave its (now stale) lists behind.
        let cache = TopNCache::new(1, &Registry::new());
        cache.invalidate(2, &[0], &[7], false);
        cache.top_n(1, 7, 1, |_b| vec![(0u32, 1.0f32)]); // late read, old snapshot
        // A fresh read at version 2 must re-score, not reuse the stale list.
        let calls = AtomicUsize::new(0);
        cache.top_n(2, 7, 1, |_b| {
            calls.fetch_add(1, Ordering::Relaxed);
            vec![(0u32, 2.0f32)]
        });
        assert_eq!(calls.load(Ordering::Relaxed), 1, "stale insert must have been refused");
    }

    #[test]
    fn lookup_scores_is_all_or_nothing() {
        let cache = TopNCache::new(2, &Registry::new());
        // ncols = 4 → band 0 holds cols {0, 1}, band 1 holds {2, 3}.
        // Band 1's list omits col 3 (rated or truncated — ambiguous).
        cache.top_n(1, 7, 4, |b| {
            if b == 0 {
                band_list(&[(0, 1.5), (1, 2.5)])
            } else {
                band_list(&[(2, 3.5)])
            }
        });
        assert_eq!(
            cache.lookup_scores(1, 7, 4, &[1, 2]),
            Some(vec![Some(2.5), Some(3.5)])
        );
        assert!(
            cache.lookup_scores(1, 7, 4, &[3]).is_none(),
            "absence from a valid list must fail the whole lookup"
        );
        assert_eq!(
            cache.lookup_scores(1, 7, 4, &[0, 9]),
            Some(vec![Some(1.5), None]),
            "out-of-range columns resolve to None without a band probe"
        );
        cache.invalidate(2, &[1], &[], false);
        assert!(cache.lookup_scores(2, 7, 4, &[2]).is_none(), "dirty band");
        assert_eq!(cache.mpredict_counts(), (2, 2));
    }

    #[test]
    fn subscribers_observe_publishes_in_order_and_unsubscribe() {
        let cache = TopNCache::new(2, &Registry::new());
        let seen = Arc::new(Mutex::new(Vec::new()));
        let seen2 = seen.clone();
        cache.subscribe(Box::new(move |v, dirty| {
            seen2.lock().unwrap().push((v, dirty.to_vec()));
            v < 3 // unsubscribe after version 3
        }));
        cache.invalidate(2, &[1], &[], false);
        cache.invalidate(3, &[], &[], true);
        cache.invalidate(4, &[0], &[], false); // sink already dropped
        assert_eq!(
            *seen.lock().unwrap(),
            vec![(2, vec![1]), (3, vec![])],
            "push order follows publish order; growth pushes an empty set"
        );
    }
}
