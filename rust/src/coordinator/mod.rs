//! Layer-3 coordinator: everything that orchestrates the system.
//!
//! * [`rotation`] — the multi-device block-rotation scheduler of Fig. 5
//!   (MCUSGD++/MCULSH-MF): schedule construction, the virtual-clock cost
//!   model that reproduces the paper's multi-GPU speedups, and the real
//!   threaded execution path.
//! * [`stream`] — the online-learning orchestrator: bounded ingest queue
//!   with backpressure, event batching, hash-delta application, and
//!   incremental training (the "online sparse big data" pipeline).
//! * [`engine`] — the serving engine: predictions, top-N recommendation,
//!   and live ingestion against a trained CULSH-MF model.
//! * [`cache`] — the incremental read path: a per-row Top-N result
//!   cache keyed off the published snapshot version, invalidated per
//!   dirty column band (plus rated rows) by the same flush report that
//!   drives the sharded publish — warm `TOPN` reads cost O(changed
//!   bands), not O(catalog) — and the `SUBSCRIBE` push-notification
//!   fan-out.
//! * [`shared`] — the concurrent serving core: epoch-swapped,
//!   column-band-sharded read snapshots over a single writer thread, so
//!   `PREDICT`/`MPREDICT`/`TOPN`/`STATS` proceed lock-free while `RATE`
//!   events stream through the online path — reads are never blocked by
//!   a flush, and a flush republishes only the bands it dirtied.
//! * [`banded`] — the multi-writer ingest core: one write queue +
//!   writer thread per column band (conflict-free by the Latin-square
//!   band split), cross-band barrier epochs for flush and universe
//!   growth, per-band shard publishing — replies bit-identical to the
//!   single-writer flavour.
//! * [`protocol`] — the typed wire layer: [`Request`]/[`Response`]
//!   enums with two interchangeable codecs (the wire-compatible text
//!   line protocol, and a length-prefixed binary codec with sequence
//!   ids that supports pipelining), plus typed [`ErrorKind`]s.
//! * [`server`] — the TCP front end: a bounded connection-thread pool
//!   over any serving flavour, decoding wire messages into `Request`
//!   once and dispatching through one `Serving`-generic path
//!   (`serve --codec text|binary|auto`, auto-detected per connection
//!   by first byte). All three flavours launch through one
//!   [`ServeConfig`](crate::config::ServeConfig)-driven entry point,
//!   `server::serve_with`, which also hosts the `[metrics]` Prometheus
//!   scrape listener.
//! * [`admission`] — per-connection admission control (`[limits]`):
//!   token-bucket rate limiting, read-depth load shedding that drops
//!   `TOPN`/`MPREDICT` before ingest, and the poisoning writer that
//!   evicts peers blocked past their write deadline.
//! * [`client`] — [`LshmfClient`]: synchronous calls plus `pipeline()`
//!   batching (many requests in flight per connection) on either codec.
//! * [`router`] — the multi-node route tier: `lshmf route` fronts N
//!   downstream `serve` processes over the binary codec, replicating
//!   writes in one global order and scatter/gathering reads by column
//!   band, bit-identical to a monolithic engine; dead backends answer
//!   typed `ERR unavailable` and are replayed back to parity on
//!   recovery.
//!
//! Flushes run the Algorithm-4 training core in one of two modes
//! ([`FlushMode`], `serve --flush-mode exact|relaxed`): `exact` is the
//! single-threaded bit-pinned reference; `relaxed` parallelizes the
//! core *inside* the flush epoch on band threads under the
//! [`rotation`] schedule, trading bit-identity for a property-tested
//! bounded divergence. `ARCHITECTURE.md` at the repository root walks
//! the whole request path through these modules.

pub mod admission;
pub mod banded;
pub mod cache;
pub mod client;
pub mod engine;
pub mod protocol;
pub mod rotation;
pub mod router;
pub mod server;
pub mod shared;
pub mod stream;

pub use banded::{BandedEngine, BandedHandle, BandedOrchestrator};
pub use cache::TopNCache;
pub use client::{ClientCodec, LshmfClient, Pipeline};
pub use engine::Engine;
pub use protocol::{CodecChoice, ErrorKind, OkBody, Request, Response};
pub use rotation::{RotationPlan, VirtualClockReport};
pub use router::Router;
pub use shared::{SharedEngine, Snapshot, WriterHandle, DEFAULT_SHARDS};
pub use stream::{FlushMode, StreamConfig, StreamOrchestrator};
