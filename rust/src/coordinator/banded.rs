//! Multi-writer ingest: one write queue + writer thread per column band.
//!
//! PR 2's sharded snapshot left the seam explicit: the per-shard dirty
//! sets already follow the Latin-square column-band split the rotation
//! schedule ([`super::rotation`]) uses, so the remaining step to
//! horizontal write scale is splitting the single `mpsc` write queue
//! into **one queue per band**. This module is that step, following the
//! cuMF line of work (Tan et al.): factor updates partition cleanly
//! along the block-rotation schedule because a rating `(i, j, r)`
//! touches only column `j`'s parameters and hash accumulators — routing
//! it to `band_of(j)` makes concurrent ingest conflict-free by
//! construction.
//!
//! Structure:
//!
//! * [`BandedOrchestrator`] wraps the [`StreamOrchestrator`] internals
//!   split per band: the **shared core** (model, combined matrix,
//!   re-rating index, training rng) is only ever touched inside a flush
//!   epoch, while each **band state** (that band's slice of the hash
//!   accumulators — [`OnlineHashState::split_bands`] — plus its pending
//!   write buffer) is owned by one band writer thread.
//! * [`BandedEngine`] is the cloneable serving handle: reads are the
//!   same lock-free [`Snapshot`] path the single-writer flavour uses
//!   (both delegate to the [`Snapshot`] read helpers, so replies cannot
//!   drift); `rate` routes to the owning band's queue and round-trips
//!   through that band's writer — concurrent raters on different bands
//!   are served by different threads in parallel.
//! * A **flush is a cross-band barrier epoch**: the triggering writer
//!   takes the flush lock, quiesces every band (acquiring the band
//!   locks in order), merges the per-band buffers back into global
//!   arrival order (each rating carries a sequence stamp), and runs
//!   exactly the single-writer computation — same dedup, same
//!   per-column absorb order, same Top-K re-search and rng draws — so
//!   the multi-writer path's replies stay **bit-identical** to the
//!   `Mutex<Engine>` reference (`tests/props.rs` holds 1, 2 and 4
//!   writers to byte-equal replies).
//! * Under `serve --flush-mode relaxed`
//!   ([`FlushMode::Relaxed`]), the epoch's **training core itself goes
//!   band-parallel**: the Top-K re-search derives each band's
//!   signatures on a thread acting for that band
//!   ([`topk_banded_parallel`], still bit-identical to the monolithic
//!   search), and the Algorithm-4 updates run on one rotation lane per
//!   band under the Latin-square schedule
//!   ([`crate::mf::online::online_update_relaxed_with_topk`]) —
//!   new-row lanes rotate across barrier-separated sub-steps so no two
//!   lanes ever touch a new row's parameters concurrently. Relaxed
//!   epochs are
//!   deterministic and race-free but reorder f32 SGD updates, so
//!   factors carry bounded rounding-scale divergence from the exact
//!   reference instead of bit-identity (property-tested); per-band
//!   training time lands in the `flush.band<b>.train_micros` metrics
//!   and each relaxed epoch counts into `flush.relaxed_epochs`.
//! * **Universe growth** (a rating whose column id exceeds current
//!   dims) widens the barrier: band boundaries move with `ncols`, so
//!   the epoch assembles the banded accumulators back into one state
//!   ([`assemble_bands`]), runs the monolithic growth path once (the
//!   relayout is unavoidable there), and re-splits on the new
//!   boundaries before the writers resume — the same epoch structure
//!   the rotation schedule already encodes.
//! * After the core flush, **each band's shard publishes
//!   independently**: dirty shards (per the flush's rated-column and
//!   moved-Top-K reports, O(report) — see `super::shared::dirty_bands`)
//!   are rebuilt concurrently on scoped builder threads, clean shards
//!   are reference-shared, and one pointer swap installs the assembled
//!   snapshot so readers never observe a torn mix of band versions.
//!
//! Buffer routing is *soft*: a rating buffered under pre-growth
//! boundaries may sit in a neighbouring band's queue until the next
//! epoch, which is harmless because every flush merges all buffers in
//! global arrival order. Hash-accumulator ownership, by contrast, is
//! exact at all times — deltas are applied only inside an epoch, after
//! re-splitting.
//!
//! # Invariants
//!
//! (Machine-checked: `cargo run -p lshmf-check` gates the lock order
//! and this section's presence in tier-1 CI.)
//!
//! * **Lock order is `flush` → `core` → `bands[0..d]`** (band locks in
//!   ascending index order). The per-rate path takes a single band
//!   lock; `buffer_batch` takes only its touched bands' locks in the
//!   same ascending order — no acquisition order can cycle.
//! * **Seq-merge restores arrival order.** Every accepted rating gets a
//!   global sequence stamp at buffering time; an epoch steals all band
//!   buffers and sorts by stamp, so the flush computation sees exactly
//!   the order a single shared buffer would have held.
//! * **Dirty-band keying is O(report).** A publish clones band `b` iff
//!   the flush rated one of `b`'s columns or the re-search moved one of
//!   `b`'s Top-K rows (or the column universe grew, which moves every
//!   band boundary); clean bands are `Arc`-shared from the previous
//!   snapshot.
//! * **Epochs are the only cross-band writers.** Between epochs each
//!   band's hash-accumulator slice is owned by its writer alone;
//!   growth re-splits ownership only inside the barrier with every
//!   band lock held.
//! * **Cache invalidation follows the swap.** The epoch invalidates the
//!   per-row Top-N cache (and fans out `SUBSCRIBE` push frames)
//!   strictly *after* `publish_banded` installs the new snapshot, using
//!   the same dirty-band report the publish keyed off plus the epoch's
//!   rated rows — a subscriber that re-reads on a push always sees the
//!   new state.

use super::cache::{PushSink, TopNCache};
use super::engine::Engine;
use super::protocol::MAX_TOPN_ITEMS;
use super::shared::{dirty_bands, full_snapshot, PublishMetrics, Snapshot};
use super::stream::{
    dedup_batch, record_relaxed_flush_metrics, FlushMode, IngestResult, StreamConfig,
    StreamOrchestrator, StreamParts,
};
use crate::lsh::{assemble_bands, topk_banded, topk_banded_parallel, OnlineHashState};
use crate::metrics::{Counter, Registry};
use crate::mf::neighbourhood::{ColBand, CulshConfig, CulshModel};
use crate::mf::online::{online_update_relaxed_with_topk, online_update_with_topk};
use crate::persist::{CheckpointSource, Persister};
use crate::rng::Rng;
use crate::sparse::{band_of, band_range, Csr, Triples};
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex, MutexGuard, RwLock};
use std::thread::JoinHandle;
use std::time::Instant;

/// A rating stamped with its global arrival order — the merge key that
/// restores the single-writer batch order across band buffers.
struct Stamped {
    seq: u64,
    i: u32,
    j: u32,
    r: f32,
}

/// One band writer's exclusively-owned state: its column range, its
/// slice of the hash accumulators (column ids band-local), and its
/// pending write buffer.
struct BandState {
    lo: usize,
    hi: usize,
    hash: OnlineHashState,
    buffer: Vec<Stamped>,
}

/// The shared core a flush epoch mutates: today's
/// [`StreamOrchestrator`] internals minus what moved into the per-band
/// [`BandState`]s (the write buffer and the hash accumulators).
struct Core {
    /// `Option` so a flush can move the model through the online update.
    model: Option<CulshModel>,
    combined_t: Triples,
    combined: Arc<Csr>,
    /// Position of each stored cell — the last-write-wins re-rating
    /// index (global, because rows span every band).
    cells: HashMap<(u32, u32), u32>,
    rng: Rng,
    train_cfg: CulshConfig,
    last_flush_cols: Vec<u32>,
    last_topk_moved: Vec<u32>,
    last_flush_rows: Vec<u32>,
    version: u64,
}

/// The multi-writer orchestrator: shared core + per-band states +
/// published snapshot. Lock order is `flush` → `core` → `bands[0..d]`;
/// the per-rate path takes only its own band lock (briefly, to push),
/// so ingest on distinct bands never contends.
pub struct BandedOrchestrator {
    snap: RwLock<Arc<Snapshot>>,
    core: Mutex<Core>,
    bands: Vec<Mutex<BandState>>,
    /// Serializes flush epochs.
    flush: Mutex<()>,
    /// Global un-flushed event count (the backpressure / batch trigger —
    /// the same global thresholds the single-writer buffer enforces).
    buffered: AtomicUsize,
    /// Arrival-order stamp source.
    seq: AtomicU64,
    /// Column extent the routing layer resolves bands against; updated
    /// at the growth barrier.
    ncols: AtomicUsize,
    cfg: StreamConfig,
    metrics: Registry,
    publish: PublishMetrics,
    /// Per-row Top-N cache over published snapshots; the flush epoch
    /// invalidates it right after each snapshot swap.
    cache: TopNCache,
    /// Rating-scale clamp, carried for checkpoint serialization.
    clamp: (f32, f32),
    /// Durability coordinator (taken from the engine at spawn). Appends
    /// happen inside the band locks; the epoch checkpoints with every
    /// band lock held, so the watermark covers all allocated seqs.
    persist: Option<Arc<Persister>>,
}

/// A write-path request for one band's writer thread.
enum BandCmd {
    Rate { i: u32, j: u32, r: f32, reply: Sender<IngestResult> },
    RateMany { batch: Vec<(u32, u32, f32)>, reply: Sender<IngestResult> },
    Flush { reply: Sender<usize> },
    Shutdown,
}

/// Per-band-writer ingest counter handles, resolved once at spawn: the
/// per-rate hot path must not allocate metric-name strings.
struct IngestMetrics {
    ingested: Arc<Counter>,
    invalid: Arc<Counter>,
    oob: Arc<Counter>,
    rejected: Arc<Counter>,
}

impl IngestMetrics {
    fn new(metrics: &Registry) -> Self {
        IngestMetrics {
            ingested: metrics.counter("stream.ingested"),
            invalid: metrics.counter("stream.invalid_value"),
            oob: metrics.counter("stream.out_of_bounds"),
            rejected: metrics.counter("stream.rejected"),
        }
    }
}

/// Cloneable handle to the multi-writer serving core. Reads are
/// lock-free after an `Arc` clone (the same [`Snapshot`] machinery as
/// [`super::shared::SharedEngine`]); writes round-trip through the
/// owning band's writer thread.
#[derive(Clone)]
pub struct BandedEngine {
    shared: Arc<BandedOrchestrator>,
    txs: Vec<Sender<BandCmd>>,
    clamp: (f32, f32),
    metrics: Registry,
}

/// Owns the band writer threads; [`BandedHandle::join`] stops them,
/// drains and republishes any buffered events, and reassembles the
/// [`Engine`] for inspection.
pub struct BandedHandle {
    handles: Vec<JoinHandle<()>>,
    txs: Vec<Sender<BandCmd>>,
    shared: Arc<BandedOrchestrator>,
    clamp: (f32, f32),
}

impl BandedEngine {
    /// Split an [`Engine`] into a concurrent read handle plus one
    /// writer thread per column band. `writers` is both the queue count
    /// and the snapshot shard count — one band, one writer, one shard.
    pub fn spawn(mut engine: Engine, writers: usize) -> (BandedEngine, BandedHandle) {
        let d = writers.max(1);
        let clamp = engine.clamp();
        let metrics = engine.metrics().clone();
        let persist = engine.take_persister();
        let version = engine.version();
        let initial = Arc::new(full_snapshot(&engine, d, version));
        let parts = engine.into_orchestrator().into_parts();
        let ncols = parts.combined.ncols();
        let mut bands: Vec<Mutex<BandState>> = parts
            .hash_state
            .split_bands(d)
            .into_iter()
            .enumerate()
            .map(|(b, hash)| {
                let (lo, hi) = band_range(b, ncols, d);
                Mutex::new(BandState { lo, hi, hash, buffer: Vec::new() })
            })
            .collect();
        // Carry any pre-spawn buffered events over, preserving arrival
        // order through the sequence stamps.
        let mut seq = 0u64;
        for (i, j, r) in parts.buffer {
            let b = route_col(j, ncols, d);
            bands[b]
                .get_mut()
                .unwrap_or_else(|e| e.into_inner())
                .buffer
                .push(Stamped { seq, i, j, r });
            seq += 1;
        }
        let buffered = seq as usize;
        // A recovered engine's carried buffer keeps its low local stamps
        // (those events are already on disk under their original seqs);
        // new allocations continue past the persisted history.
        if let Some(p) = &persist {
            seq = seq.max(p.next_seq());
        }
        let shared = Arc::new(BandedOrchestrator {
            snap: RwLock::new(initial),
            core: Mutex::new(Core {
                model: Some(parts.model),
                combined_t: parts.combined_t,
                combined: parts.combined,
                cells: parts.cells,
                rng: parts.rng,
                train_cfg: parts.train_cfg,
                last_flush_cols: parts.last_flush_cols,
                last_topk_moved: parts.last_flush_topk_moved,
                last_flush_rows: parts.last_flush_rows,
                version,
            }),
            bands,
            flush: Mutex::new(()),
            buffered: AtomicUsize::new(buffered),
            seq: AtomicU64::new(seq),
            ncols: AtomicUsize::new(ncols),
            cfg: parts.cfg,
            metrics: metrics.clone(),
            publish: PublishMetrics::new(&metrics, d),
            cache: TopNCache::new(d, &metrics),
            clamp,
            persist,
        });
        let mut txs = Vec::with_capacity(d);
        let mut handles = Vec::with_capacity(d);
        for b in 0..d {
            let (tx, rx) = channel();
            let shared2 = Arc::clone(&shared);
            handles.push(std::thread::spawn(move || band_writer_loop(shared2, b, rx)));
            txs.push(tx);
        }
        let handle = BandedHandle {
            handles,
            txs: txs.clone(),
            shared: Arc::clone(&shared),
            clamp,
        };
        (BandedEngine { shared, txs, clamp, metrics }, handle)
    }

    /// The engine's metric registry (shared with the band writers and
    /// the TCP front end).
    pub fn metrics(&self) -> &Registry {
        &self.metrics
    }

    /// Clone the current snapshot out of the lock (held only for the
    /// `Arc` clone; all computation afterwards is lock-free).
    pub fn snapshot(&self) -> Arc<Snapshot> {
        let t0 = Instant::now();
        let guard = self.shared.snap.read().unwrap_or_else(|e| e.into_inner());
        let snap = Arc::clone(&guard);
        drop(guard);
        let waited = t0.elapsed();
        self.metrics.histogram("shared.read_wait").record(waited);
        self.metrics.gauge("shared.read_wait_last_ns").set(waited.as_nanos() as f64);
        snap
    }

    /// Dimensions of the last-published snapshot.
    pub fn dims(&self) -> (usize, usize) {
        self.snapshot().dims()
    }

    /// Version of the last-published snapshot (monotonic).
    pub fn version(&self) -> u64 {
        self.snapshot().version
    }

    /// Buffered-event count of the last-published snapshot.
    pub fn buffered(&self) -> usize {
        self.snapshot().buffered()
    }

    /// Number of band writers (== queues == snapshot shards).
    pub fn writers(&self) -> usize {
        self.txs.len()
    }

    /// Predict the interaction value for (row, col) on the current
    /// snapshot. `None` if out of range.
    pub fn predict(&self, i: usize, j: usize) -> Option<f32> {
        self.metrics.counter("server.predict").inc();
        self.snapshot().predict_clamped(i, j, self.clamp)
    }

    /// Batched prediction — the whole batch reads one snapshot (the
    /// `MPREDICT` consistency contract).
    pub fn predict_many(&self, i: usize, cols: &[u32]) -> Option<Vec<Option<f32>>> {
        self.metrics.counter("server.mpredict").inc();
        let snap = self.snapshot();
        let (m, n) = snap.dims();
        if i < m {
            if let Some(hit) =
                self.shared.cache.lookup_scores(snap.version, i as u32, n, cols)
            {
                return Some(hit);
            }
        }
        snap.predict_many_clamped(i, cols, self.clamp)
    }

    /// Top-N highest-predicted unrated columns for a row, on the
    /// current snapshot. Requests up to [`MAX_TOPN_ITEMS`] (the
    /// server's `TOPN` bound) go through the per-row cache; larger
    /// programmatic requests fall back to the full lock-free re-score.
    pub fn top_n(&self, i: usize, n_items: usize) -> Vec<(u32, f32)> {
        self.metrics.counter("server.topn").inc();
        let snap = self.snapshot();
        let (m, _) = snap.dims();
        if i >= m {
            return Vec::new();
        }
        if n_items > MAX_TOPN_ITEMS {
            return snap.top_n_clamped(i, n_items, self.clamp);
        }
        let clamp = self.clamp;
        self.shared
            .cache
            .top_n(snap.version, i as u32, n_items, |b| snap.score_band(i, b, clamp))
    }

    /// The per-row Top-N cache (push-subscription surface for the
    /// server's `SUBSCRIBE` verb and the tests).
    pub fn cache(&self) -> &TopNCache {
        &self.shared.cache
    }

    /// Register a push sink fired at every publish; returns the
    /// currently-published snapshot version (the `SUBSCRIBED` reply).
    pub fn subscribe_push(&self, sink: PushSink) -> u64 {
        self.shared.cache.subscribe(sink);
        self.version()
    }

    /// Ingest a rating through the owning band's write queue. Blocks
    /// until that band's writer replies, so backpressure, validation
    /// and flush outcomes surface synchronously — protocol semantics
    /// match the single-threaded engine exactly.
    pub fn rate(&self, i: u32, j: u32, r: f32) -> IngestResult {
        self.metrics.counter("server.rate").inc();
        let timer = self.metrics.timer("shared.write_wait");
        let b = self.route(j);
        let (reply_tx, reply_rx) = channel();
        if self.txs[b].send(BandCmd::Rate { i, j, r, reply: reply_tx }).is_err() {
            // Writers are gone (shutdown): surface as backpressure
            // rather than panicking a connection thread.
            return IngestResult::Rejected;
        }
        let result = reply_rx.recv().unwrap_or(IngestResult::Rejected);
        drop(timer);
        result
    }

    /// Batch-ingest ratings (the `MRATE` verb): one round-trip through
    /// a single band writer, which validates and admits the whole batch
    /// as one unit (backpressure reserved once — see `ingest_batch`)
    /// and distributes the events to their owning bands' buffers. The
    /// carrying queue is the first event's band, so clients that shard
    /// their batches by band keep the per-band queue distribution. An
    /// empty batch answers [`IngestResult::Ignored`] without touching a
    /// queue — the same no-payload contract as the single-writer path.
    pub fn rate_many(&self, batch: &[(u32, u32, f32)]) -> IngestResult {
        self.metrics.counter("server.mrate").inc();
        if batch.is_empty() {
            return IngestResult::Ignored;
        }
        let timer = self.metrics.timer("shared.write_wait");
        let b = self.route(batch[0].1);
        let (reply_tx, reply_rx) = channel();
        if self.txs[b]
            .send(BandCmd::RateMany { batch: batch.to_vec(), reply: reply_tx })
            .is_err()
        {
            return IngestResult::Rejected;
        }
        let result = reply_rx.recv().unwrap_or(IngestResult::Rejected);
        drop(timer);
        result
    }

    /// Force-apply buffered ratings across every band; returns the
    /// number applied.
    pub fn flush(&self) -> usize {
        self.metrics.counter("server.flush").inc();
        let (reply_tx, reply_rx) = channel();
        if self.txs[0].send(BandCmd::Flush { reply: reply_tx }).is_err() {
            return 0;
        }
        reply_rx.recv().unwrap_or(0)
    }

    /// Metrics snapshot (server `STATS` verb): the same coherent-header
    /// contract as the single-writer flavour, plus a `writers` line.
    pub fn stats(&self) -> String {
        self.metrics.counter("server.stats").inc();
        let snap = self.snapshot();
        let (m, n) = snap.dims();
        format!(
            "dims {m}x{n}\nbuffered {}\nversion {}\nshards {}\nwriters {}\n{}",
            snap.buffered(),
            snap.version,
            snap.shards().len(),
            self.txs.len(),
            self.metrics.snapshot()
        )
    }

    /// Band owning column `j` under the current routing extent.
    fn route(&self, j: u32) -> usize {
        route_col(j, self.shared.ncols.load(Ordering::Relaxed), self.txs.len())
    }
}

/// Band routing: out-of-universe columns (growth ratings) clamp to the
/// last band — the flush merges every band's buffer globally, so soft
/// routing never affects what a flush applies.
fn route_col(j: u32, ncols: usize, d: usize) -> usize {
    if ncols == 0 {
        return 0;
    }
    band_of((j as usize).min(ncols - 1), ncols, d)
}

impl BandedHandle {
    /// Stop every band writer, drain and republish buffered events
    /// (the same (version, buffered) coherence contract as the
    /// single-writer shutdown path), and reassemble the [`Engine`].
    pub fn join(self) -> Engine {
        for tx in &self.txs {
            let _ = tx.send(BandCmd::Shutdown);
        }
        for h in self.handles {
            h.join().expect("band writer panicked");
        }
        flush_epoch(&self.shared, true);
        let metrics = self.shared.metrics.clone();
        let cfg = self.shared.cfg.clone();
        let mut core = self.shared.core.lock().unwrap_or_else(|e| e.into_inner());
        let guards: Vec<MutexGuard<'_, BandState>> = self
            .shared
            .bands
            .iter()
            .map(|m| m.lock().unwrap_or_else(|e| e.into_inner()))
            .collect();
        let refs: Vec<&OnlineHashState> = guards.iter().map(|g| &g.hash).collect();
        let hash_state = assemble_bands(&refs);
        let parts = StreamParts {
            model: core.model.take().expect("model present outside flush"),
            hash_state,
            combined_t: std::mem::replace(&mut core.combined_t, Triples::new(0, 0)),
            combined: Arc::clone(&core.combined),
            cells: std::mem::take(&mut core.cells),
            buffer: Vec::new(),
            last_flush_cols: std::mem::take(&mut core.last_flush_cols),
            last_flush_topk_moved: std::mem::take(&mut core.last_topk_moved),
            last_flush_rows: std::mem::take(&mut core.last_flush_rows),
            cfg,
            train_cfg: core.train_cfg.clone(),
            rng: core.rng.clone(),
            metrics: metrics.clone(),
        };
        let version = core.version;
        drop(guards);
        drop(core);
        let mut engine = Engine::new(StreamOrchestrator::from_parts(parts), self.clamp, metrics);
        engine.set_version(version);
        if let Some(p) = self.shared.persist.clone() {
            engine.attach_persister(p);
        }
        engine
    }
}

/// Band `b`'s writer: owns that band's queue; `Rate` commands validate,
/// stamp and buffer into the band's own state, and any flush trigger
/// (batch threshold, capacity, explicit `FLUSH`) runs the cross-band
/// epoch on this thread.
fn band_writer_loop(shared: Arc<BandedOrchestrator>, band: usize, rx: Receiver<BandCmd>) {
    let im = IngestMetrics::new(&shared.metrics);
    for cmd in rx {
        match cmd {
            BandCmd::Rate { i, j, r, reply } => {
                let _ = reply.send(ingest_rate(&shared, &im, band, i, j, r));
            }
            BandCmd::RateMany { batch, reply } => {
                let _ = reply.send(ingest_batch(&shared, &im, &batch));
            }
            BandCmd::Flush { reply } => {
                let _ = reply.send(flush_epoch(&shared, true));
            }
            BandCmd::Shutdown => break,
        }
    }
}

/// The per-rate path, ordered exactly like
/// [`StreamOrchestrator::ingest`]: validate, backpressure, buffer,
/// batch trigger. Only this band's lock is taken (briefly, to push) —
/// raters on other bands proceed in parallel.
///
/// Concurrent linearization: with `reject_when_full`, admission is an
/// atomic reserve on the global count, so backpressure rejects exactly
/// at `queue_capacity` even when raters race on different bands. A
/// flush trigger that loses its race (another band's epoch already
/// applied everything, so this epoch applies 0) answers `Buffered` —
/// the truthful reply for the linearization in which this rating
/// buffered and the *other* flush applied it — never `Flushed {0}`.
fn ingest_rate(
    shared: &BandedOrchestrator,
    im: &IngestMetrics,
    band: usize,
    i: u32,
    j: u32,
    r: f32,
) -> IngestResult {
    let cfg = &shared.cfg;
    if !r.is_finite() {
        im.invalid.inc();
        return IngestResult::InvalidValue;
    }
    if i as usize >= cfg.max_rows || j as usize >= cfg.max_cols {
        im.oob.inc();
        return IngestResult::OutOfBounds;
    }
    if cfg.reject_when_full {
        // Atomically reserve a buffer slot: reject iff the count is
        // already at capacity (check-then-act would let concurrent
        // raters on other bands overshoot the limit).
        let reserved = shared.buffered.fetch_update(
            Ordering::Relaxed,
            Ordering::Relaxed,
            |n| if n >= cfg.queue_capacity { None } else { Some(n + 1) },
        );
        if reserved.is_err() {
            im.rejected.inc();
            return IngestResult::Rejected;
        }
        buffer_rating(shared, band, i, j, r, true);
    } else {
        if shared.buffered.load(Ordering::Relaxed) >= cfg.queue_capacity {
            // Flush first, then retain the triggering event un-flushed
            // — the single-writer capacity contract.
            let applied = flush_epoch(shared, false);
            buffer_rating(shared, band, i, j, r, false);
            im.ingested.inc();
            return if applied > 0 {
                IngestResult::Flushed { applied }
            } else {
                IngestResult::Buffered
            };
        }
        buffer_rating(shared, band, i, j, r, false);
    }
    im.ingested.inc();
    if shared.buffered.load(Ordering::Relaxed) >= cfg.batch_size {
        let applied = flush_epoch(shared, false);
        if applied > 0 {
            return IngestResult::Flushed { applied };
        }
    }
    IngestResult::Buffered
}

/// Stamp and buffer one accepted rating into `band`, and keep the
/// *current* snapshot's buffered counter fresh (one relaxed store — the
/// same coherence discipline as the single-writer path). Everything
/// happens **inside the band lock**: a flush epoch holds every band
/// lock from steal through publish, so (a) each stolen entry's count
/// increment has provably landed — the epoch's `fetch_sub` can never
/// underflow — and (b) the snapshot read here is genuinely current —
/// a stale count can never land on a snapshot published after the
/// steal. (Holding the band lock across the snapshot read cannot
/// deadlock: the only writer of `snap` is an epoch, which takes the
/// write lock strictly after acquiring all band locks.) `reserved`
/// says the caller already counted this event (the atomic-reserve
/// backpressure path).
fn buffer_rating(
    shared: &BandedOrchestrator,
    band: usize,
    i: u32,
    j: u32,
    r: f32,
    reserved: bool,
) {
    let mut state = shared.bands[band].lock().unwrap_or_else(|e| e.into_inner());
    // Seq allocation and WAL append happen inside the band lock: an
    // epoch (which holds every band lock) can then trust that every
    // allocated seq has both landed in a buffer and reached its log —
    // the exact-watermark precondition of the checkpoint hook.
    let seq = shared.seq.fetch_add(1, Ordering::Relaxed);
    if let Some(p) = &shared.persist {
        p.append_rate(band, seq, i, j, r);
    }
    state.buffer.push(Stamped { seq, i, j, r });
    let now = if reserved {
        shared.buffered.load(Ordering::Relaxed)
    } else {
        shared.buffered.fetch_add(1, Ordering::Relaxed) + 1
    };
    shared
        .snap
        .read()
        .unwrap_or_else(|e| e.into_inner())
        .note_buffered(now);
}

/// The vectorized ingest path (`MRATE`), mirroring
/// [`StreamOrchestrator::ingest_batch`] step for step so batch replies
/// stay identical to the single-writer reference: all-or-nothing
/// validation in the same per-event value-then-bounds order, one atomic
/// backpressure reservation for the whole batch, then admission and the
/// batch-size trigger.
fn ingest_batch(
    shared: &BandedOrchestrator,
    im: &IngestMetrics,
    batch: &[(u32, u32, f32)],
) -> IngestResult {
    let cfg = &shared.cfg;
    if batch.is_empty() {
        return IngestResult::Ignored;
    }
    for &(i, j, r) in batch {
        if !r.is_finite() {
            im.invalid.inc();
            return IngestResult::InvalidValue;
        }
        if i as usize >= cfg.max_rows || j as usize >= cfg.max_cols {
            im.oob.inc();
            return IngestResult::OutOfBounds;
        }
    }
    let mut applied = 0usize;
    if cfg.reject_when_full {
        // One atomic reserve for the whole batch: reject unless the
        // buffer can hold all of it (no partial admission, and the
        // capacity stays exact under concurrent raters on other bands).
        let reserved = shared.buffered.fetch_update(Ordering::Relaxed, Ordering::Relaxed, |n| {
            if n + batch.len() > cfg.queue_capacity {
                None
            } else {
                Some(n + batch.len())
            }
        });
        if reserved.is_err() {
            im.rejected.inc();
            return IngestResult::Rejected;
        }
        buffer_batch(shared, batch, true);
    } else {
        if shared.buffered.load(Ordering::Relaxed) + batch.len() > cfg.queue_capacity {
            // Flush the backlog first, then admit the batch un-flushed —
            // the single-writer capacity contract, batch-wide.
            applied += flush_epoch(shared, false);
        }
        buffer_batch(shared, batch, false);
    }
    im.ingested.add(batch.len() as u64);
    if shared.buffered.load(Ordering::Relaxed) >= cfg.batch_size {
        applied += flush_epoch(shared, false);
    }
    if applied > 0 {
        IngestResult::Flushed { applied }
    } else {
        IngestResult::Buffered
    }
}

/// Stamp and distribute one admitted batch into its owning bands'
/// buffers, then refresh the current snapshot's buffered counter once.
/// The locks of every band **the batch touches** are held together —
/// acquired in ascending index order, the same order a flush epoch
/// uses, so the orders cannot cycle — which gives the batch the same
/// atomicity the single-writer path gets for free: an epoch acquires
/// *all* band locks before stealing, so it must wait on the touched
/// bands and can never steal half a batch, and every pushed entry's
/// count increment has provably landed before an epoch's `fetch_sub`
/// runs. Untouched bands stay unlocked, so batch ingest on disjoint
/// band sets proceeds in parallel. `reserved` says the caller already
/// counted the batch (the atomic-reserve backpressure path).
fn buffer_batch(shared: &BandedOrchestrator, batch: &[(u32, u32, f32)], reserved: bool) {
    let d = shared.bands.len();
    let ncols = shared.ncols.load(Ordering::Relaxed);
    let mut touched: Vec<usize> =
        batch.iter().map(|&(_, j, _)| route_col(j, ncols, d)).collect();
    touched.sort_unstable();
    touched.dedup();
    // slot[b] = index into `guards` for touched band b
    let mut slot = vec![usize::MAX; d];
    let mut guards: Vec<MutexGuard<'_, BandState>> = Vec::with_capacity(touched.len());
    for (idx, &b) in touched.iter().enumerate() {
        slot[b] = idx;
        guards.push(shared.bands[b].lock().unwrap_or_else(|e| e.into_inner()));
    }
    // One block allocation under the touched-band locks keeps the
    // batch's seqs contiguous — the shape the WAL batch record (and the
    // single-writer replay of it) requires. The carrying band is the
    // first event's, whose lock this batch holds.
    let base = shared.seq.fetch_add(batch.len() as u64, Ordering::Relaxed);
    if let Some(p) = &shared.persist {
        p.append_batch(route_col(batch[0].1, ncols, d), base, batch);
    }
    for (k, &(i, j, r)) in batch.iter().enumerate() {
        let seq = base + k as u64;
        guards[slot[route_col(j, ncols, d)]].buffer.push(Stamped { seq, i, j, r });
    }
    let now = if reserved {
        shared.buffered.load(Ordering::Relaxed)
    } else {
        shared.buffered.fetch_add(batch.len(), Ordering::Relaxed) + batch.len()
    };
    // As in `buffer_rating`: reading `snap` under the band locks cannot
    // deadlock (the only writer of `snap` is an epoch, which takes the
    // write lock strictly after acquiring all band locks — including at
    // least one this batch holds), and it guarantees the count lands on
    // a snapshot that precedes any post-steal publish.
    shared
        .snap
        .read()
        .unwrap_or_else(|e| e.into_inner())
        .note_buffered(now);
}

/// The cross-band flush epoch. Lock order `flush` → `core` →
/// `bands[0..d]`; per-rate paths take a single band lock and
/// `buffer_batch` takes the band locks in the same ascending order, so
/// the orders cannot cycle. Steals every band's buffer, restores global
/// arrival order via the sequence stamps, applies the batch through
/// exactly the single-writer computation, and publishes the per-band
/// shards. Returns the applied count. `explicit` marks client-driven
/// flushes (`FLUSH` verb, shutdown drain): those are external inputs a
/// replay cannot re-derive, so they log a WAL marker; threshold- and
/// capacity-triggered epochs re-fire deterministically and do not.
fn flush_epoch(shared: &BandedOrchestrator, explicit: bool) -> usize {
    let _epoch = shared.flush.lock().unwrap_or_else(|e| e.into_inner());
    let mut core_guard = shared.core.lock().unwrap_or_else(|e| e.into_inner());
    let core: &mut Core = &mut core_guard;
    let mut guards: Vec<MutexGuard<'_, BandState>> = shared
        .bands
        .iter()
        .map(|m| m.lock().unwrap_or_else(|e| e.into_inner()))
        .collect();

    let mut raw: Vec<Stamped> = Vec::new();
    for g in guards.iter_mut() {
        raw.append(&mut g.buffer);
    }
    if raw.is_empty() {
        return 0;
    }
    if explicit {
        if let Some(p) = &shared.persist {
            // All band locks are held: the marker's seq is greater than
            // every stolen event's and smaller than anything after the
            // epoch, so replay re-runs the flush at exactly this point.
            let seq = shared.seq.fetch_add(1, Ordering::Relaxed);
            p.append_flush(0, seq);
        }
    }
    shared.buffered.fetch_sub(raw.len(), Ordering::Relaxed);
    raw.sort_unstable_by_key(|e| e.seq);
    let batch: Vec<(u32, u32, f32)> = raw.iter().map(|e| (e.i, e.j, e.r)).collect();

    let old_rows = core.combined_t.nrows();
    let old_cols = core.combined_t.ncols();
    let new_rows = batch
        .iter()
        .map(|&(i, _, _)| i as usize + 1)
        .chain(std::iter::once(old_rows))
        .max()
        .unwrap();
    let new_cols = batch
        .iter()
        .map(|&(_, j, _)| j as usize + 1)
        .chain(std::iter::once(old_cols))
        .max()
        .unwrap();

    let applied = if new_cols > old_cols {
        grow_and_flush(shared, core, &mut guards, batch)
    } else {
        flush_in_place(shared, core, &mut guards, batch, old_rows, new_rows, old_cols)
    };
    if applied > 0 {
        publish_banded(shared, core, &guards);
        // Invalidate (and push-notify) strictly after the swap — see
        // the module invariants. Growth (rows or cols) clears the whole
        // cache; otherwise the epoch's own dirty-band report keys it.
        let d = guards.len();
        let grew = new_rows > old_rows || new_cols > old_cols;
        let dirty: Vec<u32> = if grew {
            Vec::new()
        } else {
            let mut bands: Vec<u32> =
                dirty_bands(&core.last_flush_cols, &core.last_topk_moved, new_cols, d)
                    .into_iter()
                    .map(|b| b as u32)
                    .collect();
            bands.sort_unstable();
            bands
        };
        shared.cache.invalidate(core.version, &dirty, &core.last_flush_rows, grew);
        if let Some(p) = &shared.persist {
            // Checkpoint hook, with every band lock still held: no seq
            // can be allocated concurrently, so `counter - 1` is an
            // exact watermark; the buffer is empty (all stolen) and the
            // band accumulators reassemble to the post-flush hash state.
            let counter = shared.seq.load(Ordering::Relaxed);
            p.bump_seq_to(counter);
            let refs: Vec<&OnlineHashState> = guards.iter().map(|g| &g.hash).collect();
            let hash = assemble_bands(&refs);
            let src = CheckpointSource {
                engine_version: core.version,
                clamp: shared.clamp,
                hash: &hash,
                model: core.model.as_ref().expect("model present outside flush"),
                triples: &core.combined_t,
                buffer: &[],
                rng: &core.rng,
            };
            p.note_applied_flush(&src, counter - 1);
        }
    }
    applied
}

/// The conflict-free in-place flush (no column growth, so band
/// boundaries are stable and every hash delta lands in the band that
/// owns the column). The computation is ordered exactly like
/// [`StreamOrchestrator::flush`] — merge order, dedup, per-column
/// absorb order, Top-K re-search, rng draws — which is what keeps
/// multi-writer replies bit-identical to the single-writer reference.
fn flush_in_place(
    shared: &BandedOrchestrator,
    core: &mut Core,
    guards: &mut [MutexGuard<'_, BandState>],
    batch: Vec<(u32, u32, f32)>,
    old_rows: usize,
    new_rows: usize,
    old_cols: usize,
) -> usize {
    let d = guards.len();
    let increment = dedup_batch(batch);
    core.combined_t.grow_to(new_rows, old_cols);
    let mut fresh: Vec<(u32, u32, f32)> = Vec::with_capacity(increment.len());
    let mut rerated = 0u64;
    for &(i, j, r) in &increment {
        if let Some(&pos) = core.cells.get(&(i, j)) {
            let old = core.combined_t.entries()[pos as usize].2;
            core.combined_t.entries_mut()[pos as usize].2 = r;
            let g: &mut BandState = &mut guards[band_of(j as usize, old_cols, d)];
            let local_j = j as usize - g.lo;
            g.hash.reabsorb(i as usize, local_j, old, r);
            rerated += 1;
        } else {
            core.cells.insert((i, j), core.combined_t.nnz() as u32);
            core.combined_t.push(i as usize, j as usize, r);
            fresh.push((i, j, r));
        }
    }
    // Fresh-cell absorption, band-local: each band takes its own
    // columns' entries in batch order, so every accumulator receives
    // exactly the delta sequence the monolithic `apply_increment`
    // would feed it (per-column order is all that f64 summation needs).
    for g in guards.iter_mut() {
        let g: &mut BandState = g;
        let (lo, hi) = (g.lo, g.hi);
        let local: Vec<(u32, u32, f32)> = fresh
            .iter()
            .filter(|&&(_, j, _)| (j as usize) >= lo && (j as usize) < hi)
            .map(|&(i, j, r)| (i, j - lo as u32, r))
            .collect();
        g.hash.apply_increment(&local, hi - lo);
    }
    shared.metrics.counter("stream.rerated").add(rerated);

    let combined = Arc::new(Csr::from_triples(&core.combined_t));
    let model = core.model.take().expect("model present outside flush");
    let k = model.k();
    let epochs = shared.cfg.online_epochs;
    let flush_mode = shared.cfg.flush_mode;
    let timer = shared.metrics.histogram("stream.flush_seconds");
    let refs: Vec<&OnlineHashState> = guards.iter().map(|g| &g.hash).collect();
    let train_cfg = &core.train_cfg;
    let rng = &mut core.rng;
    // Exact mode runs the single-threaded reference computation (bit-
    // identical replies); relaxed mode fans the re-search's signature
    // phase out band-locally and runs the training epochs on one
    // rotation lane per band — the training core finally executes
    // *inside* the epoch on band threads instead of one orchestrator
    // thread. Both modes consume the rng identically.
    let report = timer.time(|| match flush_mode {
        FlushMode::Exact => {
            let (topk, _) = topk_banded(&refs, k, rng);
            online_update_with_topk(
                model, topk, &combined, &fresh, old_rows, old_cols, train_cfg, epochs, rng,
            )
        }
        FlushMode::Relaxed => {
            let (topk, _) = topk_banded_parallel(&refs, k, rng);
            online_update_relaxed_with_topk(
                model, topk, &combined, &fresh, old_rows, old_cols, train_cfg, epochs, d,
                rng,
            )
        }
    });
    if flush_mode == FlushMode::Relaxed {
        record_relaxed_flush_metrics(&shared.metrics, &report.band_train_micros);
    }
    core.model = Some(report.model);
    core.combined = combined;
    core.last_flush_cols = increment.iter().map(|&(_, j, _)| j).collect();
    core.last_flush_rows = increment.iter().map(|&(i, _, _)| i).collect();
    core.last_topk_moved = report.topk_moved_cols;
    shared.metrics.counter("stream.flushes").inc();
    shared
        .metrics
        .counter("stream.applied")
        .add(increment.len() as u64);
    increment.len()
}

/// The cross-band growth barrier: every band writer is already
/// quiesced (the caller holds all band locks), the banded accumulators
/// are assembled back into one monolithic state, the single-writer
/// flush runs **verbatim** on a temporarily reassembled
/// [`StreamOrchestrator`] (column growth must relayout the whole
/// accumulator set anyway, so the assembly costs nothing extra
/// asymptotically), and the state re-splits on the recomputed band
/// boundaries before the writers resume.
fn grow_and_flush(
    shared: &BandedOrchestrator,
    core: &mut Core,
    guards: &mut [MutexGuard<'_, BandState>],
    batch: Vec<(u32, u32, f32)>,
) -> usize {
    let d = guards.len();
    let refs: Vec<&OnlineHashState> = guards.iter().map(|g| &g.hash).collect();
    let hash_state = assemble_bands(&refs);
    let parts = StreamParts {
        model: core.model.take().expect("model present outside flush"),
        hash_state,
        combined_t: std::mem::replace(&mut core.combined_t, Triples::new(0, 0)),
        combined: Arc::clone(&core.combined),
        cells: std::mem::take(&mut core.cells),
        buffer: batch,
        last_flush_cols: Vec::new(),
        last_flush_topk_moved: Vec::new(),
        last_flush_rows: Vec::new(),
        cfg: shared.cfg.clone(),
        train_cfg: core.train_cfg.clone(),
        rng: std::mem::replace(&mut core.rng, Rng::seeded(0)),
        metrics: shared.metrics.clone(),
    };
    let mut orch = StreamOrchestrator::from_parts(parts);
    let applied = orch.flush();
    let parts = orch.into_parts();
    core.model = Some(parts.model);
    core.combined_t = parts.combined_t;
    core.combined = parts.combined;
    core.cells = parts.cells;
    core.rng = parts.rng;
    core.last_flush_cols = parts.last_flush_cols;
    core.last_topk_moved = parts.last_flush_topk_moved;
    core.last_flush_rows = parts.last_flush_rows;
    let new_ncols = core.combined.ncols();
    for (b, (g, hash)) in guards
        .iter_mut()
        .zip(parts.hash_state.split_bands(d))
        .enumerate()
    {
        let (lo, hi) = band_range(b, new_ncols, d);
        g.hash = hash;
        g.lo = lo;
        g.hi = hi;
    }
    shared.ncols.store(new_ncols, Ordering::Relaxed);
    applied
}

/// Publish after a flush epoch: each band's shard is decided and built
/// independently — clean shards (per the flush's O(report) dirty set)
/// are reference-shared from the previous snapshot, dirty shards are
/// rebuilt concurrently on scoped builder threads acting for their band
/// — then one pointer swap installs the assembled snapshot.
fn publish_banded(
    shared: &BandedOrchestrator,
    core: &mut Core,
    guards: &[MutexGuard<'_, BandState>],
) {
    let prev = Arc::clone(&shared.snap.read().unwrap_or_else(|e| e.into_inner()));
    let model = core.model.as_ref().expect("model present outside flush");
    let matrix = Arc::clone(&core.combined);
    let (nrows, ncols) = (matrix.nrows(), matrix.ncols());
    let (prev_rows, prev_cols) = prev.dims();
    let d = guards.len();
    let mut bytes_cloned = 0usize;

    let rows = if nrows != prev_rows {
        let rf = model.row_factors();
        bytes_cloned += rf.bytes();
        Arc::new(rf)
    } else {
        prev.rows_arc()
    };

    let touched = dirty_bands(&core.last_flush_cols, &core.last_topk_moved, ncols, d);
    let ranges: Vec<Option<(usize, usize)>> = (0..d)
        .map(|b| {
            let clean = ncols == prev_cols && !touched.contains(&b);
            if clean {
                None
            } else {
                Some((guards[b].lo, guards[b].hi))
            }
        })
        .collect();
    let dirty_count = ranges.iter().flatten().count();
    let built: Vec<Option<ColBand>> = if dirty_count <= 1 {
        ranges
            .iter()
            .copied()
            .map(|r| r.map(|(lo, hi)| model.col_band(lo, hi)))
            .collect()
    } else {
        std::thread::scope(|s| {
            let builders: Vec<_> = ranges
                .iter()
                .copied()
                .map(|r| r.map(|(lo, hi)| s.spawn(move || model.col_band(lo, hi))))
                .collect();
            builders
                .into_iter()
                .map(|h| h.map(|h| h.join().expect("shard builder panicked")))
                .collect()
        })
    };
    let mut cloned_bands = vec![false; d];
    let shards: Vec<Arc<ColBand>> = built
        .into_iter()
        .enumerate()
        .map(|(b, band)| match band {
            Some(band) => {
                bytes_cloned += band.bytes();
                cloned_bands[b] = true;
                Arc::new(band)
            }
            None => Arc::clone(&prev.shards()[b]),
        })
        .collect();

    core.version += 1;
    let snap = Arc::new(Snapshot::assemble(
        rows,
        shards.into(),
        matrix,
        core.version,
        shared.buffered.load(Ordering::Relaxed),
    ));
    let swap = Instant::now();
    let mut guard = shared.snap.write().unwrap_or_else(|e| e.into_inner());
    *guard = snap;
    drop(guard);
    shared.publish.publish_wait().record(swap.elapsed());
    shared.publish.record(&cloned_bands, bytes_cloned);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::shared::SharedEngine;
    use crate::coordinator::stream::StreamOrchestrator;
    use crate::lsh::{OnlineHashState, SimLsh};
    use crate::mf::neighbourhood::{train_culsh_logged, CulshConfig};
    use crate::rng::Rng;
    use crate::sparse::{Csc, Csr, Triples};

    fn engine(rng: &mut Rng, stream_cfg: StreamConfig) -> Engine {
        let (m, n) = (25, 12);
        let mut t = Triples::new(m, n);
        let mut seen = std::collections::HashSet::new();
        while t.nnz() < 140 {
            let (i, j) = (rng.below(m), rng.below(n));
            if seen.insert((i, j)) {
                t.push(i, j, 1.0 + rng.f32() * 4.0);
            }
        }
        let csr = Csr::from_triples(&t);
        let csc = Csc::from_triples(&t);
        let lsh = SimLsh::new(1, 4, 8, 2);
        let hash_state = OnlineHashState::build(lsh, &csc);
        let (topk, _) = hash_state.topk(3, rng);
        let cfg = CulshConfig { f: 4, k: 3, epochs: 3, ..Default::default() };
        let (model, _) = train_culsh_logged(&csr, topk, &cfg, rng);
        let registry = Registry::new();
        let orch = StreamOrchestrator::new(
            model,
            hash_state,
            t,
            stream_cfg,
            cfg,
            rng.split(1),
            registry.clone(),
        );
        Engine::new(orch, (1.0, 5.0), registry)
    }

    #[test]
    fn reads_match_single_threaded_engine() {
        let mut rng = Rng::seeded(91);
        let e = engine(&mut rng, StreamConfig::default());
        let want_p = e.predict(2, 3);
        let want_top = e.top_n(2, 4);
        let want_many = e.predict_many(2, &[0, 3, 99]);
        for writers in [1usize, 2, 4] {
            let mut rng2 = Rng::seeded(91);
            let e = engine(&mut rng2, StreamConfig::default());
            let (banded, handle) = BandedEngine::spawn(e, writers);
            assert_eq!(banded.predict(2, 3), want_p, "writers={writers}");
            assert_eq!(banded.top_n(2, 4), want_top, "writers={writers}");
            assert_eq!(banded.predict_many(2, &[0, 3, 99]), want_many, "writers={writers}");
            assert!(banded.predict(999, 0).is_none());
            assert!(banded.top_n(999, 4).is_empty());
            assert!(banded.predict_many(999, &[0]).is_none());
            assert_eq!(banded.version(), 0);
            assert_eq!(banded.writers(), writers);
            handle.join();
        }
    }

    /// Batch-triggered flush through a band writer: growth applies, a
    /// snapshot publishes, and the joined engine holds the same state.
    #[test]
    fn rate_flush_publishes_new_snapshot() {
        let mut rng = Rng::seeded(92);
        let e = engine(&mut rng, StreamConfig { batch_size: 4, ..Default::default() });
        let (banded, handle) = BandedEngine::spawn(e, 3);
        let (m0, n0) = banded.dims();
        assert!(banded.predict(0, n0 + 2).is_none());
        for k in 0..3 {
            assert_eq!(banded.rate(0, (n0 + k) as u32, 5.0), IngestResult::Buffered);
        }
        // 4th rating hits batch_size -> cross-band flush -> publish; it
        // re-rates the 3rd cell, so last-write-wins dedup applies 3
        let res = banded.rate(0, (n0 + 2) as u32, 4.0);
        assert!(matches!(res, IngestResult::Flushed { applied: 3 }), "{res:?}");
        assert_eq!(banded.version(), 1);
        assert_eq!(banded.dims(), (m0, n0 + 3));
        let p = banded.predict(0, n0 + 2).unwrap();
        assert!((1.0..=5.0).contains(&p));
        let engine = handle.join();
        assert_eq!(engine.dims(), (m0, n0 + 3));
    }

    #[test]
    fn explicit_flush_and_stats() {
        let mut rng = Rng::seeded(93);
        let e = engine(&mut rng, StreamConfig::default());
        let (banded, handle) = BandedEngine::spawn(e, 2);
        assert_eq!(banded.rate(1, 2, 4.0), IngestResult::Buffered);
        let stats = banded.stats();
        assert!(stats.contains("buffered 1"), "{stats}");
        assert!(stats.contains("version 0"), "{stats}");
        assert!(stats.contains("writers 2"), "{stats}");
        assert_eq!(banded.flush(), 1);
        assert_eq!(banded.flush(), 0, "nothing left to apply");
        let stats = banded.stats();
        assert!(stats.contains("buffered 0"), "{stats}");
        assert!(stats.contains("version 1"), "{stats}");
        assert!(stats.contains("server.rate"), "{stats}");
        handle.join();
    }

    /// `MRATE` through a band writer: one round-trip admits the whole
    /// batch, events land in their owning bands, growth widens the
    /// barrier, and the reply matches the single-writer flavour.
    #[test]
    fn rate_many_distributes_across_bands() {
        let mut rng = Rng::seeded(90);
        let e = engine(&mut rng, StreamConfig { batch_size: 100, ..Default::default() });
        let (banded, handle) = BandedEngine::spawn(e, 4);
        let (_, n0) = banded.dims();
        assert_eq!(banded.rate_many(&[]), IngestResult::Ignored);
        assert_eq!(
            banded.rate_many(&[(0, 0, 3.0), (0, 5, f32::NAN)]),
            IngestResult::InvalidValue,
            "one bad value refuses the whole batch"
        );
        assert_eq!(banded.buffered(), 0);
        // a batch spanning every band plus a growth column
        let batch: Vec<(u32, u32, f32)> =
            vec![(0, 0, 3.0), (1, 5, 4.0), (2, 11, 2.0), (3, n0 as u32 + 2, 5.0)];
        assert_eq!(banded.rate_many(&batch), IngestResult::Buffered);
        assert_eq!(banded.buffered(), 4);
        assert_eq!(banded.flush(), 4);
        assert_eq!(banded.dims().1, n0 + 3, "growth applied through the barrier");
        let p = banded.predict(3, n0 + 2).expect("grown column must serve");
        assert!((1.0..=5.0).contains(&p));
        handle.join();
    }

    /// Batch backpressure stays global and batch-atomic across bands:
    /// the reservation covers the whole batch or rejects it whole, even
    /// though its events would land in different bands' buffers.
    #[test]
    fn rate_many_backpressure_is_batch_atomic_across_bands() {
        let mut rng = Rng::seeded(89);
        let e = engine(
            &mut rng,
            StreamConfig {
                queue_capacity: 3,
                batch_size: 100,
                reject_when_full: true,
                ..Default::default()
            },
        );
        let (banded, handle) = BandedEngine::spawn(e, 4);
        // cols 1 and 11 live in different bands (12 cols at d=4)
        assert_eq!(banded.rate_many(&[(0, 1, 3.0), (0, 11, 3.0)]), IngestResult::Buffered);
        assert_eq!(
            banded.rate_many(&[(0, 5, 3.0), (0, 7, 3.0)]),
            IngestResult::Rejected,
            "2 buffered + 2 > 3: reject the whole batch"
        );
        assert_eq!(banded.buffered(), 2, "no partial admission into any band");
        assert_eq!(banded.rate_many(&[(0, 5, 3.0)]), IngestResult::Buffered);
        banded.flush();
        handle.join();
    }

    /// `MRATE` replies match the single-writer flavour on the same
    /// sequential script (batches spanning bands, growth, a flush
    /// trigger) — the vectorized path is a transport optimization, not
    /// a semantic fork.
    #[test]
    fn rate_many_matches_shared_engine_sequence() {
        let cfgs = StreamConfig { batch_size: 5, max_rows: 500, max_cols: 500, ..Default::default() };
        let mut rng_a = Rng::seeded(88);
        let (shared, shared_writer) =
            SharedEngine::spawn_sharded(engine(&mut rng_a, cfgs.clone()), 3);
        let mut rng_b = Rng::seeded(88);
        let (banded, banded_handle) = BandedEngine::spawn(engine(&mut rng_b, cfgs), 3);
        let batches: Vec<Vec<(u32, u32, f32)>> = vec![
            vec![(0, 0, 3.0), (1, 11, 4.0)],
            vec![(2, 6, 2.0), (3, 14, 5.0), (4, 2, 1.5)], // 5th event -> flush + growth
            vec![(0, 0, 2.0)],
            vec![(5, 20, 4.5), (6, 1, 3.5)], // more growth
        ];
        for batch in &batches {
            assert_eq!(shared.rate_many(batch), banded.rate_many(batch), "{batch:?}");
        }
        assert_eq!(shared.flush(), banded.flush());
        assert_eq!(shared.dims(), banded.dims());
        for i in 0..26 {
            for j in 0..21 {
                assert_eq!(shared.predict(i, j), banded.predict(i, j), "predict({i},{j})");
            }
        }
        let ea = shared_writer.join();
        let eb = banded_handle.join();
        assert_eq!(ea.dims(), eb.dims());
    }

    /// Backpressure is a *global* contract: the threshold counts
    /// un-flushed events across every band's buffer, exactly like the
    /// single shared buffer it replaces.
    #[test]
    fn backpressure_is_global_across_bands() {
        let mut rng = Rng::seeded(94);
        let e = engine(
            &mut rng,
            StreamConfig {
                queue_capacity: 2,
                batch_size: 100,
                reject_when_full: true,
                ..Default::default()
            },
        );
        let (banded, handle) = BandedEngine::spawn(e, 4);
        // two buffered events land in different bands (cols 1 and 11 of
        // 12 at d=4), yet the third is rejected globally
        assert_eq!(banded.rate(0, 1, 3.0), IngestResult::Buffered);
        assert_eq!(banded.rate(0, 11, 3.0), IngestResult::Buffered);
        assert_eq!(banded.rate(0, 5, 3.0), IngestResult::Rejected);
        banded.flush();
        assert_eq!(banded.rate(0, 5, 3.0), IngestResult::Buffered);
        handle.join();
    }

    #[test]
    fn validation_round_trips_through_band_writers() {
        let mut rng = Rng::seeded(95);
        let e = engine(
            &mut rng,
            StreamConfig { max_rows: 1000, max_cols: 1000, ..Default::default() },
        );
        let (banded, handle) = BandedEngine::spawn(e, 3);
        assert_eq!(banded.rate(0, 1, f32::NAN), IngestResult::InvalidValue);
        assert_eq!(banded.rate(4_000_000_000, 0, 5.0), IngestResult::OutOfBounds);
        assert_eq!(banded.buffered(), 0);
        handle.join();
    }

    /// The shutdown coherence contract holds for the multi-writer path
    /// too: join drains, and the drained state is REPUBLISHED before the
    /// buffered counter drops to zero.
    #[test]
    fn shutdown_drain_republishes_before_zeroing_buffered() {
        let mut rng = Rng::seeded(97);
        let e = engine(&mut rng, StreamConfig::default());
        let (banded, handle) = BandedEngine::spawn(e, 4);
        let (m0, n0) = banded.dims();
        assert_eq!(banded.rate(0, n0 as u32, 5.0), IngestResult::Buffered);
        assert_eq!(banded.buffered(), 1);
        let engine = handle.join();
        assert_eq!(engine.dims(), (m0, n0 + 1), "join drained the rating");
        assert_eq!(banded.buffered(), 0);
        assert_eq!(banded.version(), 1, "the drain must publish");
        assert_eq!(banded.dims(), (m0, n0 + 1));
        let p = banded.predict(0, n0).expect("drained rating must be servable");
        assert!((1.0..=5.0).contains(&p));
        // writers are gone: writes surface as backpressure, reads serve
        assert_eq!(banded.rate(0, 0, 3.0), IngestResult::Rejected);
        assert_eq!(banded.flush(), 0);
    }

    /// The growth barrier recomputes band boundaries: after a flush that
    /// widens the universe, the published shards tile the new column
    /// axis exactly and new columns route and serve.
    #[test]
    fn growth_barrier_recomputes_band_boundaries() {
        let mut rng = Rng::seeded(98);
        let e = engine(&mut rng, StreamConfig::default());
        let (banded, handle) = BandedEngine::spawn(e, 4);
        let (_, n0) = banded.dims();
        // growth ratings spread across several bands plus new columns
        for (i, j) in [(0u32, 0u32), (1, 5), (2, n0 as u32 + 6), (3, n0 as u32)] {
            assert_eq!(banded.rate(i, j, 4.0), IngestResult::Buffered, "({i},{j})");
        }
        assert_eq!(banded.flush(), 4);
        let snap = banded.snapshot();
        assert_eq!(snap.dims().1, n0 + 7);
        let mut covered = 0usize;
        for shard in snap.shards() {
            assert_eq!(shard.lo, covered, "bands must tile contiguously");
            covered = shard.hi;
        }
        assert_eq!(covered, n0 + 7, "bands must cover the grown axis");
        assert!(banded.predict(2, n0 + 6).is_some());
        // post-growth traffic keeps flowing through the re-split bands
        assert_eq!(banded.rate(0, (n0 + 6) as u32, 2.0), IngestResult::Buffered);
        assert_eq!(banded.flush(), 1);
        handle.join();
    }

    /// A flush that touches one band clones only the dirty shards (per
    /// the O(report) dirty set); clean bands and the row factors
    /// republish by reference.
    #[test]
    fn publish_shares_clean_shards() {
        let mut rng = Rng::seeded(96);
        let e = engine(&mut rng, StreamConfig::default());
        let metrics = e.metrics().clone();
        let full_bytes = e.model().bytes() + e.matrix().bytes();
        let (banded, handle) = BandedEngine::spawn(e, 4);
        let before = banded.snapshot();
        // re-rate inside band 0 only (cols 0..3 of 12 at d=4)
        assert_eq!(banded.rate(0, 0, 3.5), IngestResult::Buffered);
        assert_eq!(banded.rate(1, 1, 2.5), IngestResult::Buffered);
        assert_eq!(banded.flush(), 2);
        let after = banded.snapshot();
        assert_eq!(after.version, 1);
        assert!(
            !Arc::ptr_eq(&before.shards()[0], &after.shards()[0]),
            "dirty band republished by reference"
        );
        assert!(
            Arc::ptr_eq(&before.rows_arc(), &after.rows_arc()),
            "row factors must be reference-shared when rows did not grow"
        );
        let cloned = metrics.gauge("shared.publish_bytes_cloned").get();
        assert!(cloned > 0.0);
        assert!(
            cloned < full_bytes as f64,
            "partial publish ({cloned}) must beat the full clone ({full_bytes})"
        );
        assert!(metrics.counter("shared.shard0.publishes").get() >= 1);
        handle.join();
    }

    /// Relaxed flush mode on the multi-writer path: the in-place epoch
    /// trains on band threads (the `flush.relaxed_epochs` counter and
    /// every band's `flush.band<b>.train_micros` appear in the shared
    /// registry — the `STATS` contract), the snapshot publishes, and
    /// reads serve the grown universe.
    #[test]
    fn relaxed_flush_epoch_trains_on_band_threads() {
        let mut rng = Rng::seeded(86);
        let e = engine(
            &mut rng,
            StreamConfig {
                batch_size: 1_000,
                flush_mode: FlushMode::Relaxed,
                flush_bands: 4,
                ..Default::default()
            },
        );
        let metrics = e.metrics().clone();
        let (banded, handle) = BandedEngine::spawn(e, 4);
        let (m0, n0) = banded.dims();
        // 24 distinct new-row cells over every column band — enough
        // trainable entries to clear the rotation cutoff, no column
        // growth, so the band-parallel in-place epoch runs.
        for q in 0..24u32 {
            let (i, j) = (m0 as u32 + q / 12, q % 12);
            assert_eq!(banded.rate(i, j, 2.0 + (q % 3) as f32), IngestResult::Buffered);
        }
        assert_eq!(banded.flush(), 24);
        assert_eq!(banded.version(), 1);
        assert_eq!(banded.dims(), (m0 + 2, n0));
        let p = banded.predict(m0, 3).expect("new row must serve after the epoch");
        assert!((1.0..=5.0).contains(&p));
        let stats = banded.stats();
        assert!(stats.contains("flush.relaxed_epochs 1"), "{stats}");
        for b in 0..4 {
            assert!(
                stats.contains(&format!("flush.band{b}.train_micros")),
                "band {b} timing missing:\n{stats}"
            );
        }
        assert_eq!(metrics.counter("flush.relaxed_epochs").get(), 1);
        handle.join();
    }

    /// The multi-writer engine's full write/read protocol surface
    /// matches the single-writer [`SharedEngine`] step for step on the
    /// same seed (the randomized cross-check lives in `tests/props.rs`).
    #[test]
    fn banded_matches_shared_engine_sequence() {
        let cfgs = StreamConfig { batch_size: 5, max_rows: 500, max_cols: 500, ..Default::default() };
        let mut rng_a = Rng::seeded(99);
        let (shared, shared_writer) =
            SharedEngine::spawn_sharded(engine(&mut rng_a, cfgs.clone()), 3);
        let mut rng_b = Rng::seeded(99);
        let (banded, banded_handle) = BandedEngine::spawn(engine(&mut rng_b, cfgs), 3);
        let script: Vec<(u32, u32, f32)> = vec![
            (0, 0, 3.0),
            (1, 11, 4.0),
            (2, 6, 2.0),
            (3, 14, 5.0), // growth: col 14 > 11
            (4, 2, 1.5),  // 5th -> batch flush with growth
            (0, 0, 2.0),
            (5, 20, 4.5), // more growth
        ];
        for &(i, j, r) in &script {
            assert_eq!(shared.rate(i, j, r), banded.rate(i, j, r), "rate({i},{j},{r})");
        }
        assert_eq!(shared.flush(), banded.flush());
        assert_eq!(shared.dims(), banded.dims());
        assert_eq!(shared.version(), banded.version());
        for i in 0..26 {
            for j in 0..21 {
                assert_eq!(shared.predict(i, j), banded.predict(i, j), "predict({i},{j})");
            }
            assert_eq!(shared.top_n(i, 5), banded.top_n(i, 5), "top_n({i})");
        }
        let ea = shared_writer.join();
        let eb = banded_handle.join();
        assert_eq!(ea.dims(), eb.dims());
    }
}
